"""AOT compiler: lower every stage entry point to HLO **text** artifacts.

This is the only place Python touches the system: ``make artifacts`` runs it
once, the rust runtime (``rust/src/runtime``) loads the text with
``HloModuleProto::from_text_file`` and compiles it on the PJRT CPU client.

HLO text — NOT ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifact bundle layout (one directory per model x pipeline split x mbs):

  artifacts/<cfg>-s<STAGES>-mb<MBS>/
    meta.json             # shapes, param counts, FLOPs — rust reads this
    stage<i>_init.hlo.txt # (key u32[2]) -> flat_params
    stage<i>_fwd.hlo.txt
    stage<i>_bwd.hlo.txt
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_stage(
    spec: model.StageSpec,
    mbs: int,
    out_dir: pathlib.Path,
    *,
    use_flash: bool = True,
    use_fused_xent: bool = True,
) -> dict:
    """Lower init/fwd/bwd for one stage; returns its meta entry."""
    fns = model.make_stage_fns(
        spec, use_flash=use_flash, use_fused_xent=use_fused_xent
    )
    flat, h, tok = model.example_inputs(spec, mbs)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    single = spec.n_stages == 1

    if spec.has_head and single:
        fwd_args = (flat, tok, tok)
        bwd_args = (flat, tok, tok)
    elif spec.has_head:
        fwd_args = (flat, h, tok)
        bwd_args = (flat, h, tok)
    elif spec.has_embed:
        fwd_args = (flat, tok)
        bwd_args = (flat, tok, h)
    else:
        fwd_args = (flat, h)
        bwd_args = (flat, h, h)

    entries = {}
    for name, fn, args in (
        ("init", fns["init"], (key,)),
        ("fwd", fns["fwd"], fwd_args),
        ("bwd", fns["bwd"], bwd_args),
    ):
        text = to_hlo_text(jax.jit(fn).lower(*args))
        fname = f"stage{spec.index}_{name}.hlo.txt"
        (out_dir / fname).write_text(text)
        entries[name] = fname

    return {
        "index": spec.index,
        "layer_start": spec.layer_start,
        "layer_end": spec.layer_end,
        "has_embed": spec.has_embed,
        "has_head": spec.has_head,
        "param_count": fns["n_params"],
        "artifacts": entries,
    }


def build_bundle(
    cfg_name: str,
    n_stages: int,
    mbs: int,
    root: pathlib.Path,
    *,
    use_flash: bool = True,
    use_fused_xent: bool = True,
    force: bool = False,
) -> pathlib.Path:
    cfg = configs.get(cfg_name)
    out_dir = root / f"{cfg.name}-s{n_stages}-mb{mbs}"
    meta_path = out_dir / "meta.json"
    if meta_path.exists() and not force:
        print(f"[aot] {out_dir} up to date, skipping")
        return out_dir
    out_dir.mkdir(parents=True, exist_ok=True)

    specs = model.make_stages(cfg, n_stages)
    stages = [
        lower_stage(
            spec, mbs, out_dir, use_flash=use_flash, use_fused_xent=use_fused_xent
        )
        for spec in specs
    ]

    tokens_per_mb = mbs * cfg.seq
    meta = {
        "model": {
            "name": cfg.name,
            "n_layers": cfg.n_layers,
            "hidden": cfg.hidden,
            "n_heads": cfg.n_heads,
            "vocab": cfg.vocab,
            "seq": cfg.seq,
            "total_params": cfg.total_params(),
        },
        "n_stages": n_stages,
        "mbs": mbs,
        "use_flash": use_flash,
        "use_fused_xent": use_fused_xent,
        "tokens_per_microbatch": tokens_per_mb,
        "flops_per_microbatch": cfg.flops_per_token() * tokens_per_mb,
        "stages": stages,
    }
    meta_path.write_text(json.dumps(meta, indent=2))
    print(f"[aot] wrote {out_dir} ({n_stages} stages, mbs={mbs})")
    return out_dir


# Bundles `make artifacts` builds by default: what the rust tests, examples
# and the e2e driver load.
DEFAULT_BUNDLES = [
    # (config, n_stages, mbs)
    ("tiny", 1, 2),
    ("tiny", 2, 2),
    ("mini", 2, 2),
    ("mini", 4, 1),
    ("gpt-10m", 2, 1),
    ("gpt-125m", 4, 1),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", help="model config name (see configs.py)")
    ap.add_argument("--stages", type=int, default=1)
    ap.add_argument("--mbs", type=int, default=1, help="micro-batch size")
    ap.add_argument("--out", default="../artifacts", help="artifact root dir")
    ap.add_argument("--no-flash", action="store_true")
    ap.add_argument("--no-fused-xent", action="store_true")
    ap.add_argument("--force", action="store_true", help="rebuild even if fresh")
    args = ap.parse_args(argv)

    root = pathlib.Path(args.out)
    kw = dict(
        use_flash=not args.no_flash,
        use_fused_xent=not args.no_fused_xent,
        force=args.force,
    )
    if args.config:
        build_bundle(args.config, args.stages, args.mbs, root, **kw)
    else:
        for cfg_name, n_stages, mbs in DEFAULT_BUNDLES:
            build_bundle(cfg_name, n_stages, mbs, root, **kw)
    return 0


if __name__ == "__main__":
    sys.exit(main())
