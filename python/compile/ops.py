"""Differentiable wrappers around the L1 Pallas kernels.

``pallas_call`` has no automatic autodiff rule, so each kernel is exposed
through ``jax.custom_vjp``: the forward is the Pallas kernel, the backward
recomputes what it needs with pure jnp — exactly the Flash-Attention
strategy (recompute scores in the backward instead of storing the
``seq x seq`` probability matrix), which is also what the paper's
``checkpoint-activations=True`` recipe (Table V) does at stage level.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .kernels import flash_attention as _flash_kernel
from .kernels import layernorm as _ln_kernel
from .kernels import softmax_xent as _xent_kernel
from .kernels import ref as _ref

# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@jax.custom_vjp
def attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal self-attention; forward runs the Pallas flash kernel."""
    return _flash_kernel(q, k, v, causal=True)


def _attention_fwd(q, k, v):
    return attention(q, k, v), (q, k, v)


def _attention_bwd(res, g):
    """FA-style backward: recompute the score matrix, never store it.

    dV = P^T dO;  dP = dO V^T;  dS = P * (dP - rowsum(dP * P));
    dQ = dS K * scale;  dK = dS^T Q * scale.
    """
    q, k, v = res
    seq, head_dim = q.shape[-2], q.shape[-1]
    scale = 1.0 / math.sqrt(head_dim)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    mask = jnp.tril(jnp.ones((seq, seq), dtype=bool))
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    g = g.astype(jnp.float32)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, g)
    dp = jnp.einsum("bhqd,bhkd->bhqk", g, v.astype(jnp.float32))
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, k.astype(jnp.float32)) * scale
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, q.astype(jnp.float32)) * scale
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


attention.defvjp(_attention_fwd, _attention_bwd)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Naive attention (materialised scores) — the paper's pre-FA baseline."""
    return _ref.attention_ref(q, k, v, causal=True)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------


@jax.custom_vjp
def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array) -> jax.Array:
    return _ln_kernel(x, gamma, beta)


def _ln_fwd(x, gamma, beta):
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + 1e-5)
    xhat = (xf - mean) * inv
    y = (xhat * gamma + beta).astype(x.dtype)
    # zero-size sentinel carries the primal dtype (residuals must be arrays)
    return y, (xhat, inv, gamma, jnp.zeros((0,), x.dtype))


def _ln_bwd(res, g):
    xhat, inv, gamma, dtype_sentinel = res
    dtype = dtype_sentinel.dtype
    g = g.astype(jnp.float32)
    d = xhat.shape[-1]
    dgamma = jnp.sum(g * xhat, axis=tuple(range(g.ndim - 1)))
    dbeta = jnp.sum(g, axis=tuple(range(g.ndim - 1)))
    gx = g * gamma
    dx = inv * (
        gx
        - jnp.mean(gx, axis=-1, keepdims=True)
        - xhat * jnp.mean(gx * xhat, axis=-1, keepdims=True)
    )
    return dx.astype(dtype), dgamma, dbeta


layernorm.defvjp(_ln_fwd, _ln_bwd)


# ---------------------------------------------------------------------------
# softmax cross-entropy
# ---------------------------------------------------------------------------


@jax.custom_vjp
def softmax_xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Per-token CE; forward streams vocab blocks through the Pallas kernel."""
    return _xent_kernel(logits, targets)


def _xent_fwd(logits, targets):
    return softmax_xent(logits, targets), (logits, targets)


def _xent_bwd(res, g):
    logits, targets = res
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
    dlogits = (p - onehot) * g[:, None]
    return dlogits.astype(logits.dtype), None


softmax_xent.defvjp(_xent_fwd, _xent_bwd)


def gelu(x: jax.Array) -> jax.Array:
    """tanh-approximation GELU (what Megatron's fused kernel computes)."""
    return jax.nn.gelu(x, approximate=True)
