"""L1 Pallas kernel: fused softmax + cross-entropy over the vocabulary.

The LM-head loss is the other memory-bound hot-spot of GPT training: the
naive lowering materialises ``(tokens, vocab)`` probabilities.  This kernel
streams vocabulary blocks through VMEM with an online logsumexp (the same
recurrence flash-attention uses for its softmax) and accumulates the target
logit with a masked sum — no gather, no materialised probability matrix.

loss[t] = logsumexp(logits[t, :]) - logits[t, target[t]]

Runs ``interpret=True``.  Oracle: ``ref.softmax_xent_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_ROWS = 128
DEFAULT_BLOCK_V = 512

NEG_INF = -1e30


def _xent_kernel(
    logits_ref,
    tgt_ref,
    loss_ref,
    m_ref,
    l_ref,
    t_ref,
    *,
    block_v: int,
    num_v_blocks: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        t_ref[...] = jnp.zeros_like(t_ref)

    s = logits_ref[...].astype(jnp.float32)  # (block_rows, block_v)
    tgt = tgt_ref[...]  # (block_rows, 1) int32

    col = j * block_v + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)

    # Accumulate the target logit: exactly one column matches per row
    # (padded rows carry target -1 and never match).
    t_ref[...] += jnp.sum(jnp.where(col == tgt, s, 0.0), axis=-1, keepdims=True)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    l_ref[...] = jnp.exp(m_prev - m_new) * l_ref[...] + jnp.sum(
        jnp.exp(s - m_new), axis=-1, keepdims=True
    )
    m_ref[...] = m_new

    @pl.when(j == num_v_blocks - 1)
    def _finalize():
        lse = m_ref[...] + jnp.log(l_ref[...])
        loss_ref[...] = (lse - t_ref[...]).astype(loss_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_v"))
def softmax_xent(
    logits: jax.Array,
    targets: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_v: int = DEFAULT_BLOCK_V,
) -> jax.Array:
    """Per-token cross-entropy; ``logits (n, V)``, ``targets (n,) int32``."""
    if logits.ndim != 2 or targets.shape != logits.shape[:1]:
        raise ValueError(f"bad shapes: logits {logits.shape}, targets {targets.shape}")
    n, v = logits.shape

    block_rows = min(block_rows, max(n, 1))
    block_v = min(block_v, max(v, 1))

    n_pad = ((n + block_rows - 1) // block_rows) * block_rows
    v_pad = ((v + block_v - 1) // block_v) * block_v
    if n_pad != n or v_pad != v:
        # Pad rows with target -1 (matches no column) and vocab columns with
        # NEG_INF so they cannot win the max or contribute to the sum.
        logits = jnp.pad(
            logits, [(0, n_pad - n), (0, v_pad - v)], constant_values=NEG_INF
        )
        targets = jnp.pad(targets, [(0, n_pad - n)], constant_values=-1)

    tgt2 = targets.reshape(n_pad, 1).astype(jnp.int32)

    loss = pl.pallas_call(
        functools.partial(
            _xent_kernel, block_v=block_v, num_v_blocks=v_pad // block_v
        ),
        grid=(n_pad // block_rows, v_pad // block_v),
        in_specs=[
            pl.BlockSpec((block_rows, block_v), lambda i, j: (i, j)),
            pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_rows, 1), jnp.float32),
            pltpu.VMEM((block_rows, 1), jnp.float32),
            pltpu.VMEM((block_rows, 1), jnp.float32),
        ],
        interpret=True,
    )(logits, tgt2)

    return loss.reshape(n_pad)[:n]
