"""Pallas kernels (L1) and their pure-jnp oracles.

Everything here is build-time Python: kernels are lowered (interpret=True)
into the HLO artifacts the rust runtime executes; nothing in this package
runs on the request path.
"""

from .flash_attention import flash_attention
from .layernorm import layernorm
from .softmax_xent import softmax_xent

__all__ = ["flash_attention", "layernorm", "softmax_xent"]
