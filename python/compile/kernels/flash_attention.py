"""L1 Pallas kernel: Flash-Attention (tiled online-softmax) for TPU.

The paper's single named kernel-level optimization is Flash-Attention-2
(§V.A: "up to 30% throughput improvement").  FA2 is a CUDA/ROCm algorithm
expressed with threadblocks staging Q/K/V tiles in shared memory (LDS on
MI250X) and warp-level softmax reductions.  This file is the TPU rethink
(DESIGN.md §Hardware-Adaptation):

  * LDS tiles           -> ``BlockSpec``-driven HBM->VMEM blocks.  The grid
    iterates (batch*heads, q_block, k_block); Pallas keeps one
    ``(block_q, head_dim)`` Q tile and one ``(block_k, head_dim)`` K/V tile
    resident in VMEM per step and double-buffers the HBM transfers.
  * warp shuffle max/sum -> lane-wise vector ops on ``(block_q, 1)`` running
    max / running sum carried in VMEM scratch across the k_block grid
    dimension (the innermost, fastest-varying one).
  * tensor-core MMA      -> MXU: QK^T and PV contractions over full tiles,
    accumulated in f32 regardless of the input dtype.

``interpret=True`` is mandatory here: the CPU PJRT backend cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO so the kernel
runs inside the AOT artifacts the rust runtime loads.  Correctness is pinned
to ``ref.attention_ref`` by ``python/tests/test_flash_attention.py``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

# A finite stand-in for -inf: keeps exp() exactly 0 for fully-masked rows
# without generating NaNs via (-inf) - (-inf) in the rescale path.
NEG_INF = -1e30


def _attention_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
):
    """One (bh, q_block, k_block) grid step of the online-softmax recurrence.

    Scratch refs (``acc``, ``m``, ``l``) persist across the innermost
    k_block dimension; the output tile is finalised on the last k step.
    """
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (block_q, head_dim)
    k = k_ref[0].astype(jnp.float32)  # (block_k, head_dim)
    v = v_ref[0].astype(jnp.float32)  # (block_k, head_dim)

    # MXU contraction: scores tile.
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    s = s * scale  # (block_q, block_k)

    if causal:
        i = pl.program_id(1)
        row = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        col = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(col <= row, s, NEG_INF)

    m_prev = m_ref[...]  # (block_q, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)

    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)  # rescale factor for the old state

    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new

    @pl.when(j == num_k_blocks - 1)
    def _finalize():
        # Fully-masked rows (can only happen with padding) have l == 0;
        # guard the divide so they emit 0 instead of NaN.
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "scale")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """Tiled attention over ``(batch, heads, seq, head_dim)`` inputs.

    Equivalent to ``softmax(q @ k^T * scale [+ causal mask]) @ v`` computed
    without materialising the ``seq x seq`` score matrix.  ``seq`` is padded
    internally to a block multiple; block sizes are clamped to ``seq``.
    """
    if q.ndim != 4:
        raise ValueError(f"expected (batch, heads, seq, head_dim), got {q.shape}")
    if q.shape != k.shape or q.shape != v.shape:
        raise ValueError(f"q/k/v shape mismatch: {q.shape} {k.shape} {v.shape}")
    batch, heads, seq, head_dim = q.shape
    if scale is None:
        scale = 1.0 / math.sqrt(head_dim)

    block_q = min(block_q, max(seq, 1))
    block_k = min(block_k, max(seq, 1))

    # Pad seq to a common multiple of both blocks.  Padded key columns are
    # neutralised by the causal mask for rows < seq and by the final slice
    # for rows >= seq; for non-causal attention we mask them explicitly by
    # padding K with NEG_INF-producing zeros and relying on the causal=False
    # path below adding an explicit validity mask.
    pad_to = math.lcm(block_q, block_k)
    seq_p = ((seq + pad_to - 1) // pad_to) * pad_to

    if seq_p != seq:
        pad = [(0, 0), (0, 0), (0, seq_p - seq), (0, 0)]
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)

    bh = batch * heads
    q3 = q.reshape(bh, seq_p, head_dim)
    k3 = k.reshape(bh, seq_p, head_dim)
    v3 = v.reshape(bh, seq_p, head_dim)

    num_q_blocks = seq_p // block_q
    num_k_blocks = seq_p // block_k

    # Non-causal with padding needs the padded key columns masked out.  We
    # fold that into the same masked-score path by enabling the causal
    # branch only when asked; padding correctness for the non-causal case is
    # handled by masking scores against the true seq length.
    kernel = functools.partial(
        _attention_kernel,
        scale=scale,
        causal=causal or seq_p != seq,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=num_k_blocks,
    )
    if not causal and seq_p != seq:
        # Rare test-only path (ragged non-causal): fall back to masking via
        # causal-style iota against seq. Implemented by running the causal
        # kernel with an amended mask is incorrect; instead just slice-pad K
        # scores by running unpadded when possible.
        raise ValueError(
            "non-causal flash_attention requires seq to be a multiple of "
            f"block sizes (seq={seq}, block_q={block_q}, block_k={block_k})"
        )

    grid = (bh, num_q_blocks, num_k_blocks)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, head_dim), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, head_dim), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, head_dim), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, head_dim), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq_p, head_dim), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, head_dim), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q3, k3, v3)

    out = out.reshape(batch, heads, seq_p, head_dim)
    if seq_p != seq:
        out = out[:, :, :seq, :]
    return out


def vmem_footprint_bytes(
    block_q: int, block_k: int, head_dim: int, dtype_bytes: int = 2
) -> int:
    """Estimated VMEM residency of one grid step (for DESIGN.md §Perf).

    One Q tile + one K tile + one V tile + one O tile (input dtype), plus
    f32 scratch (acc, m, l) and the f32 score tile the compiler keeps live.
    """
    tiles = (block_q + 2 * block_k + block_q) * head_dim * dtype_bytes
    scratch = (block_q * head_dim + 2 * block_q) * 4
    scores = block_q * block_k * 4
    return tiles + scratch + scores


def mxu_utilization_estimate(block_q: int, block_k: int, head_dim: int) -> float:
    """Fraction of MXU 128x128 tiles fed full by the chosen block shapes."""

    def eff(n: int) -> float:
        return min(n, 128) / 128.0

    # Two contractions per step: (bq x d) @ (d x bk) and (bq x bk) @ (bk x d).
    qk = eff(block_q) * eff(head_dim) * eff(block_k)
    pv = eff(block_q) * eff(block_k) * eff(head_dim)
    return (qk + pv) / 2.0
