"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: small, obviously-right lowerings
with no tiling, no online recurrences, no padding tricks.  Every kernel in
this package must match its `*_ref` to float32 tolerance (pytest +
hypothesis sweeps in ``python/tests/``).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    """Naive softmax attention over ``(batch, heads, seq, head_dim)``."""
    *_, seq, head_dim = q.shape
    if scale is None:
        scale = 1.0 / math.sqrt(head_dim)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((seq, seq), dtype=bool))
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def layernorm_ref(
    x: jax.Array, gamma: jax.Array, beta: jax.Array, *, eps: float = 1e-5
) -> jax.Array:
    """LayerNorm over the last axis, f32 internals."""
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps) * gamma + beta
    return y.astype(x.dtype)


def softmax_xent_ref(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Per-token cross-entropy; ``logits (n, V)``, ``targets (n,)``."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    tgt = jnp.take_along_axis(lf, targets[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return lse - tgt
