"""L1 Pallas kernel: fused LayerNorm.

Megatron-DeepSpeed ships a fused LayerNorm CUDA kernel (one of the ops the
paper had to hipify for ROCm, §II.F.1).  The TPU expression: block rows into
VMEM, compute the row mean/variance with lane-wise reductions, and apply
scale+shift in the same pass — one HBM read and one HBM write per element
instead of the separate mean/var/normalise passes of the naive lowering.

Runs ``interpret=True`` (CPU PJRT).  Oracle: ``ref.layernorm_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (block_rows, d)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mean
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    y = xc * inv * g_ref[...] + b_ref[...]
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def layernorm(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    *,
    eps: float = 1e-5,
    block_rows: int = DEFAULT_BLOCK_ROWS,
) -> jax.Array:
    """LayerNorm over the last axis of ``x`` (any leading shape)."""
    if gamma.shape != x.shape[-1:] or beta.shape != x.shape[-1:]:
        raise ValueError(
            f"gamma/beta must be ({x.shape[-1]},), got {gamma.shape}/{beta.shape}"
        )
    lead = x.shape[:-1]
    d = x.shape[-1]
    n = 1
    for s in lead:
        n *= s
    x2 = x.reshape(n, d)

    block_rows = min(block_rows, max(n, 1))
    n_pad = ((n + block_rows - 1) // block_rows) * block_rows
    if n_pad != n:
        x2 = jnp.pad(x2, [(0, n_pad - n), (0, 0)])

    g2 = gamma.reshape(1, d)
    b2 = beta.reshape(1, d)

    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(n_pad // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), x.dtype),
        interpret=True,
    )(x2, g2, b2)

    if n_pad != n:
        out = out[:n]
    return out.reshape(*lead, d)
