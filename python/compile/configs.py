"""Model configurations (L2).

``PAPER_ZOO`` mirrors Table I of the paper — these define the 22B/175B/1T
architectures used by the rust performance model (which has its own copy in
``rust/src/config/model.rs``; ``tests/test_configs.py`` cross-checks the
parameter-count formula against the paper's 12·L·d² rule).

``EXEC_ZOO`` are the configurations we actually lower to HLO and train
end-to-end on the CPU PJRT backend.  They follow the same GPT-2-style
architecture, just sized for a single-core testbed.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """GPT-style decoder-only transformer architecture."""

    name: str
    n_layers: int
    hidden: int
    n_heads: int
    vocab: int = 32000
    seq: int = 2048

    def __post_init__(self) -> None:
        if self.hidden % self.n_heads != 0:
            raise ValueError(
                f"{self.name}: hidden {self.hidden} not divisible by "
                f"heads {self.n_heads}"
            )

    @property
    def head_dim(self) -> int:
        return self.hidden // self.n_heads

    @property
    def ffn_hidden(self) -> int:
        return 4 * self.hidden

    def layer_params(self) -> int:
        """Parameters of one transformer layer.

        Attention: qkv (d x 3d + 3d bias) + proj (d x d + d bias);
        FFN: d x 4d + 4d and 4d x d + d; two LayerNorms (2d each).
        The paper's back-of-envelope is 11 d**2 (Fig 2) / 12 L d**2 total;
        the exact count below includes biases and norms.
        """
        d = self.hidden
        attn = d * 3 * d + 3 * d + d * d + d
        ffn = d * 4 * d + 4 * d + 4 * d * d + d
        norms = 4 * d
        return attn + ffn + norms

    def embed_params(self) -> int:
        return self.vocab * self.hidden + self.seq * self.hidden

    def head_params(self) -> int:
        """Final LayerNorm + untied LM head."""
        return 2 * self.hidden + self.hidden * self.vocab

    def total_params(self) -> int:
        return (
            self.embed_params()
            + self.n_layers * self.layer_params()
            + self.head_params()
        )

    def paper_params(self) -> int:
        """The paper's 12·L·d² estimate (§II.A)."""
        return 12 * self.n_layers * self.hidden * self.hidden

    def flops_per_token(self) -> float:
        """Training FLOPs per token, ~6N plus attention quadratic term."""
        n = self.total_params()
        attn_extra = 12.0 * self.n_layers * self.hidden * self.seq
        return 6.0 * n + attn_extra

    def stage_layers(self, n_stages: int) -> List[Tuple[int, int]]:
        """Split ``n_layers`` into ``n_stages`` contiguous [start, end) spans,
        earlier stages taking the remainder (Megatron-style)."""
        if not 1 <= n_stages <= self.n_layers:
            raise ValueError(
                f"n_stages must be in [1, {self.n_layers}], got {n_stages}"
            )
        base, rem = divmod(self.n_layers, n_stages)
        spans = []
        start = 0
        for i in range(n_stages):
            size = base + (1 if i < rem else 0)
            spans.append((start, start + size))
            start += size
        return spans

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))


# Table I of the paper.  (The 1.4B row prints hidden=2114, which is not
# divisible by its 24 heads — an apparent typo for 2112; we use 2112 and
# note the delta in EXPERIMENTS.md.)
PAPER_ZOO: Dict[str, ModelConfig] = {
    "1.4b": ModelConfig("1.4b", n_layers=24, hidden=2112, n_heads=24, vocab=51200),
    "22b": ModelConfig("22b", n_layers=48, hidden=6144, n_heads=48, vocab=51200),
    "175b": ModelConfig("175b", n_layers=96, hidden=12288, n_heads=96, vocab=51200),
    "1t": ModelConfig("1t", n_layers=128, hidden=25600, n_heads=128, vocab=51200),
}

# Configurations small enough to lower + execute on this testbed.
EXEC_ZOO: Dict[str, ModelConfig] = {
    # unit-test scale: lowers in seconds, runs in milliseconds
    "tiny": ModelConfig("tiny", n_layers=2, hidden=64, n_heads=2, vocab=256, seq=32),
    # integration scale: ~4 pipeline stages worth of layers
    "mini": ModelConfig("mini", n_layers=4, hidden=128, n_heads=4, vocab=512, seq=64),
    # e2e scale: ~10M params, trains a few hundred steps in minutes
    "gpt-10m": ModelConfig(
        "gpt-10m", n_layers=4, hidden=256, n_heads=8, vocab=4096, seq=128
    ),
    # headline e2e scale: ~124M params (GPT-2 small shape)
    "gpt-125m": ModelConfig(
        "gpt-125m", n_layers=12, hidden=768, n_heads=12, vocab=16384, seq=256
    ),
}

ZOO: Dict[str, ModelConfig] = {**PAPER_ZOO, **EXEC_ZOO}


def get(name: str) -> ModelConfig:
    try:
        return ZOO[name]
    except KeyError:
        raise KeyError(f"unknown model config {name!r}; known: {sorted(ZOO)}")
