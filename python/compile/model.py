"""L2: GPT-style decoder model in JAX, partitioned into pipeline stages.

The paper trains GPT models (Table I) under Megatron-DeepSpeed's 3D
parallelism.  The rust coordinator (L3) owns the parallelism; this module
owns the *per-stage compute graphs* it schedules:

  stage 0        : embedding (+ first span of layers)
  stages 1..p-2  : spans of transformer layers
  stage p-1      : last span + final LayerNorm + LM head + CE loss

Every stage exposes three entry points, each lowered by ``aot.py`` to a
standalone HLO-text artifact the rust runtime compiles once and executes on
the request path:

  init(key)                  -> flat_params
  fwd(flat_params, x[, tgt]) -> y | loss
  bwd(flat_params, x[, tgt], gy) -> (gflat, gx[, loss])

Parameters travel as ONE flat f32 vector per stage (``ravel_pytree``
ordering): the rust side then treats optimizer state, ZeRO-1 shards and
gradient all-reduces as operations over contiguous buffers, exactly like
DeepSpeed's flattened fp32 groups.

Backward entry points RECOMPUTE the stage forward inside the vjp instead of
consuming saved activations — this is activation checkpointing at stage
granularity, matching the paper's recipe (Table V: checkpoint-activations).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from . import ops
from .configs import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# stage specification
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One pipeline stage: which layers it owns and whether it carries the
    embedding (first stage) and/or the head+loss (last stage)."""

    cfg: ModelConfig
    index: int
    n_stages: int
    layer_start: int
    layer_end: int

    @property
    def has_embed(self) -> bool:
        return self.index == 0

    @property
    def has_head(self) -> bool:
        return self.index == self.n_stages - 1

    @property
    def n_layers(self) -> int:
        return self.layer_end - self.layer_start


def make_stages(cfg: ModelConfig, n_stages: int) -> List[StageSpec]:
    spans = cfg.stage_layers(n_stages)
    return [
        StageSpec(cfg, i, n_stages, start, end)
        for i, (start, end) in enumerate(spans)
    ]


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def _init_layer(key: jax.Array, cfg: ModelConfig) -> Params:
    d = cfg.hidden
    k = jax.random.split(key, 4)
    std = 0.02
    return {
        "ln1_g": jnp.ones((d,), jnp.float32),
        "ln1_b": jnp.zeros((d,), jnp.float32),
        "w_qkv": std * jax.random.normal(k[0], (d, 3 * d), jnp.float32),
        "b_qkv": jnp.zeros((3 * d,), jnp.float32),
        "w_proj": std * jax.random.normal(k[1], (d, d), jnp.float32),
        "b_proj": jnp.zeros((d,), jnp.float32),
        "ln2_g": jnp.ones((d,), jnp.float32),
        "ln2_b": jnp.zeros((d,), jnp.float32),
        "w_fc": std * jax.random.normal(k[2], (d, 4 * d), jnp.float32),
        "b_fc": jnp.zeros((4 * d,), jnp.float32),
        "w_out": std * jax.random.normal(k[3], (4 * d, d), jnp.float32),
        "b_out": jnp.zeros((d,), jnp.float32),
    }


def init_stage_params(key: jax.Array, spec: StageSpec) -> Params:
    """Initialise one stage's parameters.

    Partition-INDEPENDENT: every layer's key is derived by folding its
    *global* layer index into the base key (embedding and head get fixed
    sentinel indices), so re-partitioning the model across a different
    number of pipeline stages reproduces bit-identical parameters — the
    invariant that lets `tests/engine.rs` compare a 2-stage pipeline
    against the fused single-stage baseline.
    """
    cfg = spec.cfg
    params: Params = {
        "layers": [
            _init_layer(jax.random.fold_in(key, spec.layer_start + i), cfg)
            for i in range(spec.n_layers)
        ]
    }
    if spec.has_embed:
        params["tok_emb"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 1_000_000), (cfg.vocab, cfg.hidden), jnp.float32
        )
        params["pos_emb"] = 0.01 * jax.random.normal(
            jax.random.fold_in(key, 1_000_001), (cfg.seq, cfg.hidden), jnp.float32
        )
    if spec.has_head:
        params["lnf_g"] = jnp.ones((cfg.hidden,), jnp.float32)
        params["lnf_b"] = jnp.zeros((cfg.hidden,), jnp.float32)
        params["w_head"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 1_000_002), (cfg.hidden, cfg.vocab), jnp.float32
        )
    return params


@functools.lru_cache(maxsize=None)
def _stage_unravel(spec: StageSpec):
    """(param_count, unravel_fn) for a stage, derived without running init."""
    shapes = jax.eval_shape(
        lambda: init_stage_params(jax.random.PRNGKey(0), spec)
    )
    leaves, treedef = jax.tree_util.tree_flatten(shapes)
    zeros = [jnp.zeros(l.shape, l.dtype) for l in leaves]
    template = jax.tree_util.tree_unflatten(treedef, zeros)
    flat, unravel = ravel_pytree(template)
    return int(flat.size), unravel


def stage_param_count(spec: StageSpec) -> int:
    return _stage_unravel(spec)[0]


# ---------------------------------------------------------------------------
# forward compute
# ---------------------------------------------------------------------------


def _layer_fwd(p: Params, x: jax.Array, cfg: ModelConfig, use_flash: bool) -> jax.Array:
    """Pre-LN transformer layer: x + attn(ln1(x)); h + ffn(ln2(h))."""
    b, s, d = x.shape
    h = ops.layernorm(x, p["ln1_g"], p["ln1_b"])
    qkv = h @ p["w_qkv"] + p["b_qkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t: jax.Array) -> jax.Array:
        return t.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)

    attn_fn = ops.attention if use_flash else ops.attention_ref
    a = attn_fn(heads(q), heads(k), heads(v))
    a = a.transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + a @ p["w_proj"] + p["b_proj"]

    h = ops.layernorm(x, p["ln2_g"], p["ln2_b"])
    h = ops.gelu(h @ p["w_fc"] + p["b_fc"])
    return x + h @ p["w_out"] + p["b_out"]


def _embed_fwd(p: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    s = tokens.shape[1]
    h = jnp.take(p["tok_emb"], tokens, axis=0)
    return h + p["pos_emb"][:s][None, :, :]


def _head_loss_fwd(
    p: Params, x: jax.Array, targets: jax.Array, use_fused_xent: bool
) -> jax.Array:
    """Final LN + LM head + mean next-token CE."""
    h = ops.layernorm(x, p["lnf_g"], p["lnf_b"])
    logits = h @ p["w_head"]  # (b, s, V)
    b, s, v = logits.shape
    flat_logits = logits.reshape(b * s, v)
    flat_targets = targets.reshape(b * s)
    if use_fused_xent:
        loss = ops.softmax_xent(flat_logits, flat_targets)
    else:
        lf = flat_logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        tgt = jnp.take_along_axis(
            lf, flat_targets[:, None].astype(jnp.int32), axis=-1
        )[:, 0]
        loss = lse - tgt
    return jnp.mean(loss)


def stage_apply(
    spec: StageSpec,
    params: Params,
    x: jax.Array,
    targets: jax.Array | None = None,
    *,
    use_flash: bool = True,
    use_fused_xent: bool = True,
) -> jax.Array:
    """Run one stage: tokens->h for stage 0, h->h for middle, h->loss last."""
    cfg = spec.cfg
    h = _embed_fwd(params, x, cfg) if spec.has_embed else x
    for lp in params["layers"]:
        h = _layer_fwd(lp, h, cfg, use_flash)
    if spec.has_head:
        assert targets is not None, "last stage needs targets"
        return _head_loss_fwd(params, h, targets, use_fused_xent)
    return h


# ---------------------------------------------------------------------------
# flat-parameter entry points (what aot.py lowers)
# ---------------------------------------------------------------------------


def make_stage_fns(
    spec: StageSpec, *, use_flash: bool = True, use_fused_xent: bool = True
) -> Dict[str, Any]:
    """Build the jit-able flat-signature functions for one stage.

    Returns a dict with callables:
      ``init(key_data: uint32[2]) -> (flat,)``
      ``fwd(flat, x[, targets]) -> (y,) | (loss,)``
      ``bwd``:
        stage 0      : (flat, tokens, gy)       -> (gflat,)
        middle       : (flat, x, gy)            -> (gflat, gx)
        last (p > 1) : (flat, x, targets)       -> (gflat, gx, loss)
        single stage : (flat, tokens, targets)  -> (gflat, loss)
    """
    n_params, unravel = _stage_unravel(spec)
    kw = dict(use_flash=use_flash, use_fused_xent=use_fused_xent)

    def init(key_data: jax.Array):
        key = jax.random.wrap_key_data(key_data, impl="threefry2x32")
        flat, _ = ravel_pytree(init_stage_params(key, spec))
        return (flat,)

    single = spec.n_stages == 1

    if spec.has_head:

        def fwd(flat, x, targets):
            return (stage_apply(spec, unravel(flat), x, targets, **kw),)

        if single:

            def bwd(flat, tokens, targets):
                def f(fl):
                    return stage_apply(spec, unravel(fl), tokens, targets, **kw)

                loss, pull = jax.vjp(f, flat)
                (gflat,) = pull(jnp.float32(1.0))
                return gflat, loss

        else:

            def bwd(flat, x, targets):
                def f(fl, xx):
                    return stage_apply(spec, unravel(fl), xx, targets, **kw)

                loss, pull = jax.vjp(f, flat, x)
                gflat, gx = pull(jnp.float32(1.0))
                return gflat, gx, loss

    else:

        def fwd(flat, x):
            return (stage_apply(spec, unravel(flat), x, **kw),)

        if spec.has_embed:

            def bwd(flat, tokens, gy):
                def f(fl):
                    return stage_apply(spec, unravel(fl), tokens, **kw)

                _, pull = jax.vjp(f, flat)
                (gflat,) = pull(gy)
                return (gflat,)

        else:

            def bwd(flat, x, gy):
                def f(fl, xx):
                    return stage_apply(spec, unravel(fl), xx, **kw)

                _, pull = jax.vjp(f, flat, x)
                gflat, gx = pull(gy)
                return gflat, gx

    return {"init": init, "fwd": fwd, "bwd": bwd, "n_params": n_params}


def full_loss(
    cfg: ModelConfig,
    stage_flats: List[jax.Array],
    tokens: jax.Array,
    targets: jax.Array,
    n_stages: int,
    **kw,
) -> jax.Array:
    """Whole-model loss from per-stage flat params (numerics cross-check)."""
    specs = make_stages(cfg, n_stages)
    h: jax.Array = tokens
    for spec, flat in zip(specs, stage_flats):
        _, unravel = _stage_unravel(spec)
        if spec.has_head:
            return stage_apply(spec, unravel(flat), h, targets, **kw)
        h = stage_apply(spec, unravel(flat), h, **kw)
    raise AssertionError("unreachable")


def example_inputs(
    spec: StageSpec, mbs: int
) -> Tuple[jax.ShapeDtypeStruct, ...]:
    """ShapeDtypeStructs for lowering each entry point of a stage."""
    cfg = spec.cfg
    f32, i32 = jnp.float32, jnp.int32
    flat = jax.ShapeDtypeStruct((stage_param_count(spec),), f32)
    h = jax.ShapeDtypeStruct((mbs, cfg.seq, cfg.hidden), f32)
    tok = jax.ShapeDtypeStruct((mbs, cfg.seq), i32)
    return flat, h, tok
