"""L2 model tests: stage partitioning, flat-parameter round trips,
pipeline-vs-monolith gradient equality, and partition-independent init."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import configs, model


TINY = configs.get("tiny")


def key_data(seed):
    return jnp.asarray(np.array([0, seed], dtype=np.uint32))


def init_stages(cfg, n_stages, seed=7):
    specs = model.make_stages(cfg, n_stages)
    fns = [model.make_stage_fns(s) for s in specs]
    flats = [f["init"](key_data(seed))[0] for f in fns]
    return specs, fns, flats


def sample_batch(cfg, b=2, seed=0):
    rng = np.random.default_rng(seed)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (b, cfg.seq)).astype(np.int32))
    tgt = jnp.asarray(rng.integers(0, cfg.vocab, (b, cfg.seq)).astype(np.int32))
    return tok, tgt


class TestConfigs:
    def test_paper_zoo_matches_table1(self):
        for name, layers, hidden, heads in [
            ("22b", 48, 6144, 48),
            ("175b", 96, 12288, 96),
            ("1t", 128, 25600, 128),
        ]:
            c = configs.get(name)
            assert (c.n_layers, c.hidden, c.n_heads) == (layers, hidden, heads)

    def test_param_formula_close_to_12ld2(self):
        for name in ["22b", "175b", "1t"]:
            c = configs.get(name)
            rel = abs(c.total_params() - c.paper_params()) / c.paper_params()
            assert rel < 0.15, name

    def test_stage_layers_partition(self):
        c = configs.get("175b")
        for p in [1, 3, 16, 96]:
            spans = c.stage_layers(p)
            assert spans[0][0] == 0 and spans[-1][1] == c.n_layers
            assert all(a[1] == b[0] for a, b in zip(spans, spans[1:]))

    def test_invalid_stage_counts(self):
        with pytest.raises(ValueError):
            TINY.stage_layers(0)
        with pytest.raises(ValueError):
            TINY.stage_layers(TINY.n_layers + 1)

    def test_heads_divide_hidden(self):
        with pytest.raises(ValueError):
            configs.ModelConfig("bad", 2, 65, 2, 100, 32)


class TestStageFns:
    def test_param_counts_sum(self):
        for n_stages in [1, 2]:
            specs, fns, flats = init_stages(TINY, n_stages)
            total = sum(f["n_params"] for f in fns)
            assert total == TINY.total_params()
            for f, flat in zip(fns, flats):
                assert flat.size == f["n_params"]

    def test_forward_shapes(self):
        specs, fns, flats = init_stages(TINY, 2)
        tok, tgt = sample_batch(TINY)
        (h,) = fns[0]["fwd"](flats[0], tok)
        assert h.shape == (2, TINY.seq, TINY.hidden)
        (loss,) = fns[1]["fwd"](flats[1], h, tgt)
        assert loss.shape == ()
        assert float(loss) > 0

    def test_pipeline_grads_match_monolith(self):
        specs, fns, flats = init_stages(TINY, 2)
        tok, tgt = sample_batch(TINY)
        (h,) = fns[0]["fwd"](flats[0], tok)
        g1, gh, loss = fns[1]["bwd"](flats[1], h, tgt)
        (g0,) = fns[0]["bwd"](flats[0], tok, gh)

        def floss(f0, f1):
            return model.full_loss(TINY, [f0, f1], tok, tgt, 2)

        g0_ref, g1_ref = jax.grad(floss, argnums=(0, 1))(flats[0], flats[1])
        np.testing.assert_allclose(np.asarray(g0), np.asarray(g0_ref), atol=1e-6)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g1_ref), atol=1e-6)
        np.testing.assert_allclose(float(loss), float(floss(flats[0], flats[1])), atol=1e-5)

    def test_partition_independent_init(self):
        # concatenated stage params must be identical for 1 and 2 stages
        _, _, flats1 = init_stages(TINY, 1, seed=3)
        _, _, flats2 = init_stages(TINY, 2, seed=3)
        # NOTE: ravel order within a stage is embed/head + layers; compare
        # through the loss instead of raw concatenation
        tok, tgt = sample_batch(TINY)
        l1 = model.full_loss(TINY, flats1, tok, tgt, 1)
        l2 = model.full_loss(TINY, flats2, tok, tgt, 2)
        np.testing.assert_allclose(float(l1), float(l2), atol=1e-5)

    def test_different_seeds_different_params(self):
        _, _, a = init_stages(TINY, 1, seed=1)
        _, _, b = init_stages(TINY, 1, seed=2)
        assert not np.allclose(np.asarray(a[0]), np.asarray(b[0]))

    def test_single_stage_bwd_returns_loss(self):
        specs, fns, flats = init_stages(TINY, 1)
        tok, tgt = sample_batch(TINY)
        gflat, loss = fns[0]["bwd"](flats[0], tok, tgt)
        assert gflat.shape == flats[0].shape
        (loss_fwd,) = fns[0]["fwd"](flats[0], tok, tgt)
        np.testing.assert_allclose(float(loss), float(loss_fwd), atol=1e-5)

    def test_flash_and_ref_attention_agree_in_model(self):
        specs = model.make_stages(TINY, 1)
        flat = model.make_stage_fns(specs[0])["init"](key_data(5))[0]
        tok, tgt = sample_batch(TINY)
        with_flash = model.make_stage_fns(specs[0], use_flash=True)["fwd"](flat, tok, tgt)
        without = model.make_stage_fns(specs[0], use_flash=False)["fwd"](flat, tok, tgt)
        np.testing.assert_allclose(float(with_flash[0]), float(without[0]), atol=1e-3)

    def test_fused_and_naive_xent_agree_in_model(self):
        specs = model.make_stages(TINY, 1)
        flat = model.make_stage_fns(specs[0])["init"](key_data(5))[0]
        tok, tgt = sample_batch(TINY)
        fused = model.make_stage_fns(specs[0], use_fused_xent=True)["fwd"](flat, tok, tgt)
        naive = model.make_stage_fns(specs[0], use_fused_xent=False)["fwd"](flat, tok, tgt)
        np.testing.assert_allclose(float(fused[0]), float(naive[0]), atol=1e-4)

    @settings(max_examples=6, deadline=None)
    @given(n_stages=st.integers(1, 2), b=st.integers(1, 3), seed=st.integers(0, 1000))
    def test_hypothesis_loss_reasonable(self, n_stages, b, seed):
        # fresh params, random batch: loss must sit near log(vocab)
        specs, fns, flats = init_stages(TINY, n_stages, seed=seed % 50 + 1)
        tok, tgt = sample_batch(TINY, b=b, seed=seed)
        loss = float(model.full_loss(TINY, flats, tok, tgt, n_stages))
        assert 0.5 * np.log(TINY.vocab) < loss < 2.0 * np.log(TINY.vocab)
