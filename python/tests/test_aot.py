"""AOT pipeline tests: HLO text emission, meta.json schema, caching."""

import json
import pathlib

import jax
import jax.numpy as jnp
import pytest

from compile import aot, configs, model


@pytest.fixture(scope="module")
def bundle(tmp_path_factory):
    root = tmp_path_factory.mktemp("artifacts")
    out = aot.build_bundle("tiny", 2, 2, root)
    return out


def test_bundle_layout(bundle):
    names = sorted(p.name for p in bundle.iterdir())
    assert "meta.json" in names
    for i in range(2):
        for kind in ["init", "fwd", "bwd"]:
            assert f"stage{i}_{kind}.hlo.txt" in names


def test_hlo_is_text_not_proto(bundle):
    text = (bundle / "stage0_fwd.hlo.txt").read_text()
    # HLO text starts with the module declaration and is pure ASCII
    assert text.lstrip().startswith("HloModule")
    assert text.isascii()
    # entry computation present
    assert "ENTRY" in text


def test_meta_schema(bundle):
    meta = json.loads((bundle / "meta.json").read_text())
    cfg = configs.get("tiny")
    assert meta["model"]["total_params"] == cfg.total_params()
    assert meta["n_stages"] == 2
    assert meta["mbs"] == 2
    assert meta["tokens_per_microbatch"] == 2 * cfg.seq
    stages = meta["stages"]
    assert stages[0]["has_embed"] and not stages[0]["has_head"]
    assert stages[1]["has_head"] and not stages[1]["has_embed"]
    assert sum(s["param_count"] for s in stages) == cfg.total_params()
    specs = model.make_stages(cfg, 2)
    for s, spec in zip(stages, specs):
        assert s["param_count"] == model.stage_param_count(spec)


def test_cache_skip_and_force(bundle, capsys):
    # second build with same params must skip
    out = aot.build_bundle("tiny", 2, 2, bundle.parent)
    assert out == bundle
    assert "skipping" in capsys.readouterr().out


def test_unknown_config_rejected(tmp_path):
    with pytest.raises(KeyError):
        aot.build_bundle("no-such-model", 1, 1, tmp_path)


def test_single_stage_bundle(tmp_path):
    out = aot.build_bundle("tiny", 1, 1, tmp_path)
    meta = json.loads((out / "meta.json").read_text())
    assert meta["n_stages"] == 1
    s = meta["stages"][0]
    assert s["has_embed"] and s["has_head"]


def test_lowering_is_deterministic(bundle, tmp_path):
    """The same (config, stages, mbs) must lower to byte-identical HLO —
    the property that makes `make artifacts` reproducible and lets the
    rust runtime cache compiled executables by path."""
    out2 = aot.build_bundle("tiny", 2, 2, tmp_path)
    for name in ["stage0_fwd.hlo.txt", "stage1_bwd.hlo.txt", "stage0_init.hlo.txt"]:
        a = (bundle / name).read_text()
        b = (out2 / name).read_text()
        assert a == b, f"{name} differs between lowerings"


def test_fwd_hlo_declares_expected_signature(bundle):
    """stage0 fwd consumes a flat f32 param vector and s32[2,32] tokens and
    emits f32[2,32,64] activations (visible in the entry layout)."""
    text = (bundle / "stage0_fwd.hlo.txt").read_text()
    header = text.splitlines()[0]
    assert "entry_computation_layout" in header, header
    assert "s32[2,32]" in header, header
    assert "f32[2,32,64]" in header, header
