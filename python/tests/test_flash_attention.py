"""Flash-attention Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes per the repro brief; fixed cases pin the
block-boundary and padding edge cases.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attention
from compile.kernels import ref


def rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


def assert_matches_ref(q, k, v, causal=True, **kw):
    out = flash_attention(q, k, v, causal=causal, **kw)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), atol=2e-3, rtol=2e-3
    )


class TestFixedShapes:
    def test_single_block(self):
        rng = np.random.default_rng(0)
        q, k, v = (rand(rng, 1, 1, 16, 8) for _ in range(3))
        assert_matches_ref(q, k, v, block_q=16, block_k=16)

    def test_multi_block_exact_tiling(self):
        rng = np.random.default_rng(1)
        q, k, v = (rand(rng, 2, 2, 64, 16) for _ in range(3))
        assert_matches_ref(q, k, v, block_q=16, block_k=32)

    def test_ragged_seq_needs_padding(self):
        # 50 is not a multiple of 16: exercises the pad+mask path
        rng = np.random.default_rng(2)
        q, k, v = (rand(rng, 2, 3, 50, 16) for _ in range(3))
        assert_matches_ref(q, k, v, block_q=16, block_k=16)

    def test_non_causal(self):
        rng = np.random.default_rng(3)
        q, k, v = (rand(rng, 1, 2, 32, 8) for _ in range(3))
        assert_matches_ref(q, k, v, causal=False, block_q=16, block_k=16)

    def test_non_causal_ragged_rejected(self):
        rng = np.random.default_rng(4)
        q, k, v = (rand(rng, 1, 1, 30, 8) for _ in range(3))
        with pytest.raises(ValueError):
            flash_attention(q, k, v, causal=False, block_q=16, block_k=16)

    def test_custom_scale(self):
        rng = np.random.default_rng(5)
        q, k, v = (rand(rng, 1, 1, 32, 8) for _ in range(3))
        out = flash_attention(q, k, v, causal=True, scale=0.5, block_q=16, block_k=16)
        want = ref.attention_ref(q, k, v, causal=True, scale=0.5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-3)

    def test_shape_mismatch_rejected(self):
        rng = np.random.default_rng(6)
        q = rand(rng, 1, 1, 16, 8)
        k = rand(rng, 1, 1, 32, 8)
        with pytest.raises(ValueError):
            flash_attention(q, k, q)
        with pytest.raises(ValueError):
            flash_attention(q[0], k[0], q[0])  # 3D input

    def test_first_row_attends_only_to_itself(self):
        # causal row 0 == v row 0 regardless of everything else
        rng = np.random.default_rng(7)
        q, k, v = (rand(rng, 1, 1, 32, 8) for _ in range(3))
        out = flash_attention(q, k, v, block_q=16, block_k=16)
        np.testing.assert_allclose(
            np.asarray(out)[0, 0, 0], np.asarray(v)[0, 0, 0], atol=1e-5
        )

    def test_numerically_large_logits_stable(self):
        # online softmax must not overflow where naive exp would
        rng = np.random.default_rng(8)
        q = rand(rng, 1, 1, 32, 8) * 30.0
        k = rand(rng, 1, 1, 32, 8) * 30.0
        v = rand(rng, 1, 1, 32, 8)
        out = flash_attention(q, k, v, block_q=16, block_k=16)
        assert np.isfinite(np.asarray(out)).all()

    def test_bfloat16_inputs(self):
        rng = np.random.default_rng(9)
        q, k, v = (
            jnp.asarray(rng.standard_normal((1, 2, 32, 16)), dtype=jnp.bfloat16)
            for _ in range(3)
        )
        out = flash_attention(q, k, v, block_q=16, block_k=16)
        want = ref.attention_ref(q, k, v, causal=True)
        assert out.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(out, dtype=np.float32),
            np.asarray(want, dtype=np.float32),
            atol=5e-2,
            rtol=5e-2,
        )


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 3),
    heads=st.integers(1, 3),
    seq=st.integers(2, 96),
    head_dim=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shape_sweep(batch, heads, seq, head_dim, causal, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (rand(rng, batch, heads, seq, head_dim) for _ in range(3))
    bq, bk = 16, 16
    if not causal and seq % math.lcm(bq, bk) != 0:
        causal = True  # non-causal requires aligned seq (documented)
    assert_matches_ref(q, k, v, causal=causal, block_q=bq, block_k=bk)


def test_vmem_footprint_model():
    from compile.kernels.flash_attention import (
        mxu_utilization_estimate,
        vmem_footprint_bytes,
    )

    # the shipped default blocks must fit comfortably in 16 MiB VMEM
    assert vmem_footprint_bytes(128, 128, 128) < 16 * 2**20
    # and feed the MXU full tiles
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    assert mxu_utilization_estimate(8, 8, 8) < 0.01
