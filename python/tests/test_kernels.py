"""LayerNorm and fused softmax-xent Pallas kernels vs their oracles, plus
the differentiable wrappers in ops.py (custom_vjp correctness against
jax.grad of the reference implementations)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import ops
from compile.kernels import layernorm, softmax_xent
from compile.kernels import ref


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


class TestLayerNorm:
    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        x, g, b = rand(rng, 33, 65), rand(rng, 65), rand(rng, 65)
        np.testing.assert_allclose(
            np.asarray(layernorm(x, g, b)),
            np.asarray(ref.layernorm_ref(x, g, b)),
            atol=1e-5,
        )

    def test_3d_input(self):
        rng = np.random.default_rng(1)
        x, g, b = rand(rng, 2, 17, 32), rand(rng, 32), rand(rng, 32)
        np.testing.assert_allclose(
            np.asarray(layernorm(x, g, b)),
            np.asarray(ref.layernorm_ref(x, g, b)),
            atol=1e-5,
        )

    def test_bad_gamma_shape(self):
        rng = np.random.default_rng(2)
        with pytest.raises(ValueError):
            layernorm(rand(rng, 4, 8), rand(rng, 9), rand(rng, 8))

    def test_output_stats(self):
        # unit gamma, zero beta => each row ~N(0,1)
        rng = np.random.default_rng(3)
        x = rand(rng, 64, 256) * 5.0 + 3.0
        y = np.asarray(layernorm(x, jnp.ones(256), jnp.zeros(256)))
        np.testing.assert_allclose(y.mean(axis=-1), 0.0, atol=1e-4)
        np.testing.assert_allclose(y.std(axis=-1), 1.0, atol=1e-2)

    @settings(max_examples=20, deadline=None)
    @given(
        rows=st.integers(1, 70),
        d=st.integers(2, 130),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, rows, d, seed):
        rng = np.random.default_rng(seed)
        x, g, b = rand(rng, rows, d), rand(rng, d), rand(rng, d)
        np.testing.assert_allclose(
            np.asarray(layernorm(x, g, b, block_rows=16)),
            np.asarray(ref.layernorm_ref(x, g, b)),
            atol=2e-4,
            rtol=2e-4,
        )


class TestSoftmaxXent:
    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        logits = rand(rng, 37, 101) * 3
        tgt = jnp.asarray(rng.integers(0, 101, 37).astype(np.int32))
        np.testing.assert_allclose(
            np.asarray(softmax_xent(logits, tgt)),
            np.asarray(ref.softmax_xent_ref(logits, tgt)),
            atol=1e-4,
        )

    def test_blocked_vocab(self):
        rng = np.random.default_rng(1)
        logits = rand(rng, 16, 1000)
        tgt = jnp.asarray(rng.integers(0, 1000, 16).astype(np.int32))
        out = softmax_xent(logits, tgt, block_rows=8, block_v=128)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.softmax_xent_ref(logits, tgt)), atol=1e-4
        )

    def test_perfect_prediction_low_loss(self):
        v = 64
        logits = jnp.full((4, v), -20.0)
        tgt = jnp.asarray([1, 5, 9, 13], dtype=jnp.int32)
        logits = logits.at[jnp.arange(4), tgt].set(20.0)
        loss = np.asarray(softmax_xent(logits, tgt))
        assert (loss < 1e-3).all()

    def test_uniform_logits_log_vocab(self):
        v = 128
        logits = jnp.zeros((3, v))
        tgt = jnp.asarray([0, 64, 127], dtype=jnp.int32)
        np.testing.assert_allclose(
            np.asarray(softmax_xent(logits, tgt)), np.log(v), atol=1e-5
        )

    def test_extreme_logits_stable(self):
        logits = jnp.asarray([[1e4, -1e4, 0.0]])
        tgt = jnp.asarray([0], dtype=jnp.int32)
        assert np.isfinite(np.asarray(softmax_xent(logits, tgt))).all()

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(1, 40),
        v=st.integers(2, 600),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, n, v, seed):
        rng = np.random.default_rng(seed)
        logits = rand(rng, n, v) * 2
        tgt = jnp.asarray(rng.integers(0, v, n).astype(np.int32))
        out = softmax_xent(logits, tgt, block_rows=16, block_v=64)
        np.testing.assert_allclose(
            np.asarray(out),
            np.asarray(ref.softmax_xent_ref(logits, tgt)),
            atol=2e-4,
            rtol=2e-4,
        )


class TestDifferentiableWrappers:
    """ops.py custom_vjp gradients vs jax.grad of the references."""

    def test_attention_grads(self):
        rng = np.random.default_rng(0)
        q, k, v = (rand(rng, 1, 2, 32, 8) for _ in range(3))

        def kernel_loss(q, k, v):
            return jnp.sum(ops.attention(q, k, v) ** 2)

        def ref_loss(q, k, v):
            return jnp.sum(ref.attention_ref(q, k, v, causal=True) ** 2)

        gk = jax.grad(kernel_loss, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(ref_loss, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)

    def test_layernorm_grads(self):
        rng = np.random.default_rng(1)
        x, g, b = rand(rng, 9, 33), rand(rng, 33), rand(rng, 33)

        def kernel_loss(x, g, b):
            return jnp.sum(jnp.sin(ops.layernorm(x, g, b)))

        def ref_loss(x, g, b):
            return jnp.sum(jnp.sin(ref.layernorm_ref(x, g, b)))

        gk = jax.grad(kernel_loss, argnums=(0, 1, 2))(x, g, b)
        gr = jax.grad(ref_loss, argnums=(0, 1, 2))(x, g, b)
        for a, b_ in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-4)

    def test_xent_grads(self):
        rng = np.random.default_rng(2)
        logits = rand(rng, 11, 40)
        tgt = jnp.asarray(rng.integers(0, 40, 11).astype(np.int32))

        def kernel_loss(l):
            return jnp.mean(ops.softmax_xent(l, tgt))

        def ref_loss(l):
            return jnp.mean(ref.softmax_xent_ref(l, tgt))

        np.testing.assert_allclose(
            np.asarray(jax.grad(kernel_loss)(logits)),
            np.asarray(jax.grad(ref_loss)(logits)),
            atol=1e-5,
        )

    def test_attention_grad_finite_diff(self):
        # independent spot-check against numerical differentiation
        rng = np.random.default_rng(3)
        q, k, v = (rand(rng, 1, 1, 8, 4) for _ in range(3))

        def f(q):
            return float(jnp.sum(ops.attention(q, k, v)))

        g = jax.grad(lambda q: jnp.sum(ops.attention(q, k, v)))(q)
        eps = 1e-3
        dq = np.zeros_like(np.asarray(q))
        dq[0, 0, 3, 2] = eps
        num = (f(q + dq) - f(q - dq)) / (2 * eps)
        np.testing.assert_allclose(np.asarray(g)[0, 0, 3, 2], num, atol=1e-2)
