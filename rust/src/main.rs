//! `frontier` — CLI for the frontier-llm training system.
//!
//! Subcommands map onto the paper's workflow:
//!
//! * `tables`    — print Tables I/II/V and the Fig 5 bandwidth matrix
//! * `simulate`  — evaluate one (model, strategy) through the perf model
//! * `sweep`     — regenerate the Fig 6/7/8 parameter sweeps
//! * `scaling`   — weak/strong scaling studies (Figs 12/13)
//! * `hpo`       — the §IV DeepHyper-style search + Fig 10 SHAP ranking
//! * `train`     — REAL training: the pipeline/DP/ZeRO-1 engine over the
//!                 AOT-compiled JAX/Pallas artifacts (`make artifacts`)

use anyhow::Result;

use frontier_llm::config::{self, ParallelConfig, ScheduleKind};
use frontier_llm::coordinator::{train, EngineConfig, FaultSpec};
use frontier_llm::hpo;
use frontier_llm::mem;
use frontier_llm::metrics::weak_scaling_efficiency;
use frontier_llm::optim::AdamConfig;
use frontier_llm::perf::{sim, PerfModel};
use frontier_llm::topology::Machine;
use frontier_llm::util::args::Args;

const USAGE: &str = "\
frontier — 3D-parallel LLM training on a simulated Frontier (ORNL 2023 repro)

USAGE: frontier <command> [options]

COMMANDS:
  tables                       print Tables I/II/V and the Fig 5 matrix
  simulate [--model 175b] [--tp N] [--pp N] [--dp N] [--mbs N] [--gbs N]
           [--interleave V] [--zero-stage 0|1|2|3] [--no-flash] [--des]
  sweep    [--axis tp|gbs|pp-fixed|pp-scaled]
  scaling  [--model 175b|1t] [--mode weak|strong]
  hpo      [--evals N] [--seed N]
  train    [--bundle tiny-s2-mb2 | --bundle builtin:tiny-s4-mb2]
           [--artifacts DIR] [--dp N] [--tp N] [--microbatches N] [--steps N]
           [--zero-stage 0|1|2|3] [--gpipe | --interleave V]
           [--no-overlap] [--bucket-floats N] [--collective-algo ring|naive]
           [--precision fp32|bf16] [--loss-scale S] [--loss-scale-growth N]
           [--nodes N] [--grad-wire fp32|bf16|int8] [--zero3-prefetch N]
           [--lr F] [--seed N] [--log-every N]
           [--checkpoint DIR] [--checkpoint-every N] [--resume]
           [--async-checkpoint] [--ckpt-keep N] [--comm-timeout-ms MS]
           [--experts N] [--moe-topk K] [--capacity-factor F] [--ep N]
           [--fault kill@S:R|join@S|ckpt-crash@S:R|write-fail@S:R:N[,...]]
           [--trace-out FILE] [--metrics-jsonl FILE]

  --tp N shards every builtin stage across N tensor-parallel worker
  threads (Megatron column/row-parallel linears, vocab-parallel embed and
  head, per-layer all-reduces through real collectives).  Builtin bundles
  only; N must divide the model's hidden and vocab dims.

  DP gradient sync overlaps with the backward pass by default (bucketed
  nonblocking all-reduce, bit-identical trajectories): --no-overlap
  launches the same buckets sequentially after the step's op stream,
  --bucket-floats sets the bucket granularity, and --collective-algo
  picks the algorithm for the small grad-norm/loss syncs.

  --zero-stage selects the ZeRO sharding ladder: 0 = plain DDP, 1 =
  optimizer states sharded 1/dp, 2 = + true reduce-scatter gradient
  shards (the overlapped buckets become partition-aligned reduce-
  scatters; each rank materialises only its own reduced shard), 3 = +
  parameter shards with on-demand per-layer all-gathers (prefetched one
  use ahead, dropped after use; builtin bundles only).  Every stage
  walks the stage-0 loss trajectory bitwise at fp32.  --zero1 survives
  as a deprecated alias for --zero-stage 1.

  --precision bf16 (builtin bundles only) stores params/activations/
  grads in bf16 with f32-accumulating kernels, keeps fp32 master weights
  in the optimizer (sharded under --zero-stage 1+), halves every collective
  payload (packed-u16 wire), and arms the dynamic loss scaler:
  --loss-scale sets the initial (power-of-two) scale, --loss-scale-growth
  the clean-step interval before it doubles (0 = static).

  --nodes N places the world packed onto N Frontier nodes (8 GCDs each)
  and switches every sharded DP collective to the two-tier hierarchical
  path: intra-node reduce, inter-node exchange over one representative
  per node, intra-node fan-out — bitwise-identical trajectories to the
  flat path at fp32 and on the bf16 grid.  The report then splits every
  payload counter by tier.  --grad-wire picks the inter-node gradient
  wire format (default: the precision's native width); int8 sends
  blockwise-scaled 8-bit payloads (f32 scale per 128-float block) on the
  inter-node hop only.  --zero3-prefetch N widens the ZeRO-3 gather
  lookahead to N chunks ((N+1)-chunk peak residency; default 1).

  The engine is elastic: every collective wait carries a deadline
  (--comm-timeout-ms, default 10000; 0 disables), so a dead worker
  surfaces as a diagnostic PeerLost error instead of a silent hang —
  and with checkpointing enabled the run recovers by restarting at dp-1
  from the last manifest (optimizer shards re-partition on load; the
  post-recovery trajectory is bitwise a fresh run at the new dp).
  --fault injects failures deterministically and accepts a comma-
  separated list (one fault per step): kill@STEP:RANK kills one world
  rank at the top of that step, join@STEP grows the world to dp+1 at a
  planned step, ckpt-crash@STEP:RANK kills a rank mid-save (leaving a
  torn staging directory the next load must fall back past), and
  write-fail@STEP:RANK:COUNT makes that rank's first COUNT checkpoint
  writes at that step fail transiently (absorbed by retry-with-
  backoff).  The report counts recovery events and lost (recomputed)
  steps.

  --experts N turns every builtin stage block into a top-k MoE layer
  (N expert FFN copies behind a deterministic softmax gate) by rewriting
  the bundle name to its -moeNkK variant; --moe-topk K picks the experts
  per token (default 2, clamped to N) and --capacity-factor F sizes the
  per-expert token buffers (GShard ceil(F·tokens·k/N), default 1.25;
  overflow tokens are dropped from the expert branch and counted in the
  report).  --ep N shards the experts over blocks of N consecutive DP
  replicas; tokens reach remote experts through a deterministic
  dtype-packed all_to_all (dispatch + combine per MoE block), N must
  divide both --dp and --experts, and expert PARAMETERS stay
  DP-replicated — so the loss trajectory is bitwise identical at any ep
  (fp32) and the ZeRO/checkpoint machinery is untouched.  --experts 1
  is bitwise the dense model.

  Checkpoints are crash-consistent generations: each save stages into
  gen-<step>.tmp/, every file carries a CRC32 header, the manifest
  lists per-file size+checksum, and commit is one atomic rename to
  gen-<step>/.  Load picks the newest generation that verifies and
  falls back past torn or corrupt ones; --ckpt-keep N (default 2)
  retains a chain of N committed generations.  --async-checkpoint
  snapshots params/opt state at the barrier and persists on a
  background saver thread so the step loop resumes immediately —
  saved bytes and trajectories stay bitwise-identical to sync saves.

  --trace-out FILE records per-rank spans (compute, tp/dp/pp/zero/moe
  collectives, optimizer, checkpoint) and merges them into one Chrome
  Trace Event Format JSON after training — load it in Perfetto or
  chrome://tracing (one pid per worker rank, one tid per chunk lane).
  --metrics-jsonl FILE streams one self-describing JSON object per
  logged step: loss, grad norm, loss scale, step wall time, per-category
  trace milliseconds, and the delta of every TrainReport counter.
  Tracing is observational only: trajectories and all payload counters
  stay bitwise identical with tracing on or off.

  Quickstart:

    frontier train --bundle builtin:tiny-s4-mb2 --tp 2 --dp 2 --steps 20
    frontier train --bundle builtin:tiny-s4-mb2 --precision bf16 --dp 2 --steps 20
    frontier train --bundle builtin:tiny-s2-mb2 --dp 2 --steps 8 \\
        --checkpoint /tmp/ck --checkpoint-every 2 --fault kill@3:1
";

/// `--zero-stage {0..3}` with `--zero1` as the deprecated stage-1 alias
/// (an explicit `--zero-stage` wins when both are given).
fn parse_zero_stage(args: &Args) -> Result<frontier_llm::zero::ShardingStage> {
    use frontier_llm::zero::ShardingStage;
    match args.get("zero-stage") {
        Some(s) => ShardingStage::parse(s)
            .ok_or_else(|| anyhow::anyhow!("--zero-stage must be 0|1|2|3, got {s:?}")),
        None if args.flag("zero1") => Ok(ShardingStage::OptimizerStates),
        None => Ok(ShardingStage::Ddp),
    }
}

fn main() -> Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    match args.command() {
        Some("tables") => cmd_tables(),
        Some("simulate") => cmd_simulate(&args),
        Some("sweep") => cmd_sweep(&args.opt_str("axis", "tp")),
        Some("scaling") => {
            cmd_scaling(&args.opt_str("model", "175b"), &args.opt_str("mode", "weak"))
        }
        Some("hpo") => cmd_hpo(
            args.opt("evals", 128).map_err(anyhow::Error::msg)?,
            args.opt("seed", 7).map_err(anyhow::Error::msg)?,
        ),
        Some("train") => cmd_train(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_tables() -> Result<()> {
    println!("== Table I: GPT model zoo ==");
    println!(
        "{:>6} {:>8} {:>8} {:>7} {:>12} {:>12}",
        "model", "layers", "hidden", "heads", "12Ld^2", "exact"
    );
    for m in config::paper_zoo() {
        println!(
            "{:>6} {:>8} {:>8} {:>7} {:>12.2e} {:>12.2e}",
            m.name,
            m.n_layers,
            m.hidden,
            m.n_heads,
            m.paper_params() as f64,
            m.total_params() as f64
        );
    }

    println!("\n== Table II: memory requirement (mixed precision + Adam) ==");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>12}",
        "model", "params(6x)", "grads(4x)", "optim(4x)", "total(14x)"
    );
    for (name, n) in
        [("22B", 22e9 as u64), ("175B", 175e9 as u64), ("1T", 1_000_000_000_000u64)]
    {
        let (p, g, o, t) = mem::table2_row(n);
        let gb = |b: u64| format!("{:.0} GB", b as f64 / 1e9);
        println!("{:>6} {:>12} {:>12} {:>12} {:>12}", name, gb(p), gb(g), gb(o), gb(t));
    }

    println!("\n== Fig 5: GPU link bandwidth matrix (GB/s), one node + neighbour ==");
    let m = Machine::new(2);
    for row in m.bandwidth_matrix(10) {
        let cells: Vec<String> = row.iter().map(|b| format!("{b:>4.0}")).collect();
        println!("{}", cells.join(" "));
    }

    println!("\n== Table V: tuned recipes ==");
    let perf = PerfModel::default();
    println!(
        "{:>6} {:>4} {:>4} {:>4} {:>6} {:>6} {:>10} {:>10}",
        "model", "TP", "PP", "MBS", "GBS", "GPUs", "paper%", "model%"
    );
    for (r, paper_pct, _) in config::fig11_recipes() {
        let b = perf.evaluate(&r.model, &r.parallel).expect("recipe evaluates");
        println!(
            "{:>6} {:>4} {:>4} {:>4} {:>6} {:>6} {:>9.2}% {:>9.2}%",
            r.model.name,
            r.parallel.tp,
            r.parallel.pp,
            r.parallel.mbs,
            r.parallel.gbs,
            r.gpus(),
            paper_pct,
            b.pct_peak
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let model = args.opt_str("model", "175b");
    let tp: u32 = args.opt("tp", 1).map_err(anyhow::Error::msg)?;
    let pp: u32 = args.opt("pp", 1).map_err(anyhow::Error::msg)?;
    let dp: u32 = args.opt("dp", 1).map_err(anyhow::Error::msg)?;
    let mbs: u32 = args.opt("mbs", 1).map_err(anyhow::Error::msg)?;
    let gbs: u32 = args.opt("gbs", 16).map_err(anyhow::Error::msg)?;
    let interleave: u32 = args.opt("interleave", 1).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(interleave >= 1, "--interleave must be >= 1");

    let spec =
        config::lookup(&model).ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
    let mut cfg = ParallelConfig::default()
        .with_tp(tp)
        .with_pp(pp)
        .with_dp(dp)
        .with_mbs(mbs)
        .with_gbs(gbs)
        .with_zero_stage(parse_zero_stage(args)?)
        .with_flash(!args.flag("no-flash"));
    if interleave > 1 {
        cfg = cfg.with_interleave(interleave);
    }
    let perf = PerfModel::default();
    match perf.evaluate(&spec, &cfg) {
        Ok(b) => {
            let mb = mem::per_gpu(&spec, &cfg);
            let gib = |x: u64| x as f64 / (1u64 << 30) as f64;
            println!(
                "model {model}  tp{tp} pp{pp} dp{dp} mbs{mbs} gbs{gbs} (m={})",
                cfg.microbatches()
            );
            println!(
                "  memory/GPU    {:>10.1} GiB (params {:.1} + grads {:.1} + optim {:.1} + act {:.1} + ovh {:.1})",
                mb.gib(),
                gib(mb.params),
                gib(mb.grads),
                gib(mb.optimizer),
                gib(mb.activations),
                gib(mb.overhead)
            );
            println!("  step time     {:>10.3} s", b.t_step);
            println!("    compute     {:>10.3} s", b.t_compute);
            println!("    tp comm     {:>10.3} s", b.t_tp_comm);
            println!(
                "    bubble      {:>10.3} s ({:.1}% analytic)",
                b.t_bubble,
                100.0 * cfg.bubble_fraction()
            );
            println!("    pp p2p      {:>10.3} s", b.t_pp_comm);
            println!("    dp sync     {:>10.3} s", b.t_dp_comm);
            println!("    optimizer   {:>10.3} s", b.t_optimizer);
            println!(
                "  throughput    {:>10.1} TFLOPS/GPU = {:.2}% of peak",
                b.tflops_per_gpu, b.pct_peak
            );
            println!("  arith. int.   {:>10.0} flops/byte", b.arithmetic_intensity);
            if args.flag("des") {
                let s = sim::simulate(&perf, &spec, &cfg)
                    .map_err(|e| anyhow::anyhow!("{e:?}"))?;
                println!(
                    "  [DES] pipeline {:.3} s, measured bubble {:.1}%, {:.2}% of peak",
                    s.t_pipeline,
                    100.0 * s.bubble_fraction,
                    s.pct_peak
                );
            }
        }
        Err(e) => println!("configuration cannot run: {e:?}"),
    }
    Ok(())
}

fn cmd_sweep(axis: &str) -> Result<()> {
    let perf = PerfModel::default();
    let show = |label: String, r: Result<frontier_llm::perf::StepBreakdown, frontier_llm::perf::PerfError>| match r {
        Ok(b) => println!("  {label}: {:>6.1} TFLOPS/GPU ({:.2}%)", b.tflops_per_gpu, b.pct_peak),
        Err(e) => println!("  {label}: {e:?}"),
    };
    match axis {
        "tp" => {
            let m = config::lookup("1.4b").unwrap();
            println!("Fig 6 — throughput vs TP (1.4B, 8 GPUs)");
            for tp in [1u32, 2, 4, 8] {
                let cfg = ParallelConfig::default()
                    .with_tp(tp)
                    .with_dp(8 / tp)
                    .with_gbs(64)
                    .with_mbs(4);
                show(format!("TP={tp}"), perf.evaluate(&m, &cfg));
            }
        }
        "gbs" => {
            println!("Fig 7a — throughput vs GBS (22B, tp2 pp8)");
            let m = config::lookup("22b").unwrap();
            for gbs in [8u32, 16, 32, 64, 128, 256] {
                let cfg = ParallelConfig::default().with_tp(2).with_pp(8).with_gbs(gbs);
                show(format!("GBS={gbs:>4}"), perf.evaluate(&m, &cfg));
            }
            println!("Fig 7b — throughput vs GBS (1T, tp8 pp64)");
            let m = config::lookup("1t").unwrap();
            for gbs in [64u32, 128, 256, 512, 1024, 1600] {
                let cfg = ParallelConfig::default()
                    .with_tp(8)
                    .with_pp(64)
                    .with_gbs(gbs)
                    .with_zero1(true);
                show(format!("GBS={gbs:>4}"), perf.evaluate(&m, &cfg));
            }
        }
        "pp-fixed" => {
            println!("Fig 8a — throughput vs PP, GBS fixed at 128 (175B, tp8)");
            let m = config::lookup("175b").unwrap();
            for pp in [8u32, 12, 16, 24, 32] {
                let cfg = ParallelConfig::default().with_tp(8).with_pp(pp).with_gbs(128);
                show(format!("PP={pp:>2}"), perf.evaluate(&m, &cfg));
            }
        }
        "pp-scaled" => {
            println!("Fig 8b — throughput vs PP, GBS scaled to fix bubble (175B, tp8)");
            let m = config::lookup("175b").unwrap();
            for (pp, gbs) in [(8u32, 128u32), (12, 192), (16, 256), (24, 384), (32, 512)] {
                let cfg = ParallelConfig::default().with_tp(8).with_pp(pp).with_gbs(gbs);
                show(format!("PP={pp:>2} GBS={gbs:>3}"), perf.evaluate(&m, &cfg));
            }
        }
        other => anyhow::bail!("unknown sweep axis {other} (tp | gbs | pp-fixed | pp-scaled)"),
    }
    Ok(())
}

fn cmd_scaling(model: &str, mode: &str) -> Result<()> {
    let perf = PerfModel::default();
    let (recipe, points): (_, Vec<u32>) = match model {
        "175b" => (config::recipe_175b(), vec![128, 256, 512, 1024]),
        "1t" => (config::recipe_1t(), vec![1024, 2048, 3072]),
        _ => anyhow::bail!("scaling supports 175b | 1t"),
    };
    let per_replica = recipe.parallel.gpus_per_replica();
    let gbs_per_replica = recipe.parallel.gbs / recipe.parallel.dp;
    println!(
        "{mode} scaling, {model}: tp{} pp{} ({} GPUs/replica)",
        recipe.parallel.tp, recipe.parallel.pp, per_replica
    );

    let mut base: Option<(u32, f64)> = None;
    for gpus in points {
        let dp = gpus / per_replica;
        let gbs = match mode {
            "weak" => gbs_per_replica * dp,
            "strong" => {
                if model == "175b" {
                    8000
                } else {
                    8016
                }
            }
            _ => anyhow::bail!("mode must be weak | strong"),
        };
        let mut cfg = recipe.parallel.clone().with_dp(dp).with_gbs(gbs);
        if cfg.gbs % cfg.dp != 0 {
            cfg.gbs = (cfg.gbs / cfg.dp) * cfg.dp;
        }
        match perf.samples_per_sec(&recipe.model, &cfg) {
            Ok(sps) => {
                let eff = base
                    .map(|b| weak_scaling_efficiency(b, (gpus, sps)))
                    .unwrap_or(100.0);
                if base.is_none() {
                    base = Some((gpus, sps));
                }
                println!(
                    "  {gpus:>5} GPUs (dp={dp:>3}, gbs={:>5}): {sps:>9.2} samples/s  eff {eff:>6.2}%",
                    cfg.gbs
                );
            }
            Err(e) => println!("  {gpus:>5} GPUs: {e:?}"),
        }
    }
    Ok(())
}

fn cmd_hpo(evals: u32, seed: u64) -> Result<()> {
    let perf = PerfModel::default();
    let result = hpo::run_search(
        &perf,
        &hpo::SearchConfig { n_evals: evals, seed, ..Default::default() },
    );
    println!("Fig 9 — search trajectory ({evals} evaluations)");
    for (i, ev) in result.evals.iter().enumerate() {
        let marker = match &ev.objective {
            Some(v) => format!("{v:>7.1} TFLOPS/GPU"),
            None => format!("FAILED ({})", ev.failure.as_deref().unwrap_or("?")),
        };
        if i % 8 == 0 || ev.objective.is_none() {
            println!(
                "  #{i:>3} pp{:<2} tp{} mbs{:<2} gas{:<2} z{} n{:<2} v{} -> {marker}  best={:.1}",
                ev.point.pp,
                ev.point.tp,
                ev.point.mbs,
                ev.point.gas,
                ev.point.zero_stage.index(),
                ev.point.nnodes,
                ev.point.interleave,
                result.best_trajectory[i]
            );
        }
    }
    let q = result.failures_by_quarter();
    println!("failures by quarter: {q:?} (paper: frequency decreases with time)");
    if let Some(best) = result.best() {
        println!("best: {:?} -> {:.1} TFLOPS/GPU", best.point, best.objective.unwrap());
    }

    println!("\nFig 10 — SHAP sensitivity (mean |SHAP| on achieved FLOPS)");
    for (name, v) in hpo::shap_ranking(&result, 96) {
        println!("  {name:<12} {v:>8.3}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let bundle = {
        let mut b = args.opt_str("bundle", "tiny-s2-mb2");
        let experts: u32 = args.opt("experts", 1).map_err(anyhow::Error::msg)?;
        if experts > 1 {
            // rewrite the bundle to its MoE variant: tiny-s4-mb2 ->
            // tiny-moe<E>k<K>-s4-mb2 (builtin bundles only)
            anyhow::ensure!(
                b.starts_with("builtin:"),
                "--experts needs a builtin: bundle, got {b:?}"
            );
            anyhow::ensure!(
                !b.contains("-moe"),
                "bundle {b:?} already names an expert count; drop --experts"
            );
            let topk: u32 = args.opt("moe-topk", 2).map_err(anyhow::Error::msg)?;
            anyhow::ensure!(
                topk >= 1 && topk <= experts,
                "--moe-topk must be in 1..=experts ({experts}), got {topk}"
            );
            b = b.replacen("-s", &format!("-moe{experts}k{topk}-s"), 1);
        } else if args.get("moe-topk").is_some() {
            anyhow::bail!("--moe-topk needs --experts N with N > 1");
        }
        b
    };
    let cfg = EngineConfig {
        artifacts_root: args.opt_str("artifacts", "artifacts").into(),
        bundle,
        dp: args.opt("dp", 1).map_err(anyhow::Error::msg)?,
        tp: args.opt("tp", 1).map_err(anyhow::Error::msg)?,
        ep: args.opt("ep", 1).map_err(anyhow::Error::msg)?,
        capacity_factor: args.opt("capacity-factor", 1.25f32).map_err(anyhow::Error::msg)?,
        schedule: {
            let v: u32 = args.opt("interleave", 1).map_err(anyhow::Error::msg)?;
            anyhow::ensure!(v >= 1, "--interleave must be >= 1");
            if args.flag("gpipe") {
                anyhow::ensure!(v <= 1, "--gpipe and --interleave are exclusive");
                ScheduleKind::GPipe
            } else if v > 1 {
                ScheduleKind::Interleaved1F1B { v }
            } else {
                ScheduleKind::OneF1B
            }
        },
        microbatches: args.opt("microbatches", 4).map_err(anyhow::Error::msg)?,
        steps: args.opt("steps", 20).map_err(anyhow::Error::msg)?,
        adam: AdamConfig {
            lr: args.opt("lr", 3e-4).map_err(anyhow::Error::msg)?,
            ..Default::default()
        },
        lr_schedule: None,
        zero_stage: parse_zero_stage(args)?,
        overlap_grad_sync: !args.flag("no-overlap"),
        grad_bucket_floats: args
            .opt("bucket-floats", 1usize << 15)
            .map_err(anyhow::Error::msg)?,
        collective_algo: match args.opt_str("collective-algo", "ring").as_str() {
            "ring" => frontier_llm::collectives::Algo::Ring,
            "naive" => frontier_llm::collectives::Algo::Naive,
            other => anyhow::bail!("--collective-algo must be ring|naive, got {other:?}"),
        },
        precision: {
            let name = args.opt_str("precision", "fp32");
            frontier_llm::precision::Dtype::parse(&name)
                .ok_or_else(|| anyhow::anyhow!("--precision must be fp32|bf16, got {name:?}"))?
        },
        loss_scale_init: args.opt("loss-scale", 1.0f32).map_err(anyhow::Error::msg)?,
        loss_scale_growth_interval: args
            .opt("loss-scale-growth", 0u32)
            .map_err(anyhow::Error::msg)?,
        seed: args.opt("seed", 1234).map_err(anyhow::Error::msg)?,
        log_every: args.opt("log-every", 1).map_err(anyhow::Error::msg)?,
        checkpoint_dir: args.get("checkpoint").map(Into::into),
        checkpoint_every: args.opt("checkpoint-every", 0).map_err(anyhow::Error::msg)?,
        resume: args.flag("resume"),
        async_checkpoint: args.flag("async-checkpoint"),
        ckpt_keep: args.opt("ckpt-keep", 2usize).map_err(anyhow::Error::msg)?,
        nodes: args.opt("nodes", 0u32).map_err(anyhow::Error::msg)?,
        grad_wire: match args.get("grad-wire") {
            Some(s) => Some(frontier_llm::precision::GradWire::parse(s).ok_or_else(|| {
                anyhow::anyhow!("--grad-wire must be fp32|bf16|int8, got {s:?}")
            })?),
            None => None,
        },
        zero3_prefetch: args.opt("zero3-prefetch", 1usize).map_err(anyhow::Error::msg)?,
        comm_timeout_ms: args.opt("comm-timeout-ms", 10_000u64).map_err(anyhow::Error::msg)?,
        faults: match args.get("fault") {
            Some(s) => FaultSpec::parse_list(s).map_err(anyhow::Error::msg)?,
            None => Vec::new(),
        },
        trace_out: args.get("trace-out").map(Into::into),
        metrics_jsonl: args.get("metrics-jsonl").map(Into::into),
    };
    let report = train(&cfg)?;
    println!();
    print!("{}", report.render_summary());
    Ok(())
}
