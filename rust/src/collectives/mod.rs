//! Real shared-memory collectives for the execution engine.
//!
//! The training engine (`coordinator`) runs one OS thread per simulated
//! GCD.  These collectives are the RCCL stand-in: actual data movement
//! between worker threads with the same algorithms RCCL uses — a naive
//! deposit-reduce for small groups and a chunked **ring all-reduce**
//! (reduce-scatter + all-gather phases over per-neighbour mailboxes) for
//! the large gradient buffers.  Byte counters feed `metrics`.
//!
//! Every mailbox hop and bucket deposit moves a [`Payload`] `Arc`
//! (zero-copy; fan-out shares one buffer, the single-consumer p2p case
//! recovers the owned `Vec` for free), and the engine's
//! backward-overlapped gradient sync rides the **nonblocking bucketed
//! all-reduce** ([`Group::start_all_reduce`] → [`ReduceHandle::wait`]):
//! deterministic rank-order reduction, computed once by the round's
//! completing depositor so the cost hides under backward compute.
//!
//! Correctness contracts (tested below + proptest in `rust/tests/props.rs`):
//! * `ring` and `naive` all-reduce produce identical sums (up to fp
//!   association order, which we make deterministic by rank order);
//! * `reduce_scatter` followed by `all_gather` equals `all_reduce`;
//! * every rank of a group must participate in every round (the engine's
//!   schedules guarantee this; violations deadlock rather than corrupt);
//! * a [`SubGroup`] all-reduce involves only its members — disjoint
//!   subgroups of one parent reduce independently and concurrently.
//!
//! **Subgroups.**  Tensor-parallel shards need collectives over a *subset*
//! of the world (the `tp` consecutive ranks of one pipeline×data cell).
//! [`SubGroup`] builds them over a parent [`Group`]'s tagged mailboxes:
//! ring reduce-scatter + all-gather between member neighbours, in a tag
//! namespace that cannot collide with the engine's pipeline p2p traffic.
//! Each subgroup counts the *payload* f32 bytes entering its all-reduces
//! (once per collective, not per wire hop) — the instrumentation the TP
//! perf cross-validation tests compare against `perf`'s analytic comm
//! term.

use std::collections::{HashMap, VecDeque};
use std::panic::panic_any;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::precision::{pack_bf16, unpack_bf16, Dtype, GradWire};
use crate::topology::{GpuId, Machine};
use crate::trace::{self, Category};

/// A deadline-bounded collective wait expired: some peer never showed up.
///
/// Raised (via `panic_any`, unwinding the worker thread) by every wait
/// site of a [`Group`] whose communication timeout is armed
/// ([`Group::set_comm_timeout`]) — mailbox receives, the barrier/exchange
/// round, and the nonblocking all-reduce / reduce-scatter / all-gather
/// handles.  The coordinator harvests the payload at `join` time and
/// either reports the diagnostic or triggers an elastic reconfiguration.
/// With the timeout disarmed (the default — unit tests, library use) the
/// waits stay unbounded and bit-identical to the pre-elastic engine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PeerLost {
    /// The missing peer's group rank, when the wait site can name one
    /// (p2p receives and deposit rounds can; a drain wait cannot).
    pub rank: Option<usize>,
    /// Tag of the stuck round / message.
    pub tag: u64,
    /// Which wait site expired.
    pub what: &'static str,
    /// The configured deadline that expired, in milliseconds.
    pub waited_ms: u64,
}

impl std::fmt::Display for PeerLost {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.rank {
            Some(r) => write!(
                f,
                "collective timeout after {} ms in {}: peer rank {} never arrived (tag {:#x})",
                self.waited_ms, self.what, r, self.tag
            ),
            None => write!(
                f,
                "collective timeout after {} ms in {} (tag {:#x}): a peer never arrived",
                self.waited_ms, self.what, self.tag
            ),
        }
    }
}

impl std::error::Error for PeerLost {}

/// One `Condvar` wait step of a deadline-bounded loop: unbounded when no
/// deadline is armed (bit-identical to the legacy engine), otherwise a
/// `wait_timeout` that, once the deadline passes, asks `diagnose` to name
/// the missing peer, releases the lock, and unwinds with the [`PeerLost`]
/// payload instead of hanging forever.
fn wait_bounded<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    deadline: Option<(Instant, u64)>,
    diagnose: impl FnOnce(&T, u64) -> PeerLost,
) -> MutexGuard<'a, T> {
    match deadline {
        None => cv.wait(guard).unwrap(),
        Some((at, ms)) => {
            let now = Instant::now();
            if now >= at {
                let lost = diagnose(&guard, ms);
                drop(guard); // don't poison the lock for surviving peers
                panic_any(lost);
            }
            cv.wait_timeout(guard, at - now).unwrap().0
        }
    }
}

/// Node placement of a communicator's ranks: which Frontier node each
/// group rank lives on, with nodes numbered in first-appearance order
/// (so the map is invariant under global node renaming and works for DP
/// groups that stride across nodes — the tp-innermost layouts).
///
/// The **representative** of a node is its lowest group rank; the
/// hierarchical collectives route every inter-node exchange through
/// representatives only.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeMap {
    /// `node[rank]` = node index of that group rank (first-appearance
    /// numbering, dense `0..n_nodes`).
    node: Vec<usize>,
    n_nodes: usize,
}

impl NodeMap {
    /// Build from an explicit per-rank node assignment (any labels;
    /// renumbered densely in first-appearance order).
    pub fn new(assignment: &[usize]) -> Self {
        assert!(!assignment.is_empty(), "node map needs at least one rank");
        let mut seen: Vec<usize> = Vec::new();
        let node = assignment
            .iter()
            .map(|&a| match seen.iter().position(|&s| s == a) {
                Some(i) => i,
                None => {
                    seen.push(a);
                    seen.len() - 1
                }
            })
            .collect();
        Self { node, n_nodes: seen.len() }
    }

    /// Derive from the machine topology and the group's GPU (GCD) ids —
    /// `Machine::node_of` per member, in group-rank order.
    pub fn from_gpus(machine: &Machine, gpus: &[GpuId]) -> Self {
        let assignment: Vec<usize> =
            gpus.iter().map(|&g| machine.node_of(g) as usize).collect();
        Self::new(&assignment)
    }

    /// All `n` ranks co-resident on one node (the flat/degenerate map).
    pub fn flat(n: usize) -> Self {
        assert!(n >= 1);
        Self { node: vec![0; n], n_nodes: 1 }
    }

    pub fn len(&self) -> usize {
        self.node.len()
    }

    pub fn is_empty(&self) -> bool {
        self.node.is_empty()
    }

    /// Number of distinct nodes the group spans.
    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    /// Node index of a group rank.
    pub fn node_of(&self, rank: usize) -> usize {
        self.node[rank]
    }

    /// Group ranks resident on `node`, ascending.
    pub fn members_of(&self, node: usize) -> Vec<usize> {
        (0..self.node.len()).filter(|&r| self.node[r] == node).collect()
    }

    /// The node's representative: its lowest group rank.
    pub fn representative(&self, node: usize) -> usize {
        self.node
            .iter()
            .position(|&nd| nd == node)
            .expect("node index out of range")
    }

    /// Is this rank its node's representative?
    pub fn is_representative(&self, rank: usize) -> bool {
        self.representative(self.node[rank]) == rank
    }

    /// Number of nodes holding more than one rank (the nodes whose
    /// node-local gathers actually move intra-node bytes; single-member
    /// nodes assemble immediately).
    pub fn n_multi_nodes(&self) -> usize {
        (0..self.n_nodes).filter(|&nd| self.node.iter().filter(|&&x| x == nd).count() > 1).count()
    }
}

/// Zero-copy message payload: every mailbox hop and nonblocking-bucket
/// deposit moves an `Arc`, never a deep copy.  Fan-out paths (a deposit
/// read by all ranks) share one buffer; the common single-consumer p2p
/// case recovers the owned `Vec` without copying via `Arc::try_unwrap`.
pub type Payload = Arc<Vec<f32>>;

/// All-reduce algorithm selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    /// Every rank reads every deposit and reduces locally (fine for small
    /// groups / small payloads).
    Naive,
    /// Chunked ring: reduce-scatter then all-gather, 2(n-1) neighbour
    /// exchanges of 1/n of the payload (what RCCL runs on the big buffers).
    Ring,
}

#[derive(Default)]
struct ExchangeState {
    deposits: Vec<Option<Arc<Vec<f32>>>>,
    arrived: usize,
    read: usize,
    ready: bool,
    gen: u64,
}

/// Untagged traffic (ring collectives, plain pipeline p2p) uses this tag;
/// chunked pipeline traffic tags messages so `v` virtual-stage channels
/// can multiplex one (from, to) mailbox without FIFO interleaving hazards.
pub const TAG_ANY: u64 = 0;

/// Tag namespace for subgroup collectives.  The engine's pipeline p2p
/// uses directions 1 (fwd) and 2 (bwd) in the top tag bits; subgroups
/// claim direction 3, qualified by a per-subgroup id.
const TAG_SUBGROUP: u64 = 3 << 48;

struct Mailbox {
    queue: Mutex<VecDeque<(u64, Payload)>>,
    cv: Condvar,
}

impl Mailbox {
    fn new() -> Self {
        Self { queue: Mutex::new(VecDeque::new()), cv: Condvar::new() }
    }

    fn send(&self, tag: u64, data: Payload) {
        self.queue.lock().unwrap().push_back((tag, data));
        // single consumer per (from, to) mailbox
        self.cv.notify_one();
    }

    /// Pop the oldest message whose tag matches (FIFO within a tag).
    /// `from` is the sender rank, named in the diagnostic should the
    /// deadline expire before a matching message arrives.
    fn recv(&self, tag: u64, from: usize, deadline: Option<(Instant, u64)>) -> Payload {
        let mut q = self.queue.lock().unwrap();
        loop {
            if let Some(pos) = q.iter().position(|(t, _)| *t == tag) {
                return q.remove(pos).unwrap().1;
            }
            q = wait_bounded(&self.cv, q, deadline, |_, ms| PeerLost {
                rank: Some(from),
                tag,
                what: "p2p recv",
                waited_ms: ms,
            });
        }
    }
}

/// One in-flight nonblocking all-gather round (see
/// [`Group::start_all_gather_dtype`]): per-rank shard deposits assembled
/// into one shared full buffer by whichever rank's deposit completes the
/// round — pure placement, no reduction, so the result is exact at any
/// arrival order.
#[derive(Default)]
struct AgRound {
    deposits: Vec<Option<Payload>>,
    arrived: usize,
    /// The assembled full buffer, produced by the completing depositor.
    result: Option<Payload>,
    taken: usize,
    /// Unpacked element count of the assembled buffer.
    total: usize,
    /// Wire dtype every rank of the round must agree on.
    wire: Dtype,
}

/// One in-flight nonblocking bucket round (see [`Group::start_all_reduce`]).
#[derive(Default)]
struct NbRound {
    deposits: Vec<Option<Payload>>,
    arrived: usize,
    /// Rank-order sum, produced by whichever rank's deposit completed
    /// the round (so the reduction cost lands under that rank's compute
    /// stream, not in anyone's `wait`).
    result: Option<Payload>,
    taken: usize,
    /// Unpacked element count of this round (deposits may be bf16-packed).
    len: usize,
    /// Wire dtype every rank of the round must agree on.
    wire: Dtype,
    /// Hierarchical round marker: the inter-node grad wire (`None` for
    /// flat rounds).  Every rank of one round must agree.
    hier_wire: Option<GradWire>,
}

/// One in-flight nonblocking all-to-all round (see
/// [`Group::start_all_to_all_dtype`]): every rank deposits `n` wire-cast
/// parts (one per destination), and whichever rank's deposit completes
/// the round assembles each destination's receive set — its part from
/// every source, in source-rank order.  Pure placement, no reduction, so
/// the result is exact at any arrival order.
#[derive(Default)]
struct A2aRound {
    /// `deposits[src]` = src's per-destination parts (wire-packed).
    deposits: Vec<Option<Vec<Payload>>>,
    arrived: usize,
    /// `results[dst][src]` = unpacked f32 part from src to dst, produced
    /// by the completing depositor.
    results: Option<Vec<Vec<Payload>>>,
    taken: usize,
    /// Unpacked element counts, `lens[src][dst]` (each source chooses its
    /// own part shapes; destinations learn them from the result).
    lens: Vec<Vec<usize>>,
    /// Wire dtype every rank of the round must agree on.
    wire: Dtype,
}

/// A communicator over `n` ranks (one per worker thread).
pub struct Group {
    n: usize,
    /// Node placement of the ranks (None = topology-blind legacy group;
    /// hierarchical entry points then treat all ranks as co-resident).
    nodes: Option<NodeMap>,
    state: Mutex<ExchangeState>,
    cv: Condvar,
    /// `mail[to][from]`: FIFO channel from `from` to `to`.
    mail: Vec<Vec<Mailbox>>,
    /// In-flight nonblocking bucket rounds, addressed by caller tag.
    nb: Mutex<HashMap<u64, NbRound>>,
    nb_cv: Condvar,
    /// In-flight nonblocking all-gather rounds (ZeRO-3's on-demand
    /// parameter gathers), in their own tag namespace.
    ag: Mutex<HashMap<u64, AgRound>>,
    ag_cv: Condvar,
    /// In-flight **node-local** all-gather rounds (ZeRO++-style secondary
    /// parameter gathers), keyed by (node, tag) — per-node rounds among
    /// that node's members only.
    agn: Mutex<HashMap<(usize, u64), AgRound>>,
    agn_cv: Condvar,
    /// In-flight nonblocking all-to-all rounds (the MoE token dispatch /
    /// combine exchanges), in their own tag namespace.
    a2a: Mutex<HashMap<u64, A2aRound>>,
    a2a_cv: Condvar,
    pub bytes_moved: AtomicU64,
    pub rounds: AtomicU64,
    /// Nonblocking bucket rounds completed.
    pub nb_rounds: AtomicU64,
    /// Logical payload bytes of completed nonblocking bucket rounds —
    /// element count × wire-dtype width, counted once per round (the
    /// reduce-scatter-input volume, NOT per-deposit wire traffic).  The
    /// dtype-aware perf DP comm term is pinned EXACTLY against this.
    pub nb_payload_bytes: AtomicU64,
    /// Logical payload bytes of `all_gather` rounds — blocking AND
    /// nonblocking — (element count × dtype width, once per round): the
    /// stage-1/2 updated-parameter gathers plus ZeRO-3's on-demand
    /// per-layer gathers, the AG half of the RS+AG wire accounting.
    pub ag_payload_bytes: AtomicU64,
    /// High-water mark of full-parameter floats a single rank held live
    /// through ZeRO-3's gather-use-drop lifecycle (engine-maintained;
    /// max over the group's ranks) — the per-layer-residency contract
    /// the mem tests validate.
    pub ag_peak_floats: AtomicU64,
    /// Logical pipeline p2p activation payload bytes (element count ×
    /// wire dtype, once per boundary send; engine-maintained) — pinned
    /// EXACTLY against the analytic PP p2p term, and exactly halved by
    /// the packed-bf16 activation wire.
    pub pp_payload_bytes: AtomicU64,
    /// Engine-maintained timing of nonblocking grad-sync work *hidden*
    /// under the backward pass (nanoseconds; the launch site decides
    /// the classification — see `coordinator::worker`).
    pub nb_hidden_ns: AtomicU64,
    /// Engine-maintained timing of *exposed* nonblocking grad-sync work
    /// (post-backward launches plus drain waits), nanoseconds.
    pub nb_exposed_ns: AtomicU64,
    /// Per-tier split of the hierarchical bucket rounds' wire traffic:
    /// bytes crossing **intra-node** links (each non-representative's
    /// contribution up to its representative, plus each reduced payload
    /// delivered back down), at the storage wire width.  Zero on flat
    /// rounds — the legacy counters above advance identically either way,
    /// so every pre-hierarchy pin is untouched.
    pub nb_intra_bytes: AtomicU64,
    /// Per-tier split of the hierarchical bucket rounds: bytes entering
    /// the **inter-node** exchange — each node's combined partial crosses
    /// the Slingshot tier exactly once, at the grad-wire width (`k ×
    /// grad_wire.payload_bytes(len)` per round; zero when the group sits
    /// on one node).
    pub nb_inter_bytes: AtomicU64,
    /// Intra-node bytes of hierarchical all-gather rounds: each
    /// non-representative's shard up (storage wire) plus the assembled
    /// buffer back down to each non-representative, plus the ZeRO++
    /// node-local secondary gathers (one `total`-sized assembly per
    /// multi-member node round).
    pub ag_intra_bytes: AtomicU64,
    /// Inter-node bytes of hierarchical all-gather rounds: each node's
    /// combined shard crosses the Slingshot tier once — `total × wire`
    /// per round when the group spans nodes (parameter gathers keep the
    /// storage wire; the quantized grad wire is gradient-only).
    pub ag_inter_bytes: AtomicU64,
    /// Engine-maintained per-tier split of the pipeline p2p payload
    /// (classified by the sender from the src/dest node placement).
    pub pp_intra_bytes: AtomicU64,
    /// Engine-maintained inter-node half of the pipeline p2p payload.
    pub pp_inter_bytes: AtomicU64,
    /// All-to-all rounds completed (once per round, by the completing
    /// depositor).
    pub a2a_rounds: AtomicU64,
    /// Logical payload bytes of completed all-to-all rounds — the sum of
    /// every (src, dst) part's element count **including** each rank's
    /// self part, × wire-dtype width, counted once per round.  The MoE
    /// perf a2a term is pinned EXACTLY against this.
    pub a2a_payload_bytes: AtomicU64,
    /// Per-tier split of the all-to-all payload: bytes of src ≠ dst parts
    /// whose endpoints are co-resident (by the group's [`NodeMap`]).
    /// Stays zero on topology-blind groups, like the other tier splits.
    pub a2a_intra_bytes: AtomicU64,
    /// Inter-node half of the src ≠ dst all-to-all payload.
    pub a2a_inter_bytes: AtomicU64,
    /// Deadline (milliseconds) for every collective wait on this group;
    /// 0 (the default) keeps the legacy unbounded waits.  See
    /// [`Group::set_comm_timeout`].
    comm_timeout_ms: AtomicU64,
}

impl Group {
    pub fn new(n: usize) -> Arc<Self> {
        Self::new_with_nodes(n, None)
    }

    /// Communicator with an explicit node placement — the topology-aware
    /// constructor the engine uses when `--nodes` is set.  `nodes` must
    /// cover exactly `n` ranks.
    pub fn new_with_nodes(n: usize, nodes: Option<NodeMap>) -> Arc<Self> {
        assert!(n >= 1);
        if let Some(map) = &nodes {
            assert_eq!(map.len(), n, "node map must cover every rank");
        }
        let mail = (0..n)
            .map(|_| (0..n).map(|_| Mailbox::new()).collect())
            .collect();
        Arc::new(Self {
            n,
            nodes,
            state: Mutex::new(ExchangeState {
                deposits: vec![None; n],
                ..Default::default()
            }),
            cv: Condvar::new(),
            mail,
            nb: Mutex::new(HashMap::new()),
            nb_cv: Condvar::new(),
            ag: Mutex::new(HashMap::new()),
            ag_cv: Condvar::new(),
            agn: Mutex::new(HashMap::new()),
            agn_cv: Condvar::new(),
            a2a: Mutex::new(HashMap::new()),
            a2a_cv: Condvar::new(),
            bytes_moved: AtomicU64::new(0),
            rounds: AtomicU64::new(0),
            nb_rounds: AtomicU64::new(0),
            nb_payload_bytes: AtomicU64::new(0),
            ag_payload_bytes: AtomicU64::new(0),
            ag_peak_floats: AtomicU64::new(0),
            pp_payload_bytes: AtomicU64::new(0),
            nb_hidden_ns: AtomicU64::new(0),
            nb_exposed_ns: AtomicU64::new(0),
            nb_intra_bytes: AtomicU64::new(0),
            nb_inter_bytes: AtomicU64::new(0),
            ag_intra_bytes: AtomicU64::new(0),
            ag_inter_bytes: AtomicU64::new(0),
            pp_intra_bytes: AtomicU64::new(0),
            pp_inter_bytes: AtomicU64::new(0),
            a2a_rounds: AtomicU64::new(0),
            a2a_payload_bytes: AtomicU64::new(0),
            a2a_intra_bytes: AtomicU64::new(0),
            a2a_inter_bytes: AtomicU64::new(0),
            comm_timeout_ms: AtomicU64::new(0),
        })
    }

    /// Arm (or, with 0, disarm) the group's communication deadline: every
    /// wait — mailbox recv, barrier/exchange, nonblocking round redeems —
    /// becomes bounded, unwinding with a [`PeerLost`] diagnostic naming
    /// the missing peer rank and tag instead of hanging forever on a dead
    /// rank.  Disarmed by default so library users and the pre-elastic
    /// test suite see bit-identical behavior.
    pub fn set_comm_timeout(&self, ms: u64) {
        self.comm_timeout_ms.store(ms, Ordering::Relaxed);
    }

    /// Configured communication timeout in milliseconds (0 = unbounded).
    pub fn comm_timeout_ms(&self) -> u64 {
        self.comm_timeout_ms.load(Ordering::Relaxed)
    }

    /// The deadline a wait starting *now* must meet, if armed.
    fn comm_deadline(&self) -> Option<(Instant, u64)> {
        let ms = self.comm_timeout_ms.load(Ordering::Relaxed);
        (ms > 0).then(|| (Instant::now() + Duration::from_millis(ms), ms))
    }

    /// The node placement this group was built with, if any.
    pub fn node_map(&self) -> Option<&NodeMap> {
        self.nodes.as_ref()
    }

    /// Effective node map for the hierarchical entry points: the
    /// configured placement, or everyone-on-one-node when absent.
    fn hier_map(&self) -> NodeMap {
        self.nodes.clone().unwrap_or_else(|| NodeMap::flat(self.n))
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Deposit `data`, wait for all ranks, return every rank's deposit.
    fn exchange(&self, rank: usize, data: Vec<f32>) -> Vec<Arc<Vec<f32>>> {
        assert!(rank < self.n);
        if self.n == 1 {
            return vec![Arc::new(data)];
        }
        self.bytes_moved.fetch_add(4 * data.len() as u64, Ordering::Relaxed);
        let deadline = self.comm_deadline();
        let mut s = self.state.lock().unwrap();
        // wait for the previous round to fully drain before depositing
        while s.ready {
            s = wait_bounded(&self.cv, s, deadline, |_, ms| PeerLost {
                rank: None,
                tag: TAG_ANY,
                what: "barrier/exchange drain",
                waited_ms: ms,
            });
        }
        let my_gen = s.gen;
        debug_assert!(s.deposits[rank].is_none(), "rank {rank} double deposit");
        s.deposits[rank] = Some(Arc::new(data));
        s.arrived += 1;
        if s.arrived == self.n {
            s.ready = true;
            self.cv.notify_all();
        }
        while !(s.ready && s.gen == my_gen) {
            s = wait_bounded(&self.cv, s, deadline, |st: &ExchangeState, ms| PeerLost {
                rank: st.deposits.iter().position(|d| d.is_none()),
                tag: TAG_ANY,
                what: "barrier/exchange",
                waited_ms: ms,
            });
        }
        let snap: Vec<Arc<Vec<f32>>> =
            s.deposits.iter().map(|d| d.as_ref().unwrap().clone()).collect();
        s.read += 1;
        if s.read == self.n {
            s.deposits.iter_mut().for_each(|d| *d = None);
            s.arrived = 0;
            s.read = 0;
            s.ready = false;
            s.gen += 1;
            self.rounds.fetch_add(1, Ordering::Relaxed);
            self.cv.notify_all();
        }
        snap
    }

    /// Synchronisation barrier.
    pub fn barrier(&self, rank: usize) {
        self.exchange(rank, Vec::new());
    }

    /// Point-to-point send to `to` (FIFO per (from, to) pair).
    pub fn send(&self, from: usize, to: usize, data: Vec<f32>) {
        self.send_tagged(from, to, TAG_ANY, data);
    }

    /// Blocking receive from `from`.
    pub fn recv(&self, to: usize, from: usize) -> Vec<f32> {
        self.recv_tagged(to, from, TAG_ANY)
    }

    /// Tagged p2p send: the virtual-stage engine multiplexes `v` chunk
    /// channels over one (from, to) pair by tagging each message with
    /// (direction, chunk, micro-batch); FIFO order holds within a tag.
    /// The owned `Vec` is wrapped in a [`Payload`] `Arc` — no copy.
    pub fn send_tagged(&self, from: usize, to: usize, tag: u64, data: Vec<f32>) {
        self.send_shared(from, to, tag, Arc::new(data));
    }

    /// Zero-copy tagged send of an already-shared payload (fan-out
    /// senders enqueue `Arc` clones of one buffer).
    pub fn send_shared(&self, from: usize, to: usize, tag: u64, data: Payload) {
        assert!(from < self.n && to < self.n && from != to);
        self.bytes_moved.fetch_add(4 * data.len() as u64, Ordering::Relaxed);
        self.mail[to][from].send(tag, data);
    }

    /// Blocking receive of the oldest message from `from` carrying `tag`.
    /// Recovers the owned `Vec` without a copy when this receiver is the
    /// only holder (the p2p case); shared fan-out payloads are cloned.
    pub fn recv_tagged(&self, to: usize, from: usize, tag: u64) -> Vec<f32> {
        match Arc::try_unwrap(self.recv_shared(to, from, tag)) {
            Ok(v) => v,
            Err(shared) => shared.as_slice().to_vec(),
        }
    }

    /// Blocking receive returning the shared payload itself (read-only
    /// consumers — e.g. the ring reduce step — skip even the unwrap).
    pub fn recv_shared(&self, to: usize, from: usize, tag: u64) -> Payload {
        assert!(from < self.n && to < self.n && from != to);
        self.mail[to][from].recv(tag, from, self.comm_deadline())
    }

    /// In-place sum all-reduce.  Deterministic: reduction is always in
    /// rank order regardless of arrival order or algorithm.
    pub fn all_reduce_sum(&self, rank: usize, buf: &mut [f32], algo: Algo) {
        if self.n == 1 {
            return;
        }
        match algo {
            Algo::Naive => {
                let snap = self.exchange(rank, buf.to_vec());
                buf.iter_mut().for_each(|x| *x = 0.0);
                for contrib in &snap {
                    debug_assert_eq!(contrib.len(), buf.len());
                    for (x, &c) in buf.iter_mut().zip(contrib.iter()) {
                        *x += c;
                    }
                }
            }
            Algo::Ring => self.ring_all_reduce(rank, buf),
        }
    }

    /// Chunked ring all-reduce (in place).  `buf` is split into `n` chunks;
    /// after n-1 reduce-scatter steps rank r owns the full sum of chunk
    /// `(r+1) % n`; n-1 all-gather steps circulate the owned chunks.
    fn ring_all_reduce(&self, rank: usize, buf: &mut [f32]) {
        let n = self.n;
        let right = (rank + 1) % n;
        let left = (rank + n - 1) % n;
        let bounds = chunk_bounds(buf.len(), n);

        // To keep numerics identical to `Naive` (rank-order sums), the ring
        // reduce accumulates contributions in rank order: each step sends
        // the *partial* chunk and the receiver adds its own value so chunk
        // c ends up as sum_{r} contrib[r][c] in arrival order
        // (left-neighbour order).  Determinism, not bit-equality with
        // Naive, is the contract; tests use approx comparison.
        for step in 0..n - 1 {
            let send_idx = (rank + n - step) % n;
            let recv_idx = (rank + n - step - 1) % n;
            let (s0, s1) = bounds[send_idx];
            self.send(rank, right, buf[s0..s1].to_vec());
            let incoming = self.recv_shared(rank, left, TAG_ANY);
            let (r0, r1) = bounds[recv_idx];
            debug_assert_eq!(incoming.len(), r1 - r0);
            for (x, &inc) in buf[r0..r1].iter_mut().zip(incoming.iter()) {
                *x += inc;
            }
        }
        // all-gather the reduced chunks around the ring
        for step in 0..n - 1 {
            let send_idx = (rank + 1 + n - step) % n;
            let recv_idx = (rank + n - step) % n;
            let (s0, s1) = bounds[send_idx];
            self.send(rank, right, buf[s0..s1].to_vec());
            let incoming = self.recv_shared(rank, left, TAG_ANY);
            let (r0, r1) = bounds[recv_idx];
            buf[r0..r1].copy_from_slice(&incoming);
        }
    }

    /// Sum-reduce `buf` across ranks and return only this rank's shard
    /// (ZeRO-1's gradient path).  Shard bounds from [`chunk_bounds`].
    pub fn reduce_scatter_sum(&self, rank: usize, buf: &[f32]) -> Vec<f32> {
        let bounds = chunk_bounds(buf.len(), self.n);
        if self.n == 1 {
            return buf.to_vec();
        }
        let snap = self.exchange(rank, buf.to_vec());
        let (lo, hi) = bounds[rank];
        let mut shard = vec![0.0f32; hi - lo];
        for contrib in &snap {
            for (x, &c) in shard.iter_mut().zip(contrib[lo..hi].iter()) {
                *x += c;
            }
        }
        shard
    }

    /// Gather every rank's shard into the full buffer (ZeRO-1's updated-
    /// parameter path).  Shards must follow [`chunk_bounds`] sizing.
    pub fn all_gather(&self, rank: usize, shard: &[f32], out: &mut [f32]) {
        self.all_gather_dtype(rank, shard, out, Dtype::F32);
    }

    /// Dtype-aware [`Group::all_gather`]: bf16 shards exchange as packed
    /// u16 pairs (half the wire bytes).  When the shards already sit on
    /// the bf16 grid — the ZeRO-1 case, where the optimizer re-quantized
    /// the updated parameters — the pack is lossless and the assembled
    /// buffer is bit-identical to the f32 exchange.  Rank 0 counts the
    /// round's logical payload (`out.len() × dtype`) into
    /// `ag_payload_bytes`.
    pub fn all_gather_dtype(&self, rank: usize, shard: &[f32], out: &mut [f32], dtype: Dtype) {
        if rank == 0 && self.n > 1 {
            self.ag_payload_bytes
                .fetch_add(dtype.bytes() * out.len() as u64, Ordering::Relaxed);
        }
        self.all_gather_dtype_uncounted(rank, shard, out, dtype);
    }

    /// [`Group::all_gather_dtype`] without advancing `ag_payload_bytes` —
    /// for out-of-band assemblies (the ZeRO-3 checkpoint save) that must
    /// not perturb the EXACT parameter-gather wire pins.
    pub fn all_gather_dtype_uncounted(
        &self,
        rank: usize,
        shard: &[f32],
        out: &mut [f32],
        dtype: Dtype,
    ) {
        let bounds = chunk_bounds(out.len(), self.n);
        let (lo, hi) = bounds[rank];
        assert_eq!(shard.len(), hi - lo, "shard size mismatch for rank {rank}");
        if self.n == 1 {
            out.copy_from_slice(shard);
            return;
        }
        let payload = match dtype {
            Dtype::F32 => shard.to_vec(),
            Dtype::Bf16 => pack_bf16(shard),
        };
        let snap = self.exchange(rank, payload);
        for (r, contrib) in snap.iter().enumerate() {
            let (lo, hi) = bounds[r];
            match dtype {
                Dtype::F32 => out[lo..hi].copy_from_slice(contrib),
                Dtype::Bf16 => out[lo..hi].copy_from_slice(&unpack_bf16(contrib, hi - lo)),
            }
        }
    }

    /// Broadcast `buf` from `root` to all ranks.
    pub fn broadcast(&self, rank: usize, root: usize, buf: &mut [f32]) {
        if self.n == 1 {
            return;
        }
        let payload = if rank == root { buf.to_vec() } else { Vec::new() };
        let snap = self.exchange(rank, payload);
        if rank != root {
            buf.copy_from_slice(&snap[root]);
        }
    }

    /// Nonblocking bucketed all-reduce, deposit phase.  Returns
    /// immediately; redeem the sum with [`ReduceHandle::wait`].
    ///
    /// Semantics and contracts:
    ///
    /// * **Deterministic** — the result is the rank-order sum (identical
    ///   to [`Algo::Naive`] blocking all-reduce, bit for bit), however
    ///   deposits interleave in time.  This is what lets the engine
    ///   overlap gradient sync with backward compute without perturbing
    ///   the loss trajectory.
    /// * **Zero-copy** — deposits are [`Payload`] `Arc`s; the reduction
    ///   reads every rank's buffer in place and is computed exactly once,
    ///   by whichever rank's deposit completes the round (so its cost
    ///   hides under that rank's compute stream; everyone else's `wait`
    ///   just takes the shared result).
    /// * **Tags are single-use** — concurrent buckets are addressed by
    ///   caller tag, and a tag may not be reused until every rank has
    ///   redeemed its handle (the engine folds `(step, chunk, bucket)`
    ///   into the tag; violations panic as double deposits).
    pub fn start_all_reduce(
        self: &Arc<Self>,
        rank: usize,
        tag: u64,
        data: Vec<f32>,
    ) -> ReduceHandle {
        self.start_all_reduce_dtype(rank, tag, data, Dtype::F32)
    }

    /// Dtype-aware [`Group::start_all_reduce`]: a `Bf16` round wire-casts
    /// each deposit (quantize, then pack two u16 halves per f32 lane —
    /// half the bytes through the mailboxes and the counters), and the
    /// completing depositor unpacks every contribution before the
    /// rank-order f32 sum.  The redeemed result is always full-width f32,
    /// bit-identical to a blocking `Algo::Naive` all-reduce of the
    /// quantized inputs (property-tested in `tests/props.rs`) — so the
    /// overlapped ≡ sequential bitwise guarantee survives bf16 intact.
    pub fn start_all_reduce_dtype(
        self: &Arc<Self>,
        rank: usize,
        tag: u64,
        mut data: Vec<f32>,
        wire: Dtype,
    ) -> ReduceHandle {
        assert!(rank < self.n);
        let len = data.len();
        if self.n == 1 {
            // single rank: the sum is the wire-cast deposit itself
            wire.quantize_slice(&mut data);
            return ReduceHandle { group: self.clone(), tag, immediate: Some(data) };
        }
        let deposit: Payload = match wire {
            Dtype::F32 => Arc::new(data),
            Dtype::Bf16 => Arc::new(pack_bf16(&data)),
        };
        self.bytes_moved.fetch_add(4 * deposit.len() as u64, Ordering::Relaxed);
        let mut nb = self.nb.lock().unwrap();
        let round = nb.entry(tag).or_insert_with(|| NbRound {
            deposits: vec![None; self.n],
            len,
            wire,
            ..Default::default()
        });
        assert!(round.result.is_none(), "bucket tag {tag:#x} reused before fully drained");
        assert!(round.deposits[rank].is_none(), "rank {rank} double deposit on bucket {tag:#x}");
        assert!(
            round.len == len && round.wire == wire,
            "bucket {tag:#x}: rank {rank} deposited {len}×{:?} into a {}×{:?} round",
            wire,
            round.len,
            round.wire
        );
        assert!(
            round.hier_wire.is_none(),
            "bucket {tag:#x}: flat deposit from rank {rank} into a hierarchical round"
        );
        round.deposits[rank] = Some(deposit);
        round.arrived += 1;
        if round.arrived == self.n {
            // this deposit completes the round: reduce NOW, outside the
            // lock, so concurrent buckets keep flowing and the cost lands
            // on this rank's timeline instead of in anyone's wait()
            let deps: Vec<Payload> = round
                .deposits
                .iter()
                .map(|d| d.as_ref().expect("deposited").clone())
                .collect();
            drop(nb);
            let mut sum = vec![0.0f32; len];
            for contrib in &deps {
                match wire {
                    Dtype::F32 => {
                        debug_assert_eq!(contrib.len(), len);
                        for (x, &c) in sum.iter_mut().zip(contrib.iter()) {
                            *x += c;
                        }
                    }
                    Dtype::Bf16 => {
                        let unpacked = unpack_bf16(contrib, len);
                        for (x, &c) in sum.iter_mut().zip(unpacked.iter()) {
                            *x += c;
                        }
                    }
                }
            }
            let mut nb = self.nb.lock().unwrap();
            nb.get_mut(&tag).expect("in-flight round").result = Some(Arc::new(sum));
            self.nb_rounds.fetch_add(1, Ordering::Relaxed);
            self.nb_payload_bytes
                .fetch_add(wire.bytes() * len as u64, Ordering::Relaxed);
            self.nb_cv.notify_all();
        }
        ReduceHandle { group: self.clone(), tag, immediate: None }
    }

    /// Nonblocking **partition-aligned reduce-scatter** bucket: every rank
    /// deposits its contribution over one span of the gradient buffer
    /// that lies wholly inside `owner`'s DP partition, and only `owner`'s
    /// [`ScatterHandle::wait`] materialises the reduced span — the
    /// ZeRO-2/3 gradient dataflow.
    ///
    /// Rides the same deterministic rank-order machinery as
    /// [`Group::start_all_reduce_dtype`] (the completing depositor folds
    /// every wire-cast deposit in rank order, exactly once), so the shard
    /// the owner receives is bit-for-bit the slice a bucketed all-reduce
    /// would have produced — the invariant that keeps every sharding
    /// stage on the DDP trajectory, overlapped or not.  Payload counters
    /// advance identically (`nb_payload_bytes` counts the bucket's
    /// reduce-scatter-input volume once per round), so the per-step DP
    /// gradient wire volume is the same `params × dtype` under every
    /// stage.
    pub fn start_reduce_scatter_dtype(
        self: &Arc<Self>,
        rank: usize,
        tag: u64,
        data: Vec<f32>,
        owner: usize,
        wire: Dtype,
    ) -> ScatterHandle {
        assert!(owner < self.n, "bucket owner {owner} out of range");
        ScatterHandle {
            owner: rank == owner,
            inner: self.start_all_reduce_dtype(rank, tag, data, wire),
        }
    }

    // -----------------------------------------------------------------
    // Hierarchical (two-tier) collectives.  Phase 1 reduces intra-node
    // among co-resident ranks, phase 2 runs the inter-node exchange over
    // exactly one representative per node (the node's lowest group
    // rank), phase 3 broadcasts/scatters back intra-node.  In this
    // shared-memory simulator the three phases execute as one deposit
    // round; what the hierarchy changes is (a) the per-tier byte
    // accounting below, and (b) the value transformation of the
    // inter-node hop: when the grad wire re-quantizes relative to the
    // storage wire (int8 always; bf16 over f32 storage), each node's
    // rank-order partial is round-tripped through the inter-node
    // encoding before the node-order fold.  When it does not — fp32 over
    // fp32, bf16 over bf16, or a single node — the two-tier fold
    // collapses to exactly the flat rank-order sum, **bitwise** (f32
    // addition is non-associative, so this is a design invariant, not an
    // accident: the value-preserving inter hop lets the fold stay flat).
    //
    // Per-tier byte conventions (mirrored EXACTLY by the analytic
    // `perf::hier_*` contract functions):
    // * intra = payloads crossing intra-node links at the storage wire
    //   width: each non-representative's contribution up, plus each
    //   result payload delivered back down to a rank that needs it
    //   (all-reduce: all `n-k` non-representatives; reduce-scatter: the
    //   owner iff it is not a representative);
    // * inter = each node's combined partial entering the inter-node
    //   exchange once, at the grad-wire width — `k ×
    //   grad_wire.payload_bytes(len)`; zero when the group spans one
    //   node.
    // -----------------------------------------------------------------

    /// Hierarchical [`Group::start_all_reduce_dtype`]: two-tier fold
    /// with an optional quantized inter-node grad wire.  Bitwise equal
    /// to the flat round whenever `grad_wire` does not re-quantize over
    /// `wire` (property-tested in `tests/props.rs`).
    pub fn start_all_reduce_hier(
        self: &Arc<Self>,
        rank: usize,
        tag: u64,
        data: Vec<f32>,
        wire: Dtype,
        grad_wire: GradWire,
    ) -> ReduceHandle {
        let map = self.hier_map();
        let (n, k) = (self.n as u64, map.n_nodes() as u64);
        self.start_hier_round(rank, tag, data, wire, grad_wire, 2 * (n - k))
    }

    /// Hierarchical [`Group::start_reduce_scatter_dtype`]: the intra
    /// tier reduces each node's contributions to its representative and
    /// delivers the reduced span to `owner` only, so the down-phase
    /// costs one payload iff the owner is not itself a representative.
    pub fn start_reduce_scatter_hier(
        self: &Arc<Self>,
        rank: usize,
        tag: u64,
        data: Vec<f32>,
        owner: usize,
        wire: Dtype,
        grad_wire: GradWire,
    ) -> ScatterHandle {
        assert!(owner < self.n, "bucket owner {owner} out of range");
        let map = self.hier_map();
        let (n, k) = (self.n as u64, map.n_nodes() as u64);
        let down = u64::from(!map.is_representative(owner));
        ScatterHandle {
            owner: rank == owner,
            inner: self.start_hier_round(rank, tag, data, wire, grad_wire, (n - k) + down),
        }
    }

    /// Shared deposit/fold machinery of the hierarchical bucket rounds.
    /// `intra_payloads` is the round's tier-1/3 payload count (each of
    /// size `len × wire`), fixed by the caller's collective shape.
    fn start_hier_round(
        self: &Arc<Self>,
        rank: usize,
        tag: u64,
        mut data: Vec<f32>,
        wire: Dtype,
        grad_wire: GradWire,
        intra_payloads: u64,
    ) -> ReduceHandle {
        assert!(rank < self.n);
        let len = data.len();
        if self.n == 1 {
            wire.quantize_slice(&mut data);
            return ReduceHandle { group: self.clone(), tag, immediate: Some(data) };
        }
        let map = self.hier_map();
        let k = map.n_nodes();
        let deposit: Payload = match wire {
            Dtype::F32 => Arc::new(data),
            Dtype::Bf16 => Arc::new(pack_bf16(&data)),
        };
        self.bytes_moved.fetch_add(4 * deposit.len() as u64, Ordering::Relaxed);
        let mut nb = self.nb.lock().unwrap();
        let round = nb.entry(tag).or_insert_with(|| NbRound {
            deposits: vec![None; self.n],
            len,
            wire,
            hier_wire: Some(grad_wire),
            ..Default::default()
        });
        assert!(round.result.is_none(), "bucket tag {tag:#x} reused before fully drained");
        assert!(round.deposits[rank].is_none(), "rank {rank} double deposit on bucket {tag:#x}");
        assert!(
            round.len == len && round.wire == wire && round.hier_wire == Some(grad_wire),
            "hier bucket {tag:#x}: rank {rank} deposited {len}×{:?}/{:?} into a {}×{:?}/{:?} round",
            wire,
            grad_wire,
            round.len,
            round.wire,
            round.hier_wire
        );
        round.deposits[rank] = Some(deposit);
        round.arrived += 1;
        if round.arrived == self.n {
            let deps: Vec<Payload> = round
                .deposits
                .iter()
                .map(|d| d.as_ref().expect("deposited").clone())
                .collect();
            drop(nb);
            let sum = hier_fold(&deps, len, wire, grad_wire, &map);
            let mut nb = self.nb.lock().unwrap();
            nb.get_mut(&tag).expect("in-flight round").result = Some(Arc::new(sum));
            self.nb_rounds.fetch_add(1, Ordering::Relaxed);
            self.nb_payload_bytes
                .fetch_add(wire.bytes() * len as u64, Ordering::Relaxed);
            self.nb_intra_bytes
                .fetch_add(wire.bytes() * len as u64 * intra_payloads, Ordering::Relaxed);
            if k > 1 {
                self.nb_inter_bytes
                    .fetch_add(k as u64 * grad_wire.payload_bytes(len as u64), Ordering::Relaxed);
            }
            self.nb_cv.notify_all();
        }
        ReduceHandle { group: self.clone(), tag, immediate: None }
    }

    /// Hierarchical [`Group::start_all_gather_shared`] (the ZeRO-3
    /// **primary** parameter gather): assembly is pure placement, so the
    /// result is bit-identical to the flat gather; what changes is the
    /// per-tier accounting — non-representative shards ride the intra
    /// tier up, each node's combined shard crosses the inter tier once
    /// (`total × wire` summed over nodes), and the assembled buffer
    /// rides back down to each non-representative.  Parameter gathers
    /// keep the storage wire: the quantized grad wire is gradient-only.
    pub fn start_all_gather_hier(
        self: &Arc<Self>,
        rank: usize,
        tag: u64,
        shard: Payload,
        total: usize,
        wire: Dtype,
    ) -> GatherHandle {
        self.start_all_gather_inner(rank, tag, shard, total, wire, true)
    }

    /// Node-local **secondary** all-gather (ZeRO++-style hpZ): a round
    /// among this rank's node members only, assembling the full
    /// `total`-element buffer from the node's secondary partition
    /// (`chunk_bounds(total, node_size)` spans, member-position order).
    /// All traffic is intra-node (`total × wire` per multi-member node
    /// round; a lone member's shard IS the buffer — immediate, free).
    /// Tags live in a per-node namespace: co-resident ranks must agree,
    /// different nodes never collide.
    pub fn start_all_gather_node(
        self: &Arc<Self>,
        rank: usize,
        tag: u64,
        shard: Payload,
        total: usize,
        wire: Dtype,
    ) -> NodeGatherHandle {
        assert!(rank < self.n);
        let map = self.hier_map();
        let node = map.node_of(rank);
        let members = map.members_of(node);
        let l = members.len();
        let pos = members.iter().position(|&m| m == rank).expect("member");
        let bounds = chunk_bounds(total, l);
        let (lo, hi) = bounds[pos];
        assert_eq!(shard.len(), hi - lo, "secondary shard size mismatch for rank {rank}");
        if l == 1 {
            return NodeGatherHandle {
                group: self.clone(),
                key: (node, tag),
                participants: 1,
                immediate: Some(shard),
            };
        }
        let deposit: Payload = match wire {
            Dtype::F32 => shard,
            Dtype::Bf16 => Arc::new(pack_bf16(&shard)),
        };
        self.bytes_moved.fetch_add(4 * deposit.len() as u64, Ordering::Relaxed);
        let key = (node, tag);
        let mut agn = self.agn.lock().unwrap();
        let round = agn.entry(key).or_insert_with(|| AgRound {
            deposits: vec![None; l],
            total,
            wire,
            ..Default::default()
        });
        assert!(round.result.is_none(), "node gather tag {tag:#x} reused before fully drained");
        assert!(
            round.deposits[pos].is_none(),
            "rank {rank} double deposit on node gather {tag:#x}"
        );
        assert!(
            round.total == total && round.wire == wire,
            "node gather {tag:#x}: rank {rank} deposited into a {}×{:?} round as {total}×{wire:?}",
            round.total,
            round.wire
        );
        round.deposits[pos] = Some(deposit);
        round.arrived += 1;
        if round.arrived == l {
            let deps: Vec<Payload> = round
                .deposits
                .iter()
                .map(|d| d.as_ref().expect("deposited").clone())
                .collect();
            drop(agn);
            let mut out = vec![0.0f32; total];
            for (p, contrib) in deps.iter().enumerate() {
                let (lo, hi) = bounds[p];
                match wire {
                    Dtype::F32 => out[lo..hi].copy_from_slice(contrib),
                    Dtype::Bf16 => out[lo..hi].copy_from_slice(&unpack_bf16(contrib, hi - lo)),
                }
            }
            let mut agn = self.agn.lock().unwrap();
            agn.get_mut(&key).expect("in-flight node gather").result = Some(Arc::new(out));
            self.ag_intra_bytes
                .fetch_add(wire.bytes() * total as u64, Ordering::Relaxed);
            self.agn_cv.notify_all();
        }
        NodeGatherHandle { group: self.clone(), key, participants: l, immediate: None }
    }

    /// Nonblocking all-gather, deposit phase (ZeRO-3's prefetchable
    /// on-demand parameter gather).  `shard` must be this rank's
    /// [`chunk_bounds`] slice of a `total`-element buffer; deposits are
    /// wire-cast (bf16 shards pack two-per-lane — half the bytes, and
    /// lossless whenever the shards already sit on the bf16 grid, the
    /// optimizer-maintained invariant).  The completing depositor
    /// assembles the shared full buffer — pure placement, no reduction,
    /// exact at any arrival order — and counts the round's logical
    /// payload (`total × dtype`) into `ag_payload_bytes`.  Redeem with
    /// [`GatherHandle::wait_shared`]; tags live in their own namespace
    /// and may not be reused until every rank has redeemed.
    pub fn start_all_gather_dtype(
        self: &Arc<Self>,
        rank: usize,
        tag: u64,
        shard: Vec<f32>,
        total: usize,
        wire: Dtype,
    ) -> GatherHandle {
        self.start_all_gather_shared(rank, tag, Arc::new(shard), total, wire)
    }

    /// Zero-copy deposit variant of [`Group::start_all_gather_dtype`]:
    /// an f32-wire deposit is the shared buffer itself (the engine hands
    /// its parameter-shard `Arc` straight in — no shard-sized copy per
    /// gather); bf16 still packs (which is itself the copy).
    pub fn start_all_gather_shared(
        self: &Arc<Self>,
        rank: usize,
        tag: u64,
        shard: Payload,
        total: usize,
        wire: Dtype,
    ) -> GatherHandle {
        self.start_all_gather_inner(rank, tag, shard, total, wire, false)
    }

    fn start_all_gather_inner(
        self: &Arc<Self>,
        rank: usize,
        tag: u64,
        shard: Payload,
        total: usize,
        wire: Dtype,
        hier: bool,
    ) -> GatherHandle {
        assert!(rank < self.n);
        let bounds = chunk_bounds(total, self.n);
        let (lo, hi) = bounds[rank];
        assert_eq!(shard.len(), hi - lo, "gather shard size mismatch for rank {rank}");
        if self.n == 1 {
            return GatherHandle { group: self.clone(), tag, immediate: Some(shard) };
        }
        let deposit: Payload = match wire {
            Dtype::F32 => shard,
            Dtype::Bf16 => Arc::new(pack_bf16(&shard)),
        };
        self.bytes_moved.fetch_add(4 * deposit.len() as u64, Ordering::Relaxed);
        let mut ag = self.ag.lock().unwrap();
        let round = ag.entry(tag).or_insert_with(|| AgRound {
            deposits: vec![None; self.n],
            total,
            wire,
            ..Default::default()
        });
        assert!(round.result.is_none(), "gather tag {tag:#x} reused before fully drained");
        assert!(round.deposits[rank].is_none(), "rank {rank} double deposit on gather {tag:#x}");
        assert!(
            round.total == total && round.wire == wire,
            "gather {tag:#x}: rank {rank} deposited into a {}×{:?} round as {total}×{wire:?}",
            round.total,
            round.wire
        );
        round.deposits[rank] = Some(deposit);
        round.arrived += 1;
        if round.arrived == self.n {
            let deps: Vec<Payload> = round
                .deposits
                .iter()
                .map(|d| d.as_ref().expect("deposited").clone())
                .collect();
            drop(ag);
            let mut out = vec![0.0f32; total];
            for (r, contrib) in deps.iter().enumerate() {
                let (lo, hi) = bounds[r];
                match wire {
                    Dtype::F32 => out[lo..hi].copy_from_slice(contrib),
                    Dtype::Bf16 => out[lo..hi].copy_from_slice(&unpack_bf16(contrib, hi - lo)),
                }
            }
            let mut ag = self.ag.lock().unwrap();
            ag.get_mut(&tag).expect("in-flight gather").result = Some(Arc::new(out));
            self.ag_payload_bytes
                .fetch_add(wire.bytes() * total as u64, Ordering::Relaxed);
            if hier {
                // intra: non-representative shards up + full buffer back
                // down to each non-representative; inter: each node's
                // combined shard crosses once (Σ node shards = total)
                let map = self.hier_map();
                let (n, k) = (self.n as u64, map.n_nodes() as u64);
                let up: u64 = (0..self.n)
                    .filter(|&r| !map.is_representative(r))
                    .map(|r| (bounds[r].1 - bounds[r].0) as u64)
                    .sum();
                self.ag_intra_bytes
                    .fetch_add(wire.bytes() * (up + (n - k) * total as u64), Ordering::Relaxed);
                if k > 1 {
                    self.ag_inter_bytes
                        .fetch_add(wire.bytes() * total as u64, Ordering::Relaxed);
                }
            }
            self.ag_cv.notify_all();
        }
        GatherHandle { group: self.clone(), tag, immediate: None }
    }

    /// Nonblocking **all-to-all**: rank `r` deposits `n` parts —
    /// `parts[d]` goes to destination `d` (the self part included) — and
    /// [`AllToAllHandle::wait`] returns this rank's receive set: its part
    /// from every source, **in source-rank order**, regardless of deposit
    /// arrival order.  Pure placement (no reduction), so the exchange is
    /// deterministic by construction; a `Bf16` wire packs every part
    /// (self parts too, so the value transformation is rank-count
    /// invariant) and the completing depositor unpacks on assembly.
    ///
    /// Part shapes are per-source free: each source picks its own part
    /// lengths (empty parts are fine) and destinations learn them from
    /// the received vectors.  Tags live in their own namespace and are
    /// single-use until every rank has redeemed, like the bucket rounds.
    ///
    /// Counters: `a2a_rounds` and `a2a_payload_bytes` (every part of
    /// every rank, × wire width) advance once per round;
    /// `a2a_intra_bytes`/`a2a_inter_bytes` split the src ≠ dst parts by
    /// the group's node placement (zero on topology-blind groups).  This
    /// is the MoE dispatch/combine wire (see `moe`).
    pub fn start_all_to_all_dtype(
        self: &Arc<Self>,
        rank: usize,
        tag: u64,
        parts: Vec<Vec<f32>>,
        wire: Dtype,
    ) -> AllToAllHandle {
        assert!(rank < self.n);
        assert_eq!(parts.len(), self.n, "all-to-all needs one part per destination");
        if self.n == 1 {
            // single rank: the receive set is the wire-cast self part
            let mut part = parts.into_iter().next().expect("one part");
            wire.quantize_slice(&mut part);
            return AllToAllHandle {
                group: self.clone(),
                rank,
                tag,
                immediate: Some(vec![part]),
            };
        }
        let lens: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        let deposit: Vec<Payload> = parts
            .into_iter()
            .map(|p| match wire {
                Dtype::F32 => Arc::new(p),
                Dtype::Bf16 => Arc::new(pack_bf16(&p)),
            })
            .collect();
        let packed: u64 = deposit.iter().map(|p| p.len() as u64).sum();
        self.bytes_moved.fetch_add(4 * packed, Ordering::Relaxed);
        let handle = AllToAllHandle { group: self.clone(), rank, tag, immediate: None };
        let mut a2a = self.a2a.lock().unwrap();
        let round = a2a.entry(tag).or_insert_with(|| A2aRound {
            deposits: vec![None; self.n],
            lens: vec![Vec::new(); self.n],
            wire,
            ..Default::default()
        });
        assert!(round.results.is_none(), "all-to-all tag {tag:#x} reused before fully drained");
        assert!(
            round.deposits[rank].is_none(),
            "rank {rank} double deposit on all-to-all {tag:#x}"
        );
        assert!(
            round.wire == wire,
            "all-to-all {tag:#x}: rank {rank} deposited {wire:?} into a {:?} round",
            round.wire
        );
        round.deposits[rank] = Some(deposit);
        round.lens[rank] = lens;
        round.arrived += 1;
        if round.arrived == self.n {
            // this deposit completes the round: assemble NOW, outside the
            // lock, so concurrent rounds keep flowing and the unpack cost
            // lands on this rank's timeline instead of in anyone's wait()
            let deps: Vec<Vec<Payload>> = round
                .deposits
                .iter()
                .map(|d| d.as_ref().expect("deposited").clone())
                .collect();
            let lens = round.lens.clone();
            drop(a2a);
            let results: Vec<Vec<Payload>> = (0..self.n)
                .map(|dst| {
                    (0..self.n)
                        .map(|src| match wire {
                            Dtype::F32 => deps[src][dst].clone(),
                            Dtype::Bf16 => {
                                Arc::new(unpack_bf16(&deps[src][dst], lens[src][dst]))
                            }
                        })
                        .collect()
                })
                .collect();
            let total: u64 = lens.iter().flatten().map(|&l| l as u64).sum();
            let (mut intra, mut inter) = (0u64, 0u64);
            if let Some(map) = &self.nodes {
                for src in 0..self.n {
                    for dst in 0..self.n {
                        if src == dst {
                            continue;
                        }
                        let b = wire.bytes() * lens[src][dst] as u64;
                        if map.node_of(src) == map.node_of(dst) {
                            intra += b;
                        } else {
                            inter += b;
                        }
                    }
                }
            }
            let mut a2a = self.a2a.lock().unwrap();
            a2a.get_mut(&tag).expect("in-flight round").results = Some(results);
            self.a2a_rounds.fetch_add(1, Ordering::Relaxed);
            self.a2a_payload_bytes.fetch_add(wire.bytes() * total, Ordering::Relaxed);
            self.a2a_intra_bytes.fetch_add(intra, Ordering::Relaxed);
            self.a2a_inter_bytes.fetch_add(inter, Ordering::Relaxed);
            self.a2a_cv.notify_all();
        }
        handle
    }

    /// Blocking [`Group::start_all_to_all_dtype`]: deposit, wait, return
    /// this rank's parts from every source in source-rank order.
    pub fn all_to_all(
        self: &Arc<Self>,
        rank: usize,
        tag: u64,
        parts: Vec<Vec<f32>>,
        wire: Dtype,
    ) -> Vec<Vec<f32>> {
        let _s = trace::span(Category::MoeA2a, "all_to_all");
        self.start_all_to_all_dtype(rank, tag, parts, wire).wait()
    }
}

/// Handle on one in-flight nonblocking bucket round (see
/// [`Group::start_all_reduce`]).
#[must_use = "an unredeemed bucket deadlocks the round's other ranks"]
pub struct ReduceHandle {
    group: Arc<Group>,
    tag: u64,
    /// Single-rank groups reduce to the deposit itself.
    immediate: Option<Vec<f32>>,
}

impl ReduceHandle {
    /// Block until every rank has deposited, then return an owned copy
    /// of the rank-order sum.  The last rank to redeem recovers the
    /// shared buffer without a copy; prefer [`ReduceHandle::wait_shared`]
    /// when a borrow suffices (the engine's drain copies straight out of
    /// the shared sum into its gradient buffer — one copy total).
    pub fn wait(self) -> Vec<f32> {
        match Arc::try_unwrap(self.wait_shared()) {
            Ok(sum) => sum,
            Err(shared) => shared.as_slice().to_vec(),
        }
    }

    /// Like [`ReduceHandle::wait`] but zero-copy: returns the shared
    /// rank-order sum itself.  Redeeming also retires the round once
    /// every rank has done so (freeing the tag for reuse).
    pub fn wait_shared(self) -> Payload {
        if let Some(data) = self.immediate {
            return Arc::new(data);
        }
        // tags inherit from the enclosing span (the drain's chunk lane)
        let _s = trace::span(Category::DpSync, "reduce_wait");
        let n = self.group.n;
        let deadline = self.group.comm_deadline();
        let tag = self.tag;
        let mut nb = self.group.nb.lock().unwrap();
        loop {
            let round = nb.get_mut(&self.tag).expect("bucket round vanished");
            if round.result.is_some() {
                round.taken += 1;
                let result = round.result.as_ref().expect("result set").clone();
                if round.taken == n {
                    nb.remove(&self.tag);
                }
                return result;
            }
            nb = wait_bounded(&self.group.nb_cv, nb, deadline, |m, ms| PeerLost {
                rank: m
                    .get(&tag)
                    .and_then(|r| r.deposits.iter().position(|d| d.is_none())),
                tag,
                what: "nonblocking all-reduce",
                waited_ms: ms,
            });
        }
    }
}

/// Handle on one in-flight reduce-scatter bucket (see
/// [`Group::start_reduce_scatter_dtype`]).  Every rank must redeem its
/// handle (that is what retires the round and frees the tag), but only
/// the bucket's owner receives — and therefore materialises — the
/// reduced span.
#[must_use = "an unredeemed reduce-scatter bucket deadlocks the round's other ranks"]
pub struct ScatterHandle {
    inner: ReduceHandle,
    owner: bool,
}

impl ScatterHandle {
    /// Block until every rank has deposited.  The owner gets an owned
    /// copy of the bucket's rank-order sum; every other rank gets `None`
    /// without copying a byte of the result.  Prefer
    /// [`ScatterHandle::wait_shared`] when a borrow suffices (the
    /// engine's drain copies straight out of the shared sum into its
    /// gradient shard — one copy total).
    pub fn wait(self) -> Option<Vec<f32>> {
        self.wait_shared().map(|shared| match Arc::try_unwrap(shared) {
            Ok(v) => v,
            Err(s) => s.as_slice().to_vec(),
        })
    }

    /// Zero-copy redeem: the shared rank-order sum itself for the owner,
    /// `None` for everyone else.  Redeeming retires the round once every
    /// rank has done so.
    pub fn wait_shared(self) -> Option<Payload> {
        let shared = self.inner.wait_shared();
        self.owner.then_some(shared)
    }
}

/// Handle on one in-flight nonblocking all-gather round (see
/// [`Group::start_all_gather_dtype`]).
#[must_use = "an unredeemed gather deadlocks the round's other ranks"]
pub struct GatherHandle {
    group: Arc<Group>,
    tag: u64,
    /// Single-rank groups gather to the deposit itself.
    immediate: Option<Payload>,
}

impl GatherHandle {
    /// Block until every rank has deposited, then return an owned copy of
    /// the assembled buffer.
    pub fn wait(self) -> Vec<f32> {
        match Arc::try_unwrap(self.wait_shared()) {
            Ok(v) => v,
            Err(shared) => shared.as_slice().to_vec(),
        }
    }

    /// Zero-copy redeem: the shared assembled buffer itself (ZeRO-3 hands
    /// this straight to the stage entry points as the step's parameter
    /// view).  Redeeming also retires the round once every rank has done
    /// so, freeing the tag.
    pub fn wait_shared(self) -> Payload {
        if let Some(data) = self.immediate {
            return data;
        }
        let _s = trace::span(Category::ZeroGather, "gather_wait");
        let n = self.group.n;
        let deadline = self.group.comm_deadline();
        let tag = self.tag;
        let mut ag = self.group.ag.lock().unwrap();
        loop {
            let round = ag.get_mut(&self.tag).expect("gather round vanished");
            if round.result.is_some() {
                round.taken += 1;
                let result = round.result.as_ref().expect("result set").clone();
                if round.taken == n {
                    ag.remove(&self.tag);
                }
                return result;
            }
            ag = wait_bounded(&self.group.ag_cv, ag, deadline, |m, ms| PeerLost {
                rank: m
                    .get(&tag)
                    .and_then(|r| r.deposits.iter().position(|d| d.is_none())),
                tag,
                what: "nonblocking all-gather",
                waited_ms: ms,
            });
        }
    }
}

/// Handle on one in-flight all-to-all round (see
/// [`Group::start_all_to_all_dtype`]).
#[must_use = "an unredeemed all-to-all deadlocks the round's other ranks"]
pub struct AllToAllHandle {
    group: Arc<Group>,
    rank: usize,
    tag: u64,
    /// Single-rank groups exchange the wire-cast self part.
    immediate: Option<Vec<Vec<f32>>>,
}

impl AllToAllHandle {
    /// Block until every rank has deposited, then return an owned copy of
    /// this rank's receive set — one part per source, in source-rank
    /// order.  Prefer [`AllToAllHandle::wait_shared`] when borrows
    /// suffice.
    pub fn wait(self) -> Vec<Vec<f32>> {
        self.wait_shared()
            .into_iter()
            .map(|p| match Arc::try_unwrap(p) {
                Ok(v) => v,
                Err(shared) => shared.as_slice().to_vec(),
            })
            .collect()
    }

    /// Zero-copy redeem: the shared per-source parts themselves.
    /// Redeeming also retires the round once every rank has done so
    /// (freeing the tag for reuse).
    pub fn wait_shared(self) -> Vec<Payload> {
        if let Some(parts) = self.immediate {
            return parts.into_iter().map(Arc::new).collect();
        }
        let _s = trace::span(Category::MoeA2a, "a2a_wait");
        let n = self.group.n;
        let deadline = self.group.comm_deadline();
        let tag = self.tag;
        let mut a2a = self.group.a2a.lock().unwrap();
        loop {
            let round = a2a.get_mut(&self.tag).expect("all-to-all round vanished");
            if round.results.is_some() {
                let mine = round.results.as_ref().expect("results set")[self.rank].clone();
                round.taken += 1;
                if round.taken == n {
                    a2a.remove(&self.tag);
                }
                return mine;
            }
            a2a = wait_bounded(&self.group.a2a_cv, a2a, deadline, |m, ms| PeerLost {
                rank: m
                    .get(&tag)
                    .and_then(|r| r.deposits.iter().position(|d| d.is_none())),
                tag,
                what: "nonblocking all-to-all",
                waited_ms: ms,
            });
        }
    }
}

/// Handle on one in-flight node-local secondary all-gather (see
/// [`Group::start_all_gather_node`]).
#[must_use = "an unredeemed node gather deadlocks the node's other ranks"]
pub struct NodeGatherHandle {
    group: Arc<Group>,
    key: (usize, u64),
    participants: usize,
    /// Single-member nodes gather to the deposit itself.
    immediate: Option<Payload>,
}

impl NodeGatherHandle {
    /// Block until every node member has deposited, then return an owned
    /// copy of the assembled buffer.
    pub fn wait(self) -> Vec<f32> {
        match Arc::try_unwrap(self.wait_shared()) {
            Ok(v) => v,
            Err(shared) => shared.as_slice().to_vec(),
        }
    }

    /// Zero-copy redeem of the node-assembled buffer; retires the round
    /// (freeing the tag within the node) once every member has redeemed.
    pub fn wait_shared(self) -> Payload {
        if let Some(data) = self.immediate {
            return data;
        }
        let _s = trace::span(Category::ZeroGather, "node_gather_wait");
        let n = self.participants;
        let deadline = self.group.comm_deadline();
        let key = self.key;
        let mut agn = self.group.agn.lock().unwrap();
        loop {
            let round = agn.get_mut(&self.key).expect("node gather round vanished");
            if round.result.is_some() {
                round.taken += 1;
                let result = round.result.as_ref().expect("result set").clone();
                if round.taken == n {
                    agn.remove(&self.key);
                }
                return result;
            }
            agn = wait_bounded(&self.group.agn_cv, agn, deadline, |m, ms| PeerLost {
                // rank here is the missing *member position* within the node
                rank: m
                    .get(&key)
                    .and_then(|r| r.deposits.iter().position(|d| d.is_none())),
                tag: key.1,
                what: "node-local all-gather",
                waited_ms: ms,
            });
        }
    }
}

/// The hierarchical rounds' fold.  Value-preserving inter hops (and
/// single-node maps) collapse to the flat global rank-order sum —
/// bitwise identical to [`Group::start_all_reduce_dtype`]'s fold.  A
/// re-quantizing grad wire folds each node's members in rank order,
/// round-trips the node partial through the inter-node encoding, then
/// folds the partials in node-index order — deterministic at any deposit
/// arrival order.
fn hier_fold(
    deps: &[Payload],
    len: usize,
    wire: Dtype,
    grad_wire: GradWire,
    map: &NodeMap,
) -> Vec<f32> {
    let add = |sum: &mut [f32], contrib: &Payload| match wire {
        Dtype::F32 => {
            debug_assert_eq!(contrib.len(), len);
            for (x, &c) in sum.iter_mut().zip(contrib.iter()) {
                *x += c;
            }
        }
        Dtype::Bf16 => {
            let unpacked = unpack_bf16(contrib, len);
            for (x, &c) in sum.iter_mut().zip(unpacked.iter()) {
                *x += c;
            }
        }
    };
    let k = map.n_nodes();
    if k == 1 || !grad_wire.requantizes_over(wire) {
        let mut sum = vec![0.0f32; len];
        for contrib in deps {
            add(&mut sum, contrib);
        }
        return sum;
    }
    let mut total = vec![0.0f32; len];
    for node in 0..k {
        let mut partial = vec![0.0f32; len];
        for r in map.members_of(node) {
            add(&mut partial, &deps[r]);
        }
        grad_wire.roundtrip_slice(&mut partial);
        for (x, &p) in total.iter_mut().zip(partial.iter()) {
            *x += p;
        }
    }
    total
}

/// A collective communicator over a *subset* of a parent [`Group`]'s
/// ranks, built on the parent's tagged mailboxes (the parent's barrier /
/// `exchange` machinery needs every world rank, so subgroup collectives
/// run a ring between member neighbours instead).
///
/// Members execute SPMD: every member must issue the same sequence of
/// subgroup collectives in the same order (FIFO holds per tag, so
/// back-to-back rounds cannot interleave).
pub struct SubGroup {
    parent: Arc<Group>,
    /// Parent ranks, strictly ascending; position in this list is the
    /// subgroup rank.
    members: Vec<usize>,
    tag: u64,
    /// Payload bytes entering all-reduce calls on this subgroup, counted
    /// once per collective round (by subgroup rank 0) — i.e. the logical
    /// reduced volume, not wire traffic.  Wire bytes still land in the
    /// parent's `bytes_moved`.
    pub ar_bytes: AtomicU64,
    /// All-reduce rounds completed on this subgroup.
    pub ar_rounds: AtomicU64,
}

impl SubGroup {
    /// Build a subgroup over `members` (parent ranks, strictly ascending).
    /// `id` must be unique among subgroups that share a (from, to) member
    /// pair; disjoint subgroups may reuse ids.
    pub fn new(parent: &Arc<Group>, members: Vec<usize>, id: u64) -> Arc<Self> {
        assert!(!members.is_empty(), "subgroup needs at least one member");
        assert!(
            members.windows(2).all(|w| w[0] < w[1]),
            "subgroup members must be strictly ascending"
        );
        assert!(members.iter().all(|&r| r < parent.len()), "member out of range");
        Arc::new(Self {
            parent: parent.clone(),
            members,
            tag: TAG_SUBGROUP | id,
            ar_bytes: AtomicU64::new(0),
            ar_rounds: AtomicU64::new(0),
        })
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    pub fn members(&self) -> &[usize] {
        &self.members
    }

    /// Subgroup rank of a parent rank (panics if not a member).
    pub fn index_of(&self, parent_rank: usize) -> usize {
        self.members
            .iter()
            .position(|&r| r == parent_rank)
            .expect("parent rank is not a member of this subgroup")
    }

    /// Ring all-reduce with an arbitrary commutative/associative fold:
    /// reduce-scatter then all-gather between member neighbours over the
    /// parent's tagged mailboxes.  In place; every member ends with
    /// identical bytes.
    fn ring_fold<F: Fn(f32, f32) -> f32>(&self, parent_rank: usize, buf: &mut [f32], fold: F) {
        let n = self.members.len();
        if n == 1 {
            return;
        }
        let i = self.index_of(parent_rank);
        if i == 0 {
            self.ar_bytes.fetch_add(4 * buf.len() as u64, Ordering::Relaxed);
            self.ar_rounds.fetch_add(1, Ordering::Relaxed);
        }
        let right = self.members[(i + 1) % n];
        let left = self.members[(i + n - 1) % n];
        let bounds = chunk_bounds(buf.len(), n);
        for step in 0..n - 1 {
            let send_idx = (i + n - step) % n;
            let recv_idx = (i + n - step - 1) % n;
            let (s0, s1) = bounds[send_idx];
            self.parent.send_tagged(parent_rank, right, self.tag, buf[s0..s1].to_vec());
            let incoming = self.parent.recv_shared(parent_rank, left, self.tag);
            let (r0, r1) = bounds[recv_idx];
            debug_assert_eq!(incoming.len(), r1 - r0);
            for (x, &inc) in buf[r0..r1].iter_mut().zip(incoming.iter()) {
                *x = fold(*x, inc);
            }
        }
        for step in 0..n - 1 {
            let send_idx = (i + 1 + n - step) % n;
            let recv_idx = (i + n - step) % n;
            let (s0, s1) = bounds[send_idx];
            self.parent.send_tagged(parent_rank, right, self.tag, buf[s0..s1].to_vec());
            let incoming = self.parent.recv_shared(parent_rank, left, self.tag);
            let (r0, r1) = bounds[recv_idx];
            buf[r0..r1].copy_from_slice(&incoming);
        }
    }

    /// Deposit-exchange all-reduce with wire casting: every member
    /// fan-outs one (possibly bf16-packed) payload to every other member
    /// and folds all contributions **in member-rank order** — so the
    /// result is exactly a rank-order fold of the wire-cast inputs,
    /// independent of arrival timing (the `Algo::Naive` semantics, and
    /// the only algorithm a packed payload supports: ring hops forward
    /// *partial sums*, which a half-width wire would re-quantize at
    /// every hop).
    fn exchange_fold<F: Fn(f32, f32) -> f32>(
        &self,
        parent_rank: usize,
        buf: &mut [f32],
        wire: Dtype,
        fold: F,
    ) {
        let n = self.members.len();
        if n == 1 {
            // match the bucket path's single-rank contract: the result is
            // still the wire-cast input (no-op for f32)
            wire.quantize_slice(buf);
            return;
        }
        let i = self.index_of(parent_rank);
        if i == 0 {
            self.ar_bytes
                .fetch_add(wire.bytes() * buf.len() as u64, Ordering::Relaxed);
            self.ar_rounds.fetch_add(1, Ordering::Relaxed);
        }
        // wire cast: the local contribution must equal what the others
        // receive, so quantize in place before anything reads `buf`
        wire.quantize_slice(buf);
        let payload: Payload = match wire {
            Dtype::F32 => Arc::new(buf.to_vec()),
            Dtype::Bf16 => Arc::new(pack_bf16(buf)),
        };
        for (r, &m) in self.members.iter().enumerate() {
            if r != i {
                self.parent.send_shared(parent_rank, m, self.tag, payload.clone());
            }
        }
        let mut acc = vec![0.0f32; buf.len()];
        for (r, &m) in self.members.iter().enumerate() {
            let owned;
            let contrib: &[f32] = if r == i {
                &*buf
            } else {
                let incoming = self.parent.recv_shared(parent_rank, m, self.tag);
                owned = match wire {
                    Dtype::F32 => incoming.as_slice().to_vec(),
                    Dtype::Bf16 => unpack_bf16(&incoming, buf.len()),
                };
                &owned
            };
            debug_assert_eq!(contrib.len(), acc.len());
            if r == 0 {
                acc.copy_from_slice(contrib);
            } else {
                for (a, &c) in acc.iter_mut().zip(contrib) {
                    *a = fold(*a, c);
                }
            }
        }
        buf.copy_from_slice(&acc);
    }

    /// In-place sum all-reduce across the subgroup members (f32 ring —
    /// the legacy path every existing caller pins).
    pub fn all_reduce_sum(&self, parent_rank: usize, buf: &mut [f32]) {
        self.all_reduce_sum_cfg(parent_rank, buf, Algo::Ring, Dtype::F32);
    }

    /// In-place max all-reduce (vocab-parallel softmax stability term).
    pub fn all_reduce_max(&self, parent_rank: usize, buf: &mut [f32]) {
        self.all_reduce_max_cfg(parent_rank, buf, Algo::Ring, Dtype::F32);
    }

    /// Sum all-reduce with explicit algorithm + wire dtype.  `(Ring,
    /// F32)` is the chunked ring; everything else runs the deposit
    /// exchange (`Naive` semantics, and the only shape a packed bf16
    /// payload supports — see [`SubGroup::exchange_fold`]).
    pub fn all_reduce_sum_cfg(&self, parent_rank: usize, buf: &mut [f32], algo: Algo, wire: Dtype) {
        match (algo, wire) {
            (Algo::Ring, Dtype::F32) => self.ring_fold(parent_rank, buf, |a, b| a + b),
            _ => self.exchange_fold(parent_rank, buf, wire, |a, b| a + b),
        }
    }

    /// Max all-reduce with explicit algorithm + wire dtype.
    pub fn all_reduce_max_cfg(&self, parent_rank: usize, buf: &mut [f32], algo: Algo, wire: Dtype) {
        match (algo, wire) {
            (Algo::Ring, Dtype::F32) => self.ring_fold(parent_rank, buf, f32::max),
            _ => self.exchange_fold(parent_rank, buf, wire, f32::max),
        }
    }
}

/// One rank's handle on its tensor-parallel subgroup: the subgroup plus
/// this thread's parent rank.  The tp = 1 case ([`TpComm::solo`]) turns
/// every collective into a no-op, so the sharded compute paths double as
/// the dense ones.
///
/// The communicator carries the engine's collective configuration: the
/// wire [`Dtype`] (bf16 payloads pack two values per lane — half the
/// bytes and half the instrumented `ar_bytes`) and the [`Algo`] for the
/// f32 case.  Defaults (`F32`, `Ring`) reproduce the pre-mixed-precision
/// engine bitwise.
#[derive(Clone)]
pub struct TpComm {
    group: Arc<SubGroup>,
    rank: usize,
    wire: Dtype,
    algo: Algo,
}

impl TpComm {
    pub fn new(group: Arc<SubGroup>, parent_rank: usize) -> Self {
        group.index_of(parent_rank); // assert membership
        Self { group, rank: parent_rank, wire: Dtype::F32, algo: Algo::Ring }
    }

    /// The tp = 1 no-communication communicator.
    pub fn solo() -> Self {
        let parent = Group::new(1);
        Self {
            group: SubGroup::new(&parent, vec![0], 0),
            rank: 0,
            wire: Dtype::F32,
            algo: Algo::Ring,
        }
    }

    /// Communicator with a bf16 (or explicit f32) wire dtype.
    pub fn with_wire(mut self, wire: Dtype) -> Self {
        self.wire = wire;
        self
    }

    /// Communicator with an explicit f32 collective algorithm.
    pub fn with_algo(mut self, algo: Algo) -> Self {
        self.algo = algo;
        self
    }

    /// Tensor-parallel group size.
    pub fn tp(&self) -> usize {
        self.group.len()
    }

    /// This shard's rank within the TP group.
    pub fn tp_rank(&self) -> usize {
        self.group.index_of(self.rank)
    }

    /// Collective payload dtype of this communicator.
    pub fn wire(&self) -> Dtype {
        self.wire
    }

    pub fn all_reduce_sum(&self, buf: &mut [f32]) {
        // solo communicators skip the span too: nothing moves at tp = 1
        let _s = (self.group.len() > 1).then(|| trace::span(Category::TpComm, "tp_allreduce"));
        self.group.all_reduce_sum_cfg(self.rank, buf, self.algo, self.wire);
    }

    pub fn all_reduce_max(&self, buf: &mut [f32]) {
        let _s = (self.group.len() > 1).then(|| trace::span(Category::TpComm, "tp_allreduce_max"));
        self.group.all_reduce_max_cfg(self.rank, buf, self.algo, self.wire);
    }
}

/// Split `len` elements into `n` contiguous chunks, earlier chunks taking
/// the remainder (matches `ModelSpec::stage_spans` convention).
pub fn chunk_bounds(len: usize, n: usize) -> Vec<(usize, usize)> {
    let base = len / n;
    let rem = len % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let size = base + usize::from(i < rem);
        out.push((start, start + size));
        start += size;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn run_ranks<F>(n: usize, f: F)
    where
        F: Fn(usize, Arc<Group>) + Send + Sync + 'static,
    {
        let group = Group::new(n);
        let f = Arc::new(f);
        let handles: Vec<_> = (0..n)
            .map(|r| {
                let g = group.clone();
                let f = f.clone();
                thread::spawn(move || f(r, g))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    fn test_data(rank: usize, len: usize) -> Vec<f32> {
        (0..len).map(|i| ((rank * 31 + i) as f32 * 0.1).sin()).collect()
    }

    fn expected_sum(n: usize, len: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; len];
        for r in 0..n {
            for (x, v) in out.iter_mut().zip(test_data(r, len)) {
                *x += v;
            }
        }
        out
    }

    #[test]
    fn naive_all_reduce_sums() {
        for n in [1usize, 2, 3, 4, 8] {
            let len = 103;
            let want = expected_sum(n, len);
            run_ranks(n, move |rank, g| {
                let mut buf = test_data(rank, len);
                g.all_reduce_sum(rank, &mut buf, Algo::Naive);
                for (a, b) in buf.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-4);
                }
            });
        }
    }

    #[test]
    fn ring_matches_naive() {
        for n in [2usize, 3, 4, 7, 8] {
            for len in [8usize, 64, 1000, 1003] {
                let want = expected_sum(n, len);
                run_ranks(n, move |rank, g| {
                    let mut buf = test_data(rank, len);
                    g.all_reduce_sum(rank, &mut buf, Algo::Ring);
                    for (i, (a, b)) in buf.iter().zip(&want).enumerate() {
                        assert!((a - b).abs() < 1e-3, "n={n} len={len} i={i}: {a} vs {b}");
                    }
                });
            }
        }
    }

    #[test]
    fn reduce_scatter_then_all_gather_is_all_reduce() {
        let n = 4;
        let len = 50;
        let want = expected_sum(n, len);
        run_ranks(n, move |rank, g| {
            let buf = test_data(rank, len);
            let shard = g.reduce_scatter_sum(rank, &buf);
            let mut full = vec![0.0f32; len];
            g.all_gather(rank, &shard, &mut full);
            for (a, b) in full.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn broadcast_from_each_root() {
        let n = 4;
        for root in 0..n {
            run_ranks(n, move |rank, g| {
                let mut buf = if rank == root {
                    vec![42.0f32; 17]
                } else {
                    vec![0.0f32; 17]
                };
                g.broadcast(rank, root, &mut buf);
                assert!(buf.iter().all(|&x| x == 42.0));
            });
        }
    }

    #[test]
    fn p2p_fifo_order() {
        run_ranks(2, |rank, g| {
            if rank == 0 {
                g.send(0, 1, vec![1.0]);
                g.send(0, 1, vec![2.0]);
            } else {
                assert_eq!(g.recv(1, 0), vec![1.0]);
                assert_eq!(g.recv(1, 0), vec![2.0]);
            }
        });
    }

    #[test]
    fn tagged_p2p_matches_out_of_order() {
        // receiver can drain tags in a different order than they arrived,
        // and FIFO holds within one tag — the chunked-pipeline contract
        run_ranks(2, |rank, g| {
            if rank == 0 {
                g.send_tagged(0, 1, 7, vec![7.0]);
                g.send_tagged(0, 1, 9, vec![9.0]);
                g.send_tagged(0, 1, 7, vec![7.5]);
            } else {
                assert_eq!(g.recv_tagged(1, 0, 9), vec![9.0]);
                assert_eq!(g.recv_tagged(1, 0, 7), vec![7.0]);
                assert_eq!(g.recv_tagged(1, 0, 7), vec![7.5]);
            }
        });
    }

    #[test]
    fn repeated_rounds_no_corruption() {
        // stress the generation/drain logic with many back-to-back rounds
        let n = 4;
        run_ranks(n, move |rank, g| {
            for round in 0..50 {
                let mut buf = vec![(rank + round) as f32; 16];
                g.all_reduce_sum(rank, &mut buf, Algo::Naive);
                let want = (0..n).map(|r| (r + round) as f32).sum::<f32>();
                assert!(buf.iter().all(|&x| (x - want).abs() < 1e-5), "round {round}");
            }
        });
    }

    #[test]
    fn chunk_bounds_cover() {
        for len in [0usize, 1, 7, 8, 100] {
            for n in [1usize, 2, 3, 8] {
                let b = chunk_bounds(len, n);
                assert_eq!(b.len(), n);
                assert_eq!(b[0].0, 0);
                assert_eq!(b.last().unwrap().1, len);
                for w in b.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                }
            }
        }
    }

    #[test]
    fn subgroup_all_reduce_sums_members_only() {
        // world of 4; subgroup {1, 3} must reduce only its members while
        // ranks 0 and 2 stay idle
        let world = Group::new(4);
        let sub = SubGroup::new(&world, vec![1, 3], 0);
        let handles: Vec<_> = [1usize, 3]
            .into_iter()
            .map(|rank| {
                let s = sub.clone();
                thread::spawn(move || {
                    let mut buf = test_data(rank, 33);
                    s.all_reduce_sum(rank, &mut buf);
                    buf
                })
            })
            .collect();
        let mut want = vec![0.0f32; 33];
        for r in [1usize, 3] {
            for (x, v) in want.iter_mut().zip(test_data(r, 33)) {
                *x += v;
            }
        }
        for h in handles {
            let got = h.join().unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
        // payload accounting: one round of 33 floats
        assert_eq!(sub.ar_bytes.load(Ordering::Relaxed), 4 * 33);
        assert_eq!(sub.ar_rounds.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn disjoint_subgroups_reduce_concurrently() {
        let world = Group::new(6);
        let a = SubGroup::new(&world, vec![0, 1, 2], 0);
        let b = SubGroup::new(&world, vec![3, 4, 5], 1);
        let mut handles = Vec::new();
        for rank in 0..6usize {
            let sub = if rank < 3 { a.clone() } else { b.clone() };
            handles.push(thread::spawn(move || {
                let mut buf = vec![rank as f32; 20];
                for _ in 0..10 {
                    sub.all_reduce_sum(rank, &mut buf);
                }
                buf
            }));
        }
        for (rank, h) in handles.into_iter().enumerate() {
            let got = h.join().unwrap();
            // after 10 rounds the value is rank-sum * 3^9 within the group
            let base: f32 = if rank < 3 { 0.0 + 1.0 + 2.0 } else { 3.0 + 4.0 + 5.0 };
            let want = base * 3.0f32.powi(9);
            assert!(
                got.iter().all(|&x| (x - want).abs() / want.max(1.0) < 1e-4),
                "rank {rank}: {} vs {want}",
                got[0]
            );
        }
    }

    #[test]
    fn subgroup_all_reduce_max() {
        let world = Group::new(3);
        let sub = SubGroup::new(&world, vec![0, 1, 2], 7);
        let handles: Vec<_> = (0..3usize)
            .map(|rank| {
                let s = sub.clone();
                thread::spawn(move || {
                    let mut buf: Vec<f32> =
                        (0..10).map(|i| ((rank * 17 + i * 3) % 11) as f32 - 5.0).collect();
                    s.all_reduce_max(rank, &mut buf);
                    buf
                })
            })
            .collect();
        let mut want = vec![f32::NEG_INFINITY; 10];
        for rank in 0..3usize {
            for (i, w) in want.iter_mut().enumerate() {
                *w = w.max(((rank * 17 + i * 3) % 11) as f32 - 5.0);
            }
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), want);
        }
    }

    #[test]
    fn tp_comm_solo_is_noop() {
        let comm = TpComm::solo();
        assert_eq!(comm.tp(), 1);
        assert_eq!(comm.tp_rank(), 0);
        let mut buf = vec![1.0f32, 2.0, 3.0];
        comm.all_reduce_sum(&mut buf);
        assert_eq!(buf, vec![1.0, 2.0, 3.0]);
        comm.all_reduce_max(&mut buf);
        assert_eq!(buf, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn subgroup_short_buffer_smaller_than_group() {
        // len < n leaves some ring chunks empty; must still be exact
        let world = Group::new(4);
        let sub = SubGroup::new(&world, vec![0, 1, 2, 3], 0);
        let handles: Vec<_> = (0..4usize)
            .map(|rank| {
                let s = sub.clone();
                thread::spawn(move || {
                    let mut buf = vec![rank as f32 + 1.0; 2];
                    s.all_reduce_sum(rank, &mut buf);
                    buf
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![10.0, 10.0]);
        }
    }

    #[test]
    fn byte_counters_advance() {
        let n = 2;
        run_ranks(n, move |rank, g| {
            let mut buf = vec![1.0f32; 100];
            g.all_reduce_sum(rank, &mut buf, Algo::Ring);
            if rank == 0 {
                assert!(g.bytes_moved.load(Ordering::Relaxed) > 0);
            }
        });
    }

    #[test]
    fn shared_payload_fanout_no_reorder() {
        // one Arc payload sent to two receivers; each sees the same bytes
        run_ranks(3, |rank, g| {
            if rank == 0 {
                let payload: Payload = Arc::new(vec![1.0, 2.0, 3.0]);
                g.send_shared(0, 1, 5, payload.clone());
                g.send_shared(0, 2, 5, payload);
            } else {
                assert_eq!(g.recv_tagged(rank, 0, 5), vec![1.0, 2.0, 3.0]);
            }
        });
    }

    #[test]
    fn nonblocking_all_reduce_matches_blocking() {
        // rank-order sum, bit-identical to Algo::Naive
        for n in [1usize, 2, 3, 4] {
            let len = 37;
            let mut want = vec![0.0f32; len];
            for r in 0..n {
                for (x, v) in want.iter_mut().zip(test_data(r, len)) {
                    *x += v;
                }
            }
            run_ranks(n, move |rank, g| {
                let h = g.start_all_reduce(rank, 0xB0, test_data(rank, len));
                assert_eq!(h.wait(), want, "n={n} rank={rank}");
            });
        }
    }

    #[test]
    fn nonblocking_buckets_interleave() {
        // several buckets in flight at once, deposited in different
        // orders per rank, must each reduce independently
        let n = 4;
        run_ranks(n, move |rank, g| {
            let handles: Vec<_> = (0..4u64)
                .map(|b| {
                    // ranks deposit buckets in different orders
                    let bucket = if rank % 2 == 0 { b } else { 3 - b };
                    let data = vec![(rank + bucket as usize) as f32; 8];
                    (bucket, g.start_all_reduce(rank, bucket, data))
                })
                .collect();
            for (bucket, h) in handles {
                let want = (0..n).map(|r| (r + bucket as usize) as f32).sum::<f32>();
                assert!(h.wait().iter().all(|&x| x == want), "bucket {bucket}");
            }
        });
    }

    #[test]
    fn nonblocking_round_counter_and_tag_reuse() {
        let n = 2;
        let group = Group::new(n);
        // two sequential rounds on the same tag: legal once fully drained
        for round in 0..2 {
            let handles: Vec<_> = (0..n)
                .map(|rank| {
                    let g = group.clone();
                    thread::spawn(move || g.start_all_reduce(rank, 7, vec![rank as f32; 4]).wait())
                })
                .collect();
            for h in handles {
                assert_eq!(h.join().unwrap(), vec![1.0; 4], "round {round}");
            }
        }
        assert_eq!(group.nb_rounds.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn nonblocking_single_rank_is_identity() {
        let g = Group::new(1);
        let h = g.start_all_reduce(0, 1, vec![4.0, 5.0]);
        assert_eq!(h.wait(), vec![4.0, 5.0]);
        assert_eq!(g.nb_rounds.load(Ordering::Relaxed), 0);
    }

    /// Rank-order f32 sum of the bf16-quantized inputs — what every
    /// packed-wire collective must reproduce bitwise.
    fn quantized_rank_order_sum(n: usize, len: usize) -> Vec<f32> {
        let mut want = vec![0.0f32; len];
        for r in 0..n {
            for (x, v) in want.iter_mut().zip(test_data(r, len)) {
                *x += Dtype::Bf16.quantize(v);
            }
        }
        want
    }

    #[test]
    fn bf16_bucketed_allreduce_matches_quantized_rank_order_sum() {
        for n in [1usize, 2, 3, 4] {
            for len in [1usize, 8, 37] {
                // odd lengths exercise the pack pad half
                let want = if n == 1 {
                    Dtype::Bf16.quantized(&test_data(0, len))
                } else {
                    quantized_rank_order_sum(n, len)
                };
                run_ranks(n, move |rank, g| {
                    let h = g.start_all_reduce_dtype(rank, 0xBF, test_data(rank, len), Dtype::Bf16);
                    let got = h.wait();
                    assert_eq!(got.len(), len);
                    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                        assert_eq!(a.to_bits(), b.to_bits(), "n={n} len={len} i={i}");
                    }
                });
            }
        }
    }

    #[test]
    fn bf16_bucket_counters_count_half_width_payload() {
        let n = 2;
        let len = 101usize; // odd: 51 packed lanes
        run_ranks(n, move |rank, g| {
            g.start_all_reduce_dtype(rank, 1, vec![1.0f32; len], Dtype::Bf16).wait();
            g.barrier(rank);
            if rank == 0 {
                assert_eq!(g.nb_payload_bytes.load(Ordering::Relaxed), 2 * len as u64);
                // wire traffic moved packed lanes: 4 bytes × ceil(len/2) per deposit
                let deposits = 4 * len.div_ceil(2) as u64 * n as u64;
                assert!(g.bytes_moved.load(Ordering::Relaxed) >= deposits);
            }
        });
    }

    #[test]
    fn bf16_subgroup_allreduce_is_rank_order_quantized_sum() {
        for tp in [2usize, 4] {
            for len in [5usize, 33] {
                let world = Group::new(tp);
                let sub = SubGroup::new(&world, (0..tp).collect(), 0);
                let want = quantized_rank_order_sum(tp, len);
                let handles: Vec<_> = (0..tp)
                    .map(|rank| {
                        let s = sub.clone();
                        thread::spawn(move || {
                            let mut buf = test_data(rank, len);
                            s.all_reduce_sum_cfg(rank, &mut buf, Algo::Ring, Dtype::Bf16);
                            buf
                        })
                    })
                    .collect();
                for h in handles {
                    let got = h.join().unwrap();
                    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                        assert_eq!(a.to_bits(), b.to_bits(), "tp={tp} len={len} i={i}");
                    }
                }
                // half-width payload accounting, one round
                assert_eq!(sub.ar_bytes.load(Ordering::Relaxed), 2 * len as u64);
                assert_eq!(sub.ar_rounds.load(Ordering::Relaxed), 1);
            }
        }
    }

    #[test]
    fn subgroup_exchange_fold_f32_matches_ring() {
        // Algo::Naive routes through the deposit exchange; same sums as
        // the ring up to association order
        let tp = 3;
        let len = 40;
        let world = Group::new(tp);
        let sub = SubGroup::new(&world, (0..tp).collect(), 0);
        let mut want = vec![0.0f32; len];
        for r in 0..tp {
            for (x, v) in want.iter_mut().zip(test_data(r, len)) {
                *x += v;
            }
        }
        let handles: Vec<_> = (0..tp)
            .map(|rank| {
                let s = sub.clone();
                thread::spawn(move || {
                    let mut buf = test_data(rank, len);
                    s.all_reduce_sum_cfg(rank, &mut buf, Algo::Naive, Dtype::F32);
                    let mut mx = test_data(rank, len);
                    s.all_reduce_max_cfg(rank, &mut mx, Algo::Naive, Dtype::F32);
                    (buf, mx)
                })
            })
            .collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (rank, (got, mx)) in results.iter().enumerate() {
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-4, "rank {rank} i={i}: {a} vs {b}");
            }
            // every rank agrees bitwise (deterministic rank-order fold)
            assert_eq!(got, &results[0].0, "rank {rank} diverged");
            assert_eq!(mx, &results[0].1, "rank {rank} max diverged");
        }
    }

    #[test]
    fn reduce_scatter_buckets_owner_gets_rank_order_sum() {
        // partition-aligned RS buckets: each owner's shard is bitwise the
        // slice of the rank-order sum a bucketed all-reduce would produce
        for n in [2usize, 3, 4] {
            let len = 37;
            let want = expected_sum(n, len);
            run_ranks(n, move |rank, g| {
                let bounds = chunk_bounds(len, n);
                let data = test_data(rank, len);
                let handles: Vec<_> = bounds
                    .iter()
                    .enumerate()
                    .map(|(owner, &(lo, hi))| {
                        (
                            owner,
                            lo,
                            g.start_reduce_scatter_dtype(
                                rank,
                                0xC0 + owner as u64,
                                data[lo..hi].to_vec(),
                                owner,
                                Dtype::F32,
                            ),
                        )
                    })
                    .collect();
                for (owner, lo, h) in handles {
                    match h.wait() {
                        Some(shard) => {
                            assert_eq!(owner, rank, "non-owner got a shard");
                            for (i, v) in shard.iter().enumerate() {
                                assert_eq!(
                                    v.to_bits(),
                                    want[lo + i].to_bits(),
                                    "n={n} owner={owner} i={i}"
                                );
                            }
                        }
                        None => assert_ne!(owner, rank, "owner got nothing"),
                    }
                }
            });
        }
    }

    #[test]
    fn reduce_scatter_counts_the_same_payload_as_all_reduce() {
        let n = 2;
        let len = 64usize;
        run_ranks(n, move |rank, g| {
            let bounds = chunk_bounds(len, n);
            let handles: Vec<_> = bounds
                .iter()
                .enumerate()
                .map(|(owner, &(lo, hi))| {
                    g.start_reduce_scatter_dtype(
                        rank,
                        owner as u64,
                        vec![1.0f32; hi - lo],
                        owner,
                        Dtype::Bf16,
                    )
                })
                .collect();
            for h in handles {
                std::hint::black_box(h.wait());
            }
            g.barrier(rank);
            if rank == 0 {
                // one bf16 round per owner span: Σ span × 2 bytes = len × 2
                assert_eq!(g.nb_payload_bytes.load(Ordering::Relaxed), 2 * len as u64);
                assert_eq!(g.nb_rounds.load(Ordering::Relaxed), n as u64);
            }
        });
    }

    #[test]
    fn nonblocking_all_gather_assembles_and_counts() {
        for n in [1usize, 2, 4] {
            let total = 53usize;
            run_ranks(n, move |rank, g| {
                let bounds = chunk_bounds(total, n);
                let (lo, hi) = bounds[rank];
                // shard values on the bf16 grid (the ZeRO-3 case)
                let shard = Dtype::Bf16.quantized(&test_data(rank, hi - lo));
                let h32 = g.start_all_gather_dtype(rank, 1, shard.clone(), total, Dtype::F32);
                let f32_out = h32.wait();
                let h16 = g.start_all_gather_dtype(rank, 2, shard, total, Dtype::Bf16);
                let bf16_out = h16.wait();
                assert_eq!(f32_out.len(), total);
                assert_eq!(f32_out, bf16_out, "packed gather of grid values must be exact");
                // every rank's span equals its deposit
                for r in 0..n {
                    let (lo, hi) = bounds[r];
                    let want = Dtype::Bf16.quantized(&test_data(r, hi - lo));
                    assert_eq!(&f32_out[lo..hi], want.as_slice(), "n={n} span {r}");
                }
                g.barrier(rank);
                if rank == 0 && n > 1 {
                    // one f32 round (4·total) + one bf16 round (2·total)
                    assert_eq!(g.ag_payload_bytes.load(Ordering::Relaxed), 6 * total as u64);
                }
            });
        }
    }

    #[test]
    fn nonblocking_gathers_prefetch_interleaved() {
        // several gather rounds in flight at once (the prefetch pattern),
        // redeemed in launch order while deposits interleave across ranks
        let n = 3;
        let total = 24usize;
        run_ranks(n, move |rank, g| {
            let bounds = chunk_bounds(total, n);
            let (lo, hi) = bounds[rank];
            let handles: Vec<_> = (0..4u64)
                .map(|t| {
                    let shard: Vec<f32> =
                        (lo..hi).map(|i| (i as f32) + 100.0 * t as f32).collect();
                    g.start_all_gather_dtype(rank, t, shard, total, Dtype::F32)
                })
                .collect();
            for (t, h) in handles.into_iter().enumerate() {
                let full = h.wait();
                for (i, v) in full.iter().enumerate() {
                    assert_eq!(*v, i as f32 + 100.0 * t as f32, "round {t} elem {i}");
                }
            }
        });
    }

    #[test]
    fn all_gather_bf16_is_lossless_for_grid_values_and_counts_bytes() {
        let n = 4;
        let len = 51usize;
        let group = Group::new(n);
        let bounds = chunk_bounds(len, n);
        let handles: Vec<_> = (0..n)
            .map(|rank| {
                let g = group.clone();
                let (lo, hi) = bounds[rank];
                thread::spawn(move || {
                    // shards already on the bf16 grid (the ZeRO-1 case)
                    let shard = Dtype::Bf16.quantized(&test_data(rank, hi - lo));
                    let mut f32_out = vec![0.0f32; len];
                    g.all_gather(rank, &shard, &mut f32_out);
                    let mut bf16_out = vec![0.0f32; len];
                    g.all_gather_dtype(rank, &shard, &mut bf16_out, Dtype::Bf16);
                    (f32_out, bf16_out)
                })
            })
            .collect();
        for h in handles {
            let (a, b) = h.join().unwrap();
            assert_eq!(a, b, "packed all-gather of grid values must be bit-identical");
        }
        // one f32 round (4·len) + one bf16 round (2·len)
        assert_eq!(group.ag_payload_bytes.load(Ordering::Relaxed), 6 * len as u64);
    }

    // ------------------------- hierarchical -------------------------

    fn run_ranks_nodes<F>(n: usize, map: NodeMap, f: F) -> Arc<Group>
    where
        F: Fn(usize, Arc<Group>) + Send + Sync + 'static,
    {
        let group = Group::new_with_nodes(n, Some(map));
        let f = Arc::new(f);
        let handles: Vec<_> = (0..n)
            .map(|r| {
                let g = group.clone();
                let f = f.clone();
                thread::spawn(move || f(r, g))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        group
    }

    #[test]
    fn node_map_first_appearance_numbering() {
        let m = NodeMap::new(&[5, 5, 2, 5, 2]);
        assert_eq!(m.len(), 5);
        assert_eq!(m.n_nodes(), 2);
        assert_eq!((0..5).map(|r| m.node_of(r)).collect::<Vec<_>>(), vec![0, 0, 1, 0, 1]);
        assert_eq!(m.members_of(0), vec![0, 1, 3]);
        assert_eq!(m.members_of(1), vec![2, 4]);
        assert_eq!(m.representative(0), 0);
        assert_eq!(m.representative(1), 2);
        assert!(m.is_representative(0) && m.is_representative(2));
        assert!(!m.is_representative(1) && !m.is_representative(3) && !m.is_representative(4));
        assert_eq!(m.n_multi_nodes(), 2);
        // strided assignment (the tp-innermost DP group shape)
        let s = NodeMap::new(&[0, 1, 0, 1]);
        assert_eq!(s.members_of(0), vec![0, 2]);
        assert_eq!(s.members_of(1), vec![1, 3]);
        assert_eq!(s.n_multi_nodes(), 2);
        let flat = NodeMap::flat(4);
        assert_eq!(flat.n_nodes(), 1);
        assert_eq!(flat.n_multi_nodes(), 1);
        let machine = Machine::new(2);
        let g = NodeMap::from_gpus(&machine, &[2, 10, 3, 11]);
        assert_eq!((0..4).map(|r| g.node_of(r)).collect::<Vec<_>>(), vec![0, 1, 0, 1]);
    }

    #[test]
    fn hier_allreduce_bitwise_flat_when_value_preserving() {
        // fp32 wire over fp32 storage never re-quantizes: the two-tier
        // fold must equal the flat rank-order sum BITWISE
        for (n, nodes) in [(4usize, vec![0, 0, 1, 1]), (6, vec![0, 1, 2, 0, 1, 2]), (3, vec![0, 1, 2])]
        {
            let len = 41;
            let want = expected_sum(n, len);
            let g = run_ranks_nodes(n, NodeMap::new(&nodes), move |rank, g| {
                let h =
                    g.start_all_reduce_hier(rank, 0xA1, test_data(rank, len), Dtype::F32, GradWire::F32);
                let got = h.wait();
                for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "n={n} i={i}");
                }
            });
            // legacy counters advance exactly as a flat round would
            assert_eq!(g.nb_rounds.load(Ordering::Relaxed), 1);
            assert_eq!(g.nb_payload_bytes.load(Ordering::Relaxed), 4 * len as u64);
        }
    }

    #[test]
    fn hier_bf16_over_bf16_matches_flat_grid_sum() {
        // bf16 grad wire over bf16 storage: value-preserving → the flat
        // quantized rank-order sum, bitwise
        let n = 4;
        let len = 37;
        let want = quantized_rank_order_sum(n, len);
        run_ranks_nodes(n, NodeMap::new(&[0, 0, 1, 1]), move |rank, g| {
            let h = g.start_all_reduce_hier(rank, 7, test_data(rank, len), Dtype::Bf16, GradWire::Bf16);
            let got = h.wait();
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "i={i}");
            }
        });
    }

    #[test]
    fn hier_tier_counters_allreduce() {
        // n=4 over k=2 nodes, fp32 storage, int8 grad wire:
        // intra = 2·(n-k) payloads × 4·len; inter = k × int8(len)
        let n = 4usize;
        let len = 256usize;
        let g = run_ranks_nodes(n, NodeMap::new(&[0, 0, 1, 1]), move |rank, g| {
            g.start_all_reduce_hier(rank, 1, vec![1.0f32; len], Dtype::F32, GradWire::Int8)
                .wait();
        });
        assert_eq!(
            g.nb_intra_bytes.load(Ordering::Relaxed),
            2 * 2 * 4 * len as u64
        );
        assert_eq!(
            g.nb_inter_bytes.load(Ordering::Relaxed),
            2 * GradWire::Int8.payload_bytes(len as u64)
        );
        // int8 inter ≤ 1/4 + scale overhead of the fp32 wire
        assert!(
            g.nb_inter_bytes.load(Ordering::Relaxed) as f64
                <= 2.0 * 4.0 * len as f64 * (0.25 + 1.0 / 128.0)
        );
    }

    #[test]
    fn hier_single_node_is_all_intra_and_bitwise_flat_even_at_int8() {
        // one node → no inter hop → the int8 wire never engages: bitwise
        // flat, inter counter zero
        let n = 3;
        let len = 29;
        let want = expected_sum(n, len);
        let g = run_ranks_nodes(n, NodeMap::flat(n), move |rank, g| {
            let h = g.start_all_reduce_hier(rank, 9, test_data(rank, len), Dtype::F32, GradWire::Int8);
            let got = h.wait();
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "i={i}");
            }
        });
        assert_eq!(g.nb_inter_bytes.load(Ordering::Relaxed), 0);
        assert_eq!(g.nb_intra_bytes.load(Ordering::Relaxed), 2 * 2 * 4 * len as u64);
    }

    #[test]
    fn hier_int8_fold_matches_mirror_and_is_deterministic() {
        // node partials in rank order, int8 round-trip per partial, fold
        // in node order — mirrored serially here
        let n = 5usize;
        let len = 200usize;
        let nodes = vec![0usize, 1, 0, 1, 0];
        let map = NodeMap::new(&nodes);
        let mut want = vec![0.0f32; len];
        for node in 0..map.n_nodes() {
            let mut partial = vec![0.0f32; len];
            for r in map.members_of(node) {
                for (x, v) in partial.iter_mut().zip(test_data(r, len)) {
                    *x += v;
                }
            }
            GradWire::Int8.roundtrip_slice(&mut partial);
            for (x, &p) in want.iter_mut().zip(partial.iter()) {
                *x += p;
            }
        }
        for trial in 0..3 {
            let want = want.clone();
            let nodes = nodes.clone();
            run_ranks_nodes(n, NodeMap::new(&nodes), move |rank, g| {
                // stagger deposit order across trials/ranks
                if (rank + trial) % 2 == 0 {
                    std::thread::yield_now();
                }
                let h =
                    g.start_all_reduce_hier(rank, 3, test_data(rank, len), Dtype::F32, GradWire::Int8);
                let got = h.wait();
                for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "trial={trial} i={i}");
                }
            });
        }
    }

    #[test]
    fn hier_reduce_scatter_counters_depend_on_owner_placement() {
        // owner 0 is node 0's representative (no down payload); owner 1
        // is not (one down payload)
        let n = 4usize;
        let len = 64usize;
        for (owner, extra_down) in [(0usize, 0u64), (1, 1)] {
            let g = run_ranks_nodes(n, NodeMap::new(&[0, 0, 1, 1]), move |rank, g| {
                let h = g.start_reduce_scatter_hier(
                    rank,
                    5,
                    vec![1.0f32; len],
                    owner,
                    Dtype::F32,
                    GradWire::Bf16,
                );
                let got = h.wait();
                assert_eq!(got.is_some(), rank == owner);
            });
            assert_eq!(
                g.nb_intra_bytes.load(Ordering::Relaxed),
                (2 + extra_down) * 4 * len as u64,
                "owner={owner}"
            );
            assert_eq!(
                g.nb_inter_bytes.load(Ordering::Relaxed),
                2 * GradWire::Bf16.payload_bytes(len as u64)
            );
        }
    }

    #[test]
    fn hier_rs_value_preserving_matches_flat_shards_bitwise() {
        let n = 4usize;
        let len = 39usize;
        let want = expected_sum(n, len);
        run_ranks_nodes(n, NodeMap::new(&[0, 1, 0, 1]), move |rank, g| {
            let bounds = chunk_bounds(len, n);
            let data = test_data(rank, len);
            let handles: Vec<_> = bounds
                .iter()
                .enumerate()
                .map(|(owner, &(lo, hi))| {
                    (
                        owner,
                        lo,
                        g.start_reduce_scatter_hier(
                            rank,
                            0xD0 + owner as u64,
                            data[lo..hi].to_vec(),
                            owner,
                            Dtype::F32,
                            GradWire::F32,
                        ),
                    )
                })
                .collect();
            for (owner, lo, h) in handles {
                if let Some(shard) = h.wait() {
                    assert_eq!(owner, rank);
                    for (i, v) in shard.iter().enumerate() {
                        assert_eq!(v.to_bits(), want[lo + i].to_bits(), "owner={owner} i={i}");
                    }
                }
            }
        });
    }

    #[test]
    fn hier_all_gather_assembles_and_splits_tiers() {
        // n=3 over nodes [0,0,1]: rank 1 is the only non-representative;
        // intra = span(1)·w up + (n-k)·total·w down; inter = total·w
        let n = 3usize;
        let total = 31usize;
        let g = run_ranks_nodes(n, NodeMap::new(&[0, 0, 1]), move |rank, g| {
            let bounds = chunk_bounds(total, n);
            let (lo, hi) = bounds[rank];
            let shard: Vec<f32> = test_data(rank, hi - lo);
            let h = g.start_all_gather_hier(rank, 2, Arc::new(shard), total, Dtype::F32);
            let full = h.wait();
            for r in 0..n {
                let (lo, hi) = bounds[r];
                assert_eq!(&full[lo..hi], test_data(r, hi - lo).as_slice(), "span {r}");
            }
        });
        let bounds = chunk_bounds(total, n);
        let span1 = (bounds[1].1 - bounds[1].0) as u64;
        assert_eq!(
            g.ag_intra_bytes.load(Ordering::Relaxed),
            4 * (span1 + total as u64)
        );
        assert_eq!(g.ag_inter_bytes.load(Ordering::Relaxed), 4 * total as u64);
        // legacy logical counter advances exactly like a flat gather
        assert_eq!(g.ag_payload_bytes.load(Ordering::Relaxed), 4 * total as u64);
    }

    #[test]
    fn node_gather_assembles_from_secondary_shards() {
        // nodes [0,0,1]: ranks 0/1 hold halves of the node-0 secondary
        // partition; rank 2 is alone, so its shard IS the buffer
        let n = 3usize;
        let total = 20usize;
        let truth: Vec<f32> = (0..total).map(|i| i as f32 * 0.5).collect();
        let truth2 = truth.clone();
        let g = run_ranks_nodes(n, NodeMap::new(&[0, 0, 1]), move |rank, g| {
            let map = g.node_map().unwrap().clone();
            let members = map.members_of(map.node_of(rank));
            let pos = members.iter().position(|&m| m == rank).unwrap();
            let bounds = chunk_bounds(total, members.len());
            let (lo, hi) = bounds[pos];
            let shard: Payload = Arc::new(truth2[lo..hi].to_vec());
            let h = g.start_all_gather_node(rank, 4, shard, total, Dtype::F32);
            let full = h.wait();
            assert_eq!(full, truth2, "rank {rank}");
        });
        // one multi-member node round (node 0); node 1 was immediate
        assert_eq!(g.ag_intra_bytes.load(Ordering::Relaxed), 4 * total as u64);
        assert_eq!(g.ag_inter_bytes.load(Ordering::Relaxed), 0);
        // secondary gathers do NOT advance the primary logical counter
        assert_eq!(g.ag_payload_bytes.load(Ordering::Relaxed), 0);
    }

    /// rank r's part for destination d in the a2a tests.
    fn a2a_part(rank: usize, dst: usize, len: usize) -> Vec<f32> {
        (0..len).map(|i| ((rank * 131 + dst * 17 + i) as f32 * 0.07).cos()).collect()
    }

    #[test]
    fn all_to_all_routes_parts_in_source_order() {
        for n in [1usize, 2, 3, 4] {
            let len = 33usize;
            run_ranks(n, move |rank, g| {
                let parts: Vec<Vec<f32>> = (0..n).map(|d| a2a_part(rank, d, len)).collect();
                let got = g.all_to_all(rank, 7, parts, Dtype::F32);
                assert_eq!(got.len(), n);
                for src in 0..n {
                    assert_eq!(got[src], a2a_part(src, rank, len), "src {src} -> dst {rank}");
                }
            });
        }
    }

    #[test]
    fn all_to_all_round_trip_is_identity() {
        // a2a, then a2a of the received parts back to their sources,
        // reproduces every rank's original parts exactly
        for n in [2usize, 3, 4] {
            let len = 21usize;
            run_ranks(n, move |rank, g| {
                let parts: Vec<Vec<f32>> = (0..n).map(|d| a2a_part(rank, d, len)).collect();
                let fwd = g.all_to_all(rank, 11, parts.clone(), Dtype::F32);
                let back = g.all_to_all(rank, 12, fwd, Dtype::F32);
                assert_eq!(back, parts, "rank {rank}: a2a ∘ a2a must be identity");
            });
        }
    }

    #[test]
    fn all_to_all_is_deterministic_across_arrival_orders() {
        // jitter the deposit order across repeats; the routed parts (pure
        // placement, assembled in source-rank order) never change
        let n = 4usize;
        let len = 17usize;
        for round in 0..6u64 {
            run_ranks(n, move |rank, g| {
                thread::sleep(Duration::from_micros(((rank as u64 * 7 + round * 13) % 5) * 200));
                let parts: Vec<Vec<f32>> = (0..n).map(|d| a2a_part(rank, d, len)).collect();
                let got = g.all_to_all(rank, 100 + round, parts, Dtype::F32);
                for src in 0..n {
                    assert_eq!(got[src], a2a_part(src, rank, len));
                }
            });
        }
    }

    #[test]
    fn all_to_all_ragged_and_empty_parts() {
        // each (src, dst) pair has its own length; empty parts are legal
        let n = 3usize;
        run_ranks(n, move |rank, g| {
            let parts: Vec<Vec<f32>> =
                (0..n).map(|d| a2a_part(rank, d, (rank * n + d) % 4)).collect();
            let got = g.all_to_all(rank, 21, parts, Dtype::F32);
            for src in 0..n {
                assert_eq!(got[src], a2a_part(src, rank, (src * n + rank) % 4));
            }
        });
    }

    #[test]
    fn all_to_all_bf16_wire_matches_quantized_f32() {
        // a Bf16-wire exchange ≡ quantize every part to the bf16 grid,
        // then exchange over the f32 wire (pack/unpack is value-exact on
        // grid points) — including the self part
        let n = 3usize;
        let len = 40usize;
        run_ranks(n, move |rank, g| {
            let parts: Vec<Vec<f32>> = (0..n).map(|d| a2a_part(rank, d, len)).collect();
            let quantized: Vec<Vec<f32>> = parts
                .iter()
                .map(|p| {
                    let mut q = p.clone();
                    Dtype::Bf16.quantize_slice(&mut q);
                    q
                })
                .collect();
            let via_bf16 = g.all_to_all(rank, 31, parts, Dtype::Bf16);
            let via_f32 = g.all_to_all(rank, 32, quantized, Dtype::F32);
            assert_eq!(via_bf16, via_f32, "rank {rank}");
        });
    }

    #[test]
    fn all_to_all_counters_count_all_parts_once_per_round() {
        let n = 4usize;
        let len = 10usize;
        let group = Group::new(n);
        let handles: Vec<_> = (0..n)
            .map(|r| {
                let g = group.clone();
                thread::spawn(move || {
                    let parts: Vec<Vec<f32>> = (0..n).map(|d| a2a_part(r, d, len)).collect();
                    let _ = g.all_to_all(r, 41, parts, Dtype::F32);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(group.a2a_rounds.load(Ordering::Relaxed), 1);
        // every (src, dst) part including self parts, once per round
        assert_eq!(
            group.a2a_payload_bytes.load(Ordering::Relaxed),
            4 * (n * n * len) as u64
        );
        // topology-blind group: tier splits stay zero
        assert_eq!(group.a2a_intra_bytes.load(Ordering::Relaxed), 0);
        assert_eq!(group.a2a_inter_bytes.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn all_to_all_tier_split_follows_node_map() {
        // nodes [0,0,1,1]: of the 12 src≠dst pairs, 4 are intra-node
        // (0↔1, 2↔3) and 8 cross the inter tier
        let n = 4usize;
        let len = 10usize;
        let g = run_ranks_nodes(n, NodeMap::new(&[0, 0, 1, 1]), move |rank, g| {
            let parts: Vec<Vec<f32>> = (0..n).map(|d| a2a_part(rank, d, len)).collect();
            let got = g.all_to_all(rank, 51, parts, Dtype::F32);
            for src in 0..n {
                assert_eq!(got[src], a2a_part(src, rank, len));
            }
        });
        let part_bytes = 4 * len as u64;
        assert_eq!(g.a2a_payload_bytes.load(Ordering::Relaxed), part_bytes * (n * n) as u64);
        assert_eq!(g.a2a_intra_bytes.load(Ordering::Relaxed), part_bytes * 4);
        assert_eq!(g.a2a_inter_bytes.load(Ordering::Relaxed), part_bytes * 8);
        // bf16 wire halves every tier's bytes
        let g2 = run_ranks_nodes(n, NodeMap::new(&[0, 0, 1, 1]), move |rank, g| {
            let parts: Vec<Vec<f32>> = (0..n).map(|d| a2a_part(rank, d, len)).collect();
            let _ = g.all_to_all(rank, 52, parts, Dtype::Bf16);
        });
        let half = 2 * len as u64;
        assert_eq!(g2.a2a_payload_bytes.load(Ordering::Relaxed), half * (n * n) as u64);
        assert_eq!(g2.a2a_intra_bytes.load(Ordering::Relaxed), half * 4);
        assert_eq!(g2.a2a_inter_bytes.load(Ordering::Relaxed), half * 8);
    }
}
