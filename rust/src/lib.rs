//! # frontier-llm
//!
//! Production-quality reproduction of **"Optimizing Distributed Training
//! on Frontier for Large Language Models"** (Dash et al., ORNL, 2023).
//!
//! The paper ports Megatron-DeepSpeed to the AMD/ROCm Frontier
//! supercomputer and derives tuned 3D-parallel (tensor x pipeline x data)
//! training recipes for 22B/175B/1T GPT models.  This crate rebuilds that
//! system as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the distributed-training coordinator: 3D rank
//!   layout, pipeline schedules (GPipe / 1F1B / interleaved 1F1B over
//!   virtual stages, executed for real end-to-end), collectives, ZeRO-1
//!   sharded optimizer, the Frontier topology + performance models that
//!   regenerate every figure/table, and a Bayesian HPO engine with SHAP
//!   sensitivity (the paper's DeepHyper study).
//! * **L2** — `python/compile/model.py`: the GPT stage graphs in JAX,
//!   AOT-lowered to HLO text artifacts.
//! * **L1** — `python/compile/kernels/`: Pallas flash-attention, fused
//!   LayerNorm and fused softmax-xent kernels called from L2.
//!
//! Python never runs at training time: the [`runtime`] module loads the
//! HLO artifacts via PJRT and the [`coordinator`] drives them from worker
//! threads that stand in for Frontier's MI250X GCDs.
//!
//! See `DESIGN.md` for the full system inventory and the per-figure
//! experiment index; `EXPERIMENTS.md` records paper-vs-measured results.

pub mod collectives;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod hpo;
pub mod mem;
pub mod metrics;
pub mod moe;
pub mod optim;
pub mod parallel;
pub mod perf;
pub mod precision;
pub mod runtime;
pub mod schedule;
pub mod topology;
pub mod trace;
pub mod util;
pub mod zero;
