//! 3D process-group layout: world rank <-> (pp, dp, tp) coordinates.
//!
//! Megatron's `initialize_model_parallel` ordering, which the paper's
//! Megatron-DeepSpeed port inherits: tensor-parallel ranks are consecutive
//! (innermost), data-parallel next, pipeline outermost:
//!
//! `rank = pp_rank * (dp * tp) + dp_rank * tp + tp_rank`
//!
//! Consecutive TP ranks map to consecutive GCDs, so with `tp <= 8` a TP
//! group lives inside a node (and with `tp = 2` inside one MI250X card) —
//! precisely the placement reasoning of §III.A.

use crate::topology::{GpuId, Machine};

/// Coordinates of a rank in the 3D decomposition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Coords {
    pub pp: u32,
    pub dp: u32,
    pub tp: u32,
}

/// The full rank layout for one (tp, pp, dp) decomposition.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RankLayout {
    pub tp: u32,
    pub pp: u32,
    pub dp: u32,
}

impl RankLayout {
    pub fn new(tp: u32, pp: u32, dp: u32) -> Self {
        assert!(tp >= 1 && pp >= 1 && dp >= 1);
        Self { tp, pp, dp }
    }

    pub fn world_size(&self) -> u32 {
        self.tp * self.pp * self.dp
    }

    pub fn coords(&self, rank: u32) -> Coords {
        assert!(rank < self.world_size(), "rank {rank} out of range");
        let tp = rank % self.tp;
        let dp = (rank / self.tp) % self.dp;
        let pp = rank / (self.tp * self.dp);
        Coords { pp, dp, tp }
    }

    pub fn rank_of(&self, c: Coords) -> u32 {
        assert!(c.tp < self.tp && c.dp < self.dp && c.pp < self.pp);
        c.pp * (self.dp * self.tp) + c.dp * self.tp + c.tp
    }

    /// The TP group containing `rank` (consecutive ranks).
    pub fn tp_group(&self, rank: u32) -> Vec<u32> {
        let c = self.coords(rank);
        (0..self.tp)
            .map(|t| self.rank_of(Coords { tp: t, ..c }))
            .collect()
    }

    /// The DP group containing `rank` (stride `tp`).
    pub fn dp_group(&self, rank: u32) -> Vec<u32> {
        let c = self.coords(rank);
        (0..self.dp)
            .map(|d| self.rank_of(Coords { dp: d, ..c }))
            .collect()
    }

    /// The PP group containing `rank` (stride `dp*tp`), first to last stage.
    pub fn pp_group(&self, rank: u32) -> Vec<u32> {
        let c = self.coords(rank);
        (0..self.pp)
            .map(|p| self.rank_of(Coords { pp: p, ..c }))
            .collect()
    }

    /// All distinct TP groups.
    pub fn all_tp_groups(&self) -> Vec<Vec<u32>> {
        let mut out = Vec::new();
        for pp in 0..self.pp {
            for dp in 0..self.dp {
                out.push(
                    (0..self.tp)
                        .map(|tp| self.rank_of(Coords { pp, dp, tp }))
                        .collect(),
                );
            }
        }
        out
    }

    /// All distinct DP groups.
    pub fn all_dp_groups(&self) -> Vec<Vec<u32>> {
        let mut out = Vec::new();
        for pp in 0..self.pp {
            for tp in 0..self.tp {
                out.push(
                    (0..self.dp)
                        .map(|dp| self.rank_of(Coords { pp, dp, tp }))
                        .collect(),
                );
            }
        }
        out
    }

    /// All distinct PP groups.
    pub fn all_pp_groups(&self) -> Vec<Vec<u32>> {
        let mut out = Vec::new();
        for dp in 0..self.dp {
            for tp in 0..self.tp {
                out.push(
                    (0..self.pp)
                        .map(|pp| self.rank_of(Coords { pp, dp, tp }))
                        .collect(),
                );
            }
        }
        out
    }

    /// Identity placement: world rank r on GCD r.  The layout above is
    /// designed so this naive placement already honours the paper's rules.
    pub fn gpu_of(&self, rank: u32) -> GpuId {
        rank
    }

    /// Does every TP group stay inside one node under identity placement?
    pub fn tp_within_node(&self, machine: &Machine) -> bool {
        self.all_tp_groups()
            .iter()
            .all(|g| !machine.spans_nodes(&g.iter().map(|&r| self.gpu_of(r)).collect::<Vec<_>>()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coords_roundtrip() {
        let l = RankLayout::new(4, 8, 3);
        for r in 0..l.world_size() {
            assert_eq!(l.rank_of(l.coords(r)), r);
        }
    }

    #[test]
    fn tp_groups_consecutive() {
        let l = RankLayout::new(8, 2, 2);
        for g in l.all_tp_groups() {
            for w in g.windows(2) {
                assert_eq!(w[1], w[0] + 1);
            }
        }
    }

    #[test]
    fn groups_partition_world() {
        let l = RankLayout::new(2, 3, 4);
        for groups in [l.all_tp_groups(), l.all_dp_groups(), l.all_pp_groups()] {
            let mut seen = vec![false; l.world_size() as usize];
            for g in &groups {
                for &r in g {
                    assert!(!seen[r as usize], "rank {r} in two groups");
                    seen[r as usize] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "groups must cover the world");
        }
    }

    #[test]
    fn tp8_stays_in_node() {
        // tp divides 8 => consecutive placement keeps TP groups node-local
        let m = Machine::for_gpus(64);
        for tp in [1u32, 2, 4, 8] {
            let l = RankLayout::new(tp, 4, 16 / tp.min(2));
            assert!(l.tp_within_node(&m), "tp={tp}");
        }
        // tp=16 must span nodes
        let l = RankLayout::new(16, 2, 2);
        assert!(!l.tp_within_node(&m));
    }

    #[test]
    fn group_membership_consistency() {
        let l = RankLayout::new(2, 2, 2);
        for r in 0..l.world_size() {
            assert!(l.tp_group(r).contains(&r));
            assert!(l.dp_group(r).contains(&r));
            assert!(l.pp_group(r).contains(&r));
        }
    }
}
