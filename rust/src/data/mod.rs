//! Synthetic training corpus + batching.
//!
//! The paper trains on tokenised web-scale corpora (RedPajama/Dolma-class
//! data we do not have).  The substitution (DESIGN.md §1): a synthetic
//! corpus with *learnable sequential structure* — a token-level Markov
//! chain over a Zipfian vocabulary — so the e2e example's loss curve has a
//! real signal to descend toward the chain's conditional entropy, not just
//! memorised noise.  The data pipeline (sampler -> micro-batch iterator ->
//! per-DP-rank sharding) is the part of the system the paper's workflow
//! actually exercises, and it is identical for real data.


/// Deterministic xorshift64* PRNG — no external crates, reproducible runs.
#[derive(Debug, Clone)]
pub struct Rng64 {
    state: u64,
}

impl Rng64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        (self.next_f64() * n as f64) as u64
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

/// Markov-chain corpus generator: each token's distribution depends on the
/// previous token through a sparse transition table with Zipfian marginals.
#[derive(Debug, Clone)]
pub struct SyntheticCorpus {
    vocab: u32,
    /// `succ[t]` = the `k` preferred successors of token `t`.
    succ: Vec<Vec<u32>>,
    /// Probability mass on the preferred successors (rest is uniform).
    peak: f64,
    rng: Rng64,
    prev: u32,
}

impl SyntheticCorpus {
    pub fn new(vocab: u32, seed: u64) -> Self {
        assert!(vocab >= 4);
        let k = 4usize;
        let mut rng = Rng64::new(seed);
        let succ = (0..vocab)
            .map(|_| {
                (0..k)
                    // Zipf-ish: low token ids are preferred successors
                    .map(|_| {
                        let z = rng.next_f64();
                        ((vocab as f64).powf(z) - 1.0) as u32 % vocab
                    })
                    .collect()
            })
            .collect();
        Self { vocab, succ, peak: 0.85, rng, prev: 0 }
    }

    pub fn vocab(&self) -> u32 {
        self.vocab
    }

    /// Next token of the stream.
    pub fn next_token(&mut self) -> u32 {
        let t = if self.rng.next_f64() < self.peak {
            let opts = &self.succ[self.prev as usize];
            opts[self.rng.below(opts.len() as u64) as usize]
        } else {
            self.rng.below(self.vocab as u64) as u32
        };
        self.prev = t;
        t
    }

    /// Fill a `(batch, seq+1)` token block; the extra column lets callers
    /// split input/target with a one-token shift.
    pub fn sample_block(&mut self, batch: usize, seq: usize) -> Vec<Vec<u32>> {
        (0..batch)
            .map(|_| (0..=seq).map(|_| self.next_token()).collect())
            .collect()
    }
}

/// One micro-batch: next-token prediction pair, row-major i32 (what the
/// PJRT stage executables take).
#[derive(Debug, Clone, PartialEq)]
pub struct MicroBatch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

/// Deterministic, DP-sharded micro-batch stream.
///
/// Every DP rank constructs its own `BatchStream` with the same base seed;
/// rank `r` of `dp` draws disjoint sample indices `r, r+dp, r+2dp, ...` —
/// the contract a distributed sampler must satisfy (tested below).
pub struct BatchStream {
    corpus: SyntheticCorpus,
    dp_rank: usize,
    dp: usize,
    batch: usize,
    seq: usize,
    cursor: usize,
}

impl BatchStream {
    pub fn new(vocab: u32, seed: u64, dp_rank: usize, dp: usize, batch: usize, seq: usize) -> Self {
        assert!(dp_rank < dp);
        Self {
            corpus: SyntheticCorpus::new(vocab, seed),
            dp_rank,
            dp,
            batch,
            seq,
            cursor: 0,
        }
    }

    /// Fast-forward past `n` micro-batches (checkpoint resume: the data
    /// stream is a pure function of (seed, cursor), so skipping replays
    /// the PRNG without building the batches).
    pub fn skip_microbatches(&mut self, n: usize) {
        for _ in 0..n {
            let _ = self.next_microbatch();
        }
    }

    /// Next micro-batch for this DP rank.
    pub fn next_microbatch(&mut self) -> MicroBatch {
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut targets = Vec::with_capacity(self.batch * self.seq);
        let mut taken = 0;
        while taken < self.batch {
            let row: Vec<u32> = (0..=self.seq).map(|_| self.corpus.next_token()).collect();
            let mine = self.cursor % self.dp == self.dp_rank;
            self.cursor += 1;
            if !mine {
                continue;
            }
            tokens.extend(row[..self.seq].iter().map(|&t| t as i32));
            targets.extend(row[1..].iter().map(|&t| t as i32));
            taken += 1;
        }
        MicroBatch { tokens, targets, batch: self.batch, seq: self.seq }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng64::new(7);
        let mut b = Rng64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng64::new(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn rng_uniform_below() {
        let mut r = Rng64::new(3);
        let mut counts = [0usize; 4];
        for _ in 0..4000 {
            counts[r.below(4) as usize] += 1;
        }
        for c in counts {
            assert!((700..1300).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn corpus_tokens_in_range() {
        let mut c = SyntheticCorpus::new(100, 1);
        for _ in 0..1000 {
            assert!(c.next_token() < 100);
        }
    }

    #[test]
    fn corpus_has_structure() {
        // successor distribution after a fixed token must be concentrated
        // (that's the learnable signal)
        let mut c = SyntheticCorpus::new(64, 2);
        let mut follows = vec![0usize; 64];
        let mut prev = c.next_token();
        let mut hits = 0;
        for _ in 0..20000 {
            let t = c.next_token();
            if prev == 5 {
                follows[t as usize] += 1;
                hits += 1;
            }
            prev = t;
        }
        if hits > 50 {
            let max = *follows.iter().max().unwrap();
            assert!(max as f64 / hits as f64 > 0.15, "max {max} of {hits}");
        }
    }

    #[test]
    fn targets_shift_tokens_by_one() {
        let mut s = BatchStream::new(50, 9, 0, 1, 2, 8);
        let mb = s.next_microbatch();
        assert_eq!(mb.tokens.len(), 16);
        assert_eq!(mb.targets.len(), 16);
        // rows are contiguous streams: target[i] == token[i+1] within a row
        for row in 0..2 {
            for i in 0..7 {
                assert_eq!(mb.targets[row * 8 + i], mb.tokens[row * 8 + i + 1]);
            }
        }
    }

    #[test]
    fn dp_ranks_draw_disjoint_samples() {
        // two DP ranks with the same seed must see different rows, and
        // together exactly the rows a dp=1 stream sees
        let mk = |rank, dp| BatchStream::new(64, 42, rank, dp, 2, 4);
        let mut solo = mk(0, 1);
        let a = solo.next_microbatch();
        let b = solo.next_microbatch();
        let mut r0 = mk(0, 2);
        let mut r1 = mk(1, 2);
        let m0 = r0.next_microbatch();
        let m1 = r1.next_microbatch();
        // rank 0 gets rows 0,2 (= solo rows 0 and 2), rank 1 rows 1,3
        let solo_rows: Vec<&[i32]> =
            a.tokens.chunks(4).chain(b.tokens.chunks(4)).collect();
        assert_eq!(&m0.tokens[..4], solo_rows[0]);
        assert_eq!(&m1.tokens[..4], solo_rows[1]);
        assert_eq!(&m0.tokens[4..], solo_rows[2]);
        assert_eq!(&m1.tokens[4..], solo_rows[3]);
    }
}
