//! Worker thread: one simulated GCD executing its stage's instruction
//! stream against the compiled PJRT executables.

use std::sync::{mpsc, Arc};

use anyhow::{anyhow, Context, Result};

use crate::collectives::Group;
use crate::data::BatchStream;
use crate::runtime::{lit_u32, scalar_f32, to_f32, Bundle, Runtime};
use crate::schedule::{Op, Schedule};
use crate::zero::DistOptimizer;

use super::{checkpoint, EngineConfig};

/// Everything a worker needs; handed over at spawn.
pub struct WorkerCtx {
    pub cfg: EngineConfig,
    pub rt: Arc<Runtime>,
    pub bundle: Arc<Bundle>,
    pub sched: Arc<Schedule>,
    pub world: Arc<Group>,
    pub dp_group: Arc<Group>,
    pub pp_rank: usize,
    pub dp_rank: usize,
    pub pp: usize,
    pub dp: usize,
    /// First step index (non-zero when resuming from a checkpoint).
    pub start_step: u32,
    /// Only the (last-stage, dp=0) worker reports losses.
    pub loss_tx: Option<mpsc::Sender<(u32, f32, f32)>>,
}

impl WorkerCtx {
    fn world_rank(&self) -> usize {
        self.pp_rank * self.dp + self.dp_rank
    }

    fn prev_rank(&self) -> usize {
        (self.pp_rank - 1) * self.dp + self.dp_rank
    }

    fn next_rank(&self) -> usize {
        (self.pp_rank + 1) * self.dp + self.dp_rank
    }
}

/// Worker main loop.
pub fn run(ctx: WorkerCtx) -> Result<()> {
    let meta = &ctx.bundle.meta;
    let stage = &ctx.bundle.stages[ctx.pp_rank];
    let sm = &stage.meta;
    let is_first = sm.has_embed;
    let is_last = sm.has_head;
    let single = ctx.pp == 1;

    let b = meta.mbs as usize;
    let s = meta.model.seq as usize;
    let d = meta.model.hidden as usize;
    let act_dims: [usize; 3] = [b, s, d];
    let tok_dims: [usize; 2] = [b, s];
    let n_params = sm.param_count as usize;

    // ---- parameter init: identical across DP replicas, and identical
    // across pipeline partitions (init keys fold in GLOBAL layer indices
    // python-side, so the key is the same for every stage) ----
    let key = [ctx.cfg.seed as u32, 0x5eed_0000];
    let key_lit = lit_u32(&key, &[2])?;
    let init_out = stage.init.run(&[&key_lit]).context("running stage init")?;
    let mut params = to_f32(&init_out[0])?;
    anyhow::ensure!(params.len() == n_params, "init size mismatch");

    let mut opt = DistOptimizer::new(
        ctx.cfg.zero1,
        ctx.cfg.adam,
        n_params,
        ctx.dp_rank,
        ctx.dp,
    );

    // ---- checkpoint resume: params (shared) + this rank's opt state ----
    if ctx.cfg.resume {
        let dir = ctx.cfg.checkpoint_dir.as_ref().expect("validated by leader");
        let (p, _) = checkpoint::read_f32(&checkpoint::params_path(dir, ctx.pp_rank))?;
        anyhow::ensure!(p.len() == n_params, "checkpoint params size mismatch");
        params = p;
        let (state, t) =
            checkpoint::read_f32(&checkpoint::opt_path(dir, ctx.pp_rank, ctx.dp_rank))?;
        opt.import_state(&state, t);
    }

    // ---- data: first and last stages draw the SAME dp-sharded stream ----
    let mut stream = (is_first || is_last).then(|| {
        BatchStream::new(
            meta.model.vocab as u32,
            ctx.cfg.seed ^ 0xDA7A,
            ctx.dp_rank,
            ctx.dp,
            b,
            s,
        )
    });

    let m = ctx.cfg.microbatches as usize;
    let mut grad_accum = vec![0.0f32; n_params];
    // per-microbatch stash: stage input activations (checkpointing: inputs
    // only), token/target rows for the boundary stages
    let mut stash_x: Vec<Option<Vec<f32>>> = vec![None; m];
    let mut stash_tok: Vec<Option<Vec<i32>>> = vec![None; m];
    let mut stash_tgt: Vec<Option<Vec<i32>>> = vec![None; m];

    // fast-forward the data stream past already-trained steps
    if ctx.start_step > 0 {
        if let Some(stream) = stream.as_mut() {
            stream.skip_microbatches(ctx.start_step as usize * m);
        }
    }

    for rel_step in 0..ctx.cfg.steps {
        let step = ctx.start_step + rel_step;
        grad_accum.iter_mut().for_each(|g| *g = 0.0);
        let mut loss_sum = 0.0f32;

        // draw this step's micro-batches up front (schedule issues
        // forwards in order, so index mb matches draw order)
        if let Some(stream) = stream.as_mut() {
            for mb in 0..m {
                let batch = stream.next_microbatch();
                if is_first {
                    stash_tok[mb] = Some(batch.tokens.clone());
                }
                if is_last {
                    stash_tgt[mb] = Some(batch.targets);
                }
            }
        }

        // upload the parameter vector ONCE per step; every micro-batch's
        // fwd/bwd reuses the same device buffer (EXPERIMENTS.md §Perf)
        let params_buf = ctx.rt.buf_f32(&params, &[n_params])?;

        for op in &ctx.sched.streams[ctx.pp_rank] {
            match *op {
                Op::Forward { mb } => {
                    let mb = mb as usize;
                    if single {
                        // single-stage: fwd is folded into bwd; nothing to do
                        continue;
                    }
                    if is_first {
                        let tokens = stash_tok[mb].as_ref().unwrap();
                        let tok_buf = ctx.rt.buf_i32(tokens, &tok_dims)?;
                        let out = stage
                            .fwd
                            .run_b(&[&params_buf.0, &tok_buf.0])
                            .context("stage fwd (embed)")?;
                        let y = to_f32(&out[0])?;
                        self_send(&ctx, ctx.next_rank(), y);
                    } else if is_last {
                        // last stage: stash the incoming activation; the
                        // loss+grads come from the backward entry point
                        let x = ctx.world.recv(ctx.world_rank(), ctx.prev_rank());
                        stash_x[mb] = Some(x);
                    } else {
                        let x = ctx.world.recv(ctx.world_rank(), ctx.prev_rank());
                        let x_buf = ctx.rt.buf_f32(&x, &act_dims)?;
                        let out = stage
                            .fwd
                            .run_b(&[&params_buf.0, &x_buf.0])
                            .context("stage fwd")?;
                        let y = to_f32(&out[0])?;
                        stash_x[mb] = Some(x);
                        self_send(&ctx, ctx.next_rank(), y);
                    }
                }
                Op::Backward { mb } => {
                    let mb = mb as usize;
                    if single {
                        // fused fwd+bwd: (flat, tokens, targets) -> (gflat, loss)
                        let tokens = stash_tok[mb].take().unwrap();
                        let targets = stash_tgt[mb].take().unwrap();
                        let tok_buf = ctx.rt.buf_i32(&tokens, &tok_dims)?;
                        let tgt_buf = ctx.rt.buf_i32(&targets, &tok_dims)?;
                        let out = stage
                            .bwd
                            .run_b(&[&params_buf.0, &tok_buf.0, &tgt_buf.0])
                            .context("single-stage bwd")?;
                        accumulate(&mut grad_accum, &to_f32(&out[0])?);
                        loss_sum += scalar_f32(&out[1])?;
                    } else if is_last {
                        let x = stash_x[mb].take().unwrap();
                        let targets = stash_tgt[mb].take().unwrap();
                        let x_buf = ctx.rt.buf_f32(&x, &act_dims)?;
                        let tgt_buf = ctx.rt.buf_i32(&targets, &tok_dims)?;
                        let out = stage
                            .bwd
                            .run_b(&[&params_buf.0, &x_buf.0, &tgt_buf.0])
                            .context("last-stage bwd")?;
                        accumulate(&mut grad_accum, &to_f32(&out[0])?);
                        let gx = to_f32(&out[1])?;
                        loss_sum += scalar_f32(&out[2])?;
                        self_send(&ctx, ctx.prev_rank(), gx);
                    } else if is_first {
                        let gy = ctx.world.recv(ctx.world_rank(), ctx.next_rank());
                        let tokens = stash_tok[mb].take().unwrap();
                        let tok_buf = ctx.rt.buf_i32(&tokens, &tok_dims)?;
                        let gy_buf = ctx.rt.buf_f32(&gy, &act_dims)?;
                        let out = stage
                            .bwd
                            .run_b(&[&params_buf.0, &tok_buf.0, &gy_buf.0])
                            .context("first-stage bwd")?;
                        accumulate(&mut grad_accum, &to_f32(&out[0])?);
                    } else {
                        let gy = ctx.world.recv(ctx.world_rank(), ctx.next_rank());
                        let x = stash_x[mb].take().unwrap();
                        let x_buf = ctx.rt.buf_f32(&x, &act_dims)?;
                        let gy_buf = ctx.rt.buf_f32(&gy, &act_dims)?;
                        let out = stage
                            .bwd
                            .run_b(&[&params_buf.0, &x_buf.0, &gy_buf.0])
                            .context("middle-stage bwd")?;
                        accumulate(&mut grad_accum, &to_f32(&out[0])?);
                        let gx = to_f32(&out[1])?;
                        self_send(&ctx, ctx.prev_rank(), gx);
                    }
                }
            }
        }

        // gradient accumulation: mean over micro-batches
        let inv_m = 1.0 / m as f32;
        grad_accum.iter_mut().for_each(|g| *g *= inv_m);

        // DP sync + (sharded) optimizer step
        let lr_scale = ctx
            .cfg
            .lr_schedule
            .map(|sch| sch.scale(step as u64))
            .unwrap_or(1.0);
        let grad_norm = opt.step(
            &ctx.dp_group,
            ctx.dp_rank,
            &mut params,
            &mut grad_accum,
            lr_scale,
        );

        // periodic checkpoint: every rank persists its own piece after a
        // world barrier (so all stages are at the same step), dp-rank-0
        // writes the shared params, stage0/dp0 writes the manifest
        let every = ctx.cfg.checkpoint_every;
        let last_step = rel_step + 1 == ctx.cfg.steps;
        if let Some(dir) = ctx.cfg.checkpoint_dir.as_ref() {
            if (every > 0 && (rel_step + 1) % every == 0) || last_step {
                ctx.world.barrier(ctx.world_rank());
                if ctx.dp_rank == 0 {
                    checkpoint::write_f32(
                        &checkpoint::params_path(dir, ctx.pp_rank),
                        &params,
                        (step + 1) as u64,
                    )?;
                }
                let (state, t) = opt.export_state();
                checkpoint::write_f32(
                    &checkpoint::opt_path(dir, ctx.pp_rank, ctx.dp_rank),
                    &state,
                    t,
                )?;
                ctx.world.barrier(ctx.world_rank());
                if ctx.pp_rank == 0 && ctx.dp_rank == 0 {
                    checkpoint::Manifest {
                        step: step + 1,
                        bundle: ctx.cfg.bundle.clone(),
                        pp: ctx.pp as u32,
                        dp: ctx.dp as u32,
                        zero1: ctx.cfg.zero1,
                    }
                    .save(dir)?;
                }
            }
        }

        // loss reporting: mean across micro-batches, then across DP
        if is_last {
            let mut l = vec![loss_sum * inv_m];
            ctx.dp_group
                .all_reduce_sum(ctx.dp_rank, &mut l, crate::collectives::Algo::Naive);
            let mean_loss = l[0] / ctx.dp as f32;
            if let Some(tx) = &ctx.loss_tx {
                tx.send((step, mean_loss, grad_norm))
                    .map_err(|_| anyhow!("leader hung up"))?;
            }
        }
    }
    Ok(())
}

fn self_send(ctx: &WorkerCtx, to: usize, data: Vec<f32>) {
    ctx.world.send(ctx.world_rank(), to, data);
}

fn accumulate(acc: &mut [f32], g: &[f32]) {
    debug_assert_eq!(acc.len(), g.len());
    for (a, &v) in acc.iter_mut().zip(g.iter()) {
        *a += v;
    }
}
