//! Worker thread: one simulated GCD executing its instruction stream over
//! `v` virtual-stage chunk slots against the stage backends (PJRT
//! executables or builtin reference stages), as one shard of its
//! tensor-parallel group.
//!
//! Chunk `c` of worker `r` is global stage `g = c * pp + r`; activations
//! flow `g -> g+1` (worker `(r+1) % pp`), gradients `g -> g-1`.  Because
//! several chunk channels share each (from, to) worker mailbox, every
//! message is tagged with `(direction, destination chunk, micro-batch)`;
//! with `pp = 1` the chunk boundary stays worker-local and skips the
//! mailboxes entirely.
//!
//! With `tp > 1` the worker is one of `tp` shard threads of a pipeline
//! cell: it executes the SAME instruction stream as its TP siblings
//! (SPMD), each op's per-layer all-reduces running inside the sharded
//! stage entry points through `TpComm`.  Pipeline p2p connects
//! *corresponding* tp ranks of adjacent cells — every shard holds the
//! full activation after its row-parallel all-reduce, so the boundary
//! protocol is unchanged from the dense engine.
//!
//! **Backward-overlapped gradient sync** (the paper's §IV DeepSpeed
//! lever, executed for real): each chunk counts down its micro-batch
//! backwards; the moment the last one completes, the chunk's gradient
//! is finalised (1/m scale + TP replicated-span sync) and split into
//! nonblocking all-reduce buckets on the DP group, which reduce under
//! whatever backward compute is still in flight.  The handles drain
//! just before the optimizer step.  Because the bucketed all-reduce
//! sums in rank order no matter when deposits land, the overlapped and
//! sequential paths produce **bit-identical** loss trajectories — the
//! equivalence the overlap tests pin.  Launch-site timing classifies
//! every second of sync work as hidden (mid-stream) or exposed
//! (post-stream / drain); `TrainReport` surfaces the two and `perf`
//! prices its DP comm term from the same fraction.

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::collectives::{Group, ReduceHandle, SubGroup, TpComm};
use crate::data::BatchStream;
use crate::precision::{Dtype, LossScaler};
use crate::runtime::{Bundle, ParamsHandle, Runtime, StageExecutables};
use crate::schedule::{Op, Schedule};
use crate::zero::DistOptimizer;

use super::{checkpoint, EngineConfig};

/// Everything a worker needs; handed over at spawn.
pub struct WorkerCtx {
    pub cfg: EngineConfig,
    pub rt: Arc<Runtime>,
    pub bundle: Arc<Bundle>,
    pub sched: Arc<Schedule>,
    pub world: Arc<Group>,
    /// This worker's tensor-parallel subgroup (its pp×dp cell).
    pub tp_group: Arc<SubGroup>,
    pub dp_group: Arc<Group>,
    pub pp_rank: usize,
    pub dp_rank: usize,
    pub tp_rank: usize,
    /// Pipeline ranks (worker grid depth).
    pub pp: usize,
    pub dp: usize,
    /// Tensor-parallel shards per pipeline cell.
    pub tp: usize,
    /// Virtual chunks hosted by this worker (global stages = pp * v).
    pub v: usize,
    /// First step index (non-zero when resuming from a checkpoint).
    pub start_step: u32,
    /// Loss-scaler state to start from (the checkpointed scale on
    /// resume, `cfg.loss_scale_init` otherwise).
    pub start_loss_scale: f32,
    pub start_scale_good: u32,
    /// Only the (last-rank, dp=0, tp=0) worker reports losses:
    /// (step, loss, grad norm, post-update loss scale, skipped).
    pub loss_tx: Option<mpsc::Sender<(u32, f32, f32, f32, bool)>>,
}

const TAG_FWD: u64 = 1;
const TAG_BWD: u64 = 2;

fn tag(direction: u64, chunk: usize, mb: usize) -> u64 {
    (direction << 48) | ((chunk as u64) << 24) | mb as u64
}

/// In-flight DP gradient buckets of one chunk: `(span lo, span hi,
/// nonblocking all-reduce handle)`.
type ChunkBuckets = Vec<(usize, usize, ReduceHandle)>;

/// Per-chunk gradient finalisation, run the moment the chunk's last
/// micro-batch backward completes: mean over micro-batches, then the
/// TP replicated-span mean sync (the row-parallel bias gradient is
/// identical across shards by construction — the sync pins that
/// invariant against drift; sharded parameters are disjoint per shard
/// and need no sync).
fn finalize_chunk_grads(
    grads: &mut [f32],
    inv_m: f32,
    replicated: Option<(usize, usize)>,
    comm: &TpComm,
) {
    grads.iter_mut().for_each(|x| *x *= inv_m);
    if let Some((lo, hi)) = replicated {
        let inv_tp = 1.0 / comm.tp() as f32;
        comm.all_reduce_sum(&mut grads[lo..hi]);
        grads[lo..hi].iter_mut().for_each(|x| *x *= inv_tp);
    }
}

/// Split a chunk's gradient buffer into `bucket_floats`-sized spans and
/// launch each as a nonblocking all-reduce on the DP group.  The tag
/// folds `(step, chunk, bucket)` — 32/8/24 bits — so concurrent rounds
/// never collide and no tag is reused before its round drains; the
/// field widths are enforced (not just debug-checked), since an
/// overflow would alias another chunk's round and abort the run as a
/// double deposit.
fn launch_grad_buckets(
    group: &Arc<Group>,
    rank: usize,
    step: u32,
    chunk: usize,
    grads: &[f32],
    bucket_floats: usize,
    wire: Dtype,
) -> ChunkBuckets {
    let bucket = bucket_floats.max(1);
    assert!(chunk < (1 << 8), "chunk {chunk} overflows the bucket-tag field");
    let n_buckets = grads.len().div_ceil(bucket);
    assert!(
        n_buckets < (1 << 24),
        "grad_bucket_floats {bucket_floats} yields {n_buckets} buckets (tag field is 24 bits)"
    );
    let mut out = Vec::with_capacity(n_buckets);
    let mut lo = 0;
    while lo < grads.len() {
        let hi = (lo + bucket).min(grads.len());
        let tag = ((step as u64) << 32) | ((chunk as u64) << 24) | out.len() as u64;
        out.push((
            lo,
            hi,
            group.start_all_reduce_dtype(rank, tag, grads[lo..hi].to_vec(), wire),
        ));
        lo = hi;
    }
    out
}

/// Finalize chunk `c`'s gradient ([`finalize_chunk_grads`]) and launch
/// its DP buckets, charging the launch time to the hidden (mid-stream)
/// or exposed (post-stream) timer — the single definition both call
/// sites share so the hidden/exposed split cannot drift.
#[allow(clippy::too_many_arguments)]
fn finalize_and_launch(
    ctx: &WorkerCtx,
    comm: &TpComm,
    stage: &StageExecutables,
    grads: &mut [f32],
    inv_m: f32,
    step: u32,
    c: usize,
    hidden: bool,
) -> ChunkBuckets {
    finalize_chunk_grads(grads, inv_m, stage.tp_replicated_span(), comm);
    if ctx.dp == 1 {
        return Vec::new();
    }
    let t0 = Instant::now();
    let buckets = launch_grad_buckets(
        &ctx.dp_group,
        ctx.dp_rank,
        step,
        c,
        grads,
        ctx.cfg.grad_bucket_floats,
        ctx.cfg.precision,
    );
    let counter = if hidden { &ctx.dp_group.nb_hidden_ns } else { &ctx.dp_group.nb_exposed_ns };
    counter.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    buckets
}

impl WorkerCtx {
    /// Megatron rank order, TP innermost.
    fn world_rank(&self) -> usize {
        (self.pp_rank * self.dp + self.dp_rank) * self.tp + self.tp_rank
    }

    /// World rank of the same (dp, tp) coordinates on another pipeline
    /// cell — the p2p peer for activations/gradients.
    fn world_rank_of(&self, pp_rank: usize) -> usize {
        (pp_rank * self.dp + self.dp_rank) * self.tp + self.tp_rank
    }

    /// Total global (virtual) stages.
    fn k(&self) -> usize {
        self.pp * self.v
    }

    /// Global stage of chunk `c` on this worker.
    fn global(&self, chunk: usize) -> usize {
        chunk * self.pp + self.pp_rank
    }
}

/// Worker-local routing state: in-flight self-delivered chunk boundaries
/// (only reachable when `pp == 1`).
#[derive(Default)]
struct LocalChannels {
    acts: HashMap<(usize, usize), Vec<f32>>,
    grads: HashMap<(usize, usize), Vec<f32>>,
}

/// Send the forward activation of global stage `g` downstream.
fn send_act(ctx: &WorkerCtx, local: &mut LocalChannels, g: usize, mb: usize, y: Vec<f32>) {
    let dest_stage = g + 1;
    let dest_rank = dest_stage % ctx.pp;
    let dest_chunk = dest_stage / ctx.pp;
    if dest_rank == ctx.pp_rank {
        local.acts.insert((dest_chunk, mb), y);
    } else {
        ctx.world.send_tagged(
            ctx.world_rank(),
            ctx.world_rank_of(dest_rank),
            tag(TAG_FWD, dest_chunk, mb),
            y,
        );
    }
}

/// Receive the input activation for this worker's chunk `c` (global `g`).
fn recv_act(ctx: &WorkerCtx, local: &mut LocalChannels, g: usize, mb: usize) -> Vec<f32> {
    let chunk = g / ctx.pp;
    let src_rank = (g - 1) % ctx.pp;
    if src_rank == ctx.pp_rank {
        local.acts.remove(&(chunk, mb)).expect("local activation present")
    } else {
        ctx.world.recv_tagged(
            ctx.world_rank(),
            ctx.world_rank_of(src_rank),
            tag(TAG_FWD, chunk, mb),
        )
    }
}

/// Send the input-gradient of global stage `g` upstream.
fn send_grad(ctx: &WorkerCtx, local: &mut LocalChannels, g: usize, mb: usize, gx: Vec<f32>) {
    let dest_stage = g - 1;
    let dest_rank = dest_stage % ctx.pp;
    let dest_chunk = dest_stage / ctx.pp;
    if dest_rank == ctx.pp_rank {
        local.grads.insert((dest_chunk, mb), gx);
    } else {
        ctx.world.send_tagged(
            ctx.world_rank(),
            ctx.world_rank_of(dest_rank),
            tag(TAG_BWD, dest_chunk, mb),
            gx,
        );
    }
}

/// Receive the upstream gradient for this worker's chunk `c` (global `g`).
fn recv_grad(ctx: &WorkerCtx, local: &mut LocalChannels, g: usize, mb: usize) -> Vec<f32> {
    let chunk = g / ctx.pp;
    let src_rank = (g + 1) % ctx.pp;
    if src_rank == ctx.pp_rank {
        local.grads.remove(&(chunk, mb)).expect("local gradient present")
    } else {
        ctx.world.recv_tagged(
            ctx.world_rank(),
            ctx.world_rank_of(src_rank),
            tag(TAG_BWD, chunk, mb),
        )
    }
}

/// Worker main loop.
pub fn run(ctx: WorkerCtx) -> Result<()> {
    let meta = &ctx.bundle.meta;
    let k = ctx.k();
    let single = k == 1;
    let dims = ctx.bundle.dims();
    // chunk 0 of rank 0 embeds; chunk v-1 of rank pp-1 computes the loss
    let owns_embed = ctx.pp_rank == 0;
    let owns_head = ctx.pp_rank == ctx.pp - 1;

    // this shard's tensor-parallel communicator (no-op when tp = 1),
    // carrying the run's wire dtype (bf16 payloads pack half-width) and
    // collective algorithm for its all-reduces
    let comm = TpComm::new(ctx.tp_group.clone(), ctx.world_rank())
        .with_wire(ctx.cfg.precision)
        .with_algo(ctx.cfg.collective_algo);

    // dynamic loss scaling: live whenever the run is mixed-precision or
    // an explicit scale was requested — including a non-unit scale
    // restored from a checkpoint manifest (a resume must keep unscaling
    // even if the resuming config omitted --loss-scale); fully inert (no
    // extra collectives, no extra float ops) on the default fp32 path,
    // which must stay bitwise-identical to the pre-mixed-precision engine
    let scaling_active = ctx.cfg.precision != Dtype::F32
        || ctx.cfg.loss_scale_init != 1.0
        || ctx.start_loss_scale != 1.0
        || ctx.cfg.loss_scale_growth_interval > 0;
    let mut scaler = LossScaler::with_state(
        ctx.start_loss_scale,
        ctx.cfg.loss_scale_growth_interval,
        ctx.start_scale_good,
    );

    // ---- per-chunk slots: stage executables, params, optimizer ----
    // tp = 1 borrows the bundle's dense stages; tp > 1 derives this
    // shard's view of each hosted chunk (builtin backend only)
    let owned_shards: Vec<StageExecutables> = if ctx.tp > 1 {
        (0..ctx.v)
            .map(|c| ctx.bundle.stages[ctx.global(c)].tp_shard(ctx.tp, ctx.tp_rank))
            .collect::<Result<Vec<_>>>()?
    } else {
        Vec::new()
    };
    let stages: Vec<&StageExecutables> = if ctx.tp > 1 {
        owned_shards.iter().collect()
    } else {
        (0..ctx.v).map(|c| &ctx.bundle.stages[ctx.global(c)]).collect()
    };
    // parameters live behind `Arc`s so the per-step handle staging is
    // zero-copy (the builtin backend clones the Arc, not the buffer);
    // the optimizer mutates through `Arc::make_mut` after the handles
    // drop, so no copy-on-write ever triggers
    let mut params: Vec<Arc<Vec<f32>>> = Vec::with_capacity(ctx.v);
    let mut opts: Vec<DistOptimizer> = Vec::with_capacity(ctx.v);
    for stage in &stages {
        // parameter init: identical across DP replicas and across pipeline
        // partitions (init keys fold in GLOBAL layer indices on both
        // backends, so the key is the same for every partitioning); TP
        // shards slice the same dense component streams
        let p = stage.init_params(ctx.cfg.seed)?;
        anyhow::ensure!(
            p.len() as u64 == stage.meta.param_count,
            "init size mismatch on stage {}",
            stage.meta.index
        );
        opts.push(DistOptimizer::new(
            ctx.cfg.zero1,
            ctx.cfg.adam,
            p.len(),
            ctx.dp_rank,
            ctx.dp,
            ctx.cfg.collective_algo,
            ctx.cfg.precision,
        ));
        params.push(Arc::new(p));
    }

    // ---- checkpoint resume: params (shared) + this rank's opt state ----
    if ctx.cfg.resume {
        let dir = ctx.cfg.checkpoint_dir.as_ref().expect("validated by leader");
        for (c, stage) in stages.iter().enumerate() {
            let g = ctx.global(c);
            let (p, _) =
                checkpoint::read_f32(&checkpoint::params_path(dir, g, ctx.tp_rank))?;
            anyhow::ensure!(
                p.len() as u64 == stage.meta.param_count,
                "checkpoint params size mismatch on stage {g}"
            );
            params[c] = Arc::new(p);
            let (state, t) = checkpoint::read_f32(&checkpoint::opt_path(
                dir,
                g,
                ctx.tp_rank,
                ctx.dp_rank,
            ))?;
            opts[c].import_state(&state, t);
        }
    }

    // ---- data: embed and head owners draw the SAME dp-sharded stream ----
    let mut stream = (owns_embed || owns_head).then(|| {
        BatchStream::new(
            meta.model.vocab as u32,
            ctx.cfg.seed ^ 0xDA7A,
            ctx.dp_rank,
            ctx.dp,
            dims.b,
            dims.s,
        )
    });

    let m = ctx.cfg.microbatches as usize;
    let inv_m = 1.0 / m as f32;
    // overlap only exists with a DP group to sync against
    let overlap = ctx.cfg.overlap_grad_sync && ctx.dp > 1;
    let mut grad_accum: Vec<Vec<f32>> =
        params.iter().map(|p| vec![0.0f32; p.len()]).collect();
    // per-(chunk, micro-batch) stash: stage input activations
    // (checkpointing: inputs only); token/target rows for the boundary
    // chunks
    let mut stash_x: Vec<Vec<Option<Vec<f32>>>> = vec![vec![None; m]; ctx.v];
    let mut stash_tok: Vec<Option<Vec<i32>>> = vec![None; m];
    let mut stash_tgt: Vec<Option<Vec<i32>>> = vec![None; m];
    let mut local = LocalChannels::default();

    // fast-forward the data stream past already-trained steps
    if ctx.start_step > 0 {
        if let Some(stream) = stream.as_mut() {
            stream.skip_microbatches(ctx.start_step as usize * m);
        }
    }

    for rel_step in 0..ctx.cfg.steps {
        let step = ctx.start_step + rel_step;
        for g in grad_accum.iter_mut() {
            g.iter_mut().for_each(|x| *x = 0.0);
        }
        let mut loss_sum = 0.0f32;
        // the loss scale applied to this step's backward (a power of two,
        // so scaling is exact; 1.0 keeps the multiplies skipped entirely)
        let scale = scaler.scale();
        // per-chunk backward countdown + this step's in-flight buckets
        let mut bwd_left: Vec<usize> = vec![m; ctx.v];
        let mut buckets: Vec<ChunkBuckets> = (0..ctx.v).map(|_| Vec::new()).collect();
        let mut finalized = vec![false; ctx.v];

        // draw this step's micro-batches up front (the schedule issues
        // each chunk's forwards in order, so index mb matches draw order)
        if let Some(stream) = stream.as_mut() {
            for mb in 0..m {
                let batch = stream.next_microbatch();
                if owns_embed {
                    stash_tok[mb] = Some(batch.tokens.clone());
                }
                if owns_head {
                    stash_tgt[mb] = Some(batch.targets);
                }
            }
        }

        // stage each chunk's parameter vector ONCE per step; every
        // micro-batch's fwd/bwd reuses the same handle (EXPERIMENTS.md
        // §Perf).  Builtin stages share the Arc — zero bytes copied.
        let mut handles: Vec<ParamsHandle> = Vec::with_capacity(ctx.v);
        for (stage, p) in stages.iter().zip(&params) {
            handles.push(stage.prepare_params_shared(&ctx.rt, p)?);
        }

        for op in &ctx.sched.streams[ctx.pp_rank] {
            let c = op.chunk() as usize;
            let g = ctx.global(c);
            let stage = stages[c];
            let pbuf = &handles[c];
            match *op {
                Op::Forward { mb, .. } => {
                    let mb = mb as usize;
                    if single {
                        // single-stage: fwd is folded into bwd; nothing to do
                        continue;
                    }
                    if g == 0 {
                        let tokens = stash_tok[mb].as_ref().unwrap();
                        let y = stage.fwd_first(&ctx.rt, pbuf, &comm, tokens, dims)?;
                        send_act(&ctx, &mut local, g, mb, y);
                    } else if g == k - 1 {
                        // head chunk: stash the incoming activation; the
                        // loss + grads come from the backward entry point
                        let x = recv_act(&ctx, &mut local, g, mb);
                        stash_x[c][mb] = Some(x);
                    } else {
                        let x = recv_act(&ctx, &mut local, g, mb);
                        let y = stage.fwd_mid(&ctx.rt, pbuf, &comm, &x, dims)?;
                        stash_x[c][mb] = Some(x);
                        send_act(&ctx, &mut local, g, mb, y);
                    }
                }
                Op::Backward { mb, .. } => {
                    let mb = mb as usize;
                    if single {
                        // fused fwd+bwd: (flat, tokens, targets) -> (gflat, loss)
                        let tokens = stash_tok[mb].take().unwrap();
                        let targets = stash_tgt[mb].take().unwrap();
                        let (mut gp, loss) =
                            stage.bwd_single(&ctx.rt, pbuf, &comm, &tokens, &targets, dims)?;
                        if scale != 1.0 {
                            gp.iter_mut().for_each(|x| *x *= scale);
                        }
                        accumulate(&mut grad_accum[c], &gp);
                        loss_sum += loss;
                    } else if g == k - 1 {
                        let x = stash_x[c][mb].take().unwrap();
                        let targets = stash_tgt[mb].take().unwrap();
                        let (mut gp, mut gx, loss) =
                            stage.bwd_last(&ctx.rt, pbuf, &comm, &x, &targets, dims)?;
                        // loss scaling enters at the source: the head
                        // stage's own grads and the gradient it sends
                        // upstream (everything upstream scales through
                        // the chain automatically)
                        if scale != 1.0 {
                            gp.iter_mut().for_each(|x| *x *= scale);
                            gx.iter_mut().for_each(|x| *x *= scale);
                        }
                        accumulate(&mut grad_accum[c], &gp);
                        loss_sum += loss;
                        send_grad(&ctx, &mut local, g, mb, gx);
                    } else if g == 0 {
                        let gy = recv_grad(&ctx, &mut local, g, mb);
                        let tokens = stash_tok[mb].take().unwrap();
                        let gp = stage.bwd_first(&ctx.rt, pbuf, &comm, &tokens, &gy, dims)?;
                        accumulate(&mut grad_accum[c], &gp);
                    } else {
                        let gy = recv_grad(&ctx, &mut local, g, mb);
                        let x = stash_x[c][mb].take().unwrap();
                        let (gp, gx) = stage.bwd_mid(&ctx.rt, pbuf, &comm, &x, &gy, dims)?;
                        accumulate(&mut grad_accum[c], &gp);
                        send_grad(&ctx, &mut local, g, mb, gx);
                    }
                    // the chunk's LAST backward just ran: finalize its
                    // gradient and (overlap mode) launch its DP buckets
                    // so the sync hides under the remaining backward ops
                    bwd_left[c] -= 1;
                    if overlap && bwd_left[c] == 0 {
                        buckets[c] = finalize_and_launch(
                            &ctx,
                            &comm,
                            stages[c],
                            &mut grad_accum[c],
                            inv_m,
                            step,
                            c,
                            true,
                        );
                        finalized[c] = true;
                    }
                }
            }
        }

        // release the step-scoped parameter handles so the optimizer
        // can mutate the Arc'd buffers below without copy-on-write
        drop(handles);

        // chunks whose last backward fell at the very end of the stream
        // — or every chunk in sequential mode — finalize here, their
        // bucket launches landing on the exposed timeline
        for c in 0..ctx.v {
            if !finalized[c] {
                buckets[c] = finalize_and_launch(
                    &ctx,
                    &comm,
                    stages[c],
                    &mut grad_accum[c],
                    inv_m,
                    step,
                    c,
                    false,
                );
            }
        }

        // drain every chunk's bucket handles in a fixed order (every
        // rank of a DP row walks the same sequence, so the per-chunk
        // collective rounds line up; bucket reduction is rank-order
        // deterministic regardless of overlap timing, so overlapped ≡
        // sequential bit for bit)
        let lr_scale = ctx
            .cfg
            .lr_schedule
            .map(|sch| sch.scale(step as u64))
            .unwrap_or(1.0);
        for c in 0..ctx.v {
            if ctx.dp > 1 {
                let t0 = Instant::now();
                for (lo, hi, h) in buckets[c].drain(..) {
                    // zero-copy redeem: one copy, shared sum -> grads
                    let sum = h.wait_shared();
                    grad_accum[c][lo..hi].copy_from_slice(&sum);
                }
                ctx.dp_group
                    .nb_exposed_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                let inv_dp = 1.0 / ctx.dp as f32;
                grad_accum[c].iter_mut().for_each(|x| *x *= inv_dp);
            }
        }

        // mixed precision: every worker must reach the same skip verdict
        // (a skipped step leaves every optimizer untouched), so the local
        // non-finite-gradient flag is agreed across the WHOLE world with
        // a 1-float all-reduce before the scaler rules.  Then unscale the
        // surviving gradients (1/scale is a power of two — exact).
        let mut skipped = false;
        if scaling_active {
            let local_overflow =
                grad_accum.iter().any(|g| g.iter().any(|x| !x.is_finite()));
            let mut flag = vec![if local_overflow { 1.0f32 } else { 0.0 }];
            ctx.world
                .all_reduce_sum(ctx.world_rank(), &mut flag, ctx.cfg.collective_algo);
            skipped = scaler.update(flag[0] > 0.0);
            if !skipped && scale != 1.0 {
                let inv = 1.0 / scale;
                for g in grad_accum.iter_mut() {
                    g.iter_mut().for_each(|x| *x *= inv);
                }
            }
        }

        // (sharded) optimizer step, chunk by chunk; combined pre-clip
        // norm over every chunk this worker hosts (a single chunk's
        // spike must not be masked by the last chunk's).  A scaler-
        // skipped step touches no optimizer state at all — Adam's step
        // count included — and reports an infinite gradient norm.
        let grad_norm = if skipped {
            f32::INFINITY
        } else {
            let mut grad_norm_sq = 0.0f32;
            for c in 0..ctx.v {
                // under TP the clip norm combines across the tensor group
                // (replicated span counted once) — dense-equivalent clipping
                let tp_ctx = stages[c].tp_replicated_span().map(|span| (&comm, span));
                let norm = opts[c].step_reduced(
                    &ctx.dp_group,
                    ctx.dp_rank,
                    Arc::make_mut(&mut params[c]),
                    &mut grad_accum[c],
                    lr_scale,
                    tp_ctx,
                );
                grad_norm_sq += norm * norm;
            }
            grad_norm_sq.sqrt()
        };

        // periodic checkpoint: every rank persists its own pieces after a
        // world barrier (so all stages are at the same step).  Files are
        // keyed (global stage, tp rank): each tensor shard's dp-rank-0
        // worker writes that shard's params; every rank writes its own
        // optimizer state; pp0/dp0/tp0 writes the manifest.
        let every = ctx.cfg.checkpoint_every;
        let last_step = rel_step + 1 == ctx.cfg.steps;
        if let Some(dir) = ctx.cfg.checkpoint_dir.as_ref() {
            if (every > 0 && (rel_step + 1) % every == 0) || last_step {
                ctx.world.barrier(ctx.world_rank());
                for c in 0..ctx.v {
                    let g = ctx.global(c);
                    if ctx.dp_rank == 0 {
                        checkpoint::write_f32(
                            &checkpoint::params_path(dir, g, ctx.tp_rank),
                            &params[c],
                            (step + 1) as u64,
                        )?;
                    }
                    let (state, t) = opts[c].export_state();
                    checkpoint::write_f32(
                        &checkpoint::opt_path(dir, g, ctx.tp_rank, ctx.dp_rank),
                        &state,
                        t,
                    )?;
                }
                ctx.world.barrier(ctx.world_rank());
                if ctx.pp_rank == 0 && ctx.dp_rank == 0 && ctx.tp_rank == 0 {
                    checkpoint::Manifest {
                        step: step + 1,
                        bundle: ctx.cfg.bundle.clone(),
                        stages: ctx.k() as u32,
                        tp: ctx.tp as u32,
                        dp: ctx.dp as u32,
                        zero1: ctx.cfg.zero1,
                        precision: ctx.cfg.precision.name().to_string(),
                        loss_scale: scaler.scale(),
                        scale_good_steps: scaler.good_steps(),
                    }
                    .save(dir)?;
                }
            }
        }

        // loss reporting: mean across micro-batches, then across DP
        if owns_head {
            let mut l = vec![loss_sum * inv_m];
            ctx.dp_group
                .all_reduce_sum(ctx.dp_rank, &mut l, ctx.cfg.collective_algo);
            let mean_loss = l[0] / ctx.dp as f32;
            if let Some(tx) = &ctx.loss_tx {
                tx.send((step, mean_loss, grad_norm, scaler.scale(), skipped))
                    .map_err(|_| anyhow!("leader hung up"))?;
            }
        }
    }
    Ok(())
}

fn accumulate(acc: &mut [f32], g: &[f32]) {
    debug_assert_eq!(acc.len(), g.len());
    for (a, &v) in acc.iter_mut().zip(g.iter()) {
        *a += v;
    }
}
