//! Worker thread: one simulated GCD executing its instruction stream over
//! `v` virtual-stage chunk slots against the stage backends (PJRT
//! executables or builtin reference stages), as one shard of its
//! tensor-parallel group.
//!
//! Chunk `c` of worker `r` is global stage `g = c * pp + r`; activations
//! flow `g -> g+1` (worker `(r+1) % pp`), gradients `g -> g-1`.  Because
//! several chunk channels share each (from, to) worker mailbox, every
//! message is tagged with `(direction, destination chunk, micro-batch)`;
//! with `pp = 1` the chunk boundary stays worker-local and skips the
//! mailboxes entirely.
//!
//! With `tp > 1` the worker is one of `tp` shard threads of a pipeline
//! cell: it executes the SAME instruction stream as its TP siblings
//! (SPMD), each op's per-layer all-reduces running inside the sharded
//! stage entry points through `TpComm`.  Pipeline p2p connects
//! *corresponding* tp ranks of adjacent cells — every shard holds the
//! full activation after its row-parallel all-reduce, so the boundary
//! protocol is unchanged from the dense engine.

use std::collections::HashMap;
use std::sync::{mpsc, Arc};

use anyhow::{anyhow, Result};

use crate::collectives::{Group, SubGroup, TpComm};
use crate::data::BatchStream;
use crate::runtime::{Bundle, ParamsHandle, Runtime, StageExecutables};
use crate::schedule::{Op, Schedule};
use crate::zero::DistOptimizer;

use super::{checkpoint, EngineConfig};

/// Everything a worker needs; handed over at spawn.
pub struct WorkerCtx {
    pub cfg: EngineConfig,
    pub rt: Arc<Runtime>,
    pub bundle: Arc<Bundle>,
    pub sched: Arc<Schedule>,
    pub world: Arc<Group>,
    /// This worker's tensor-parallel subgroup (its pp×dp cell).
    pub tp_group: Arc<SubGroup>,
    pub dp_group: Arc<Group>,
    pub pp_rank: usize,
    pub dp_rank: usize,
    pub tp_rank: usize,
    /// Pipeline ranks (worker grid depth).
    pub pp: usize,
    pub dp: usize,
    /// Tensor-parallel shards per pipeline cell.
    pub tp: usize,
    /// Virtual chunks hosted by this worker (global stages = pp * v).
    pub v: usize,
    /// First step index (non-zero when resuming from a checkpoint).
    pub start_step: u32,
    /// Only the (last-rank, dp=0, tp=0) worker reports losses.
    pub loss_tx: Option<mpsc::Sender<(u32, f32, f32)>>,
}

const TAG_FWD: u64 = 1;
const TAG_BWD: u64 = 2;

fn tag(direction: u64, chunk: usize, mb: usize) -> u64 {
    (direction << 48) | ((chunk as u64) << 24) | mb as u64
}

impl WorkerCtx {
    /// Megatron rank order, TP innermost.
    fn world_rank(&self) -> usize {
        (self.pp_rank * self.dp + self.dp_rank) * self.tp + self.tp_rank
    }

    /// World rank of the same (dp, tp) coordinates on another pipeline
    /// cell — the p2p peer for activations/gradients.
    fn world_rank_of(&self, pp_rank: usize) -> usize {
        (pp_rank * self.dp + self.dp_rank) * self.tp + self.tp_rank
    }

    /// Total global (virtual) stages.
    fn k(&self) -> usize {
        self.pp * self.v
    }

    /// Global stage of chunk `c` on this worker.
    fn global(&self, chunk: usize) -> usize {
        chunk * self.pp + self.pp_rank
    }
}

/// Worker-local routing state: in-flight self-delivered chunk boundaries
/// (only reachable when `pp == 1`).
#[derive(Default)]
struct LocalChannels {
    acts: HashMap<(usize, usize), Vec<f32>>,
    grads: HashMap<(usize, usize), Vec<f32>>,
}

/// Send the forward activation of global stage `g` downstream.
fn send_act(ctx: &WorkerCtx, local: &mut LocalChannels, g: usize, mb: usize, y: Vec<f32>) {
    let dest_stage = g + 1;
    let dest_rank = dest_stage % ctx.pp;
    let dest_chunk = dest_stage / ctx.pp;
    if dest_rank == ctx.pp_rank {
        local.acts.insert((dest_chunk, mb), y);
    } else {
        ctx.world.send_tagged(
            ctx.world_rank(),
            ctx.world_rank_of(dest_rank),
            tag(TAG_FWD, dest_chunk, mb),
            y,
        );
    }
}

/// Receive the input activation for this worker's chunk `c` (global `g`).
fn recv_act(ctx: &WorkerCtx, local: &mut LocalChannels, g: usize, mb: usize) -> Vec<f32> {
    let chunk = g / ctx.pp;
    let src_rank = (g - 1) % ctx.pp;
    if src_rank == ctx.pp_rank {
        local.acts.remove(&(chunk, mb)).expect("local activation present")
    } else {
        ctx.world.recv_tagged(
            ctx.world_rank(),
            ctx.world_rank_of(src_rank),
            tag(TAG_FWD, chunk, mb),
        )
    }
}

/// Send the input-gradient of global stage `g` upstream.
fn send_grad(ctx: &WorkerCtx, local: &mut LocalChannels, g: usize, mb: usize, gx: Vec<f32>) {
    let dest_stage = g - 1;
    let dest_rank = dest_stage % ctx.pp;
    let dest_chunk = dest_stage / ctx.pp;
    if dest_rank == ctx.pp_rank {
        local.grads.insert((dest_chunk, mb), gx);
    } else {
        ctx.world.send_tagged(
            ctx.world_rank(),
            ctx.world_rank_of(dest_rank),
            tag(TAG_BWD, dest_chunk, mb),
            gx,
        );
    }
}

/// Receive the upstream gradient for this worker's chunk `c` (global `g`).
fn recv_grad(ctx: &WorkerCtx, local: &mut LocalChannels, g: usize, mb: usize) -> Vec<f32> {
    let chunk = g / ctx.pp;
    let src_rank = (g + 1) % ctx.pp;
    if src_rank == ctx.pp_rank {
        local.grads.remove(&(chunk, mb)).expect("local gradient present")
    } else {
        ctx.world.recv_tagged(
            ctx.world_rank(),
            ctx.world_rank_of(src_rank),
            tag(TAG_BWD, chunk, mb),
        )
    }
}

/// Worker main loop.
pub fn run(ctx: WorkerCtx) -> Result<()> {
    let meta = &ctx.bundle.meta;
    let k = ctx.k();
    let single = k == 1;
    let dims = ctx.bundle.dims();
    // chunk 0 of rank 0 embeds; chunk v-1 of rank pp-1 computes the loss
    let owns_embed = ctx.pp_rank == 0;
    let owns_head = ctx.pp_rank == ctx.pp - 1;

    // this shard's tensor-parallel communicator (no-op when tp = 1)
    let comm = TpComm::new(ctx.tp_group.clone(), ctx.world_rank());

    // ---- per-chunk slots: stage executables, params, optimizer ----
    // tp = 1 borrows the bundle's dense stages; tp > 1 derives this
    // shard's view of each hosted chunk (builtin backend only)
    let owned_shards: Vec<StageExecutables> = if ctx.tp > 1 {
        (0..ctx.v)
            .map(|c| ctx.bundle.stages[ctx.global(c)].tp_shard(ctx.tp, ctx.tp_rank))
            .collect::<Result<Vec<_>>>()?
    } else {
        Vec::new()
    };
    let stages: Vec<&StageExecutables> = if ctx.tp > 1 {
        owned_shards.iter().collect()
    } else {
        (0..ctx.v).map(|c| &ctx.bundle.stages[ctx.global(c)]).collect()
    };
    let mut params: Vec<Vec<f32>> = Vec::with_capacity(ctx.v);
    let mut opts: Vec<DistOptimizer> = Vec::with_capacity(ctx.v);
    for stage in &stages {
        // parameter init: identical across DP replicas and across pipeline
        // partitions (init keys fold in GLOBAL layer indices on both
        // backends, so the key is the same for every partitioning); TP
        // shards slice the same dense component streams
        let p = stage.init_params(ctx.cfg.seed)?;
        anyhow::ensure!(
            p.len() as u64 == stage.meta.param_count,
            "init size mismatch on stage {}",
            stage.meta.index
        );
        opts.push(DistOptimizer::new(
            ctx.cfg.zero1,
            ctx.cfg.adam,
            p.len(),
            ctx.dp_rank,
            ctx.dp,
        ));
        params.push(p);
    }

    // ---- checkpoint resume: params (shared) + this rank's opt state ----
    if ctx.cfg.resume {
        let dir = ctx.cfg.checkpoint_dir.as_ref().expect("validated by leader");
        for (c, stage) in stages.iter().enumerate() {
            let g = ctx.global(c);
            let (p, _) =
                checkpoint::read_f32(&checkpoint::params_path(dir, g, ctx.tp_rank))?;
            anyhow::ensure!(
                p.len() as u64 == stage.meta.param_count,
                "checkpoint params size mismatch on stage {g}"
            );
            params[c] = p;
            let (state, t) = checkpoint::read_f32(&checkpoint::opt_path(
                dir,
                g,
                ctx.tp_rank,
                ctx.dp_rank,
            ))?;
            opts[c].import_state(&state, t);
        }
    }

    // ---- data: embed and head owners draw the SAME dp-sharded stream ----
    let mut stream = (owns_embed || owns_head).then(|| {
        BatchStream::new(
            meta.model.vocab as u32,
            ctx.cfg.seed ^ 0xDA7A,
            ctx.dp_rank,
            ctx.dp,
            dims.b,
            dims.s,
        )
    });

    let m = ctx.cfg.microbatches as usize;
    let mut grad_accum: Vec<Vec<f32>> =
        params.iter().map(|p| vec![0.0f32; p.len()]).collect();
    // per-(chunk, micro-batch) stash: stage input activations
    // (checkpointing: inputs only); token/target rows for the boundary
    // chunks
    let mut stash_x: Vec<Vec<Option<Vec<f32>>>> = vec![vec![None; m]; ctx.v];
    let mut stash_tok: Vec<Option<Vec<i32>>> = vec![None; m];
    let mut stash_tgt: Vec<Option<Vec<i32>>> = vec![None; m];
    let mut local = LocalChannels::default();

    // fast-forward the data stream past already-trained steps
    if ctx.start_step > 0 {
        if let Some(stream) = stream.as_mut() {
            stream.skip_microbatches(ctx.start_step as usize * m);
        }
    }

    for rel_step in 0..ctx.cfg.steps {
        let step = ctx.start_step + rel_step;
        for g in grad_accum.iter_mut() {
            g.iter_mut().for_each(|x| *x = 0.0);
        }
        let mut loss_sum = 0.0f32;

        // draw this step's micro-batches up front (the schedule issues
        // each chunk's forwards in order, so index mb matches draw order)
        if let Some(stream) = stream.as_mut() {
            for mb in 0..m {
                let batch = stream.next_microbatch();
                if owns_embed {
                    stash_tok[mb] = Some(batch.tokens.clone());
                }
                if owns_head {
                    stash_tgt[mb] = Some(batch.targets);
                }
            }
        }

        // upload each chunk's parameter vector ONCE per step; every
        // micro-batch's fwd/bwd reuses the same handle (EXPERIMENTS.md
        // §Perf)
        let mut handles: Vec<ParamsHandle> = Vec::with_capacity(ctx.v);
        for (stage, p) in stages.iter().zip(&params) {
            handles.push(stage.prepare_params(&ctx.rt, p)?);
        }

        for op in &ctx.sched.streams[ctx.pp_rank] {
            let c = op.chunk() as usize;
            let g = ctx.global(c);
            let stage = stages[c];
            let pbuf = &handles[c];
            match *op {
                Op::Forward { mb, .. } => {
                    let mb = mb as usize;
                    if single {
                        // single-stage: fwd is folded into bwd; nothing to do
                        continue;
                    }
                    if g == 0 {
                        let tokens = stash_tok[mb].as_ref().unwrap();
                        let y = stage.fwd_first(&ctx.rt, pbuf, &comm, tokens, dims)?;
                        send_act(&ctx, &mut local, g, mb, y);
                    } else if g == k - 1 {
                        // head chunk: stash the incoming activation; the
                        // loss + grads come from the backward entry point
                        let x = recv_act(&ctx, &mut local, g, mb);
                        stash_x[c][mb] = Some(x);
                    } else {
                        let x = recv_act(&ctx, &mut local, g, mb);
                        let y = stage.fwd_mid(&ctx.rt, pbuf, &comm, &x, dims)?;
                        stash_x[c][mb] = Some(x);
                        send_act(&ctx, &mut local, g, mb, y);
                    }
                }
                Op::Backward { mb, .. } => {
                    let mb = mb as usize;
                    if single {
                        // fused fwd+bwd: (flat, tokens, targets) -> (gflat, loss)
                        let tokens = stash_tok[mb].take().unwrap();
                        let targets = stash_tgt[mb].take().unwrap();
                        let (gp, loss) =
                            stage.bwd_single(&ctx.rt, pbuf, &comm, &tokens, &targets, dims)?;
                        accumulate(&mut grad_accum[c], &gp);
                        loss_sum += loss;
                    } else if g == k - 1 {
                        let x = stash_x[c][mb].take().unwrap();
                        let targets = stash_tgt[mb].take().unwrap();
                        let (gp, gx, loss) =
                            stage.bwd_last(&ctx.rt, pbuf, &comm, &x, &targets, dims)?;
                        accumulate(&mut grad_accum[c], &gp);
                        loss_sum += loss;
                        send_grad(&ctx, &mut local, g, mb, gx);
                    } else if g == 0 {
                        let gy = recv_grad(&ctx, &mut local, g, mb);
                        let tokens = stash_tok[mb].take().unwrap();
                        let gp = stage.bwd_first(&ctx.rt, pbuf, &comm, &tokens, &gy, dims)?;
                        accumulate(&mut grad_accum[c], &gp);
                    } else {
                        let gy = recv_grad(&ctx, &mut local, g, mb);
                        let x = stash_x[c][mb].take().unwrap();
                        let (gp, gx) = stage.bwd_mid(&ctx.rt, pbuf, &comm, &x, &gy, dims)?;
                        accumulate(&mut grad_accum[c], &gp);
                        send_grad(&ctx, &mut local, g, mb, gx);
                    }
                }
            }
        }

        // gradient accumulation: mean over micro-batches
        let inv_m = 1.0 / m as f32;
        for g in grad_accum.iter_mut() {
            g.iter_mut().for_each(|x| *x *= inv_m);
        }

        // TP grad sync: mean-reduce the replicated-parameter gradients
        // (the row-parallel bias) across the TP group before the
        // optimizer step.  They are identical across shards by
        // construction — the sync pins that invariant against drift.
        // Sharded parameters are disjoint per shard and need no sync.
        if ctx.tp > 1 {
            let inv_tp = 1.0 / ctx.tp as f32;
            for c in 0..ctx.v {
                if let Some((lo, hi)) = stages[c].tp_replicated_span() {
                    comm.all_reduce_sum(&mut grad_accum[c][lo..hi]);
                    grad_accum[c][lo..hi].iter_mut().for_each(|x| *x *= inv_tp);
                }
            }
        }

        // DP sync + (sharded) optimizer step, chunk by chunk (every rank
        // of a DP row walks its chunks in the same order, so the
        // per-chunk collective rounds line up)
        let lr_scale = ctx
            .cfg
            .lr_schedule
            .map(|sch| sch.scale(step as u64))
            .unwrap_or(1.0);
        // combined pre-clip norm over every chunk this worker hosts (a
        // single chunk's spike must not be masked by the last chunk's)
        let mut grad_norm_sq = 0.0f32;
        for c in 0..ctx.v {
            // under TP the clip norm combines across the tensor group
            // (replicated span counted once) — dense-equivalent clipping
            let tp_ctx = stages[c].tp_replicated_span().map(|span| (&comm, span));
            let norm = opts[c].step(
                &ctx.dp_group,
                ctx.dp_rank,
                &mut params[c],
                &mut grad_accum[c],
                lr_scale,
                tp_ctx,
            );
            grad_norm_sq += norm * norm;
        }
        let grad_norm = grad_norm_sq.sqrt();

        // periodic checkpoint: every rank persists its own pieces after a
        // world barrier (so all stages are at the same step).  Files are
        // keyed (global stage, tp rank): each tensor shard's dp-rank-0
        // worker writes that shard's params; every rank writes its own
        // optimizer state; pp0/dp0/tp0 writes the manifest.
        let every = ctx.cfg.checkpoint_every;
        let last_step = rel_step + 1 == ctx.cfg.steps;
        if let Some(dir) = ctx.cfg.checkpoint_dir.as_ref() {
            if (every > 0 && (rel_step + 1) % every == 0) || last_step {
                ctx.world.barrier(ctx.world_rank());
                for c in 0..ctx.v {
                    let g = ctx.global(c);
                    if ctx.dp_rank == 0 {
                        checkpoint::write_f32(
                            &checkpoint::params_path(dir, g, ctx.tp_rank),
                            &params[c],
                            (step + 1) as u64,
                        )?;
                    }
                    let (state, t) = opts[c].export_state();
                    checkpoint::write_f32(
                        &checkpoint::opt_path(dir, g, ctx.tp_rank, ctx.dp_rank),
                        &state,
                        t,
                    )?;
                }
                ctx.world.barrier(ctx.world_rank());
                if ctx.pp_rank == 0 && ctx.dp_rank == 0 && ctx.tp_rank == 0 {
                    checkpoint::Manifest {
                        step: step + 1,
                        bundle: ctx.cfg.bundle.clone(),
                        stages: ctx.k() as u32,
                        tp: ctx.tp as u32,
                        dp: ctx.dp as u32,
                        zero1: ctx.cfg.zero1,
                    }
                    .save(dir)?;
                }
            }
        }

        // loss reporting: mean across micro-batches, then across DP
        if owns_head {
            let mut l = vec![loss_sum * inv_m];
            ctx.dp_group
                .all_reduce_sum(ctx.dp_rank, &mut l, crate::collectives::Algo::Naive);
            let mean_loss = l[0] / ctx.dp as f32;
            if let Some(tx) = &ctx.loss_tx {
                tx.send((step, mean_loss, grad_norm))
                    .map_err(|_| anyhow!("leader hung up"))?;
            }
        }
    }
    Ok(())
}

fn accumulate(acc: &mut [f32], g: &[f32]) {
    debug_assert_eq!(acc.len(), g.len());
    for (a, &v) in acc.iter_mut().zip(g.iter()) {
        *a += v;
    }
}
