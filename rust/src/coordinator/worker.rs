//! Worker thread: one simulated GCD executing its instruction stream over
//! `v` virtual-stage chunk slots against the stage backends (PJRT
//! executables or builtin reference stages), as one shard of its
//! tensor-parallel group.
//!
//! Chunk `c` of worker `r` is global stage `g = c * pp + r`; activations
//! flow `g -> g+1` (worker `(r+1) % pp`), gradients `g -> g-1`.  Because
//! several chunk channels share each (from, to) worker mailbox, every
//! message is tagged with `(direction, destination chunk, micro-batch)`;
//! with `pp = 1` the chunk boundary stays worker-local and skips the
//! mailboxes entirely.  Cross-worker boundary payloads ride the engine's
//! wire dtype: under bf16 the (grid-constrained) activations pack two
//! values per lane — half the p2p bytes, bit-lossless, counted into
//! `pp_payload_bytes` and pinned against the analytic PP p2p term.
//!
//! With `tp > 1` the worker is one of `tp` shard threads of a pipeline
//! cell: it executes the SAME instruction stream as its TP siblings
//! (SPMD), each op's per-layer all-reduces running inside the sharded
//! stage entry points through `TpComm`.
//!
//! **Backward-overlapped gradient sync** (the paper's §IV DeepSpeed
//! lever, executed for real): each chunk counts down its micro-batch
//! backwards; the moment the last one completes, the chunk's gradient
//! is finalised (1/m scale + TP replicated-span sync) and split into
//! nonblocking buckets on the DP group, which reduce under whatever
//! backward compute is still in flight.  The handles drain just before
//! the optimizer step.  Under sharding stages 0/1 the buckets are
//! all-reduces (every rank drains the full reduced buffer); under
//! stages 2/3 they are **partition-aligned reduce-scatter** buckets —
//! each bucket's span lies wholly inside one rank's `chunk_bounds`
//! partition, and only that owner materialises the reduced span, so the
//! persistent reduced gradient on a rank is its `1/dp` shard.  Both
//! shapes reduce in rank order no matter when deposits land, so
//! overlapped ≡ sequential stays **bit-identical** across every stage.
//!
//! **ZeRO-3 parameter lifecycle** (stage 3): each rank stores only its
//! flat parameter shard of every hosted chunk.  Around each op that
//! needs parameters, the full vector is assembled by a nonblocking DP
//! all-gather — launched `--zero3-prefetch` param-using ops ahead,
//! redeemed zero-copy as the op's parameter view, and dropped right
//! after the op — so peak full-parameter residency is `(N+1)` gathered
//! chunks, never the worker's whole model share (`ag_peak_floats`
//! records the high-water mark the mem tests validate).  The optimizer
//! then steps the shard in place; no post-step gather exists.  Under
//! `--nodes` the gathers split into an inter-node primary on first
//! touch plus node-local secondary gathers after (ZeRO++ hpZ).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::collectives::{
    chunk_bounds, GatherHandle, Group, Payload, ReduceHandle, ScatterHandle, SubGroup, TpComm,
};
use crate::data::BatchStream;
use crate::moe::{MoeA2a, MoeFwdCtx};
use crate::precision::{pack_bf16, unpack_bf16, Dtype, GradWire, LossScaler};
use crate::runtime::{Bundle, BuiltinSpec, ParamsHandle, Runtime, StageExecutables};
use crate::schedule::{Op, Schedule};
use crate::topology::packed_gpu_of;
use crate::trace::{self, Category};
use crate::zero::DistOptimizer;

use super::{checkpoint, EngineConfig, FaultSpec, KilledByFault};

/// Everything a worker needs; handed over at spawn.
pub struct WorkerCtx {
    pub cfg: EngineConfig,
    pub rt: Arc<Runtime>,
    pub bundle: Arc<Bundle>,
    pub sched: Arc<Schedule>,
    pub world: Arc<Group>,
    /// This worker's tensor-parallel subgroup (its pp×dp cell).
    pub tp_group: Arc<SubGroup>,
    pub dp_group: Arc<Group>,
    /// Expert-parallel group — this worker's block of `ep` consecutive
    /// DP replicas at its (pp, tp) cell, carrying the token-routing
    /// `all_to_all`.  `None` on dense runs, at `ep = 1`, or on an
    /// elastic leg whose dp broke the divisibility (rank-local routing).
    pub ep_group: Option<Arc<Group>>,
    /// Rank within `ep_group` (`dp_rank % ep`; 0 when `None`).
    pub ep_rank: usize,
    /// World-shared dropped-token counter: each (pp, dp) cell's tp=0
    /// shard charges its MoE capacity drops once per scheduled block
    /// forward (TP shards route identically — one count per cell).
    pub moe_dropped: Arc<AtomicU64>,
    pub pp_rank: usize,
    pub dp_rank: usize,
    pub tp_rank: usize,
    /// Pipeline ranks (worker grid depth).
    pub pp: usize,
    pub dp: usize,
    /// Tensor-parallel shards per pipeline cell.
    pub tp: usize,
    /// Virtual chunks hosted by this worker (global stages = pp * v).
    pub v: usize,
    /// First step index (non-zero when resuming from a checkpoint).
    pub start_step: u32,
    /// Loss-scaler state to start from (the checkpointed scale on
    /// resume, `cfg.loss_scale_init` otherwise).
    pub start_loss_scale: f32,
    pub start_scale_good: u32,
    /// dp the checkpoint being resumed was written at (== `dp` when not
    /// resuming).  When it differs, the resume path re-partitions the
    /// optimizer shards across the new dp (`checkpoint::reslice_opt_state`)
    /// — the elastic dp±1 reconfiguration.
    pub ckpt_dp: usize,
    /// The verified committed generation directory resume files load
    /// from (`None` when not resuming).
    pub ckpt_from: Option<std::path::PathBuf>,
    /// Shared save state (timers, retrying writer, injected write-fail
    /// budget) when `checkpoint_dir` is set.
    pub save: Option<Arc<checkpoint::SaveCtx>>,
    /// Snapshot hand-off to the background saver thread under
    /// `--async-checkpoint`; `None` puts saves inline on the sync path.
    pub save_tx: Option<mpsc::Sender<checkpoint::SavePart>>,
    /// Per-rank resident optimizer-state bytes, reported back to the
    /// leader (max over workers) — the measured shard-bytes figure the
    /// examples print.
    pub opt_state_bytes: Arc<AtomicU64>,
    /// Only the (last-rank, dp=0, tp=0) worker reports losses:
    /// (step, loss, grad norm, post-update loss scale, skipped).
    pub loss_tx: Option<mpsc::Sender<(u32, f32, f32, f32, bool)>>,
    /// Span registry when the run traces (`--trace-out` /
    /// `--metrics-jsonl`); `None` keeps every span site a no-op.
    pub trace: Option<Arc<trace::Registry>>,
}

const TAG_FWD: u64 = 1;
const TAG_BWD: u64 = 2;

/// Per-op MoE forward context: the a2a routing handle (tag base folds
/// `(step, chunk, mb)` — 32/16/15 bits; bit 0 is reserved for the
/// dispatch/combine phase inside the stage), the activation wire dtype,
/// and the dropped-token counter (tp=0 shard only, so each (pp, dp)
/// cell charges drops exactly once per scheduled forward).  EP-group
/// members are DP replicas at the same pp_rank running the identical
/// instruction stream, so the per-op tags line up across the group —
/// including the fused forwards inside `bwd_last`/`bwd_single`.
fn moe_fwd_ctx<'a>(ctx: &'a WorkerCtx, step: u32, c: usize, mb: usize) -> MoeFwdCtx<'a> {
    assert!(c < (1 << 16) && mb < (1 << 15), "moe a2a tag field overflow");
    MoeFwdCtx {
        a2a: ctx.ep_group.as_ref().map(|g| MoeA2a {
            group: g,
            ep_rank: ctx.ep_rank,
            tag_base: ((step as u64) << 32) | ((c as u64) << 16) | ((mb as u64) << 1),
        }),
        wire: ctx.cfg.precision,
        dropped: (ctx.tp_rank == 0).then(|| &*ctx.moe_dropped),
    }
}

fn tag(direction: u64, chunk: usize, mb: usize) -> u64 {
    (direction << 48) | ((chunk as u64) << 24) | mb as u64
}

/// In-flight DP gradient sync of one chunk, `(span lo, span hi, handle)`
/// per bucket: all-reduce buckets under stages 0/1 (every rank redeems
/// the full reduced span), partition-aligned reduce-scatter buckets
/// under stages 2/3 (only the span's owner materialises it).
enum ChunkSync {
    AllReduce(Vec<(usize, usize, ReduceHandle)>),
    ReduceScatter(Vec<(usize, usize, ScatterHandle)>),
}

/// Per-chunk gradient finalisation, run the moment the chunk's last
/// micro-batch backward completes: mean over micro-batches, then the
/// TP replicated-span mean sync (the row-parallel bias gradient is
/// identical across shards by construction — the sync pins that
/// invariant against drift; sharded parameters are disjoint per shard
/// and need no sync).
fn finalize_chunk_grads(
    grads: &mut [f32],
    inv_m: f32,
    replicated: Option<(usize, usize)>,
    comm: &TpComm,
) {
    grads.iter_mut().for_each(|x| *x *= inv_m);
    if let Some((lo, hi)) = replicated {
        let inv_tp = 1.0 / comm.tp() as f32;
        comm.all_reduce_sum(&mut grads[lo..hi]);
        grads[lo..hi].iter_mut().for_each(|x| *x *= inv_tp);
    }
}

/// Split a chunk's gradient buffer into `bucket_floats`-sized spans and
/// launch each as a nonblocking all-reduce on the DP group.  The tag
/// folds `(step, chunk, bucket)` — 32/8/24 bits — so concurrent rounds
/// never collide and no tag is reused before its round drains; the
/// field widths are enforced (not just debug-checked), since an
/// overflow would alias another chunk's round and abort the run as a
/// double deposit.
fn launch_grad_buckets(
    group: &Arc<Group>,
    rank: usize,
    step: u32,
    chunk: usize,
    grads: &[f32],
    bucket_floats: usize,
    wire: Dtype,
    hier: Option<GradWire>,
) -> Vec<(usize, usize, ReduceHandle)> {
    let bucket = bucket_floats.max(1);
    assert!(chunk < (1 << 8), "chunk {chunk} overflows the bucket-tag field");
    let n_buckets = grads.len().div_ceil(bucket);
    assert!(
        n_buckets < (1 << 24),
        "grad_bucket_floats {bucket_floats} yields {n_buckets} buckets (tag field is 24 bits)"
    );
    let mut out = Vec::with_capacity(n_buckets);
    let mut lo = 0;
    while lo < grads.len() {
        let hi = (lo + bucket).min(grads.len());
        let tag = ((step as u64) << 32) | ((chunk as u64) << 24) | out.len() as u64;
        let h = match hier {
            Some(gw) => {
                group.start_all_reduce_hier(rank, tag, grads[lo..hi].to_vec(), wire, gw)
            }
            None => group.start_all_reduce_dtype(rank, tag, grads[lo..hi].to_vec(), wire),
        };
        out.push((lo, hi, h));
        lo = hi;
    }
    out
}

/// The stage-2/3 counterpart of [`launch_grad_buckets`]: split the
/// buffer along the DP partition FIRST (`chunk_bounds`), then bucket
/// within each owner's range, so every bucket has exactly one owner and
/// the drained shards tile this rank's partition.  Same tag layout,
/// bucket index counted across owners.
fn launch_rs_buckets(
    group: &Arc<Group>,
    rank: usize,
    step: u32,
    chunk: usize,
    grads: &[f32],
    bucket_floats: usize,
    wire: Dtype,
    hier: Option<GradWire>,
) -> Vec<(usize, usize, ScatterHandle)> {
    let bucket = bucket_floats.max(1);
    assert!(chunk < (1 << 8), "chunk {chunk} overflows the bucket-tag field");
    let bounds = chunk_bounds(grads.len(), group.len());
    let n_buckets: usize = bounds.iter().map(|(lo, hi)| (hi - lo).div_ceil(bucket)).sum();
    assert!(
        n_buckets < (1 << 24),
        "grad_bucket_floats {bucket_floats} yields {n_buckets} buckets (tag field is 24 bits)"
    );
    let mut out = Vec::with_capacity(n_buckets);
    for (owner, &(olo, ohi)) in bounds.iter().enumerate() {
        let mut lo = olo;
        while lo < ohi {
            let hi = (lo + bucket).min(ohi);
            let tag = ((step as u64) << 32) | ((chunk as u64) << 24) | out.len() as u64;
            let h = match hier {
                Some(gw) => group.start_reduce_scatter_hier(
                    rank,
                    tag,
                    grads[lo..hi].to_vec(),
                    owner,
                    wire,
                    gw,
                ),
                None => {
                    group.start_reduce_scatter_dtype(rank, tag, grads[lo..hi].to_vec(), owner, wire)
                }
            };
            out.push((lo, hi, h));
            lo = hi;
        }
    }
    out
}

/// Finalize chunk `c`'s gradient ([`finalize_chunk_grads`]) and launch
/// its DP buckets — all-reduce or partition-aligned reduce-scatter,
/// per the run's sharding stage — charging the launch time to the
/// hidden (mid-stream) or exposed (post-stream) timer; the single
/// definition both call sites share so the hidden/exposed split cannot
/// drift.
#[allow(clippy::too_many_arguments)]
fn finalize_and_launch(
    ctx: &WorkerCtx,
    comm: &TpComm,
    stage: &StageExecutables,
    grads: &mut [f32],
    inv_m: f32,
    step: u32,
    c: usize,
    hidden: bool,
) -> ChunkSync {
    finalize_chunk_grads(grads, inv_m, stage.tp_replicated_span(), comm);
    if ctx.dp == 1 {
        return ChunkSync::AllReduce(Vec::new());
    }
    // the op names are load-bearing: trace::Registry::summarize
    // classifies dp overlap from them (hidden launches vs exposed
    // launches + drains), cross-checked against the timers below
    let _s = trace::span_cm(
        Category::DpSync,
        if hidden { "dp_launch_hidden" } else { "dp_launch_exposed" },
        c as u32,
        trace::TAG_NONE,
    );
    let t0 = Instant::now();
    // topology-aware runs route every bucket through the two-tier path,
    // the configured grad wire shaping only the inter-node hop
    let hier = ctx.cfg.hier().then(|| ctx.cfg.effective_grad_wire());
    let sync = if ctx.cfg.zero_stage.shards_grads() {
        ChunkSync::ReduceScatter(launch_rs_buckets(
            &ctx.dp_group,
            ctx.dp_rank,
            step,
            c,
            grads,
            ctx.cfg.grad_bucket_floats,
            ctx.cfg.precision,
            hier,
        ))
    } else {
        ChunkSync::AllReduce(launch_grad_buckets(
            &ctx.dp_group,
            ctx.dp_rank,
            step,
            c,
            grads,
            ctx.cfg.grad_bucket_floats,
            ctx.cfg.precision,
            hier,
        ))
    };
    let counter = if hidden { &ctx.dp_group.nb_hidden_ns } else { &ctx.dp_group.nb_exposed_ns };
    counter.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    sync
}

impl WorkerCtx {
    /// Megatron rank order, TP innermost.
    fn world_rank(&self) -> usize {
        (self.pp_rank * self.dp + self.dp_rank) * self.tp + self.tp_rank
    }

    /// World rank of the same (dp, tp) coordinates on another pipeline
    /// cell — the p2p peer for activations/gradients.
    fn world_rank_of(&self, pp_rank: usize) -> usize {
        (pp_rank * self.dp + self.dp_rank) * self.tp + self.tp_rank
    }

    /// Total global (virtual) stages.
    fn k(&self) -> usize {
        self.pp * self.v
    }

    /// Global stage of chunk `c` on this worker.
    fn global(&self, chunk: usize) -> usize {
        chunk * self.pp + self.pp_rank
    }
}

/// Worker-local routing state: in-flight self-delivered chunk boundaries
/// (only reachable when `pp == 1`).
#[derive(Default)]
struct LocalChannels {
    acts: HashMap<(usize, usize), Vec<f32>>,
    grads: HashMap<(usize, usize), Vec<f32>>,
}

/// Wire-cast a boundary activation/gradient for a cross-worker p2p send:
/// bf16 packs the (grid-constrained) values two per lane — half the
/// bytes, bit-lossless on unpack.  Counts the send's logical payload
/// (`elements × wire width`) into the world group's `pp_payload_bytes`;
/// under `--nodes` the same bytes are additionally classified per tier
/// (`pp_intra_bytes` / `pp_inter_bytes`) by the packed placement of the
/// two endpoints.
fn p2p_pack(ctx: &WorkerCtx, dest_rank: usize, data: Vec<f32>) -> Vec<f32> {
    let bytes = ctx.cfg.precision.bytes() * data.len() as u64;
    ctx.world.pp_payload_bytes.fetch_add(bytes, Ordering::Relaxed);
    if ctx.cfg.hier() {
        let world = (ctx.pp * ctx.dp * ctx.tp) as u32;
        let src = packed_gpu_of(world, ctx.cfg.nodes, ctx.world_rank() as u32);
        let dst = packed_gpu_of(world, ctx.cfg.nodes, dest_rank as u32);
        let tier = if src / crate::topology::GPUS_PER_NODE == dst / crate::topology::GPUS_PER_NODE
        {
            &ctx.world.pp_intra_bytes
        } else {
            &ctx.world.pp_inter_bytes
        };
        tier.fetch_add(bytes, Ordering::Relaxed);
    }
    match ctx.cfg.precision {
        Dtype::F32 => data,
        Dtype::Bf16 => pack_bf16(&data),
    }
}

/// Inverse of [`p2p_pack`] on the receive side; boundary payloads are
/// always full `b × s × d` activations, so the unpacked length is fixed.
fn p2p_unpack(ctx: &WorkerCtx, data: Vec<f32>) -> Vec<f32> {
    match ctx.cfg.precision {
        Dtype::F32 => data,
        Dtype::Bf16 => {
            let dims = ctx.bundle.dims();
            unpack_bf16(&data, dims.b * dims.s * dims.d)
        }
    }
}

/// Send the forward activation of global stage `g` downstream.
fn send_act(ctx: &WorkerCtx, local: &mut LocalChannels, g: usize, mb: usize, y: Vec<f32>) {
    let dest_stage = g + 1;
    let dest_rank = dest_stage % ctx.pp;
    let dest_chunk = dest_stage / ctx.pp;
    if dest_rank == ctx.pp_rank {
        local.acts.insert((dest_chunk, mb), y);
    } else {
        let _s = trace::span_cm(Category::PpP2p, "send_act", dest_chunk as u32, mb as u32);
        let dest = ctx.world_rank_of(dest_rank);
        let payload = p2p_pack(ctx, dest, y);
        ctx.world.send_tagged(ctx.world_rank(), dest, tag(TAG_FWD, dest_chunk, mb), payload);
    }
}

/// Receive the input activation for this worker's chunk `c` (global `g`).
fn recv_act(ctx: &WorkerCtx, local: &mut LocalChannels, g: usize, mb: usize) -> Vec<f32> {
    let chunk = g / ctx.pp;
    let src_rank = (g - 1) % ctx.pp;
    if src_rank == ctx.pp_rank {
        local.acts.remove(&(chunk, mb)).expect("local activation present")
    } else {
        // recv_* spans are the pipeline-stall signal: their self time is
        // the measured bubble numerator in trace::Registry::summarize
        let _s = trace::span_cm(Category::PpP2p, "recv_act", chunk as u32, mb as u32);
        let raw = ctx.world.recv_tagged(
            ctx.world_rank(),
            ctx.world_rank_of(src_rank),
            tag(TAG_FWD, chunk, mb),
        );
        p2p_unpack(ctx, raw)
    }
}

/// Send the input-gradient of global stage `g` upstream.
fn send_grad(ctx: &WorkerCtx, local: &mut LocalChannels, g: usize, mb: usize, gx: Vec<f32>) {
    let dest_stage = g - 1;
    let dest_rank = dest_stage % ctx.pp;
    let dest_chunk = dest_stage / ctx.pp;
    if dest_rank == ctx.pp_rank {
        local.grads.insert((dest_chunk, mb), gx);
    } else {
        let _s = trace::span_cm(Category::PpP2p, "send_grad", dest_chunk as u32, mb as u32);
        let dest = ctx.world_rank_of(dest_rank);
        let payload = p2p_pack(ctx, dest, gx);
        ctx.world.send_tagged(ctx.world_rank(), dest, tag(TAG_BWD, dest_chunk, mb), payload);
    }
}

/// Receive the upstream gradient for this worker's chunk `c` (global `g`).
fn recv_grad(ctx: &WorkerCtx, local: &mut LocalChannels, g: usize, mb: usize) -> Vec<f32> {
    let chunk = g / ctx.pp;
    let src_rank = (g + 1) % ctx.pp;
    if src_rank == ctx.pp_rank {
        local.grads.remove(&(chunk, mb)).expect("local gradient present")
    } else {
        let _s = trace::span_cm(Category::PpP2p, "recv_grad", chunk as u32, mb as u32);
        let raw = ctx.world.recv_tagged(
            ctx.world_rank(),
            ctx.world_rank_of(src_rank),
            tag(TAG_BWD, chunk, mb),
        );
        p2p_unpack(ctx, raw)
    }
}

/// Does this op drive a stage's compute with its parameter vector?  The
/// head chunk's Forward only stashes its incoming activation, and the
/// fused single-stage path folds Forward into Backward — neither touches
/// params.  THE single source of truth for the ZeRO-3 gather plan and
/// the op loop's gathered-view acquisition (and the predicate
/// `perf::builtin_zero3_ag_floats_per_step` mirrors analytically).
fn op_uses_params(op: &Op, single: bool, g: usize, k: usize) -> bool {
    match op {
        Op::Forward { .. } => !single && g != k - 1,
        Op::Backward { .. } => true,
    }
}

/// ZeRO-3 gather plan entry: `(chunk, direction, micro-batch)` of one
/// param-using op, in stream order.
type GatherPlanEntry = (usize, u64, u64);

/// Tag of one ZeRO-3 on-demand gather round: `(step, dir, chunk, mb)` —
/// 32/2/8/20 bits, in the gathers' own tag namespace (the `ag` map), so
/// the in-flight prefetch window can never collide across steps, chunks
/// or directions.
fn gather_tag(step: u32, dir: u64, chunk: usize, mb: u64) -> u64 {
    assert!(chunk < (1 << 8) && mb < (1 << 20), "gather tag field overflow");
    ((step as u64) << 32) | (dir << 28) | ((chunk as u64) << 20) | mb
}

/// The ZeRO-3 gather-use-drop driver for one step's op stream: walks the
/// per-step plan of param-using ops, keeps at most `--zero3-prefetch`
/// gathers in flight beyond the op being executed (the residency bound
/// is `(N+1)` gathered chunks), and tracks the full-parameter float
/// residency high-water mark (gathered buffers count from launch — the
/// assembled buffer may exist any time after — until release).
///
/// Under `--nodes` the gather tier splits ZeRO++-hpZ style: a chunk's
/// FIRST param use each step runs the hierarchical (inter-node) primary
/// all-gather, and the redeeming rank slices its node-local **secondary
/// partition** out of the assembled buffer; every LATER use that step is
/// served by a node-local gather over the secondary shards — zero
/// inter-node bytes after first touch.  Secondary shards persist for the
/// step only (the optimizer rewrites the primaries at the step boundary).
struct Zero3Gathers {
    plan: Vec<GatherPlanEntry>,
    /// `primary[i]`: plan entry `i` is its chunk's first use of the step
    /// (always `true` in flat mode — every gather is a full DP gather).
    primary: Vec<bool>,
    next_launch: usize,
    next_use: usize,
    /// One slot per launched plan entry: `Some` holds a primary gather's
    /// handle; `None` marks a secondary (node-served) entry, redeemed
    /// synchronously at acquire time.
    pending: VecDeque<Option<GatherHandle>>,
    /// Node-local secondary parameter shard per chunk (hier mode only).
    secondary: Vec<Option<Payload>>,
    live_floats: u64,
    peak_floats: u64,
}

impl Zero3Gathers {
    fn new(plan: Vec<GatherPlanEntry>, v: usize, hier: bool) -> Self {
        let mut seen = vec![false; v];
        let primary = plan
            .iter()
            .map(|&(c, _, _)| !hier || !std::mem::replace(&mut seen[c], true))
            .collect();
        Self {
            plan,
            primary,
            next_launch: 0,
            next_use: 0,
            pending: VecDeque::new(),
            secondary: vec![None; v],
            live_floats: 0,
            peak_floats: 0,
        }
    }

    /// Reset the per-step cursors (the plan itself is step-invariant;
    /// only the tags fold the step index) and drop the stale secondary
    /// shards — the optimizer just rewrote the primary partitions.
    fn begin_step(&mut self) {
        debug_assert!(self.pending.is_empty(), "gathers leaked across steps");
        self.next_launch = 0;
        self.next_use = 0;
        self.secondary.iter_mut().for_each(|s| *s = None);
    }

    fn launch_through(
        &mut self,
        ctx: &WorkerCtx,
        params: &[Arc<Vec<f32>>],
        full_len: &[usize],
        step: u32,
        upto: usize,
    ) {
        while self.next_launch < self.plan.len() && self.next_launch <= upto {
            let (c, dir, mb) = self.plan[self.next_launch];
            if self.primary[self.next_launch] {
                // the f32 deposit is the shard Arc itself — no copy (bf16
                // packs, which is itself the wire cast)
                let tag = gather_tag(step, dir, c, mb);
                let h = if ctx.cfg.hier() {
                    ctx.dp_group.start_all_gather_hier(
                        ctx.dp_rank,
                        tag,
                        params[c].clone(),
                        full_len[c],
                        ctx.cfg.precision,
                    )
                } else {
                    ctx.dp_group.start_all_gather_shared(
                        ctx.dp_rank,
                        tag,
                        params[c].clone(),
                        full_len[c],
                        ctx.cfg.precision,
                    )
                };
                self.pending.push_back(Some(h));
                self.live_floats += full_len[c] as u64;
                self.peak_floats = self.peak_floats.max(self.live_floats);
            } else {
                self.pending.push_back(None);
            }
            self.next_launch += 1;
        }
    }

    /// Full parameter view for the next param-using op (must be chunk
    /// `c`): launches up through the next `--zero3-prefetch` plan
    /// entries and redeems this op's gather zero-copy (primary) or runs
    /// the node-local secondary gather (hier, after first touch).
    fn acquire(
        &mut self,
        ctx: &WorkerCtx,
        params: &[Arc<Vec<f32>>],
        full_len: &[usize],
        step: u32,
        c: usize,
    ) -> Arc<Vec<f32>> {
        // hard assert: a plan/loop divergence here would hand the op
        // another chunk's parameters — fail loudly in release too
        assert_eq!(self.plan[self.next_use].0, c, "gather plan out of sync");
        let (_, dir, mb) = self.plan[self.next_use];
        self.launch_through(ctx, params, full_len, step, self.next_use + ctx.cfg.zero3_prefetch);
        let slot = self.pending.pop_front().expect("gather launched before use");
        self.next_use += 1;
        match slot {
            Some(h) => {
                let full = h.wait_shared();
                if ctx.cfg.hier() {
                    // hpZ first touch: persist this rank's slice of the
                    // node-local secondary partition
                    let map = ctx.dp_group.node_map().expect("hier groups carry node maps");
                    let members = map.members_of(map.node_of(ctx.dp_rank));
                    self.secondary[c] = Some(if members.len() > 1 {
                        let pos = members.iter().position(|&r| r == ctx.dp_rank).unwrap();
                        let (lo, hi) = chunk_bounds(full_len[c], members.len())[pos];
                        Arc::new(full[lo..hi].to_vec())
                    } else {
                        full.clone()
                    });
                }
                full
            }
            None => {
                // served intra-node from the secondary partition; the
                // assembled buffer is transient like any gathered view
                let shard =
                    self.secondary[c].clone().expect("secondary shard set by first touch");
                self.live_floats += full_len[c] as u64;
                self.peak_floats = self.peak_floats.max(self.live_floats);
                ctx.dp_group
                    .start_all_gather_node(
                        ctx.dp_rank,
                        gather_tag(step, dir, c, mb),
                        shard,
                        full_len[c],
                        ctx.cfg.precision,
                    )
                    .wait_shared()
            }
        }
    }

    /// Drop accounting for a gathered buffer after its op retires.
    fn release(&mut self, floats: usize) {
        self.live_floats -= floats as u64;
    }
}

/// Worker main loop.
pub fn run(ctx: WorkerCtx) -> Result<()> {
    // RAII tracer install: spans recorded anywhere on this thread land in
    // the registry; the guard flushes the buffer on every exit path
    // (clean return, Err, injected-kill, PeerLost unwind)
    let _trace = ctx.trace.as_ref().map(|r| r.install(ctx.world_rank()));
    let meta = &ctx.bundle.meta;
    let k = ctx.k();
    let single = k == 1;
    let dims = ctx.bundle.dims();
    // chunk 0 of rank 0 embeds; chunk v-1 of rank pp-1 computes the loss
    let owns_embed = ctx.pp_rank == 0;
    let owns_head = ctx.pp_rank == ctx.pp - 1;

    // sharding-stage dataflow switches (both degenerate at dp = 1, where
    // a rank's partition IS the full buffer and no wire moves)
    let rs_flow = ctx.cfg.zero_stage.shards_grads() && ctx.dp > 1;
    let z3_flow = ctx.cfg.zero_stage.shards_params() && ctx.dp > 1;

    // this shard's tensor-parallel communicator (no-op when tp = 1),
    // carrying the run's wire dtype (bf16 payloads pack half-width) and
    // collective algorithm for its all-reduces
    let comm = TpComm::new(ctx.tp_group.clone(), ctx.world_rank())
        .with_wire(ctx.cfg.precision)
        .with_algo(ctx.cfg.collective_algo);

    // dynamic loss scaling: live whenever the run is mixed-precision or
    // an explicit scale was requested — including a non-unit scale
    // restored from a checkpoint manifest (a resume must keep unscaling
    // even if the resuming config omitted --loss-scale); fully inert (no
    // extra collectives, no extra float ops) on the default fp32 path,
    // which must stay bitwise-identical to the pre-mixed-precision engine
    let scaling_active = ctx.cfg.precision != Dtype::F32
        || ctx.cfg.loss_scale_init != 1.0
        || ctx.start_loss_scale != 1.0
        || ctx.cfg.loss_scale_growth_interval > 0;
    let mut scaler = LossScaler::with_state(
        ctx.start_loss_scale,
        ctx.cfg.loss_scale_growth_interval,
        ctx.start_scale_good,
    );

    // ---- per-chunk slots: stage executables, params, optimizer ----
    // tp = 1 borrows the bundle's dense stages; tp > 1 derives this
    // shard's view of each hosted chunk (builtin backend only)
    let owned_shards: Vec<StageExecutables> = if ctx.tp > 1 {
        (0..ctx.v)
            .map(|c| ctx.bundle.stages[ctx.global(c)].tp_shard(ctx.tp, ctx.tp_rank))
            .collect::<Result<Vec<_>>>()?
    } else {
        Vec::new()
    };
    let stages: Vec<&StageExecutables> = if ctx.tp > 1 {
        owned_shards.iter().collect()
    } else {
        (0..ctx.v).map(|c| &ctx.bundle.stages[ctx.global(c)]).collect()
    };
    // FULL (TP-shard) parameter counts per hosted chunk, and this rank's
    // DP-partition range of each — the flat ownership map every sharded
    // stage slices by
    let full_len: Vec<usize> = stages.iter().map(|s| s.meta.param_count as usize).collect();
    let shard_bounds: Vec<(usize, usize)> =
        full_len.iter().map(|&n| chunk_bounds(n, ctx.dp)[ctx.dp_rank]).collect();

    // parameters live behind `Arc`s so the per-step handle staging is
    // zero-copy (the builtin backend clones the Arc, not the buffer);
    // the optimizer mutates through `Arc::make_mut` after the handles
    // drop, so copy-on-write never triggers on stages 0-2.  Under
    // ZeRO-3 the stored vector is this rank's shard, deposited by Arc
    // into the gather rounds — a lagging peer's un-retired round can
    // briefly pin the old buffer, in which case make_mut copies the
    // shard once (values stay correct either way: assembly reads the
    // pre-step deposits).
    let mut params: Vec<Arc<Vec<f32>>> = Vec::with_capacity(ctx.v);
    let mut opts: Vec<DistOptimizer> = Vec::with_capacity(ctx.v);
    for (c, stage) in stages.iter().enumerate() {
        // parameter init: identical across DP replicas and across pipeline
        // partitions (init keys fold in GLOBAL layer indices on both
        // backends, so the key is the same for every partitioning); TP
        // shards slice the same dense component streams; ZeRO-3 keeps
        // only this rank's flat range of the (transient) full init
        let p = stage.init_params(ctx.cfg.seed)?;
        anyhow::ensure!(
            p.len() == full_len[c],
            "init size mismatch on stage {}",
            stage.meta.index
        );
        opts.push(DistOptimizer::new(
            ctx.cfg.zero_stage,
            ctx.cfg.adam,
            p.len(),
            ctx.dp_rank,
            ctx.dp,
            ctx.cfg.collective_algo,
            ctx.cfg.precision,
        ));
        let stored = if z3_flow {
            let (lo, hi) = shard_bounds[c];
            p[lo..hi].to_vec()
        } else {
            p
        };
        params.push(Arc::new(stored));
    }

    // ---- checkpoint resume: params (shared) + this rank's opt state ----
    if ctx.cfg.resume {
        // the coordinator resolved (and verified) the newest committed
        // generation; every rank loads from that same directory
        let dir = ctx.ckpt_from.as_ref().expect("resolved by leader");
        for c in 0..ctx.v {
            let g = ctx.global(c);
            let (p, _) =
                checkpoint::read_f32(&checkpoint::params_path(dir, g, ctx.tp_rank))?;
            anyhow::ensure!(
                p.len() == full_len[c],
                "checkpoint params size mismatch on stage {g}"
            );
            params[c] = Arc::new(if z3_flow {
                let (lo, hi) = shard_bounds[c];
                p[lo..hi].to_vec()
            } else {
                p
            });
            // optimizer state: same-dp resumes read this rank's own shard
            // file back; a dp change re-partitions.  Stage 0 keeps FULL
            // identical state on every rank, so any rank count resumes
            // from dp-rank 0's file; stages 1+ reassemble the old shards
            // and re-slice onto the new 1/dp partition.
            let (state, t) = if ctx.ckpt_dp == ctx.dp {
                checkpoint::read_f32(&checkpoint::opt_path(dir, g, ctx.tp_rank, ctx.dp_rank))?
            } else if !ctx.cfg.zero_stage.shards_optimizer() {
                checkpoint::read_f32(&checkpoint::opt_path(dir, g, ctx.tp_rank, 0))?
            } else {
                checkpoint::reslice_opt_state(
                    dir,
                    g,
                    ctx.tp_rank,
                    ctx.ckpt_dp,
                    ctx.dp,
                    ctx.dp_rank,
                    full_len[c],
                )?
            };
            opts[c].import_state(&state, t);
        }
    }

    // ---- data: embed and head owners draw the SAME dp-sharded stream ----
    let mut stream = (owns_embed || owns_head).then(|| {
        BatchStream::new(
            meta.model.vocab as u32,
            ctx.cfg.seed ^ 0xDA7A,
            ctx.dp_rank,
            ctx.dp,
            dims.b,
            dims.s,
        )
    });

    let m = ctx.cfg.microbatches as usize;
    let inv_m = 1.0 / m as f32;
    // overlap only exists with a DP group to sync against
    let overlap = ctx.cfg.overlap_grad_sync && ctx.dp > 1;
    // full-length local accumulation buffers (backward always produces
    // full local gradients; sharding bites at the REDUCED gradient)
    let mut grad_accum: Vec<Vec<f32>> =
        full_len.iter().map(|&n| vec![0.0f32; n]).collect();
    // stages 2/3: the reduce-scattered shard each drain deposits into —
    // the only reduced gradient this rank ever materialises
    let mut red_grads: Vec<Vec<f32>> = if rs_flow {
        shard_bounds.iter().map(|&(lo, hi)| vec![0.0f32; hi - lo]).collect()
    } else {
        Vec::new()
    };
    // per-(chunk, micro-batch) stash: stage input activations
    // (checkpointing: inputs only); token/target rows for the boundary
    // chunks
    let mut stash_x: Vec<Vec<Option<Vec<f32>>>> = vec![vec![None; m]; ctx.v];
    let mut stash_tok: Vec<Option<Vec<i32>>> = vec![None; m];
    let mut stash_tgt: Vec<Option<Vec<i32>>> = vec![None; m];
    let mut local = LocalChannels::default();

    // ZeRO-3: the step-invariant plan of param-using ops, in stream
    // order — the head chunk's Forward only stashes its input and the
    // fused single-stage path folds Forward into Backward, so neither
    // gathers
    let mut z3 = z3_flow.then(|| {
        let plan: Vec<GatherPlanEntry> = ctx.sched.streams[ctx.pp_rank]
            .iter()
            .filter_map(|op| {
                let c = op.chunk() as usize;
                let g = ctx.global(c);
                let dir = if op.is_forward() { TAG_FWD } else { TAG_BWD };
                op_uses_params(op, single, g, k).then_some((c, dir, op.mb() as u64))
            })
            .collect();
        Zero3Gathers::new(plan, ctx.v, ctx.cfg.hier())
    });

    // fast-forward the data stream past already-trained steps
    if ctx.start_step > 0 {
        if let Some(stream) = stream.as_mut() {
            stream.skip_microbatches(ctx.start_step as usize * m);
        }
    }

    for rel_step in 0..ctx.cfg.steps {
        let step = ctx.start_step + rel_step;
        trace::step_mark(step);
        // deterministic fault injection: die at the top of the step,
        // before any collective — the step boundary is the only point
        // where a death can never tear a checkpoint (saves are barrier-
        // bracketed at the END of a step).  Peers hit the comm deadline
        // (PeerLost) and the coordinator shrinks the world.
        for f in &ctx.cfg.faults {
            if let FaultSpec::Kill { step: ks, rank } = *f {
                if step == ks && ctx.world_rank() == rank {
                    return Err(anyhow::Error::new(KilledByFault { step: ks, rank }));
                }
            }
        }
        for g in grad_accum.iter_mut() {
            g.iter_mut().for_each(|x| *x = 0.0);
        }
        let mut loss_sum = 0.0f32;
        // the loss scale applied to this step's backward (a power of two,
        // so scaling is exact; 1.0 keeps the multiplies skipped entirely)
        let scale = scaler.scale();
        // per-chunk backward countdown + this step's in-flight buckets
        let mut bwd_left: Vec<usize> = vec![m; ctx.v];
        let mut syncs: Vec<ChunkSync> =
            (0..ctx.v).map(|_| ChunkSync::AllReduce(Vec::new())).collect();
        let mut finalized = vec![false; ctx.v];
        if let Some(z) = z3.as_mut() {
            z.begin_step();
        }

        // draw this step's micro-batches up front (the schedule issues
        // each chunk's forwards in order, so index mb matches draw order)
        if let Some(stream) = stream.as_mut() {
            for mb in 0..m {
                let batch = stream.next_microbatch();
                if owns_embed {
                    stash_tok[mb] = Some(batch.tokens.clone());
                }
                if owns_head {
                    stash_tgt[mb] = Some(batch.targets);
                }
            }
        }

        // stage each chunk's parameter vector ONCE per step; every
        // micro-batch's fwd/bwd reuses the same handle (EXPERIMENTS.md
        // §Perf).  Builtin stages share the Arc — zero bytes copied.
        // Under ZeRO-3 these hold the (never-computed-on) shard; every
        // param-using op overrides them with its on-demand gathered view.
        let mut handles: Vec<ParamsHandle> = Vec::with_capacity(ctx.v);
        for (stage, p) in stages.iter().zip(&params) {
            handles.push(stage.prepare_params_shared(&ctx.rt, p)?);
        }

        for op in &ctx.sched.streams[ctx.pp_rank] {
            let c = op.chunk() as usize;
            let g = ctx.global(c);
            let stage = stages[c];
            if single && op.is_forward() {
                // single-stage: fwd is folded into bwd; nothing to do
                continue;
            }
            // ZeRO-3: assemble this op's full parameter view (prefetched
            // one param-using op ahead; dropped right after the op)
            let uses_params = op_uses_params(op, single, g, k);
            let gathered_view: ParamsHandle;
            let pbuf: &ParamsHandle = match z3.as_mut() {
                Some(z) if uses_params => {
                    let full = {
                        let _s = trace::span_cm(
                            Category::ZeroGather,
                            "z3_acquire",
                            c as u32,
                            op.mb(),
                        );
                        z.acquire(&ctx, &params, &full_len, step, c)
                    };
                    gathered_view = ParamsHandle::Host(full);
                    &gathered_view
                }
                _ => &handles[c],
            };
            match *op {
                Op::Forward { mb, .. } => {
                    let mb = mb as usize;
                    if g == 0 {
                        let tokens = stash_tok[mb].as_ref().unwrap();
                        let y = {
                            let _s =
                                trace::span_cm(Category::Compute, "fwd_first", c as u32, mb as u32);
                            stage.fwd_first_ctx(
                                &ctx.rt,
                                pbuf,
                                &comm,
                                tokens,
                                dims,
                                &moe_fwd_ctx(&ctx, step, c, mb),
                            )?
                        };
                        send_act(&ctx, &mut local, g, mb, y);
                    } else if g == k - 1 {
                        // head chunk: stash the incoming activation; the
                        // loss + grads come from the backward entry point
                        let x = recv_act(&ctx, &mut local, g, mb);
                        stash_x[c][mb] = Some(x);
                    } else {
                        let x = recv_act(&ctx, &mut local, g, mb);
                        let y = {
                            let _s =
                                trace::span_cm(Category::Compute, "fwd_mid", c as u32, mb as u32);
                            stage.fwd_mid_ctx(
                                &ctx.rt,
                                pbuf,
                                &comm,
                                &x,
                                dims,
                                &moe_fwd_ctx(&ctx, step, c, mb),
                            )?
                        };
                        stash_x[c][mb] = Some(x);
                        send_act(&ctx, &mut local, g, mb, y);
                    }
                }
                Op::Backward { mb, .. } => {
                    let mb = mb as usize;
                    if single {
                        // fused fwd+bwd: (flat, tokens, targets) -> (gflat, loss)
                        let tokens = stash_tok[mb].take().unwrap();
                        let targets = stash_tgt[mb].take().unwrap();
                        let (mut gp, loss) = {
                            let _s = trace::span_cm(
                                Category::Compute,
                                "bwd_single",
                                c as u32,
                                mb as u32,
                            );
                            stage.bwd_single_ctx(
                                &ctx.rt,
                                pbuf,
                                &comm,
                                &tokens,
                                &targets,
                                dims,
                                &moe_fwd_ctx(&ctx, step, c, mb),
                            )?
                        };
                        if scale != 1.0 {
                            gp.iter_mut().for_each(|x| *x *= scale);
                        }
                        accumulate(&mut grad_accum[c], &gp);
                        loss_sum += loss;
                    } else if g == k - 1 {
                        let x = stash_x[c][mb].take().unwrap();
                        let targets = stash_tgt[mb].take().unwrap();
                        let (mut gp, mut gx, loss) = {
                            let _s =
                                trace::span_cm(Category::Compute, "bwd_last", c as u32, mb as u32);
                            stage.bwd_last_ctx(
                                &ctx.rt,
                                pbuf,
                                &comm,
                                &x,
                                &targets,
                                dims,
                                &moe_fwd_ctx(&ctx, step, c, mb),
                            )?
                        };
                        // loss scaling enters at the source: the head
                        // stage's own grads and the gradient it sends
                        // upstream (everything upstream scales through
                        // the chain automatically)
                        if scale != 1.0 {
                            gp.iter_mut().for_each(|x| *x *= scale);
                            gx.iter_mut().for_each(|x| *x *= scale);
                        }
                        accumulate(&mut grad_accum[c], &gp);
                        loss_sum += loss;
                        send_grad(&ctx, &mut local, g, mb, gx);
                    } else if g == 0 {
                        let gy = recv_grad(&ctx, &mut local, g, mb);
                        let tokens = stash_tok[mb].take().unwrap();
                        let gp = {
                            let _s =
                                trace::span_cm(Category::Compute, "bwd_first", c as u32, mb as u32);
                            stage.bwd_first(&ctx.rt, pbuf, &comm, &tokens, &gy, dims)?
                        };
                        accumulate(&mut grad_accum[c], &gp);
                    } else {
                        let gy = recv_grad(&ctx, &mut local, g, mb);
                        let x = stash_x[c][mb].take().unwrap();
                        let (gp, gx) = {
                            let _s =
                                trace::span_cm(Category::Compute, "bwd_mid", c as u32, mb as u32);
                            stage.bwd_mid(&ctx.rt, pbuf, &comm, &x, &gy, dims)?
                        };
                        accumulate(&mut grad_accum[c], &gp);
                        send_grad(&ctx, &mut local, g, mb, gx);
                    }
                    // the chunk's LAST backward just ran: finalize its
                    // gradient and (overlap mode) launch its DP buckets
                    // so the sync hides under the remaining backward ops
                    bwd_left[c] -= 1;
                    if overlap && bwd_left[c] == 0 {
                        syncs[c] = finalize_and_launch(
                            &ctx,
                            &comm,
                            stages[c],
                            &mut grad_accum[c],
                            inv_m,
                            step,
                            c,
                            true,
                        );
                        finalized[c] = true;
                    }
                }
            }
            // ZeRO-3: this op's gathered view retires with the op
            if uses_params {
                if let Some(z) = z3.as_mut() {
                    z.release(full_len[c]);
                }
            }
        }

        // release the step-scoped parameter handles so the optimizer
        // can mutate the Arc'd buffers below without copy-on-write
        drop(handles);

        // chunks whose last backward fell at the very end of the stream
        // — or every chunk in sequential mode — finalize here, their
        // bucket launches landing on the exposed timeline
        for c in 0..ctx.v {
            if !finalized[c] {
                syncs[c] = finalize_and_launch(
                    &ctx,
                    &comm,
                    stages[c],
                    &mut grad_accum[c],
                    inv_m,
                    step,
                    c,
                    false,
                );
            }
        }

        // drain every chunk's bucket handles in a fixed order (every
        // rank of a DP row walks the same sequence, so the per-chunk
        // collective rounds line up; bucket reduction is rank-order
        // deterministic regardless of overlap timing, so overlapped ≡
        // sequential bit for bit).  All-reduce buckets land the full
        // reduced buffer in grad_accum; reduce-scatter buckets tile
        // exactly this rank's partition into red_grads — the identical
        // elementwise values, shard-resident.
        let lr_scale = ctx
            .cfg
            .lr_schedule
            .map(|sch| sch.scale(step as u64))
            .unwrap_or(1.0);
        for c in 0..ctx.v {
            if ctx.dp > 1 {
                let _s = trace::span_cm(Category::DpSync, "dp_drain", c as u32, trace::TAG_NONE);
                let inv_dp = 1.0 / ctx.dp as f32;
                let t0 = Instant::now();
                match &mut syncs[c] {
                    ChunkSync::AllReduce(buckets) => {
                        for (lo, hi, h) in buckets.drain(..) {
                            // zero-copy redeem: one copy, shared sum -> grads
                            let sum = h.wait_shared();
                            grad_accum[c][lo..hi].copy_from_slice(&sum);
                        }
                        ctx.dp_group
                            .nb_exposed_ns
                            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        grad_accum[c].iter_mut().for_each(|x| *x *= inv_dp);
                    }
                    ChunkSync::ReduceScatter(buckets) => {
                        let (slo, _shi) = shard_bounds[c];
                        for (lo, hi, h) in buckets.drain(..) {
                            // zero-copy redeem: one copy, shared sum -> shard
                            if let Some(sum) = h.wait_shared() {
                                debug_assert_eq!(sum.len(), hi - lo);
                                red_grads[c][lo - slo..hi - slo].copy_from_slice(&sum);
                            }
                        }
                        ctx.dp_group
                            .nb_exposed_ns
                            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        red_grads[c].iter_mut().for_each(|x| *x *= inv_dp);
                    }
                }
            }
        }

        // mixed precision: every worker must reach the same skip verdict
        // (a skipped step leaves every optimizer untouched), so the local
        // non-finite-gradient flag is agreed across the WHOLE world with
        // a 1-float all-reduce before the scaler rules.  Then unscale the
        // surviving gradients (1/scale is a power of two — exact).  The
        // sharded stages inspect only their reduced shard — the union
        // over ranks covers the full buffer, so the world-agreed verdict
        // is identical to DDP's.
        let mut skipped = false;
        if scaling_active {
            let local_overflow = if rs_flow {
                red_grads.iter().any(|g| g.iter().any(|x| !x.is_finite()))
            } else {
                grad_accum.iter().any(|g| g.iter().any(|x| !x.is_finite()))
            };
            let mut flag = vec![if local_overflow { 1.0f32 } else { 0.0 }];
            {
                let _s = trace::span(Category::DpSync, "scaler_agree");
                ctx.world
                    .all_reduce_sum(ctx.world_rank(), &mut flag, ctx.cfg.collective_algo);
            }
            skipped = scaler.update(flag[0] > 0.0);
            if !skipped && scale != 1.0 {
                let inv = 1.0 / scale;
                let bufs = if rs_flow { &mut red_grads } else { &mut grad_accum };
                for g in bufs.iter_mut() {
                    g.iter_mut().for_each(|x| *x *= inv);
                }
            }
        }

        // (sharded) optimizer step, chunk by chunk; combined pre-clip
        // norm over every chunk this worker hosts (a single chunk's
        // spike must not be masked by the last chunk's).  A scaler-
        // skipped step touches no optimizer state at all — Adam's step
        // count included — and reports an infinite gradient norm.
        let grad_norm = if skipped {
            f32::INFINITY
        } else {
            let mut grad_norm_sq = 0.0f32;
            for c in 0..ctx.v {
                // under TP the clip norm combines across the tensor group
                // (replicated span counted once) — dense-equivalent clipping
                let tp_ctx = stages[c].tp_replicated_span().map(|span| (&comm, span));
                let step_grads: &mut Vec<f32> =
                    if rs_flow { &mut red_grads[c] } else { &mut grad_accum[c] };
                let norm = opts[c].step_reduced(
                    &ctx.dp_group,
                    ctx.dp_rank,
                    Arc::make_mut(&mut params[c]),
                    step_grads,
                    lr_scale,
                    tp_ctx,
                );
                grad_norm_sq += norm * norm;
            }
            grad_norm_sq.sqrt()
        };

        // periodic checkpoint: every rank snapshots its own pieces after
        // a world barrier (so all stages are at the same step).  Files
        // are keyed (global stage, tp rank): each tensor shard's
        // dp-rank-0 worker carries that shard's params — assembled by a
        // blocking DP all-gather under ZeRO-3, so the on-disk format is
        // stage-independent for stages 0-2 resumes of each other's shape
        // class; every rank carries its own optimizer state; pp0/dp0/tp0
        // carries the manifest.  The snapshot is Arc clones of the live
        // parameter storage — the optimizer's `Arc::make_mut` copy-on-
        // write means later steps never leak into it, which is what
        // keeps the async path bitwise identical to sync.  Sync saves
        // write the snapshot to the generation's staging dir inline and
        // the leader commits it (one atomic rename) after a second
        // barrier; async saves hand the snapshot to the saver thread and
        // resume the step loop immediately.
        let every = ctx.cfg.checkpoint_every;
        let last_step = rel_step + 1 == ctx.cfg.steps;
        if let Some(save) = ctx.save.clone() {
            if (every > 0 && (rel_step + 1) % every == 0) || last_step {
                let _s = trace::span(Category::Checkpoint, "ckpt_save");
                let t0 = Instant::now();
                let ckpt_step = step + 1;
                let staging = checkpoint::staging_dir(&save.root, ckpt_step);
                let leader = ctx.pp_rank == 0 && ctx.dp_rank == 0 && ctx.tp_rank == 0;
                if leader && ctx.save_tx.is_none() {
                    // sync path: sweep any stale torn staging for this
                    // step before peers write (the barrier below orders
                    // this ahead of every staging write)
                    let _ = std::fs::remove_dir_all(&staging);
                }
                ctx.world.barrier(ctx.world_rank());
                let mut files: Vec<(String, Arc<Vec<f32>>, u64)> = Vec::new();
                for c in 0..ctx.v {
                    let g = ctx.global(c);
                    if z3_flow {
                        // out-of-band assembly: must not advance the
                        // ag_payload counter the on-demand pin measures
                        let mut full = vec![0.0f32; full_len[c]];
                        ctx.dp_group.all_gather_dtype_uncounted(
                            ctx.dp_rank,
                            &params[c],
                            &mut full,
                            ctx.cfg.precision,
                        );
                        if ctx.dp_rank == 0 {
                            files.push((
                                checkpoint::params_file_name(g, ctx.tp_rank),
                                Arc::new(full),
                                ckpt_step as u64,
                            ));
                        }
                    } else if ctx.dp_rank == 0 {
                        files.push((
                            checkpoint::params_file_name(g, ctx.tp_rank),
                            params[c].clone(),
                            ckpt_step as u64,
                        ));
                    }
                    let (state, t) = opts[c].export_state();
                    files.push((
                        checkpoint::opt_file_name(g, ctx.tp_rank, ctx.dp_rank),
                        Arc::new(state),
                        t,
                    ));
                }
                // the expert *configuration* (experts, topk) is part of
                // the checkpoint's identity — a resume under a different
                // expert shape hard-rejects; ep is recorded as the
                // world's effective routing width (informational: the
                // trajectory is ep-invariant, so any valid ep resumes)
                let moe_spec = BuiltinSpec::parse(&ctx.cfg.bundle);
                let manifest = leader.then(|| checkpoint::Manifest {
                    step: ckpt_step,
                    bundle: ctx.cfg.bundle.clone(),
                    stages: ctx.k() as u32,
                    tp: ctx.tp as u32,
                    dp: ctx.dp as u32,
                    experts: moe_spec.as_ref().map_or(1, |s| s.experts as u32),
                    moe_topk: moe_spec.as_ref().map_or(1, |s| s.topk as u32),
                    ep: ctx.ep_group.as_ref().map_or(1, |g| g.len() as u32),
                    zero_stage: ctx.cfg.zero_stage.index(),
                    precision: ctx.cfg.precision.name().to_string(),
                    loss_scale: scaler.scale(),
                    scale_good_steps: scaler.good_steps(),
                    grad_wire: ctx.cfg.effective_grad_wire().name().to_string(),
                    nodes: ctx.cfg.nodes,
                    files: Vec::new(),
                });
                // ckpt-crash@<gen>:<rank>: die *inside* this save — the
                // generation can never commit, so recovery must fall
                // back to the last committed one
                let crash = ctx.cfg.faults.iter().any(|f| {
                    matches!(*f, FaultSpec::CkptCrash { step: cs, rank }
                        if cs == ckpt_step && rank == ctx.world_rank())
                });
                match &ctx.save_tx {
                    Some(tx) => {
                        if crash {
                            // die at the hand-off: this rank's part never
                            // reaches the saver, the step's staging stays
                            // torn, and the commit count never fills
                            return Err(anyhow::Error::new(KilledByFault {
                                step: ckpt_step,
                                rank: ctx.world_rank(),
                            }));
                        }
                        tx.send(checkpoint::SavePart {
                            step: ckpt_step,
                            world_rank: ctx.world_rank(),
                            files,
                            manifest,
                        })
                        .map_err(|_| anyhow!("checkpoint saver thread died"))?;
                    }
                    None => {
                        if crash {
                            // die mid-write: stage all but the last file,
                            // leaving a genuinely torn staging dir, and
                            // never reach the commit barrier
                            for (name, data, aux) in
                                files.iter().take(files.len().saturating_sub(1))
                            {
                                save.write_file(
                                    ckpt_step,
                                    ctx.world_rank(),
                                    &staging.join(name),
                                    data,
                                    *aux,
                                )?;
                            }
                            return Err(anyhow::Error::new(KilledByFault {
                                step: ckpt_step,
                                rank: ctx.world_rank(),
                            }));
                        }
                        for (name, data, aux) in &files {
                            save.write_file(
                                ckpt_step,
                                ctx.world_rank(),
                                &staging.join(name),
                                data,
                                *aux,
                            )?;
                        }
                        ctx.world.barrier(ctx.world_rank());
                        if let Some(m) = manifest {
                            checkpoint::commit_generation(&save.root, ckpt_step, m)?;
                            checkpoint::prune_generations(&save.root, save.keep)?;
                        }
                    }
                }
                save.exposed_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
        }

        // loss reporting: mean across micro-batches, then across DP
        if owns_head {
            let mut l = vec![loss_sum * inv_m];
            {
                let _s = trace::span(Category::DpSync, "loss_allreduce");
                ctx.dp_group
                    .all_reduce_sum(ctx.dp_rank, &mut l, ctx.cfg.collective_algo);
            }
            let mean_loss = l[0] / ctx.dp as f32;
            if let Some(tx) = &ctx.loss_tx {
                tx.send((step, mean_loss, grad_norm, scaler.scale(), skipped))
                    .map_err(|_| anyhow!("leader hung up"))?;
            }
        }
    }

    // per-rank measured residency, reported through the leader: the
    // ZeRO-3 gather high-water mark and this rank's resident optimizer
    // shard bytes
    if let Some(z) = &z3 {
        ctx.dp_group.ag_peak_floats.fetch_max(z.peak_floats, Ordering::Relaxed);
    }
    let opt_bytes: usize = opts.iter().map(|o| o.state_bytes()).sum();
    ctx.opt_state_bytes.fetch_max(opt_bytes as u64, Ordering::Relaxed);
    Ok(())
}

fn accumulate(acc: &mut [f32], g: &[f32]) {
    debug_assert_eq!(acc.len(), g.len());
    for (a, &v) in acc.iter_mut().zip(g.iter()) {
        *a += v;
    }
}
