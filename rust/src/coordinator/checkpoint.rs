//! Training checkpoints: crash-consistent save/restore of parameters +
//! optimizer state, with generation directories and an async save path.
//!
//! Format — one file per **(global stage, tp rank)**, written by that
//! shard's dp-rank-0 worker; DP replicas hold identical parameters so one
//! copy suffices, and under ZeRO stages 1+ each DP rank persists only its
//! own optimizer shard, matching DeepSpeed's per-rank checkpoint layout.
//! Each save lands in its own **generation** directory:
//!
//! ```text
//! ckpt-dir/
//!   gen-<step>.tmp/                 # staging: files land here first
//!   gen-<step>/                     # committed generation (atomic rename)
//!     MANIFEST.json                 # step, bundle, world shape, file list
//!     stage<g>.tp<t>.params.bin     # f32 LE: flat (sharded) param vector
//!     stage<g>.tp<t>.dp<r>.opt.bin  # f32 LE: adam m ++ adam v (+ step count)
//! ```
//!
//! Crash consistency: every `.bin` carries a CRC32 of its payload in the
//! header, the manifest lists every file with its size + checksum, all
//! writes go through temp-file + atomic rename, and the commit itself is
//! one `rename(gen-<step>.tmp, gen-<step>)` — a kill at any instant
//! leaves either the previous committed generation or a fully-verified
//! new one.  `latest_committed` scans generations newest-first and falls
//! back past torn staging dirs and corrupt files; `prune_generations`
//! keeps the newest `--ckpt-keep` chain.
//!
//! Keying by *global* stage (not worker rank) means a run can resume
//! under a different pipeline chunking (`v`) of the same bundle; keying
//! by tp rank means every tensor shard round-trips its own slice.  The
//! manifest pins `(bundle, global stages, tp, dp, zero_stage)` —
//! resuming with a different tp or dp is rejected rather than
//! mis-assembled, and sharding stages resume only into themselves or
//! across the layout-identical 1 ↔ 2 pair (`ShardingStage::
//! resume_compatible`).  Parameter files always hold the FULL (tp-shard)
//! vector — ZeRO-3 runs assemble it with a blocking DP all-gather at
//! save time and re-slice their shard on resume.
//!
//! Binary payloads are little-endian f32 with a 28-byte header
//! (magic, version, element count, adam step, payload CRC32).  Version-1
//! files (24-byte header, no CRC) still read for back-compat.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use super::FaultSpec;
use crate::collectives::chunk_bounds;
use crate::util::json::Json;

const MAGIC: u32 = 0x46_4C_4C_4D; // "FLLM"
const VERSION: u32 = 2;

// ---------------------------------------------------------------------
// CRC32 (IEEE 802.3, reflected, poly 0xEDB88320) — table-driven, no deps
// ---------------------------------------------------------------------

fn crc32_table() -> &'static [u32; 256] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// CRC32 of a byte slice (IEEE; matches zlib's `crc32`).
pub fn crc32(bytes: &[u8]) -> u32 {
    let table = crc32_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------
// Manifest
// ---------------------------------------------------------------------

/// One checkpoint file as recorded by the manifest: name, on-disk size,
/// and the CRC32 of its f32 payload (the same value the file's own
/// header carries) — `verify_generation` re-derives both before a
/// generation is trusted for resume.
#[derive(Debug, Clone, PartialEq)]
pub struct FileEntry {
    pub name: String,
    pub bytes: u64,
    pub crc32: u32,
}

/// Checkpoint metadata (MANIFEST.json).
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub step: u32,
    pub bundle: String,
    /// Global stages (`pp × v`) — NOT worker ranks, so re-chunked resumes
    /// of the same bundle validate.
    pub stages: u32,
    pub tp: u32,
    pub dp: u32,
    /// Expert count of the bundle's MoE stages (1 = dense).  Part of the
    /// checkpoint's identity: parameter files carry one segment per
    /// expert plus the gate, so resuming under a different expert shape
    /// hard-rejects.  Legacy manifests default to 1.
    pub experts: u32,
    /// Routed experts per token (top-k); 1 for dense and legacy
    /// manifests.  A top-k change alters the routing (and so the
    /// trajectory) silently — mismatches are rejected with `experts`.
    pub moe_topk: u32,
    /// Effective expert-parallel width the writing world ran at.
    /// Informational only — trajectories are ep-invariant, so any valid
    /// ep resumes any other; recorded so the tier-split a2a counters can
    /// be interpreted after the fact.  Legacy manifests default to 1.
    pub ep: u32,
    /// ZeRO sharding stage (0..=3) the checkpoint was written at; legacy
    /// manifests carried a `zero1` bool, parsed as stage 0/1.
    pub zero_stage: u32,
    /// Engine precision name ("fp32" / "bf16") — resuming under a
    /// different precision is rejected (the optimizer state layout and
    /// the parameter grid both change).
    pub precision: String,
    /// Dynamic loss-scaler state at the checkpointed step, so a resumed
    /// run continues the exact scale schedule.
    pub loss_scale: f32,
    pub scale_good_steps: u32,
    /// Effective inter-node gradient wire the run used ("fp32" / "bf16" /
    /// "int8").  int8 re-quantizes, so resuming under a different wire
    /// silently changes the trajectory — mismatches are rejected.  Legacy
    /// manifests (no field) derive the wire from their precision, which
    /// is exactly what `EngineConfig::effective_grad_wire` does for runs
    /// that never passed `--grad-wire`.
    pub grad_wire: String,
    /// Node count the run was packed onto (0 = flat legacy collectives;
    /// legacy manifests default to 1).  Recorded so tier-split payload
    /// counters can be interpreted after a placement change — never a
    /// resume blocker, since placement does not affect values.
    pub nodes: u32,
    /// Every data file in this generation with size + payload CRC32;
    /// filled by `commit_generation`.  Legacy (pre-generation) manifests
    /// parse to an empty list, which verifies vacuously.
    pub files: Vec<FileEntry>,
}

impl Manifest {
    pub fn to_json(&self) -> String {
        let files = self
            .files
            .iter()
            .map(|f| {
                format!(
                    "{{\"name\": {}, \"bytes\": {}, \"crc32\": {}}}",
                    crate::util::json::escape(&f.name),
                    f.bytes,
                    f.crc32
                )
            })
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            "{{\"step\": {}, \"bundle\": {}, \"stages\": {}, \"tp\": {}, \"dp\": {}, \
             \"experts\": {}, \"moe_topk\": {}, \"ep\": {}, \
             \"zero_stage\": {}, \"precision\": {}, \"loss_scale\": {}, \"scale_good_steps\": {}, \
             \"grad_wire\": {}, \"nodes\": {}, \"files\": [{}]}}",
            self.step,
            crate::util::json::escape(&self.bundle),
            self.stages,
            self.tp,
            self.dp,
            self.experts,
            self.moe_topk,
            self.ep,
            self.zero_stage,
            crate::util::json::escape(&self.precision),
            self.loss_scale,
            self.scale_good_steps,
            crate::util::json::escape(&self.grad_wire),
            self.nodes,
            files
        )
    }

    pub fn from_json(src: &str) -> Result<Self> {
        let j = Json::parse(src).map_err(|e| anyhow!("{e}"))?;
        let stages = match j.u64_field("stages") {
            Ok(s) => s as u32,
            // pre-TP manifests carried the worker-rank count as "pp" and
            // keyed files stage<g>.params.bin — not convertible here
            Err(_) if j.u64_field("pp").is_ok() => {
                return Err(anyhow!(
                    "incompatible checkpoint: pre-tensor-parallel manifest format \
                     (worker-rank keyed); this build keys checkpoints by \
                     (global stage, tp rank) — re-train to produce a new checkpoint"
                ))
            }
            Err(e) => return Err(anyhow!("{e}")),
        };
        let files = match j.get("files").and_then(Json::as_arr) {
            Some(arr) => arr
                .iter()
                .map(|f| {
                    Ok(FileEntry {
                        name: f.str_field("name").map_err(|e| anyhow!("{e}"))?,
                        bytes: f.u64_field("bytes").map_err(|e| anyhow!("{e}"))?,
                        crc32: f.u64_field("crc32").map_err(|e| anyhow!("{e}"))? as u32,
                    })
                })
                .collect::<Result<Vec<_>>>()?,
            // legacy flat-dir manifests predate the file list
            None => Vec::new(),
        };
        Ok(Self {
            step: j.u64_field("step").map_err(|e| anyhow!("{e}"))? as u32,
            bundle: j.str_field("bundle").map_err(|e| anyhow!("{e}"))?,
            stages,
            tp: j.u64_field("tp").map_err(|e| anyhow!("{e}"))? as u32,
            dp: j.u64_field("dp").map_err(|e| anyhow!("{e}"))? as u32,
            // pre-MoE manifests are all dense: one expert, top-1, ep 1
            experts: j.u64_field("experts").unwrap_or(1) as u32,
            moe_topk: j.u64_field("moe_topk").unwrap_or(1) as u32,
            ep: j.u64_field("ep").unwrap_or(1) as u32,
            zero_stage: match j.u64_field("zero_stage") {
                Ok(s) => s as u32,
                // pre-staged manifests carried a zero1 bool: stage 0 or 1
                Err(_) => u32::from(j.bool_field("zero1").map_err(|e| anyhow!("{e}"))?),
            },
            // pre-mixed-precision checkpoints were all fp32 at scale 1
            precision: j.str_field("precision").unwrap_or_else(|_| "fp32".to_string()),
            loss_scale: j.f64_field("loss_scale").unwrap_or(1.0) as f32,
            scale_good_steps: j.u64_field("scale_good_steps").unwrap_or(0) as u32,
            // pre-hierarchical manifests never quantized the wire: the
            // effective wire was the precision's native width (fp32 for
            // fp32 runs — the back-compat default — bf16 for bf16 runs)
            grad_wire: j.str_field("grad_wire").unwrap_or_else(|_| {
                j.str_field("precision").unwrap_or_else(|_| "fp32".to_string())
            }),
            nodes: j.u64_field("nodes").unwrap_or(1) as u32,
            files,
        })
    }

    /// Validate this manifest against a resuming run's shape.  Bundle,
    /// global stage count, tp, precision, and grad wire must match — a
    /// mismatch there cannot be re-assembled and is rejected hard.  `dp`
    /// deliberately does NOT appear: the optimizer shards are
    /// re-partitioned on load (`reslice_opt_state`), so any dp resumes
    /// any dp — the elastic dp±1 reconfiguration path.  The sharding
    /// stage ladder has its own compatibility rule
    /// (`ShardingStage::resume_compatible`), checked by the coordinator.
    pub fn validate_resume(
        &self,
        bundle: &str,
        stages: u32,
        tp: u32,
        precision: &str,
        grad_wire: &str,
        experts: u32,
        moe_topk: u32,
    ) -> Result<()> {
        // the expert-config check runs FIRST: a `-moe` shape change also
        // changes the bundle string, and the targeted message beats the
        // generic bundle-mismatch one
        anyhow::ensure!(
            self.experts == experts && self.moe_topk == moe_topk,
            "checkpoint expert config (experts={}, topk={}) does not match this run's \
             (experts={}, topk={}) — parameter files carry one segment per expert plus \
             the gate, so a different expert shape cannot be re-assembled; re-train to \
             produce a new checkpoint (ep, by contrast, re-routes freely: trajectories \
             are ep-invariant)",
            self.experts,
            self.moe_topk,
            experts,
            moe_topk
        );
        anyhow::ensure!(
            self.bundle == bundle && self.stages == stages,
            "checkpoint bundle mismatch: {:?} at {} global stages vs this run's {:?} at {} — \
             parameter files cannot be re-assembled across bundles; re-train to produce a \
             new checkpoint",
            self.bundle,
            self.stages,
            bundle,
            stages
        );
        anyhow::ensure!(
            self.tp == tp,
            "checkpoint tensor-parallel degree {} does not match this run's {} — parameter \
             files are keyed by tp rank and tensor shards do not re-slice; re-train to \
             produce a new checkpoint (dp, by contrast, re-partitions on resume)",
            self.tp,
            tp
        );
        anyhow::ensure!(
            self.precision == precision,
            "checkpoint precision {:?} does not match this run's {:?} — the parameter \
             grid and optimizer-state layout both change with precision",
            self.precision,
            precision
        );
        anyhow::ensure!(
            self.grad_wire == grad_wire,
            "checkpoint gradient wire {:?} does not match this run's effective wire {:?} — \
             a re-quantizing wire (int8) changes the trajectory, so resuming across wire \
             formats would silently fork the run; pass a matching --grad-wire/--nodes",
            self.grad_wire,
            grad_wire
        );
        Ok(())
    }

    /// Write MANIFEST.json atomically: temp file in the same directory,
    /// then rename — a crash mid-write never truncates a live manifest.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let tmp = dir.join("MANIFEST.json.tmp");
        std::fs::write(&tmp, self.to_json()).context("writing checkpoint manifest")?;
        std::fs::rename(&tmp, dir.join("MANIFEST.json"))
            .context("committing checkpoint manifest")
    }

    pub fn load(dir: &Path) -> Result<Self> {
        Self::from_json(
            &std::fs::read_to_string(dir.join("MANIFEST.json"))
                .with_context(|| format!("no checkpoint manifest in {dir:?}"))?,
        )
    }
}

// ---------------------------------------------------------------------
// Binary f32 files (v2: checksummed header, atomic rename)
// ---------------------------------------------------------------------

/// Write an f32 buffer with header; `aux` carries e.g. the Adam step
/// count.  The payload CRC32 goes in the header, and the write is temp
/// file + atomic rename — a live checkpoint file is never truncated in
/// place, and a crash mid-write leaves at worst a stray `.tmp`.
pub fn write_f32(path: &Path, data: &[f32], aux: u64) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut payload = Vec::with_capacity(data.len() * 4);
    for v in data {
        payload.extend_from_slice(&v.to_le_bytes());
    }
    let crc = crc32(&payload);
    let tmp = tmp_name(path);
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        f.write_all(&MAGIC.to_le_bytes())?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&(data.len() as u64).to_le_bytes())?;
        f.write_all(&aux.to_le_bytes())?;
        f.write_all(&crc.to_le_bytes())?;
        f.write_all(&payload)?;
        f.flush()?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("committing {path:?}"))
}

fn tmp_name(path: &Path) -> PathBuf {
    let mut name = path.file_name().unwrap_or_default().to_os_string();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Read an f32 buffer; returns (data, aux).  Version-2 files verify the
/// payload CRC32 against the header; version-1 files (pre-CRC) read
/// without the check for back-compat.
pub fn read_f32(path: &Path) -> Result<(Vec<f32>, u64)> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
    );
    let mut h = [0u8; 4];
    f.read_exact(&mut h)?;
    anyhow::ensure!(u32::from_le_bytes(h) == MAGIC, "bad checkpoint magic");
    f.read_exact(&mut h)?;
    let version = u32::from_le_bytes(h);
    anyhow::ensure!(version == 1 || version == VERSION, "unsupported version {version}");
    let mut h8 = [0u8; 8];
    f.read_exact(&mut h8)?;
    let n = u64::from_le_bytes(h8) as usize;
    f.read_exact(&mut h8)?;
    let aux = u64::from_le_bytes(h8);
    let want_crc = if version >= 2 {
        f.read_exact(&mut h)?;
        Some(u32::from_le_bytes(h))
    } else {
        None
    };
    let mut bytes = vec![0u8; n * 4];
    f.read_exact(&mut bytes)?;
    if let Some(want) = want_crc {
        let got = crc32(&bytes);
        anyhow::ensure!(
            got == want,
            "checkpoint payload corrupt in {path:?}: crc32 {got:#010x} != header {want:#010x}"
        );
    }
    let data = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok((data, aux))
}

/// Header-level inspection of a checkpoint file: size consistency plus
/// the payload CRC32 recomputed from the bytes on disk.  For v2 files
/// the recomputed CRC must match the header's; truncation, bit-flips,
/// and torn writes all surface here.
fn inspect_file(path: &Path) -> Result<FileEntry> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    anyhow::ensure!(bytes.len() >= 24, "checkpoint file {path:?} truncated (no header)");
    anyhow::ensure!(
        u32::from_le_bytes(bytes[0..4].try_into().unwrap()) == MAGIC,
        "bad checkpoint magic in {path:?}"
    );
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    anyhow::ensure!(version == 1 || version == VERSION, "unsupported version {version}");
    let n = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    let header = if version >= 2 { 28 } else { 24 };
    anyhow::ensure!(
        bytes.len() == header + n * 4,
        "checkpoint file {path:?} holds {} bytes, header promises {}",
        bytes.len(),
        header + n * 4
    );
    let crc = crc32(&bytes[header..]);
    if version >= 2 {
        let want = u32::from_le_bytes(bytes[24..28].try_into().unwrap());
        anyhow::ensure!(
            crc == want,
            "checkpoint payload corrupt in {path:?}: crc32 {crc:#010x} != header {want:#010x}"
        );
    }
    Ok(FileEntry {
        name: path.file_name().unwrap_or_default().to_string_lossy().into_owned(),
        bytes: bytes.len() as u64,
        crc32: crc,
    })
}

pub fn params_file_name(stage: usize, tp_rank: usize) -> String {
    format!("stage{stage}.tp{tp_rank}.params.bin")
}

pub fn opt_file_name(stage: usize, tp_rank: usize, dp_rank: usize) -> String {
    format!("stage{stage}.tp{tp_rank}.dp{dp_rank}.opt.bin")
}

pub fn params_path(dir: &Path, stage: usize, tp_rank: usize) -> PathBuf {
    dir.join(params_file_name(stage, tp_rank))
}

pub fn opt_path(dir: &Path, stage: usize, tp_rank: usize, dp_rank: usize) -> PathBuf {
    dir.join(opt_file_name(stage, tp_rank, dp_rank))
}

// ---------------------------------------------------------------------
// Generations: staging, commit, scan, prune
// ---------------------------------------------------------------------

/// Committed generation directory for the checkpoint at `step`.
pub fn gen_dir(root: &Path, step: u32) -> PathBuf {
    root.join(format!("gen-{step}"))
}

/// Staging directory a generation is assembled in before the atomic
/// commit rename.  A crash mid-save leaves this behind; it is never
/// eligible for resume and is cleaned up by `prune_generations`.
pub fn staging_dir(root: &Path, step: u32) -> PathBuf {
    root.join(format!("gen-{step}.tmp"))
}

fn gen_step(name: &str) -> Option<u32> {
    name.strip_prefix("gen-").and_then(|s| s.parse().ok())
}

fn staging_step(name: &str) -> Option<u32> {
    name.strip_prefix("gen-")?.strip_suffix(".tmp").and_then(|s| s.parse().ok())
}

/// Inspect every `.bin` in a staging directory, building the verified
/// file list the manifest commits — sorted by name so the manifest (and
/// therefore the committed bytes) is deterministic across save paths.
fn scan_file_entries(dir: &Path) -> Result<Vec<FileEntry>> {
    let mut entries = Vec::new();
    for e in std::fs::read_dir(dir).with_context(|| format!("scanning staging {dir:?}"))? {
        let e = e?;
        let name = e.file_name().to_string_lossy().into_owned();
        if name.ends_with(".bin") {
            entries.push(inspect_file(&e.path())?);
        }
    }
    entries.sort_by(|a, b| a.name.cmp(&b.name));
    Ok(entries)
}

/// Commit a staged generation: scan the staging dir into the manifest's
/// file list (size + CRC32 per file), write the manifest into staging
/// (itself atomically), then promote the whole directory with a single
/// rename.  Any crash before the rename leaves only a `.tmp` staging
/// dir; any crash after leaves a fully-verified committed generation.
pub fn commit_generation(root: &Path, step: u32, mut manifest: Manifest) -> Result<()> {
    let staging = staging_dir(root, step);
    manifest.files = scan_file_entries(&staging)?;
    anyhow::ensure!(
        !manifest.files.is_empty(),
        "refusing to commit empty checkpoint generation {staging:?}"
    );
    manifest.save(&staging)?;
    let dest = gen_dir(root, step);
    if dest.exists() {
        // a re-save of the same step (recovery re-walking a leg): the
        // old committed generation is replaced, never truncated in place
        std::fs::remove_dir_all(&dest)?;
    }
    std::fs::rename(&staging, &dest)
        .with_context(|| format!("committing checkpoint generation {dest:?}"))
}

/// Verify a committed generation against its manifest: every listed
/// file must exist with the recorded size and a matching recomputed
/// payload CRC32.  Legacy manifests (empty file list) verify vacuously.
pub fn verify_generation(dir: &Path, manifest: &Manifest) -> Result<()> {
    for want in &manifest.files {
        let got = inspect_file(&dir.join(&want.name))?;
        anyhow::ensure!(
            got.bytes == want.bytes && got.crc32 == want.crc32,
            "checkpoint file {} in {dir:?} does not match its manifest entry \
             ({} bytes crc {:#010x} vs recorded {} bytes crc {:#010x})",
            want.name,
            got.bytes,
            got.crc32,
            want.bytes,
            want.crc32
        );
    }
    Ok(())
}

/// A resolved, verified checkpoint: the directory files load from plus
/// its manifest.
#[derive(Debug, Clone)]
pub struct ResolvedCkpt {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

/// Scan `root` for the newest **committed** generation whose manifest
/// parses and whose every file verifies (size + CRC32).  Torn staging
/// dirs (`gen-N.tmp`) are never candidates; a corrupt newest generation
/// falls back to the next one down the chain.  A legacy flat-layout
/// checkpoint (MANIFEST.json at the root, no generation dirs) is
/// accepted last so pre-generation checkpoints keep resuming.
pub fn latest_committed(root: &Path) -> Result<Option<ResolvedCkpt>> {
    if !root.is_dir() {
        return Ok(None);
    }
    let mut gens: Vec<(u32, PathBuf)> = Vec::new();
    for e in std::fs::read_dir(root)? {
        let e = e?;
        let name = e.file_name().to_string_lossy().into_owned();
        if let Some(step) = gen_step(&name) {
            if e.path().is_dir() {
                gens.push((step, e.path()));
            }
        }
    }
    gens.sort_by(|a, b| b.0.cmp(&a.0));
    for (_, dir) in &gens {
        let ok = Manifest::load(dir).and_then(|m| {
            verify_generation(dir, &m)?;
            Ok(m)
        });
        match ok {
            Ok(manifest) => return Ok(Some(ResolvedCkpt { dir: dir.clone(), manifest })),
            Err(_) => continue, // torn or corrupt: fall back down the chain
        }
    }
    if root.join("MANIFEST.json").is_file() {
        let manifest = Manifest::load(root)?;
        verify_generation(root, &manifest)?;
        return Ok(Some(ResolvedCkpt { dir: root.to_path_buf(), manifest }));
    }
    Ok(None)
}

/// Retire old generations, keeping the newest `keep` committed ones
/// (minimum 1), and sweep stale staging dirs older than the newest
/// committed generation (a staging dir newer than every committed one
/// may still be in flight and is left alone).
pub fn prune_generations(root: &Path, keep: usize) -> Result<()> {
    let keep = keep.max(1);
    let mut committed: Vec<(u32, PathBuf)> = Vec::new();
    let mut staged: Vec<(u32, PathBuf)> = Vec::new();
    for e in std::fs::read_dir(root)? {
        let e = e?;
        if !e.path().is_dir() {
            continue;
        }
        let name = e.file_name().to_string_lossy().into_owned();
        if let Some(step) = staging_step(&name) {
            staged.push((step, e.path()));
        } else if let Some(step) = gen_step(&name) {
            committed.push((step, e.path()));
        }
    }
    committed.sort_by(|a, b| b.0.cmp(&a.0));
    for (_, dir) in committed.iter().skip(keep) {
        std::fs::remove_dir_all(dir).with_context(|| format!("pruning {dir:?}"))?;
    }
    if let Some(&(newest, _)) = committed.first() {
        for (step, dir) in &staged {
            if *step <= newest {
                std::fs::remove_dir_all(dir).with_context(|| format!("sweeping {dir:?}"))?;
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Save context: retrying writes, fault injection, hidden/exposed timers
// ---------------------------------------------------------------------

const WRITE_ATTEMPTS: u32 = 5;

struct WriteFailSlot {
    step: u32,
    rank: usize,
    left: AtomicU32,
}

/// Shared per-run save state: the checkpoint root, retention policy,
/// injected write-failure budget, and the hidden/exposed save timers
/// (classified like the PR-3 `dp_overlap` pair: *exposed* time stalls
/// the step loop — the barrier + snapshot on the async path, the whole
/// write on the sync path — while *hidden* time drains on the saver
/// thread behind training).
pub struct SaveCtx {
    pub root: PathBuf,
    pub keep: usize,
    pub world_size: usize,
    pub exposed_ns: AtomicU64,
    pub hidden_ns: AtomicU64,
    write_fails: Vec<WriteFailSlot>,
}

impl SaveCtx {
    pub fn new(root: PathBuf, keep: usize, world_size: usize, faults: &[FaultSpec]) -> Self {
        let write_fails = faults
            .iter()
            .filter_map(|f| match *f {
                FaultSpec::WriteFail { step, rank, count } => {
                    Some(WriteFailSlot { step, rank, left: AtomicU32::new(count) })
                }
                _ => None,
            })
            .collect();
        Self {
            root,
            keep,
            world_size,
            exposed_ns: AtomicU64::new(0),
            hidden_ns: AtomicU64::new(0),
            write_fails,
        }
    }

    /// Consume one injected failure if a `write-fail@step:rank` budget
    /// covers this write attempt.
    fn inject_write_fail(&self, ckpt_step: u32, world_rank: usize) -> bool {
        self.write_fails.iter().any(|s| {
            s.step == ckpt_step
                && s.rank == world_rank
                && s.left
                    .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
                    .is_ok()
        })
    }

    /// Write one checkpoint file with bounded retry + exponential
    /// backoff on transient failures (injected or real).  Exhausting
    /// the retry budget is a hard error — the save cannot be trusted.
    pub fn write_file(
        &self,
        ckpt_step: u32,
        world_rank: usize,
        path: &Path,
        data: &[f32],
        aux: u64,
    ) -> Result<()> {
        let mut last_err = None;
        for attempt in 0..WRITE_ATTEMPTS {
            if attempt > 0 {
                std::thread::sleep(std::time::Duration::from_millis(1 << (attempt - 1)));
            }
            if self.inject_write_fail(ckpt_step, world_rank) {
                last_err = Some(anyhow!(
                    "injected transient write failure (write-fail@{ckpt_step}:{world_rank})"
                ));
                continue;
            }
            match write_f32(path, data, aux) {
                Ok(()) => return Ok(()),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap()).with_context(|| {
            format!("checkpoint write {path:?} failed after {WRITE_ATTEMPTS} attempts")
        })
    }
}

// ---------------------------------------------------------------------
// Async saver: snapshot hand-off channel + background persist thread
// ---------------------------------------------------------------------

/// One rank's in-memory snapshot of a checkpoint step, handed to the
/// saver thread at the checkpoint barrier.  The tensors are `Arc`
/// clones of the live parameter storage — the optimizer's
/// `Arc::make_mut` copy-on-write means subsequent steps cannot leak
/// into the snapshot (this is what makes async ≡ sync bitwise).
pub struct SavePart {
    /// Manifest step of the generation this part belongs to (`step + 1`).
    pub step: u32,
    pub world_rank: usize,
    /// (file name, payload, aux) triples this rank persists.
    pub files: Vec<(String, Arc<Vec<f32>>, u64)>,
    /// The (pp0, dp0, tp0) leader's part carries the manifest skeleton;
    /// the saver fills its file list at commit time.
    pub manifest: Option<Manifest>,
}

/// Background saver loop: drain snapshot parts, persist each rank's
/// files into the generation's staging dir (with retry/backoff through
/// `SaveCtx::write_file`), and commit + prune once all `world_size`
/// parts of a step have landed.  Steps left incomplete when the channel
/// closes (a rank died mid-save) stay as torn staging dirs — exactly
/// the state `latest_committed` skips.  Time spent here is *hidden*
/// save time.  Any error tears the run down as a hard failure when the
/// coordinator joins this thread.
pub fn run_saver(ctx: Arc<SaveCtx>, rx: Receiver<SavePart>) -> Result<()> {
    let mut arrived: BTreeMap<u32, usize> = BTreeMap::new();
    let mut manifests: HashMap<u32, Manifest> = HashMap::new();
    let mut started: HashSet<u32> = HashSet::new();
    for part in rx {
        let t0 = std::time::Instant::now();
        let staging = staging_dir(&ctx.root, part.step);
        if started.insert(part.step) {
            // stale staging from a previous torn save of this step
            let _ = std::fs::remove_dir_all(&staging);
        }
        std::fs::create_dir_all(&staging)?;
        for (name, data, aux) in &part.files {
            ctx.write_file(part.step, part.world_rank, &staging.join(name), data, *aux)?;
        }
        if let Some(m) = part.manifest {
            manifests.insert(part.step, m);
        }
        let seen = arrived.entry(part.step).or_insert(0);
        *seen += 1;
        if *seen == ctx.world_size {
            let manifest = manifests
                .remove(&part.step)
                .ok_or_else(|| anyhow!("checkpoint step {} has no manifest part", part.step))?;
            commit_generation(&ctx.root, part.step, manifest)?;
            prune_generations(&ctx.root, ctx.keep)?;
            arrived.remove(&part.step);
        }
        ctx.hidden_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Optimizer-shard re-partitioning (elastic dp±1)
// ---------------------------------------------------------------------

/// Re-partition a stage's **sharded** optimizer state (ZeRO stages 1-3)
/// from a checkpoint written at `old_dp` ranks onto `new_dp` ranks:
/// read every old rank's shard file, reassemble the full per-component
/// vectors (Adam `m ++ v`, plus the fp32 masters under bf16 — the
/// component count is derived from the shard sizes, so both layouts
/// re-slice through the same path), and return exactly the state
/// `import_state` expects for `new_dp`'s rank `dp_rank` partition of an
/// `n_params`-element stage.
///
/// The old shards are `chunk_bounds(n_params, old_dp)` spans — contiguous
/// and ascending — so the reassembly is pure placement: the resliced
/// state is bitwise the state a run checkpointed at `new_dp` would have
/// written, which is what keeps post-recovery trajectories bitwise
/// identical to fresh runs at the new world.
pub fn reslice_opt_state(
    dir: &Path,
    stage: usize,
    tp_rank: usize,
    old_dp: usize,
    new_dp: usize,
    dp_rank: usize,
    n_params: usize,
) -> Result<(Vec<f32>, u64)> {
    let old_bounds = chunk_bounds(n_params, old_dp);
    let mut shards: Vec<Vec<f32>> = Vec::with_capacity(old_dp);
    let mut comp: Option<usize> = None;
    let mut t = 0u64;
    for r in 0..old_dp {
        let (s, aux) = read_f32(&opt_path(dir, stage, tp_rank, r))?;
        let (lo, hi) = old_bounds[r];
        let len = hi - lo;
        if len > 0 {
            anyhow::ensure!(
                s.len() % len == 0 && (2..=3).contains(&(s.len() / len)),
                "optimizer shard {stage}.tp{tp_rank}.dp{r} holds {} floats for a \
                 {len}-element partition — expected 2 (m ++ v) or 3 (+ masters) components",
                s.len()
            );
            let c = s.len() / len;
            anyhow::ensure!(
                comp.map_or(true, |c0| c0 == c),
                "optimizer shards disagree on component count (rank {r}: {c} vs {:?})",
                comp
            );
            comp = Some(c);
        } else {
            anyhow::ensure!(s.is_empty(), "empty partition carries optimizer state");
        }
        t = t.max(aux);
        shards.push(s);
    }
    let comp = comp.unwrap_or(2);
    let mut full = vec![vec![0.0f32; n_params]; comp];
    for (r, s) in shards.iter().enumerate() {
        let (lo, hi) = old_bounds[r];
        let len = hi - lo;
        for (k, component) in full.iter_mut().enumerate() {
            component[lo..hi].copy_from_slice(&s[k * len..(k + 1) * len]);
        }
    }
    let (nlo, nhi) = chunk_bounds(n_params, new_dp)[dp_rank];
    let mut out = Vec::with_capacity(comp * (nhi - nlo));
    for component in &full {
        out.extend_from_slice(&component[nlo..nhi]);
    }
    Ok((out, t))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest(step: u32) -> Manifest {
        Manifest {
            step,
            bundle: "tiny-s2-mb2".into(),
            stages: 2,
            tp: 1,
            dp: 1,
            experts: 1,
            moe_topk: 1,
            ep: 1,
            zero_stage: 1,
            precision: "fp32".into(),
            loss_scale: 1.0,
            scale_good_steps: 0,
            grad_wire: "fp32".into(),
            nodes: 1,
            files: Vec::new(),
        }
    }

    #[test]
    fn crc32_matches_the_ieee_check_vector() {
        // the canonical zlib/IEEE test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn f32_round_trip() {
        let dir = std::env::temp_dir().join(format!("fllm-ckpt-{}", std::process::id()));
        let path = dir.join("x.bin");
        let data: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        write_f32(&path, &data, 42).unwrap();
        let (back, aux) = read_f32(&path).unwrap();
        assert_eq!(back, data);
        assert_eq!(aux, 42);
        // the atomic write leaves no temp file behind
        assert!(!tmp_name(&path).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn payload_bit_flip_detected() {
        let dir = std::env::temp_dir().join(format!("fllm-crc-{}", std::process::id()));
        let path = dir.join("x.bin");
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        write_f32(&path, &data, 7).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[40] ^= 0x10; // flip one payload bit
        std::fs::write(&path, &bytes).unwrap();
        let err = read_f32(&path).unwrap_err().to_string();
        assert!(err.contains("corrupt"), "{err}");
        assert!(inspect_file(&path).is_err());
        // truncation is a size mismatch at inspect and a read error
        write_f32(&path, &data, 7).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(read_f32(&path).is_err());
        assert!(inspect_file(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_v1_files_still_read() {
        // a pre-CRC (version 1) file: 24-byte header, no checksum
        let dir = std::env::temp_dir().join(format!("fllm-v1-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v1.bin");
        let data = [1.5f32, -2.5, 3.25];
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&(data.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&9u64.to_le_bytes());
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let (back, aux) = read_f32(&path).unwrap();
        assert_eq!(back, data);
        assert_eq!(aux, 9);
        assert!(inspect_file(&path).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_round_trip() {
        for stage in 0..4u32 {
            let m = Manifest {
                step: 17,
                bundle: "tiny-moe8k2-s2-mb2".into(),
                stages: 2,
                tp: 4,
                dp: 3,
                experts: 8,
                moe_topk: 2,
                ep: 4,
                zero_stage: stage,
                precision: "bf16".into(),
                loss_scale: 2048.0,
                scale_good_steps: 7,
                grad_wire: "int8".into(),
                nodes: 2,
                files: vec![
                    FileEntry {
                        name: "stage0.tp0.params.bin".into(),
                        bytes: 412,
                        crc32: 0xDEAD_BEEF,
                    },
                    FileEntry { name: "stage0.tp0.dp0.opt.bin".into(), bytes: 92, crc32: 7 },
                ],
            };
            let back = Manifest::from_json(&m.to_json()).unwrap();
            assert_eq!(m, back);
            // fractional scales survive too (post-backoff states)
            let m2 = Manifest { loss_scale: 0.03125, ..m };
            assert_eq!(Manifest::from_json(&m2.to_json()).unwrap(), m2);
        }
    }

    #[test]
    fn manifest_without_precision_defaults_to_fp32() {
        // pre-mixed-precision manifests keep loading, and their zero1
        // bool parses onto the stage ladder
        let legacy = "{\"step\": 3, \"bundle\": \"tiny-s2-mb2\", \"stages\": 2, \
                      \"tp\": 1, \"dp\": 1, \"zero1\": false}";
        let m = Manifest::from_json(legacy).unwrap();
        assert_eq!(m.precision, "fp32");
        assert_eq!(m.loss_scale, 1.0);
        assert_eq!(m.scale_good_steps, 0);
        assert_eq!(m.zero_stage, 0);
        // pre-hierarchical manifests ran a flat fp32 wire on one node
        assert_eq!(m.grad_wire, "fp32");
        assert_eq!(m.nodes, 1);
        // pre-generation manifests carry no file list: verify is vacuous
        assert!(m.files.is_empty());
        // pre-MoE manifests are dense: one expert, top-1, ep 1
        assert_eq!((m.experts, m.moe_topk, m.ep), (1, 1, 1));
        let legacy_z1 = "{\"step\": 3, \"bundle\": \"tiny-s2-mb2\", \"stages\": 2, \
                         \"tp\": 1, \"dp\": 2, \"zero1\": true}";
        assert_eq!(Manifest::from_json(legacy_z1).unwrap().zero_stage, 1);
    }

    #[test]
    fn legacy_grad_wire_follows_precision() {
        // a pre-hierarchical bf16 manifest trained with a bf16 wire; defaulting
        // its grad_wire to fp32 would spuriously reject every legacy bf16 resume
        let legacy = "{\"step\": 3, \"bundle\": \"tiny-s2-mb2\", \"stages\": 2, \
                      \"tp\": 1, \"dp\": 1, \"zero1\": false, \"precision\": \"bf16\"}";
        let m = Manifest::from_json(legacy).unwrap();
        assert_eq!(m.grad_wire, "bf16");
        assert_eq!(m.nodes, 1);
    }

    #[test]
    fn validate_resume_rejects_shape_not_dp() {
        let m = Manifest {
            step: 4,
            bundle: "tiny-s2-mb2".into(),
            stages: 2,
            tp: 2,
            dp: 3,
            experts: 1,
            moe_topk: 1,
            ep: 1,
            zero_stage: 1,
            precision: "bf16".into(),
            loss_scale: 1024.0,
            scale_good_steps: 2,
            grad_wire: "bf16".into(),
            nodes: 1,
            files: Vec::new(),
        };
        // dp deliberately absent: any dp re-partitions on resume
        m.validate_resume("tiny-s2-mb2", 2, 2, "bf16", "bf16", 1, 1).unwrap();
        let tp_err = m
            .validate_resume("tiny-s2-mb2", 2, 4, "bf16", "bf16", 1, 1)
            .unwrap_err()
            .to_string();
        assert!(tp_err.contains("re-partitions"), "{tp_err}");
        assert!(m.validate_resume("other", 2, 2, "bf16", "bf16", 1, 1).is_err());
        assert!(m.validate_resume("tiny-s2-mb2", 3, 2, "bf16", "bf16", 1, 1).is_err());
        assert!(m.validate_resume("tiny-s2-mb2", 2, 2, "fp32", "bf16", 1, 1).is_err());
        let wire_err = m
            .validate_resume("tiny-s2-mb2", 2, 2, "bf16", "int8", 1, 1)
            .unwrap_err()
            .to_string();
        assert!(wire_err.contains("grad-wire"), "{wire_err}");
    }

    #[test]
    fn validate_resume_rejects_expert_config_mismatch_with_a_targeted_error() {
        let m = Manifest { experts: 4, moe_topk: 2, ep: 2, ..manifest(4) };
        // matching expert config resumes at ANY ep (trajectories are
        // ep-invariant, so ep never blocks)
        m.validate_resume("tiny-s2-mb2", 2, 1, "fp32", "fp32", 4, 2).unwrap();
        // experts mismatch: targeted message, ahead of the bundle check
        let err = m
            .validate_resume("tiny-s2-mb2", 2, 1, "fp32", "fp32", 8, 2)
            .unwrap_err()
            .to_string();
        assert!(err.contains("expert config"), "{err}");
        assert!(err.contains("experts=4"), "{err}");
        // top-k mismatch rejects the same way
        let err = m
            .validate_resume("tiny-s2-mb2", 2, 1, "fp32", "fp32", 4, 1)
            .unwrap_err()
            .to_string();
        assert!(err.contains("topk=2"), "{err}");
        // a dense checkpoint refuses a MoE resume (and vice versa)
        let dense = manifest(4);
        assert!(dense.validate_resume("tiny-s2-mb2", 2, 1, "fp32", "fp32", 4, 1).is_err());
    }

    #[test]
    fn reslice_opt_state_round_trips() {
        let dir = std::env::temp_dir().join(format!("fllm-reslice-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let n = 13usize;
        for comp in [2usize, 3] {
            // write a dp=3 checkpoint of a comp-component state vector
            let full: Vec<Vec<f32>> = (0..comp)
                .map(|k| (0..n).map(|i| (k * 100 + i) as f32 + 0.5).collect())
                .collect();
            for (r, &(lo, hi)) in chunk_bounds(n, 3).iter().enumerate() {
                let mut shard = Vec::new();
                for component in &full {
                    shard.extend_from_slice(&component[lo..hi]);
                }
                write_f32(&opt_path(&dir, 1, 0, r), &shard, 9).unwrap();
            }
            // reslice onto dp=2 and check each new rank sees exactly its partition
            for (r, &(lo, hi)) in chunk_bounds(n, 2).iter().enumerate() {
                let (s, t) = reslice_opt_state(&dir, 1, 0, 3, 2, r, n).unwrap();
                assert_eq!(t, 9);
                let mut want = Vec::new();
                for component in &full {
                    want.extend_from_slice(&component[lo..hi]);
                }
                assert_eq!(s, want, "comp={comp} rank={r}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_manifest_gets_targeted_error() {
        let legacy = "{\"step\": 3, \"bundle\": \"tiny-s2-mb2\", \"pp\": 2, \
                      \"dp\": 1, \"zero1\": false}";
        let err = Manifest::from_json(legacy).unwrap_err().to_string();
        assert!(err.contains("pre-tensor-parallel"), "{err}");
    }

    #[test]
    fn paths_key_stage_and_tp_rank() {
        let dir = Path::new("/tmp/x");
        assert!(params_path(dir, 3, 1).ends_with("stage3.tp1.params.bin"));
        assert!(opt_path(dir, 3, 1, 2).ends_with("stage3.tp1.dp2.opt.bin"));
        assert!(gen_dir(dir, 12).ends_with("gen-12"));
        assert!(staging_dir(dir, 12).ends_with("gen-12.tmp"));
    }

    #[test]
    fn corrupt_file_rejected() {
        let dir = std::env::temp_dir().join(format!("fllm-ckpt2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(read_f32(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    fn stage_generation(root: &Path, step: u32, seed: f32) {
        let staging = staging_dir(root, step);
        write_f32(&params_path(&staging, 0, 0), &[seed, seed + 1.0], step as u64).unwrap();
        write_f32(&opt_path(&staging, 0, 0, 0), &[seed * 2.0; 4], step as u64).unwrap();
    }

    #[test]
    fn commit_is_atomic_and_latest_falls_back_past_torn_state() {
        let root = std::env::temp_dir().join(format!("fllm-gen-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        // two committed generations plus a torn (never-committed) staging dir
        for step in [2u32, 4] {
            stage_generation(&root, step, step as f32);
            commit_generation(&root, step, manifest(step)).unwrap();
            assert!(!staging_dir(&root, step).exists());
        }
        stage_generation(&root, 6, 6.0); // torn: no commit
        let got = latest_committed(&root).unwrap().unwrap();
        assert_eq!(got.manifest.step, 4);
        assert!(got.dir.ends_with("gen-4"));
        assert_eq!(got.manifest.files.len(), 2, "commit records every .bin");

        // corrupt the newest committed generation -> falls back to gen-2
        let victim = params_path(&gen_dir(&root, 4), 0, 0);
        let mut bytes = std::fs::read(&victim).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&victim, &bytes).unwrap();
        let got = latest_committed(&root).unwrap().unwrap();
        assert_eq!(got.manifest.step, 2);

        // delete a listed file entirely -> same fallback
        std::fs::remove_file(&victim).unwrap();
        assert_eq!(latest_committed(&root).unwrap().unwrap().manifest.step, 2);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn prune_keeps_the_newest_chain_and_sweeps_stale_staging() {
        let root = std::env::temp_dir().join(format!("fllm-prune-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        for step in [1u32, 2, 3, 4] {
            stage_generation(&root, step, step as f32);
            commit_generation(&root, step, manifest(step)).unwrap();
        }
        stage_generation(&root, 3, 3.0); // stale torn staging below the newest
        stage_generation(&root, 9, 9.0); // in-flight staging above it
        prune_generations(&root, 2).unwrap();
        assert!(!gen_dir(&root, 1).exists());
        assert!(!gen_dir(&root, 2).exists());
        assert!(gen_dir(&root, 3).exists());
        assert!(gen_dir(&root, 4).exists());
        assert!(!staging_dir(&root, 3).exists(), "stale staging swept");
        assert!(staging_dir(&root, 9).exists(), "in-flight staging kept");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn write_fail_budget_retries_then_exhausts() {
        let root = std::env::temp_dir().join(format!("fllm-wf-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(&root).unwrap();
        let faults = [FaultSpec::WriteFail { step: 5, rank: 0, count: 2 }];
        let ctx = SaveCtx::new(root.clone(), 2, 1, &faults);
        // two injected failures burn two attempts; the third succeeds
        ctx.write_file(5, 0, &root.join("a.bin"), &[1.0, 2.0], 0).unwrap();
        assert_eq!(read_f32(&root.join("a.bin")).unwrap().0, vec![1.0, 2.0]);
        // a budget bigger than the retry limit is a hard error
        let faults = [FaultSpec::WriteFail { step: 5, rank: 0, count: 99 }];
        let ctx = SaveCtx::new(root.clone(), 2, 1, &faults);
        let err = ctx.write_file(5, 0, &root.join("b.bin"), &[1.0], 0).unwrap_err().to_string();
        assert!(err.contains("failed after"), "{err}");
        // other (step, rank) writes are untouched by the budget
        ctx.write_file(6, 0, &root.join("c.bin"), &[3.0], 0).unwrap();
        std::fs::remove_dir_all(&root).ok();
    }
}
