//! Training checkpoints: save/restore parameters + optimizer state.
//!
//! Format — one file per **(global stage, tp rank)**, written by that
//! shard's dp-rank-0 worker; DP replicas hold identical parameters so one
//! copy suffices, and under ZeRO stages 1+ each DP rank persists only its
//! own optimizer shard, matching DeepSpeed's per-rank checkpoint layout:
//!
//! ```text
//! ckpt-dir/
//!   MANIFEST.json                 # step, bundle, world shape
//!   stage<g>.tp<t>.params.bin     # f32 LE: flat (sharded) param vector
//!   stage<g>.tp<t>.dp<r>.opt.bin  # f32 LE: adam m ++ adam v (+ step count)
//! ```
//!
//! Keying by *global* stage (not worker rank) means a run can resume
//! under a different pipeline chunking (`v`) of the same bundle; keying
//! by tp rank means every tensor shard round-trips its own slice.  The
//! manifest pins `(bundle, global stages, tp, dp, zero_stage)` —
//! resuming with a different tp or dp is rejected rather than
//! mis-assembled, and sharding stages resume only into themselves or
//! across the layout-identical 1 ↔ 2 pair (`ShardingStage::
//! resume_compatible`).  Parameter files always hold the FULL (tp-shard)
//! vector — ZeRO-3 runs assemble it with a blocking DP all-gather at
//! save time and re-slice their shard on resume.
//!
//! Binary payloads are little-endian f32 with an 16-byte header
//! (magic, version, element count, adam step).

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

const MAGIC: u32 = 0x46_4C_4C_4D; // "FLLM"
const VERSION: u32 = 1;

/// Checkpoint metadata (MANIFEST.json).
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub step: u32,
    pub bundle: String,
    /// Global stages (`pp × v`) — NOT worker ranks, so re-chunked resumes
    /// of the same bundle validate.
    pub stages: u32,
    pub tp: u32,
    pub dp: u32,
    /// ZeRO sharding stage (0..=3) the checkpoint was written at; legacy
    /// manifests carried a `zero1` bool, parsed as stage 0/1.
    pub zero_stage: u32,
    /// Engine precision name ("fp32" / "bf16") — resuming under a
    /// different precision is rejected (the optimizer state layout and
    /// the parameter grid both change).
    pub precision: String,
    /// Dynamic loss-scaler state at the checkpointed step, so a resumed
    /// run continues the exact scale schedule.
    pub loss_scale: f32,
    pub scale_good_steps: u32,
}

impl Manifest {
    pub fn to_json(&self) -> String {
        format!(
            "{{\"step\": {}, \"bundle\": {}, \"stages\": {}, \"tp\": {}, \"dp\": {}, \
             \"zero_stage\": {}, \"precision\": {}, \"loss_scale\": {}, \"scale_good_steps\": {}}}",
            self.step,
            crate::util::json::escape(&self.bundle),
            self.stages,
            self.tp,
            self.dp,
            self.zero_stage,
            crate::util::json::escape(&self.precision),
            self.loss_scale,
            self.scale_good_steps
        )
    }

    pub fn from_json(src: &str) -> Result<Self> {
        let j = Json::parse(src).map_err(|e| anyhow!("{e}"))?;
        let stages = match j.u64_field("stages") {
            Ok(s) => s as u32,
            // pre-TP manifests carried the worker-rank count as "pp" and
            // keyed files stage<g>.params.bin — not convertible here
            Err(_) if j.u64_field("pp").is_ok() => {
                return Err(anyhow!(
                    "incompatible checkpoint: pre-tensor-parallel manifest format \
                     (worker-rank keyed); this build keys checkpoints by \
                     (global stage, tp rank) — re-train to produce a new checkpoint"
                ))
            }
            Err(e) => return Err(anyhow!("{e}")),
        };
        Ok(Self {
            step: j.u64_field("step").map_err(|e| anyhow!("{e}"))? as u32,
            bundle: j.str_field("bundle").map_err(|e| anyhow!("{e}"))?,
            stages,
            tp: j.u64_field("tp").map_err(|e| anyhow!("{e}"))? as u32,
            dp: j.u64_field("dp").map_err(|e| anyhow!("{e}"))? as u32,
            zero_stage: match j.u64_field("zero_stage") {
                Ok(s) => s as u32,
                // pre-staged manifests carried a zero1 bool: stage 0 or 1
                Err(_) => u32::from(j.bool_field("zero1").map_err(|e| anyhow!("{e}"))?),
            },
            // pre-mixed-precision checkpoints were all fp32 at scale 1
            precision: j.str_field("precision").unwrap_or_else(|_| "fp32".to_string()),
            loss_scale: j.f64_field("loss_scale").unwrap_or(1.0) as f32,
            scale_good_steps: j.u64_field("scale_good_steps").unwrap_or(0) as u32,
        })
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("MANIFEST.json"), self.to_json())
            .context("writing checkpoint manifest")
    }

    pub fn load(dir: &Path) -> Result<Self> {
        Self::from_json(
            &std::fs::read_to_string(dir.join("MANIFEST.json"))
                .with_context(|| format!("no checkpoint manifest in {dir:?}"))?,
        )
    }
}

/// Write an f32 buffer with header; `aux` carries e.g. the Adam step count.
pub fn write_f32(path: &Path, data: &[f32], aux: u64) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(&MAGIC.to_le_bytes())?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(data.len() as u64).to_le_bytes())?;
    f.write_all(&aux.to_le_bytes())?;
    for v in data {
        f.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read an f32 buffer; returns (data, aux).
pub fn read_f32(path: &Path) -> Result<(Vec<f32>, u64)> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
    );
    let mut h = [0u8; 4];
    f.read_exact(&mut h)?;
    anyhow::ensure!(u32::from_le_bytes(h) == MAGIC, "bad checkpoint magic");
    f.read_exact(&mut h)?;
    anyhow::ensure!(u32::from_le_bytes(h) == VERSION, "unsupported version");
    let mut h8 = [0u8; 8];
    f.read_exact(&mut h8)?;
    let n = u64::from_le_bytes(h8) as usize;
    f.read_exact(&mut h8)?;
    let aux = u64::from_le_bytes(h8);
    let mut bytes = vec![0u8; n * 4];
    f.read_exact(&mut bytes)?;
    let data = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok((data, aux))
}

pub fn params_path(dir: &Path, stage: usize, tp_rank: usize) -> PathBuf {
    dir.join(format!("stage{stage}.tp{tp_rank}.params.bin"))
}

pub fn opt_path(dir: &Path, stage: usize, tp_rank: usize, dp_rank: usize) -> PathBuf {
    dir.join(format!("stage{stage}.tp{tp_rank}.dp{dp_rank}.opt.bin"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_round_trip() {
        let dir = std::env::temp_dir().join(format!("fllm-ckpt-{}", std::process::id()));
        let path = dir.join("x.bin");
        let data: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        write_f32(&path, &data, 42).unwrap();
        let (back, aux) = read_f32(&path).unwrap();
        assert_eq!(back, data);
        assert_eq!(aux, 42);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_round_trip() {
        for stage in 0..4u32 {
            let m = Manifest {
                step: 17,
                bundle: "tiny-s2-mb2".into(),
                stages: 2,
                tp: 4,
                dp: 3,
                zero_stage: stage,
                precision: "bf16".into(),
                loss_scale: 2048.0,
                scale_good_steps: 7,
            };
            let back = Manifest::from_json(&m.to_json()).unwrap();
            assert_eq!(m, back);
            // fractional scales survive too (post-backoff states)
            let m2 = Manifest { loss_scale: 0.03125, ..m };
            assert_eq!(Manifest::from_json(&m2.to_json()).unwrap(), m2);
        }
    }

    #[test]
    fn manifest_without_precision_defaults_to_fp32() {
        // pre-mixed-precision manifests keep loading, and their zero1
        // bool parses onto the stage ladder
        let legacy = "{\"step\": 3, \"bundle\": \"tiny-s2-mb2\", \"stages\": 2, \
                      \"tp\": 1, \"dp\": 1, \"zero1\": false}";
        let m = Manifest::from_json(legacy).unwrap();
        assert_eq!(m.precision, "fp32");
        assert_eq!(m.loss_scale, 1.0);
        assert_eq!(m.scale_good_steps, 0);
        assert_eq!(m.zero_stage, 0);
        let legacy_z1 = "{\"step\": 3, \"bundle\": \"tiny-s2-mb2\", \"stages\": 2, \
                         \"tp\": 1, \"dp\": 2, \"zero1\": true}";
        assert_eq!(Manifest::from_json(legacy_z1).unwrap().zero_stage, 1);
    }

    #[test]
    fn legacy_manifest_gets_targeted_error() {
        let legacy = "{\"step\": 3, \"bundle\": \"tiny-s2-mb2\", \"pp\": 2, \
                      \"dp\": 1, \"zero1\": false}";
        let err = Manifest::from_json(legacy).unwrap_err().to_string();
        assert!(err.contains("pre-tensor-parallel"), "{err}");
    }

    #[test]
    fn paths_key_stage_and_tp_rank() {
        let dir = Path::new("/tmp/x");
        assert!(params_path(dir, 3, 1).ends_with("stage3.tp1.params.bin"));
        assert!(opt_path(dir, 3, 1, 2).ends_with("stage3.tp1.dp2.opt.bin"));
    }

    #[test]
    fn corrupt_file_rejected() {
        let dir = std::env::temp_dir().join(format!("fllm-ckpt2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(read_f32(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
