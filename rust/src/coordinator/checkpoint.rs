//! Training checkpoints: save/restore parameters + optimizer state.
//!
//! Format — one file per **(global stage, tp rank)**, written by that
//! shard's dp-rank-0 worker; DP replicas hold identical parameters so one
//! copy suffices, and under ZeRO stages 1+ each DP rank persists only its
//! own optimizer shard, matching DeepSpeed's per-rank checkpoint layout:
//!
//! ```text
//! ckpt-dir/
//!   MANIFEST.json                 # step, bundle, world shape
//!   stage<g>.tp<t>.params.bin     # f32 LE: flat (sharded) param vector
//!   stage<g>.tp<t>.dp<r>.opt.bin  # f32 LE: adam m ++ adam v (+ step count)
//! ```
//!
//! Keying by *global* stage (not worker rank) means a run can resume
//! under a different pipeline chunking (`v`) of the same bundle; keying
//! by tp rank means every tensor shard round-trips its own slice.  The
//! manifest pins `(bundle, global stages, tp, dp, zero_stage)` —
//! resuming with a different tp or dp is rejected rather than
//! mis-assembled, and sharding stages resume only into themselves or
//! across the layout-identical 1 ↔ 2 pair (`ShardingStage::
//! resume_compatible`).  Parameter files always hold the FULL (tp-shard)
//! vector — ZeRO-3 runs assemble it with a blocking DP all-gather at
//! save time and re-slice their shard on resume.
//!
//! Binary payloads are little-endian f32 with an 16-byte header
//! (magic, version, element count, adam step).

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::collectives::chunk_bounds;
use crate::util::json::Json;

const MAGIC: u32 = 0x46_4C_4C_4D; // "FLLM"
const VERSION: u32 = 1;

/// Checkpoint metadata (MANIFEST.json).
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub step: u32,
    pub bundle: String,
    /// Global stages (`pp × v`) — NOT worker ranks, so re-chunked resumes
    /// of the same bundle validate.
    pub stages: u32,
    pub tp: u32,
    pub dp: u32,
    /// ZeRO sharding stage (0..=3) the checkpoint was written at; legacy
    /// manifests carried a `zero1` bool, parsed as stage 0/1.
    pub zero_stage: u32,
    /// Engine precision name ("fp32" / "bf16") — resuming under a
    /// different precision is rejected (the optimizer state layout and
    /// the parameter grid both change).
    pub precision: String,
    /// Dynamic loss-scaler state at the checkpointed step, so a resumed
    /// run continues the exact scale schedule.
    pub loss_scale: f32,
    pub scale_good_steps: u32,
    /// Effective inter-node gradient wire the run used ("fp32" / "bf16" /
    /// "int8").  int8 re-quantizes, so resuming under a different wire
    /// silently changes the trajectory — mismatches are rejected.  Legacy
    /// manifests (no field) derive the wire from their precision, which
    /// is exactly what `EngineConfig::effective_grad_wire` does for runs
    /// that never passed `--grad-wire`.
    pub grad_wire: String,
    /// Node count the run was packed onto (0 = flat legacy collectives;
    /// legacy manifests default to 1).  Recorded so tier-split payload
    /// counters can be interpreted after a placement change — never a
    /// resume blocker, since placement does not affect values.
    pub nodes: u32,
}

impl Manifest {
    pub fn to_json(&self) -> String {
        format!(
            "{{\"step\": {}, \"bundle\": {}, \"stages\": {}, \"tp\": {}, \"dp\": {}, \
             \"zero_stage\": {}, \"precision\": {}, \"loss_scale\": {}, \"scale_good_steps\": {}, \
             \"grad_wire\": {}, \"nodes\": {}}}",
            self.step,
            crate::util::json::escape(&self.bundle),
            self.stages,
            self.tp,
            self.dp,
            self.zero_stage,
            crate::util::json::escape(&self.precision),
            self.loss_scale,
            self.scale_good_steps,
            crate::util::json::escape(&self.grad_wire),
            self.nodes
        )
    }

    pub fn from_json(src: &str) -> Result<Self> {
        let j = Json::parse(src).map_err(|e| anyhow!("{e}"))?;
        let stages = match j.u64_field("stages") {
            Ok(s) => s as u32,
            // pre-TP manifests carried the worker-rank count as "pp" and
            // keyed files stage<g>.params.bin — not convertible here
            Err(_) if j.u64_field("pp").is_ok() => {
                return Err(anyhow!(
                    "incompatible checkpoint: pre-tensor-parallel manifest format \
                     (worker-rank keyed); this build keys checkpoints by \
                     (global stage, tp rank) — re-train to produce a new checkpoint"
                ))
            }
            Err(e) => return Err(anyhow!("{e}")),
        };
        Ok(Self {
            step: j.u64_field("step").map_err(|e| anyhow!("{e}"))? as u32,
            bundle: j.str_field("bundle").map_err(|e| anyhow!("{e}"))?,
            stages,
            tp: j.u64_field("tp").map_err(|e| anyhow!("{e}"))? as u32,
            dp: j.u64_field("dp").map_err(|e| anyhow!("{e}"))? as u32,
            zero_stage: match j.u64_field("zero_stage") {
                Ok(s) => s as u32,
                // pre-staged manifests carried a zero1 bool: stage 0 or 1
                Err(_) => u32::from(j.bool_field("zero1").map_err(|e| anyhow!("{e}"))?),
            },
            // pre-mixed-precision checkpoints were all fp32 at scale 1
            precision: j.str_field("precision").unwrap_or_else(|_| "fp32".to_string()),
            loss_scale: j.f64_field("loss_scale").unwrap_or(1.0) as f32,
            scale_good_steps: j.u64_field("scale_good_steps").unwrap_or(0) as u32,
            // pre-hierarchical manifests never quantized the wire: the
            // effective wire was the precision's native width (fp32 for
            // fp32 runs — the back-compat default — bf16 for bf16 runs)
            grad_wire: j.str_field("grad_wire").unwrap_or_else(|_| {
                j.str_field("precision").unwrap_or_else(|_| "fp32".to_string())
            }),
            nodes: j.u64_field("nodes").unwrap_or(1) as u32,
        })
    }

    /// Validate this manifest against a resuming run's shape.  Bundle,
    /// global stage count, tp, precision, and grad wire must match — a
    /// mismatch there cannot be re-assembled and is rejected hard.  `dp`
    /// deliberately does NOT appear: the optimizer shards are
    /// re-partitioned on load (`reslice_opt_state`), so any dp resumes
    /// any dp — the elastic dp±1 reconfiguration path.  The sharding
    /// stage ladder has its own compatibility rule
    /// (`ShardingStage::resume_compatible`), checked by the coordinator.
    pub fn validate_resume(
        &self,
        bundle: &str,
        stages: u32,
        tp: u32,
        precision: &str,
        grad_wire: &str,
    ) -> Result<()> {
        anyhow::ensure!(
            self.bundle == bundle && self.stages == stages,
            "checkpoint bundle mismatch: {:?} at {} global stages vs this run's {:?} at {} — \
             parameter files cannot be re-assembled across bundles; re-train to produce a \
             new checkpoint",
            self.bundle,
            self.stages,
            bundle,
            stages
        );
        anyhow::ensure!(
            self.tp == tp,
            "checkpoint tensor-parallel degree {} does not match this run's {} — parameter \
             files are keyed by tp rank and tensor shards do not re-slice; re-train to \
             produce a new checkpoint (dp, by contrast, re-partitions on resume)",
            self.tp,
            tp
        );
        anyhow::ensure!(
            self.precision == precision,
            "checkpoint precision {:?} does not match this run's {:?} — the parameter \
             grid and optimizer-state layout both change with precision",
            self.precision,
            precision
        );
        anyhow::ensure!(
            self.grad_wire == grad_wire,
            "checkpoint gradient wire {:?} does not match this run's effective wire {:?} — \
             a re-quantizing wire (int8) changes the trajectory, so resuming across wire \
             formats would silently fork the run; pass a matching --grad-wire/--nodes",
            self.grad_wire,
            grad_wire
        );
        Ok(())
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("MANIFEST.json"), self.to_json())
            .context("writing checkpoint manifest")
    }

    pub fn load(dir: &Path) -> Result<Self> {
        Self::from_json(
            &std::fs::read_to_string(dir.join("MANIFEST.json"))
                .with_context(|| format!("no checkpoint manifest in {dir:?}"))?,
        )
    }
}

/// Write an f32 buffer with header; `aux` carries e.g. the Adam step count.
pub fn write_f32(path: &Path, data: &[f32], aux: u64) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(&MAGIC.to_le_bytes())?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(data.len() as u64).to_le_bytes())?;
    f.write_all(&aux.to_le_bytes())?;
    for v in data {
        f.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read an f32 buffer; returns (data, aux).
pub fn read_f32(path: &Path) -> Result<(Vec<f32>, u64)> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
    );
    let mut h = [0u8; 4];
    f.read_exact(&mut h)?;
    anyhow::ensure!(u32::from_le_bytes(h) == MAGIC, "bad checkpoint magic");
    f.read_exact(&mut h)?;
    anyhow::ensure!(u32::from_le_bytes(h) == VERSION, "unsupported version");
    let mut h8 = [0u8; 8];
    f.read_exact(&mut h8)?;
    let n = u64::from_le_bytes(h8) as usize;
    f.read_exact(&mut h8)?;
    let aux = u64::from_le_bytes(h8);
    let mut bytes = vec![0u8; n * 4];
    f.read_exact(&mut bytes)?;
    let data = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok((data, aux))
}

pub fn params_path(dir: &Path, stage: usize, tp_rank: usize) -> PathBuf {
    dir.join(format!("stage{stage}.tp{tp_rank}.params.bin"))
}

pub fn opt_path(dir: &Path, stage: usize, tp_rank: usize, dp_rank: usize) -> PathBuf {
    dir.join(format!("stage{stage}.tp{tp_rank}.dp{dp_rank}.opt.bin"))
}

/// Re-partition a stage's **sharded** optimizer state (ZeRO stages 1-3)
/// from a checkpoint written at `old_dp` ranks onto `new_dp` ranks:
/// read every old rank's shard file, reassemble the full per-component
/// vectors (Adam `m ++ v`, plus the fp32 masters under bf16 — the
/// component count is derived from the shard sizes, so both layouts
/// re-slice through the same path), and return exactly the state
/// `import_state` expects for `new_dp`'s rank `dp_rank` partition of an
/// `n_params`-element stage.
///
/// The old shards are `chunk_bounds(n_params, old_dp)` spans — contiguous
/// and ascending — so the reassembly is pure placement: the resliced
/// state is bitwise the state a run checkpointed at `new_dp` would have
/// written, which is what keeps post-recovery trajectories bitwise
/// identical to fresh runs at the new world.
pub fn reslice_opt_state(
    dir: &Path,
    stage: usize,
    tp_rank: usize,
    old_dp: usize,
    new_dp: usize,
    dp_rank: usize,
    n_params: usize,
) -> Result<(Vec<f32>, u64)> {
    let old_bounds = chunk_bounds(n_params, old_dp);
    let mut shards: Vec<Vec<f32>> = Vec::with_capacity(old_dp);
    let mut comp: Option<usize> = None;
    let mut t = 0u64;
    for r in 0..old_dp {
        let (s, aux) = read_f32(&opt_path(dir, stage, tp_rank, r))?;
        let (lo, hi) = old_bounds[r];
        let len = hi - lo;
        if len > 0 {
            anyhow::ensure!(
                s.len() % len == 0 && (2..=3).contains(&(s.len() / len)),
                "optimizer shard {stage}.tp{tp_rank}.dp{r} holds {} floats for a \
                 {len}-element partition — expected 2 (m ++ v) or 3 (+ masters) components",
                s.len()
            );
            let c = s.len() / len;
            anyhow::ensure!(
                comp.map_or(true, |c0| c0 == c),
                "optimizer shards disagree on component count (rank {r}: {c} vs {:?})",
                comp
            );
            comp = Some(c);
        } else {
            anyhow::ensure!(s.is_empty(), "empty partition carries optimizer state");
        }
        t = t.max(aux);
        shards.push(s);
    }
    let comp = comp.unwrap_or(2);
    let mut full = vec![vec![0.0f32; n_params]; comp];
    for (r, s) in shards.iter().enumerate() {
        let (lo, hi) = old_bounds[r];
        let len = hi - lo;
        for (k, component) in full.iter_mut().enumerate() {
            component[lo..hi].copy_from_slice(&s[k * len..(k + 1) * len]);
        }
    }
    let (nlo, nhi) = chunk_bounds(n_params, new_dp)[dp_rank];
    let mut out = Vec::with_capacity(comp * (nhi - nlo));
    for component in &full {
        out.extend_from_slice(&component[nlo..nhi]);
    }
    Ok((out, t))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_round_trip() {
        let dir = std::env::temp_dir().join(format!("fllm-ckpt-{}", std::process::id()));
        let path = dir.join("x.bin");
        let data: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        write_f32(&path, &data, 42).unwrap();
        let (back, aux) = read_f32(&path).unwrap();
        assert_eq!(back, data);
        assert_eq!(aux, 42);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_round_trip() {
        for stage in 0..4u32 {
            let m = Manifest {
                step: 17,
                bundle: "tiny-s2-mb2".into(),
                stages: 2,
                tp: 4,
                dp: 3,
                zero_stage: stage,
                precision: "bf16".into(),
                loss_scale: 2048.0,
                scale_good_steps: 7,
                grad_wire: "int8".into(),
                nodes: 2,
            };
            let back = Manifest::from_json(&m.to_json()).unwrap();
            assert_eq!(m, back);
            // fractional scales survive too (post-backoff states)
            let m2 = Manifest { loss_scale: 0.03125, ..m };
            assert_eq!(Manifest::from_json(&m2.to_json()).unwrap(), m2);
        }
    }

    #[test]
    fn manifest_without_precision_defaults_to_fp32() {
        // pre-mixed-precision manifests keep loading, and their zero1
        // bool parses onto the stage ladder
        let legacy = "{\"step\": 3, \"bundle\": \"tiny-s2-mb2\", \"stages\": 2, \
                      \"tp\": 1, \"dp\": 1, \"zero1\": false}";
        let m = Manifest::from_json(legacy).unwrap();
        assert_eq!(m.precision, "fp32");
        assert_eq!(m.loss_scale, 1.0);
        assert_eq!(m.scale_good_steps, 0);
        assert_eq!(m.zero_stage, 0);
        // pre-hierarchical manifests ran a flat fp32 wire on one node
        assert_eq!(m.grad_wire, "fp32");
        assert_eq!(m.nodes, 1);
        let legacy_z1 = "{\"step\": 3, \"bundle\": \"tiny-s2-mb2\", \"stages\": 2, \
                         \"tp\": 1, \"dp\": 2, \"zero1\": true}";
        assert_eq!(Manifest::from_json(legacy_z1).unwrap().zero_stage, 1);
    }

    #[test]
    fn legacy_grad_wire_follows_precision() {
        // a pre-hierarchical bf16 manifest trained with a bf16 wire; defaulting
        // its grad_wire to fp32 would spuriously reject every legacy bf16 resume
        let legacy = "{\"step\": 3, \"bundle\": \"tiny-s2-mb2\", \"stages\": 2, \
                      \"tp\": 1, \"dp\": 1, \"zero1\": false, \"precision\": \"bf16\"}";
        let m = Manifest::from_json(legacy).unwrap();
        assert_eq!(m.grad_wire, "bf16");
        assert_eq!(m.nodes, 1);
    }

    #[test]
    fn validate_resume_rejects_shape_not_dp() {
        let m = Manifest {
            step: 4,
            bundle: "tiny-s2-mb2".into(),
            stages: 2,
            tp: 2,
            dp: 3,
            zero_stage: 1,
            precision: "bf16".into(),
            loss_scale: 1024.0,
            scale_good_steps: 2,
            grad_wire: "bf16".into(),
            nodes: 1,
        };
        // dp deliberately absent: any dp re-partitions on resume
        m.validate_resume("tiny-s2-mb2", 2, 2, "bf16", "bf16").unwrap();
        let tp_err = m
            .validate_resume("tiny-s2-mb2", 2, 4, "bf16", "bf16")
            .unwrap_err()
            .to_string();
        assert!(tp_err.contains("re-partitions"), "{tp_err}");
        assert!(m.validate_resume("other", 2, 2, "bf16", "bf16").is_err());
        assert!(m.validate_resume("tiny-s2-mb2", 3, 2, "bf16", "bf16").is_err());
        assert!(m.validate_resume("tiny-s2-mb2", 2, 2, "fp32", "bf16").is_err());
        let wire_err = m
            .validate_resume("tiny-s2-mb2", 2, 2, "bf16", "int8")
            .unwrap_err()
            .to_string();
        assert!(wire_err.contains("grad-wire"), "{wire_err}");
    }

    #[test]
    fn reslice_opt_state_round_trips() {
        let dir = std::env::temp_dir().join(format!("fllm-reslice-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let n = 13usize;
        for comp in [2usize, 3] {
            // write a dp=3 checkpoint of a comp-component state vector
            let full: Vec<Vec<f32>> = (0..comp)
                .map(|k| (0..n).map(|i| (k * 100 + i) as f32 + 0.5).collect())
                .collect();
            for (r, &(lo, hi)) in chunk_bounds(n, 3).iter().enumerate() {
                let mut shard = Vec::new();
                for component in &full {
                    shard.extend_from_slice(&component[lo..hi]);
                }
                write_f32(&opt_path(&dir, 1, 0, r), &shard, 9).unwrap();
            }
            // reslice onto dp=2 and check each new rank sees exactly its partition
            for (r, &(lo, hi)) in chunk_bounds(n, 2).iter().enumerate() {
                let (s, t) = reslice_opt_state(&dir, 1, 0, 3, 2, r, n).unwrap();
                assert_eq!(t, 9);
                let mut want = Vec::new();
                for component in &full {
                    want.extend_from_slice(&component[lo..hi]);
                }
                assert_eq!(s, want, "comp={comp} rank={r}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_manifest_gets_targeted_error() {
        let legacy = "{\"step\": 3, \"bundle\": \"tiny-s2-mb2\", \"pp\": 2, \
                      \"dp\": 1, \"zero1\": false}";
        let err = Manifest::from_json(legacy).unwrap_err().to_string();
        assert!(err.contains("pre-tensor-parallel"), "{err}");
    }

    #[test]
    fn paths_key_stage_and_tp_rank() {
        let dir = Path::new("/tmp/x");
        assert!(params_path(dir, 3, 1).ends_with("stage3.tp1.params.bin"));
        assert!(opt_path(dir, 3, 1, 2).ends_with("stage3.tp1.dp2.opt.bin"));
    }

    #[test]
    fn corrupt_file_rejected() {
        let dir = std::env::temp_dir().join(format!("fllm-ckpt2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(read_f32(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
