//! The distributed-training engine (the paper's L3 contribution, executed
//! for real).
//!
//! One OS thread per simulated GCD.  The world is the full 3-D
//! `pp × dp × tp` grid (Megatron ordering — TP innermost, so a TP group
//! is `tp` consecutive ranks, the §III.A placement rule): pipeline
//! workers execute the *same* `schedule::Schedule` instruction streams
//! the simulator prices, pass activations/gradients through the
//! `collectives::Group` mailboxes, run per-layer tensor-parallel
//! all-reduces through their `collectives::SubGroup`, accumulate
//! gradients over micro-batches, and synchronise per-stage DP groups
//! through a real ring all-reduce (or, under sharding stages 2+, a
//! partition-aligned reduce-scatter whose shards are all each rank ever
//! materialises) before the sharded Adam step.
//!
//! **Virtual stages:** with `Interleaved1F1B { v }` the bundle's
//! `n_stages` stage executables are split `v` per worker — worker `r`
//! hosts the model chunks with global stages `{r, r+p, ..., r+(v-1)p}`
//! where `p = n_stages / v` — and chunked activations/gradients are
//! multiplexed over the worker mailboxes with `(direction, chunk, mb)`
//! tags.  Plain GPipe/1F1B are the `v = 1` case (one chunk per worker).
//!
//! **Tensor parallelism:** with `tp > 1` every pipeline worker becomes
//! `tp` shard threads.  Each shard owns its column/row slice of every
//! hosted chunk (Megatron §II.B: column-parallel first linear,
//! row-parallel second linear, vocab-sharded embed, vocab-parallel head)
//! and replays the *same* instruction stream SPMD; the per-layer forward
//! and backward all-reduces run inside the stage entry points through
//! the shard's `TpComm`.  Activations cross pipeline boundaries p2p
//! between *corresponding* tp ranks (each shard holds the full activation
//! after its row-parallel all-reduce, exactly like Megatron).  Only
//! builtin bundles shard; the AOT artifacts stay tensor-dense.
//!
//! Compute is either the AOT-compiled JAX/Pallas stage executables loaded
//! by [`crate::runtime`] (Python is never on this path) or the pure-Rust
//! builtin reference stages (`builtin:*` bundles) — both behind the same
//! typed stage contract.
//!
//! ```text
//!            leader (train)
//!   ┌───────────┬───────────┐          losses / metrics (mpsc)
//!   │ worker 0  │ worker 1  │ ...
//!   │ dp=0 dp=1 │ dp=0 dp=1 │   <- worker threads, one per "GCD",
//!   │ tp0…tpk   │ tp0…tpk   │      v chunk slots each
//!   └───────────┴───────────┘
//!     activations ->  <- gradients     (world group, tagged mailboxes)
//!     TP all-reduce per layer          (per-cell SubGroup of the world)
//!     DP all-reduce per chunk          (per (pp, tp) row Group)
//! ```

pub mod checkpoint;
pub mod worker;

use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;

use anyhow::{anyhow, Context, Result};

use crate::collectives::{Algo, Group, NodeMap, PeerLost, SubGroup};
use crate::config::ScheduleKind;
use crate::metrics::StepTimer;
use crate::optim::{AdamConfig, LrSchedule};
use crate::precision::{CastPolicy, Dtype, GradWire};
use crate::runtime::{Bundle, BuiltinSpec, Runtime, StageBackend};
use crate::schedule;
use crate::topology::{packed_gpu_of, Machine, GPUS_PER_NODE};
use crate::trace::{self, CounterSet};
use crate::zero::ShardingStage;

/// Deterministic fault injection (CLI `--fault`): reproduce the failure
/// modes the paper's 1024+-GCD runs hit in production, on demand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSpec {
    /// `kill@<step>:<rank>` — world rank `rank` dies at the top of step
    /// `step`, before any collective of that step.  Its peers hit the
    /// comm deadline (`PeerLost`), the coordinator stops the world at the
    /// last completed checkpoint, and a dp−1 world resumes from it.
    Kill { step: u32, rank: usize },
    /// `join@<step>` — a planned capacity increase: the run checkpoints
    /// at `step` and a dp+1 world resumes from that manifest.
    Join { step: u32 },
    /// `ckpt-crash@<step>:<rank>` — world rank `rank` dies *inside* the
    /// save that would commit generation `step` (after some of its files
    /// are staged, before the commit).  The torn staging dir is never
    /// eligible for resume, so recovery restarts from the last
    /// *committed* generation — the crash-consistency contract.
    CkptCrash { step: u32, rank: usize },
    /// `write-fail@<step>:<rank>:<count>` — the first `count` checkpoint
    /// write attempts of generation `step` on world rank `rank` fail
    /// transiently.  The save path's bounded retry-with-backoff absorbs
    /// budgets under the retry limit; bigger budgets become hard errors.
    WriteFail { step: u32, rank: usize, count: u32 },
}

impl FaultSpec {
    /// Parse one fault: `kill@<step>:<rank>`, `join@<step>`,
    /// `ckpt-crash@<step>:<rank>`, or `write-fail@<step>:<rank>:<count>`.
    pub fn parse(s: &str) -> Option<Self> {
        if let Some(rest) = s.strip_prefix("kill@") {
            let (step, rank) = rest.split_once(':')?;
            return Some(FaultSpec::Kill { step: step.parse().ok()?, rank: rank.parse().ok()? });
        }
        if let Some(rest) = s.strip_prefix("join@") {
            return Some(FaultSpec::Join { step: rest.parse().ok()? });
        }
        if let Some(rest) = s.strip_prefix("ckpt-crash@") {
            let (step, rank) = rest.split_once(':')?;
            return Some(FaultSpec::CkptCrash {
                step: step.parse().ok()?,
                rank: rank.parse().ok()?,
            });
        }
        if let Some(rest) = s.strip_prefix("write-fail@") {
            let mut it = rest.split(':');
            let (step, rank, count) = (it.next()?, it.next()?, it.next()?);
            if it.next().is_some() {
                return None;
            }
            return Some(FaultSpec::WriteFail {
                step: step.parse().ok()?,
                rank: rank.parse().ok()?,
                count: count.parse().ok()?,
            });
        }
        None
    }

    /// Parse the full CLI grammar: a comma-separated fault list, e.g.
    /// `kill@5:1,ckpt-crash@8:0`.  Malformed items and duplicate steps
    /// (two faults scheduled at the same step would race recovery
    /// nondeterministically) are rejected with a targeted message.
    pub fn parse_list(s: &str) -> Result<Vec<Self>, String> {
        let mut out: Vec<FaultSpec> = Vec::new();
        for item in s.split(',') {
            let item = item.trim();
            if item.is_empty() {
                return Err(format!("empty fault in list {s:?}"));
            }
            let f = Self::parse(item).ok_or_else(|| {
                format!(
                    "malformed fault {item:?}: expected kill@<step>:<rank>, join@<step>, \
                     ckpt-crash@<step>:<rank>, or write-fail@<step>:<rank>:<count>"
                )
            })?;
            if out.iter().any(|o| o.step() == f.step()) {
                return Err(format!(
                    "duplicate fault step {}: two faults at the same step would race \
                     recovery nondeterministically",
                    f.step()
                ));
            }
            out.push(f);
        }
        Ok(out)
    }

    /// The step this fault fires at (kill/join: the training step;
    /// ckpt-crash/write-fail: the checkpoint generation's step).
    pub fn step(&self) -> u32 {
        match *self {
            FaultSpec::Kill { step, .. }
            | FaultSpec::Join { step }
            | FaultSpec::CkptCrash { step, .. }
            | FaultSpec::WriteFail { step, .. } => step,
        }
    }

    /// Does this fault take a rank down (requiring timeout-driven
    /// recovery in its peers)?
    pub fn is_killing(&self) -> bool {
        matches!(self, FaultSpec::Kill { .. } | FaultSpec::CkptCrash { .. })
    }
}

/// The typed error a fault-killed worker dies with — the coordinator
/// downcasts it to tell an injected kill from a real worker failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KilledByFault {
    pub step: u32,
    pub rank: usize,
}

impl fmt::Display for KilledByFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "fault injection killed world rank {} at the top of step {}",
            self.rank, self.step
        )
    }
}

impl std::error::Error for KilledByFault {}

/// Engine configuration for one training run.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Artifact root (usually `artifacts/`).
    pub artifacts_root: PathBuf,
    /// Bundle directory name, e.g. `tiny-s2-mb2` (see `Bundle::dir_name`),
    /// or a builtin bundle like `builtin:tiny-s4-mb2` (no artifacts, no
    /// PJRT — the pure-Rust reference stages).
    pub bundle: String,
    /// Data-parallel replicas.
    pub dp: usize,
    /// Tensor-parallel shards per pipeline worker (builtin bundles only;
    /// the AOT artifacts are compiled tensor-dense).
    pub tp: usize,
    /// Expert-parallel group size for `builtin:*-moe*` bundles: each
    /// (pp, tp) cell's DP replicas split into blocks of `ep` consecutive
    /// ranks that shard the expert *compute* `ep` ways (rank `r` of a
    /// block owns experts `[r·E/ep, (r+1)·E/ep)`) and exchange routed
    /// tokens through the deterministic `all_to_all`.  Expert
    /// *parameters* stay DP-replicated — the optimizer, ZeRO sharding
    /// and checkpoints see the identical flat vector at any `ep` — so
    /// `ep` changes only where expert FLOPs run and what crosses the
    /// wire; trajectories are ep-invariant (bitwise at fp32).  Requires
    /// `experts % ep == 0` and `ep | dp`; an elastic leg whose shrunken
    /// dp breaks divisibility falls back to `ep = 1` for that world.
    pub ep: usize,
    /// MoE capacity factor: each expert accepts at most
    /// `ceil(cf · tokens · topk / experts)` tokens per micro-batch
    /// (clamped to `tokens`); overflow tokens lose that expert's combine
    /// contribution (dropped) and count into
    /// `TrainReport::moe_dropped_tokens`.  1.25 is the GShard default;
    /// ignored by dense bundles.
    pub capacity_factor: f32,
    pub schedule: ScheduleKind,
    /// Micro-batches per replica per step (gradient-accumulation steps).
    pub microbatches: u32,
    pub steps: u32,
    pub adam: AdamConfig,
    pub lr_schedule: Option<LrSchedule>,
    /// ZeRO sharding stage across the DP group: 0 = plain DDP, 1 =
    /// optimizer states sharded, 2 = + reduce-scattered gradient shards,
    /// 3 = + on-demand-gathered parameter shards (builtin bundles only —
    /// the gathered views are host buffers).  CLI: `--zero-stage`
    /// (`--zero1` survives as a deprecated alias for stage 1).
    pub zero_stage: ShardingStage,
    /// Overlap DP gradient sync with the backward pass: each chunk's
    /// gradient buckets launch (nonblocking) as soon as its last
    /// micro-batch backward finishes, and drain just before the
    /// optimizer step.  `false` launches the same buckets after the op
    /// stream (sequential sync).  Loss trajectories are **bit-identical**
    /// either way — the bucketed all-reduce reduces in rank order
    /// regardless of deposit timing.
    pub overlap_grad_sync: bool,
    /// Gradient-bucket granularity (f32 elements per nonblocking
    /// all-reduce bucket); DeepSpeed's `allreduce_bucket_size` analogue.
    pub grad_bucket_floats: usize,
    /// Collective algorithm for the small syncs (grad-norm combine,
    /// loss reduction, the loss-scaler's overflow agreement) AND —
    /// since the wire became dtype-aware — the tensor-parallel
    /// all-reduces (`Naive` selects the deposit-exchange fold, whose
    /// f32 association order differs from `Ring`'s; the default `Ring`
    /// keeps the PR-3 fp32 numerics bit for bit).
    pub collective_algo: Algo,
    /// Numeric precision of the run.  `F32` is the bitwise-pinned legacy
    /// engine.  `Bf16` (builtin bundles only) stores params/activations/
    /// grads on the bf16 grid with f32-accumulating kernels, keeps fp32
    /// master weights in the optimizer, halves every collective payload
    /// (packed-u16 wire), and arms the dynamic loss scaler.
    pub precision: Dtype,
    /// Initial loss scale (a power of two keeps scaling bitwise-neutral;
    /// 1.0 + fp32 leaves the scaling machinery fully inert).
    pub loss_scale_init: f32,
    /// Consecutive overflow-free steps before the scale doubles
    /// (0 = static scale, the default).
    pub loss_scale_growth_interval: u32,
    /// Number of Frontier nodes the world is packed onto (CLI `--nodes`).
    /// `0` keeps the legacy flat collectives (no topology attached).
    /// With `nodes >= 1` ranks take the packed placement
    /// (`topology::packed_gpu_of`), DP groups get node maps derived from
    /// their members' GCD ids, and every sharded collective runs the
    /// hierarchical two-tier path — bitwise-identical to flat under a
    /// value-preserving grad wire, with per-tier byte counters split into
    /// `*_intra_bytes` / `*_inter_bytes`.
    pub nodes: u32,
    /// Wire format of the *inter-node* hop of hierarchical gradient
    /// collectives (CLI `--grad-wire {fp32,bf16,int8}`).  `None` derives
    /// the wire from `precision` (fp32 -> fp32, bf16 -> bf16), which
    /// never re-quantizes and so keeps hierarchical ≡ flat bitwise.
    /// `Int8` swaps in the blockwise-scaled quantized wire (per-128-block
    /// f32 scale + i8 codes, deterministic RNE) — ~4x fewer inter-node
    /// bytes at a bounded, deterministic rounding cost.  Requires
    /// `nodes >= 1`.
    pub grad_wire: Option<GradWire>,
    /// ZeRO-3 gather lookahead depth (CLI `--zero3-prefetch`): how many
    /// *future* parameter uses each rank keeps in flight beyond the one
    /// it is redeeming.  The residency bound is `(N+1)` gathered chunks;
    /// `1` reproduces the PR-5 gather-use-drop pipeline exactly.
    pub zero3_prefetch: usize,
    pub seed: u64,
    /// Print a progress line every `log_every` steps (0 = silent).
    pub log_every: u32,
    /// When set, save a checkpoint here at the end of the run (and every
    /// `checkpoint_every` steps if > 0).
    pub checkpoint_dir: Option<PathBuf>,
    pub checkpoint_every: u32,
    /// Resume from `checkpoint_dir` (params + optimizer + data cursor).
    pub resume: bool,
    /// Persist checkpoints on a background saver thread: at the save
    /// barrier each rank snapshots its state in memory (Arc clones — the
    /// optimizer's copy-on-write keeps the snapshot isolated) and the
    /// step loop resumes immediately while I/O drains.  Saved bytes and
    /// trajectories are bitwise identical to sync saves.
    pub async_checkpoint: bool,
    /// Committed checkpoint generations to retain (`--ckpt-keep N`,
    /// minimum 1): a chain of last-good fallbacks for corrupt or torn
    /// newest generations.
    pub ckpt_keep: usize,
    /// Deadline on every collective wait (p2p recv, barrier, nonblocking
    /// all-reduce / all-gather drains), in milliseconds.  `0` leaves the
    /// waits unbounded — the unit-test default, where a slow CI machine
    /// must not fail a correct run.  The CLI arms 10 s by default, so a
    /// dead peer surfaces as a diagnostic [`PeerLost`] (rank + tag)
    /// instead of a silent permanent hang.  A scheduled `kill` fault
    /// arms a 5 s deadline even at 0: recovery starts from a timeout.
    pub comm_timeout_ms: u64,
    /// Deterministic fault injection (`--fault kill@S:R,join@S,...` —
    /// a comma-separated list, at most one fault per step); empty
    /// (default) injects nothing.
    pub faults: Vec<FaultSpec>,
    /// Write the merged per-rank span timeline here as Chrome Trace
    /// Event Format JSON after the run (CLI `--trace-out`; one `pid`
    /// per worker rank, one `tid` per chunk slot — loads in Perfetto).
    /// `None` (default) records nothing: every instrumentation site is
    /// a thread-local no-op and the trajectory is bitwise identical.
    pub trace_out: Option<PathBuf>,
    /// Stream one self-describing JSON object per step here (CLI
    /// `--metrics-jsonl`): loss/scale/wall time, per-category trace
    /// milliseconds, and the per-step delta of every engine counter.
    pub metrics_jsonl: Option<PathBuf>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            artifacts_root: PathBuf::from("artifacts"),
            bundle: String::from("tiny-s2-mb2"),
            dp: 1,
            tp: 1,
            ep: 1,
            capacity_factor: 1.25,
            schedule: ScheduleKind::OneF1B,
            microbatches: 2,
            steps: 10,
            adam: AdamConfig::default(),
            lr_schedule: None,
            zero_stage: ShardingStage::Ddp,
            overlap_grad_sync: true,
            grad_bucket_floats: 1 << 15,
            collective_algo: Algo::Ring,
            precision: Dtype::F32,
            loss_scale_init: 1.0,
            loss_scale_growth_interval: 0,
            nodes: 0,
            grad_wire: None,
            zero3_prefetch: 1,
            seed: 1234,
            log_every: 0,
            checkpoint_dir: None,
            checkpoint_every: 0,
            resume: false,
            async_checkpoint: false,
            ckpt_keep: 2,
            comm_timeout_ms: 0,
            faults: Vec::new(),
            trace_out: None,
            metrics_jsonl: None,
        }
    }
}

impl EngineConfig {
    /// The grad wire the run actually uses on the inter-node hop:
    /// explicit `--grad-wire`, else derived from the storage precision.
    pub fn effective_grad_wire(&self) -> GradWire {
        self.grad_wire.unwrap_or(GradWire::for_dtype(self.precision))
    }

    /// Hierarchical (topology-aware) collectives enabled?
    pub fn hier(&self) -> bool {
        self.nodes >= 1
    }

    /// Does this run record spans / stream metrics (either export set)?
    pub fn trace_enabled(&self) -> bool {
        self.trace_out.is_some() || self.metrics_jsonl.is_some()
    }
}

/// Per-step record (what the e2e example logs as the loss curve).
#[derive(Debug, Clone)]
pub struct StepLog {
    pub step: u32,
    /// Mean training loss across every micro-batch and DP replica.
    pub loss: f32,
    /// Pre-clip gradient norm combined over the reporting worker's
    /// hosted chunks (per-chunk norms are TP/DP-global; see `zero`);
    /// `INFINITY` on loss-scaler-skipped steps.
    pub grad_norm: f32,
    pub step_time_s: f64,
    /// Loss scale after this step's scaler update — what the next step
    /// will apply (constant 1.0 under fp32; matches the checkpointed
    /// scaler state at every step boundary).
    pub loss_scale: f32,
    /// Whether the optimizer step was skipped by the loss scaler.
    pub skipped: bool,
}

/// Outcome of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub logs: Vec<StepLog>,
    pub world_size: usize,
    pub total_params: u64,
    pub tokens_per_step: u64,
    pub mean_step_time_s: f64,
    pub tokens_per_sec: f64,
    /// Bytes moved through collectives (p2p + all-reduce) over the run.
    pub comm_bytes: u64,
    /// Tensor-parallel all-reduce payload bytes (logical reduced volume,
    /// summed over every TP subgroup) — cross-validated against the
    /// analytic TP comm term in `perf` by the engine tests.
    pub tp_ar_bytes: u64,
    /// Tensor-parallel all-reduce rounds executed across the run.
    pub tp_ar_rounds: u64,
    /// DP gradient-sync seconds *hidden* under backward compute
    /// (bucket launches + reductions issued mid-stream), summed over
    /// workers — the measured-overlap perf contract's numerator.
    pub dp_sync_hidden_s: f64,
    /// DP gradient-sync seconds *exposed* on the critical path
    /// (post-backward launches + drain waits), summed over workers.
    pub dp_sync_exposed_s: f64,
    /// Nonblocking gradient-bucket rounds completed across every DP
    /// group — pinned EXACTLY against the analytic bucket count
    /// (`steps × Σ_stages ⌈params / grad_bucket_floats⌉`) by the
    /// overlap tests, the way PR 2 pinned TP all-reduce bytes.
    pub dp_bucket_rounds: u64,
    /// Logical DP gradient-bucket payload bytes (element count × wire
    /// dtype, once per bucket round) — pinned EXACTLY against
    /// `perf::dp_grad_payload_bytes` per step; exactly halves under bf16.
    pub dp_bucket_payload_bytes: u64,
    /// Logical parameter all-gather payload bytes: the stage-1/2
    /// updated-parameter gathers (the second half of the reduce-scatter
    /// + all-gather wire accounting) or ZeRO-3's on-demand per-use
    /// gathers; 0 for plain DDP, which never gathers.
    pub dp_param_ag_bytes: u64,
    /// Logical pipeline p2p activation payload bytes (boundary
    /// activations down + boundary gradients up, element count × wire
    /// dtype) — pinned EXACTLY against `perf`'s PP p2p term; exactly
    /// halves under the packed-bf16 activation wire.
    pub pp_p2p_payload_bytes: u64,
    /// Per-tier split of the DP gradient-sync payload under hierarchical
    /// collectives (`nodes >= 1`): bytes crossing *intra-node* links
    /// (phase-1 reduce up to the node representative + phase-3 fan back
    /// out) at the storage wire width.  0 in flat mode.
    pub dp_bucket_intra_bytes: u64,
    /// Bytes crossing the *inter-node* tier (one combined partial per
    /// node entering the exchange) at the grad-wire width — the counter
    /// the int8 wire shrinks ~4x.  0 in flat mode or on one node.
    pub dp_bucket_inter_bytes: u64,
    /// Per-tier split of the parameter all-gather payload (stage-1/2
    /// post-step gathers + ZeRO-3 on-demand gathers, including the
    /// node-local secondary-partition gathers that replace inter-node
    /// traffic after first touch).  0 in flat mode.
    pub dp_param_ag_intra_bytes: u64,
    /// Inter-node tier of the parameter all-gathers (representatives
    /// exchanging the assembled buffer).  0 in flat mode or on one node.
    pub dp_param_ag_inter_bytes: u64,
    /// Per-tier split of the pipeline p2p payload: boundary tensors
    /// between workers co-resident on a node.  0 in flat mode.
    pub pp_p2p_intra_bytes: u64,
    /// Boundary tensors crossing nodes (adjacent pipeline stages placed
    /// on different nodes under packed placement).  0 in flat mode.
    pub pp_p2p_inter_bytes: u64,
    /// Expert-parallel `all_to_all` rounds completed across every EP
    /// group over the run (dispatch and combine count separately) —
    /// pinned EXACTLY against `perf::moe_a2a_rounds_per_step` by the MoE
    /// tests.  0 on dense runs and at `ep = 1` (routing stays
    /// rank-local, no wire).
    pub moe_a2a_rounds: u64,
    /// Logical `all_to_all` payload bytes (Σ part elements × wire dtype
    /// over every src→dst pair including self, once per round) — pinned
    /// EXACTLY against the analytic `perf::moe_a2a_payload_bytes` term;
    /// exactly halves under the packed-bf16 wire.
    pub moe_a2a_payload_bytes: u64,
    /// Per-tier split of the a2a payload under `--nodes` (src ≠ dst
    /// pairs only, classified by the packed placement of the two
    /// endpoints).  0 in flat mode.
    pub moe_a2a_intra_bytes: u64,
    /// Inter-node tier of the a2a payload.  0 in flat mode or when the
    /// EP group sits on one node.
    pub moe_a2a_inter_bytes: u64,
    /// Tokens dropped at expert capacity across the run, summed over DP
    /// replicas, hosted chunks and micro-batches (charged once per
    /// scheduled block forward by each cell's tp=0 shard; backward
    /// recomputes never double-count).
    pub moe_dropped_tokens: u64,
    /// Sharding stage the run executed at.
    pub zero_stage: ShardingStage,
    /// ZeRO-3 gather-use-drop residency: the high-water mark of
    /// full-parameter floats any single rank held gathered at once
    /// (current op + up to `zero3_prefetch` lookahead gathers, so at
    /// most `(N+1)` chunks) — the engine-measured bound the mem model's
    /// per-layer transient term is validated against.  0 unless stage 3
    /// ran with dp > 1.
    pub zero3_peak_gathered_floats: u64,
    /// Resident optimizer-state bytes on the heaviest rank (Adam moments
    /// + fp32 masters; shard-sized under stages 1+) — the measured
    /// shard-bytes figure.
    pub opt_state_bytes_per_rank: u64,
    /// Numeric precision the run executed at.
    pub precision: Dtype,
    /// Loss scale after the final step.
    pub final_loss_scale: f32,
    /// Optimizer steps skipped by the dynamic loss scaler.
    pub steps_skipped: u64,
    /// Elastic reconfigurations the run survived: each fault recovery
    /// (dp−1 restart from the last manifest) or planned join (dp+1)
    /// counts once.  0 on an undisturbed run.
    pub recovery_events: u64,
    /// Optimizer steps whose results were discarded by a fault recovery
    /// — steps the failed world completed beyond its last checkpoint,
    /// recomputed by the shrunken world.  The measured bounded-loss cost
    /// of a failure (≤ `checkpoint_every` by construction).
    pub lost_steps: u64,
    /// Checkpoint-save milliseconds *hidden* behind training — the saver
    /// thread's persist + commit time under `--async-checkpoint`
    /// (classified like the `dp_sync_hidden_s` overlap timer).  0 on the
    /// sync path, where every write is on the critical path.
    pub ckpt_save_hidden_ms: f64,
    /// Checkpoint-save milliseconds *exposed* on the step loop's critical
    /// path: the whole barrier+write+commit on the sync path; only the
    /// barrier + in-memory snapshot hand-off on the async path.
    pub ckpt_save_exposed_ms: f64,
    /// Aggregated span-timeline summary when the run traced
    /// (`--trace-out` / `--metrics-jsonl`); `None` on untraced runs.
    /// Feeds `trace::audit` and the trace block of `render_summary`.
    pub trace_summary: Option<trace::Summary>,
    /// Effective gradient wire dtype of the run's inter-node hop
    /// ([`EngineConfig::effective_grad_wire`]) — recorded so the summary
    /// renders without the config in hand.
    pub grad_wire: GradWire,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        self.logs.last().map(|l| l.loss).unwrap_or(f32::NAN)
    }

    pub fn initial_loss(&self) -> f32 {
        self.logs.first().map(|l| l.loss).unwrap_or(f32::NAN)
    }

    /// Raw (total) DP gradient-sync seconds: hidden + exposed.
    pub fn dp_sync_raw_s(&self) -> f64 {
        self.dp_sync_hidden_s + self.dp_sync_exposed_s
    }

    /// Engine-measured DP overlap fraction, `1 - exposed / raw` — the
    /// same contract function `perf::CostModel` prices its exposed DP
    /// comm term with (see [`crate::perf::dp_overlap_fraction`]).
    pub fn dp_overlap_fraction(&self) -> f64 {
        crate::perf::dp_overlap_fraction(self.dp_sync_raw_s(), self.dp_sync_exposed_s)
    }

    /// Raw (total) checkpoint-save milliseconds: hidden + exposed.
    pub fn ckpt_save_raw_ms(&self) -> f64 {
        self.ckpt_save_hidden_ms + self.ckpt_save_exposed_ms
    }

    /// The run summary every driver prints (`train`, `quickstart`,
    /// `train_e2e` all render this one block — the counters print once,
    /// here, instead of being hand-rolled three times).  Optional lines
    /// appear only when their subsystem ran; a trace block is appended
    /// when the run recorded spans.
    pub fn render_summary(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let kb = |b: u64| b as f64 / 1e3;
        writeln!(
            s,
            "trained {} params on {} workers: loss {:.4} -> {:.4}",
            self.total_params,
            self.world_size,
            self.initial_loss(),
            self.final_loss()
        )
        .unwrap();
        writeln!(s, "tokens/step       : {}", self.tokens_per_step).unwrap();
        writeln!(s, "mean step time    : {:.3} s", self.mean_step_time_s).unwrap();
        writeln!(s, "throughput        : {:.0} tokens/s", self.tokens_per_sec).unwrap();
        writeln!(s, "collective traffic: {:.1} MB", self.comm_bytes as f64 / 1e6).unwrap();
        writeln!(
            s,
            "precision         : {} (loss scale {}, {} skipped steps)",
            self.precision.name(),
            self.final_loss_scale,
            self.steps_skipped
        )
        .unwrap();
        writeln!(
            s,
            "dp wire           : {:.1} KB grad buckets ({} rounds) + {:.1} KB param all-gather",
            kb(self.dp_bucket_payload_bytes),
            self.dp_bucket_rounds,
            kb(self.dp_param_ag_bytes)
        )
        .unwrap();
        writeln!(
            s,
            "zero stage        : {} ({}); {:.1} KB optimizer state/rank{}",
            self.zero_stage.index(),
            self.zero_stage.name(),
            kb(self.opt_state_bytes_per_rank),
            if self.zero3_peak_gathered_floats > 0 {
                format!(
                    ", peak gathered params {:.1} KB (gather-use-drop)",
                    4.0 * self.zero3_peak_gathered_floats as f64 / 1e3
                )
            } else {
                String::new()
            }
        )
        .unwrap();
        if self.pp_p2p_payload_bytes > 0 {
            writeln!(
                s,
                "pp p2p wire       : {:.1} KB boundary activation payload ({} wire)",
                kb(self.pp_p2p_payload_bytes),
                self.precision.name()
            )
            .unwrap();
        }
        if self.tp_ar_rounds > 0 {
            writeln!(
                s,
                "tp all-reduce     : {} rounds, {:.1} MB reduced payload",
                self.tp_ar_rounds,
                self.tp_ar_bytes as f64 / 1e6
            )
            .unwrap();
        }
        if self.moe_a2a_rounds > 0 || self.moe_dropped_tokens > 0 {
            writeln!(
                s,
                "moe a2a wire      : {} rounds, {:.1} KB routed payload \
                 ({:.1} KB intra / {:.1} KB inter), {} token(s) dropped at capacity",
                self.moe_a2a_rounds,
                kb(self.moe_a2a_payload_bytes),
                kb(self.moe_a2a_intra_bytes),
                kb(self.moe_a2a_inter_bytes),
                self.moe_dropped_tokens
            )
            .unwrap();
        }
        let tiered = self.dp_bucket_intra_bytes
            + self.dp_bucket_inter_bytes
            + self.dp_param_ag_intra_bytes
            + self.dp_param_ag_inter_bytes
            + self.pp_p2p_intra_bytes
            + self.pp_p2p_inter_bytes;
        if tiered > 0 {
            writeln!(
                s,
                "hier tiers        : grad sync {:.1} KB intra / {:.1} KB inter ({} wire), \
                 param AG {:.1} KB intra / {:.1} KB inter, \
                 pp p2p {:.1} KB intra / {:.1} KB inter",
                kb(self.dp_bucket_intra_bytes),
                kb(self.dp_bucket_inter_bytes),
                self.grad_wire.name(),
                kb(self.dp_param_ag_intra_bytes),
                kb(self.dp_param_ag_inter_bytes),
                kb(self.pp_p2p_intra_bytes),
                kb(self.pp_p2p_inter_bytes)
            )
            .unwrap();
        }
        if self.dp_sync_raw_s() > 0.0 {
            writeln!(
                s,
                "dp sync           : {:.1} ms raw, {:.1} ms exposed ({:.0}% overlapped)",
                self.dp_sync_raw_s() * 1e3,
                self.dp_sync_exposed_s * 1e3,
                self.dp_overlap_fraction() * 100.0
            )
            .unwrap();
        }
        if self.ckpt_save_raw_ms() > 0.0 {
            writeln!(
                s,
                "ckpt save         : {:.1} ms exposed, {:.1} ms hidden (saver thread)",
                self.ckpt_save_exposed_ms, self.ckpt_save_hidden_ms
            )
            .unwrap();
        }
        if self.recovery_events > 0 {
            writeln!(
                s,
                "elastic           : {} recovery event(s), {} step(s) lost and recomputed, \
                 finished on {} workers",
                self.recovery_events, self.lost_steps, self.world_size
            )
            .unwrap();
        }
        if let Some(t) = &self.trace_summary {
            writeln!(
                s,
                "trace             : {} spans over {} ranks x {} steps; \
                 dp overlap {:.0}%, pp bubble {:.1}%, accounting {:.3}x wall",
                t.events, t.ranks, t.steps, t.dp_overlap * 100.0,
                t.bubble_fraction * 100.0, t.max_busy_over_wall
            )
            .unwrap();
            let mut cats = String::new();
            for cat in trace::RECORDED {
                let ms = t.ms_per_rank_step(cat);
                if ms > 0.0 {
                    if !cats.is_empty() {
                        cats.push_str(", ");
                    }
                    write!(cats, "{} {:.2}", cat.name(), ms).unwrap();
                }
            }
            writeln!(s, "trace ms/step/rank: {cats}").unwrap();
        }
        s
    }
}

/// Run a full training job; blocks until every worker joins.
pub fn train(cfg: &EngineConfig) -> Result<TrainReport> {
    if cfg.bundle.starts_with("builtin:") {
        // builtin bundles need no PJRT client and no artifacts on disk
        let spec = BuiltinSpec::parse(&cfg.bundle).ok_or_else(|| {
            anyhow!(
                "malformed builtin bundle name {:?} (expected builtin:<tiny|mini>-s<K>-mb<B>)",
                cfg.bundle
            )
        })?;
        let bundle = Arc::new(Bundle::builtin_with(
            &spec,
            CastPolicy::for_dtype(cfg.precision),
            cfg.capacity_factor,
        ));
        return train_with_bundle(cfg, Runtime::null(), bundle);
    }
    anyhow::ensure!(
        cfg.precision == Dtype::F32,
        "--precision {} requires a builtin:* bundle — the AOT artifact stages are compiled fp32",
        cfg.precision.name()
    );
    let rt = Runtime::cpu()?;
    let bundle = Arc::new(Bundle::load(&rt, cfg.artifacts_root.join(&cfg.bundle))?);
    train_with_bundle(cfg, rt, bundle)
}

/// Same as [`train`] but with a pre-loaded bundle (benches reuse it).
pub fn train_with_bundle(
    cfg: &EngineConfig,
    rt: Arc<Runtime>,
    bundle: Arc<Bundle>,
) -> Result<TrainReport> {
    let n_stages = bundle.meta.n_stages as usize;
    let dp = cfg.dp;
    let tp = cfg.tp;
    anyhow::ensure!(dp >= 1, "dp must be >= 1");
    anyhow::ensure!(tp >= 1, "tp must be >= 1");
    anyhow::ensure!(cfg.microbatches >= 1, "need at least one micro-batch");
    anyhow::ensure!(
        cfg.loss_scale_init.is_finite() && cfg.loss_scale_init > 0.0,
        "loss scale must be positive and finite"
    );
    if cfg.precision != Dtype::F32 {
        // mixed precision needs stages built under the matching policy
        // (train() does this for builtin bundles; pre-built bundles from
        // benches must opt in explicitly via Bundle::builtin_with_policy)
        let want = CastPolicy::for_dtype(cfg.precision);
        let ok = bundle.stages.iter().all(
            |s| matches!(&s.backend, StageBackend::Builtin(st) if st.policy == want),
        );
        anyhow::ensure!(
            ok,
            "--precision {} requires a builtin:* bundle built with the matching cast \
             policy (AOT artifact stages are compiled fp32-dense)",
            cfg.precision.name()
        );
    }
    if cfg.zero_stage.shards_params() {
        // ZeRO-3 hands each op a host-buffer gathered parameter view;
        // the XLA artifact stages stage device buffers instead
        anyhow::ensure!(
            cfg.bundle.starts_with("builtin:"),
            "--zero-stage 3 requires a builtin:* bundle — the AOT artifact stages \
             stage device parameter buffers, not on-demand gathered host views"
        );
    }
    if tp > 1 {
        // only the builtin backend shards; fail fast with a clear message
        // (tp_shard re-validates per stage)
        anyhow::ensure!(
            cfg.bundle.starts_with("builtin:"),
            "tensor parallelism (tp = {tp}) requires a builtin:* bundle — \
             AOT artifact stages are compiled tensor-dense"
        );
        let spec = BuiltinSpec::parse(&cfg.bundle)
            .ok_or_else(|| anyhow!("malformed builtin bundle {:?}", cfg.bundle))?;
        anyhow::ensure!(
            spec.tp_ok(tp),
            "tp {tp} must divide hidden {} and vocab {}",
            spec.hidden,
            spec.vocab
        );
    }
    anyhow::ensure!(cfg.ep >= 1, "ep must be >= 1");
    anyhow::ensure!(
        cfg.capacity_factor.is_finite() && cfg.capacity_factor > 0.0,
        "--capacity-factor must be positive and finite"
    );
    if cfg.ep > 1 {
        // expert parallelism routes tokens between the builtin MoE
        // stages; fail fast with the divisibility contract spelled out
        anyhow::ensure!(
            cfg.bundle.starts_with("builtin:"),
            "expert parallelism (ep = {}) requires a builtin:*-moe* bundle — \
             AOT artifact stages are compiled dense",
            cfg.ep
        );
        let spec = BuiltinSpec::parse(&cfg.bundle)
            .ok_or_else(|| anyhow!("malformed builtin bundle {:?}", cfg.bundle))?;
        anyhow::ensure!(
            spec.moe,
            "expert parallelism (ep = {}) needs a MoE bundle \
             (builtin:*-moe<E>[k<K>]-*); {:?} is dense",
            cfg.ep,
            cfg.bundle
        );
        anyhow::ensure!(
            spec.experts % cfg.ep == 0,
            "ep {} must divide the bundle's expert count {}: every EP rank owns \
             experts/ep whole experts",
            cfg.ep,
            spec.experts
        );
        anyhow::ensure!(
            cfg.dp % cfg.ep == 0,
            "ep {} must divide dp {}: EP groups are blocks of ep consecutive \
             DP replicas",
            cfg.ep,
            cfg.dp
        );
    }

    // virtual chunking: v stage executables per worker
    let v = cfg.schedule.chunks() as usize;
    anyhow::ensure!(
        v >= 1 && n_stages % v == 0,
        "interleave v={v} must divide the bundle's {n_stages} stages"
    );
    let pp = n_stages / v;
    if v > 1 {
        anyhow::ensure!(
            cfg.microbatches as usize % pp == 0,
            "interleaved 1F1B needs micro-batches ({}) divisible by pipeline ranks ({pp})",
            cfg.microbatches
        );
    }
    if let Some(wire) = cfg.grad_wire {
        anyhow::ensure!(
            cfg.nodes >= 1 || wire == GradWire::for_dtype(cfg.precision),
            "--grad-wire {} only shapes the inter-node hop of hierarchical \
             collectives — pass --nodes N (>= 1) to enable them",
            wire.name()
        );
    }
    // the per-node packing bound is checked inside run_world: dp (and so
    // the world size) changes across elastic legs

    let sched = schedule::build(cfg.schedule, pp as u32, cfg.microbatches);
    sched.validate().map_err(|e| anyhow!("invalid schedule: {e}"))?;
    let sched = Arc::new(sched);

    // ---- elastic outer loop -----------------------------------------------
    // Each iteration runs one *world* (a full set of worker threads at the
    // current dp).  On a fault — the injected kill, or a peer lost to a
    // collective deadline — the world stops at its last manifest and a new
    // one launches at dp−1, re-partitioning the optimizer shards on load;
    // a planned `join@N` splits the run at N and grows to dp+1.  Recovery
    // is literally "a fresh run at the new world resuming from the
    // checkpoint" — the same code path — which is what makes the
    // post-recovery trajectory bitwise identical to one
    // (`tests/elastic.rs` locks the full stage × precision grid).
    let mut attempt = cfg.clone();
    let mut resume = resolve_resume(&attempt, n_stages)?;
    let total_target = resume.start_step + cfg.steps;
    let opt_state_bytes = Arc::new(AtomicU64::new(0));
    let mut logs: Vec<StepLog> = Vec::new();
    let mut counters = CounterSet::default();
    // the registry outlives every elastic leg: worker threads of each
    // world flush their span buffers into it on exit, and the leader
    // harvests per-step counter snapshots through it
    let registry = cfg.trace_enabled().then(trace::Registry::new);
    let mut step_counters: Vec<CounterSet> = Vec::new();
    let mut recovery_events = 0u64;
    let mut lost_steps = 0u64;
    let world_size = loop {
        // a planned join splits the leg so it checkpoints exactly at N
        // (the earliest pending join when several are scheduled)
        let pending_join = attempt
            .faults
            .iter()
            .filter_map(|f| match *f {
                FaultSpec::Join { step } if resume.start_step < step && step < total_target => {
                    Some(step)
                }
                _ => None,
            })
            .min();
        if let Some(step) = pending_join {
            anyhow::ensure!(
                attempt.checkpoint_dir.is_some(),
                "--fault join@{step} needs --checkpoint DIR: the grown world picks \
                 its state up from the manifest"
            );
        }
        attempt.steps = pending_join.unwrap_or(total_target) - resume.start_step;
        let run = run_world(
            &attempt,
            &rt,
            &bundle,
            &sched,
            pp,
            v,
            &resume,
            &opt_state_bytes,
            registry.as_ref(),
            counters,
        )?;
        counters.add(&run.c);
        match run.failure {
            None => {
                logs.extend(run.logs);
                step_counters.extend(run.step_counters);
                match pending_join {
                    Some(join_step) => {
                        // grow: dp+1 resumes from the leg-final checkpoint
                        recovery_events += 1;
                        attempt.dp += 1;
                        attempt.faults.retain(|f| *f != FaultSpec::Join { step: join_step });
                        attempt.resume = true;
                        resume = resolve_resume(&attempt, n_stages)?;
                    }
                    None => break run.world_size,
                }
            }
            Some(failure) => {
                // without an injected killing fault this is a real failure:
                // surface the diagnostic instead of silently shrinking
                if !attempt.faults.iter().any(FaultSpec::is_killing) {
                    return Err(failure.into_error());
                }
                anyhow::ensure!(
                    attempt.dp > 1,
                    "{failure} at dp=1 — no surviving data-parallel replica to shrink onto"
                );
                recovery_events += 1;
                attempt.dp -= 1;
                // the fired fault is spent; faults scheduled for later
                // steps stay armed for the recovered world
                match &failure {
                    RunFailure::Killed(k) => {
                        let fired = k.step;
                        attempt.faults.retain(|f| f.step() > fired);
                    }
                    RunFailure::Lost(_) => attempt.faults.clear(),
                }
                attempt.resume = attempt
                    .checkpoint_dir
                    .as_deref()
                    .is_some_and(|d| matches!(checkpoint::latest_committed(d), Ok(Some(_))));
                resume = if attempt.resume {
                    resolve_resume(&attempt, n_stages)?
                } else {
                    // the fault hit before any checkpoint was committed:
                    // the shrunken world restarts the run from scratch
                    ResumePoint {
                        start_step: 0,
                        loss_scale: cfg.loss_scale_init,
                        scale_good: 0,
                        ckpt_dp: attempt.dp,
                        dir: None,
                    }
                };
                // steps the failed leg completed beyond the recovery point
                // are recomputed by the new world — the fault's step cost
                // (counter snapshots stay zipped with the kept logs)
                let total = run.logs.len();
                let mut kept = 0usize;
                for (i, l) in run.logs.into_iter().enumerate() {
                    if l.step < resume.start_step {
                        if let Some(sc) = run.step_counters.get(i) {
                            step_counters.push(*sc);
                        }
                        logs.push(l);
                        kept += 1;
                    }
                }
                lost_steps += (total - kept) as u64;
            }
        }
    };

    // ---- trace export -----------------------------------------------------
    // Merge every rank's span buffer (all elastic legs flushed into the
    // one registry) into the Chrome trace, and difference the per-step
    // counter snapshots into the JSONL stream.
    let trace_summary = match &registry {
        Some(reg) => {
            if let Some(path) = &cfg.trace_out {
                reg.write_chrome_trace(path)
                    .with_context(|| format!("writing chrome trace to {path:?}"))?;
            }
            if let Some(path) = &cfg.metrics_jsonl {
                let metas: Vec<trace::StepMeta> = logs
                    .iter()
                    .map(|l| trace::StepMeta {
                        step: l.step,
                        loss: l.loss,
                        grad_norm: l.grad_norm,
                        loss_scale: l.loss_scale,
                        skipped: l.skipped,
                        step_time_s: l.step_time_s,
                    })
                    .collect();
                reg.write_metrics_jsonl(path, &metas, &step_counters, &counters)
                    .with_context(|| format!("writing metrics jsonl to {path:?}"))?;
            }
            Some(reg.summarize())
        }
        None => None,
    };

    let tokens_per_step =
        bundle.meta.tokens_per_microbatch * cfg.microbatches as u64 * attempt.dp as u64;
    let mut timer = StepTimer::new();
    for l in &logs {
        timer.record(l.step_time_s);
    }
    let mean_step = timer.mean_after_warmup(1.min(logs.len().saturating_sub(1)));
    let steps_skipped = logs.iter().filter(|l| l.skipped).count() as u64;
    let final_loss_scale = logs.last().map(|l| l.loss_scale).unwrap_or(resume.loss_scale);
    Ok(TrainReport {
        world_size,
        total_params: bundle.meta.model.total_params,
        tokens_per_step,
        mean_step_time_s: mean_step,
        tokens_per_sec: tokens_per_step as f64 / mean_step,
        comm_bytes: counters.comm_bytes,
        tp_ar_bytes: counters.tp_ar_bytes,
        tp_ar_rounds: counters.tp_ar_rounds,
        dp_sync_hidden_s: counters.dp_sync_hidden_ns as f64 / 1e9,
        dp_sync_exposed_s: counters.dp_sync_exposed_ns as f64 / 1e9,
        dp_bucket_rounds: counters.dp_bucket_rounds,
        dp_bucket_payload_bytes: counters.dp_bucket_payload_bytes,
        dp_param_ag_bytes: counters.dp_param_ag_bytes,
        pp_p2p_payload_bytes: counters.pp_p2p_payload_bytes,
        dp_bucket_intra_bytes: counters.dp_bucket_intra_bytes,
        dp_bucket_inter_bytes: counters.dp_bucket_inter_bytes,
        dp_param_ag_intra_bytes: counters.dp_param_ag_intra_bytes,
        dp_param_ag_inter_bytes: counters.dp_param_ag_inter_bytes,
        pp_p2p_intra_bytes: counters.pp_p2p_intra_bytes,
        pp_p2p_inter_bytes: counters.pp_p2p_inter_bytes,
        moe_a2a_rounds: counters.moe_a2a_rounds,
        moe_a2a_payload_bytes: counters.moe_a2a_payload_bytes,
        moe_a2a_intra_bytes: counters.moe_a2a_intra_bytes,
        moe_a2a_inter_bytes: counters.moe_a2a_inter_bytes,
        moe_dropped_tokens: counters.moe_dropped_tokens,
        zero_stage: cfg.zero_stage,
        zero3_peak_gathered_floats: counters.zero3_peak_gathered_floats,
        opt_state_bytes_per_rank: opt_state_bytes.load(Ordering::Relaxed),
        precision: cfg.precision,
        final_loss_scale,
        steps_skipped,
        recovery_events,
        lost_steps,
        ckpt_save_hidden_ms: counters.ckpt_hidden_ns as f64 / 1e6,
        ckpt_save_exposed_ms: counters.ckpt_exposed_ns as f64 / 1e6,
        trace_summary,
        grad_wire: cfg.effective_grad_wire(),
        logs,
    })
}

/// Where a world (re)starts: the first step index, the loss-scaler state,
/// the dp the checkpoint on disk was written at (when it differs from
/// the attempt's dp, the workers re-partition the optimizer shards on
/// load — the elastic dp±1 path), and the verified generation directory
/// the files load from.
#[derive(Debug, Clone)]
struct ResumePoint {
    start_step: u32,
    loss_scale: f32,
    scale_good: u32,
    ckpt_dp: usize,
    /// The committed generation directory (or legacy flat dir) resume
    /// files load from; `None` on a fresh start.
    dir: Option<PathBuf>,
}

/// Validate the manifest against this run's shape and pick up the step /
/// loss-scaler / checkpoint-dp state where it left off.  Global stages,
/// not worker ranks — re-chunked and re-partitioned resumes are legal.
fn resolve_resume(cfg: &EngineConfig, n_stages: usize) -> Result<ResumePoint> {
    if !cfg.resume {
        return Ok(ResumePoint {
            start_step: 0,
            loss_scale: cfg.loss_scale_init,
            scale_good: 0,
            ckpt_dp: cfg.dp,
            dir: None,
        });
    }
    let root = cfg
        .checkpoint_dir
        .as_ref()
        .ok_or_else(|| anyhow!("--resume requires a checkpoint dir"))?;
    let resolved = checkpoint::latest_committed(root)?
        .ok_or_else(|| anyhow!("no committed checkpoint generation in {root:?}"))?;
    let (dir, manifest) = (resolved.dir, resolved.manifest);
    let spec = BuiltinSpec::parse(&cfg.bundle);
    manifest.validate_resume(
        &cfg.bundle,
        n_stages as u32,
        cfg.tp as u32,
        cfg.precision.name(),
        cfg.effective_grad_wire().name(),
        spec.as_ref().map_or(1, |s| s.experts as u32),
        spec.as_ref().map_or(1, |s| s.topk as u32),
    )?;
    let ckpt_stage = ShardingStage::from_index(manifest.zero_stage)
        .ok_or_else(|| anyhow!("manifest carries unknown zero_stage {}", manifest.zero_stage))?;
    anyhow::ensure!(
        ckpt_stage.resume_compatible(cfg.zero_stage),
        "checkpoint sharding stage {} cannot resume as stage {}: only the identical \
         stage, or the reshard-compatible 1 <-> 2 pair (same 1/dp optimizer-shard \
         layout, full on-disk params), round-trips — stages 0 and 3 change the \
         optimizer-state or parameter residency layout",
        ckpt_stage.index(),
        cfg.zero_stage.index()
    );
    anyhow::ensure!(manifest.dp >= 1, "manifest records dp=0");
    Ok(ResumePoint {
        start_step: manifest.step,
        loss_scale: manifest.loss_scale,
        scale_good: manifest.scale_good_steps,
        ckpt_dp: manifest.dp as usize,
        dir: Some(dir),
    })
}

/// Why a world stopped early.
#[derive(Debug)]
enum RunFailure {
    /// The injected `kill@step:rank` fired.
    Killed(KilledByFault),
    /// A collective wait hit its deadline — a peer is gone.
    Lost(PeerLost),
}

impl fmt::Display for RunFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RunFailure::Killed(k) => k.fmt(f),
            RunFailure::Lost(l) => l.fmt(f),
        }
    }
}

impl RunFailure {
    fn into_error(self) -> anyhow::Error {
        match self {
            RunFailure::Killed(k) => anyhow::Error::new(k),
            RunFailure::Lost(l) => anyhow::Error::new(l).context(
                "collective wait timed out: a peer is gone and the run has no \
                 fault/recovery plan (pass --fault, or fix the hang)",
            ),
        }
    }
}

/// One world: spawned, run to completion or first fault, harvested.
/// Counters live in [`trace::CounterSet`] — the registry-owned snapshot
/// type `TrainReport` totals and the JSONL stream difference per step.
struct WorldRun {
    logs: Vec<StepLog>,
    world_size: usize,
    /// `None` on a clean leg; the distinguished fault otherwise.  Real
    /// worker errors (I/O, asserts) propagate as `Err` instead.
    failure: Option<RunFailure>,
    c: CounterSet,
    /// When tracing: one *absolute* counter snapshot per entry of
    /// `logs`, harvested by the leader right after logging the step
    /// (includes the `base` totals of earlier elastic legs, so legs
    /// concatenate without re-basing).  Empty when tracing is off.
    step_counters: Vec<CounterSet>,
}

/// Suppress the default panic printout for [`PeerLost`] panics: they are
/// the *expected* way a worker abandons a collective when a peer dies,
/// and the coordinator harvests them from the join handles.  Every other
/// panic keeps the previous hook's behavior.
fn install_peer_lost_hook() {
    static HOOK: std::sync::Once = std::sync::Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<PeerLost>().is_none() {
                prev(info);
            }
        }));
    });
}

/// Spawn and run one full world at `cfg.dp`, harvesting logs, counters,
/// and the distinguished fault (if any) from the worker joins.  With a
/// `registry` the workers record spans into it and the leader snapshots
/// the counters after every logged step (`base` re-bases the snapshots
/// onto the totals of earlier elastic legs).
#[allow(clippy::too_many_arguments)]
fn run_world(
    cfg: &EngineConfig,
    rt: &Arc<Runtime>,
    bundle: &Arc<Bundle>,
    sched: &Arc<schedule::Schedule>,
    pp: usize,
    v: usize,
    resume: &ResumePoint,
    opt_state_bytes: &Arc<AtomicU64>,
    registry: Option<&Arc<trace::Registry>>,
    base: CounterSet,
) -> Result<WorldRun> {
    let dp = cfg.dp;
    let tp = cfg.tp;
    let world_size = pp * dp * tp;
    if cfg.hier() {
        // dp changes across elastic legs, so the packing check is per world
        let per_node = (world_size as u32).div_ceil(cfg.nodes);
        anyhow::ensure!(
            per_node <= GPUS_PER_NODE,
            "world {world_size} packed onto {} nodes needs {per_node} GCDs per node \
             (a Frontier node has {GPUS_PER_NODE})",
            cfg.nodes
        );
    }

    // world group: tagged p2p mailboxes between workers.  Megatron rank
    // order, TP innermost: rank = (pp_rank * dp + dp_rank) * tp + tp_rank.
    // Per (pp, dp) cell: a TP SubGroup over its tp consecutive world
    // ranks (layer all-reduces + replicated-grad sync).  Per (pp, tp)
    // row: a DP Group for gradient sync across replicas.
    let world = Group::new(world_size);
    let tp_groups: Vec<Arc<SubGroup>> = (0..pp * dp)
        .map(|cell| {
            let base = cell * tp;
            SubGroup::new(&world, (base..base + tp).collect(), cell as u64)
        })
        .collect();
    // under `--nodes N` each DP group carries the node map of its
    // members' GCDs (packed placement, tp-innermost ranks — DP groups
    // stride by `tp`, so the map handles node-interleaved members)
    let machine = cfg.hier().then(|| Machine::new(cfg.nodes));
    let dp_groups: Vec<Arc<Group>> = (0..pp * tp)
        .map(|row| {
            let nodes = machine.as_ref().map(|m| {
                let (pp_rank, tp_rank) = (row / tp, row % tp);
                let gpus: Vec<_> = (0..dp)
                    .map(|d| {
                        let rank = (pp_rank * dp + d) * tp + tp_rank;
                        packed_gpu_of(world_size as u32, cfg.nodes, rank as u32)
                    })
                    .collect();
                NodeMap::from_gpus(m, &gpus)
            });
            Group::new_with_nodes(dp, nodes)
        })
        .collect();

    // expert-parallel groups: blocks of `ep` *consecutive* DP replicas
    // per (pp, tp) cell, carrying the token-routing all_to_all.  An
    // elastic leg whose shrunken dp broke the divisibility falls back to
    // ep = 1 (routing stays rank-local) — numerically free, because
    // trajectories are ep-invariant by construction.
    let ep = if cfg.ep > 1 && dp % cfg.ep == 0 { cfg.ep } else { 1 };
    let ep_groups: Vec<Arc<Group>> = if ep > 1 {
        let blocks = dp / ep;
        (0..pp * tp * blocks)
            .map(|i| {
                let (cell, block) = (i / blocks, i % blocks);
                let (pp_rank, tp_rank) = (cell / tp, cell % tp);
                let nodes = machine.as_ref().map(|m| {
                    let gpus: Vec<_> = (0..ep)
                        .map(|e| {
                            let rank = (pp_rank * dp + block * ep + e) * tp + tp_rank;
                            packed_gpu_of(world_size as u32, cfg.nodes, rank as u32)
                        })
                        .collect();
                    NodeMap::from_gpus(m, &gpus)
                });
                Group::new_with_nodes(ep, nodes)
            })
            .collect()
    } else {
        Vec::new()
    };
    // world-shared dropped-token counter, charged by tp=0 shards
    let moe_dropped = Arc::new(AtomicU64::new(0));

    // arm the deadline on every wait a dead peer could strand: either the
    // explicit --comm-timeout-ms, or a defensive default when a kill is
    // scheduled (the killed rank's peers MUST time out to start recovery).
    // TP subgroup traffic rides the world mailboxes, so bounding the world
    // and DP groups covers every collective in the engine path.
    let timeout_ms = if cfg.comm_timeout_ms > 0 {
        cfg.comm_timeout_ms
    } else if cfg.faults.iter().any(FaultSpec::is_killing) {
        5_000
    } else {
        0
    };
    if timeout_ms > 0 {
        install_peer_lost_hook();
        world.set_comm_timeout(timeout_ms);
        for g in &dp_groups {
            g.set_comm_timeout(timeout_ms);
        }
        for g in &ep_groups {
            g.set_comm_timeout(timeout_ms);
        }
    }

    // per-step report: (step, loss, grad norm, loss scale, skipped)
    let (loss_tx, loss_rx) = mpsc::channel::<(u32, f32, f32, f32, bool)>();

    // checkpoint save context: hidden/exposed timers + the retrying
    // writer (with any injected write-fail budget).  Under
    // `--async-checkpoint` a background saver thread drains the ranks'
    // in-memory snapshots and commits generations off the critical path.
    let save_ctx = cfg.checkpoint_dir.as_ref().map(|root| {
        Arc::new(checkpoint::SaveCtx::new(root.clone(), cfg.ckpt_keep, world_size, &cfg.faults))
    });
    let (save_tx, saver_handle) = match (&save_ctx, cfg.async_checkpoint) {
        (Some(ctx), true) => {
            let (tx, rx) = mpsc::channel::<checkpoint::SavePart>();
            let ctx = ctx.clone();
            let h = thread::Builder::new()
                .name("ckpt-saver".into())
                .spawn(move || checkpoint::run_saver(ctx, rx))
                .context("spawning checkpoint saver")?;
            (Some(tx), Some(h))
        }
        _ => (None, None),
    };

    let mut handles = Vec::with_capacity(world_size);
    for pp_rank in 0..pp {
        for dp_rank in 0..dp {
            for tp_rank in 0..tp {
                let ctx = worker::WorkerCtx {
                    cfg: cfg.clone(),
                    rt: rt.clone(),
                    bundle: bundle.clone(),
                    sched: sched.clone(),
                    world: world.clone(),
                    tp_group: tp_groups[pp_rank * dp + dp_rank].clone(),
                    dp_group: dp_groups[pp_rank * tp + tp_rank].clone(),
                    ep_group: (ep > 1).then(|| {
                        let i = (pp_rank * tp + tp_rank) * (dp / ep) + dp_rank / ep;
                        ep_groups[i].clone()
                    }),
                    ep_rank: dp_rank % ep,
                    moe_dropped: moe_dropped.clone(),
                    pp_rank,
                    dp_rank,
                    tp_rank,
                    pp,
                    dp,
                    tp,
                    v,
                    start_step: resume.start_step,
                    start_loss_scale: resume.loss_scale,
                    start_scale_good: resume.scale_good,
                    ckpt_dp: resume.ckpt_dp,
                    ckpt_from: resume.dir.clone(),
                    save: save_ctx.clone(),
                    save_tx: save_tx.clone(),
                    opt_state_bytes: opt_state_bytes.clone(),
                    loss_tx: if pp_rank == pp - 1 && dp_rank == 0 && tp_rank == 0 {
                        Some(loss_tx.clone())
                    } else {
                        None
                    },
                    trace: registry.cloned(),
                };
                handles.push(
                    thread::Builder::new()
                        .name(format!("gcd-p{pp_rank}d{dp_rank}t{tp_rank}"))
                        .spawn(move || worker::run(ctx))
                        .context("spawning worker")?,
                );
            }
        }
    }
    drop(loss_tx);
    drop(save_tx); // the workers hold the only live snapshot senders

    // counter harvest (relaxed atomics — exact once the workers have
    // joined; mid-run reads are the leader's per-step snapshots, whose
    // tail drift the JSONL writer closes against the final totals).
    // TP subgroup ring traffic flows through the world mailboxes, so
    // world.bytes_moved already includes its wire bytes; the subgroup
    // counters track the logical all-reduce payload separately.
    let sum_dp = |f: fn(&Group) -> &AtomicU64| {
        dp_groups.iter().map(|g| f(g).load(Ordering::Relaxed)).sum::<u64>()
    };
    let sum_ep = |f: fn(&Group) -> &AtomicU64| {
        ep_groups.iter().map(|g| f(g).load(Ordering::Relaxed)).sum::<u64>()
    };
    let harvest = || CounterSet {
        comm_bytes: world.bytes_moved.load(Ordering::Relaxed)
            + sum_dp(|g| &g.bytes_moved)
            + sum_ep(|g| &g.bytes_moved),
        tp_ar_bytes: tp_groups.iter().map(|g| g.ar_bytes.load(Ordering::Relaxed)).sum(),
        tp_ar_rounds: tp_groups.iter().map(|g| g.ar_rounds.load(Ordering::Relaxed)).sum(),
        dp_sync_hidden_ns: sum_dp(|g| &g.nb_hidden_ns),
        dp_sync_exposed_ns: sum_dp(|g| &g.nb_exposed_ns),
        dp_bucket_rounds: sum_dp(|g| &g.nb_rounds),
        dp_bucket_payload_bytes: sum_dp(|g| &g.nb_payload_bytes),
        dp_param_ag_bytes: sum_dp(|g| &g.ag_payload_bytes),
        pp_p2p_payload_bytes: world.pp_payload_bytes.load(Ordering::Relaxed),
        dp_bucket_intra_bytes: sum_dp(|g| &g.nb_intra_bytes),
        dp_bucket_inter_bytes: sum_dp(|g| &g.nb_inter_bytes),
        dp_param_ag_intra_bytes: sum_dp(|g| &g.ag_intra_bytes),
        dp_param_ag_inter_bytes: sum_dp(|g| &g.ag_inter_bytes),
        pp_p2p_intra_bytes: world.pp_intra_bytes.load(Ordering::Relaxed),
        pp_p2p_inter_bytes: world.pp_inter_bytes.load(Ordering::Relaxed),
        moe_a2a_rounds: sum_ep(|g| &g.a2a_rounds),
        moe_a2a_payload_bytes: sum_ep(|g| &g.a2a_payload_bytes),
        moe_a2a_intra_bytes: sum_ep(|g| &g.a2a_intra_bytes),
        moe_a2a_inter_bytes: sum_ep(|g| &g.a2a_inter_bytes),
        moe_dropped_tokens: moe_dropped.load(Ordering::Relaxed),
        zero3_peak_gathered_floats: dp_groups
            .iter()
            .map(|g| g.ag_peak_floats.load(Ordering::Relaxed))
            .max()
            .unwrap_or(0),
        ckpt_hidden_ns: save_ctx.as_ref().map_or(0, |s| s.hidden_ns.load(Ordering::Relaxed)),
        ckpt_exposed_ns: save_ctx.as_ref().map_or(0, |s| s.exposed_ns.load(Ordering::Relaxed)),
    };

    // leader: collect per-step losses as they stream in.  The channel
    // closes when the reporting worker exits — cleanly, by injected kill,
    // or by PeerLost panic — so this loop can never outlive a fault.
    let mut logs: Vec<StepLog> = Vec::with_capacity(cfg.steps as usize);
    let mut step_counters: Vec<CounterSet> = Vec::new();
    let start = std::time::Instant::now();
    let mut last = 0.0f64;
    while let Ok((step, loss, grad_norm, loss_scale, skipped)) = loss_rx.recv() {
        let now = start.elapsed().as_secs_f64();
        let dt = now - last;
        last = now;
        if cfg.log_every > 0 && step % cfg.log_every == 0 {
            let skip_note = if skipped { "  [overflow: step skipped]" } else { "" };
            println!(
                "step {step:>5}  loss {loss:8.4}  |g| {grad_norm:8.3}  {dt:7.3}s/step{skip_note}"
            );
        }
        logs.push(StepLog { step, loss, grad_norm, step_time_s: dt, loss_scale, skipped });
        if registry.is_some() {
            let mut snap = harvest();
            snap.add(&base);
            step_counters.push(snap);
        }
    }

    // harvest every join before deciding the outcome: an injected kill
    // outranks the secondary PeerLost panics it causes in the survivors,
    // and any *real* worker error outranks both
    let mut failure: Option<RunFailure> = None;
    let mut hard: Option<anyhow::Error> = None;
    for h in handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => match e.downcast::<KilledByFault>() {
                Ok(k) => failure = Some(RunFailure::Killed(k)),
                Err(e) => hard = hard.or(Some(e.context("worker failed"))),
            },
            Err(payload) => match payload.downcast::<PeerLost>() {
                Ok(l) => {
                    if failure.is_none() {
                        failure = Some(RunFailure::Lost(*l));
                    }
                }
                Err(_) => hard = hard.or(Some(anyhow!("worker panicked"))),
            },
        }
    }
    // the saver's channel closed with the last worker; join it and
    // harvest its errors (retry budget exhausted, commit failure) as
    // hard failures — they are the root cause of any dependent worker
    // error ("saver thread died"), so they take precedence
    if let Some(h) = saver_handle {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(e.context("checkpoint saver failed")),
            Err(_) => return Err(anyhow!("checkpoint saver panicked")),
        }
    }
    if let Some(e) = hard {
        return Err(e);
    }

    let c = harvest();
    Ok(WorldRun { logs, world_size, failure, c, step_counters })
}
