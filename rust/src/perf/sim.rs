//! Discrete-event pipeline simulator.
//!
//! Executes the *actual* `schedule::Schedule` instruction streams against
//! the comm/kernel cost models: each pipeline rank is a resource that runs
//! its ops in stream order, forwards become available to the next *global*
//! stage after the p2p transfer, backwards flow the other way.  With
//! interleaved schedules a rank hosts `v` model chunks and each op costs a
//! `1/v` share of the stage compute.  The measured idle time IS the
//! pipeline bubble — no closed-form `(p-1)/m` or `(p-1)/(m v)` assumption
//! — so this cross-validates the analytic model (`perf::PerfModel`) and
//! exposes schedule effects the formula hides (GPipe's fill/drain
//! asymmetry, unsaturated pipelines, interleaving's extra p2p hops).

use crate::comm::CommModel;
use crate::config::{ModelSpec, ParallelConfig};
use crate::parallel::RankLayout;
use crate::schedule::{self, Op};
use crate::topology::Machine;

use super::{PerfError, PerfModel};

/// Simulated timeline of one training step for a single pipeline replica.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Wall-clock of the pipelined fwd/bwd phase (max over ranks).
    pub t_pipeline: f64,
    /// Per-rank busy time (compute + folded TP comm).
    pub busy: Vec<f64>,
    /// Per-rank idle (bubble) time inside the pipeline phase.
    pub idle: Vec<f64>,
    /// Measured bubble fraction on the busiest rank's timeline.
    pub bubble_fraction: f64,
    /// End-to-end step time (adds DP sync + optimizer from the cost model).
    pub t_step: f64,
    pub pct_peak: f64,
}

/// Simulate one step of `cfg` on `model`.
pub fn simulate(
    perf: &PerfModel,
    model: &ModelSpec,
    cfg: &ParallelConfig,
) -> Result<SimResult, PerfError> {
    cfg.validate().map_err(PerfError::Invalid)?;
    let analytic = perf.evaluate(model, cfg)?; // reuses OOM + validity checks

    let p = cfg.pp as usize;
    let m = cfg.microbatches();
    let sched = schedule::build(cfg.schedule, cfg.pp, m);
    sched.validate().map_err(PerfError::Invalid)?;
    let v = sched.v as usize;
    let k = sched.global_stages() as usize; // global (virtual) stages

    let machine = Machine::for_gpus(cfg.world_size());
    let comm = CommModel::new(machine);
    let layout = RankLayout::new(cfg.tp, cfg.pp, cfg.dp);

    // per-op durations from the same pricing as the analytic model;
    // a chunk is a 1/v slice of the rank's layers
    let (t_fwd, t_bwd) = per_microbatch_times(perf, model, cfg, &comm, &layout);
    let (t_fwd_c, t_bwd_c) = (t_fwd / v as f64, t_bwd / v as f64);
    let p2p_bytes = cfg.mbs as u64 * model.seq * model.hidden * cfg.precision.bytes();
    let stride = (cfg.dp * cfg.tp).min(comm.machine.n_gpus() - 1);
    let t_hop = comm.p2p(0, stride, p2p_bytes) * (1.0 - perf.pp_overlap);

    // event-driven execution: fixed-point over rank program counters;
    // completion times are tracked per *global* stage g = chunk * p + rank
    let mut pc = vec![0usize; p];
    let mut clock = vec![0.0f64; p]; // next free time per rank
    let mut busy = vec![0.0f64; p];
    let mut fwd_done = vec![vec![f64::NAN; m as usize]; k];
    let mut bwd_done = vec![vec![f64::NAN; m as usize]; k];

    loop {
        let mut progressed = false;
        for i in 0..p {
            while pc[i] < sched.streams[i].len() {
                let op = sched.streams[i][pc[i]];
                let g = (op.chunk() as usize) * p + i;
                let mb = op.mb() as usize;
                let ready = match op {
                    Op::Forward { .. } => {
                        if g == 0 {
                            Some(0.0)
                        } else if fwd_done[g - 1][mb].is_nan() {
                            None
                        } else {
                            // the producing chunk sits on rank (g-1) % p;
                            // a same-rank chunk boundary needs no transfer
                            let hop = if (g - 1) % p != i { t_hop } else { 0.0 };
                            Some(fwd_done[g - 1][mb] + hop)
                        }
                    }
                    Op::Backward { .. } => {
                        if g == k - 1 {
                            // loss is local; backward can start right after
                            // this chunk's own forward of that micro-batch
                            Some(fwd_done[g][mb])
                        } else if bwd_done[g + 1][mb].is_nan() {
                            None
                        } else {
                            let hop = if (g + 1) % p != i { t_hop } else { 0.0 };
                            Some(bwd_done[g + 1][mb] + hop)
                        }
                    }
                };
                let Some(ready) = ready else { break };
                if ready.is_nan() {
                    break;
                }
                let dur = if op.is_forward() { t_fwd_c } else { t_bwd_c };
                let start = clock[i].max(ready);
                let done = start + dur;
                clock[i] = done;
                busy[i] += dur;
                match op {
                    Op::Forward { .. } => fwd_done[g][mb] = done,
                    Op::Backward { .. } => bwd_done[g][mb] = done,
                }
                pc[i] += 1;
                progressed = true;
            }
        }
        if pc.iter().enumerate().all(|(i, &c)| c == sched.streams[i].len()) {
            break;
        }
        assert!(progressed, "schedule deadlocked in simulation");
    }

    let t_pipeline = clock.iter().cloned().fold(0.0, f64::max);
    let idle: Vec<f64> = busy.iter().map(|b| t_pipeline - b).collect();
    let bubble_fraction = idle.iter().cloned().fold(0.0, f64::max) / t_pipeline;

    // end-of-step terms priced identically to the analytic model
    let t_step = t_pipeline + analytic.t_pp_comm.min(0.0).max(0.0) // p2p already in timeline
        + analytic.t_dp_comm
        + analytic.t_optimizer;

    let pct_peak = analytic.hw_flops_per_gpu / t_step / crate::topology::PEAK_FP16_FLOPS * 100.0;

    Ok(SimResult { t_pipeline, busy, idle, bubble_fraction, t_step, pct_peak })
}

/// Expose the per-microbatch stage times the analytic model prices
/// (fwd, bwd), including folded TP all-reduces.
fn per_microbatch_times(
    perf: &PerfModel,
    model: &ModelSpec,
    cfg: &ParallelConfig,
    _comm: &CommModel,
    _layout: &RankLayout,
) -> (f64, f64) {
    // recover (t_fwd + t_bwd) from the analytic breakdown of a single
    // replica with the same per-microbatch pricing
    let solo = ParallelConfig { dp: 1, gbs: cfg.gbs / cfg.dp, ..cfg.clone() };
    let b = perf.evaluate(model, &solo).expect("solo replica must evaluate");
    let m = solo.microbatches() as f64;
    let t_mb = (b.t_compute + b.t_tp_comm) / m;
    // forward is 1/(3+r) of a microbatch with recompute r
    let recompute = if cfg.checkpoint_activations { 1.0 } else { 0.0 };
    let t_fwd = t_mb / (3.0 + recompute);
    let t_bwd = t_mb - t_fwd;
    (t_fwd, t_bwd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{lookup, ParallelConfig, ScheduleKind};

    fn pm() -> PerfModel {
        PerfModel::default()
    }

    #[test]
    fn sim_matches_analytic_bubble() {
        // measured bubble on rank p-1 ~ (p-1)/(m+p-1) for 1F1B
        let m = lookup("22b").unwrap();
        let cfg = ParallelConfig::default().with_tp(2).with_pp(8).with_gbs(32);
        let sim = simulate(&pm(), &m, &cfg).unwrap();
        let analytic = cfg.bubble_fraction();
        assert!(
            (sim.bubble_fraction - analytic).abs() < 0.12,
            "sim {:.3} vs analytic {:.3}",
            sim.bubble_fraction,
            analytic
        );
    }

    #[test]
    fn interleaved_bubble_matches_analytic() {
        // THE tentpole cross-validation: executing the real interleaved
        // streams must reproduce the (p-1)/(m v + p - 1) bubble within 10%
        // relative error for saturated pipelines (m >= 2p, m % p == 0)
        let m = lookup("22b").unwrap();
        for v in [2u32, 4] {
            let cfg = ParallelConfig::default()
                .with_tp(2)
                .with_pp(8)
                .with_gbs(32) // m = 32 = 4p, 32 % 8 == 0
                .with_interleave(v);
            let sim = simulate(&pm(), &m, &cfg).unwrap();
            let analytic = cfg.bubble_fraction();
            let rel = (sim.bubble_fraction - analytic).abs() / analytic;
            assert!(
                rel < 0.10,
                "v={v}: sim {:.4} vs analytic {:.4} (rel {rel:.3})",
                sim.bubble_fraction,
                analytic
            );
        }
    }

    #[test]
    fn interleaving_shrinks_measured_bubble_and_step() {
        let m = lookup("22b").unwrap();
        let base = ParallelConfig::default().with_tp(2).with_pp(8).with_gbs(32);
        let plain = simulate(&pm(), &m, &base).unwrap();
        let inter = simulate(&pm(), &m, &base.clone().with_interleave(4)).unwrap();
        assert!(
            inter.bubble_fraction < plain.bubble_fraction,
            "interleaved {:.4} !< plain {:.4}",
            inter.bubble_fraction,
            plain.bubble_fraction
        );
        assert!(inter.t_pipeline < plain.t_pipeline);
    }

    #[test]
    fn sim_and_closed_form_agree_on_throughput() {
        let m = lookup("175b").unwrap();
        let cfg = ParallelConfig::default().with_tp(8).with_pp(16).with_gbs(256);
        let sim = simulate(&pm(), &m, &cfg).unwrap();
        let ana = pm().evaluate(&m, &cfg).unwrap();
        let rel = (sim.pct_peak - ana.pct_peak).abs() / ana.pct_peak;
        assert!(rel < 0.15, "sim {:.2}% vs analytic {:.2}%", sim.pct_peak, ana.pct_peak);
    }

    #[test]
    fn interleaved_sim_agrees_with_analytic_throughput() {
        let m = lookup("175b").unwrap();
        let cfg = ParallelConfig::default()
            .with_tp(8)
            .with_pp(16)
            .with_gbs(256)
            .with_interleave(2);
        let sim = simulate(&pm(), &m, &cfg).unwrap();
        let ana = pm().evaluate(&m, &cfg).unwrap();
        let rel = (sim.pct_peak - ana.pct_peak).abs() / ana.pct_peak;
        assert!(rel < 0.15, "sim {:.2}% vs analytic {:.2}%", sim.pct_peak, ana.pct_peak);
    }

    #[test]
    fn gpipe_slower_than_1f1b_when_unsaturated() {
        let m = lookup("22b").unwrap();
        let base = ParallelConfig::default().with_tp(2).with_pp(8).with_gbs(16);
        let f1b = simulate(&pm(), &m, &base).unwrap();
        let gp = simulate(
            &pm(),
            &m,
            &base.clone().with_schedule(ScheduleKind::GPipe),
        )
        .unwrap();
        // same bubble in time terms, but GPipe can never beat 1F1B
        assert!(gp.t_pipeline >= f1b.t_pipeline * 0.99);
    }

    #[test]
    fn deeper_pipeline_more_measured_bubble() {
        let m = lookup("22b").unwrap();
        let b2 = simulate(&pm(), &m, &ParallelConfig::default().with_tp(8).with_pp(2).with_gbs(32))
            .unwrap();
        let b8 =
            simulate(&pm(), &m, &ParallelConfig::default().with_tp(8).with_pp(8).with_gbs(32))
                .unwrap();
        assert!(b8.bubble_fraction > b2.bubble_fraction);
    }

    #[test]
    fn single_stage_no_bubble() {
        let m = lookup("22b").unwrap();
        let cfg = ParallelConfig::default().with_tp(8).with_gbs(8);
        let sim = simulate(&pm(), &m, &cfg).unwrap();
        assert!(sim.bubble_fraction < 1e-9);
    }
}
