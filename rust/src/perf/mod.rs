//! Throughput performance model: predicts GPU throughput (TFLOPS and % of
//! the 191.5 TFLOPS MI250X fp16 peak) for any (model, strategy) pair.
//!
//! Step time is decomposed exactly the way the paper reasons about it:
//!
//! `t_step = pipeline(t_fwd_mb + t_bwd_mb; p, m)  +  exposed PP p2p
//!           + exposed DP grad sync + optimizer step`
//!
//! with per-micro-batch compute priced by a kernel-efficiency curve and TP
//! all-reduces priced by `comm::CommModel` on the Frontier topology.  The
//! curve is calibrated against the single anchor the repro brief allows —
//! the paper's measured 38.38% at 22B (Fig 11) — and everything else
//! (Figs 6, 7, 8, 11, 12, 13 and all four §III observations) must *follow*.
//!
//! Two evaluators share this pricing: the closed-form one below and the
//! discrete-event simulator in [`sim`], which executes the actual
//! `schedule::Schedule` instruction streams.  `tests` cross-validate them.

pub mod sim;

use crate::collectives::chunk_bounds;
use crate::comm::CommModel;
use crate::config::{ModelSpec, ParallelConfig};
use crate::mem;
use crate::parallel::RankLayout;
use crate::precision::GradWire;
use crate::topology::{packed_gpu_of, GpuId, Machine, HBM_BW, PEAK_FP16_FLOPS};

// ---------------------------------------------------------------------------
// The TP communication contract (§II.B), shared between the analytic
// model below and the execution engine's instrumented `SubGroup`s.
// ---------------------------------------------------------------------------

/// Payload of ONE tensor-parallel all-reduce of the full activation —
/// `tokens × hidden` elements at `prec_bytes` each.  This is the quantity
/// the closed-form model prices per sharded block (1 forward + 1 backward
/// all-reduce each; a transformer layer has 2 such blocks — attention and
/// MLP — hence the model's 2-fwd + 2-bwd per layer), and the quantity the
/// engine's `SubGroup` counters report per collective.
pub fn tp_allreduce_payload_bytes(tokens: u64, hidden: u64, prec_bytes: u64) -> u64 {
    tokens * hidden * prec_bytes
}

/// Sharded blocks per transformer layer (attention + MLP), each costing
/// one forward and one backward activation all-reduce.
pub const TP_BLOCKS_PER_TRANSFORMER_LAYER: u64 = 2;

/// Exact all-reduce payload (f32 **elements**) the sharded builtin engine
/// moves through one TP group per micro-batch, per pipeline (summed over
/// that replica's stages).  Composition, all of size `tokens × hidden`
/// unless noted:
///
/// * per stage block: 1 forward + 1 backward (input-grad) all-reduce;
/// * vocab-sharded embedding: 1 forward all-reduce, plus 1 more in the
///   first-stage backward's checkpointing recompute (absent on the fused
///   single-stage path, which embeds once);
/// * vocab-parallel head: 1 all-reduce for the `dy` input gradient, plus
///   the softmax statistics — `tokens` elements of all-reduce-max and
///   `2·tokens` of packed (sum-exp, target-logit) all-reduce-sum.
///
/// The engine test `tp_comm_bytes_match_analytic` pins the instrumented
/// `SubGroup` counters to exactly `4 ×` this value (f32) per micro-batch.
pub fn builtin_tp_ar_floats_per_microbatch(n_stages: u64, tokens: u64, hidden: u64) -> u64 {
    let td = tokens * hidden;
    let block_ars = 2 * n_stages; // 1 fwd + 1 bwd per block
    let embed_ars = if n_stages == 1 { 1 } else { 2 }; // fwd (+ bwd recompute)
    let head_ars = 1; // dlogits -> dy
    (block_ars + embed_ars + head_ars) * td + 3 * tokens
}

/// Per-step, per-TP-group all-reduce payload (f32 elements) of the
/// engine's optimizer-step synchronisation, per hosted stage: the
/// replicated-gradient sync (row-parallel bias, `hidden` elements) plus
/// the 1-float TP-global clip-norm combine.
pub fn builtin_tp_grad_sync_floats_per_step(stages_hosted: u64, hidden: u64) -> u64 {
    stages_hosted * (hidden + 1)
}

/// Dtype-aware variant of [`builtin_tp_ar_floats_per_microbatch`]: every
/// TP collective follows the engine's wire dtype (bf16 payloads pack two
/// values per f32 lane), so the byte volume is uniformly `wire_bytes ×
/// elements` — the EXACT pin for the instrumented `SubGroup` counters at
/// bf16, and exactly half the fp32 measurement.
pub fn builtin_tp_ar_bytes_per_microbatch(
    n_stages: u64,
    tokens: u64,
    hidden: u64,
    wire_bytes: u64,
) -> u64 {
    wire_bytes * builtin_tp_ar_floats_per_microbatch(n_stages, tokens, hidden)
}

/// Dtype-aware variant of [`builtin_tp_grad_sync_floats_per_step`].
pub fn builtin_tp_grad_sync_bytes_per_step(
    stages_hosted: u64,
    hidden: u64,
    wire_bytes: u64,
) -> u64 {
    wire_bytes * builtin_tp_grad_sync_floats_per_step(stages_hosted, hidden)
}

// ---------------------------------------------------------------------------
// The DP gradient-sync wire contract (§II.D), dtype-aware.  ZeRO-1 moves
// the same reduce volume as plain DDP (reduce-scatter in, all-gather of
// the updated parameters out — the equal-wire-volume argument behind its
// last-place SHAP rank), so the contract splits into the two named
// halves the engine counters measure.
// ---------------------------------------------------------------------------

/// Logical per-step DP gradient-reduction payload: every parameter's
/// gradient crosses the DP group once, at the wire dtype's width.  The
/// engine's `TrainReport::dp_bucket_payload_bytes` equals
/// `steps × Σ_stages dp_grad_payload_bytes(params, wire)` EXACTLY
/// (bucketing and overlap timing cannot change the volume).
pub fn dp_grad_payload_bytes(n_params: u64, wire_bytes: u64) -> u64 {
    n_params * wire_bytes
}

/// Logical per-step updated-parameter all-gather payload of sharding
/// stages 1/2 (the second half of the RS+AG accounting; plain DDP
/// gathers nothing, and stage 3 replaces this with the on-demand
/// per-use gathers below).  Engine counter:
/// `TrainReport::dp_param_ag_bytes`.
pub fn zero1_allgather_payload_bytes(n_params: u64, param_bytes: u64) -> u64 {
    n_params * param_bytes
}

/// ZeRO-3 on-demand parameter all-gather payload (f32 **elements**) per
/// DP replica per step for the builtin engine: every param-using op
/// gathers its stage's full (TP-shard) parameter vector.  Per stage
/// that is `m` forward visits (except the head chunk, whose forward
/// only stashes its input, and the fused single-stage path, whose
/// forward is folded into backward) plus `m` backward visits:
///
/// `Σ_g (m·[g uses fwd params] + m) × params(g)`
///
/// The engine pin: `TrainReport::dp_param_ag_bytes` equals
/// `steps × wire_bytes ×` this, summed over the grid's (pp × tp) DP
/// groups.
pub fn builtin_zero3_ag_floats_per_step(stage_params: &[u64], m: u64) -> u64 {
    let k = stage_params.len();
    stage_params
        .iter()
        .enumerate()
        .map(|(g, &p)| {
            let fwd = if k == 1 || g == k - 1 { 0 } else { m };
            (fwd + m) * p
        })
        .sum()
}

/// Pipeline p2p activation payload (f32 **elements**) per DP replica
/// per TP shard per step: one boundary activation down + one boundary
/// gradient up per micro-batch per stage boundary, each `tokens ×
/// hidden` elements.  With `pp == 1` every boundary is worker-local and
/// never touches the wire.  The engine pin:
/// `TrainReport::pp_p2p_payload_bytes == steps × dp × tp × wire_bytes ×`
/// this — and the packed-bf16 activation wire makes the bf16 measurement
/// exactly half the fp32 one.
pub fn builtin_pp_p2p_floats_per_step(
    n_stages: u64,
    pp: u64,
    m: u64,
    tokens: u64,
    hidden: u64,
) -> u64 {
    if pp <= 1 {
        return 0;
    }
    2 * m * (n_stages - 1) * tokens * hidden
}

// ---------------------------------------------------------------------------
// The hierarchical (two-tier) wire contract.  These functions mirror the
// engine's per-tier byte counters EXACTLY — same bucket splitting, same
// representative convention (first group rank on each node), same
// per-bucket int8 block overhead — so `TrainReport::*_intra_bytes` /
// `*_inter_bytes` equal `steps ×` these, summed over the grid's DP
// groups.  All take the DP group's per-rank node assignment (raw node
// ids under the packed placement; only the partition shape matters).
// ---------------------------------------------------------------------------

/// Node assignment of one DP group under the engine's packed placement:
/// member `d`'s world rank is `(pp_rank·dp + d)·tp + tp_rank` (Megatron
/// order, TP innermost) and its node is that of `packed_gpu_of`.  This
/// is the exact map `coordinator::train_with_bundle` attaches to the
/// group — different (pp, tp) rows can land different shapes, so tier
/// contracts must be composed per row.
pub fn packed_dp_group_nodes(
    pp_rank: usize,
    tp_rank: usize,
    pp: usize,
    dp: usize,
    tp: usize,
    nodes: u32,
) -> Vec<u32> {
    let world = (pp * dp * tp) as u32;
    let machine = Machine::new(nodes);
    (0..dp)
        .map(|d| {
            let rank = ((pp_rank * dp + d) * tp + tp_rank) as u32;
            machine.node_of(packed_gpu_of(world, nodes, rank))
        })
        .collect()
}

/// (n ranks, k distinct nodes, per-rank is-representative flags): the
/// shared shape every tier term derives from.  A rank represents its
/// node iff it is the FIRST group rank on that node — the same
/// convention `collectives::NodeMap::representative` uses.
fn hier_shape(node_of: &[u32]) -> (u64, u64, Vec<bool>) {
    let mut seen: Vec<u32> = Vec::new();
    let reps: Vec<bool> = node_of
        .iter()
        .map(|&nd| {
            if seen.contains(&nd) {
                false
            } else {
                seen.push(nd);
                true
            }
        })
        .collect();
    (node_of.len() as u64, seen.len() as u64, reps)
}

/// Grad-wire payload of one span split into engine-sized buckets — the
/// int8 wire's 4-byte-per-128-block scale overhead applies PER BUCKET,
/// exactly as `launch_grad_buckets`/`launch_rs_buckets` quantize each
/// bucket independently.
fn bucketed_wire_bytes(len: u64, bucket: u64, grad_wire: GradWire) -> u64 {
    let bucket = bucket.max(1);
    let mut sum = 0;
    let mut lo = 0;
    while lo < len {
        let l = bucket.min(len - lo);
        sum += grad_wire.payload_bytes(l);
        lo += l;
    }
    sum
}

/// Per-tier bytes of ONE chunk's hierarchical all-reduce gradient sync
/// (sharding stages 0/1): each of the `⌈len/bucket⌉` buckets counts
/// `2(n−k)` intra-node payloads at the storage wire width (non-reps up,
/// results back down) and, when the group spans nodes, `k` inter-node
/// payloads at the grad-wire width.  Returns `(intra, inter)`.
pub fn hier_ar_tier_bytes(
    len: u64,
    bucket_floats: u64,
    node_of: &[u32],
    wire_bytes: u64,
    grad_wire: GradWire,
) -> (u64, u64) {
    let (n, k, _) = hier_shape(node_of);
    if n <= 1 {
        return (0, 0);
    }
    let intra = wire_bytes * len * 2 * (n - k);
    let inter =
        if k > 1 { k * bucketed_wire_bytes(len, bucket_floats, grad_wire) } else { 0 };
    (intra, inter)
}

/// Per-tier bytes of ONE chunk's hierarchical partition-aligned
/// reduce-scatter sync (stages 2/3): buckets split along the DP
/// partition first (`chunk_bounds`), and each owner's span counts
/// `(n−k)` intra payloads up plus one more down when the owner is not
/// its node's representative.  Returns `(intra, inter)`.
pub fn hier_rs_tier_bytes(
    len: u64,
    bucket_floats: u64,
    node_of: &[u32],
    wire_bytes: u64,
    grad_wire: GradWire,
) -> (u64, u64) {
    let (n, k, reps) = hier_shape(node_of);
    if n <= 1 {
        return (0, 0);
    }
    let bounds = chunk_bounds(len as usize, n as usize);
    let mut intra = 0;
    let mut inter = 0;
    for (owner, &(lo, hi)) in bounds.iter().enumerate() {
        let span = (hi - lo) as u64;
        let down = u64::from(!reps[owner]);
        intra += wire_bytes * span * ((n - k) + down);
        if k > 1 {
            inter += k * bucketed_wire_bytes(span, bucket_floats, grad_wire);
        }
    }
    (intra, inter)
}

/// Per-tier bytes of ONE primary hierarchical parameter all-gather of a
/// `total`-element buffer: every non-representative's shard crosses the
/// intra tier up, the representatives exchange the assembled buffer
/// over the inter tier (`wire × total` when the group spans nodes), and
/// the full buffer fans back down to each of the `n−k` non-reps.
/// Parameter gathers always ride the storage wire (the grad wire shapes
/// gradients only).  Returns `(intra, inter)`.
pub fn hier_ag_tier_bytes(total: u64, node_of: &[u32], wire_bytes: u64) -> (u64, u64) {
    let (n, k, reps) = hier_shape(node_of);
    if n <= 1 {
        return (0, 0);
    }
    let bounds = chunk_bounds(total as usize, n as usize);
    let up: u64 = bounds
        .iter()
        .zip(&reps)
        .filter(|(_, &rep)| !rep)
        .map(|(&(lo, hi), _)| (hi - lo) as u64)
        .sum();
    let intra = wire_bytes * (up + (n - k) * total);
    let inter = if k > 1 { wire_bytes * total } else { 0 };
    (intra, inter)
}

/// Intra-tier bytes of ONE node-local secondary gather (ZeRO++ hpZ:
/// every stage-3 use after a chunk's per-step first touch): each node
/// with 2+ co-resident members reassembles the full buffer from its
/// secondary partition — `wire × total` per such node; lone members
/// already hold the whole buffer and move nothing.  The inter tier is
/// zero by construction.
pub fn hier_node_ag_intra_bytes(total: u64, node_of: &[u32], wire_bytes: u64) -> u64 {
    let mut seen: Vec<(u32, u64)> = Vec::new();
    for &nd in node_of {
        match seen.iter_mut().find(|(n, _)| *n == nd) {
            Some((_, c)) => *c += 1,
            None => seen.push((nd, 1)),
        }
    }
    let multi = seen.iter().filter(|&&(_, c)| c > 1).count() as u64;
    multi * wire_bytes * total
}

/// Per-step, per-DP-group tier bytes of the hierarchical DP gradient
/// sync over this group's hosted chunks: AR buckets under stages 0/1,
/// partition-aligned RS buckets under stages 2/3.  Returns
/// `(intra, inter)` — the EXACT per-step increment of the group's
/// `nb_intra_bytes` / `nb_inter_bytes`.
pub fn hier_grad_sync_tier_bytes(
    chunk_params: &[u64],
    bucket_floats: u64,
    node_of: &[u32],
    wire_bytes: u64,
    grad_wire: GradWire,
    sharded_grads: bool,
) -> (u64, u64) {
    let mut intra = 0;
    let mut inter = 0;
    for &p in chunk_params {
        let (i, e) = if sharded_grads {
            hier_rs_tier_bytes(p, bucket_floats, node_of, wire_bytes, grad_wire)
        } else {
            hier_ar_tier_bytes(p, bucket_floats, node_of, wire_bytes, grad_wire)
        };
        intra += i;
        inter += e;
    }
    (intra, inter)
}

/// Per-step tier bytes of the ZeRO-3 on-demand gathers under the
/// hierarchical path, for a single-pp-row grid (every stage's gathers
/// run on DP groups of the given shape): each stage's FIRST param use
/// per step is a primary (inter-node) gather; its remaining
/// `fwd + m − 1` uses are node-local secondary gathers (use counts
/// mirror [`builtin_zero3_ag_floats_per_step`] exactly).  Returns
/// `(intra, inter)`.
pub fn builtin_zero3_hier_ag_tier_bytes(
    stage_params: &[u64],
    m: u64,
    node_of: &[u32],
    wire_bytes: u64,
) -> (u64, u64) {
    let k = stage_params.len();
    let mut intra = 0;
    let mut inter = 0;
    for (g, &p) in stage_params.iter().enumerate() {
        let fwd = if k == 1 || g == k - 1 { 0 } else { m };
        let uses = fwd + m;
        if uses == 0 {
            continue;
        }
        let (i, e) = hier_ag_tier_bytes(p, node_of, wire_bytes);
        intra += i + (uses - 1) * hier_node_ag_intra_bytes(p, node_of, wire_bytes);
        inter += e;
    }
    (intra, inter)
}

// ---------------------------------------------------------------------------
// The MoE expert-parallel wire contract.  These functions mirror the
// engine's `Group::a2a_*` counters EXACTLY: one round per dispatch and
// one per combine of every scheduled MoE block forward (including the
// fused forwards inside `bwd_last`/`bwd_single`; backward recomputes
// stay local), payload counted once per round over ALL `ep²` (src, dst)
// parts including each rank's self part, and the tier split classifying
// only the src ≠ dst parts by the EP group's `NodeMap`.  At `ep == 1`
// no EP group exists — the engine takes the all-local path and every
// counter stays zero, so every function here returns 0 for `ep <= 1`.
// ---------------------------------------------------------------------------

/// Per-expert token capacity per micro-batch — the EXACT mirror of
/// `moe::capacity`: `ceil(cf · tokens · topk / experts)`, clamped to
/// `[1, tokens]` (at `experts == 1` the clamp lands on `tokens`, which
/// is what makes a top-1 single-expert MoE bitwise-dense).
pub fn moe_capacity(tokens: u64, topk: u64, experts: u64, capacity_factor: f32) -> u64 {
    let raw =
        (capacity_factor as f64 * (tokens * topk) as f64 / experts as f64).ceil();
    (raw as u64).min(tokens).max(1)
}

/// All-to-all rounds per step summed over every EP group of the grid:
/// each of the `n_stages` stage chunks runs one dispatch + one combine
/// round per micro-batch, in each of the `tp × (dp / ep)` EP-group
/// columns.  Engine pin: `TrainReport::moe_a2a_rounds == steps ×` this.
pub fn moe_a2a_rounds_per_step(n_stages: u64, m: u64, tp: u64, dp: u64, ep: u64) -> u64 {
    if ep <= 1 {
        return 0;
    }
    tp * (dp / ep) * n_stages * 2 * m
}

/// Payload bytes of ONE all-to-all round: `ep²` parts (self included) of
/// `(experts / ep) · cap · hidden` elements each at the wire width —
/// i.e. `ep · experts · cap · hidden · wire_bytes`.  Engine pin:
/// `TrainReport::moe_a2a_payload_bytes ==
/// steps × moe_a2a_rounds_per_step(..) ×` this `/ (steps × rounds)` —
/// rounds are homogeneous, so payload = rounds × this.
pub fn moe_a2a_payload_bytes_per_round(
    ep: u64,
    experts: u64,
    cap: u64,
    hidden: u64,
    wire_bytes: u64,
) -> u64 {
    if ep <= 1 {
        return 0;
    }
    ep * experts * cap * hidden * wire_bytes
}

/// Per-step `(intra, inter)` tier bytes of the MoE all-to-all under the
/// engine's packed placement: EP group member `e` of block `b` at cell
/// `(pp_rank, tp_rank)` is world rank `(pp_rank·dp + b·ep + e)·tp +
/// tp_rank`, and each ordered src ≠ dst pair moves one
/// `(experts/ep)·cap·hidden`-element part per round, classified by node
/// co-residency.  Topology-blind runs (`nodes == 0`) keep both tiers
/// zero, exactly like the engine counters.  Engine pin:
/// `TrainReport::moe_a2a_{intra,inter}_bytes == steps ×` this.
pub fn moe_a2a_tier_bytes_per_step(
    n_stages: u64,
    m: u64,
    pp: usize,
    tp: usize,
    dp: usize,
    ep: usize,
    experts: u64,
    cap: u64,
    hidden: u64,
    wire_bytes: u64,
    nodes: u32,
) -> (u64, u64) {
    if ep <= 1 || nodes == 0 {
        return (0, 0);
    }
    let world = (pp * dp * tp) as u32;
    let machine = Machine::new(nodes);
    let part = (experts / ep as u64) * cap * hidden * wire_bytes;
    // chunks hosted per pipeline worker × (dispatch + combine) per mb
    let rounds_per_group = 2 * m * (n_stages / pp as u64);
    let (mut intra, mut inter) = (0u64, 0u64);
    for pp_rank in 0..pp {
        for tp_rank in 0..tp {
            for block in 0..dp / ep {
                let node: Vec<u32> = (0..ep)
                    .map(|e| {
                        let rank = ((pp_rank * dp + block * ep + e) * tp + tp_rank) as u32;
                        machine.node_of(packed_gpu_of(world, nodes, rank))
                    })
                    .collect();
                for i in 0..ep {
                    for j in 0..ep {
                        if i == j {
                            continue;
                        }
                        if node[i] == node[j] {
                            intra += part;
                        } else {
                            inter += part;
                        }
                    }
                }
            }
        }
    }
    (intra * rounds_per_group, inter * rounds_per_group)
}

// ---------------------------------------------------------------------------
// The DP overlap contract (§IV: DeepSpeed hides the gradient all-reduce
// under backward), shared between the analytic model and the engine's
// measured hidden/exposed gradient-sync timers.
// ---------------------------------------------------------------------------

/// Default fraction of the DP gradient reduction hidden under backward,
/// used absent an engine measurement (the DeepSpeed-style assumption the
/// paper-figure calibrations were fitted with).
pub const DEFAULT_DP_OVERLAP: f64 = 0.65;

/// Measured DP overlap fraction from (raw, exposed) gradient-sync
/// seconds: `1 - exposed / raw`, clamped to `[0, 1]`.
///
/// This is THE contract function tying the model to the engine: the
/// engine's `TrainReport::dp_overlap_fraction` computes it from its
/// hidden/exposed bucket timers, and [`PerfModel::dp_exposed_comm_time`]
/// prices the model's exposed DP term as `raw * (1 - fraction)` — so a
/// model calibrated with the measured fraction reproduces the engine's
/// exposed comm time exactly (the overlap analogue of PR 2's TP
/// all-reduce byte pin).
pub fn dp_overlap_fraction(raw_s: f64, exposed_s: f64) -> f64 {
    if raw_s <= 0.0 {
        return 0.0;
    }
    (1.0 - exposed_s / raw_s).clamp(0.0, 1.0)
}

/// Kernel-efficiency model: what fraction of peak the GEMMs sustain.
#[derive(Debug, Clone)]
pub struct KernelModel {
    /// Asymptotic GEMM efficiency on MI250X (calibrated, see module doc).
    pub e_max: f64,
    /// Half-saturation point in tokens per micro-batch (GEMM M dimension).
    pub tokens_half: f64,
    /// Long-tail saturation: GEMM efficiency keeps creeping up well past
    /// the knee (wave quantisation amortises slowly on MI250X).  Weight of
    /// the slow component; its half-point is `tokens_tail_half`.
    pub tokens_tail_weight: f64,
    pub tokens_tail_half: f64,
    /// Half-saturation point of the per-shard width `d / tp` (GEMM N/K).
    pub width_half: f64,
    /// Fixed per-layer launch/sync overhead (kernel launches, norms).
    pub layer_overhead: f64,
    /// Slowdown of the attention block without Flash-Attention
    /// (calibrated so the paper models gain "up to 30%", §V.A).
    pub no_flash_attn_penalty: f64,
}

impl Default for KernelModel {
    fn default() -> Self {
        Self {
            e_max: 0.515,
            tokens_half: 220.0,
            tokens_tail_weight: 0.10,
            tokens_tail_half: 8000.0,
            width_half: 330.0,
            layer_overhead: 180.0e-6,
            no_flash_attn_penalty: 1.9,
        }
    }
}

impl KernelModel {
    /// Sustained fraction of peak for this (model, strategy) pair.
    pub fn efficiency(&self, model: &ModelSpec, cfg: &ParallelConfig) -> f64 {
        let tokens = (cfg.mbs as u64 * model.seq) as f64;
        let width = (model.hidden / cfg.tp as u64) as f64;
        let fast = tokens / (tokens + self.tokens_half);
        let tail = (1.0 - self.tokens_tail_weight)
            + self.tokens_tail_weight * tokens / (tokens + self.tokens_tail_half);
        self.e_max * fast * tail * (width / (width + self.width_half))
    }
}

/// Why a configuration cannot run (mirrors the paper's HPO failures).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PerfError {
    /// Per-GPU footprint exceeds 64 GB HBM (Fig 9's red arrows).
    OutOfMemory { required_gib: u64 },
    /// Batch/parallelism factorisation is inconsistent.
    Invalid(String),
}

/// Full decomposition of one training step (seconds unless noted).
#[derive(Debug, Clone)]
pub struct StepBreakdown {
    /// Pure compute across the pipelined micro-batches (incl. recompute).
    pub t_compute: f64,
    /// TP all-reduce time folded into each micro-batch.
    pub t_tp_comm: f64,
    /// Pipeline bubble (idle) time.
    pub t_bubble: f64,
    /// Exposed (non-overlapped) PP activation/grad p2p time.
    pub t_pp_comm: f64,
    /// Exposed DP gradient synchronisation time.
    pub t_dp_comm: f64,
    /// Optimizer step (HBM-bound parameter update).
    pub t_optimizer: f64,
    pub t_step: f64,
    /// Hardware FLOPs executed per GPU per step (incl. recompute).
    pub hw_flops_per_gpu: f64,
    /// Model FLOPs (6·N·tokens share) per GPU per step.
    pub model_flops_per_gpu: f64,
    /// Achieved hardware TFLOPS per GPU.
    pub tflops_per_gpu: f64,
    /// Percentage of the 191.5 TFLOPS fp16 peak — the paper's headline
    /// metric (Fig 11).
    pub pct_peak: f64,
    /// Arithmetic intensity (FLOPs / HBM byte) for the roofline check §V.B.
    pub arithmetic_intensity: f64,
}

/// The closed-form performance model.
#[derive(Debug, Clone)]
pub struct PerfModel {
    pub kernel: KernelModel,
    /// Fraction of PP p2p hidden under compute (DeepSpeed overlaps sends).
    pub pp_overlap: f64,
    /// Fraction of the DP gradient reduction hidden under backward.
    /// Defaults to [`DEFAULT_DP_OVERLAP`]; calibrate from a real run
    /// with [`PerfModel::with_dp_overlap`] fed by the engine's measured
    /// `TrainReport::dp_overlap_fraction` (see [`dp_overlap_fraction`]).
    pub dp_overlap: f64,
}

impl Default for PerfModel {
    fn default() -> Self {
        Self { kernel: KernelModel::default(), pp_overlap: 0.0, dp_overlap: DEFAULT_DP_OVERLAP }
    }
}

impl PerfModel {
    pub fn new() -> Self {
        Self::default()
    }

    /// Model with an engine-measured (or hypothesised) DP overlap
    /// fraction in place of the default.
    pub fn with_dp_overlap(mut self, fraction: f64) -> Self {
        self.dp_overlap = fraction.clamp(0.0, 1.0);
        self
    }

    /// Exposed (non-hidden) DP gradient-sync time the model prices for
    /// a raw sync time — the engine-facing half of the overlap contract.
    pub fn dp_exposed_comm_time(&self, raw_s: f64) -> f64 {
        raw_s * (1.0 - self.dp_overlap)
    }

    /// Exposed DP sync time of a topology-aware (hierarchical) run,
    /// priced per tier from the engine's `*_intra_bytes`/`*_inter_bytes`
    /// counters (or the matching `hier_*` contract terms) through
    /// [`CommModel::tiered_time`] — the `Machine::link`-driven per-tier
    /// bandwidth terms.  The overlap fraction applies to the whole sync,
    /// exactly as in the flat path.
    pub fn hier_dp_comm_time(
        &self,
        comm: &CommModel,
        gpu_group: &[GpuId],
        intra_bytes: u64,
        inter_bytes: u64,
    ) -> f64 {
        self.dp_exposed_comm_time(comm.tiered_time(gpu_group, intra_bytes, inter_bytes))
    }

    /// Per-micro-batch, per-GPU forward compute+TP-comm time for one stage
    /// (the largest stage: ceil(L/pp) layers).
    fn microbatch_times(
        &self,
        model: &ModelSpec,
        cfg: &ParallelConfig,
        comm: &CommModel,
        layout: &RankLayout,
    ) -> (f64, f64, f64) {
        let b = cfg.mbs as u64;
        let s = model.seq;
        let d = model.hidden;
        let tokens = (b * s) as f64;
        let layers_stage = model.n_layers.div_ceil(cfg.pp);

        // ---- compute ----
        let eff = self.kernel.efficiency(model, cfg);
        let rate = PEAK_FP16_FLOPS * eff;

        // per-layer fwd flops per TP shard: dense 2·N_layer·tokens plus the
        // quadratic attention term 2·2·d·s per token (QK^T and PV)
        let n_layer = model.layer_params() as f64 / cfg.tp as f64;
        let quad = 4.0 * d as f64 * s as f64 / cfg.tp as f64; // per token
        let mut fwd_flops_layer = 2.0 * n_layer * tokens + quad * tokens;

        // attention block share of layer time; without FA the block runs
        // `no_flash_attn_penalty` slower (memory-bound softmax paths)
        let attn_flops = (4.0 * (d as f64 / cfg.tp as f64) * d as f64) * 2.0 * tokens
            + quad * tokens;
        let attn_share = (attn_flops / fwd_flops_layer).min(1.0);
        let flash_mult = if cfg.flash_attention {
            1.0
        } else {
            1.0 + attn_share * (self.kernel.no_flash_attn_penalty - 1.0)
        };

        // MoE layers: the capacity-padded expert buffers push `E · cap`
        // token slots through the FFN GEMMs instead of `tokens` (the
        // engine computes every expert's buffer to capacity), plus the
        // TP-replicated `d × E` gate matmul.  Dense (experts = 1) adds
        // exactly nothing, keeping the calibrated figures bit-stable.
        if cfg.experts > 1 {
            let e = cfg.experts as f64;
            let cap = moe_capacity(
                b * s,
                cfg.moe_topk as u64,
                cfg.experts as u64,
                cfg.capacity_factor,
            ) as f64;
            let ffn_params = 8.0 * (d * d) as f64 / cfg.tp as f64;
            fwd_flops_layer += 2.0 * ffn_params * (e * cap - tokens).max(0.0)
                + 2.0 * d as f64 * e * tokens;
        }

        let t_fwd_layer = fwd_flops_layer / rate * flash_mult + self.kernel.layer_overhead;

        // embedding + head cost on the boundary stages (charged to every
        // stage's budget conservatively via the max-stage convention)
        let head_flops = 2.0 * (d * model.vocab) as f64 * tokens / cfg.tp as f64;
        let t_head = head_flops / rate / cfg.pp as f64;

        // ---- TP all-reduce: 2 per layer fwd, 2 per layer bwd (one per
        // sharded block per direction; TP_BLOCKS_PER_TRANSFORMER_LAYER
        // blocks per layer) — same payload contract the engine's
        // instrumented SubGroups are tested against ----
        let tp_group = layout.tp_group(0);
        let ar_bytes = tp_allreduce_payload_bytes(b * s, d, cfg.precision.bytes());
        let (t_ar, _) = comm.allreduce(&tp_group, ar_bytes);

        let t_fwd = layers_stage as f64 * (t_fwd_layer + 2.0 * t_ar) + t_head;
        // backward: 2x fwd flops, plus full recompute when checkpointing
        let recompute = if cfg.checkpoint_activations { 1.0 } else { 0.0 };
        let t_bwd = layers_stage as f64
            * ((2.0 + recompute) * t_fwd_layer + 2.0 * t_ar)
            + 2.0 * t_head;

        (t_fwd, t_bwd, layers_stage as f64 * 4.0 * t_ar)
    }

    /// Evaluate a configuration; `Err` when it cannot run at all.
    pub fn evaluate(
        &self,
        model: &ModelSpec,
        cfg: &ParallelConfig,
    ) -> Result<StepBreakdown, PerfError> {
        cfg.validate().map_err(PerfError::Invalid)?;
        if !cfg.tp_divides(model.hidden, model.vocab) {
            return Err(PerfError::Invalid(format!(
                "tp {} does not divide hidden {} / vocab {}",
                cfg.tp, model.hidden, model.vocab
            )));
        }
        if cfg.pp > model.n_layers {
            return Err(PerfError::Invalid(format!(
                "pp {} exceeds layer count {}",
                cfg.pp, model.n_layers
            )));
        }
        let chunks = cfg.schedule.chunks();
        if cfg.pp * chunks > model.n_layers {
            return Err(PerfError::Invalid(format!(
                "pp {} x interleave {chunks} exceeds layer count {}",
                cfg.pp, model.n_layers
            )));
        }
        let breakdown = mem::per_gpu(model, cfg);
        if breakdown.total() > crate::topology::HBM_BYTES {
            return Err(PerfError::OutOfMemory { required_gib: breakdown.gib() as u64 });
        }

        let machine = Machine::for_gpus(cfg.world_size());
        let comm = CommModel::new(machine);
        let layout = RankLayout::new(cfg.tp, cfg.pp, cfg.dp);

        let m = cfg.microbatches() as f64;
        let p = cfg.pp as f64;
        let (t_fwd, t_bwd, t_tp_per_mb) = self.microbatch_times(model, cfg, &comm, &layout);
        let t_mb = t_fwd + t_bwd;

        // ---- pipeline ----
        let v = cfg.schedule.chunks() as f64;
        let fill = (p - 1.0) / v;
        let t_pipe = (m + fill) * t_mb;
        let t_bubble = fill * t_mb;
        let t_compute = m * (t_mb - t_tp_per_mb);
        let t_tp_comm = m * t_tp_per_mb;

        // ---- PP p2p ----
        let t_pp_comm = if cfg.pp > 1 {
            let bytes = cfg.mbs as u64 * model.seq * model.hidden * cfg.precision.bytes();
            // adjacent pipeline stages sit dp*tp ranks apart
            let stride = cfg.dp * cfg.tp;
            let t_hop = comm.p2p(0, stride.min(machine_last_gpu(&comm)), bytes);
            // one activation send fwd + one grad send bwd per micro-batch,
            // partially overlapped with compute
            2.0 * m * t_hop * (1.0 - self.pp_overlap)
        } else {
            0.0
        };

        // ---- DP gradient sync: half-width gradients under mixed
        // precision, same dtype convention as the TP term above (the
        // sharded stages' RS+AG pair moves the same volume inside
        // dp_grad_sync — ZeRO's equal-wire-volume argument) ----
        let mut n_local = model.total_params() / (cfg.tp as u64 * cfg.pp as u64);
        if cfg.experts > 1 {
            // (E−1) extra FFN copies per layer (TP/PP-sharded like the
            // dense FFN) plus the TP-replicated d×E gate per layer
            let ffn = 8 * model.hidden * model.hidden;
            n_local += (cfg.experts as u64 - 1) * ffn * model.n_layers as u64
                / (cfg.tp as u64 * cfg.pp as u64)
                + model.hidden * cfg.experts as u64 * model.n_layers as u64
                    / cfg.pp as u64;
        }
        let grad_bytes = dp_grad_payload_bytes(n_local, cfg.precision.bytes());
        let dp_group = layout.dp_group(0);
        let gpu_group: Vec<u32> = dp_group.iter().map(|&r| layout.gpu_of(r)).collect();
        let t_dp_raw =
            comm.dp_grad_sync(&gpu_group, grad_bytes, cfg.zero_stage.shards_optimizer());
        let mut t_dp_comm = self.dp_exposed_comm_time(t_dp_raw);
        if cfg.zero_stage.shards_params() {
            // ZeRO-3's on-demand parameter gathers: the replica's local
            // params cross the DP group once per forward and once per
            // backward pass of every micro-batch (the per-layer gathers
            // of one pass amortise to one aggregated gather; prefetch
            // hides latency, not bandwidth, so the term stays exposed)
            let ag_bytes = n_local * cfg.precision.bytes();
            t_dp_comm += 2.0 * m * comm.all_gather(&gpu_group, ag_bytes);
        }

        // ---- optimizer (HBM-bound: read/write 14 bytes/param + math) ----
        let opt_bytes = (14 * n_local) as f64
            / if cfg.zero_stage.shards_optimizer() { cfg.dp as f64 } else { 1.0 };
        let t_optimizer = opt_bytes / HBM_BW + 50.0e-6;

        let t_step = t_pipe + t_pp_comm + t_dp_comm + t_optimizer;

        // ---- flops accounting ----
        let tokens_step = (cfg.gbs as u64 * model.seq) as f64;
        let world = cfg.world_size() as f64;
        let model_flops = model.flops_per_token() * tokens_step / world;
        let recompute_factor = if cfg.checkpoint_activations { 8.0 / 6.0 } else { 1.0 };
        let hw_flops = model_flops * recompute_factor;
        let tflops = hw_flops / t_step / 1e12;

        // Arithmetic intensity: hw flops vs HBM traffic.  GEMM tiling
        // re-reads the weight panel once per ~256-row output tile (the
        // MI250X L2-resident tile height), so weight traffic is inflated
        // by tokens/256 per pass; three weight passes per micro-batch
        // (fwd, recompute, bwd) plus the stored/streamed activations.
        let tokens_mb = (cfg.mbs as u64 * model.seq) as f64;
        let tile_reuse = (tokens_mb / 256.0).max(1.0);
        let weight_bytes = 3.0 * 2.0 * n_local as f64 * tile_reuse * m;
        let act_bytes = 2.0 * 34.0 * (cfg.mbs as u64 * model.seq * model.hidden) as f64 * m
            * model.n_layers as f64
            / (cfg.tp as f64 * cfg.pp as f64);
        let ai = hw_flops / (weight_bytes + act_bytes);

        Ok(StepBreakdown {
            t_compute,
            t_tp_comm,
            t_bubble,
            t_pp_comm,
            t_dp_comm,
            t_optimizer,
            t_step,
            hw_flops_per_gpu: hw_flops,
            model_flops_per_gpu: model_flops,
            tflops_per_gpu: tflops,
            pct_peak: 100.0 * tflops * 1e12 / PEAK_FP16_FLOPS,
            arithmetic_intensity: ai,
        })
    }

    /// Samples/second for scaling studies (Figs 12, 13).
    pub fn samples_per_sec(&self, model: &ModelSpec, cfg: &ParallelConfig) -> Result<f64, PerfError> {
        let b = self.evaluate(model, cfg)?;
        Ok(cfg.gbs as f64 / b.t_step)
    }
}

fn machine_last_gpu(comm: &CommModel) -> u32 {
    comm.machine.n_gpus() - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{fig11_recipes, lookup, recipe_175b, ParallelConfig};

    fn pm() -> PerfModel {
        PerfModel::default()
    }

    #[test]
    fn observation_iii_1_tp_hurts() {
        // Fig 6: 1.4B on 8 GPUs, throughput decreases monotonically with TP
        let m = lookup("1.4b").unwrap();
        let mut last = f64::INFINITY;
        for tp in [1u32, 2, 4, 8] {
            let cfg = ParallelConfig::default()
                .with_tp(tp)
                .with_dp(8 / tp)
                .with_gbs(64)
                .with_mbs(4);
            let b = pm().evaluate(&m, &cfg).unwrap();
            assert!(
                b.pct_peak < last,
                "tp={tp}: {:.2}% !< {last:.2}%",
                b.pct_peak
            );
            last = b.pct_peak;
        }
    }

    #[test]
    fn observation_iii_2_gbs_helps() {
        // Fig 7: throughput rises with global batch size (more microbatches)
        let m = lookup("22b").unwrap();
        let mut last = 0.0;
        for gbs in [8u32, 16, 32, 64, 128] {
            let cfg = ParallelConfig::default().with_tp(2).with_pp(8).with_gbs(gbs);
            let b = pm().evaluate(&m, &cfg).unwrap();
            assert!(b.pct_peak > last, "gbs={gbs}");
            last = b.pct_peak;
        }
    }

    #[test]
    fn observation_iii_3_pp_at_fixed_gbs_hurts() {
        // Fig 8a
        let m = lookup("175b").unwrap();
        let mut last = f64::INFINITY;
        for pp in [8u32, 16, 32] {
            let cfg = ParallelConfig::default().with_tp(8).with_pp(pp).with_gbs(128);
            let b = pm().evaluate(&m, &cfg).unwrap();
            assert!(b.pct_peak < last, "pp={pp}");
            last = b.pct_peak;
        }
    }

    #[test]
    fn observation_iii_4_fixed_ratio_flat() {
        // Fig 8b: scaling GBS with PP keeps throughput within a few percent
        let m = lookup("175b").unwrap();
        let base = pm()
            .evaluate(&m, &ParallelConfig::default().with_tp(8).with_pp(8).with_gbs(128))
            .unwrap()
            .pct_peak;
        for (pp, gbs) in [(16u32, 256u32), (32, 512)] {
            let cfg = ParallelConfig::default().with_tp(8).with_pp(pp).with_gbs(gbs);
            let b = pm().evaluate(&m, &cfg).unwrap();
            let rel = (b.pct_peak - base).abs() / base;
            assert!(rel < 0.10, "pp={pp}: {:.2}% vs {base:.2}%", b.pct_peak);
        }
    }

    #[test]
    fn fig11_recipes_reproduce_achieved_throughput() {
        // Shape target: ordering 22B > 175B > 1T and values within 4 points
        let results: Vec<(f64, f64)> = fig11_recipes()
            .into_iter()
            .map(|(r, paper_pct, _)| {
                (pm().evaluate(&r.model, &r.parallel).unwrap().pct_peak, paper_pct)
            })
            .collect();
        assert!(results[0].0 > results[1].0 && results[1].0 > results[2].0);
        for (ours, paper) in &results {
            assert!(
                (ours - paper).abs() < 2.0,
                "predicted {ours:.2}% vs paper {paper:.2}%"
            );
        }
    }

    #[test]
    fn flash_attention_gain_up_to_30pct() {
        // §V.A claim: FA2 brings up to 30% throughput improvement
        let r = recipe_175b();
        let with = pm().evaluate(&r.model, &r.parallel).unwrap().tflops_per_gpu;
        let without = pm()
            .evaluate(&r.model, &r.parallel.clone().with_flash(false))
            .unwrap()
            .tflops_per_gpu;
        let gain = with / without - 1.0;
        assert!(gain > 0.10 && gain < 0.40, "gain {:.1}%", gain * 100.0);
    }

    #[test]
    fn tp_comm_contract_composition() {
        // the closed-form per-layer count (2 blocks × fwd+bwd) and the
        // builtin per-microbatch composition must agree on the shared
        // per-all-reduce payload
        let (t, d) = (16u64, 16u64);
        assert_eq!(tp_allreduce_payload_bytes(t, d, 4), t * d * 4);
        assert_eq!(TP_BLOCKS_PER_TRANSFORMER_LAYER * 2, 4); // ARs per layer
        // builtin (1 block per stage): k-stage pipeline moves 2k block ARs
        // + 2 embed + 1 head of t·d, plus 3t of softmax statistics
        for k in [2u64, 4] {
            assert_eq!(
                builtin_tp_ar_floats_per_microbatch(k, t, d),
                (2 * k + 3) * t * d + 3 * t
            );
        }
        // fused single stage embeds once
        assert_eq!(
            builtin_tp_ar_floats_per_microbatch(1, t, d),
            4 * t * d + 3 * t
        );
        assert_eq!(builtin_tp_grad_sync_floats_per_step(4, d), 4 * (d + 1));
        // the dtype-aware byte variants: width × floats, so bf16 is
        // exactly half of fp32
        for k in [1u64, 2, 4] {
            let floats = builtin_tp_ar_floats_per_microbatch(k, t, d);
            assert_eq!(builtin_tp_ar_bytes_per_microbatch(k, t, d, 4), 4 * floats);
            assert_eq!(
                builtin_tp_ar_bytes_per_microbatch(k, t, d, 2) * 2,
                builtin_tp_ar_bytes_per_microbatch(k, t, d, 4)
            );
        }
        assert_eq!(builtin_tp_grad_sync_bytes_per_step(4, d, 2), 2 * 4 * (d + 1));
    }

    #[test]
    fn dp_wire_contract_dtype_aware() {
        // reduce + (ZeRO-1) all-gather halves, at both widths
        assert_eq!(dp_grad_payload_bytes(1000, 4), 4000);
        assert_eq!(dp_grad_payload_bytes(1000, 2), 2000);
        assert_eq!(zero1_allgather_payload_bytes(1000, 2), 2000);
        // the closed-form model prices its DP term from the same fn: a
        // precision flip halves the raw DP sync volume
        use crate::config::Precision;
        let m = lookup("175b").unwrap();
        let cfg16 = ParallelConfig::default().with_tp(4).with_pp(16).with_dp(4).with_gbs(64);
        let mut cfg32 = cfg16.clone();
        cfg32.precision = Precision::Fp32;
        let b16 = pm().evaluate(&m, &cfg16).unwrap();
        let b32 = pm().evaluate(&m, &cfg32).unwrap();
        assert!(
            b32.t_dp_comm > b16.t_dp_comm,
            "fp32 grads must cost more DP sync: {} vs {}",
            b32.t_dp_comm,
            b16.t_dp_comm
        );
    }

    #[test]
    fn zero3_and_pp_p2p_contract_composition() {
        // ZeRO-3 AG floats: mid/first stages gather 2m× their params
        // (fwd + bwd), the head chunk m× (its forward only stashes), the
        // fused single stage m×
        assert_eq!(builtin_zero3_ag_floats_per_step(&[10, 20], 3), 2 * 3 * 10 + 3 * 20);
        assert_eq!(builtin_zero3_ag_floats_per_step(&[10], 3), 3 * 10);
        assert_eq!(
            builtin_zero3_ag_floats_per_step(&[5, 7, 9], 2),
            4 * 5 + 4 * 7 + 2 * 9
        );
        // PP p2p floats: 2m(k-1)·t·d across the wire, nothing at pp = 1
        assert_eq!(builtin_pp_p2p_floats_per_step(4, 4, 2, 16, 8), 2 * 2 * 3 * 16 * 8);
        assert_eq!(builtin_pp_p2p_floats_per_step(4, 2, 2, 16, 8), 2 * 2 * 3 * 16 * 8);
        assert_eq!(builtin_pp_p2p_floats_per_step(4, 1, 2, 16, 8), 0);
    }

    #[test]
    fn sharding_stage_pricing_ladder() {
        use crate::zero::ShardingStage;
        // stages 1 and 2 price identically (same RS+AG wire volume, same
        // sharded optimizer walk); stage 3 adds the per-micro-batch
        // parameter gathers to the DP term; stage 0 pays the full
        // optimizer walk
        let model = lookup("175b").unwrap();
        let base = ParallelConfig::default().with_tp(4).with_pp(16).with_dp(4).with_gbs(64);
        let eval = |s: ShardingStage| {
            pm().evaluate(&model, &base.clone().with_zero_stage(s)).unwrap()
        };
        let s0 = eval(ShardingStage::Ddp);
        let s1 = eval(ShardingStage::OptimizerStates);
        let s2 = eval(ShardingStage::Gradients);
        let s3 = eval(ShardingStage::Parameters);
        assert_eq!(s1.t_dp_comm, s2.t_dp_comm, "stage 1 and 2 move the same wire volume");
        assert_eq!(s1.t_optimizer, s2.t_optimizer);
        assert!(s3.t_dp_comm > s2.t_dp_comm, "stage 3 pays the on-demand param gathers");
        assert!(s0.t_optimizer > s1.t_optimizer, "stage 0 walks the full optimizer state");
        // the boolean alias still lands on stage 1 exactly
        let alias = pm().evaluate(&model, &base.clone().with_zero1(true)).unwrap();
        assert_eq!(alias.t_step, s1.t_step);
    }

    #[test]
    fn dp_overlap_contract_round_trips() {
        // fraction from (raw, exposed) plugged back into the model must
        // reproduce the exposed time exactly — the measured-overlap pin
        for (raw, exposed) in [(2.0f64, 0.5f64), (1.0, 1.0), (3.0, 0.0)] {
            let f = dp_overlap_fraction(raw, exposed);
            assert!((0.0..=1.0).contains(&f));
            let m = pm().with_dp_overlap(f);
            assert!((m.dp_exposed_comm_time(raw) - exposed).abs() < 1e-12);
        }
        // degenerate / clamped inputs
        assert_eq!(dp_overlap_fraction(0.0, 0.0), 0.0);
        assert_eq!(dp_overlap_fraction(-1.0, 0.5), 0.0);
        assert_eq!(dp_overlap_fraction(1.0, 2.0), 0.0); // exposed > raw clamps
        assert_eq!(pm().with_dp_overlap(7.0).dp_overlap, 1.0);
        // the default stays the calibrated paper assumption
        assert_eq!(pm().dp_overlap, DEFAULT_DP_OVERLAP);
    }

    #[test]
    fn hier_tier_contract_composition() {
        // 4 ranks over 2 nodes, reps at group ranks 0 and 2
        let nodes = [0u32, 0, 1, 1];
        // AR: intra = w·len·2(n−k); inter = k·gw(len) bucketed
        let (i, e) = hier_ar_tier_bytes(1000, 256, &nodes, 4, GradWire::F32);
        assert_eq!(i, 4 * 1000 * 2 * 2);
        assert_eq!(e, 2 * 4 * 1000);
        // one node → all intra, no inter hop at any grad wire
        let flat = [0u32, 0, 0, 0];
        let (i, e) = hier_ar_tier_bytes(1000, 256, &flat, 4, GradWire::Int8);
        assert_eq!((i, e), (4 * 1000 * 2 * 3, 0));
        // singleton group moves nothing
        assert_eq!(hier_ar_tier_bytes(1000, 256, &[7], 4, GradWire::F32), (0, 0));
        // int8 inter bytes: per-bucket block overhead — 1000 floats in
        // 256-float buckets = 3×(256 + 4·2) + (232 + 4·2) per node copy
        let (_, e8) = hier_ar_tier_bytes(1000, 256, &nodes, 4, GradWire::Int8);
        assert_eq!(e8, 2 * (3 * (256 + 8) + (232 + 8)));
        // exactly 1/4 of the fp32 wire + 4 bytes per 128-block of scale
        // (k nodes × 8 blocks across the 4 buckets) — the acceptance
        // criterion's "1/4 + scale-overhead" stated as an identity
        assert_eq!(e8, e / 4 + 4 * 2 * 8);

        // RS: owner spans of 1000 over 4 ranks are 250 each; owners 1
        // and 3 are non-reps (one extra down payload)
        let (i, e) = hier_rs_tier_bytes(1000, 256, &nodes, 4, GradWire::Bf16);
        assert_eq!(i, 4 * 250 * ((2 + 0) + (2 + 1) + (2 + 0) + (2 + 1)));
        assert_eq!(e, 2 * 2 * 1000);

        // primary AG: non-rep shards up + (n−k)·total down; reps swap
        // the assembled buffer once over the wire
        let (i, e) = hier_ag_tier_bytes(1000, &nodes, 4);
        assert_eq!(i, 4 * (2 * 250 + 2 * 1000));
        assert_eq!(e, 4 * 1000);
        // secondary node gather: w·total per multi-member node
        assert_eq!(hier_node_ag_intra_bytes(1000, &nodes, 4), 2 * 4 * 1000);
        assert_eq!(hier_node_ag_intra_bytes(1000, &[0, 1], 4), 0); // lone members
        assert_eq!(hier_node_ag_intra_bytes(1000, &[0, 0, 1], 4), 4 * 1000);

        // step-level composition sums chunks under the right shape
        let (ai, ae) =
            hier_grad_sync_tier_bytes(&[1000, 500], 256, &nodes, 4, GradWire::F32, false);
        let (a1, e1) = hier_ar_tier_bytes(1000, 256, &nodes, 4, GradWire::F32);
        let (a2, e2) = hier_ar_tier_bytes(500, 256, &nodes, 4, GradWire::F32);
        assert_eq!((ai, ae), (a1 + a2, e1 + e2));
        // z3: first touch primary + (uses−1) secondary per stage; uses
        // mirror builtin_zero3_ag_floats_per_step (mid 2m, head m)
        let (zi, ze) = builtin_zero3_hier_ag_tier_bytes(&[100, 60], 3, &nodes, 4);
        let (p1, q1) = hier_ag_tier_bytes(100, &nodes, 4);
        let (p2, q2) = hier_ag_tier_bytes(60, &nodes, 4);
        let s1 = hier_node_ag_intra_bytes(100, &nodes, 4);
        let s2 = hier_node_ag_intra_bytes(60, &nodes, 4);
        assert_eq!(zi, p1 + 5 * s1 + p2 + 2 * s2);
        assert_eq!(ze, q1 + q2);
    }

    #[test]
    fn packed_dp_group_nodes_match_engine_placement() {
        // pp=3 × dp=2 × tp=1 over 2 nodes (per_node = 3): the middle pp
        // row's DP group straddles the node boundary, the outer rows
        // stay node-local — exactly the asymmetry per-row composition
        // must handle
        assert_eq!(packed_dp_group_nodes(0, 0, 3, 2, 1, 2), vec![0, 0]);
        assert_eq!(packed_dp_group_nodes(1, 0, 3, 2, 1, 2), vec![0, 1]);
        assert_eq!(packed_dp_group_nodes(2, 0, 3, 2, 1, 2), vec![1, 1]);
        // tp-innermost stride: dp=4 × tp=2 over 2 nodes (per_node = 4)
        assert_eq!(packed_dp_group_nodes(0, 0, 1, 4, 2, 2), vec![0, 0, 1, 1]);
        assert_eq!(packed_dp_group_nodes(0, 1, 1, 4, 2, 2), vec![0, 0, 1, 1]);
        // one node → all co-resident
        assert_eq!(packed_dp_group_nodes(0, 0, 1, 4, 1, 1), vec![0, 0, 0, 0]);
    }

    #[test]
    fn hier_tier_pricing_rewards_int8_wire() {
        // per-tier pricing through Machine::link: cutting inter bytes 4x
        // (the int8 wire) must cut the priced DP time on a 2-node group,
        // and the inter tier must dominate at equal bytes
        let comm = CommModel::new(Machine::new(2));
        let group: Vec<GpuId> = vec![0, 1, 8, 9];
        let m = pm().with_dp_overlap(0.0);
        let nodes = [0u32, 0, 1, 1];
        let p = 1u64 << 22;
        let (i32b, e32b) = hier_ar_tier_bytes(p, 1 << 15, &nodes, 4, GradWire::F32);
        let (i8b, e8b) = hier_ar_tier_bytes(p, 1 << 15, &nodes, 4, GradWire::Int8);
        assert_eq!(i32b, i8b, "the grad wire shapes only the inter hop");
        let t32 = m.hier_dp_comm_time(&comm, &group, i32b, e32b);
        let t8 = m.hier_dp_comm_time(&comm, &group, i8b, e8b);
        assert!(t8 < t32, "int8 {t8} !< fp32 {t32}");
        assert!(
            m.hier_dp_comm_time(&comm, &group, 0, e32b)
                > m.hier_dp_comm_time(&comm, &group, i32b, 0),
            "inter bytes must out-cost the same intra volume"
        );
    }

    #[test]
    fn moe_wire_contract_composition() {
        // capacity mirrors moe::capacity bit for bit
        assert_eq!(moe_capacity(16, 2, 8, 1.25), 5); // ceil(1.25·32/8)
        assert_eq!(moe_capacity(16, 1, 1, 1.25), 16); // clamps to tokens at E=1
        assert_eq!(moe_capacity(4, 1, 8, 1.0), 1); // floor clamp
        for (t, k, e, cf) in [(16, 2, 8, 1.25f32), (32, 1, 4, 1.0), (7, 3, 4, 2.0)] {
            assert_eq!(
                moe_capacity(t as u64, k as u64, e as u64, cf),
                crate::moe::capacity(t, k, e, cf) as u64
            );
        }
        // rounds: dispatch + combine per (chunk, mb) in each of the
        // tp × (dp/ep) EP-group columns; identically zero at ep = 1
        assert_eq!(moe_a2a_rounds_per_step(2, 3, 2, 4, 2), 2 * 2 * 2 * 2 * 3);
        assert_eq!(moe_a2a_rounds_per_step(2, 3, 2, 4, 1), 0);
        // payload/round: ep² parts of (E/ep)·cap·d elements incl. self
        assert_eq!(moe_a2a_payload_bytes_per_round(2, 4, 5, 8, 4), 2 * 4 * 5 * 8 * 4);
        assert_eq!(moe_a2a_payload_bytes_per_round(1, 4, 5, 8, 4), 0);
        // bf16 wire halves the round payload exactly
        assert_eq!(
            moe_a2a_payload_bytes_per_round(2, 4, 5, 8, 2) * 2,
            moe_a2a_payload_bytes_per_round(2, 4, 5, 8, 4)
        );
        // tiers: 4 ranks packed on 2 nodes → group nodes [0,0,1,1], so 4
        // of the 12 src≠dst pairs are intra and 8 inter, every round
        let (i, e) = moe_a2a_tier_bytes_per_step(2, 3, 1, 1, 4, 4, 4, 5, 8, 4, 2);
        let part = 1 * 5 * 8 * 4u64;
        let rounds = 2 * 3 * 2u64;
        assert_eq!((i, e), (4 * part * rounds, 8 * part * rounds));
        // tier sum + self parts == the full payload accounting
        let payload =
            moe_a2a_rounds_per_step(2, 3, 1, 4, 4) * moe_a2a_payload_bytes_per_round(4, 4, 5, 8, 4);
        assert_eq!(i + e + 4 * part * rounds, payload);
        // topology-blind and ep = 1 keep both tiers zero
        assert_eq!(moe_a2a_tier_bytes_per_step(2, 3, 1, 1, 4, 4, 4, 5, 8, 4, 0), (0, 0));
        assert_eq!(moe_a2a_tier_bytes_per_step(2, 3, 1, 1, 4, 1, 4, 5, 8, 4, 2), (0, 0));
    }

    #[test]
    fn moe_pricing_charges_experts() {
        // sparse experts cost step time (routed FFN compute + gate) and
        // the dense identity point prices exactly like a dense run
        let m = lookup("22b").unwrap();
        let dense = ParallelConfig::default().with_tp(2).with_pp(8).with_gbs(32);
        let b_dense = pm().evaluate(&m, &dense).unwrap();
        let b_id = pm().evaluate(&m, &dense.clone().with_moe(1, 1)).unwrap();
        assert_eq!(b_dense.t_step, b_id.t_step, "E=1 top-1 must price dense");
        let b_moe = pm()
            .evaluate(&m, &dense.clone().with_moe(8, 2).with_ep(1))
            .unwrap();
        assert!(
            b_moe.t_compute > b_dense.t_compute,
            "8 top-2 experts must add routed FFN compute: {} !> {}",
            b_moe.t_compute,
            b_dense.t_compute
        );
        assert!(
            b_moe.t_dp_comm >= b_dense.t_dp_comm,
            "expert params widen the DP sync"
        );
    }

    #[test]
    fn tp_not_dividing_hidden_rejected() {
        let m = lookup("22b").unwrap(); // hidden 6144, vocab 51200
        let cfg = ParallelConfig::default().with_tp(7).with_dp(1).with_gbs(16);
        assert!(matches!(pm().evaluate(&m, &cfg), Err(PerfError::Invalid(_))));
    }

    #[test]
    fn oom_configs_rejected() {
        let m = lookup("1t").unwrap();
        let cfg = ParallelConfig::default().with_tp(8).with_pp(2).with_gbs(16);
        assert!(matches!(
            pm().evaluate(&m, &cfg),
            Err(PerfError::OutOfMemory { .. })
        ));
    }

    #[test]
    fn arithmetic_intensity_not_memory_bound() {
        // §V.B: AI of 180+, far right of the ~1 flops/byte roofline knee
        for (r, _, _) in fig11_recipes().into_iter().take(2) {
            let b = pm().evaluate(&r.model, &r.parallel).unwrap();
            assert!(b.arithmetic_intensity > 100.0, "{}", b.arithmetic_intensity);
        }
    }
}
