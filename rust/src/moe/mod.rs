//! Mixture-of-experts primitives: the deterministic top-k softmax gate,
//! capacity/dispatch planning, and the expert-parallel wire context.
//!
//! The heavy lifting (expert GEMMs, the gate matmul) stays in
//! `runtime::builtin`, which owns the parameters; this module holds the
//! pure, backend-free pieces so they can be validated in isolation:
//!
//! * **Gate** — per-token top-k selection over `E` logits with *stable
//!   tie-breaking* (higher logit wins; on exact ties the lower expert
//!   index wins), then a softmax renormalized over the selected set.
//!   `k = 1` yields probability exactly `1.0` (`exp(0)/exp(0)`), the
//!   identity the single-expert ≡ dense bitwise contract rides on.
//!   The backward is the renormalized-softmax Jacobian, finite-diff
//!   validated in the tests below.
//! * **Capacity** — every expert owns `cap = min(⌈cf·T·k/E⌉, T)` slots
//!   per microbatch; assignments beyond an expert's capacity are
//!   **dropped in token order** (deterministic, data-local, so the plan
//!   is identical at every `ep` — the invariant that keeps ep>1 on the
//!   ep=1 trajectory bitwise at fp32).  The `min(·, T)` clamp matters
//!   beyond economy: at `E = 1` it makes the expert buffer exactly the
//!   token buffer, so the TP all-reduce chunking (ring fold order is
//!   length-dependent) matches the dense path bit for bit.
//! * **[`MoeFwdCtx`]** — what a forward pass needs to go expert-parallel:
//!   the per-(pp, tp)-row EP communicator and this rank's coordinates in
//!   it, the wire dtype, and the engine's dropped-token counter.

use std::sync::atomic::AtomicU64;
use std::sync::Arc;

use crate::collectives::Group;
use crate::precision::Dtype;

/// Per-expert slot budget for one microbatch of `tokens` tokens:
/// `min(⌈capacity_factor · tokens · topk / experts⌉, tokens)`, at least 1.
/// The clamp to `tokens` is exact semantics, not just economy — no
/// expert can receive more than every token once — and it pins the
/// `E = 1` buffer length to the dense activation length (see module
/// docs).  Mirrored EXACTLY by `perf::moe_capacity`.
pub fn capacity(tokens: usize, topk: usize, experts: usize, capacity_factor: f32) -> usize {
    assert!(experts >= 1 && topk >= 1 && capacity_factor > 0.0);
    let raw = (capacity_factor as f64 * (tokens * topk) as f64 / experts as f64).ceil();
    (raw as usize).min(tokens).max(1)
}

/// Which EP-group rank owns expert `e` when `experts` are sharded over
/// `ep` ranks in contiguous blocks of `experts / ep`.
pub fn owner_of(e: usize, experts: usize, ep: usize) -> usize {
    debug_assert!(ep >= 1 && experts % ep == 0 && e < experts);
    e / (experts / ep)
}

/// The gate's per-token selection: `k` `(expert, prob)` pairs per token,
/// flattened — entry `t * k + j` is token `t`'s `j`-th pick, in
/// **descending-logit order** (ties broken toward the lower expert
/// index, so the layout is fully deterministic).
#[derive(Debug, Clone, PartialEq)]
pub struct TopK {
    /// Selected expert index per (token, pick), `t * k + j`.
    pub expert: Vec<usize>,
    /// Renormalized softmax probability per (token, pick); the `k`
    /// entries of one token sum to 1 (exactly 1.0 at `k = 1`).
    pub prob: Vec<f32>,
}

/// Deterministic top-k softmax gate over row-major `logits` (`t × e`).
///
/// Selection: `k` repeated strict-max scans, each preferring the lowest
/// index among exact ties — no sort, no hash, no RNG, so the result is
/// a pure function of the logit bits.  Probabilities: softmax over the
/// selected logits only (max-subtracted), i.e. the "renormalized top-k"
/// gate of the MoE literature.
pub fn top_k_select(logits: &[f32], t: usize, e: usize, k: usize) -> TopK {
    assert!(k >= 1 && k <= e, "topk {k} must be in 1..={e}");
    assert_eq!(logits.len(), t * e);
    let mut expert = Vec::with_capacity(t * k);
    let mut prob = Vec::with_capacity(t * k);
    let mut picked = vec![false; e];
    for row in logits.chunks_exact(e) {
        picked.iter_mut().for_each(|p| *p = false);
        for _ in 0..k {
            let mut best = usize::MAX;
            for (j, &l) in row.iter().enumerate() {
                if !picked[j] && (best == usize::MAX || l > row[best]) {
                    best = j;
                }
            }
            picked[best] = true;
            expert.push(best);
        }
        // renormalized softmax over this token's k selected logits
        let sel = &expert[expert.len() - k..];
        let m = sel.iter().map(|&j| row[j]).fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = sel.iter().map(|&j| (row[j] - m).exp()).collect();
        let z: f32 = exps.iter().sum();
        prob.extend(exps.iter().map(|&x| x / z));
    }
    TopK { expert, prob }
}

/// Backward of [`top_k_select`]'s probabilities: given the upstream
/// gradient `coeff[t * k + j] = ∂L/∂prob(t, j)`, return `∂L/∂logits`
/// (`t × e`, zero outside each token's selected set).  For one token
/// with selected probabilities `p` the renormalized-softmax Jacobian
/// gives `∂L/∂l_j = p_j · (c_j − Σ_j' p_j' c_j')`.
pub fn gate_backward(sel: &TopK, coeff: &[f32], t: usize, e: usize, k: usize) -> Vec<f32> {
    assert_eq!(sel.expert.len(), t * k);
    assert_eq!(coeff.len(), t * k);
    let mut dlogits = vec![0.0f32; t * e];
    for token in 0..t {
        let lo = token * k;
        let dot: f32 = (0..k).map(|j| sel.prob[lo + j] * coeff[lo + j]).sum();
        for j in 0..k {
            dlogits[token * e + sel.expert[lo + j]] =
                sel.prob[lo + j] * (coeff[lo + j] - dot);
        }
    }
    dlogits
}

/// The capacity-bounded dispatch plan for one microbatch: which (token,
/// pick) lands in which expert slot, and how many assignments fell off
/// the end of a full expert.
#[derive(Debug, Clone, PartialEq)]
pub struct DispatchPlan {
    /// Per expert: `(token, slot, prob)` triples, slots dense from 0 in
    /// token order (the order assignments arrived).
    pub slots: Vec<Vec<(usize, usize, f32)>>,
    /// Assignments dropped because their expert was at capacity.
    pub dropped: u64,
}

/// Assign every `(token, pick)` of `sel` to an expert slot, **in token
/// order** (then pick order within a token), dropping assignments once
/// an expert's `cap` slots are full.  Deterministic and purely local to
/// the token batch, so every EP replica of the same tokens builds the
/// same plan.
pub fn plan_dispatch(sel: &TopK, t: usize, k: usize, experts: usize, cap: usize) -> DispatchPlan {
    assert_eq!(sel.expert.len(), t * k);
    let mut slots: Vec<Vec<(usize, usize, f32)>> = vec![Vec::new(); experts];
    let mut dropped = 0u64;
    for token in 0..t {
        for j in 0..k {
            let e = sel.expert[token * k + j];
            let p = sel.prob[token * k + j];
            if slots[e].len() < cap {
                let slot = slots[e].len();
                slots[e].push((token, slot, p));
            } else {
                dropped += 1;
            }
        }
    }
    DispatchPlan { slots, dropped }
}

/// The expert-parallel wire of one forward call: the EP communicator
/// (one [`Group`] per (pp, tp) row, `ep` consecutive DP ranks), this
/// rank's coordinate in it, and the base tag for its two all-to-all
/// phases (bit 0 free: 0 = dispatch, 1 = combine).
pub struct MoeA2a<'a> {
    pub group: &'a Arc<Group>,
    pub ep_rank: usize,
    /// Tag with bit 0 clear; the stage uses `tag_base` for the dispatch
    /// round and `tag_base | 1` for the combine round.
    pub tag_base: u64,
}

/// Everything a builtin MoE stage needs from the engine to run one
/// forward: the optional EP wire (None ⇒ compute all experts locally,
/// the `ep = 1` path), the activation wire dtype for the a2a payloads,
/// and the engine's dropped-assignment counter (None on recompute paths
/// and non-zero `tp_rank`s, so each drop is counted exactly once).
pub struct MoeFwdCtx<'a> {
    pub a2a: Option<MoeA2a<'a>>,
    pub wire: Dtype,
    pub dropped: Option<&'a AtomicU64>,
}

impl MoeFwdCtx<'_> {
    /// A fully local context: no EP wire, f32 payloads, no drop counter.
    /// What the backward recompute and the library tests use.
    pub const LOCAL: MoeFwdCtx<'static> =
        MoeFwdCtx { a2a: None, wire: Dtype::F32, dropped: None };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits_for(t: usize, e: usize) -> Vec<f32> {
        (0..t * e).map(|i| ((i * 37 % 19) as f32 * 0.21).sin()).collect()
    }

    #[test]
    fn capacity_formula() {
        // cf=1.25, T=16, k=2, E=4 -> ceil(10) = 10
        assert_eq!(capacity(16, 2, 4, 1.25), 10);
        // exact division, cf=1: T=16, k=1, E=4 -> 4
        assert_eq!(capacity(16, 1, 4, 1.0), 4);
        // E=1 clamps to T regardless of cf (dense-equivalence contract)
        assert_eq!(capacity(16, 1, 1, 1.25), 16);
        assert_eq!(capacity(16, 1, 1, 4.0), 16);
        // never zero
        assert_eq!(capacity(3, 1, 8, 0.5), 1);
    }

    #[test]
    fn top1_single_expert_prob_is_exactly_one() {
        let t = 5;
        let sel = top_k_select(&logits_for(t, 1), t, 1, 1);
        assert!(sel.expert.iter().all(|&e| e == 0));
        assert!(sel.prob.iter().all(|&p| p == 1.0), "exp(0)/exp(0) must be exactly 1.0");
    }

    #[test]
    fn topk_orders_by_logit_then_index() {
        // distinct logits: picks in descending-logit order
        let sel = top_k_select(&[0.1, 0.9, 0.5, 0.3], 1, 4, 3);
        assert_eq!(sel.expert, vec![1, 2, 3]);
        assert!(sel.prob[0] > sel.prob[1] && sel.prob[1] > sel.prob[2]);
        let s: f32 = sel.prob.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
    }

    #[test]
    fn ties_break_toward_lower_expert_index() {
        // all-equal logits: selection must be 0, 1, ..., k-1 with equal probs
        let e = 5;
        let sel = top_k_select(&vec![0.25f32; e], 1, e, 3);
        assert_eq!(sel.expert, vec![0, 1, 2]);
        assert!(sel.prob.iter().all(|&p| (p - 1.0 / 3.0).abs() < 1e-6));
        // a tie among a subset: equal maxima at 1 and 3 -> 1 first
        let sel = top_k_select(&[0.0, 0.7, 0.2, 0.7], 1, 4, 2);
        assert_eq!(sel.expert, vec![1, 3]);
        assert_eq!(sel.prob[0], sel.prob[1]);
    }

    #[test]
    fn gate_backward_finite_diff() {
        let (t, e, k) = (4usize, 6usize, 3usize);
        let logits = logits_for(t, e);
        // fixed coefficients standing in for dL/dprob
        let coeff: Vec<f32> = (0..t * k).map(|i| ((i + 3) as f32 * 0.31).cos()).collect();
        let loss = |l: &[f32]| -> f64 {
            let sel = top_k_select(l, t, e, k);
            sel.prob
                .iter()
                .zip(coeff.iter())
                .map(|(&p, &c)| p as f64 * c as f64)
                .sum()
        };
        let sel = top_k_select(&logits, t, e, k);
        let analytic = gate_backward(&sel, &coeff, t, e, k);
        let eps = 1e-3f32;
        for i in 0..t * e {
            let mut up = logits.clone();
            up[i] += eps;
            let mut dn = logits.clone();
            dn[i] -= eps;
            let numeric = (loss(&up) - loss(&dn)) / (2.0 * eps as f64);
            assert!(
                (analytic[i] as f64 - numeric).abs() < 2e-3,
                "dlogits[{i}]: analytic {} vs numeric {numeric}",
                analytic[i]
            );
        }
    }

    #[test]
    fn plan_fills_slots_in_token_order_and_drops_overflow() {
        // 4 tokens, k=1, all picking expert 0, cap 3 -> token 3 dropped
        let sel = TopK {
            expert: vec![0, 0, 0, 0],
            prob: vec![1.0, 1.0, 1.0, 1.0],
        };
        let plan = plan_dispatch(&sel, 4, 1, 2, 3);
        assert_eq!(plan.slots[0], vec![(0, 0, 1.0), (1, 1, 1.0), (2, 2, 1.0)]);
        assert!(plan.slots[1].is_empty());
        assert_eq!(plan.dropped, 1);
    }

    #[test]
    fn owner_blocks_are_contiguous() {
        assert_eq!(owner_of(0, 8, 4), 0);
        assert_eq!(owner_of(1, 8, 4), 0);
        assert_eq!(owner_of(2, 8, 4), 1);
        assert_eq!(owner_of(7, 8, 4), 3);
        assert_eq!(owner_of(5, 8, 1), 0);
    }
}
