//! Optimizers over flat parameter buffers.
//!
//! The L2 stage graphs exchange parameters as one contiguous f32 vector
//! per stage (DeepSpeed's flattened fp32 groups), so Adam here is a plain
//! elementwise pass over slices — which is exactly what makes ZeRO-1
//! sharding trivial: each DP rank runs `step` on its own sub-range only
//! (`zero::Zero1Partition` hands out the ranges).


/// Adam hyper-parameters (paper's runs use standard GPT settings).
#[derive(Debug, Clone, Copy)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// Global-norm gradient clipping threshold (0 disables).
    pub grad_clip: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        Self { lr: 3e-4, beta1: 0.9, beta2: 0.95, eps: 1e-8, weight_decay: 0.0, grad_clip: 1.0 }
    }
}

/// Adam/AdamW state over a flat buffer (or a ZeRO-1 shard of one).
#[derive(Debug, Clone)]
pub struct Adam {
    pub cfg: AdamConfig,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(cfg: AdamConfig, n: usize) -> Self {
        Self { cfg, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    pub fn len(&self) -> usize {
        self.m.len()
    }

    pub fn is_empty(&self) -> bool {
        self.m.is_empty()
    }

    /// Bytes of optimizer state held (for memory accounting tests).
    pub fn state_bytes(&self) -> usize {
        2 * self.m.len() * std::mem::size_of::<f32>()
    }

    /// Serialise the state as `m ++ v` plus the step counter
    /// (checkpointing; see `coordinator::checkpoint`).
    pub fn export_state(&self) -> (Vec<f32>, u64) {
        let mut out = Vec::with_capacity(2 * self.m.len());
        out.extend_from_slice(&self.m);
        out.extend_from_slice(&self.v);
        (out, self.t)
    }

    /// Restore state exported by [`Adam::export_state`].
    pub fn import_state(&mut self, data: &[f32], t: u64) {
        assert_eq!(data.len(), 2 * self.m.len(), "optimizer state size mismatch");
        let n = self.m.len();
        self.m.copy_from_slice(&data[..n]);
        self.v.copy_from_slice(&data[n..]);
        self.t = t;
    }

    /// One Adam step over `params`/`grads` (equal length to the state).
    /// `lr_scale` multiplies the base LR (for schedules).
    pub fn step(&mut self, params: &mut [f32], grads: &[f32], lr_scale: f32) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let c = self.cfg;
        let bc1 = 1.0 - c.beta1.powi(self.t as i32);
        let bc2 = 1.0 - c.beta2.powi(self.t as i32);
        let lr = c.lr * lr_scale;
        for i in 0..params.len() {
            let g = grads[i] + c.weight_decay * params[i];
            self.m[i] = c.beta1 * self.m[i] + (1.0 - c.beta1) * g;
            self.v[i] = c.beta2 * self.v[i] + (1.0 - c.beta2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= lr * mhat / (vhat.sqrt() + c.eps);
        }
    }
}

/// Global-norm gradient clipping; returns the pre-clip norm.
pub fn clip_grad_norm(grads: &mut [f32], max_norm: f32) -> f32 {
    let norm = l2_norm(grads);
    if max_norm > 0.0 && norm > max_norm {
        let scale = max_norm / (norm + 1e-6);
        for g in grads.iter_mut() {
            *g *= scale;
        }
    }
    norm
}

/// Two-pass L2 norm (hot path: see EXPERIMENTS.md §Perf).
pub fn l2_norm(xs: &[f32]) -> f32 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
}

/// Linear-warmup + cosine-decay LR schedule (GPT-3 style).
#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub warmup_steps: u64,
    pub total_steps: u64,
    pub min_ratio: f32,
}

impl LrSchedule {
    pub fn scale(&self, step: u64) -> f32 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return (step + 1) as f32 / self.warmup_steps as f32;
        }
        if step >= self.total_steps {
            return self.min_ratio;
        }
        let progress = (step - self.warmup_steps) as f32
            / (self.total_steps - self.warmup_steps).max(1) as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * progress).cos());
        self.min_ratio + (1.0 - self.min_ratio) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_minimises_quadratic() {
        // f(x) = sum (x - 3)^2: Adam must converge to 3
        let mut params = vec![0.0f32; 8];
        let mut adam = Adam::new(AdamConfig { lr: 0.1, ..Default::default() }, 8);
        for _ in 0..500 {
            let grads: Vec<f32> = params.iter().map(|&p| 2.0 * (p - 3.0)).collect();
            adam.step(&mut params, &grads, 1.0);
        }
        for p in params {
            assert!((p - 3.0).abs() < 0.05, "{p}");
        }
    }

    #[test]
    fn sharded_steps_equal_full_step() {
        // ZeRO-1 invariant: running Adam on two half-shards produces the
        // same parameters as one full-buffer Adam.
        let n = 64;
        let grads: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut full = vec![1.0f32; n];
        let mut adam_full = Adam::new(AdamConfig::default(), n);

        let mut sharded = vec![1.0f32; n];
        let mut adam_a = Adam::new(AdamConfig::default(), n / 2);
        let mut adam_b = Adam::new(AdamConfig::default(), n / 2);

        for _ in 0..10 {
            adam_full.step(&mut full, &grads, 1.0);
            adam_a.step(&mut sharded[..n / 2], &grads[..n / 2], 1.0);
            adam_b.step(&mut sharded[n / 2..], &grads[n / 2..], 1.0);
        }
        for i in 0..n {
            assert!((full[i] - sharded[i]).abs() < 1e-7);
        }
    }

    #[test]
    fn grad_clip_caps_norm() {
        let mut g = vec![3.0f32, 4.0]; // norm 5
        let pre = clip_grad_norm(&mut g, 1.0);
        assert!((pre - 5.0).abs() < 1e-5);
        assert!((l2_norm(&g) - 1.0).abs() < 1e-4);
        // under the threshold: untouched
        let mut g2 = vec![0.3f32, 0.4];
        clip_grad_norm(&mut g2, 1.0);
        assert_eq!(g2, vec![0.3, 0.4]);
    }

    #[test]
    fn lr_schedule_shape() {
        let s = LrSchedule { warmup_steps: 10, total_steps: 100, min_ratio: 0.1 };
        assert!(s.scale(0) < s.scale(9));
        assert!((s.scale(10) - 1.0).abs() < 0.01);
        assert!(s.scale(50) < 1.0 && s.scale(50) > 0.1);
        assert_eq!(s.scale(1000), 0.1);
    }

    #[test]
    fn weight_decay_pulls_to_zero() {
        let mut params = vec![5.0f32];
        let mut adam = Adam::new(
            AdamConfig { lr: 0.05, weight_decay: 0.1, ..Default::default() },
            1,
        );
        for _ in 0..300 {
            adam.step(&mut params, &[0.0], 1.0);
        }
        assert!(params[0].abs() < 0.5, "{}", params[0]);
    }
}
