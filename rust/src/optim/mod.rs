//! Optimizers over flat parameter buffers.
//!
//! The L2 stage graphs exchange parameters as one contiguous f32 vector
//! per stage (DeepSpeed's flattened fp32 groups), so Adam here is a plain
//! elementwise pass over slices — which is exactly what makes ZeRO-1
//! sharding trivial: each DP rank runs `step` on its own sub-range only
//! (`zero::Zero1Partition` hands out the ranges).
//!
//! **Mixed precision** ([`Adam::new_mixed`]): when the working parameters
//! are bf16, Adam owns the fp32 **master copy** (initialised lazily from
//! the first step's working params, persisted through checkpoints).  The
//! update runs entirely on the masters, then re-quantizes each element to
//! the working grid — so sub-quantum updates accumulate in the masters
//! instead of vanishing, the property that makes bf16 training converge
//! (tested below: `masters_escape_the_bf16_plateau`).

use crate::precision::Dtype;

/// Adam hyper-parameters (paper's runs use standard GPT settings).
#[derive(Debug, Clone, Copy)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// Global-norm gradient clipping threshold (0 disables).
    pub grad_clip: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        Self { lr: 3e-4, beta1: 0.9, beta2: 0.95, eps: 1e-8, weight_decay: 0.0, grad_clip: 1.0 }
    }
}

/// Adam/AdamW state over a flat buffer (or a ZeRO-1 shard of one).
#[derive(Debug, Clone)]
pub struct Adam {
    pub cfg: AdamConfig,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
    /// Working-parameter dtype.  `F32` steps the params in place (the
    /// legacy bitwise path); `Bf16` steps the fp32 `master` copy and
    /// re-quantizes into the working params.
    out_dtype: Dtype,
    /// fp32 master weights (mixed precision only) — lazily captured from
    /// the working params on the first step, round-tripped by
    /// [`Adam::export_state`] / [`Adam::import_state`].
    master: Option<Vec<f32>>,
}

impl Adam {
    pub fn new(cfg: AdamConfig, n: usize) -> Self {
        Self::new_mixed(cfg, n, Dtype::F32)
    }

    /// Adam with an explicit working-parameter dtype (bf16 keeps fp32
    /// masters; f32 is identical to [`Adam::new`]).
    pub fn new_mixed(cfg: AdamConfig, n: usize, out_dtype: Dtype) -> Self {
        Self { cfg, m: vec![0.0; n], v: vec![0.0; n], t: 0, out_dtype, master: None }
    }

    pub fn len(&self) -> usize {
        self.m.len()
    }

    pub fn is_empty(&self) -> bool {
        self.m.is_empty()
    }

    /// Bytes of optimizer state held (for memory accounting tests):
    /// m + v, plus the fp32 master copy under mixed precision — the
    /// paper's 4+4+4 optimizer bytes/param.
    pub fn state_bytes(&self) -> usize {
        let masters = match self.out_dtype {
            Dtype::F32 => 0,
            Dtype::Bf16 => self.m.len(),
        };
        (2 * self.m.len() + masters) * std::mem::size_of::<f32>()
    }

    /// Serialise the state as `m ++ v` (`++ master` under mixed
    /// precision) plus the step counter (checkpointing; see
    /// `coordinator::checkpoint`).
    pub fn export_state(&self) -> (Vec<f32>, u64) {
        let n = self.m.len();
        let mut out = Vec::with_capacity(2 * n + self.master.as_ref().map_or(0, Vec::len));
        out.extend_from_slice(&self.m);
        out.extend_from_slice(&self.v);
        if let Some(master) = &self.master {
            out.extend_from_slice(master);
        }
        (out, self.t)
    }

    /// Restore state exported by [`Adam::export_state`] (`2n` floats, or
    /// `3n` when the checkpoint carries fp32 masters).
    pub fn import_state(&mut self, data: &[f32], t: u64) {
        let n = self.m.len();
        assert!(
            data.len() == 2 * n || data.len() == 3 * n,
            "optimizer state size mismatch"
        );
        self.m.copy_from_slice(&data[..n]);
        self.v.copy_from_slice(&data[n..2 * n]);
        if data.len() == 3 * n {
            self.master = Some(data[2 * n..].to_vec());
        }
        self.t = t;
    }

    /// One Adam step over `params`/`grads` (equal length to the state).
    /// `lr_scale` multiplies the base LR (for schedules).  Mixed
    /// precision steps the fp32 masters and re-quantizes the working
    /// params; the fp32 path below is the original loop, untouched.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32], lr_scale: f32) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let c = self.cfg;
        let bc1 = 1.0 - c.beta1.powi(self.t as i32);
        let bc2 = 1.0 - c.beta2.powi(self.t as i32);
        let lr = c.lr * lr_scale;
        let dt = self.out_dtype;
        if dt == Dtype::F32 {
            for i in 0..params.len() {
                let g = grads[i] + c.weight_decay * params[i];
                self.m[i] = c.beta1 * self.m[i] + (1.0 - c.beta1) * g;
                self.v[i] = c.beta2 * self.v[i] + (1.0 - c.beta2) * g * g;
                let mhat = self.m[i] / bc1;
                let vhat = self.v[i] / bc2;
                params[i] -= lr * mhat / (vhat.sqrt() + c.eps);
            }
            return;
        }
        let Adam { m, v, master, .. } = self;
        if master.is_none() {
            *master = Some(params.to_vec());
        }
        let mw = master.as_mut().expect("masters just initialised");
        for i in 0..params.len() {
            // weight decay pulls on the master, not the quantized copy
            let g = grads[i] + c.weight_decay * mw[i];
            m[i] = c.beta1 * m[i] + (1.0 - c.beta1) * g;
            v[i] = c.beta2 * v[i] + (1.0 - c.beta2) * g * g;
            let mhat = m[i] / bc1;
            let vhat = v[i] / bc2;
            mw[i] -= lr * mhat / (vhat.sqrt() + c.eps);
            params[i] = dt.quantize(mw[i]);
        }
    }
}

/// Global-norm gradient clipping; returns the pre-clip norm.
pub fn clip_grad_norm(grads: &mut [f32], max_norm: f32) -> f32 {
    let norm = l2_norm(grads);
    if max_norm > 0.0 && norm > max_norm {
        let scale = max_norm / (norm + 1e-6);
        for g in grads.iter_mut() {
            *g *= scale;
        }
    }
    norm
}

/// Two-pass L2 norm (hot path: see EXPERIMENTS.md §Perf).
pub fn l2_norm(xs: &[f32]) -> f32 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
}

/// Linear-warmup + cosine-decay LR schedule (GPT-3 style).
#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub warmup_steps: u64,
    pub total_steps: u64,
    pub min_ratio: f32,
}

impl LrSchedule {
    pub fn scale(&self, step: u64) -> f32 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return (step + 1) as f32 / self.warmup_steps as f32;
        }
        if step >= self.total_steps {
            return self.min_ratio;
        }
        let progress = (step - self.warmup_steps) as f32
            / (self.total_steps - self.warmup_steps).max(1) as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * progress).cos());
        self.min_ratio + (1.0 - self.min_ratio) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_minimises_quadratic() {
        // f(x) = sum (x - 3)^2: Adam must converge to 3
        let mut params = vec![0.0f32; 8];
        let mut adam = Adam::new(AdamConfig { lr: 0.1, ..Default::default() }, 8);
        for _ in 0..500 {
            let grads: Vec<f32> = params.iter().map(|&p| 2.0 * (p - 3.0)).collect();
            adam.step(&mut params, &grads, 1.0);
        }
        for p in params {
            assert!((p - 3.0).abs() < 0.05, "{p}");
        }
    }

    #[test]
    fn sharded_steps_equal_full_step() {
        // ZeRO-1 invariant: running Adam on two half-shards produces the
        // same parameters as one full-buffer Adam.
        let n = 64;
        let grads: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut full = vec![1.0f32; n];
        let mut adam_full = Adam::new(AdamConfig::default(), n);

        let mut sharded = vec![1.0f32; n];
        let mut adam_a = Adam::new(AdamConfig::default(), n / 2);
        let mut adam_b = Adam::new(AdamConfig::default(), n / 2);

        for _ in 0..10 {
            adam_full.step(&mut full, &grads, 1.0);
            adam_a.step(&mut sharded[..n / 2], &grads[..n / 2], 1.0);
            adam_b.step(&mut sharded[n / 2..], &grads[n / 2..], 1.0);
        }
        for i in 0..n {
            assert!((full[i] - sharded[i]).abs() < 1e-7);
        }
    }

    #[test]
    fn grad_clip_caps_norm() {
        let mut g = vec![3.0f32, 4.0]; // norm 5
        let pre = clip_grad_norm(&mut g, 1.0);
        assert!((pre - 5.0).abs() < 1e-5);
        assert!((l2_norm(&g) - 1.0).abs() < 1e-4);
        // under the threshold: untouched
        let mut g2 = vec![0.3f32, 0.4];
        clip_grad_norm(&mut g2, 1.0);
        assert_eq!(g2, vec![0.3, 0.4]);
    }

    #[test]
    fn lr_schedule_shape() {
        let s = LrSchedule { warmup_steps: 10, total_steps: 100, min_ratio: 0.1 };
        assert!(s.scale(0) < s.scale(9));
        assert!((s.scale(10) - 1.0).abs() < 0.01);
        assert!(s.scale(50) < 1.0 && s.scale(50) > 0.1);
        assert_eq!(s.scale(1000), 0.1);
    }

    #[test]
    fn mixed_adam_keeps_params_on_grid_and_masters_off_it() {
        let n = 16;
        let mut params: Vec<f32> = (0..n).map(|i| (i as f32 * 0.1).sin()).collect();
        Dtype::Bf16.quantize_slice(&mut params);
        let mut adam = Adam::new_mixed(AdamConfig { lr: 1e-3, ..Default::default() }, n, Dtype::Bf16);
        for step in 0..20 {
            let grads: Vec<f32> = (0..n).map(|i| ((i + step) as f32 * 0.3).cos()).collect();
            adam.step(&mut params, &grads, 1.0);
            for (i, p) in params.iter().enumerate() {
                assert_eq!(
                    p.to_bits(),
                    Dtype::Bf16.quantize(*p).to_bits(),
                    "step {step} param {i} off the bf16 grid"
                );
            }
        }
        // state accounting now includes the fp32 masters: 12 bytes/param
        assert_eq!(adam.state_bytes(), 3 * n * 4);
    }

    #[test]
    fn masters_escape_the_bf16_plateau() {
        // THE reason masters exist: updates far below one bf16 quantum
        // must still accumulate.  A constant gradient with a tiny LR
        // moves a bf16-quantized parameter not at all without masters,
        // but the master drifts and eventually crosses a grid step.
        let mut params = vec![1.0f32]; // bf16 quantum at 1.0 is 2^-8
        let mut adam = Adam::new_mixed(
            AdamConfig { lr: 1e-4, eps: 1e-12, ..Default::default() },
            1,
            Dtype::Bf16,
        );
        let mut moved = false;
        for _ in 0..100 {
            adam.step(&mut params, &[1.0], 1.0); // steady descent ~1e-4/step
            moved |= params[0] != 1.0;
        }
        assert!(moved, "1e-4 steps must accumulate in the master and cross the 2^-8 grid");
        // and the masters round-trip through the checkpoint format
        let (state, t) = adam.export_state();
        assert_eq!(state.len(), 3);
        let mut back = Adam::new_mixed(
            AdamConfig { lr: 1e-4, eps: 1e-12, ..Default::default() },
            1,
            Dtype::Bf16,
        );
        back.import_state(&state, t);
        let mut p2 = params.clone();
        let mut p1 = params.clone();
        adam.step(&mut p1, &[1.0], 1.0);
        back.step(&mut p2, &[1.0], 1.0);
        assert_eq!(p1, p2, "restored masters must continue the exact trajectory");
    }

    #[test]
    fn weight_decay_pulls_to_zero() {
        let mut params = vec![5.0f32];
        let mut adam = Adam::new(
            AdamConfig { lr: 0.05, weight_decay: 0.1, ..Default::default() },
            1,
        );
        for _ in 0..300 {
            adam.step(&mut params, &[0.0], 1.0);
        }
        assert!(params[0].abs() < 0.5, "{}", params[0]);
    }
}
