//! Tiny CLI argument parser: `--flag`, `--key value`, `--key=value`,
//! positional subcommands.  Replaces clap for the `frontier` binary and
//! the examples.

use std::collections::BTreeMap;
use std::str::FromStr;

/// Parsed arguments: a subcommand plus `--key value` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    /// Subcommand (first positional), if any.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Typed option with default.
    pub fn opt<T: FromStr>(&self, name: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| format!("--{name} {v:?}: {e}")),
        }
    }

    /// String option with default.
    pub fn opt_str(&self, name: &str, default: &str) -> String {
        self.options
            .get(name)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --dp 2 --steps=30 --zero1 --bundle tiny-s2-mb2");
        assert_eq!(a.command(), Some("train"));
        assert_eq!(a.opt::<usize>("dp", 1).unwrap(), 2);
        assert_eq!(a.opt::<u32>("steps", 0).unwrap(), 30);
        assert!(a.flag("zero1"));
        assert!(!a.flag("gpipe"));
        assert_eq!(a.opt_str("bundle", "x"), "tiny-s2-mb2");
    }

    #[test]
    fn defaults_apply() {
        let a = parse("simulate");
        assert_eq!(a.opt::<u32>("tp", 4).unwrap(), 4);
        assert_eq!(a.opt_str("model", "175b"), "175b");
    }

    #[test]
    fn bad_value_reports_key() {
        let a = parse("x --tp banana");
        let err = a.opt::<u32>("tp", 1).unwrap_err();
        assert!(err.contains("tp"), "{err}");
    }

    #[test]
    fn trailing_flag() {
        let a = parse("hpo --evals 16 --des");
        assert!(a.flag("des"));
        assert_eq!(a.opt::<u32>("evals", 0).unwrap(), 16);
    }
}
