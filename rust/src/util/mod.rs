//! In-tree utilities: JSON parsing and CLI argument handling.
//! (The build is fully offline — see `.cargo/config.toml` — so these
//! replace serde_json and clap.)

pub mod args;
pub mod json;
