//! Minimal JSON parser — enough for the artifact `meta.json` files and the
//! experiment logs this crate reads/writes.  No external dependencies by
//! design (the build is fully offline; see `.cargo/config.toml`).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors ----

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that reports the missing key.
    pub fn field(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError(format!("missing field {key:?}")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    // ---- convenience: field + coercion with error context ----

    pub fn u64_field(&self, key: &str) -> Result<u64, JsonError> {
        self.field(key)?
            .as_u64()
            .ok_or_else(|| JsonError(format!("field {key:?} is not a u64")))
    }

    pub fn f64_field(&self, key: &str) -> Result<f64, JsonError> {
        self.field(key)?
            .as_f64()
            .ok_or_else(|| JsonError(format!("field {key:?} is not a number")))
    }

    pub fn bool_field(&self, key: &str) -> Result<bool, JsonError> {
        self.field(key)?
            .as_bool()
            .ok_or_else(|| JsonError(format!("field {key:?} is not a bool")))
    }

    pub fn str_field(&self, key: &str) -> Result<String, JsonError> {
        Ok(self
            .field(key)?
            .as_str()
            .ok_or_else(|| JsonError(format!("field {key:?} is not a string")))?
            .to_string())
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len()
                        && (self.bytes[self.pos] & 0xC0) == 0x80
                    {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

/// Escape a string for JSON output.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_meta_json_shape() {
        let src = r#"{
            "model": {"name": "tiny", "hidden": 64, "total_params": 134912},
            "n_stages": 2, "mbs": 2, "use_flash": true,
            "flops_per_microbatch": 5.53e7,
            "stages": [{"index": 0, "has_embed": true},
                       {"index": 1, "has_embed": false}]
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.field("model").unwrap().str_field("name").unwrap(), "tiny");
        assert_eq!(j.u64_field("n_stages").unwrap(), 2);
        assert!(j.bool_field("use_flash").unwrap());
        assert!((j.f64_field("flops_per_microbatch").unwrap() - 5.53e7).abs() < 1.0);
        let stages = j.field("stages").unwrap().as_arr().unwrap();
        assert_eq!(stages.len(), 2);
        assert!(stages[0].bool_field("has_embed").unwrap());
    }

    #[test]
    fn scalars_and_arrays() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("[1, 2, 3]").unwrap().as_arr().unwrap().len(),
            3
        );
        assert_eq!(Json::parse("\"a\\nb\"").unwrap().as_str().unwrap(), "a\nb");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse("\"\\u0041µ\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "Aµ");
        assert_eq!(escape("a\"b\n"), "\"a\\\"b\\n\"");
    }

    #[test]
    fn nested_objects() {
        let j = Json::parse(r#"{"a": {"b": {"c": [true]}}}"#).unwrap();
        let c = j.field("a").unwrap().field("b").unwrap().field("c").unwrap();
        assert_eq!(c.as_arr().unwrap()[0], Json::Bool(true));
    }
}
