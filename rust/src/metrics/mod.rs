//! Training metrics: step timing, token/FLOP throughput, scaling
//! efficiency, and a small CSV logger the examples/benches share.

use std::time::Instant;

use crate::topology::PEAK_FP16_FLOPS;

/// Rolling statistics over recent step times.
#[derive(Debug, Default, Clone)]
pub struct StepTimer {
    samples: Vec<f64>,
}

impl StepTimer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, seconds: f64) {
        self.samples.push(seconds);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Mean over the samples after dropping the warmup prefix.
    pub fn mean_after_warmup(&self, warmup: usize) -> f64 {
        let rest = &self.samples[warmup.min(self.samples.len())..];
        if rest.is_empty() {
            return f64::NAN;
        }
        rest.iter().sum::<f64>() / rest.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }

    fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() - 1) as f64 * q).round() as usize;
        s[idx]
    }
}

/// Scoped wall-clock timer.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(Instant::now())
    }

    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Throughput summary for one measured configuration.
#[derive(Debug, Clone)]
pub struct Throughput {
    pub tokens_per_sec: f64,
    pub samples_per_sec: f64,
    pub tflops_per_gpu: f64,
    pub pct_peak: f64,
}

/// Compute the paper's headline metrics from measured step time.
pub fn throughput(
    step_time: f64,
    gbs: u64,
    seq: u64,
    hw_flops_per_gpu_step: f64,
) -> Throughput {
    let tokens = (gbs * seq) as f64;
    let tflops = hw_flops_per_gpu_step / step_time / 1e12;
    Throughput {
        tokens_per_sec: tokens / step_time,
        samples_per_sec: gbs as f64 / step_time,
        tflops_per_gpu: tflops,
        pct_peak: 100.0 * tflops * 1e12 / PEAK_FP16_FLOPS,
    }
}

/// Scaling efficiency (Figs 12/13): `base` = (gpus, samples/s) reference
/// point, `point` = scaled measurement.
pub fn weak_scaling_efficiency(base: (u32, f64), point: (u32, f64)) -> f64 {
    // ideal weak scaling: samples/s grows linearly with GPUs
    let ideal = base.1 * point.0 as f64 / base.0 as f64;
    100.0 * point.1 / ideal
}

pub fn strong_scaling_efficiency(base: (u32, f64), point: (u32, f64)) -> f64 {
    // identical formula: ideal speedup is linear in GPUs; kept separate so
    // call sites read like the paper's figures
    weak_scaling_efficiency(base, point)
}

/// Minimal CSV writer (examples/benches log loss curves + sweeps with it).
#[derive(Debug, Default)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(columns: &[&str]) -> Self {
        Self { header: columns.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, values: &[String]) {
        assert_eq!(values.len(), self.header.len(), "csv row arity");
        self.rows.push(values.to_vec());
    }

    pub fn rowf(&mut self, values: &[f64]) {
        self.row(&values.iter().map(|v| format!("{v}")).collect::<Vec<_>>());
    }

    pub fn write(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_string())
    }
}

/// RFC-4180 field quoting: fields containing a comma, a double quote or
/// a newline are wrapped in quotes with embedded quotes doubled; all
/// other fields (every numeric row) pass through byte-identical.
fn escape_field(s: &str) -> std::borrow::Cow<'_, str> {
    if s.contains([',', '"', '\n', '\r']) {
        std::borrow::Cow::Owned(format!("\"{}\"", s.replace('"', "\"\"")))
    } else {
        std::borrow::Cow::Borrowed(s)
    }
}

impl std::fmt::Display for Csv {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let line = |f: &mut std::fmt::Formatter<'_>, fields: &[String]| {
            for (i, field) in fields.iter().enumerate() {
                if i > 0 {
                    f.write_str(",")?;
                }
                f.write_str(&escape_field(field))?;
            }
            f.write_str("\n")
        };
        line(f, &self.header)?;
        for r in &self.rows {
            line(f, r)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_statistics() {
        let mut t = StepTimer::new();
        for v in [10.0, 1.0, 2.0, 3.0] {
            t.record(v);
        }
        assert_eq!(t.count(), 4);
        assert!((t.mean_after_warmup(1) - 2.0).abs() < 1e-9);
        assert_eq!(t.min(), 1.0);
        assert_eq!(t.p99(), 10.0);
    }

    #[test]
    fn throughput_math() {
        // 1 TFLOP of work per GPU in 0.1 s = 10 TFLOPS
        let t = throughput(0.1, 16, 128, 1e12);
        assert!((t.tflops_per_gpu - 10.0).abs() < 1e-9);
        assert!((t.tokens_per_sec - 20480.0).abs() < 1e-6);
        assert!((t.pct_peak - 100.0 * 10e12 / PEAK_FP16_FLOPS).abs() < 1e-9);
    }

    #[test]
    fn scaling_efficiency() {
        // doubling GPUs and doubling samples/s = 100%
        assert!((weak_scaling_efficiency((1024, 10.0), (2048, 20.0)) - 100.0).abs() < 1e-9);
        // doubling GPUs with 1.8x samples/s = 90%
        assert!((strong_scaling_efficiency((512, 10.0), (1024, 18.0)) - 90.0).abs() < 1e-9);
    }

    #[test]
    fn csv_round_trip() {
        let mut c = Csv::new(&["a", "b"]);
        c.rowf(&[1.0, 2.5]);
        let s = c.to_string();
        assert!(s.starts_with("a,b\n"));
        assert!(s.contains("1,2.5"));
    }

    #[test]
    fn csv_escapes_delimiters_and_quotes() {
        let mut c = Csv::new(&["name", "note"]);
        c.row(&["tp=2,dp=2".to_string(), "said \"go\"".to_string()]);
        c.row(&["multi\nline".to_string(), "plain".to_string()]);
        let s = c.to_string();
        let mut lines = s.split('\n');
        assert_eq!(lines.next(), Some("name,note"));
        // comma-bearing and quote-bearing fields are quoted, quotes doubled
        assert_eq!(lines.next(), Some("\"tp=2,dp=2\",\"said \"\"go\"\"\""));
        // the embedded newline stays inside one quoted field
        assert_eq!(lines.next(), Some("\"multi"));
        assert_eq!(lines.next(), Some("line\",plain"));
    }
}
