//! Per-GPU memory-footprint model (paper Table II + §II.A).
//!
//! Mixed-precision Adam accounting, as the paper counts it:
//!   * parameters: 6 bytes/param (fp32 master + fp16 working copy)
//!   * gradients:  4 bytes/param (fp32)
//!   * optimizer:  4 bytes/param (fp32 momentum; the paper's Table II
//!     counts 4 — we keep their accounting for the Table II repro and
//!     expose `adam_full` for the 8-byte m+v variant)
//!
//! Model parallelism divides the 14x by `tp * pp`; the ZeRO sharding
//! stage further divides per-parameter state by `dp` (§II.D), one state
//! class per stage: optimizer-owned bytes (master params + optimizer
//! states) under stages 1+, gradients under stages 2+, and the working
//! parameters themselves under stage 3 — which then also charges the
//! transient gather buffer of the engine's gather-use-drop lifecycle
//! (`(zero3_prefetch + 1)` layers' full parameters: current + the
//! prefetch window; validated against the engine-measured
//! `zero3_peak_gathered_floats` high-water mark).  Activation memory follows the checkpointing model: one stored
//! layer input per layer per in-flight micro-batch plus one layer's live
//! working set — multiplied by the schedule's peak in-flight count, which
//! is why GPipe at large `m` OOMs where 1F1B survives.
//!
//! This model is what rejects configurations during HPO: the red-arrow
//! failures of Fig 9 are exactly `fits() == false` here.
//!
//! Two selectable per-parameter layouts ([`Accounting`]): the paper's
//! Table II 14 bytes/param (the calibrated default above), and the
//! executed bf16 subsystem's **16 bytes/param** — 2 (bf16 params) +
//! 2 (bf16 grads) + 12 (fp32 master + Adam m + Adam v, all ZeRO-1
//! shardable) — the ZeRO-paper accounting `--precision bf16` realises.

use crate::config::{ModelSpec, ParallelConfig};
use crate::schedule;
use crate::topology::HBM_BYTES;

/// Fixed per-GPU overhead: HIP/ROCm runtime, RCCL buffers, framework
/// workspace, fragmentation.  (~2 GB observed in practice.)
pub const FRAMEWORK_OVERHEAD: u64 = 2 * (1 << 30);

/// Byte-per-parameter multipliers of Table II.
pub const BYTES_PARAMS: u64 = 6;
pub const BYTES_GRADS: u64 = 4;
pub const BYTES_OPTIMIZER: u64 = 4;

/// Byte-per-parameter multipliers of the bf16 mixed-precision subsystem
/// (the ZeRO-paper 16-bytes/param layout the engine now executes):
/// 2-byte working params + 2-byte grads + fp32 optimizer trio
/// (4 master + 4 momentum + 4 variance).
pub const MIXED_BYTES_PARAMS: u64 = 2;
pub const MIXED_BYTES_GRADS: u64 = 2;
pub const MIXED_BYTES_MASTER: u64 = 4;
pub const MIXED_BYTES_ADAM_M: u64 = 4;
pub const MIXED_BYTES_ADAM_V: u64 = 4;
/// Optimizer-owned bytes/param under mixed precision (master + m + v) —
/// what ZeRO-1 shards across the DP group.
pub const MIXED_BYTES_OPTIMIZER: u64 =
    MIXED_BYTES_MASTER + MIXED_BYTES_ADAM_M + MIXED_BYTES_ADAM_V;

/// Which per-parameter byte layout the footprint model charges.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Accounting {
    /// Paper Table II: 6 (fp32 master + fp16 working) + 4 (fp32 grads)
    /// + 4 (fp32 momentum) = 14 bytes/param — the calibrated default
    /// every Fig 9/11 number was fitted with.
    #[default]
    Table2,
    /// The executed bf16 subsystem: 2 + 2 + (4+4+4) = 16 bytes/param,
    /// with ZeRO-1 sharding the whole 12-byte optimizer trio by `dp`
    /// (master weights live in the optimizer shard).
    Mixed16,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryBreakdown {
    pub params: u64,
    pub grads: u64,
    pub optimizer: u64,
    pub activations: u64,
    pub overhead: u64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> u64 {
        self.params + self.grads + self.optimizer + self.activations + self.overhead
    }

    pub fn gib(&self) -> f64 {
        self.total() as f64 / (1u64 << 30) as f64
    }
}

/// Whole-model memory requirement in bytes, paper Table II accounting
/// (no activations, no overhead).  `nominal_params` lets callers pass the
/// round numbers the paper uses (22e9, 175e9, 1e12).
pub fn table2_row(nominal_params: u64) -> (u64, u64, u64, u64) {
    let p = nominal_params;
    let params = BYTES_PARAMS * p;
    let grads = BYTES_GRADS * p;
    let opt = BYTES_OPTIMIZER * p;
    (params, grads, opt, params + grads + opt)
}

/// Whole-model requirement under the executed bf16 mixed-precision
/// layout: `(params, grads, optimizer, total)` = `(2, 2, 12, 16) × p`.
pub fn mixed16_row(nominal_params: u64) -> (u64, u64, u64, u64) {
    let p = nominal_params;
    let params = MIXED_BYTES_PARAMS * p;
    let grads = MIXED_BYTES_GRADS * p;
    let opt = MIXED_BYTES_OPTIMIZER * p;
    (params, grads, opt, params + grads + opt)
}

/// Stored activation bytes for ONE micro-batch on the largest stage
/// (layer inputs only — full activation checkpointing).
fn stored_activation_per_mb(model: &ModelSpec, cfg: &ParallelConfig, layers: u32) -> u64 {
    let b = cfg.mbs as u64;
    let s = model.seq;
    let d = model.hidden;
    let prec = cfg.precision.bytes();
    // layer input per layer, sharded over TP by Megatron's sequence-split
    b * s * d * prec * layers as u64 / cfg.tp as u64
}

/// Live working set while (re)computing one layer.
/// Without flash attention the (heads x seq x seq) score matrix
/// materialises; with it only O(s·d) tiles are live.  (Korthikanti et al.'s
/// per-layer activation formula, simplified: `sbd(34 + 5·a·s²/(s·d))`.)
fn layer_working_set(model: &ModelSpec, cfg: &ParallelConfig) -> u64 {
    let b = cfg.mbs as u64;
    let s = model.seq;
    let d = model.hidden;
    let a = model.n_heads as u64;
    let prec = cfg.precision.bytes();
    let dense = 34 * b * s * d * prec / 2; // the "34sbh" term (fp16-normalised)
    let attn_matrix = if cfg.flash_attention {
        0
    } else {
        // QK^T scores + softmax output, per head
        2 * b * a * s * s * prec
    };
    (dense + attn_matrix) / cfg.tp as u64
}

/// Per-GPU memory of the worst (first) pipeline stage, Table II
/// accounting (the calibrated default).
pub fn per_gpu(model: &ModelSpec, cfg: &ParallelConfig) -> MemoryBreakdown {
    per_gpu_acct(model, cfg, Accounting::Table2)
}

/// Per-GPU memory under a selectable byte layout (see [`Accounting`]):
/// the Table II 14×/param accounting, or the executed bf16 subsystem's
/// 16×/param layout with its whole 12-byte optimizer trio (incl. fp32
/// masters) ZeRO-sharded.
pub fn per_gpu_acct(model: &ModelSpec, cfg: &ParallelConfig, acct: Accounting) -> MemoryBreakdown {
    let n_total = model.total_params();
    // first stage carries the embedding and ceil(L/pp) layers
    let spans = model.stage_spans(cfg.pp.min(model.n_layers));
    let stage0_layers = spans[0].1 - spans[0].0;
    let n_stage =
        (model.embed_params() + stage0_layers as u64 * model.layer_params()) / cfg.tp as u64;
    // cross-check against the uniform share; take the max (worst stage may
    // be the last one when the head is large)
    let last_layers = spans.last().unwrap().1 - spans.last().unwrap().0;
    let n_last =
        (model.head_params() + last_layers as u64 * model.layer_params()) / cfg.tp as u64;
    let mut n_local = n_stage.max(n_last).max(n_total / (cfg.tp as u64 * cfg.pp as u64));
    if cfg.experts > 1 {
        // MoE: (E−1) extra FFN copies per hosted layer (TP-sharded like
        // the dense FFN) plus the TP-replicated d×E gate.  Expert params
        // are DP-replicated, so they ride the same ZeRO shard arithmetic
        // as the dense ones below.
        let ffn = 8 * model.hidden * model.hidden;
        n_local += stage0_layers as u64
            * ((cfg.experts as u64 - 1) * ffn / cfg.tp as u64
                + model.hidden * cfg.experts as u64);
    }

    // per-stage `1/dp` sharding of one state class (no-op at dp = 1,
    // where a rank's partition is the whole buffer)
    let stage = cfg.zero_stage;
    let shard = |bytes: u64, sharded: bool| {
        if sharded && cfg.dp > 1 {
            bytes / cfg.dp as u64
        } else {
            bytes
        }
    };
    // ZeRO-3 gather-use-drop transient: `(zero3_prefetch + 1)` layers'
    // full (working-width) parameters live at once — current + the
    // prefetch window (two layers at the default depth of 1)
    let gather = if stage.shards_params() && cfg.dp > 1 {
        zero3_gather_transient_bytes(model, cfg)
    } else {
        0
    };
    let (params, grads, optimizer) = match acct {
        Accounting::Table2 => {
            let master = 4 * n_local; // fp32 master copy lives in the optimizer shard
            let working = BYTES_PARAMS * n_local - master; // fp16 working weights
            let params = shard(working, stage.shards_params())
                + shard(master, stage.shards_optimizer())
                + gather;
            let grads = shard(BYTES_GRADS * n_local, stage.shards_grads());
            let optimizer = shard(BYTES_OPTIMIZER * n_local, stage.shards_optimizer());
            (params, grads, optimizer)
        }
        Accounting::Mixed16 => {
            let params = shard(MIXED_BYTES_PARAMS * n_local, stage.shards_params()) + gather;
            let grads = shard(MIXED_BYTES_GRADS * n_local, stage.shards_grads());
            let optimizer = shard(MIXED_BYTES_OPTIMIZER * n_local, stage.shards_optimizer());
            (params, grads, optimizer)
        }
    };

    // activations: peak in-flight *chunk* inputs on rank 0.  With
    // interleaving the schedule counts per-chunk activations (a rank
    // hosts v chunks of ceil(L / (pp v)) layers each), so the per-unit
    // stored size shrinks by ~1/v while the in-flight count grows to
    // 2(p-1) + (v-1)p + 1 — the net (v+1)/v residency overhead of
    // interleaved 1F1B.
    let m = cfg.microbatches();
    // an unaligned interleave factor (m % pp != 0) is rejected by
    // `ParallelConfig::validate` at every evaluation entry point; for a
    // direct footprint query fall back to the v = 1 residency rather
    // than panicking in the stream generator
    let kind = match cfg.schedule {
        crate::config::ScheduleKind::Interleaved1F1B { v } if v > 1 && m % cfg.pp != 0 => {
            crate::config::ScheduleKind::OneF1B
        }
        k => k,
    };
    let sched = schedule::build(kind, cfg.pp, m);
    let n_chunks = (cfg.pp * sched.v).min(model.n_layers);
    let chunk0_layers = {
        let spans = model.stage_spans(n_chunks);
        spans[0].1 - spans[0].0
    };
    let inflight = sched.peak_inflight(0) as u64;
    let stored = if cfg.checkpoint_activations {
        stored_activation_per_mb(model, cfg, chunk0_layers)
    } else {
        // no checkpointing: the full working set of every layer is stored
        layer_working_set(model, cfg) * chunk0_layers as u64
    };
    let activations =
        inflight * stored + layer_working_set(model, cfg) + moe_transient_bytes(model, cfg);

    MemoryBreakdown { params, grads, optimizer, activations, overhead: FRAMEWORK_OVERHEAD }
}

/// Transient buffer bytes of one MoE block's capacity-padded routing:
/// every expert's input and output buffer is materialised to capacity
/// (`E × cap × d` each, at working precision) around the dispatch/
/// combine exchange — the same buffers whether the exchange is local
/// (ep = 1) or an `all_to_all` (the wire moves them, it does not add
/// residency).  Zero for dense models.
pub fn moe_transient_bytes(model: &ModelSpec, cfg: &ParallelConfig) -> u64 {
    if cfg.experts <= 1 {
        return 0;
    }
    let tokens = (cfg.mbs as u64 * model.seq) as usize;
    let cap = crate::moe::capacity(
        tokens,
        cfg.moe_topk as usize,
        cfg.experts as usize,
        cfg.capacity_factor,
    ) as u64;
    2 * cfg.experts as u64 * cap * model.hidden * cfg.precision.bytes()
}

/// Does the configuration fit in MI250X HBM?  (Fig 9's OOM failures.)
pub fn fits(model: &ModelSpec, cfg: &ParallelConfig) -> bool {
    per_gpu(model, cfg).total() <= HBM_BYTES
}

/// Working-parameter bytes/param of both accountings (fp16/bf16 working
/// copy — Table II's 6x splits as 4 master + 2 working).
const WORKING_PARAM_BYTES: u64 = 2;

/// Transient full-parameter residency of ZeRO-3's gather-use-drop
/// lifecycle: at most `(zero3_prefetch + 1)` layers' gathered
/// working-width parameters are live at once — the layer in use plus up
/// to `N` prefetched gathers in flight — the bound the engine's measured
/// `zero3_peak_gathered_floats` high-water mark validates (its per-chunk
/// granularity is this model's per-layer granularity).  The default
/// prefetch depth of 1 reproduces the historical two-layer bound.
pub fn zero3_gather_transient_bytes(model: &ModelSpec, cfg: &ParallelConfig) -> u64 {
    (cfg.zero3_prefetch as u64 + 1) * (model.layer_params() / cfg.tp as u64) * WORKING_PARAM_BYTES
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{lookup, ScheduleKind};

    #[test]
    fn table2_matches_paper() {
        let gb = |b: u64| b as f64 / 1e9;
        let (p, g, o, t) = table2_row(22_000_000_000);
        assert_eq!(gb(p).round() as i64, 132);
        assert_eq!(gb(g).round() as i64, 88);
        assert_eq!(gb(o).round() as i64, 88);
        assert_eq!(gb(t).round() as i64, 308);
        let (_, _, _, t175) = table2_row(175_000_000_000);
        assert!((gb(t175) - 2450.0).abs() < 1.0); // 2.45 TB
        let (_, _, _, t1t) = table2_row(1_000_000_000_000);
        assert!((gb(t1t) - 14_000.0).abs() < 1.0); // 14 TB
    }

    #[test]
    fn mixed16_row_matches_the_paper_arithmetic() {
        let gb = |b: u64| b as f64 / 1e9;
        let (p, g, o, t) = mixed16_row(22_000_000_000);
        assert_eq!(gb(p).round() as i64, 44); // 2 bytes/param
        assert_eq!(gb(g).round() as i64, 44); // 2 bytes/param
        assert_eq!(gb(o).round() as i64, 264); // 4 + 4 + 4 bytes/param
        assert_eq!(gb(t).round() as i64, 352); // 16 bytes/param
        let (_, _, _, t1t) = mixed16_row(1_000_000_000_000);
        assert!((gb(t1t) - 16_000.0).abs() < 1.0); // 16 TB
        assert_eq!(MIXED_BYTES_PARAMS + MIXED_BYTES_GRADS + MIXED_BYTES_OPTIMIZER, 16);
    }

    #[test]
    fn mixed16_per_gpu_selectable_and_zero1_shards_the_masters() {
        let m = lookup("175b").unwrap();
        let base = ParallelConfig::default().with_tp(8).with_pp(8).with_dp(16).with_gbs(64);
        let t2 = per_gpu_acct(&m, &base, Accounting::Table2);
        assert_eq!(t2, per_gpu(&m, &base), "Table2 must stay the default, bit for bit");
        let mx = per_gpu_acct(&m, &base, Accounting::Mixed16);
        // without ZeRO: 16x > 14x on the parameter-proportional terms
        assert!(mx.params + mx.grads + mx.optimizer > t2.params + t2.grads + t2.optimizer);
        assert_eq!(mx.activations, t2.activations, "activations are layout-independent");
        // with ZeRO-1 at large dp, Mixed16 wins: only 4 unsharded
        // bytes/param (2 + 2) vs Table II's 6 (2 working + 4 fp32 grads)
        let z = base.clone().with_zero1(true);
        let t2z = per_gpu_acct(&m, &z, Accounting::Table2);
        let mxz = per_gpu_acct(&m, &z, Accounting::Mixed16);
        assert!(
            mxz.params + mxz.grads + mxz.optimizer < t2z.params + t2z.grads + t2z.optimizer,
            "ZeRO-1 must shard the whole 12-byte optimizer trio under Mixed16"
        );
        assert!(mxz.optimizer < mx.optimizer);
    }

    #[test]
    fn single_gpu_cannot_hold_22b() {
        // §II.A: model parallelism is necessary even for one replica
        let m = lookup("22b").unwrap();
        let cfg = ParallelConfig::default().with_gbs(1);
        assert!(!fits(&m, &cfg));
    }

    #[test]
    fn table5_recipes_fit() {
        for (r, _, _) in crate::config::fig11_recipes() {
            assert!(fits(&r.model, &r.parallel), "{} must fit", r.model.name);
        }
    }

    #[test]
    fn zero1_reduces_footprint() {
        let m = lookup("175b").unwrap();
        let base = ParallelConfig::default()
            .with_tp(8)
            .with_pp(8)
            .with_dp(8)
            .with_gbs(64);
        let with = per_gpu(&m, &base.clone().with_zero1(true)).total();
        let without = per_gpu(&m, &base).total();
        assert!(with < without);
    }

    #[test]
    fn stage_ladder_monotonically_shrinks_state() {
        use crate::zero::ShardingStage;
        // each rung shards one more state class: strictly smaller
        // parameter-proportional footprint at every step up the ladder,
        // under both accountings
        let m = lookup("175b").unwrap();
        let base = ParallelConfig::default().with_tp(8).with_pp(8).with_dp(16).with_gbs(64);
        for acct in [Accounting::Table2, Accounting::Mixed16] {
            let mut last = u64::MAX;
            for i in 0..4u32 {
                let cfg = base.clone().with_zero_stage(ShardingStage::from_index(i).unwrap());
                let b = per_gpu_acct(&m, &cfg, acct);
                let state = b.params + b.grads + b.optimizer;
                assert!(state < last, "{acct:?} stage {i}: {state} !< {last}");
                last = state;
            }
        }
    }

    #[test]
    fn mixed16_stage3_approaches_16_over_d_plus_gather() {
        use crate::zero::ShardingStage;
        // the ZeRO-paper budget: at stage 3 every one of the 16
        // bytes/param is /d; what remains beyond that is exactly the
        // two-layer gather transient
        let m = lookup("175b").unwrap();
        let dp = 16;
        let cfg = ParallelConfig::default()
            .with_tp(8)
            .with_pp(8)
            .with_dp(dp)
            .with_gbs(64)
            .with_zero_stage(ShardingStage::Parameters);
        let b = per_gpu_acct(&m, &cfg, Accounting::Mixed16);
        let full = per_gpu_acct(
            &m,
            &cfg.clone().with_zero_stage(ShardingStage::Ddp),
            Accounting::Mixed16,
        );
        let gather = zero3_gather_transient_bytes(&m, &cfg);
        assert_eq!(b.params, full.params / dp as u64 + gather, "2/d params + 2-layer gather");
        assert_eq!(b.grads, full.grads / dp as u64, "2/d grads");
        assert_eq!(b.optimizer, full.optimizer / dp as u64, "12/d optimizer trio");
        // stage 2 shards grads but keeps working params replicated
        let s2 = per_gpu_acct(
            &m,
            &cfg.clone().with_zero_stage(ShardingStage::Gradients),
            Accounting::Mixed16,
        );
        assert_eq!(s2.grads, full.grads / dp as u64);
        assert_eq!(s2.params, full.params);
        assert_eq!(s2.optimizer, full.optimizer / dp as u64);
    }

    #[test]
    fn zero3_prefetch_scales_the_gather_transient() {
        use crate::zero::ShardingStage;
        let m = lookup("175b").unwrap();
        let cfg = ParallelConfig::default()
            .with_tp(8)
            .with_pp(8)
            .with_dp(16)
            .with_gbs(64)
            .with_zero_stage(ShardingStage::Parameters);
        // the default depth of 1 reproduces the historical 2-layer bound
        let one_layer = (m.layer_params() / 8) * WORKING_PARAM_BYTES;
        assert_eq!(zero3_gather_transient_bytes(&m, &cfg), 2 * one_layer);
        // (N + 1)-chunk residency: linear in the prefetch window
        for n in [0u32, 2, 3, 7] {
            let deep = cfg.clone().with_zero3_prefetch(n);
            assert_eq!(
                zero3_gather_transient_bytes(&m, &deep),
                (n as u64 + 1) * one_layer
            );
        }
        // the per-GPU footprint charges exactly that transient
        let b1 = per_gpu_acct(&m, &cfg, Accounting::Mixed16);
        let b3 = per_gpu_acct(&m, &cfg.clone().with_zero3_prefetch(3), Accounting::Mixed16);
        assert_eq!(b3.params - b1.params, 2 * one_layer);
    }

    #[test]
    fn moe_charges_expert_params_and_routing_buffers() {
        let m = lookup("22b").unwrap();
        let base = ParallelConfig::default().with_tp(2).with_pp(4).with_gbs(32);
        let dense = per_gpu(&m, &base);
        // the E = 1 top-1 identity point is bitwise the dense footprint
        assert_eq!(per_gpu(&m, &base.clone().with_moe(1, 1)), dense);
        assert_eq!(moe_transient_bytes(&m, &base), 0);
        let moe_cfg = base.clone().with_moe(8, 2);
        let moe = per_gpu(&m, &moe_cfg);
        // 7 extra FFN copies per layer dominate the parameter budget
        assert!(moe.params > 5 * dense.params, "{} !> 5×{}", moe.params, dense.params);
        assert!(moe.grads > dense.grads);
        assert!(moe.optimizer > dense.optimizer);
        // the capacity-padded routing buffers land in the activation term
        let t = moe_transient_bytes(&m, &moe_cfg);
        assert!(t > 0);
        assert_eq!(moe.activations, dense.activations + t);
        // transient = 2 · E · cap · d · prec at the working precision
        let tokens = (moe_cfg.mbs as u64 * m.seq) as usize;
        let cap = crate::moe::capacity(tokens, 2, 8, moe_cfg.capacity_factor) as u64;
        assert_eq!(t, 2 * 8 * cap * m.hidden * moe_cfg.precision.bytes());
        // ZeRO still shards the widened state: stage 1 shrinks the total
        let z = per_gpu(&m, &moe_cfg.clone().with_dp(4).with_gbs(32).with_zero1(true));
        assert!(z.optimizer < moe.optimizer);
    }

    #[test]
    fn gpipe_activation_wall() {
        // Obs: 1F1B's in-flight cap keeps activations bounded as m grows;
        // GPipe's grow linearly.
        let m = lookup("22b").unwrap();
        let f1b = ParallelConfig::default()
            .with_tp(2)
            .with_pp(8)
            .with_gbs(256)
            .with_mbs(1);
        let gp = f1b.clone().with_schedule(ScheduleKind::GPipe);
        let a_f1b = per_gpu(&m, &f1b).activations;
        let a_gp = per_gpu(&m, &gp).activations;
        assert!(a_gp > 10 * a_f1b, "gpipe {a_gp} vs 1f1b {a_f1b}");
    }

    #[test]
    fn interleaving_costs_bounded_activation_overhead() {
        // interleaved residency: (v+1)/v overhead over plain 1F1B —
        // strictly more than plain, strictly less than double
        let m = lookup("22b").unwrap();
        let base = ParallelConfig::default().with_tp(2).with_pp(8).with_gbs(32);
        let plain = per_gpu(&m, &base).activations;
        let inter = per_gpu(&m, &base.clone().with_interleave(2)).activations;
        assert!(inter > plain, "interleaved {inter} !> plain {plain}");
        assert!(inter < 2 * plain, "interleaved {inter} !< 2x plain {plain}");
    }

    #[test]
    fn bigger_mbs_more_activations() {
        let m = lookup("175b").unwrap();
        let base = ParallelConfig::default().with_tp(4).with_pp(16).with_gbs(640);
        let a1 = per_gpu(&m, &base.clone().with_mbs(1)).activations;
        let a4 = per_gpu(&m, &base.clone().with_mbs(4)).activations;
        assert!(a4 > 3 * a1);
    }

    #[test]
    fn flash_attention_trims_working_set() {
        let m = lookup("22b").unwrap();
        let cfg = ParallelConfig::default().with_tp(2).with_pp(8).with_gbs(64);
        let with = per_gpu(&m, &cfg).activations;
        let without = per_gpu(&m, &cfg.clone().with_flash(false)).activations;
        assert!(without > with);
    }
}
