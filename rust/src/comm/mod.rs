//! Analytic collective-communication cost model (α–β) on the Frontier
//! topology.
//!
//! Every communication term of the paper's analysis is priced here:
//! the per-layer TP all-reduces (§III.A), the PP activation sends, and the
//! per-step DP gradient reduction (plain all-reduce, or ZeRO-1's
//! reduce-scatter + all-gather pair, §II.D).
//!
//! Algorithm selection follows RCCL practice and the paper's observation
//! (§II.E) that "tensor parallel training across multiple nodes requires
//! slow tree-like allreduce": node-local groups use ring collectives on
//! the Infinity Fabric; groups spanning nodes use a hierarchical scheme
//! (node-local ring + inter-node ring over node leaders).

use crate::topology::{GpuId, LinkKind, Machine, GPUS_PER_NODE};

/// Which collective algorithm a cost was computed with (for reporting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    Ring,
    Tree,
    Hierarchical,
}

/// The α–β cost model bound to a machine.
#[derive(Debug, Clone)]
pub struct CommModel {
    pub machine: Machine,
    /// Fixed software overhead per collective call (RCCL launch, ~µs).
    pub launch_overhead: f64,
    /// Fraction of the analytic ring bound RCCL sustains in practice
    /// (protocol overhead, chunking, bidirectional contention).
    pub ring_efficiency: f64,
}

impl CommModel {
    pub fn new(machine: Machine) -> Self {
        Self { machine, launch_overhead: 5.0e-6, ring_efficiency: 0.55 }
    }

    /// Point-to-point transfer time.
    pub fn p2p(&self, from: GpuId, to: GpuId, bytes: u64) -> f64 {
        let link = self.machine.link(from, to);
        if link == LinkKind::Local {
            return 0.0;
        }
        link.latency() + bytes as f64 / link.bandwidth()
    }

    /// Ring all-reduce: `2(n-1)/n` traversals of the slowest ring link.
    pub fn ring_allreduce(&self, group: &[GpuId], bytes: u64) -> f64 {
        let n = group.len() as f64;
        if n <= 1.0 {
            return 0.0;
        }
        let link = self.machine.ring_bottleneck(group);
        let steps = 2.0 * (n - 1.0);
        self.launch_overhead
            + steps * link.latency()
            + (2.0 * (n - 1.0) / n) * bytes as f64
                / (link.bandwidth() * self.ring_efficiency)
    }

    /// Tree all-reduce (reduce to root + broadcast): `2 log2(n)` rounds of
    /// the full payload over the slowest link in the group.
    pub fn tree_allreduce(&self, group: &[GpuId], bytes: u64) -> f64 {
        let n = group.len() as f64;
        if n <= 1.0 {
            return 0.0;
        }
        let link = self.worst_link(group);
        let rounds = 2.0 * n.log2().ceil();
        self.launch_overhead + rounds * (link.latency() + bytes as f64 / link.bandwidth())
    }

    /// Hierarchical all-reduce for node-spanning groups:
    /// node-local ring reduce-scatter, inter-node ring all-reduce over node
    /// leaders on `bytes / local`, node-local ring all-gather.
    pub fn hierarchical_allreduce(&self, group: &[GpuId], bytes: u64) -> f64 {
        let (leaders, max_local) = self.node_partition(group);
        if leaders.len() <= 1 {
            return self.ring_allreduce(group, bytes);
        }
        let local_bytes = bytes;
        let mut t = 0.0;
        if max_local > 1 {
            // reduce-scatter + all-gather inside the node: each is
            // (l-1)/l of the payload over the intra-node fabric
            let l = max_local as f64;
            let link = LinkKind::IntraNode;
            let each = (l - 1.0) / l * local_bytes as f64 / link.bandwidth()
                + (l - 1.0) * link.latency();
            t += 2.0 * each + self.launch_overhead;
        }
        let shard = bytes / max_local.max(1) as u64;
        t += self.ring_allreduce(&leaders, shard);
        t
    }

    /// All-reduce with automatic algorithm choice; returns (time, algo).
    pub fn allreduce(&self, group: &[GpuId], bytes: u64) -> (f64, Algo) {
        if group.len() <= 1 {
            return (0.0, Algo::Ring);
        }
        if !self.machine.spans_nodes(group) {
            (self.ring_allreduce(group, bytes), Algo::Ring)
        } else if group.len() as u32 <= 2 * GPUS_PER_NODE {
            // small node-spanning groups (e.g. TP=16): tree over the NIC —
            // the slow case §II.E warns about
            (self.tree_allreduce(group, bytes), Algo::Tree)
        } else {
            (self.hierarchical_allreduce(group, bytes), Algo::Hierarchical)
        }
    }

    /// Ring all-gather of `bytes` total output: `(n-1)/n` traversals.
    pub fn all_gather(&self, group: &[GpuId], bytes: u64) -> f64 {
        let n = group.len() as f64;
        if n <= 1.0 {
            return 0.0;
        }
        let link = self.machine.ring_bottleneck(group);
        self.launch_overhead
            + (n - 1.0) * link.latency()
            + ((n - 1.0) / n) * bytes as f64 / (link.bandwidth() * self.ring_efficiency)
    }

    /// Ring reduce-scatter: same wire cost as all-gather.
    pub fn reduce_scatter(&self, group: &[GpuId], bytes: u64) -> f64 {
        self.all_gather(group, bytes)
    }

    /// Broadcast (tree) of the full payload.
    pub fn broadcast(&self, group: &[GpuId], bytes: u64) -> f64 {
        let n = group.len() as f64;
        if n <= 1.0 {
            return 0.0;
        }
        let link = self.worst_link(group);
        let rounds = n.log2().ceil();
        self.launch_overhead + rounds * (link.latency() + bytes as f64 / link.bandwidth())
    }

    /// DP gradient synchronisation per step (§II.D): ZeRO-1 replaces the
    /// all-reduce with reduce-scatter (grad shards) + all-gather (updated
    /// params) — same wire volume, so ZeRO-1 is memory relief, not a
    /// throughput lever (matches its last-place SHAP ranking, Fig 10).
    pub fn dp_grad_sync(&self, group: &[GpuId], bytes: u64, zero1: bool) -> f64 {
        if group.len() <= 1 {
            return 0.0;
        }
        if zero1 {
            if self.machine.spans_nodes(group) {
                // hierarchical RS+AG ≈ hierarchical all-reduce wire cost
                self.hierarchical_allreduce(group, bytes)
            } else {
                self.reduce_scatter(group, bytes) + self.all_gather(group, bytes)
            }
        } else {
            self.allreduce(group, bytes).0
        }
    }

    /// Price a two-tier hierarchical collective from its per-tier byte
    /// volumes (the engine's `*_intra_bytes` / `*_inter_bytes` counters,
    /// or the matching `perf::hier_*` analytic terms).  The intra tier
    /// rides the slowest `Machine::link` between co-resident members of
    /// the group (`IntraNode` when no two members share a node); the
    /// inter tier rides Slingshot.  A tier with zero bytes never
    /// launches and costs nothing — which is exactly how the int8 grad
    /// wire's ~4x inter-byte cut turns into wall-clock on multi-node DP
    /// groups.
    pub fn tiered_time(&self, group: &[GpuId], intra_bytes: u64, inter_bytes: u64) -> f64 {
        let mut t = 0.0;
        if intra_bytes > 0 {
            let mut link = LinkKind::IntraCard;
            let mut co_resident = false;
            for (i, &a) in group.iter().enumerate() {
                for &b in &group[i + 1..] {
                    if self.machine.node_of(a) == self.machine.node_of(b) {
                        co_resident = true;
                        let l = self.machine.link(a, b);
                        if l < link {
                            link = l;
                        }
                    }
                }
            }
            let link = if co_resident { link } else { LinkKind::IntraNode };
            t += self.launch_overhead
                + link.latency()
                + intra_bytes as f64 / (link.bandwidth() * self.ring_efficiency);
        }
        if inter_bytes > 0 {
            let link = LinkKind::InterNode;
            t += self.launch_overhead
                + link.latency()
                + inter_bytes as f64 / (link.bandwidth() * self.ring_efficiency);
        }
        t
    }

    fn worst_link(&self, group: &[GpuId]) -> LinkKind {
        let mut worst = LinkKind::IntraCard;
        for (i, &a) in group.iter().enumerate() {
            for &b in &group[i + 1..] {
                let l = self.machine.link(a, b);
                if l < worst {
                    worst = l;
                }
            }
        }
        worst
    }

    /// Split a group by node; returns (one leader per node, max GPUs/node).
    fn node_partition(&self, group: &[GpuId]) -> (Vec<GpuId>, u32) {
        let mut leaders: Vec<GpuId> = Vec::new();
        let mut counts: Vec<(u32, u32)> = Vec::new(); // (node, count)
        for &g in group {
            let node = self.machine.node_of(g);
            match counts.iter_mut().find(|(n, _)| *n == node) {
                Some((_, c)) => *c += 1,
                None => {
                    counts.push((node, 1));
                    leaders.push(g);
                }
            }
        }
        let max_local = counts.iter().map(|&(_, c)| c).max().unwrap_or(1);
        (leaders, max_local)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(nodes: u32) -> CommModel {
        CommModel::new(Machine::new(nodes))
    }

    #[test]
    fn tp2_beats_tp4_beats_tp8_per_byte() {
        // §III.A: TP=2 (intra-card) < TP=4/8 (intra-node) < TP>8 (NIC)
        let c = model(4);
        let bytes = 64 << 20;
        let t2 = c.ring_allreduce(&[0, 1], bytes);
        let t4 = c.ring_allreduce(&[0, 1, 2, 3], bytes);
        let t8 = c.ring_allreduce(&(0..8).collect::<Vec<_>>(), bytes);
        let (t16, algo) = c.allreduce(&(0..16).collect::<Vec<_>>(), bytes);
        assert!(t2 < t4 && t4 < t8 && t8 < t16);
        assert_eq!(algo, Algo::Tree);
    }

    #[test]
    fn ring_cost_scales_with_bytes() {
        let c = model(1);
        let g: Vec<u32> = (0..4).collect();
        let t1 = c.ring_allreduce(&g, 1 << 20);
        let t2 = c.ring_allreduce(&g, 1 << 24);
        assert!(t2 > 5.0 * t1);
    }

    #[test]
    fn singleton_group_free() {
        let c = model(1);
        assert_eq!(c.ring_allreduce(&[3], 1 << 20), 0.0);
        assert_eq!(c.allreduce(&[3], 1 << 20).0, 0.0);
        assert_eq!(c.dp_grad_sync(&[3], 1 << 20, true), 0.0);
    }

    #[test]
    fn zero1_wire_cost_close_to_allreduce() {
        // Fig 10: zero1 is the least-impactful knob — its comm cost is
        // within ~25% of the plain all-reduce.
        let c = model(1);
        let g: Vec<u32> = (0..8).collect();
        let bytes = 256 << 20;
        let ar = c.dp_grad_sync(&g, bytes, false);
        let z = c.dp_grad_sync(&g, bytes, true);
        assert!((z - ar).abs() / ar < 0.25, "ar={ar} zero1={z}");
    }

    #[test]
    fn hierarchical_beats_flat_ring_across_nodes() {
        let c = model(8);
        let g: Vec<u32> = (0..64).collect();
        let bytes = 1 << 30;
        let flat = c.ring_allreduce(&g, bytes);
        let hier = c.hierarchical_allreduce(&g, bytes);
        assert!(hier < flat, "hier={hier} flat={flat}");
    }

    #[test]
    fn tiered_time_prices_tiers_by_link_class() {
        let c = model(2);
        // 4 ranks on 2 nodes, 2 per node (packed): gpus 0,1 | 8,9
        let g = [0u32, 1, 8, 9];
        let bytes = 64 << 20;
        // inter bytes are ~4x more expensive per byte than intra bytes
        let intra_only = c.tiered_time(&g, bytes, 0);
        let inter_only = c.tiered_time(&g, 0, bytes);
        assert!(inter_only > 3.0 * intra_only, "inter={inter_only} intra={intra_only}");
        // zero-byte tiers never launch
        assert_eq!(c.tiered_time(&g, 0, 0), 0.0);
        // shrinking the inter tier (the int8 wire) shrinks the total
        let fp32 = c.tiered_time(&g, bytes, bytes);
        let int8 = c.tiered_time(&g, bytes, bytes / 4);
        assert!(int8 < fp32);
        // a one-rank-per-node group prices its intra tier on the default
        // in-node fabric rather than panicking on an empty link set
        let spread = c.tiered_time(&[0, 8], bytes, 0);
        assert!(spread > 0.0);
    }

    #[test]
    fn p2p_intercard_cheaper_than_internode() {
        let c = model(2);
        let bytes = 16 << 20;
        assert!(c.p2p(0, 1, bytes) < c.p2p(0, 8, bytes));
        assert_eq!(c.p2p(5, 5, bytes), 0.0);
    }
}
