//! Frontier machine model (paper Fig 5).
//!
//! A Frontier node carries 4 MI250X cards; each card is two Graphics
//! Compute Dies (GCDs).  Following the paper, "GPU" means a GCD, so a node
//! has 8 GPUs.  The link hierarchy (Fig 5):
//!
//! * same card (GCD pair):      4 x (50+50 GB/s) Infinity Fabric = 200 GB/s
//! * adjacent cards, same node: half of that                     = 100 GB/s
//! * non-adjacent cards:        a single 50+50 GB/s IF link      =  50 GB/s
//! * across nodes (Slingshot):  25+25 GB/s                       =  25 GB/s
//!
//! The non-adjacent-card tier matters: a TP=8 ring must traverse at least
//! one 50 GB/s hop, which is why the paper's 1T recipe (TP=8) pays more
//! per all-reduce byte than the 175B recipe (TP=4) — one of the levers
//! behind Fig 11's 36.14% -> 31.96% drop.
//!
//! Every TP/PP-placement conclusion in the paper (§III.A: keep TP <= 8 and
//! inside a node; §V.A: inter-node tree all-reduce is the bottleneck)
//! derives from this hierarchy, which is encoded here exactly.


pub const GPUS_PER_NODE: u32 = 8;
pub const GPUS_PER_CARD: u32 = 2;

/// MI250X GCD theoretical fp16 peak (paper footnote 1).
pub const PEAK_FP16_FLOPS: f64 = 191.5e12;
/// MI250X GCD HBM capacity.
pub const HBM_BYTES: u64 = 64 * (1 << 30);
/// MI250X GCD HBM bandwidth (for the roofline check, §V.B).
pub const HBM_BW: f64 = 1.6e12;

/// Link classes of Fig 5, slowest to fastest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LinkKind {
    /// Slingshot-11 NIC between nodes: 25+25 GB/s.
    InterNode,
    /// Single Infinity Fabric link between non-adjacent cards: 50 GB/s.
    IntraNodeFar,
    /// Infinity Fabric between adjacent cards in a node: ~100 GB/s.
    IntraNode,
    /// The 4x IF bundle between the two GCDs of one MI250X: 200 GB/s.
    IntraCard,
    /// Same device (no transfer).
    Local,
}

impl LinkKind {
    /// Unidirectional bandwidth in bytes/s.
    pub fn bandwidth(&self) -> f64 {
        match self {
            LinkKind::Local => f64::INFINITY,
            LinkKind::IntraCard => 200.0e9,
            LinkKind::IntraNode => 100.0e9,
            LinkKind::IntraNodeFar => 50.0e9,
            LinkKind::InterNode => 25.0e9,
        }
    }

    /// Per-message latency in seconds (DMA setup / NIC traversal).
    pub fn latency(&self) -> f64 {
        match self {
            LinkKind::Local => 0.0,
            LinkKind::IntraCard => 1.0e-6,
            LinkKind::IntraNode => 2.0e-6,
            LinkKind::IntraNodeFar => 2.0e-6,
            LinkKind::InterNode => 8.0e-6,
        }
    }
}

/// A global GPU (GCD) index on the machine.
pub type GpuId = u32;

/// The whole machine: `n_nodes` x 8 GCDs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Machine {
    pub n_nodes: u32,
}

impl Machine {
    pub fn new(n_nodes: u32) -> Self {
        assert!(n_nodes >= 1);
        Self { n_nodes }
    }

    /// Machine sized to hold exactly `gpus` GCDs (rounded up to full nodes).
    pub fn for_gpus(gpus: u32) -> Self {
        Self::new(gpus.div_ceil(GPUS_PER_NODE))
    }

    pub fn n_gpus(&self) -> u32 {
        self.n_nodes * GPUS_PER_NODE
    }

    pub fn node_of(&self, gpu: GpuId) -> u32 {
        gpu / GPUS_PER_NODE
    }

    pub fn card_of(&self, gpu: GpuId) -> u32 {
        gpu / GPUS_PER_CARD
    }

    /// Classify the link between two GCDs (Fig 5).
    pub fn link(&self, a: GpuId, b: GpuId) -> LinkKind {
        debug_assert!(a < self.n_gpus() && b < self.n_gpus());
        if a == b {
            LinkKind::Local
        } else if self.card_of(a) == self.card_of(b) {
            LinkKind::IntraCard
        } else if self.node_of(a) == self.node_of(b) {
            // adjacent cards share a dual IF link (~100 GB/s); the rest of
            // the in-node pairs ride a single 50 GB/s link
            let ca = self.card_of(a) % (GPUS_PER_NODE / GPUS_PER_CARD);
            let cb = self.card_of(b) % (GPUS_PER_NODE / GPUS_PER_CARD);
            if ca.abs_diff(cb) == 1 {
                LinkKind::IntraNode
            } else {
                LinkKind::IntraNodeFar
            }
        } else {
            LinkKind::InterNode
        }
    }

    /// Slowest link among a group of GPUs arranged in a ring — the
    /// effective bandwidth of ring collectives over the group.
    pub fn ring_bottleneck(&self, group: &[GpuId]) -> LinkKind {
        if group.len() <= 1 {
            return LinkKind::Local;
        }
        let mut worst = LinkKind::IntraCard;
        for i in 0..group.len() {
            let j = (i + 1) % group.len();
            let l = self.link(group[i], group[j]);
            if l < worst {
                worst = l;
            }
        }
        worst
    }

    /// Does the group span more than one node?  (§III.A: TP beyond a node
    /// falls off the Infinity-Fabric cliff.)
    pub fn spans_nodes(&self, group: &[GpuId]) -> bool {
        group
            .windows(2)
            .any(|w| self.node_of(w[0]) != self.node_of(w[1]))
    }

    /// Partition a GPU group by node, preserving the group's own order
    /// both across sub-groups (first-appearance node order) and within
    /// each sub-group.  Correct for *strided* groups — a tp-innermost DP
    /// group visits GCD `tp`-strides that can interleave across nodes, so
    /// contiguous chunking would assign wrong node sets.
    pub fn node_groups(&self, gpus: &[GpuId]) -> Vec<Vec<GpuId>> {
        let mut order: Vec<u32> = Vec::new();
        let mut out: Vec<Vec<GpuId>> = Vec::new();
        for &g in gpus {
            let node = self.node_of(g);
            match order.iter().position(|&n| n == node) {
                Some(i) => out[i].push(g),
                None => {
                    order.push(node);
                    out.push(vec![g]);
                }
            }
        }
        out
    }

    /// Pairwise bandwidth matrix in GB/s for the first `n` GPUs
    /// (regenerates the Fig 5 view; used by `examples/paper_tables.rs`).
    pub fn bandwidth_matrix(&self, n: u32) -> Vec<Vec<f64>> {
        let n = n.min(self.n_gpus());
        (0..n)
            .map(|i| {
                (0..n)
                    .map(|j| {
                        let bw = self.link(i, j).bandwidth();
                        if bw.is_finite() {
                            bw / 1e9
                        } else {
                            0.0
                        }
                    })
                    .collect()
            })
            .collect()
    }
}

/// Packed placement of a `world`-rank job onto `n_nodes` nodes: ranks are
/// split into `ceil(world / n_nodes)`-sized contiguous blocks, one block
/// per node, each block occupying the node's lowest GCDs.  This is the
/// engine's placement when `--nodes` is given; it keeps TP groups (which
/// are consecutive ranks) node-local whenever `tp` divides the block size.
pub fn packed_gpu_of(world: u32, n_nodes: u32, rank: u32) -> GpuId {
    assert!(n_nodes >= 1 && rank < world);
    let per_node = world.div_ceil(n_nodes);
    assert!(
        per_node <= GPUS_PER_NODE,
        "world {world} over {n_nodes} nodes needs {per_node} GCDs per node (max {GPUS_PER_NODE})"
    );
    (rank / per_node) * GPUS_PER_NODE + rank % per_node
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::RankLayout;

    #[test]
    fn link_hierarchy_matches_fig5() {
        let m = Machine::new(2);
        assert_eq!(m.link(0, 1), LinkKind::IntraCard); // GCD pair
        assert_eq!(m.link(0, 2), LinkKind::IntraNode); // adjacent cards
        assert_eq!(m.link(0, 6), LinkKind::IntraNodeFar); // card 0 <-> card 3
        assert_eq!(m.link(0, 9), LinkKind::InterNode); // across nodes
        assert_eq!(m.link(3, 3), LinkKind::Local);
        assert!(LinkKind::IntraCard.bandwidth() > LinkKind::IntraNode.bandwidth());
        assert!(LinkKind::IntraNode.bandwidth() > LinkKind::IntraNodeFar.bandwidth());
        assert!(LinkKind::IntraNodeFar.bandwidth() > LinkKind::InterNode.bandwidth());
    }

    #[test]
    fn tp2_fastest_tp8_still_in_node() {
        // §III.A: TP=2 rides the 200 GB/s GCD pair; TP 4/8 the 100 GB/s
        // fabric; anything larger hits the 25 GB/s NIC.
        let m = Machine::new(2);
        let tp2: Vec<u32> = (0..2).collect();
        let tp8: Vec<u32> = (0..8).collect();
        let tp16: Vec<u32> = (0..16).collect();
        assert_eq!(m.ring_bottleneck(&tp2), LinkKind::IntraCard);
        // the 8-GCD ring wraps from card 3 back to card 0: a 50 GB/s hop
        assert_eq!(m.ring_bottleneck(&tp8), LinkKind::IntraNodeFar);
        assert_eq!(m.ring_bottleneck(&tp16), LinkKind::InterNode);
    }

    #[test]
    fn machine_sizing() {
        assert_eq!(Machine::for_gpus(1024).n_nodes, 128);
        assert_eq!(Machine::for_gpus(3072).n_nodes, 384);
        assert_eq!(Machine::for_gpus(3).n_nodes, 1);
    }

    #[test]
    fn node_groups_preserve_order_and_split_strided_groups() {
        let m = Machine::new(2);
        // tp=2-strided DP group interleaving two nodes
        let g = m.node_groups(&[0, 2, 8, 10, 4]);
        assert_eq!(g, vec![vec![0, 2, 4], vec![8, 10]]);
        // node order follows first appearance, not node index
        let g = m.node_groups(&[9, 1, 11, 3]);
        assert_eq!(g, vec![vec![9, 11], vec![1, 3]]);
        assert_eq!(m.node_groups(&[5]), vec![vec![5]]);
        assert!(m.node_groups(&[]).is_empty());
    }

    #[test]
    fn packed_placement_fills_nodes_low_gcds_first() {
        // 8 ranks over 2 nodes: 4 per node, occupying GCDs 0-3 of each
        let got: Vec<GpuId> = (0..8).map(|r| packed_gpu_of(8, 2, r)).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 8, 9, 10, 11]);
        // full nodes reduce to the identity placement
        assert!((0..16).all(|r| packed_gpu_of(16, 2, r) == r));
        // uneven split: ceil(6/4)=2 per node
        let got: Vec<GpuId> = (0..6).map(|r| packed_gpu_of(6, 4, r)).collect();
        assert_eq!(got, vec![0, 1, 8, 9, 16, 17]);
    }

    #[test]
    #[should_panic(expected = "GCDs per node")]
    fn packed_placement_rejects_oversubscribed_nodes() {
        packed_gpu_of(32, 2, 0);
    }

    #[test]
    fn dp_groups_striding_across_nodes_map_to_correct_node_sets() {
        // Satellite: enumerate node_of per rank over a pp×dp×tp grid under
        // packed placement and check every DP group's node partition from
        // first principles.  tp-innermost layouts make DP groups stride by
        // `tp`, so their members interleave across nodes whenever the
        // group spans one.
        for (tp, pp, dp, nodes) in [
            (1u32, 1u32, 8u32, 2u32),
            (2, 1, 8, 2),
            (2, 1, 4, 2),
            (4, 1, 4, 2),
            (2, 2, 4, 2),
            (8, 1, 2, 2),
            (4, 2, 2, 2),
            (2, 2, 2, 1),
            (2, 4, 2, 4),
        ] {
            let l = RankLayout::new(tp, pp, dp);
            let world = l.world_size();
            let m = Machine::new(nodes);
            let per_node = world.div_ceil(nodes);
            // ground truth: packed placement puts rank r on node r/per_node
            for r in 0..world {
                assert_eq!(
                    m.node_of(packed_gpu_of(world, nodes, r)),
                    r / per_node,
                    "tp={tp} pp={pp} dp={dp} nodes={nodes} rank={r}"
                );
            }
            for g in l.all_dp_groups() {
                let gpus: Vec<GpuId> =
                    g.iter().map(|&r| packed_gpu_of(world, nodes, r)).collect();
                let parts = m.node_groups(&gpus);
                // partition: covers the group, order-preserving, node-pure
                assert_eq!(parts.iter().map(Vec::len).sum::<usize>(), gpus.len());
                for part in &parts {
                    let n0 = m.node_of(part[0]);
                    assert!(part.iter().all(|&x| m.node_of(x) == n0));
                }
                // one part per distinct node visited by the group
                let mut distinct: Vec<u32> = gpus.iter().map(|&x| m.node_of(x)).collect();
                distinct.sort_unstable();
                distinct.dedup();
                assert_eq!(parts.len(), distinct.len(), "tp={tp} pp={pp} dp={dp}");
                // members expected on node (rank/per_node) really are there
                for (&r, &gpu) in g.iter().zip(&gpus) {
                    assert_eq!(m.node_of(gpu), r / per_node);
                }
            }
        }
    }

    #[test]
    fn bandwidth_matrix_symmetric() {
        let m = Machine::new(1);
        let mat = m.bandwidth_matrix(8);
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(mat[i][j], mat[j][i]);
            }
            assert_eq!(mat[i][i], 0.0);
        }
    }
}
