//! Frontier machine model (paper Fig 5).
//!
//! A Frontier node carries 4 MI250X cards; each card is two Graphics
//! Compute Dies (GCDs).  Following the paper, "GPU" means a GCD, so a node
//! has 8 GPUs.  The link hierarchy (Fig 5):
//!
//! * same card (GCD pair):      4 x (50+50 GB/s) Infinity Fabric = 200 GB/s
//! * adjacent cards, same node: half of that                     = 100 GB/s
//! * non-adjacent cards:        a single 50+50 GB/s IF link      =  50 GB/s
//! * across nodes (Slingshot):  25+25 GB/s                       =  25 GB/s
//!
//! The non-adjacent-card tier matters: a TP=8 ring must traverse at least
//! one 50 GB/s hop, which is why the paper's 1T recipe (TP=8) pays more
//! per all-reduce byte than the 175B recipe (TP=4) — one of the levers
//! behind Fig 11's 36.14% -> 31.96% drop.
//!
//! Every TP/PP-placement conclusion in the paper (§III.A: keep TP <= 8 and
//! inside a node; §V.A: inter-node tree all-reduce is the bottleneck)
//! derives from this hierarchy, which is encoded here exactly.


pub const GPUS_PER_NODE: u32 = 8;
pub const GPUS_PER_CARD: u32 = 2;

/// MI250X GCD theoretical fp16 peak (paper footnote 1).
pub const PEAK_FP16_FLOPS: f64 = 191.5e12;
/// MI250X GCD HBM capacity.
pub const HBM_BYTES: u64 = 64 * (1 << 30);
/// MI250X GCD HBM bandwidth (for the roofline check, §V.B).
pub const HBM_BW: f64 = 1.6e12;

/// Link classes of Fig 5, slowest to fastest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LinkKind {
    /// Slingshot-11 NIC between nodes: 25+25 GB/s.
    InterNode,
    /// Single Infinity Fabric link between non-adjacent cards: 50 GB/s.
    IntraNodeFar,
    /// Infinity Fabric between adjacent cards in a node: ~100 GB/s.
    IntraNode,
    /// The 4x IF bundle between the two GCDs of one MI250X: 200 GB/s.
    IntraCard,
    /// Same device (no transfer).
    Local,
}

impl LinkKind {
    /// Unidirectional bandwidth in bytes/s.
    pub fn bandwidth(&self) -> f64 {
        match self {
            LinkKind::Local => f64::INFINITY,
            LinkKind::IntraCard => 200.0e9,
            LinkKind::IntraNode => 100.0e9,
            LinkKind::IntraNodeFar => 50.0e9,
            LinkKind::InterNode => 25.0e9,
        }
    }

    /// Per-message latency in seconds (DMA setup / NIC traversal).
    pub fn latency(&self) -> f64 {
        match self {
            LinkKind::Local => 0.0,
            LinkKind::IntraCard => 1.0e-6,
            LinkKind::IntraNode => 2.0e-6,
            LinkKind::IntraNodeFar => 2.0e-6,
            LinkKind::InterNode => 8.0e-6,
        }
    }
}

/// A global GPU (GCD) index on the machine.
pub type GpuId = u32;

/// The whole machine: `n_nodes` x 8 GCDs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Machine {
    pub n_nodes: u32,
}

impl Machine {
    pub fn new(n_nodes: u32) -> Self {
        assert!(n_nodes >= 1);
        Self { n_nodes }
    }

    /// Machine sized to hold exactly `gpus` GCDs (rounded up to full nodes).
    pub fn for_gpus(gpus: u32) -> Self {
        Self::new(gpus.div_ceil(GPUS_PER_NODE))
    }

    pub fn n_gpus(&self) -> u32 {
        self.n_nodes * GPUS_PER_NODE
    }

    pub fn node_of(&self, gpu: GpuId) -> u32 {
        gpu / GPUS_PER_NODE
    }

    pub fn card_of(&self, gpu: GpuId) -> u32 {
        gpu / GPUS_PER_CARD
    }

    /// Classify the link between two GCDs (Fig 5).
    pub fn link(&self, a: GpuId, b: GpuId) -> LinkKind {
        debug_assert!(a < self.n_gpus() && b < self.n_gpus());
        if a == b {
            LinkKind::Local
        } else if self.card_of(a) == self.card_of(b) {
            LinkKind::IntraCard
        } else if self.node_of(a) == self.node_of(b) {
            // adjacent cards share a dual IF link (~100 GB/s); the rest of
            // the in-node pairs ride a single 50 GB/s link
            let ca = self.card_of(a) % (GPUS_PER_NODE / GPUS_PER_CARD);
            let cb = self.card_of(b) % (GPUS_PER_NODE / GPUS_PER_CARD);
            if ca.abs_diff(cb) == 1 {
                LinkKind::IntraNode
            } else {
                LinkKind::IntraNodeFar
            }
        } else {
            LinkKind::InterNode
        }
    }

    /// Slowest link among a group of GPUs arranged in a ring — the
    /// effective bandwidth of ring collectives over the group.
    pub fn ring_bottleneck(&self, group: &[GpuId]) -> LinkKind {
        if group.len() <= 1 {
            return LinkKind::Local;
        }
        let mut worst = LinkKind::IntraCard;
        for i in 0..group.len() {
            let j = (i + 1) % group.len();
            let l = self.link(group[i], group[j]);
            if l < worst {
                worst = l;
            }
        }
        worst
    }

    /// Does the group span more than one node?  (§III.A: TP beyond a node
    /// falls off the Infinity-Fabric cliff.)
    pub fn spans_nodes(&self, group: &[GpuId]) -> bool {
        group
            .windows(2)
            .any(|w| self.node_of(w[0]) != self.node_of(w[1]))
    }

    /// Pairwise bandwidth matrix in GB/s for the first `n` GPUs
    /// (regenerates the Fig 5 view; used by `examples/paper_tables.rs`).
    pub fn bandwidth_matrix(&self, n: u32) -> Vec<Vec<f64>> {
        let n = n.min(self.n_gpus());
        (0..n)
            .map(|i| {
                (0..n)
                    .map(|j| {
                        let bw = self.link(i, j).bandwidth();
                        if bw.is_finite() {
                            bw / 1e9
                        } else {
                            0.0
                        }
                    })
                    .collect()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_hierarchy_matches_fig5() {
        let m = Machine::new(2);
        assert_eq!(m.link(0, 1), LinkKind::IntraCard); // GCD pair
        assert_eq!(m.link(0, 2), LinkKind::IntraNode); // adjacent cards
        assert_eq!(m.link(0, 6), LinkKind::IntraNodeFar); // card 0 <-> card 3
        assert_eq!(m.link(0, 9), LinkKind::InterNode); // across nodes
        assert_eq!(m.link(3, 3), LinkKind::Local);
        assert!(LinkKind::IntraCard.bandwidth() > LinkKind::IntraNode.bandwidth());
        assert!(LinkKind::IntraNode.bandwidth() > LinkKind::IntraNodeFar.bandwidth());
        assert!(LinkKind::IntraNodeFar.bandwidth() > LinkKind::InterNode.bandwidth());
    }

    #[test]
    fn tp2_fastest_tp8_still_in_node() {
        // §III.A: TP=2 rides the 200 GB/s GCD pair; TP 4/8 the 100 GB/s
        // fabric; anything larger hits the 25 GB/s NIC.
        let m = Machine::new(2);
        let tp2: Vec<u32> = (0..2).collect();
        let tp8: Vec<u32> = (0..8).collect();
        let tp16: Vec<u32> = (0..16).collect();
        assert_eq!(m.ring_bottleneck(&tp2), LinkKind::IntraCard);
        // the 8-GCD ring wraps from card 3 back to card 0: a 50 GB/s hop
        assert_eq!(m.ring_bottleneck(&tp8), LinkKind::IntraNodeFar);
        assert_eq!(m.ring_bottleneck(&tp16), LinkKind::InterNode);
    }

    #[test]
    fn machine_sizing() {
        assert_eq!(Machine::for_gpus(1024).n_nodes, 128);
        assert_eq!(Machine::for_gpus(3072).n_nodes, 384);
        assert_eq!(Machine::for_gpus(3).n_nodes, 1);
    }

    #[test]
    fn bandwidth_matrix_symmetric() {
        let m = Machine::new(1);
        let mat = m.bandwidth_matrix(8);
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(mat[i][j], mat[j][i]);
            }
            assert_eq!(mat[i][i], 0.0);
        }
    }
}
