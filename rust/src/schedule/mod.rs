//! Pipeline schedules (§II.C): GPipe and PipeDream-style 1F1B.
//!
//! A schedule is compiled to one *instruction stream per stage*: the
//! ordered list of Forward/Backward ops each pipeline rank executes.  The
//! same streams drive both the discrete-event performance simulator
//! (`perf::sim`) and the real execution engine (`coordinator`), so the
//! thing we benchmark is the thing we run.
//!
//! Interleaved 1F1B (virtual chunks) is modelled analytically in
//! `ScheduleKind::bubble_fraction`; the instruction-stream generators here
//! cover the two schedules the paper actually runs (DeepSpeed's pipeline
//! engine implements 1F1B, §V.A).

use crate::config::ScheduleKind;

/// One pipeline instruction for a stage rank.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Run the stage forward for micro-batch `mb` (receives activation from
    /// the previous stage implicitly; blocking semantics).
    Forward { mb: u32 },
    /// Run the stage backward for micro-batch `mb` (receives the gradient
    /// from the next stage implicitly).
    Backward { mb: u32 },
}

impl Op {
    pub fn mb(&self) -> u32 {
        match self {
            Op::Forward { mb } | Op::Backward { mb } => *mb,
        }
    }

    pub fn is_forward(&self) -> bool {
        matches!(self, Op::Forward { .. })
    }
}

/// Instruction streams for all `p` stages of one schedule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schedule {
    pub kind: ScheduleKind,
    pub p: u32,
    pub m: u32,
    /// `streams[stage]` = ordered ops for that stage.
    pub streams: Vec<Vec<Op>>,
}

/// GPipe (§II.C): all m forwards, flush, all m backwards (reverse order).
pub fn gpipe(p: u32, m: u32) -> Schedule {
    assert!(p >= 1 && m >= 1);
    let streams = (0..p)
        .map(|_| {
            let fwd = (0..m).map(|mb| Op::Forward { mb });
            let bwd = (0..m).rev().map(|mb| Op::Backward { mb });
            fwd.chain(bwd).collect()
        })
        .collect();
    Schedule { kind: ScheduleKind::GPipe, p, m, streams }
}

/// PipeDream-flush 1F1B (§II.C): stage `i` runs `min(p-1-i, m)` warmup
/// forwards, then alternates one-forward-one-backward, then drains.
pub fn one_f1b(p: u32, m: u32) -> Schedule {
    assert!(p >= 1 && m >= 1);
    let streams = (0..p)
        .map(|i| {
            let warmup = (p - 1 - i).min(m);
            let mut ops = Vec::with_capacity(2 * m as usize);
            let mut next_fwd = 0;
            let mut next_bwd = 0;
            for _ in 0..warmup {
                ops.push(Op::Forward { mb: next_fwd });
                next_fwd += 1;
            }
            // steady state: 1F1B until all forwards are issued
            while next_fwd < m {
                ops.push(Op::Forward { mb: next_fwd });
                next_fwd += 1;
                ops.push(Op::Backward { mb: next_bwd });
                next_bwd += 1;
            }
            // cooldown: drain remaining backwards
            while next_bwd < m {
                ops.push(Op::Backward { mb: next_bwd });
                next_bwd += 1;
            }
            ops
        })
        .collect();
    Schedule { kind: ScheduleKind::OneF1B, p, m, streams }
}

/// Build the stream set for a schedule kind (interleaved falls back to
/// plain 1F1B streams; its smaller bubble is captured analytically).
pub fn build(kind: ScheduleKind, p: u32, m: u32) -> Schedule {
    match kind {
        ScheduleKind::GPipe => gpipe(p, m),
        ScheduleKind::OneF1B | ScheduleKind::Interleaved1F1B { .. } => {
            let mut s = one_f1b(p, m);
            s.kind = kind;
            s
        }
    }
}

impl Schedule {
    /// Peak number of in-flight activations held by `stage` — what the
    /// activation-memory model charges (1F1B caps it at `p - stage`;
    /// GPipe at `m`, which is why GPipe OOMs at large m).
    pub fn peak_inflight(&self, stage: u32) -> u32 {
        let mut live: i64 = 0;
        let mut peak: i64 = 0;
        for op in &self.streams[stage as usize] {
            match op {
                Op::Forward { .. } => live += 1,
                Op::Backward { .. } => live -= 1,
            }
            peak = peak.max(live);
        }
        peak as u32
    }

    /// Check the stream invariants; returns an error description if broken.
    /// Used by proptest (`rust/tests/props.rs`).
    pub fn validate(&self) -> Result<(), String> {
        for (i, ops) in self.streams.iter().enumerate() {
            let m = self.m as usize;
            if ops.len() != 2 * m {
                return Err(format!("stage {i}: {} ops, want {}", ops.len(), 2 * m));
            }
            let mut fwd_seen = vec![false; m];
            let mut bwd_seen = vec![false; m];
            for op in ops {
                let mb = op.mb() as usize;
                match op {
                    Op::Forward { .. } => {
                        if fwd_seen[mb] {
                            return Err(format!("stage {i}: fwd {mb} twice"));
                        }
                        fwd_seen[mb] = true;
                    }
                    Op::Backward { .. } => {
                        if !fwd_seen[mb] {
                            return Err(format!("stage {i}: bwd {mb} before fwd"));
                        }
                        if bwd_seen[mb] {
                            return Err(format!("stage {i}: bwd {mb} twice"));
                        }
                        bwd_seen[mb] = true;
                    }
                }
            }
            if !fwd_seen.iter().all(|&s| s) || !bwd_seen.iter().all(|&s| s) {
                return Err(format!("stage {i}: not all micro-batches processed"));
            }
            // forwards must be issued in order (activations are a FIFO
            // between stages in the real engine)
            let fwd_order: Vec<u32> =
                ops.iter().filter(|o| o.is_forward()).map(|o| o.mb()).collect();
            if !fwd_order.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("stage {i}: forwards out of order"));
            }
        }
        // cross-stage deadlock-freedom: simulate with blocking FIFOs
        self.check_deadlock_free()
    }

    /// Abstractly execute all streams against blocking FIFO channels to
    /// prove the schedule cannot deadlock under the engine's semantics.
    fn check_deadlock_free(&self) -> Result<(), String> {
        let p = self.p as usize;
        let mut pc = vec![0usize; p]; // program counter per stage
        // acts_ready[i] = forwards completed by stage i (feeds stage i+1);
        // grads_ready[i] = backwards completed by stage i (feeds stage i-1)
        let mut acts_done: Vec<Vec<bool>> = vec![vec![false; self.m as usize]; p];
        let mut grads_done: Vec<Vec<bool>> = vec![vec![false; self.m as usize]; p];
        loop {
            let mut progressed = false;
            for i in 0..p {
                while pc[i] < self.streams[i].len() {
                    let op = self.streams[i][pc[i]];
                    let mb = op.mb() as usize;
                    let ready = match op {
                        Op::Forward { .. } => i == 0 || acts_done[i - 1][mb],
                        Op::Backward { .. } => i == p - 1 || grads_done[i + 1][mb],
                    };
                    if !ready {
                        break;
                    }
                    match op {
                        Op::Forward { .. } => acts_done[i][mb] = true,
                        Op::Backward { .. } => grads_done[i][mb] = true,
                    }
                    pc[i] += 1;
                    progressed = true;
                }
            }
            if pc.iter().enumerate().all(|(i, &c)| c == self.streams[i].len()) {
                return Ok(());
            }
            if !progressed {
                return Err(format!("deadlock at pcs {pc:?}"));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpipe_and_1f1b_validate() {
        for p in [1u32, 2, 4, 8] {
            for m in [1u32, 2, 4, 16, 33] {
                gpipe(p, m).validate().unwrap();
                one_f1b(p, m).validate().unwrap();
            }
        }
    }

    #[test]
    fn one_f1b_caps_inflight_at_stage_depth() {
        let s = one_f1b(8, 32);
        for stage in 0..8 {
            let cap = 8 - stage; // p - i
            assert!(
                s.peak_inflight(stage) <= cap,
                "stage {stage}: {} > {cap}",
                s.peak_inflight(stage)
            );
        }
    }

    #[test]
    fn gpipe_inflight_grows_with_m() {
        let s = gpipe(4, 32);
        assert_eq!(s.peak_inflight(0), 32); // why GPipe hits the memory wall
        let f = one_f1b(4, 32);
        assert_eq!(f.peak_inflight(0), 4);
    }

    #[test]
    fn steady_state_alternates() {
        let s = one_f1b(4, 16);
        // stage 0 warms up with 3 forwards then strictly alternates
        let ops = &s.streams[0];
        assert!(ops[..3].iter().all(|o| o.is_forward()));
        for i in 0..13 {
            assert!(ops[3 + 2 * i].is_forward());
            assert!(!ops[4 + 2 * i].is_forward());
        }
    }

    #[test]
    fn single_stage_degenerates() {
        let s = one_f1b(1, 4);
        // fwd/bwd strictly alternate when there is no pipeline
        let ops = &s.streams[0];
        for (idx, op) in ops.iter().enumerate() {
            assert_eq!(op.is_forward(), idx % 2 == 0);
        }
    }
}
