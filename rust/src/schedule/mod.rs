//! Pipeline schedules (§II.C): GPipe, PipeDream-style 1F1B, and
//! Megatron-style interleaved 1F1B with virtual model chunks.
//!
//! A schedule is compiled to one *instruction stream per pipeline rank*:
//! the ordered list of Forward/Backward ops that rank executes.  Every
//! instruction names a `(chunk, mb)` pair — `chunk` is the *virtual stage*
//! (model chunk) index on that rank, `mb` the micro-batch.  Plain GPipe
//! and 1F1B are the `v = 1` special case where every op runs chunk 0.
//!
//! With `v` chunks per rank the model is cut into `K = p * v` global
//! stages; rank `r` hosts the global stages `{r, r + p, ..., r + (v-1)p}`
//! (Megatron's `initialize_model_parallel` chunk assignment), so the
//! global stage of `(chunk c, rank r)` is `g = c * p + r`.
//!
//! The same streams drive all three consumers: the discrete-event
//! performance simulator (`perf::sim`), the activation-memory model
//! (`mem`), and the real execution engine (`coordinator`) — the thing we
//! benchmark is the thing we run, for *all* schedules including
//! interleaved (no analytic-only fallback).
//!
//! **Tensor parallelism is orthogonal to the instruction set.**  Streams
//! are emitted per *pipeline* rank; with `tp > 1` the engine runs each
//! stream SPMD on all `tp` shard threads of that pipeline cell — every
//! op's operands are sharded and its per-layer all-reduces happen inside
//! the stage entry points, so the schedule (ordering, dataflow, deadlock
//! proof) is identical for every tp.  Nothing here is tp-aware, by
//! design: `validate()`'s guarantees transfer to sharded execution
//! because all shards of a cell block and progress together.

use crate::config::ScheduleKind;

/// One pipeline instruction for a rank: which model chunk (virtual stage)
/// runs which micro-batch in which direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Run chunk `chunk` forward for micro-batch `mb` (receives the
    /// activation from the previous *global* stage implicitly; blocking
    /// semantics).
    Forward { chunk: u32, mb: u32 },
    /// Run chunk `chunk` backward for micro-batch `mb` (receives the
    /// gradient from the next *global* stage implicitly).
    Backward { chunk: u32, mb: u32 },
}

impl Op {
    pub fn mb(&self) -> u32 {
        match self {
            Op::Forward { mb, .. } | Op::Backward { mb, .. } => *mb,
        }
    }

    /// Virtual-stage (model chunk) index on the executing rank.
    pub fn chunk(&self) -> u32 {
        match self {
            Op::Forward { chunk, .. } | Op::Backward { chunk, .. } => *chunk,
        }
    }

    pub fn is_forward(&self) -> bool {
        matches!(self, Op::Forward { .. })
    }
}

/// Instruction streams for all `p` pipeline ranks of one schedule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schedule {
    pub kind: ScheduleKind,
    /// Pipeline ranks (worker grid depth), NOT global stages.
    pub p: u32,
    pub m: u32,
    /// Virtual chunks per rank; global stages = `p * v`.
    pub v: u32,
    /// `streams[rank]` = ordered ops for that rank.
    pub streams: Vec<Vec<Op>>,
}

/// GPipe (§II.C): all m forwards, flush, all m backwards (reverse order).
pub fn gpipe(p: u32, m: u32) -> Schedule {
    assert!(p >= 1 && m >= 1);
    let streams = (0..p)
        .map(|_| {
            let fwd = (0..m).map(|mb| Op::Forward { chunk: 0, mb });
            let bwd = (0..m).rev().map(|mb| Op::Backward { chunk: 0, mb });
            fwd.chain(bwd).collect()
        })
        .collect();
    Schedule { kind: ScheduleKind::GPipe, p, m, v: 1, streams }
}

/// PipeDream-flush 1F1B (§II.C): rank `i` runs `min(p-1-i, m)` warmup
/// forwards, then alternates one-forward-one-backward, then drains.
pub fn one_f1b(p: u32, m: u32) -> Schedule {
    assert!(p >= 1 && m >= 1);
    let streams = (0..p)
        .map(|i| {
            let warmup = (p - 1 - i).min(m);
            let mut ops = Vec::with_capacity(2 * m as usize);
            let mut next_fwd = 0;
            let mut next_bwd = 0;
            for _ in 0..warmup {
                ops.push(Op::Forward { chunk: 0, mb: next_fwd });
                next_fwd += 1;
            }
            // steady state: 1F1B until all forwards are issued
            while next_fwd < m {
                ops.push(Op::Forward { chunk: 0, mb: next_fwd });
                next_fwd += 1;
                ops.push(Op::Backward { chunk: 0, mb: next_bwd });
                next_bwd += 1;
            }
            // cooldown: drain remaining backwards
            while next_bwd < m {
                ops.push(Op::Backward { chunk: 0, mb: next_bwd });
                next_bwd += 1;
            }
            ops
        })
        .collect();
    Schedule { kind: ScheduleKind::OneF1B, p, m, v: 1, streams }
}

/// Megatron-style interleaved 1F1B over `v` model chunks per rank.
///
/// The per-rank warmup ramp is `2(p - 1 - rank) + (v - 1)p` virtual
/// forwards (capped at `m·v`), followed by the 1F1B steady state over
/// *virtual* micro-batches and a backward drain.  Virtual forward `k`
/// maps to chunk `(k mod pv) / p` of data micro-batch
/// `(k div pv)·p + (k mod p)`; virtual backwards run the chunks in
/// reverse.  Requires `m % p == 0` for `v > 1` (Megatron's constraint:
/// the interleaving window covers `p` micro-batches per chunk), which
/// also implies a saturated pipeline (`m >= p`).
///
/// The generated streams achieve the `(p-1)/(m·v)` bubble: the fill/drain
/// ramp costs `(p-1)` *chunk* slots instead of `(p-1)` full-stage slots
/// (`perf::sim` cross-validates this, and the abstract blocking execution
/// in [`Schedule::validate`] proves deadlock-freedom).
pub fn interleaved_1f1b(p: u32, m: u32, v: u32) -> Schedule {
    assert!(p >= 1 && m >= 1 && v >= 1);
    if v == 1 {
        let mut s = one_f1b(p, m);
        s.kind = ScheduleKind::Interleaved1F1B { v: 1 };
        return s;
    }
    assert!(
        m % p == 0,
        "interleaved 1F1B needs m ({m}) divisible by p ({p})"
    );
    let total = m * v;
    let window = p * v;
    // virtual forward id -> (chunk, mb)
    let fpos = |k: u32| -> (u32, u32) {
        let (grp, pos) = (k / window, k % window);
        (pos / p, grp * p + pos % p)
    };
    // virtual backward id -> (chunk, mb): chunks drain in reverse
    let bpos = |k: u32| -> (u32, u32) {
        let (grp, pos) = (k / window, k % window);
        (v - 1 - pos / p, grp * p + pos % p)
    };

    let streams = (0..p)
        .map(|rank| {
            let warmup = (2 * (p - 1 - rank) + (v - 1) * p).min(total);
            let mut ops = Vec::with_capacity(2 * total as usize);
            for k in 0..warmup {
                let (chunk, mb) = fpos(k);
                ops.push(Op::Forward { chunk, mb });
            }
            for j in 0..total - warmup {
                let (chunk, mb) = fpos(warmup + j);
                ops.push(Op::Forward { chunk, mb });
                let (chunk, mb) = bpos(j);
                ops.push(Op::Backward { chunk, mb });
            }
            for j in total - warmup..total {
                let (chunk, mb) = bpos(j);
                ops.push(Op::Backward { chunk, mb });
            }
            ops
        })
        .collect();
    Schedule { kind: ScheduleKind::Interleaved1F1B { v }, p, m, v, streams }
}

/// Build the stream set for a schedule kind.  All three schedules emit
/// genuine instruction streams — interleaved no longer falls back to
/// plain 1F1B.
pub fn build(kind: ScheduleKind, p: u32, m: u32) -> Schedule {
    match kind {
        ScheduleKind::GPipe => gpipe(p, m),
        ScheduleKind::OneF1B => one_f1b(p, m),
        ScheduleKind::Interleaved1F1B { v } => interleaved_1f1b(p, m, v),
    }
}

impl Schedule {
    /// Global stages (`p * v`): what the model is actually cut into.
    pub fn global_stages(&self) -> u32 {
        self.p * self.v
    }

    /// Global stage index of `(chunk, rank)` under the Megatron chunk
    /// assignment.
    pub fn global_stage(&self, chunk: u32, rank: u32) -> u32 {
        chunk * self.p + rank
    }

    /// Peak number of in-flight *chunk* activations held by `rank` — what
    /// the activation-memory model charges per stored chunk input.  1F1B
    /// caps it at `p - rank`; GPipe at `m` (why GPipe OOMs at large m);
    /// interleaved at `2(p-1-rank) + (v-1)p + 1` chunk slots — a `(v+1)/v`
    /// overhead over plain 1F1B in full-stage units, the known memory
    /// price of interleaving.
    pub fn peak_inflight(&self, rank: u32) -> u32 {
        let mut live: i64 = 0;
        let mut peak: i64 = 0;
        for op in &self.streams[rank as usize] {
            match op {
                Op::Forward { .. } => live += 1,
                Op::Backward { .. } => live -= 1,
            }
            peak = peak.max(live);
        }
        peak as u32
    }

    /// Check the stream invariants; returns an error description if broken.
    /// Used by proptest (`rust/tests/props.rs`).
    pub fn validate(&self) -> Result<(), String> {
        let m = self.m as usize;
        let v = self.v as usize;
        for (i, ops) in self.streams.iter().enumerate() {
            if ops.len() != 2 * m * v {
                return Err(format!("rank {i}: {} ops, want {}", ops.len(), 2 * m * v));
            }
            let mut fwd_seen = vec![false; m * v];
            let mut bwd_seen = vec![false; m * v];
            for op in ops {
                let (c, mb) = (op.chunk() as usize, op.mb() as usize);
                if c >= v || mb >= m {
                    return Err(format!("rank {i}: op out of range ({c}, {mb})"));
                }
                let slot = c * m + mb;
                match op {
                    Op::Forward { .. } => {
                        if fwd_seen[slot] {
                            return Err(format!("rank {i}: fwd ({c},{mb}) twice"));
                        }
                        fwd_seen[slot] = true;
                    }
                    Op::Backward { .. } => {
                        if !fwd_seen[slot] {
                            return Err(format!("rank {i}: bwd ({c},{mb}) before fwd"));
                        }
                        if bwd_seen[slot] {
                            return Err(format!("rank {i}: bwd ({c},{mb}) twice"));
                        }
                        bwd_seen[slot] = true;
                    }
                }
            }
            if !fwd_seen.iter().all(|&s| s) || !bwd_seen.iter().all(|&s| s) {
                return Err(format!("rank {i}: not all (chunk, mb) pairs processed"));
            }
            // per chunk, forwards must be issued in micro-batch order
            // (activations are a FIFO per (global stage, global stage + 1)
            // channel in the real engine)
            for c in 0..v {
                let order: Vec<u32> = ops
                    .iter()
                    .filter(|o| o.is_forward() && o.chunk() as usize == c)
                    .map(|o| o.mb())
                    .collect();
                if !order.windows(2).all(|w| w[0] < w[1]) {
                    return Err(format!("rank {i}: chunk {c} forwards out of order"));
                }
            }
        }
        // cross-rank deadlock-freedom: simulate with blocking FIFOs
        self.check_deadlock_free()
    }

    /// Abstractly execute all streams against blocking channels between
    /// *global* stages to prove the schedule cannot deadlock under the
    /// engine's semantics: forward of global stage `g` needs stage `g-1`'s
    /// forward of the same micro-batch; backward of `g` needs `g+1`'s
    /// backward.
    fn check_deadlock_free(&self) -> Result<(), String> {
        let p = self.p as usize;
        let k = self.global_stages() as usize;
        let m = self.m as usize;
        let mut pc = vec![0usize; p]; // program counter per rank
        let mut acts_done = vec![vec![false; m]; k];
        let mut grads_done = vec![vec![false; m]; k];
        loop {
            let mut progressed = false;
            for i in 0..p {
                while pc[i] < self.streams[i].len() {
                    let op = self.streams[i][pc[i]];
                    let g = (op.chunk() as usize) * p + i;
                    let mb = op.mb() as usize;
                    let ready = match op {
                        Op::Forward { .. } => g == 0 || acts_done[g - 1][mb],
                        Op::Backward { .. } => g == k - 1 || grads_done[g + 1][mb],
                    };
                    if !ready {
                        break;
                    }
                    match op {
                        Op::Forward { .. } => acts_done[g][mb] = true,
                        Op::Backward { .. } => grads_done[g][mb] = true,
                    }
                    pc[i] += 1;
                    progressed = true;
                }
            }
            if pc.iter().enumerate().all(|(i, &c)| c == self.streams[i].len()) {
                return Ok(());
            }
            if !progressed {
                return Err(format!("deadlock at pcs {pc:?}"));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpipe_and_1f1b_validate() {
        for p in [1u32, 2, 4, 8] {
            for m in [1u32, 2, 4, 16, 33] {
                gpipe(p, m).validate().unwrap();
                one_f1b(p, m).validate().unwrap();
            }
        }
    }

    #[test]
    fn interleaved_validates_across_grid() {
        for p in [1u32, 2, 3, 4, 8] {
            for q in [1u32, 2, 4] {
                let m = p * q;
                for v in [1u32, 2, 3, 4, 8] {
                    let s = interleaved_1f1b(p, m, v);
                    s.validate()
                        .unwrap_or_else(|e| panic!("p={p} m={m} v={v}: {e}"));
                    assert_eq!(s.v, v);
                    assert_eq!(s.global_stages(), p * v);
                }
            }
        }
    }

    #[test]
    fn interleaved_warmup_ramp() {
        // rank r warms up with 2(p-1-r) + (v-1)p forwards; the steady
        // state's leading forward follows, so the first backward sits at
        // position warmup + 1
        let (p, m, v) = (4u32, 8u32, 2u32);
        let s = interleaved_1f1b(p, m, v);
        for r in 0..p {
            let warmup = (2 * (p - 1 - r) + (v - 1) * p) as usize;
            let got = s.streams[r as usize]
                .iter()
                .take_while(|o| o.is_forward())
                .count();
            assert_eq!(got, warmup + 1, "rank {r}");
        }
    }

    #[test]
    fn interleaved_inflight_bound() {
        // peak chunk activations per rank: 2(p-1-r) + (v-1)p + 1, and
        // always at or below GPipe's all-in-flight m*v bound
        for (p, q, v) in [(2u32, 2u32, 2u32), (4, 4, 2), (4, 2, 4), (8, 4, 4)] {
            let m = p * q;
            let s = interleaved_1f1b(p, m, v);
            for r in 0..p {
                let peak = s.peak_inflight(r);
                let ramp = 2 * (p - 1 - r) + (v - 1) * p + 1;
                assert!(peak <= ramp.min(m * v), "p={p} m={m} v={v} r={r}: {peak}");
                assert!(peak <= m * v);
            }
        }
    }

    #[test]
    fn interleaved_v1_degenerates_to_plain_1f1b() {
        let a = interleaved_1f1b(4, 8, 1);
        let b = one_f1b(4, 8);
        assert_eq!(a.streams, b.streams);
        assert_eq!(a.kind, ScheduleKind::Interleaved1F1B { v: 1 });
    }

    #[test]
    fn build_emits_true_interleaved_streams() {
        // the old analytic-only fallback is gone: interleaved streams must
        // reference chunk indices beyond 0
        let s = build(ScheduleKind::Interleaved1F1B { v: 2 }, 4, 8);
        assert!(s.streams[0].iter().any(|o| o.chunk() == 1));
        assert_eq!(s.v, 2);
    }

    #[test]
    #[should_panic(expected = "divisible")]
    fn interleaved_rejects_unaligned_microbatches() {
        interleaved_1f1b(4, 6, 2);
    }

    #[test]
    fn one_f1b_caps_inflight_at_stage_depth() {
        let s = one_f1b(8, 32);
        for rank in 0..8 {
            let cap = 8 - rank; // p - i
            assert!(
                s.peak_inflight(rank) <= cap,
                "rank {rank}: {} > {cap}",
                s.peak_inflight(rank)
            );
        }
    }

    #[test]
    fn gpipe_inflight_grows_with_m() {
        let s = gpipe(4, 32);
        assert_eq!(s.peak_inflight(0), 32); // why GPipe hits the memory wall
        let f = one_f1b(4, 32);
        assert_eq!(f.peak_inflight(0), 4);
    }

    #[test]
    fn steady_state_alternates() {
        let s = one_f1b(4, 16);
        // rank 0 warms up with 3 forwards then strictly alternates
        let ops = &s.streams[0];
        assert!(ops[..3].iter().all(|o| o.is_forward()));
        for i in 0..13 {
            assert!(ops[3 + 2 * i].is_forward());
            assert!(!ops[4 + 2 * i].is_forward());
        }
    }

    #[test]
    fn single_rank_degenerates() {
        let s = one_f1b(1, 4);
        // fwd/bwd strictly alternate when there is no pipeline
        let ops = &s.streams[0];
        for (idx, op) in ops.iter().enumerate() {
            assert_eq!(op.is_forward(), idx % 2 == 0);
        }
    }

    #[test]
    fn single_rank_interleaved_chains_chunks() {
        // p=1, v=3: chunks run 0,1,2 forward then 2,1,0 backward per mb
        let s = interleaved_1f1b(1, 2, 3);
        s.validate().unwrap();
        let first: Vec<(bool, u32, u32)> = s.streams[0]
            .iter()
            .take(4)
            .map(|o| (o.is_forward(), o.chunk(), o.mb()))
            .collect();
        assert_eq!(
            first,
            vec![(true, 0, 0), (true, 1, 0), (true, 2, 0), (false, 2, 0)]
        );
    }
}
