//! ZeRO-1 sharded data parallelism (§II.D).
//!
//! ZeRO stage 1 shards the *optimizer states* (and the fp32 master copy
//! they act on) across the DP group: each rank reduce-scatters the step's
//! gradients, applies Adam to its own contiguous parameter shard only, and
//! all-gathers the updated parameters.  Wire volume matches a plain
//! all-reduce (so no throughput change — Fig 10's last-place SHAP rank)
//! while optimizer memory drops by `1/dp` (the `mem` model's accounting).
//!
//! The non-sharded baseline (`Ddp`) is implemented alongside so the two
//! paths can be tested for *bitwise-equivalent parameter trajectories* —
//! the invariant that makes ZeRO "free" to turn on.
//!
//! Two step entry points: [`DistOptimizer::step`] performs the gradient
//! sync itself (all-reduce / reduce-scatter), while
//! [`DistOptimizer::step_reduced`] consumes gradients the engine has
//! already mean-reduced through its backward-overlapped bucketed
//! nonblocking all-reduce — only the tiny norm combines and the ZeRO-1
//! parameter all-gather remain.  Both communicate the small syncs with
//! a configurable [`Algo`] (the engine default is `Ring`).

use crate::collectives::{chunk_bounds, Algo, Group, TpComm};
use crate::optim::{clip_grad_norm, Adam, AdamConfig};
use crate::precision::Dtype;
use std::sync::Arc;

/// Tensor-parallel context for the optimizer step: this shard's
/// communicator plus the span of TP-replicated parameters in its flat
/// buffer.  Gradient clipping then uses the norm over the whole TP
/// group's logical parameter vector (replicated span counted once) — the
/// dense-equivalent semantics the tp = 1/2/4 trajectory tests require.
pub type TpCtx<'a> = Option<(&'a TpComm, (usize, usize))>;

/// Squared-norm contribution of one shard's `grads` to the TP-global
/// norm: the replicated span's energy is charged at 1/tp per shard
/// (its gradients are identical across shards after the TP grad sync),
/// so the cross-shard sum counts it exactly once.  `replicated` is given
/// in `grads` coordinates and may be clamped empty.
fn tp_partial_sq(grads: &[f32], replicated: (usize, usize), tp: usize) -> f32 {
    let full: f32 = grads.iter().map(|&g| g * g).sum();
    let (lo, hi) = replicated;
    let rep: f32 = grads[lo..hi].iter().map(|&g| g * g).sum();
    full - rep * (1.0 - 1.0 / tp as f32)
}

/// Clip `grads` by the TP-global norm (replicated span counted once via
/// a 1-float subgroup all-reduce) and return the pre-clip norm — the
/// DDP clip path under tensor parallelism, shared by both step entry
/// points.
fn tp_clip(grads: &mut [f32], clip: f32, comm: &TpComm, span: (usize, usize)) -> f32 {
    let mut sq = vec![tp_partial_sq(grads, span, comm.tp())];
    comm.all_reduce_sum(&mut sq);
    let norm = sq[0].max(0.0).sqrt();
    if clip > 0.0 && norm > clip {
        let scale = clip / (norm + 1e-6);
        grads.iter_mut().for_each(|g| *g *= scale);
    }
    norm
}

/// How a DP rank synchronises gradients and steps the optimizer.
pub enum DistOptimizer {
    /// Replicated optimizer: all-reduce grads, every rank steps everything.
    Ddp(Adam),
    /// ZeRO-1: reduce-scatter, step own shard, all-gather params.
    Zero1(Zero1Optimizer),
}

impl DistOptimizer {
    /// `algo` selects the collective algorithm for the *small* syncs
    /// (the 1-float grad-norm combine) — the engine threads its
    /// `EngineConfig::collective_algo` (default `Ring`) through here.
    /// `dtype` is the working-parameter dtype: `Bf16` keeps fp32 master
    /// weights inside Adam (full masters for DDP, shard-only masters
    /// under ZeRO-1 — the paper's 4-bytes/param master term divided by
    /// `dp`) and re-quantizes the working copy after every step; it is
    /// also the ZeRO-1 parameter all-gather wire dtype.
    pub fn new(
        zero1: bool,
        cfg: AdamConfig,
        n_params: usize,
        dp_rank: usize,
        dp: usize,
        algo: Algo,
        dtype: Dtype,
    ) -> Self {
        if zero1 {
            DistOptimizer::Zero1(Zero1Optimizer::new(cfg, n_params, dp_rank, dp, algo, dtype))
        } else {
            DistOptimizer::Ddp(Adam::new_mixed(cfg, n_params, dtype))
        }
    }

    /// Synchronise `grads` across `group` (mean) and update `params`.
    /// `grads` is consumed as scratch (it holds the averaged gradient for
    /// Ddp, and is untouched past the shard for Zero1).  With `tp` set,
    /// the clip norm is combined across the tensor-parallel group
    /// (replicated span counted once) via a 1-float subgroup all-reduce.
    pub fn step(
        &mut self,
        group: &Arc<Group>,
        rank: usize,
        params: &mut [f32],
        grads: &mut [f32],
        lr_scale: f32,
        tp: TpCtx<'_>,
    ) -> f32 {
        let dp = group.len() as f32;
        match self {
            DistOptimizer::Ddp(adam) => {
                group.all_reduce_sum(rank, grads, Algo::Ring);
                grads.iter_mut().for_each(|g| *g /= dp);
                let norm = match tp {
                    None => clip_grad_norm(grads, adam.cfg.grad_clip),
                    Some((comm, span)) => tp_clip(grads, adam.cfg.grad_clip, comm, span),
                };
                adam.step(params, grads, lr_scale);
                norm
            }
            DistOptimizer::Zero1(z) => z.step(group, rank, params, grads, lr_scale, tp),
        }
    }

    /// Optimizer step over gradients that are **already DP-mean-reduced**
    /// (the engine's bucketed nonblocking all-reduce drains into `grads`
    /// before calling this).  Only the tiny syncs remain: the TP-global
    /// clip-norm combine and (ZeRO-1) the per-shard norm combine + the
    /// updated-parameter all-gather.  Every DP rank holds bit-identical
    /// `grads` here (rank-order bucket reduction), so DDP ranks step in
    /// lockstep without further communication.
    pub fn step_reduced(
        &mut self,
        group: &Arc<Group>,
        rank: usize,
        params: &mut [f32],
        grads: &mut [f32],
        lr_scale: f32,
        tp: TpCtx<'_>,
    ) -> f32 {
        match self {
            DistOptimizer::Ddp(adam) => {
                let norm = match tp {
                    None => clip_grad_norm(grads, adam.cfg.grad_clip),
                    Some((comm, span)) => tp_clip(grads, adam.cfg.grad_clip, comm, span),
                };
                adam.step(params, grads, lr_scale);
                norm
            }
            DistOptimizer::Zero1(z) => z.step_reduced(group, rank, params, grads, lr_scale, tp),
        }
    }

    /// Bytes of optimizer state resident on this rank (memory invariant).
    pub fn state_bytes(&self) -> usize {
        match self {
            DistOptimizer::Ddp(a) => a.state_bytes(),
            DistOptimizer::Zero1(z) => z.adam.state_bytes(),
        }
    }

    /// Checkpoint this rank's optimizer state (full for DDP, shard-only
    /// under ZeRO-1 — DeepSpeed's per-rank layout).
    pub fn export_state(&self) -> (Vec<f32>, u64) {
        match self {
            DistOptimizer::Ddp(a) => a.export_state(),
            DistOptimizer::Zero1(z) => z.adam.export_state(),
        }
    }

    /// Restore state exported by [`DistOptimizer::export_state`].
    pub fn import_state(&mut self, data: &[f32], t: u64) {
        match self {
            DistOptimizer::Ddp(a) => a.import_state(data, t),
            DistOptimizer::Zero1(z) => z.adam.import_state(data, t),
        }
    }
}

/// The ZeRO-1 shard owner for one flat parameter buffer.
pub struct Zero1Optimizer {
    pub adam: Adam,
    pub dp_rank: usize,
    pub dp: usize,
    pub n_params: usize,
    /// Collective algorithm for the 1-float grad-norm combine.
    pub algo: Algo,
    /// Working-parameter dtype — also the updated-parameter all-gather
    /// wire dtype (bf16 params pack two-per-lane; lossless, since Adam
    /// just re-quantized them onto the grid).
    pub dtype: Dtype,
}

impl Zero1Optimizer {
    pub fn new(
        cfg: AdamConfig,
        n_params: usize,
        dp_rank: usize,
        dp: usize,
        algo: Algo,
        dtype: Dtype,
    ) -> Self {
        assert!(dp_rank < dp);
        let (lo, hi) = chunk_bounds(n_params, dp)[dp_rank];
        Self { adam: Adam::new_mixed(cfg, hi - lo, dtype), dp_rank, dp, n_params, algo, dtype }
    }

    pub fn shard_bounds(&self) -> (usize, usize) {
        chunk_bounds(self.n_params, self.dp)[self.dp_rank]
    }

    pub fn step(
        &mut self,
        group: &Arc<Group>,
        rank: usize,
        params: &mut [f32],
        grads: &mut [f32],
        lr_scale: f32,
        tp: TpCtx<'_>,
    ) -> f32 {
        assert_eq!(params.len(), self.n_params);
        assert_eq!(group.len(), self.dp);
        let dp = self.dp as f32;

        // reduce-scatter: my shard of the summed gradient
        let mut shard = group.reduce_scatter_sum(rank, grads);
        shard.iter_mut().for_each(|g| *g /= dp);
        self.clip_step_gather(group, rank, params, &mut shard, lr_scale, tp)
    }

    /// ZeRO-1 step over already-DP-mean-reduced gradients: slice my
    /// shard out of the full buffer (identical to the reduce-scatter
    /// result — rank-order sums are elementwise, so any sub-span of the
    /// bucketed all-reduce equals the scattered shard bit for bit).
    pub fn step_reduced(
        &mut self,
        group: &Arc<Group>,
        rank: usize,
        params: &mut [f32],
        grads: &mut [f32],
        lr_scale: f32,
        tp: TpCtx<'_>,
    ) -> f32 {
        assert_eq!(params.len(), self.n_params);
        assert_eq!(grads.len(), self.n_params);
        assert_eq!(group.len(), self.dp);
        let (slo, shi) = self.shard_bounds();
        self.clip_step_gather(group, rank, params, &mut grads[slo..shi], lr_scale, tp)
    }

    /// Shared tail of both entry points, from this rank's mean-reduced
    /// gradient shard onward: combine shard norms with a tiny all-reduce
    /// (1 float, like DeepSpeed) — first across DP shards, then (under
    /// TP) across the tensor group, discounting this DP shard's overlap
    /// with the replicated span so the cross-shard sum counts it once —
    /// clip, Adam this shard only, and all-gather the updated params.
    fn clip_step_gather(
        &mut self,
        group: &Arc<Group>,
        rank: usize,
        params: &mut [f32],
        shard: &mut [f32],
        lr_scale: f32,
        tp: TpCtx<'_>,
    ) -> f32 {
        let (slo, shi) = self.shard_bounds();
        assert_eq!(shard.len(), shi - slo);
        let local_sq: f32 = match tp {
            None => shard.iter().map(|&g| g * g).sum(),
            Some((comm, (rlo, rhi))) => {
                let lo = rlo.clamp(slo, shi) - slo;
                let hi = rhi.clamp(slo, shi) - slo;
                tp_partial_sq(shard, (lo, hi), comm.tp())
            }
        };
        let mut sq = vec![local_sq];
        group.all_reduce_sum(rank, &mut sq, self.algo);
        if let Some((comm, _)) = tp {
            comm.all_reduce_sum(&mut sq);
        }
        let norm = sq[0].max(0.0).sqrt();
        let clip = self.adam.cfg.grad_clip;
        if clip > 0.0 && norm > clip {
            let scale = clip / (norm + 1e-6);
            shard.iter_mut().for_each(|g| *g *= scale);
        }

        // Adam on my shard only (mixed precision: on the shard's fp32
        // masters, re-quantized into the working copy)
        self.adam.step(&mut params[slo..shi], shard, lr_scale);

        // all-gather the updated parameters at the working dtype (bf16
        // shards ride packed u16 lanes — half the wire bytes, counted by
        // the group's ag_payload_bytes; the RS+AG wire accounting's
        // second half)
        let my = params[slo..shi].to_vec();
        group.all_gather_dtype(rank, &my, params, self.dtype);
        norm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    /// Drive `steps` optimizer steps on `dp` ranks; rank-local grads are
    /// deterministic functions of (rank, step).  Returns rank 0's params.
    fn run(dp: usize, zero1: bool, steps: usize, n: usize) -> Vec<f32> {
        let group = Group::new(dp);
        let handles: Vec<_> = (0..dp)
            .map(|rank| {
                let g = group.clone();
                thread::spawn(move || {
                    let mut params: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).cos()).collect();
                    let mut opt =
                        DistOptimizer::new(zero1, AdamConfig::default(), n, rank, dp, Algo::Ring, Dtype::F32);
                    for step in 0..steps {
                        let mut grads: Vec<f32> = (0..n)
                            .map(|i| ((i + rank * 13 + step * 7) as f32 * 0.1).sin())
                            .collect();
                        opt.step(&g, rank, &mut params, &mut grads, 1.0, None);
                    }
                    params
                })
            })
            .collect();
        let mut results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // all ranks must agree exactly after the step
        for r in 1..results.len() {
            assert_eq!(results[0], results[r], "rank {r} params diverged");
        }
        results.swap_remove(0)
    }

    #[test]
    fn zero1_matches_ddp_trajectory() {
        // THE ZeRO-1 invariant: identical parameter trajectory to DDP
        let ddp = run(4, false, 5, 37);
        let z1 = run(4, true, 5, 37);
        for (a, b) in ddp.iter().zip(&z1) {
            assert!((a - b).abs() < 2e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn zero1_state_is_sharded() {
        let n = 100;
        let dp = 4;
        let z = Zero1Optimizer::new(AdamConfig::default(), n, 1, dp, Algo::Ring, Dtype::F32);
        assert_eq!(z.adam.len(), 25);
        // DDP holds full state
        let d = DistOptimizer::new(false, AdamConfig::default(), n, 0, dp, Algo::Ring, Dtype::F32);
        let z = DistOptimizer::new(true, AdamConfig::default(), n, 0, dp, Algo::Ring, Dtype::F32);
        assert_eq!(d.state_bytes(), 4 * z.state_bytes());
    }

    #[test]
    fn shard_bounds_cover_params() {
        let n = 103;
        let dp = 4;
        let mut covered = 0;
        for r in 0..dp {
            let z = Zero1Optimizer::new(AdamConfig::default(), n, r, dp, Algo::Ring, Dtype::F32);
            let (lo, hi) = z.shard_bounds();
            covered += hi - lo;
        }
        assert_eq!(covered, n);
    }

    #[test]
    fn tp_global_clip_norm_counts_replicated_once() {
        // two TP shards, dp = 1: the clip norm must be the norm of the
        // LOGICAL vector — each shard's private elements plus the
        // replicated span counted once — not the per-shard norms
        use crate::collectives::SubGroup;
        let world = Group::new(2);
        let sub = SubGroup::new(&world, vec![0, 1], 0);
        let handles: Vec<_> = (0..2usize)
            .map(|rank| {
                let sub = sub.clone();
                thread::spawn(move || {
                    let comm = TpComm::new(sub, rank);
                    let dp_group = Group::new(1);
                    let mut opt =
                        DistOptimizer::new(false, AdamConfig::default(), 4, 0, 1, Algo::Ring, Dtype::F32);
                    let mut params = vec![0.0f32; 4];
                    // unique elements differ per shard; [2..4) replicated
                    let mut grads = if rank == 0 {
                        vec![3.0, 0.0, 1.0, 2.0]
                    } else {
                        vec![0.0, 3.0, 1.0, 2.0]
                    };
                    opt.step(&dp_group, 0, &mut params, &mut grads, 1.0, Some((&comm, (2, 4))))
                })
            })
            .collect();
        // logical vector: [3, 0] ++ [0, 3] ++ [1, 2] -> |.|² = 23
        let want = 23.0f32.sqrt();
        for h in handles {
            let norm = h.join().unwrap();
            assert!((norm - want).abs() < 1e-4, "{norm} vs {want}");
        }
    }

    #[test]
    fn single_rank_zero1_is_plain_adam() {
        let z1 = run(1, true, 3, 16);
        let ddp = run(1, false, 3, 16);
        for (a, b) in z1.iter().zip(&ddp) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    /// Like [`run`] but through [`DistOptimizer::step_reduced`]: every
    /// rank is handed the already-mean-reduced gradient (rank-order sum
    /// / dp, what the engine's bucketed all-reduce drains).
    fn run_reduced(dp: usize, zero1: bool, steps: usize, n: usize) -> Vec<f32> {
        let group = Group::new(dp);
        let handles: Vec<_> = (0..dp)
            .map(|rank| {
                let g = group.clone();
                thread::spawn(move || {
                    let mut params: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).cos()).collect();
                    let mut opt =
                        DistOptimizer::new(zero1, AdamConfig::default(), n, rank, dp, Algo::Ring, Dtype::F32);
                    for step in 0..steps {
                        // rank-order mean over every rank's gradient
                        let mut grads = vec![0.0f32; n];
                        for r in 0..dp {
                            for (i, x) in grads.iter_mut().enumerate() {
                                *x += ((i + r * 13 + step * 7) as f32 * 0.1).sin();
                            }
                        }
                        grads.iter_mut().for_each(|x| *x /= dp as f32);
                        opt.step_reduced(&g, rank, &mut params, &mut grads, 1.0, None);
                    }
                    params
                })
            })
            .collect();
        let mut results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for r in 1..results.len() {
            assert_eq!(results[0], results[r], "rank {r} params diverged (reduced path)");
        }
        results.swap_remove(0)
    }

    #[test]
    fn step_reduced_matches_step_ddp_and_zero1() {
        // the overlapped-sync optimizer path must walk the same
        // trajectory as the classic sync-inside-step path (up to the
        // all-reduce association order, hence the small tolerance)
        for zero1 in [false, true] {
            let classic = run(4, zero1, 5, 37);
            let reduced = run_reduced(4, zero1, 5, 37);
            for (a, b) in classic.iter().zip(&reduced) {
                assert!((a - b).abs() < 2e-5, "zero1={zero1}: {a} vs {b}");
            }
        }
    }

    /// Like [`run`] but under the bf16 working dtype: params start on the
    /// bf16 grid, grads are bf16-quantized per-microbatch values.
    fn run_mixed(dp: usize, zero1: bool, steps: usize, n: usize) -> Vec<f32> {
        let group = Group::new(dp);
        let handles: Vec<_> = (0..dp)
            .map(|rank| {
                let g = group.clone();
                thread::spawn(move || {
                    let mut params: Vec<f32> =
                        (0..n).map(|i| Dtype::Bf16.quantize((i as f32 * 0.01).cos())).collect();
                    let mut opt = DistOptimizer::new(
                        zero1,
                        AdamConfig::default(),
                        n,
                        rank,
                        dp,
                        Algo::Ring,
                        Dtype::Bf16,
                    );
                    for step in 0..steps {
                        let mut grads: Vec<f32> = (0..n)
                            .map(|i| {
                                Dtype::Bf16
                                    .quantize(((i + rank * 13 + step * 7) as f32 * 0.1).sin())
                            })
                            .collect();
                        opt.step(&g, rank, &mut params, &mut grads, 1.0, None);
                    }
                    params
                })
            })
            .collect();
        let mut results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for r in 1..results.len() {
            assert_eq!(results[0], results[r], "rank {r} bf16 params diverged");
        }
        results.swap_remove(0)
    }

    #[test]
    fn bf16_zero1_matches_bf16_ddp_and_stays_on_grid() {
        // the ZeRO-1 ≡ DDP invariant survives mixed precision: sharded
        // masters + packed parameter all-gather walk the DDP trajectory
        // (up to the norm-combine association order, which the bf16 grid
        // can amplify to one quantum)
        let ddp = run_mixed(4, false, 5, 37);
        let z1 = run_mixed(4, true, 5, 37);
        for (i, (a, b)) in ddp.iter().zip(&z1).enumerate() {
            assert!((a - b).abs() <= 0.008 * a.abs().max(1.0), "param {i}: {a} vs {b}");
            assert_eq!(a.to_bits(), Dtype::Bf16.quantize(*a).to_bits(), "ddp[{i}] off grid");
            assert_eq!(b.to_bits(), Dtype::Bf16.quantize(*b).to_bits(), "z1[{i}] off grid");
        }
        // mixed-precision state accounting: masters add 4 bytes/param,
        // sharded 1/dp under ZeRO-1 (after one step materialises them)
        let z = Zero1Optimizer::new(AdamConfig::default(), 100, 0, 4, Algo::Ring, Dtype::Bf16);
        assert_eq!(z.adam.state_bytes(), 3 * 25 * 4);
    }

    #[test]
    fn step_reduced_zero1_shard_slice_equals_scatter() {
        // the ZeRO-1 reduced path slices its shard out of the full
        // buffer; single rank degenerates to plain Adam — and the shard
        // slice of a rank-order sum is bitwise the scattered shard
        let a = run_reduced(1, true, 3, 16);
        let b = run(1, false, 3, 16);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }
}
