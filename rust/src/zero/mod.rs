//! Staged sharded data parallelism (§II.D, grown into the full ZeRO
//! ladder).
//!
//! One [`ShardingStage`] contract covers the whole family:
//!
//! * **Stage 0 (DDP)** — everything replicated; gradients all-reduced.
//! * **Stage 1 (ZeRO-1)** — optimizer states (and the fp32 masters they
//!   act on) sharded `1/dp`; gradients reduce-scattered logically but
//!   every rank still materialises the full reduced buffer; updated
//!   parameters all-gathered after the step.
//! * **Stage 2 (ZeRO-2)** — gradients sharded for real: the engine's
//!   backward-overlapped buckets become **partition-aligned
//!   reduce-scatter** buckets, each rank redeeming only the buckets whose
//!   span it owns, so the reduced gradient a rank holds is its `1/dp`
//!   shard and nothing more.  Wire volume is unchanged from stage 1
//!   (RS in, AG of updated params out).
//! * **Stage 3 (ZeRO-3)** — the working parameters themselves sharded:
//!   each rank stores only its flat `1/dp` range of every stage's
//!   parameter vector and all-gathers the full vector **on demand**, one
//!   layer at a time, around each forward/backward use (prefetched one
//!   use ahead, dropped after use — peak full-parameter residency is
//!   per-layer, not per-model; see `coordinator::worker`).  No post-step
//!   parameter all-gather: updated shards stay sharded.
//!
//! The correctness invariant the whole ladder hangs on: **every stage
//! walks the DDP parameter trajectory bitwise at fp32**.  Rank-order
//! bucket reduction makes the reduce-scattered shard the exact slice of
//! the all-reduced buffer, Adam is elementwise, and the gradient-clip
//! norm is combined with one deterministic recipe shared by every stage
//! ([`shard_sq`] per DP-partition span, folded in rank order, then the
//! 1-float TP combine) — so stage 0 computes locally exactly what stages
//! 1–3 assemble over the wire.
//!
//! Two step entry points: [`DistOptimizer::step`] performs the gradient
//! sync itself (all-reduce / reduce-scatter), while
//! [`DistOptimizer::step_reduced`] consumes gradients the engine has
//! already mean-reduced — full-buffer under stages 0/1, shard-only under
//! stages 2/3.

use crate::collectives::{chunk_bounds, Algo, Group, TpComm};
use crate::optim::{Adam, AdamConfig};
use crate::precision::Dtype;
use std::sync::Arc;

/// Which training state is sharded `1/dp` across the data-parallel
/// group — the ZeRO stage ladder (each stage includes the previous).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum ShardingStage {
    /// Stage 0: plain DDP, everything replicated.
    #[default]
    Ddp,
    /// Stage 1: optimizer states (incl. fp32 masters) sharded.
    OptimizerStates,
    /// Stage 2: + reduced gradients sharded (true reduce-scatter
    /// dataflow).
    Gradients,
    /// Stage 3: + working parameters sharded (on-demand gather).
    Parameters,
}

impl ShardingStage {
    /// Parse a CLI / manifest spelling (`0`..`3`, or the ZeRO names).
    pub fn parse(s: &str) -> Option<ShardingStage> {
        match s {
            "0" | "ddp" => Some(ShardingStage::Ddp),
            "1" | "zero1" => Some(ShardingStage::OptimizerStates),
            "2" | "zero2" => Some(ShardingStage::Gradients),
            "3" | "zero3" => Some(ShardingStage::Parameters),
            _ => None,
        }
    }

    /// Numeric stage (0..=3) — the manifest / CLI encoding.
    pub fn index(self) -> u32 {
        match self {
            ShardingStage::Ddp => 0,
            ShardingStage::OptimizerStates => 1,
            ShardingStage::Gradients => 2,
            ShardingStage::Parameters => 3,
        }
    }

    /// Inverse of [`ShardingStage::index`].
    pub fn from_index(i: u32) -> Option<ShardingStage> {
        match i {
            0 => Some(ShardingStage::Ddp),
            1 => Some(ShardingStage::OptimizerStates),
            2 => Some(ShardingStage::Gradients),
            3 => Some(ShardingStage::Parameters),
            _ => None,
        }
    }

    /// Short name ("ddp" / "zero1" / "zero2" / "zero3").
    pub fn name(self) -> &'static str {
        match self {
            ShardingStage::Ddp => "ddp",
            ShardingStage::OptimizerStates => "zero1",
            ShardingStage::Gradients => "zero2",
            ShardingStage::Parameters => "zero3",
        }
    }

    /// Optimizer states (and fp32 masters) live sharded (stages 1+).
    pub fn shards_optimizer(self) -> bool {
        self >= ShardingStage::OptimizerStates
    }

    /// Reduced gradients live sharded (stages 2+): the DP sync is a
    /// partition-aligned reduce-scatter, not an all-reduce.
    pub fn shards_grads(self) -> bool {
        self >= ShardingStage::Gradients
    }

    /// Working parameters live sharded (stage 3).
    pub fn shards_params(self) -> bool {
        self == ShardingStage::Parameters
    }

    /// Can a checkpoint written at `self` resume at `other`?  Identical
    /// stages always; the 1 ↔ 2 pair reshards trivially (both keep the
    /// same `1/dp` optimizer-shard layout and full checkpointed params —
    /// only the runtime gradient dataflow differs).  Everything touching
    /// stage 0 or 3 changes the on-disk optimizer-state or parameter
    /// residency layout and is rejected.
    pub fn resume_compatible(self, other: ShardingStage) -> bool {
        use ShardingStage::*;
        self == other
            || matches!(
                (self, other),
                (OptimizerStates, Gradients) | (Gradients, OptimizerStates)
            )
    }
}

impl std::fmt::Display for ShardingStage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.index())
    }
}

/// Tensor-parallel context for the optimizer step: this shard's
/// communicator plus the span of TP-replicated parameters in its flat
/// buffer.  Gradient clipping then uses the norm over the whole TP
/// group's logical parameter vector (replicated span counted once) — the
/// dense-equivalent semantics the tp = 1/2/4 trajectory tests require.
pub type TpCtx<'a> = Option<(&'a TpComm, (usize, usize))>;

/// Squared-norm contribution of one DP-partition span to the global clip
/// norm, as the f32 every stage folds: f64-accumulated sum of squares
/// (with the TP-replicated overlap charged at `1/tp`, so the cross-shard
/// sum counts it once), rounded once to f32.  THE shared brick of the
/// deterministic norm recipe — stage 0 computes it locally per span,
/// stages 1–3 compute exactly the same value on the span's owner, so the
/// rank-order fold below is bitwise identical either way.
/// `replicated` is given in `grads` coordinates and may be empty.
fn shard_sq(grads: &[f32], replicated: (usize, usize), tp: usize) -> f32 {
    let full: f64 = grads.iter().map(|&g| (g as f64) * (g as f64)).sum();
    let (lo, hi) = replicated;
    let rep: f64 = grads[lo..hi].iter().map(|&g| (g as f64) * (g as f64)).sum();
    (full - rep * (1.0 - 1.0 / tp as f64)) as f32
}

/// [`shard_sq`] of the sub-span `[lo, hi)` of a full gradient buffer,
/// with the TP-replicated span clamped into it.
fn span_sq(grads: &[f32], lo: usize, hi: usize, tp: TpCtx<'_>) -> f32 {
    match tp {
        None => shard_sq(&grads[lo..hi], (0, 0), 1),
        Some((comm, (rlo, rhi))) => {
            let l = rlo.clamp(lo, hi) - lo;
            let h = rhi.clamp(lo, hi) - lo;
            shard_sq(&grads[lo..hi], (l, h), comm.tp())
        }
    }
}

/// Rank-order fold of every DP rank's [`shard_sq`] partial.  Sharded
/// ranks each hold one partial: slot-exchange it (every slot receives
/// exactly one non-zero contribution, so the collective is exact at any
/// association order) and fold the slots `0..dp` locally.
fn dp_combine_sq(group: &Arc<Group>, rank: usize, algo: Algo, partial: f32) -> f32 {
    let dp = group.len();
    if dp == 1 {
        return partial;
    }
    let mut slots = vec![0.0f32; dp];
    slots[rank] = partial;
    group.all_reduce_sum(rank, &mut slots, algo);
    // sequential left-to-right sum = the rank-order fold
    slots.iter().copied().sum()
}

/// Finish the norm from the DP-combined sum of squares: the 1-float TP
/// combine (replicated span already discounted per shard), then sqrt.
fn finish_norm(dp_sq: f32, tp: TpCtx<'_>) -> f32 {
    let mut sq = vec![dp_sq];
    if let Some((comm, _)) = tp {
        comm.all_reduce_sum(&mut sq);
    }
    sq[0].max(0.0).sqrt()
}

/// Clip `grads` in place against `clip` given the pre-computed `norm`;
/// the scale multiply is elementwise, so clipping a full buffer and
/// clipping its shards produce bitwise-identical elements.
fn apply_clip(grads: &mut [f32], clip: f32, norm: f32) {
    if clip > 0.0 && norm > clip {
        let scale = clip / (norm + 1e-6);
        grads.iter_mut().for_each(|g| *g *= scale);
    }
}

/// DDP clip: every rank holds the full (bit-identical) reduced gradient,
/// so the DP partials are computed locally — per DP-partition span, folded
/// in rank order — reproducing exactly what the sharded stages assemble
/// over the wire.  Returns the pre-clip norm.
fn ddp_clip(dp: usize, grads: &mut [f32], clip: f32, tp: TpCtx<'_>) -> f32 {
    let mut total = 0.0f32;
    for (lo, hi) in chunk_bounds(grads.len(), dp) {
        total += span_sq(grads, lo, hi, tp);
    }
    let norm = finish_norm(total, tp);
    apply_clip(grads, clip, norm);
    norm
}

/// How a DP rank synchronises gradients and steps the optimizer.
pub enum DistOptimizer {
    /// Replicated optimizer: all-reduce grads, every rank steps everything.
    Ddp(Adam),
    /// ZeRO stages 1–3: shard owner of one flat parameter range.
    Sharded(ShardedOptimizer),
}

impl DistOptimizer {
    /// `algo` selects the collective algorithm for the *small* syncs
    /// (the grad-norm slot exchange) — the engine threads its
    /// `EngineConfig::collective_algo` (default `Ring`) through here.
    /// `dtype` is the working-parameter dtype: `Bf16` keeps fp32 master
    /// weights inside Adam (full masters for DDP, shard-only masters
    /// under stages 1+ — the paper's 4-bytes/param master term divided by
    /// `dp`) and re-quantizes the working copy after every step; it is
    /// also the parameter all-gather wire dtype.
    pub fn new(
        stage: ShardingStage,
        cfg: AdamConfig,
        n_params: usize,
        dp_rank: usize,
        dp: usize,
        algo: Algo,
        dtype: Dtype,
    ) -> Self {
        match stage {
            ShardingStage::Ddp => DistOptimizer::Ddp(Adam::new_mixed(cfg, n_params, dtype)),
            _ => DistOptimizer::Sharded(ShardedOptimizer::new(
                stage, cfg, n_params, dp_rank, dp, algo, dtype,
            )),
        }
    }

    /// Synchronise `grads` across `group` (mean) and update `params`.
    /// `grads` is consumed as scratch (it holds the averaged gradient for
    /// Ddp, and is untouched past the shard for the sharded stages).
    /// With `tp` set, the clip norm is combined across the tensor-parallel
    /// group (replicated span counted once).
    pub fn step(
        &mut self,
        group: &Arc<Group>,
        rank: usize,
        params: &mut [f32],
        grads: &mut [f32],
        lr_scale: f32,
        tp: TpCtx<'_>,
    ) -> f32 {
        let dp = group.len() as f32;
        match self {
            DistOptimizer::Ddp(adam) => {
                group.all_reduce_sum(rank, grads, Algo::Ring);
                grads.iter_mut().for_each(|g| *g /= dp);
                let norm = ddp_clip(group.len(), grads, adam.cfg.grad_clip, tp);
                adam.step(params, grads, lr_scale);
                norm
            }
            DistOptimizer::Sharded(z) => z.step(group, rank, params, grads, lr_scale, tp),
        }
    }

    /// Optimizer step over gradients that are **already DP-mean-reduced**
    /// (the engine's overlapped sync drains into them before calling
    /// this).  Buffer shapes follow the stage: DDP/stage-1 take the full
    /// reduced buffer; stages 2/3 take this rank's reduce-scattered
    /// shard, and stage 3 additionally takes the sharded `params`.
    pub fn step_reduced(
        &mut self,
        group: &Arc<Group>,
        rank: usize,
        params: &mut [f32],
        grads: &mut [f32],
        lr_scale: f32,
        tp: TpCtx<'_>,
    ) -> f32 {
        let _s = crate::trace::span(crate::trace::Category::Optimizer, "step_reduced");
        match self {
            DistOptimizer::Ddp(adam) => {
                let norm = ddp_clip(group.len(), grads, adam.cfg.grad_clip, tp);
                adam.step(params, grads, lr_scale);
                norm
            }
            DistOptimizer::Sharded(z) => z.step_reduced(group, rank, params, grads, lr_scale, tp),
        }
    }

    /// Bytes of optimizer state resident on this rank (memory invariant).
    pub fn state_bytes(&self) -> usize {
        match self {
            DistOptimizer::Ddp(a) => a.state_bytes(),
            DistOptimizer::Sharded(z) => z.adam.state_bytes(),
        }
    }

    /// Checkpoint this rank's optimizer state (full for DDP, shard-only
    /// under stages 1+ — DeepSpeed's per-rank layout, identical across
    /// stages 1–3, which is what makes 1 ↔ 2 resumes trivial).
    pub fn export_state(&self) -> (Vec<f32>, u64) {
        match self {
            DistOptimizer::Ddp(a) => a.export_state(),
            DistOptimizer::Sharded(z) => z.adam.export_state(),
        }
    }

    /// Restore state exported by [`DistOptimizer::export_state`].
    pub fn import_state(&mut self, data: &[f32], t: u64) {
        match self {
            DistOptimizer::Ddp(a) => a.import_state(data, t),
            DistOptimizer::Sharded(z) => z.adam.import_state(data, t),
        }
    }
}

/// The stage-1/2/3 shard owner for one flat parameter buffer.
pub struct ShardedOptimizer {
    pub adam: Adam,
    /// Which state lives sharded (never [`ShardingStage::Ddp`]).
    pub stage: ShardingStage,
    pub dp_rank: usize,
    pub dp: usize,
    /// FULL (unsharded) parameter count of the buffer this optimizer
    /// owns a shard of.
    pub n_params: usize,
    /// Collective algorithm for the grad-norm slot exchange.
    pub algo: Algo,
    /// Working-parameter dtype — also the updated-parameter all-gather
    /// wire dtype (bf16 params pack two-per-lane; lossless, since Adam
    /// just re-quantized them onto the grid).
    pub dtype: Dtype,
}

impl ShardedOptimizer {
    pub fn new(
        stage: ShardingStage,
        cfg: AdamConfig,
        n_params: usize,
        dp_rank: usize,
        dp: usize,
        algo: Algo,
        dtype: Dtype,
    ) -> Self {
        assert!(dp_rank < dp);
        assert!(stage.shards_optimizer(), "sharded optimizer needs stage >= 1");
        let (lo, hi) = chunk_bounds(n_params, dp)[dp_rank];
        Self {
            adam: Adam::new_mixed(cfg, hi - lo, dtype),
            stage,
            dp_rank,
            dp,
            n_params,
            algo,
            dtype,
        }
    }

    /// This rank's flat parameter range `[lo, hi)` of the full buffer.
    pub fn shard_bounds(&self) -> (usize, usize) {
        chunk_bounds(self.n_params, self.dp)[self.dp_rank]
    }

    /// Classic entry point: `grads` holds the rank-local (unreduced)
    /// gradient; reduce-scatter my shard, mean, then the shared tail.
    pub fn step(
        &mut self,
        group: &Arc<Group>,
        rank: usize,
        params: &mut [f32],
        grads: &mut [f32],
        lr_scale: f32,
        tp: TpCtx<'_>,
    ) -> f32 {
        assert_eq!(grads.len(), self.n_params);
        assert_eq!(group.len(), self.dp);
        let dp = self.dp as f32;

        // reduce-scatter: my shard of the summed gradient
        let mut shard = group.reduce_scatter_sum(rank, grads);
        shard.iter_mut().for_each(|g| *g /= dp);
        let (slo, shi) = self.shard_bounds();
        if self.stage.shards_params() {
            assert_eq!(params.len(), shi - slo, "stage-3 step takes sharded params");
            self.clip_step(group, rank, params, &mut shard, lr_scale, tp)
        } else {
            assert_eq!(params.len(), self.n_params);
            let norm =
                self.clip_step(group, rank, &mut params[slo..shi], &mut shard, lr_scale, tp);
            self.gather_params(group, rank, params);
            norm
        }
    }

    /// Step over already-DP-mean-reduced gradients.  Stage 1 receives the
    /// full reduced buffer and slices its shard (any sub-span of the
    /// rank-order bucketed all-reduce equals the scattered shard bit for
    /// bit); stages 2/3 receive the reduce-scattered shard directly —
    /// the rank never materialised anything more.
    pub fn step_reduced(
        &mut self,
        group: &Arc<Group>,
        rank: usize,
        params: &mut [f32],
        grads: &mut [f32],
        lr_scale: f32,
        tp: TpCtx<'_>,
    ) -> f32 {
        assert_eq!(group.len(), self.dp);
        let (slo, shi) = self.shard_bounds();
        match self.stage {
            ShardingStage::OptimizerStates => {
                assert_eq!(params.len(), self.n_params);
                assert_eq!(grads.len(), self.n_params);
                // split disjoint slices of two distinct buffers
                let norm = self.clip_step(
                    group,
                    rank,
                    &mut params[slo..shi],
                    &mut grads[slo..shi],
                    lr_scale,
                    tp,
                );
                self.gather_params(group, rank, params);
                norm
            }
            ShardingStage::Gradients => {
                assert_eq!(params.len(), self.n_params);
                assert_eq!(grads.len(), shi - slo, "stage-2 step takes the grad shard");
                let norm =
                    self.clip_step(group, rank, &mut params[slo..shi], grads, lr_scale, tp);
                self.gather_params(group, rank, params);
                norm
            }
            ShardingStage::Parameters => {
                assert_eq!(params.len(), shi - slo, "stage-3 step takes sharded params");
                assert_eq!(grads.len(), shi - slo, "stage-3 step takes the grad shard");
                self.clip_step(group, rank, params, grads, lr_scale, tp)
            }
            ShardingStage::Ddp => unreachable!("stage 0 is DistOptimizer::Ddp"),
        }
    }

    /// Shared tail of every entry point, from this rank's mean-reduced
    /// gradient shard onward: the deterministic norm recipe ([`shard_sq`]
    /// partial, slot-exchanged and folded in rank order, 1-float TP
    /// combine), clip, Adam this shard only.  `param_shard` is this
    /// rank's parameter range (a slice of the full buffer under stages
    /// 1/2, the whole sharded vector under stage 3).
    fn clip_step(
        &mut self,
        group: &Arc<Group>,
        rank: usize,
        param_shard: &mut [f32],
        shard: &mut [f32],
        lr_scale: f32,
        tp: TpCtx<'_>,
    ) -> f32 {
        let (slo, shi) = self.shard_bounds();
        assert_eq!(shard.len(), shi - slo);
        assert_eq!(param_shard.len(), shi - slo);
        let partial = match tp {
            None => shard_sq(shard, (0, 0), 1),
            Some((comm, (rlo, rhi))) => {
                let lo = rlo.clamp(slo, shi) - slo;
                let hi = rhi.clamp(slo, shi) - slo;
                shard_sq(shard, (lo, hi), comm.tp())
            }
        };
        let dp_sq = dp_combine_sq(group, rank, self.algo, partial);
        let norm = finish_norm(dp_sq, tp);
        apply_clip(shard, self.adam.cfg.grad_clip, norm);

        // Adam on my shard only (mixed precision: on the shard's fp32
        // masters, re-quantized into the working copy)
        self.adam.step(param_shard, shard, lr_scale);
        norm
    }

    /// All-gather the updated parameters at the working dtype (stages
    /// 1/2; bf16 shards ride packed u16 lanes — half the wire bytes,
    /// counted by the group's `ag_payload_bytes`).  Stage 3 never calls
    /// this: its parameters stay sharded and are gathered on demand
    /// around each use instead.
    fn gather_params(&self, group: &Arc<Group>, rank: usize, params: &mut [f32]) {
        let (slo, shi) = self.shard_bounds();
        let my = params[slo..shi].to_vec();
        group.all_gather_dtype(rank, &my, params, self.dtype);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    /// Drive `steps` optimizer steps on `dp` ranks; rank-local grads are
    /// deterministic functions of (rank, step).  Returns rank 0's FULL
    /// parameter vector (stage 3 ranks gather their shards for the
    /// comparison).
    fn run(dp: usize, stage: ShardingStage, steps: usize, n: usize) -> Vec<f32> {
        let group = Group::new(dp);
        let handles: Vec<_> = (0..dp)
            .map(|rank| {
                let g = group.clone();
                thread::spawn(move || {
                    let full: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).cos()).collect();
                    let mut params = if stage.shards_params() {
                        let (lo, hi) = chunk_bounds(n, dp)[rank];
                        full[lo..hi].to_vec()
                    } else {
                        full
                    };
                    let mut opt = DistOptimizer::new(
                        stage,
                        AdamConfig::default(),
                        n,
                        rank,
                        dp,
                        Algo::Ring,
                        Dtype::F32,
                    );
                    for step in 0..steps {
                        let mut grads: Vec<f32> = (0..n)
                            .map(|i| ((i + rank * 13 + step * 7) as f32 * 0.1).sin())
                            .collect();
                        opt.step(&g, rank, &mut params, &mut grads, 1.0, None);
                    }
                    if stage.shards_params() {
                        // assemble the full vector for cross-stage checks
                        let mut out = vec![0.0f32; n];
                        g.all_gather(rank, &params, &mut out);
                        out
                    } else {
                        params
                    }
                })
            })
            .collect();
        let mut results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // all ranks must agree exactly after the step
        for r in 1..results.len() {
            assert_eq!(results[0], results[r], "rank {r} params diverged");
        }
        results.swap_remove(0)
    }

    #[test]
    fn stage_ladder_parses_and_orders() {
        assert_eq!(ShardingStage::parse("0"), Some(ShardingStage::Ddp));
        assert_eq!(ShardingStage::parse("zero2"), Some(ShardingStage::Gradients));
        assert_eq!(ShardingStage::parse("4"), None);
        for i in 0..4 {
            let s = ShardingStage::from_index(i).unwrap();
            assert_eq!(s.index(), i);
            assert_eq!(ShardingStage::parse(s.name()), Some(s));
            assert_eq!(format!("{s}"), i.to_string());
        }
        assert!(ShardingStage::from_index(4).is_none());
        // each stage includes the previous
        assert!(!ShardingStage::Ddp.shards_optimizer());
        assert!(ShardingStage::OptimizerStates.shards_optimizer());
        assert!(!ShardingStage::OptimizerStates.shards_grads());
        assert!(ShardingStage::Gradients.shards_optimizer());
        assert!(ShardingStage::Gradients.shards_grads());
        assert!(!ShardingStage::Gradients.shards_params());
        assert!(ShardingStage::Parameters.shards_grads());
        assert!(ShardingStage::Parameters.shards_params());
    }

    #[test]
    fn resume_compat_is_identity_plus_the_1_2_pair() {
        use ShardingStage::*;
        for s in [Ddp, OptimizerStates, Gradients, Parameters] {
            assert!(s.resume_compatible(s));
        }
        assert!(OptimizerStates.resume_compatible(Gradients));
        assert!(Gradients.resume_compatible(OptimizerStates));
        assert!(!Ddp.resume_compatible(OptimizerStates));
        assert!(!OptimizerStates.resume_compatible(Ddp));
        assert!(!Parameters.resume_compatible(Gradients));
        assert!(!Gradients.resume_compatible(Parameters));
        assert!(!Parameters.resume_compatible(Ddp));
    }

    #[test]
    fn every_stage_matches_ddp_trajectory() {
        // the ladder invariant on the classic path: the sharded stages
        // share one rank-order reduce-scatter dataflow, so they agree
        // BIT FOR BIT among themselves; classic DDP reduces through the
        // ring (different fp association), so it is tracked within
        // tolerance.  The engine's step_reduced path is bitwise across
        // ALL stages — see step_reduced_matches_ddp_bitwise_across_stages.
        let ddp = run(4, ShardingStage::Ddp, 5, 37);
        let z1 = run(4, ShardingStage::OptimizerStates, 5, 37);
        let z2 = run(4, ShardingStage::Gradients, 5, 37);
        let z3 = run(4, ShardingStage::Parameters, 5, 37);
        assert_eq!(z1, z2, "stage 1 vs 2 must be bitwise");
        assert_eq!(z1, z3, "stage 1 vs 3 must be bitwise");
        for (a, b) in ddp.iter().zip(&z1) {
            assert!((a - b).abs() < 2e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn zero1_state_is_sharded() {
        let n = 100;
        let dp = 4;
        let z = ShardedOptimizer::new(
            ShardingStage::OptimizerStates,
            AdamConfig::default(),
            n,
            1,
            dp,
            Algo::Ring,
            Dtype::F32,
        );
        assert_eq!(z.adam.len(), 25);
        // DDP holds full state
        let d = DistOptimizer::new(
            ShardingStage::Ddp,
            AdamConfig::default(),
            n,
            0,
            dp,
            Algo::Ring,
            Dtype::F32,
        );
        for stage in [
            ShardingStage::OptimizerStates,
            ShardingStage::Gradients,
            ShardingStage::Parameters,
        ] {
            let z =
                DistOptimizer::new(stage, AdamConfig::default(), n, 0, dp, Algo::Ring, Dtype::F32);
            assert_eq!(d.state_bytes(), 4 * z.state_bytes(), "stage {stage}");
        }
    }

    #[test]
    fn shard_bounds_cover_params() {
        let n = 103;
        let dp = 4;
        let mut covered = 0;
        for r in 0..dp {
            let z = ShardedOptimizer::new(
                ShardingStage::Gradients,
                AdamConfig::default(),
                n,
                r,
                dp,
                Algo::Ring,
                Dtype::F32,
            );
            let (lo, hi) = z.shard_bounds();
            covered += hi - lo;
        }
        assert_eq!(covered, n);
    }

    #[test]
    fn tp_global_clip_norm_counts_replicated_once() {
        // two TP shards, dp = 1: the clip norm must be the norm of the
        // LOGICAL vector — each shard's private elements plus the
        // replicated span counted once — not the per-shard norms
        use crate::collectives::SubGroup;
        let world = Group::new(2);
        let sub = SubGroup::new(&world, vec![0, 1], 0);
        let handles: Vec<_> = (0..2usize)
            .map(|rank| {
                let sub = sub.clone();
                thread::spawn(move || {
                    let comm = TpComm::new(sub, rank);
                    let dp_group = Group::new(1);
                    let mut opt = DistOptimizer::new(
                        ShardingStage::Ddp,
                        AdamConfig::default(),
                        4,
                        0,
                        1,
                        Algo::Ring,
                        Dtype::F32,
                    );
                    let mut params = vec![0.0f32; 4];
                    // unique elements differ per shard; [2..4) replicated
                    let mut grads = if rank == 0 {
                        vec![3.0, 0.0, 1.0, 2.0]
                    } else {
                        vec![0.0, 3.0, 1.0, 2.0]
                    };
                    opt.step(&dp_group, 0, &mut params, &mut grads, 1.0, Some((&comm, (2, 4))))
                })
            })
            .collect();
        // logical vector: [3, 0] ++ [0, 3] ++ [1, 2] -> |.|² = 23
        let want = 23.0f32.sqrt();
        for h in handles {
            let norm = h.join().unwrap();
            assert!((norm - want).abs() < 1e-4, "{norm} vs {want}");
        }
    }

    #[test]
    fn single_rank_sharded_is_plain_adam() {
        let ddp = run(1, ShardingStage::Ddp, 3, 16);
        for stage in [
            ShardingStage::OptimizerStates,
            ShardingStage::Gradients,
            ShardingStage::Parameters,
        ] {
            let z = run(1, stage, 3, 16);
            assert_eq!(z, ddp, "stage {stage} at dp=1 must be plain Adam");
        }
    }

    /// Like [`run`] but through [`DistOptimizer::step_reduced`]: every
    /// rank is handed the already-mean-reduced gradient (rank-order sum
    /// / dp, what the engine's bucketed sync drains) — the full buffer
    /// for stages 0/1, the partition shard for stages 2/3.
    fn run_reduced(dp: usize, stage: ShardingStage, steps: usize, n: usize) -> Vec<f32> {
        let group = Group::new(dp);
        let handles: Vec<_> = (0..dp)
            .map(|rank| {
                let g = group.clone();
                thread::spawn(move || {
                    let full: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).cos()).collect();
                    let (lo, hi) = chunk_bounds(n, dp)[rank];
                    let mut params = if stage.shards_params() {
                        full[lo..hi].to_vec()
                    } else {
                        full
                    };
                    let mut opt = DistOptimizer::new(
                        stage,
                        AdamConfig::default(),
                        n,
                        rank,
                        dp,
                        Algo::Ring,
                        Dtype::F32,
                    );
                    for step in 0..steps {
                        // rank-order mean over every rank's gradient
                        let mut grads = vec![0.0f32; n];
                        for r in 0..dp {
                            for (i, x) in grads.iter_mut().enumerate() {
                                *x += ((i + r * 13 + step * 7) as f32 * 0.1).sin();
                            }
                        }
                        grads.iter_mut().for_each(|x| *x /= dp as f32);
                        let mut buf = if stage.shards_grads() && dp > 1 {
                            grads[lo..hi].to_vec()
                        } else {
                            grads
                        };
                        opt.step_reduced(&g, rank, &mut params, &mut buf, 1.0, None);
                    }
                    if stage.shards_params() {
                        let mut out = vec![0.0f32; n];
                        g.all_gather(rank, &params, &mut out);
                        out
                    } else {
                        params
                    }
                })
            })
            .collect();
        let mut results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for r in 1..results.len() {
            assert_eq!(results[0], results[r], "rank {r} params diverged (reduced path)");
        }
        results.swap_remove(0)
    }

    #[test]
    fn step_reduced_matches_ddp_bitwise_across_stages() {
        // the overlapped-sync optimizer path: full-buffer DDP vs sharded
        // grads (2/3) vs sharded params (3) — all bitwise equal, since
        // the reduced inputs are elementwise identical and the norm
        // recipe is shared
        let ddp = run_reduced(4, ShardingStage::Ddp, 5, 37);
        for stage in [
            ShardingStage::OptimizerStates,
            ShardingStage::Gradients,
            ShardingStage::Parameters,
        ] {
            let z = run_reduced(4, stage, 5, 37);
            assert_eq!(ddp, z, "stage {stage} reduced path diverged");
        }
    }

    #[test]
    fn step_reduced_matches_step_classic() {
        // the classic sync-inside-step path must walk the same trajectory
        // as the reduced path (up to the all-reduce association order of
        // the classic DDP ring, hence the small tolerance)
        for stage in [ShardingStage::Ddp, ShardingStage::OptimizerStates] {
            let classic = run(4, stage, 5, 37);
            let reduced = run_reduced(4, stage, 5, 37);
            for (a, b) in classic.iter().zip(&reduced) {
                assert!((a - b).abs() < 2e-5, "stage {stage}: {a} vs {b}");
            }
        }
    }

    /// Like [`run`] but under the bf16 working dtype: params start on the
    /// bf16 grid, grads are bf16-quantized per-microbatch values.
    fn run_mixed(dp: usize, stage: ShardingStage, steps: usize, n: usize) -> Vec<f32> {
        let group = Group::new(dp);
        let handles: Vec<_> = (0..dp)
            .map(|rank| {
                let g = group.clone();
                thread::spawn(move || {
                    let full: Vec<f32> =
                        (0..n).map(|i| Dtype::Bf16.quantize((i as f32 * 0.01).cos())).collect();
                    let mut params = if stage.shards_params() {
                        let (lo, hi) = chunk_bounds(n, dp)[rank];
                        full[lo..hi].to_vec()
                    } else {
                        full
                    };
                    let mut opt = DistOptimizer::new(
                        stage,
                        AdamConfig::default(),
                        n,
                        rank,
                        dp,
                        Algo::Ring,
                        Dtype::Bf16,
                    );
                    for step in 0..steps {
                        let mut grads: Vec<f32> = (0..n)
                            .map(|i| {
                                Dtype::Bf16
                                    .quantize(((i + rank * 13 + step * 7) as f32 * 0.1).sin())
                            })
                            .collect();
                        opt.step(&g, rank, &mut params, &mut grads, 1.0, None);
                    }
                    if stage.shards_params() {
                        let mut out = vec![0.0f32; n];
                        g.all_gather(rank, &params, &mut out);
                        out
                    } else {
                        params
                    }
                })
            })
            .collect();
        let mut results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for r in 1..results.len() {
            assert_eq!(results[0], results[r], "rank {r} bf16 params diverged");
        }
        results.swap_remove(0)
    }

    #[test]
    fn bf16_stages_match_bf16_ddp_and_stay_on_grid() {
        // the ladder invariant survives mixed precision: sharded masters
        // + packed parameter all-gathers keep the sharded stages bitwise
        // identical among themselves (rank-order dataflow, lossless
        // packed gathers of grid values) and tracking bf16 DDP within a
        // quantum (the classic DDP ring's association order differs)
        let ddp = run_mixed(4, ShardingStage::Ddp, 5, 37);
        let z1 = run_mixed(4, ShardingStage::OptimizerStates, 5, 37);
        let z2 = run_mixed(4, ShardingStage::Gradients, 5, 37);
        let z3 = run_mixed(4, ShardingStage::Parameters, 5, 37);
        assert_eq!(z1, z2, "bf16 stage 1 vs 2 must be bitwise");
        assert_eq!(z1, z3, "bf16 stage 1 vs 3 must be bitwise");
        for (i, (a, b)) in ddp.iter().zip(&z1).enumerate() {
            assert!((a - b).abs() <= 0.008 * a.abs().max(1.0), "param {i}: {a} vs {b}");
            assert_eq!(b.to_bits(), Dtype::Bf16.quantize(*b).to_bits(), "z1[{i}] off grid");
        }
        for (i, a) in ddp.iter().enumerate() {
            assert_eq!(a.to_bits(), Dtype::Bf16.quantize(*a).to_bits(), "param {i} off grid");
        }
        // mixed-precision state accounting: masters add 4 bytes/param,
        // sharded 1/dp (after one step materialises them)
        let z = ShardedOptimizer::new(
            ShardingStage::OptimizerStates,
            AdamConfig::default(),
            100,
            0,
            4,
            Algo::Ring,
            Dtype::Bf16,
        );
        assert_eq!(z.adam.state_bytes(), 3 * 25 * 4);
    }

    #[test]
    fn step_reduced_shard_slice_equals_scatter() {
        // single rank degenerates to plain Adam on every stage, and the
        // shard slice of a rank-order sum is bitwise the scattered shard
        let a = run_reduced(1, ShardingStage::Gradients, 3, 16);
        let b = run(1, ShardingStage::Ddp, 3, 16);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }
}
