//! Mixed-precision training subsystem: software-emulated bf16 storage /
//! compute with fp32 master weights and dynamic loss scaling (the paper's
//! §II.A/§IV assumption the rest of the repro now executes for real).
//!
//! The whole engine keeps moving `f32` buffers; "bf16 storage" means the
//! stored values are constrained to the bf16 grid by [`Dtype::quantize`]
//! (deterministic IEEE round-to-nearest-even truncation of the f32 to its
//! top 16 bits).  That emulation is *exact* in a useful way: the product
//! of two bf16 values (8-bit significands) fits in an f32 significand, so
//! running the f32 GEMM kernels over pre-quantized inputs IS a
//! bf16-in/f32-accumulate GEMM, bit for bit (`runtime::kernels::bf16`).
//!
//! The wire side is real, not emulated: [`pack_bf16`] / [`unpack_bf16`]
//! carry two bf16 values per `f32` lane (bit-exact u16 pack/unpack via
//! `f32::to_bits`/`from_bits`, never arithmetic on packed lanes), so the
//! collectives' bf16 payloads genuinely move half the bytes — the
//! half-width wire contract the dtype-aware `perf` comm terms are pinned
//! against.
//!
//! [`CastPolicy`] names the cast points the builtin stages apply
//! (parameter storage, activation storage, gradient storage, collective
//! wire), and [`LossScaler`] is the DeepSpeed-style dynamic loss scaler
//! the worker loop drives (overflow → skip step + halve; a run of clean
//! steps → double).  Scales are kept to powers of two, so scaling and
//! unscaling are bitwise-exact and a bf16 run with any non-overflowing
//! scale walks the identical trajectory to scale 1.0 (tested in
//! `tests/precision.rs`).

/// Element dtype of a stored buffer or collective payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Dtype {
    /// IEEE binary32 — the engine's native element type.
    #[default]
    F32,
    /// bfloat16: f32's exponent range, 8-bit significand.  Emulated as
    /// grid-constrained f32 in storage; packed two-per-lane on the wire.
    Bf16,
}

impl Dtype {
    /// Bytes per element on the wire / in the memory accounting.
    pub fn bytes(&self) -> u64 {
        match self {
            Dtype::F32 => 4,
            Dtype::Bf16 => 2,
        }
    }

    /// CLI / manifest name.
    pub fn name(&self) -> &'static str {
        match self {
            Dtype::F32 => "fp32",
            Dtype::Bf16 => "bf16",
        }
    }

    /// Parse a CLI / manifest name.
    pub fn parse(s: &str) -> Option<Dtype> {
        match s {
            "fp32" | "f32" => Some(Dtype::F32),
            "bf16" => Some(Dtype::Bf16),
            _ => None,
        }
    }

    /// Constrain one value to this dtype's grid (identity for f32;
    /// round-to-nearest-even bf16 truncation otherwise).  Idempotent and
    /// monotone (property-tested).
    pub fn quantize(&self, x: f32) -> f32 {
        match self {
            Dtype::F32 => x,
            Dtype::Bf16 => bf16_to_f32(f32_to_bf16(x)),
        }
    }

    /// In-place [`Dtype::quantize`] over a slice.  The f32 case is a
    /// no-op (no float ops touched), keeping fp32 paths bitwise-unchanged.
    pub fn quantize_slice(&self, xs: &mut [f32]) {
        if let Dtype::Bf16 = self {
            for x in xs.iter_mut() {
                *x = bf16_to_f32(f32_to_bf16(*x));
            }
        }
    }

    /// Quantized copy of a slice.
    pub fn quantized(&self, xs: &[f32]) -> Vec<f32> {
        let mut out = xs.to_vec();
        self.quantize_slice(&mut out);
        out
    }
}

/// f32 -> bf16 with IEEE round-to-nearest-even (the hardware conversion
/// MI250X/DeepSpeed perform).  NaNs are quietened but keep their payload
/// top bits; infinities and signed zeros pass through exactly; values
/// whose rounded magnitude exceeds the (shared) exponent range round to
/// infinity, exactly like the hardware.
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // truncation alone could turn a NaN into an infinity; force a
        // quiet NaN with the surviving payload bits
        return ((bits >> 16) as u16) | 0x0040;
    }
    // round to nearest even on the 16 dropped bits: add 0x7FFF plus the
    // keep-lsb, then truncate (carries ripple into the exponent, which is
    // exactly what RNE overflow to the next binade / infinity requires)
    let lsb = (bits >> 16) & 1;
    ((bits.wrapping_add(0x7FFF + lsb)) >> 16) as u16
}

/// bf16 -> f32: exact (bf16 is f32's top half).
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Pack a slice as bf16 pairs: two quantized u16 lanes per f32 bit
/// pattern (low half = even index), `ceil(n/2)` lanes total, odd tails
/// padded with a +0.0 half.  The packed lanes are opaque bit patterns —
/// they are moved (memcpy'd) through mailboxes, never used as numbers —
/// and `f32::from_bits`/`to_bits` are guaranteed lossless.
pub fn pack_bf16(xs: &[f32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(xs.len().div_ceil(2));
    let mut i = 0;
    while i < xs.len() {
        let lo = f32_to_bf16(xs[i]) as u32;
        let hi = if i + 1 < xs.len() { f32_to_bf16(xs[i + 1]) as u32 } else { 0 };
        out.push(f32::from_bits(lo | (hi << 16)));
        i += 2;
    }
    out
}

/// Unpack `n` bf16 values from [`pack_bf16`] lanes (drops the pad half).
pub fn unpack_bf16(packed: &[f32], n: usize) -> Vec<f32> {
    assert_eq!(packed.len(), n.div_ceil(2), "packed length mismatch for {n} values");
    let mut out = Vec::with_capacity(n);
    for (i, p) in packed.iter().enumerate() {
        let bits = p.to_bits();
        out.push(bf16_to_f32((bits & 0xFFFF) as u16));
        if 2 * i + 1 < n {
            out.push(bf16_to_f32((bits >> 16) as u16));
        }
    }
    out
}

/// Where the builtin stages cast: one dtype per storage/wire class.
/// `fp32()` is the identity policy (every cast a no-op — the legacy
/// bitwise-pinned path); `bf16()` is the paper's mixed-precision regime:
/// 2-byte parameters, activations and gradients with f32 accumulation,
/// fp32 master weights living in the optimizer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CastPolicy {
    /// Stored parameter dtype (the working copy the kernels read).
    pub param: Dtype,
    /// Stored activation dtype (stage outputs, stashed inputs, the
    /// gradient activations flowing backward).
    pub activation: Dtype,
    /// Stored parameter-gradient dtype (per-micro-batch stage grads;
    /// accumulation across micro-batches stays f32).
    pub grad: Dtype,
    /// Collective payload dtype (TP all-reduces, DP grad buckets,
    /// ZeRO-1 parameter all-gather).
    pub wire: Dtype,
}

impl CastPolicy {
    pub const fn fp32() -> Self {
        Self { param: Dtype::F32, activation: Dtype::F32, grad: Dtype::F32, wire: Dtype::F32 }
    }

    pub const fn bf16() -> Self {
        Self { param: Dtype::Bf16, activation: Dtype::Bf16, grad: Dtype::Bf16, wire: Dtype::Bf16 }
    }

    /// The uniform policy for an engine precision setting.
    pub fn for_dtype(dt: Dtype) -> Self {
        match dt {
            Dtype::F32 => Self::fp32(),
            Dtype::Bf16 => Self::bf16(),
        }
    }

    pub fn is_fp32(&self) -> bool {
        *self == Self::fp32()
    }
}

// ---------------------------------------------------------------------------
// The quantized gradient wire (ZeRO++-style blockwise int8), used by the
// hierarchical collectives' inter-node phase only.
// ---------------------------------------------------------------------------

/// Block length of the int8 gradient wire: one f32 scale per
/// `INT8_BLOCK` values (the ZeRO++ qgZ granularity).
pub const INT8_BLOCK: usize = 128;

/// Wire format of the gradient reduction's **inter-node** phase.  The
/// intra-node phases always move the storage dtype (the cheap fabric
/// doesn't need compression); only the Slingshot hop — the Fig-5
/// bottleneck — gets the optional narrower encoding.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GradWire {
    /// Full-width f32 payload: 4 bytes/value.
    #[default]
    F32,
    /// Packed bf16 payload: 2 bytes/value.
    Bf16,
    /// Blockwise-scaled int8: 1 byte/value plus one f32 scale per
    /// [`INT8_BLOCK`] values (`n + 4·ceil(n/128)` bytes total).
    Int8,
}

impl GradWire {
    /// CLI / manifest name.
    pub fn name(&self) -> &'static str {
        match self {
            GradWire::F32 => "fp32",
            GradWire::Bf16 => "bf16",
            GradWire::Int8 => "int8",
        }
    }

    /// Parse a CLI / manifest name.
    pub fn parse(s: &str) -> Option<GradWire> {
        match s {
            "fp32" | "f32" => Some(GradWire::F32),
            "bf16" => Some(GradWire::Bf16),
            "int8" => Some(GradWire::Int8),
            _ => None,
        }
    }

    /// The wire matching a storage dtype exactly (the default when no
    /// `--grad-wire` override is given): fp32 storage keeps an fp32 wire,
    /// bf16 storage a bf16 wire.
    pub fn for_dtype(dt: Dtype) -> GradWire {
        match dt {
            Dtype::F32 => GradWire::F32,
            Dtype::Bf16 => GradWire::Bf16,
        }
    }

    /// Bytes a payload of `n` values occupies on this wire.
    pub fn payload_bytes(&self, n: u64) -> u64 {
        match self {
            GradWire::F32 => 4 * n,
            GradWire::Bf16 => 2 * n,
            GradWire::Int8 => n + 4 * n.div_ceil(INT8_BLOCK as u64),
        }
    }

    /// Does sending values already on `storage`'s grid over this wire
    /// re-quantize them?  When `false`, the hierarchical inter-node hop
    /// is value-preserving and the two-tier reduction can keep the flat
    /// rank-order fold bit for bit.
    pub fn requantizes_over(&self, storage: Dtype) -> bool {
        match self {
            GradWire::F32 => false,
            GradWire::Bf16 => storage == Dtype::F32,
            GradWire::Int8 => true,
        }
    }

    /// In-place wire round-trip (encode + decode): identity for f32, the
    /// bf16 grid for bf16, blockwise int8 quantize→dequantize for int8.
    /// This is what a value experiences crossing the inter-node hop.
    pub fn roundtrip_slice(&self, xs: &mut [f32]) {
        match self {
            GradWire::F32 => {}
            GradWire::Bf16 => Dtype::Bf16.quantize_slice(xs),
            GradWire::Int8 => int8_roundtrip_slice(xs),
        }
    }
}

/// Round to nearest integer, ties to even — the IEEE default mode,
/// implemented manually (`f32::round_ties_even` needs a newer toolchain
/// than this crate's MSRV).  Deterministic: pure function of the input
/// bit pattern, no ambient rounding-mode dependence.
pub fn round_ties_even(x: f32) -> f32 {
    let t = x.trunc();
    let frac = x - t;
    if frac.abs() == 0.5 {
        // tie: pick the even neighbour of the two candidates t, t±1
        if (t as i64) % 2 == 0 {
            t
        } else {
            t + frac.signum()
        }
    } else {
        x.round()
    }
}

/// Blockwise int8 quantization: per [`INT8_BLOCK`] values, `scale =
/// max_abs / 127` and `code = RNE(x / scale)` clamped to ±127 (an
/// all-zero block gets scale 0 and zero codes).  Deterministic —
/// elementwise within each block, no data-dependent ordering.  Non-finite
/// inputs poison their block's scale, so overflow survives the wire as
/// non-finite dequantized values (the loss-scaler's skip logic still
/// fires).
pub fn quantize_int8(xs: &[f32]) -> (Vec<f32>, Vec<i8>) {
    let mut scales = Vec::with_capacity(xs.len().div_ceil(INT8_BLOCK));
    let mut codes = Vec::with_capacity(xs.len());
    for block in xs.chunks(INT8_BLOCK) {
        let max_abs = block.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let scale = if max_abs == 0.0 { 0.0 } else { max_abs / 127.0 };
        scales.push(scale);
        for &x in block {
            let code = if scale == 0.0 { 0.0 } else { round_ties_even(x / scale) };
            codes.push(code.clamp(-127.0, 127.0) as i8);
        }
    }
    (scales, codes)
}

/// Inverse of [`quantize_int8`]: `x̂ = code · scale` per block.
pub fn dequantize_int8(scales: &[f32], codes: &[i8]) -> Vec<f32> {
    assert_eq!(scales.len(), codes.len().div_ceil(INT8_BLOCK), "scale count mismatch");
    codes
        .iter()
        .enumerate()
        .map(|(i, &c)| c as f32 * scales[i / INT8_BLOCK])
        .collect()
}

/// In-place int8 wire round-trip.  Per-value error is bounded by half a
/// quantization step: `|x - x̂| ≤ max_abs(block) / 254`.
pub fn int8_roundtrip_slice(xs: &mut [f32]) {
    let (scales, codes) = quantize_int8(xs);
    for (i, x) in xs.iter_mut().enumerate() {
        *x = codes[i] as f32 * scales[i / INT8_BLOCK];
    }
}

/// Dynamic loss scaler (DeepSpeed/Apex semantics): gradients are scaled
/// by `scale` during backward; a non-finite gradient anywhere in the
/// world skips the optimizer step and halves the scale, and
/// `growth_interval` consecutive clean steps double it.  All factors are
/// powers of two, so scaling never perturbs the trajectory (power-of-two
/// multiplication is exact) — it only shifts where overflow happens.
#[derive(Debug, Clone, PartialEq)]
pub struct LossScaler {
    scale: f32,
    /// Consecutive overflow-free steps before the scale doubles
    /// (0 disables growth — the static-scale mode).
    growth_interval: u32,
    good_steps: u32,
    skipped: u64,
}

impl LossScaler {
    pub const GROWTH_FACTOR: f32 = 2.0;
    pub const BACKOFF_FACTOR: f32 = 0.5;
    /// Scale floor: repeated overflow cannot drive the scale to zero.
    pub const MIN_SCALE: f32 = 1.0 / 1048576.0; // 2^-20
    /// Scale ceiling for growth (2^24 — past any useful gradient range).
    pub const MAX_SCALE: f32 = 16_777_216.0;

    pub fn new(init: f32, growth_interval: u32) -> Self {
        assert!(init.is_finite() && init > 0.0, "loss scale must be positive and finite");
        Self { scale: init, growth_interval, good_steps: 0, skipped: 0 }
    }

    /// Rebuild from checkpointed state (scale + clean-step counter).
    pub fn with_state(scale: f32, growth_interval: u32, good_steps: u32) -> Self {
        let mut s = Self::new(scale, growth_interval);
        s.good_steps = good_steps;
        s
    }

    /// The scale to apply to this step's loss gradient.
    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Clean steps since the last scale change (checkpointed).
    pub fn good_steps(&self) -> u32 {
        self.good_steps
    }

    /// Steps skipped over this scaler's lifetime.
    pub fn steps_skipped(&self) -> u64 {
        self.skipped
    }

    /// Feed one step's (world-agreed) overflow verdict.  Returns `true`
    /// when the optimizer step must be skipped.
    pub fn update(&mut self, overflow: bool) -> bool {
        if overflow {
            self.scale = (self.scale * Self::BACKOFF_FACTOR).max(Self::MIN_SCALE);
            self.good_steps = 0;
            self.skipped += 1;
            return true;
        }
        self.good_steps += 1;
        if self.growth_interval > 0 && self.good_steps >= self.growth_interval {
            self.scale = (self.scale * Self::GROWTH_FACTOR).min(Self::MAX_SCALE);
            self.good_steps = 0;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng64;

    #[test]
    fn bf16_round_trip_exact_for_all_non_nan_patterns() {
        // every non-NaN bf16 bit pattern survives f32 and back unchanged
        // (incl. ±0, denormals, ±inf); NaNs come back quiet
        for h in 0..=u16::MAX {
            let f = bf16_to_f32(h);
            let back = f32_to_bf16(f);
            if f.is_nan() {
                assert!(bf16_to_f32(back).is_nan(), "{h:#06x}");
                assert_eq!(back, h | 0x0040, "{h:#06x}: NaN must quieten in place");
            } else {
                assert_eq!(back, h, "{h:#06x}");
            }
        }
    }

    #[test]
    fn rne_known_values() {
        let q = |x: f32| Dtype::Bf16.quantize(x);
        assert_eq!(q(1.0), 1.0);
        assert_eq!(q(-2.5), -2.5);
        // 1 + 2^-8 is exactly halfway between 1.0 and 1.0078125: ties to
        // even (mantissa 0) -> 1.0
        assert_eq!(q(1.00390625), 1.0);
        // just above the tie rounds up
        assert_eq!(q(1.005), 1.0078125);
        // 1 + 3·2^-8 ties between mantissa 1 and 2 -> even (2)
        assert_eq!(q(1.01171875), 1.015625);
        // overflow rounds to infinity, like the hardware conversion
        assert_eq!(q(f32::MAX), f32::INFINITY);
        assert_eq!(q(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert!(q(f32::NAN).is_nan());
        assert_eq!(q(0.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(q(-0.0).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn quantize_idempotent_and_monotone() {
        let mut rng = Rng64::new(17);
        let mut vals: Vec<f32> = (0..4000)
            .map(|i| {
                let mag = 10.0f64.powi((i % 17) as i32 - 8);
                (rng.normal() * mag) as f32
            })
            .collect();
        vals.extend([0.0, -0.0, 1e-40, -1e-40, 3.4e38, -3.4e38, f32::MIN_POSITIVE]);
        for &v in &vals {
            let q = Dtype::Bf16.quantize(v);
            assert_eq!(
                Dtype::Bf16.quantize(q).to_bits(),
                q.to_bits(),
                "idempotence at {v}"
            );
            // quantization moves by at most half a ULP of the bf16 grid
            if v.is_finite() && q.is_finite() {
                assert!((q - v).abs() <= v.abs() * (1.0 / 256.0) + f32::MIN_POSITIVE);
            }
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let qs: Vec<f32> = vals.iter().map(|&v| Dtype::Bf16.quantize(v)).collect();
        for w in qs.windows(2) {
            assert!(w[0] <= w[1], "monotonicity violated: {} > {}", w[0], w[1]);
        }
    }

    #[test]
    fn f32_dtype_is_identity() {
        let mut xs = vec![1.2345678f32, -9.87e-20, 3.4e38, f32::NAN];
        let before: Vec<u32> = xs.iter().map(|x| x.to_bits()).collect();
        Dtype::F32.quantize_slice(&mut xs);
        let after: Vec<u32> = xs.iter().map(|x| x.to_bits()).collect();
        assert_eq!(before, after);
        assert_eq!(Dtype::F32.bytes(), 4);
        assert_eq!(Dtype::Bf16.bytes(), 2);
    }

    #[test]
    fn pack_unpack_round_trip_even_and_odd() {
        let mut rng = Rng64::new(5);
        for n in [0usize, 1, 2, 3, 7, 8, 33, 100, 101] {
            let xs: Vec<f32> = (0..n).map(|_| (rng.normal() * 3.0) as f32).collect();
            let packed = pack_bf16(&xs);
            assert_eq!(packed.len(), n.div_ceil(2));
            let back = unpack_bf16(&packed, n);
            let want = Dtype::Bf16.quantized(&xs);
            assert_eq!(back.len(), n);
            for (i, (a, b)) in back.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "n={n} i={i}");
            }
        }
    }

    #[test]
    fn pack_preserves_special_values() {
        let xs = [f32::INFINITY, f32::NEG_INFINITY, f32::NAN, -0.0];
        let back = unpack_bf16(&pack_bf16(&xs), 4);
        assert_eq!(back[0], f32::INFINITY);
        assert_eq!(back[1], f32::NEG_INFINITY);
        assert!(back[2].is_nan());
        assert_eq!(back[3].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn names_and_policies() {
        assert_eq!(Dtype::parse("bf16"), Some(Dtype::Bf16));
        assert_eq!(Dtype::parse("fp32"), Some(Dtype::F32));
        assert_eq!(Dtype::parse("fp16"), None);
        assert_eq!(Dtype::Bf16.name(), "bf16");
        assert!(CastPolicy::fp32().is_fp32());
        assert!(!CastPolicy::bf16().is_fp32());
        assert_eq!(CastPolicy::for_dtype(Dtype::Bf16), CastPolicy::bf16());
    }

    #[test]
    fn round_ties_even_matches_ieee() {
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(3.5), 4.0);
        assert_eq!(round_ties_even(-2.5), -2.0);
        assert_eq!(round_ties_even(-3.5), -4.0);
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(-0.5), -0.0);
        assert_eq!(round_ties_even(2.4), 2.0);
        assert_eq!(round_ties_even(2.6), 3.0);
        assert_eq!(round_ties_even(126.5), 126.0);
        assert_eq!(round_ties_even(-127.0), -127.0);
    }

    #[test]
    fn grad_wire_names_and_bytes() {
        assert_eq!(GradWire::parse("fp32"), Some(GradWire::F32));
        assert_eq!(GradWire::parse("f32"), Some(GradWire::F32));
        assert_eq!(GradWire::parse("bf16"), Some(GradWire::Bf16));
        assert_eq!(GradWire::parse("int8"), Some(GradWire::Int8));
        assert_eq!(GradWire::parse("fp16"), None);
        assert_eq!(GradWire::Int8.name(), "int8");
        assert_eq!(GradWire::for_dtype(Dtype::F32), GradWire::F32);
        assert_eq!(GradWire::for_dtype(Dtype::Bf16), GradWire::Bf16);
        // payload bytes: 4n / 2n / n + one f32 scale per 128-block
        assert_eq!(GradWire::F32.payload_bytes(1000), 4000);
        assert_eq!(GradWire::Bf16.payload_bytes(1000), 2000);
        assert_eq!(GradWire::Int8.payload_bytes(1000), 1000 + 4 * 8);
        assert_eq!(GradWire::Int8.payload_bytes(128), 128 + 4);
        assert_eq!(GradWire::Int8.payload_bytes(129), 129 + 8);
        assert_eq!(GradWire::Int8.payload_bytes(0), 0);
        // the acceptance bound: int8 inter-node bytes ≤ 1/4 + scale
        // overhead (1/128) of the fp32 wire
        for n in [128u64, 1000, 1 << 15] {
            let int8 = GradWire::Int8.payload_bytes(n) as f64;
            let fp32 = GradWire::F32.payload_bytes(n) as f64;
            assert!(int8 <= fp32 * (0.25 + 1.0 / 128.0) + 4.0, "n={n}");
        }
    }

    #[test]
    fn grad_wire_requantization_table() {
        assert!(!GradWire::F32.requantizes_over(Dtype::F32));
        assert!(!GradWire::F32.requantizes_over(Dtype::Bf16));
        assert!(GradWire::Bf16.requantizes_over(Dtype::F32));
        assert!(!GradWire::Bf16.requantizes_over(Dtype::Bf16));
        assert!(GradWire::Int8.requantizes_over(Dtype::F32));
        assert!(GradWire::Int8.requantizes_over(Dtype::Bf16));
    }

    #[test]
    fn int8_round_trip_error_bound() {
        let mut rng = Rng64::new(99);
        for n in [1usize, 5, 127, 128, 129, 384, 1000] {
            let xs: Vec<f32> = (0..n)
                .map(|i| {
                    let mag = 10.0f64.powi((i % 9) as i32 - 4);
                    (rng.normal() * mag) as f32
                })
                .collect();
            let (scales, codes) = quantize_int8(&xs);
            assert_eq!(scales.len(), n.div_ceil(INT8_BLOCK));
            assert_eq!(codes.len(), n);
            let back = dequantize_int8(&scales, &codes);
            for (b, block) in xs.chunks(INT8_BLOCK).enumerate() {
                let max_abs = block.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                for (j, &x) in block.iter().enumerate() {
                    let err = (back[b * INT8_BLOCK + j] - x).abs();
                    assert!(
                        err <= max_abs / 254.0 + f32::EPSILON * max_abs,
                        "n={n} block={b} j={j}: err {err} vs bound {}",
                        max_abs / 254.0
                    );
                }
            }
        }
    }

    #[test]
    fn int8_deterministic_and_idempotent() {
        let mut rng = Rng64::new(7);
        let xs: Vec<f32> = (0..500).map(|_| (rng.normal() * 2.0) as f32).collect();
        // pure function: two invocations agree bitwise
        let (s1, c1) = quantize_int8(&xs);
        let (s2, c2) = quantize_int8(&xs);
        assert_eq!(s1.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
                   s2.iter().map(|s| s.to_bits()).collect::<Vec<_>>());
        assert_eq!(c1, c2);
        // round-trip is idempotent: a dequantized block re-quantizes to
        // itself (its max_abs is a representable multiple of the scale)
        let mut once = xs.clone();
        int8_roundtrip_slice(&mut once);
        let mut twice = once.clone();
        int8_roundtrip_slice(&mut twice);
        for (a, b) in once.iter().zip(&twice) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn int8_zero_block_and_overflow_poisoning() {
        // an all-zero block round-trips to exact zeros with scale 0
        let mut zeros = vec![0.0f32; 200];
        int8_roundtrip_slice(&mut zeros);
        assert!(zeros.iter().all(|&z| z == 0.0));
        // a non-finite gradient poisons its block: the dequantized values
        // stay non-finite, so the overflow skip logic still fires
        let mut xs = vec![1.0f32; INT8_BLOCK];
        xs[17] = f32::INFINITY;
        int8_roundtrip_slice(&mut xs);
        assert!(xs.iter().any(|x| !x.is_finite()), "overflow must survive the wire");
    }

    #[test]
    fn int8_extremes_hit_full_code_range() {
        // the block max quantizes to ±127 exactly and survives unscathed
        let mut xs = vec![0.5f32; INT8_BLOCK];
        xs[0] = 3.0;
        xs[1] = -3.0;
        let (scales, codes) = quantize_int8(&xs);
        assert_eq!(codes[0], 127);
        assert_eq!(codes[1], -127);
        assert_eq!(codes[0] as f32 * scales[0], 3.0);
    }

    #[test]
    fn loss_scaler_skip_and_backoff() {
        let mut s = LossScaler::new(65536.0, 0);
        assert!(!s.update(false));
        assert_eq!(s.scale(), 65536.0, "no growth when interval is 0");
        for k in 1..=5u32 {
            assert!(s.update(true), "overflow must skip");
            assert_eq!(s.scale(), 65536.0 / 2.0f32.powi(k as i32));
            assert_eq!(s.good_steps(), 0);
        }
        assert_eq!(s.steps_skipped(), 5);
        // the floor holds under unbounded overflow
        for _ in 0..200 {
            s.update(true);
        }
        assert_eq!(s.scale(), LossScaler::MIN_SCALE);
    }

    #[test]
    fn loss_scaler_growth_state_machine() {
        let mut s = LossScaler::new(1.0, 3);
        for step in 1..=9u32 {
            assert!(!s.update(false));
            assert_eq!(s.scale(), 2.0f32.powi((step / 3) as i32), "step {step}");
        }
        // an overflow resets the clean-step run and halves
        assert!(s.update(true));
        assert_eq!(s.scale(), 4.0);
        assert_eq!(s.good_steps(), 0);
        // growth is capped
        let mut s = LossScaler::new(LossScaler::MAX_SCALE, 1);
        s.update(false);
        assert_eq!(s.scale(), LossScaler::MAX_SCALE);
    }

    #[test]
    fn loss_scaler_restores_state() {
        let s = LossScaler::with_state(256.0, 4, 3);
        assert_eq!(s.scale(), 256.0);
        assert_eq!(s.good_steps(), 3);
        let mut s2 = s.clone();
        assert!(!s2.update(false)); // 4th clean step -> growth
        assert_eq!(s2.scale(), 512.0);
    }
}
