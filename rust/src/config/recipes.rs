//! The paper's tuned training recipes (Table V) plus the configurations
//! behind each figure, so every bench/example pulls the exact same setup.

use crate::zero::ShardingStage;

use super::model::{lookup, ModelSpec};
use super::parallel::{ParallelConfig, Precision, ScheduleKind};

/// One named end-to-end training setup: model + strategy + GPU count.
#[derive(Debug, Clone)]
pub struct Recipe {
    pub model: ModelSpec,
    pub parallel: ParallelConfig,
}

impl Recipe {
    pub fn gpus(&self) -> u32 {
        self.parallel.world_size()
    }

    /// The same recipe under interleaved 1F1B with `v` virtual chunks —
    /// the schedule dimension the engine/simulator can now execute for
    /// real.  Panics if the recipe's micro-batch count cannot align with
    /// the rank grid (`m % pp != 0`), mirroring Megatron's constraint.
    pub fn with_interleave(mut self, v: u32) -> Self {
        self.parallel = self.parallel.with_interleave(v);
        self.parallel
            .validate()
            .expect("recipe must stay valid under interleaving");
        self
    }
}

/// Table V, 175B column: TP=4, PP=16, MBS=1, GBS=640, ZeRO-1, FA2, bf16
/// checkpoint-activations.  Run at 1024 GPUs => dp = 1024/64 = 16.
pub fn recipe_175b() -> Recipe {
    Recipe {
        model: lookup("175b").unwrap(),
        parallel: ParallelConfig {
            tp: 4,
            pp: 16,
            dp: 16,
            mbs: 1,
            gbs: 640 * 16, // per-replica batch 640 (Fig 12a)
            zero_stage: ShardingStage::OptimizerStates,
            flash_attention: true,
            checkpoint_activations: true,
            precision: Precision::Bf16,
            schedule: ScheduleKind::OneF1B,
            zero3_prefetch: 1,
            experts: 1,
            moe_topk: 1,
            ep: 1,
            capacity_factor: 1.25,
        },
    }
}

/// Table V, 1T column: TP=8, PP=64, MBS=1, GBS=1600/replica.
/// Run at 3072 GPUs => dp = 3072/512 = 6.
pub fn recipe_1t() -> Recipe {
    Recipe {
        model: lookup("1t").unwrap(),
        parallel: ParallelConfig {
            tp: 8,
            pp: 64,
            dp: 6,
            mbs: 1,
            gbs: 1600 * 6,
            zero_stage: ShardingStage::OptimizerStates,
            flash_attention: true,
            checkpoint_activations: true,
            precision: Precision::Bf16,
            schedule: ScheduleKind::OneF1B,
            zero3_prefetch: 1,
            experts: 1,
            moe_topk: 1,
            ep: 1,
            capacity_factor: 1.25,
        },
    }
}

/// The 22B single-replica setup behind Fig 11's 38.38% point
/// (§V.B; TP within a node, modest PP, saturated pipeline).
pub fn recipe_22b() -> Recipe {
    Recipe {
        model: lookup("22b").unwrap(),
        parallel: ParallelConfig {
            tp: 2,
            pp: 4,
            dp: 1,
            mbs: 2,
            gbs: 128,
            zero_stage: ShardingStage::OptimizerStates,
            flash_attention: true,
            checkpoint_activations: true,
            precision: Precision::Bf16,
            schedule: ScheduleKind::OneF1B,
            zero3_prefetch: 1,
            experts: 1,
            moe_topk: 1,
            ep: 1,
            capacity_factor: 1.25,
        },
    }
}

/// Sparse-expert variant of the Table V 175B recipe: the same tp4 pp16
/// dp16 grid with 8 top-2 experts per FFN and the expert exchange run at
/// ep=4 (4 EP groups of 4 consecutive DP replicas per (pp, tp) cell).
/// Expert parameters stay DP-replicated, so the optimizer/ZeRO-1 setup
/// is untouched and the trajectory is ep-invariant; only the token
/// routing traffic (`all_to_all`) changes with ep.
pub fn recipe_175b_moe() -> Recipe {
    let mut r = recipe_175b();
    r.parallel = r.parallel.with_moe(8, 2).with_ep(4);
    r
}

/// All three Fig 11 recipes in paper order.
pub fn fig11_recipes() -> Vec<(Recipe, f64, f64)> {
    // (recipe, paper % of peak, paper TFLOPS)
    vec![
        (recipe_22b(), 38.38, 73.5),
        (recipe_175b(), 36.14, 69.2),
        (recipe_1t(), 31.96, 61.2),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recipes_validate() {
        for (r, _, _) in fig11_recipes() {
            r.parallel.validate().expect("recipe must be well-formed");
            assert!(r.parallel.pipeline_saturated(), "{}", r.model.name);
        }
    }

    #[test]
    fn moe_recipe_variant() {
        let r = recipe_175b_moe();
        r.parallel.validate().expect("moe recipe must be well-formed");
        assert_eq!((r.parallel.experts, r.parallel.moe_topk, r.parallel.ep), (8, 2, 4));
        // same grid and GPU count as the dense recipe — MoE changes the
        // parameter budget and routing traffic, not the decomposition
        let dense = recipe_175b();
        assert_eq!(r.gpus(), dense.gpus());
        assert_eq!(
            (r.parallel.tp, r.parallel.pp, r.parallel.dp),
            (dense.parallel.tp, dense.parallel.pp, dense.parallel.dp)
        );
        // ep divides both dp and experts by construction
        assert_eq!(r.parallel.dp % r.parallel.ep, 0);
        assert_eq!(r.parallel.experts % r.parallel.ep, 0);
    }

    #[test]
    fn recipe_gpu_counts_match_paper() {
        assert_eq!(recipe_175b().gpus(), 1024);
        assert_eq!(recipe_1t().gpus(), 3072);
    }

    #[test]
    fn interleaved_recipe_variant_shrinks_bubble() {
        // Table V's 175B recipe has m = 640, pp = 16 — interleave-aligned
        let base = recipe_175b();
        let plain_bubble = base.parallel.bubble_fraction();
        for v in [2u32, 4] {
            let r = recipe_175b().with_interleave(v);
            r.parallel.validate().unwrap();
            assert_eq!(r.parallel.schedule, ScheduleKind::Interleaved1F1B { v });
            assert!(r.parallel.bubble_fraction() < plain_bubble, "v={v}");
        }
    }

    #[test]
    fn recipe_microbatches_exceed_stages() {
        // §V.A saturation rule holds for both Table V recipes
        let r = recipe_175b();
        assert!(r.parallel.microbatches() >= r.parallel.pp);
        let r = recipe_1t();
        assert!(r.parallel.microbatches() >= r.parallel.pp);
    }
}
