//! Parallelism / training configuration (paper Table III's tunables).
//!
//! One `ParallelConfig` captures a full distribution strategy: the 3D
//! decomposition (TP x PP x DP), micro-batching, the pipeline schedule, and
//! the memory/software options the paper tunes (the ZeRO sharding stage,
//! flash attention, activation checkpointing, precision).

use crate::zero::ShardingStage;

/// Pipeline schedule flavours discussed in §II.C.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleKind {
    /// GPipe: all-forward then all-backward, bubble `(p-1)/m` *twice*
    /// (fill + drain on both passes collapse to `(p-1)` fwd + `(p-1)` bwd slots).
    GPipe,
    /// PipeDream-style one-forward-one-backward with flush (what
    /// DeepSpeed's pipeline engine implements; the paper's choice, §V.A).
    OneF1B,
    /// Megatron-style 1F1B with `v` model chunks interleaved per GPU.
    /// `schedule::interleaved_1f1b` emits the real per-chunk instruction
    /// streams (warmup ramp `2(p-1-rank) + (v-1)p` virtual forwards, then
    /// virtual 1F1B, then drain); the fill/drain then costs `(p-1)` chunk
    /// slots instead of full-stage slots, shrinking the bubble to
    /// `(p-1)/(m v)`.  Requires `m % p == 0` when `v > 1`.
    Interleaved1F1B { v: u32 },
}

impl ScheduleKind {
    /// Virtual-chunk multiplicity `v` (1 except for interleaving).
    pub fn chunks(&self) -> u32 {
        match self {
            ScheduleKind::Interleaved1F1B { v } => *v,
            _ => 1,
        }
    }

    /// Idle fraction of the steady-state pipeline (§II.C / §III.B).
    ///
    /// For interleaved 1F1B this is `((p-1)/v) / (m + (p-1)/v)` — i.e.
    /// `(p-1)/(m v + p - 1)` — which the discrete-event simulator's
    /// measured idle time reproduces from the generated per-chunk streams
    /// (see `perf::sim::tests::interleaved_bubble_matches_analytic`).
    pub fn bubble_fraction(&self, p: u32, m: u32) -> f64 {
        assert!(p >= 1 && m >= 1);
        let p = p as f64;
        let m = m as f64;
        match self {
            // fill+drain of both passes: bubble time = (p-1)(tf+tb),
            // total = (m + p - 1)(tf+tb)
            ScheduleKind::GPipe | ScheduleKind::OneF1B => (p - 1.0) / (m + p - 1.0),
            ScheduleKind::Interleaved1F1B { v } => {
                let v = *v as f64;
                let bubble = (p - 1.0) / v;
                bubble / (m + bubble)
            }
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Fp16,
    Bf16,
    Fp32,
}

impl Precision {
    pub fn bytes(&self) -> u64 {
        match self {
            Precision::Fp16 | Precision::Bf16 => 2,
            Precision::Fp32 => 4,
        }
    }
}

/// A complete distribution strategy (Table III tunables + fixed choices).
#[derive(Debug, Clone, PartialEq)]
pub struct ParallelConfig {
    /// Tensor-parallel group size (within-layer sharding, §II.B).
    pub tp: u32,
    /// Pipeline-parallel stages (layer-dimension sharding, §II.C).
    pub pp: u32,
    /// Data-parallel replica count.
    pub dp: u32,
    /// Micro-batch size per pipeline slot (samples).
    pub mbs: u32,
    /// Global batch size (samples across all replicas per step).
    pub gbs: u32,
    /// ZeRO sharding stage across the DP group (§II.D): 0 = DDP, 1 =
    /// optimizer states sharded (the paper's knob), 2 = + gradient
    /// shards, 3 = + parameter shards with on-demand gathering.
    pub zero_stage: ShardingStage,
    /// Flash-Attention v2 (§V.A: up to 30% throughput gain).
    pub flash_attention: bool,
    /// Activation checkpointing (Table V: always on for the big runs).
    pub checkpoint_activations: bool,
    pub precision: Precision,
    pub schedule: ScheduleKind,
    /// ZeRO-3 gather lookahead depth: how many future parameter chunks
    /// the engine keeps in flight beyond the one in use (§II.D's
    /// gather-use-drop lifecycle).  The transient residency bound is
    /// `(zero3_prefetch + 1)` chunks; 0 means fully synchronous gathers.
    /// Ignored unless `zero_stage` shards parameters.
    pub zero3_prefetch: u32,
    /// MoE expert count per block (1 = dense, no gate).  Experts multiply
    /// the FFN parameter budget without multiplying per-token FLOPs: each
    /// token computes through `moe_topk` experts only.
    pub experts: u32,
    /// Experts each token routes to (top-k gating).  Must not exceed
    /// `experts`; ignored (forced 1) for dense models.
    pub moe_topk: u32,
    /// Expert-parallel group size: EP groups are blocks of `ep`
    /// consecutive DP replicas per (pp, tp) cell, each owning
    /// `experts / ep` experts and exchanging tokens over a deterministic
    /// `all_to_all`.  Expert *parameters* stay DP-replicated (the ZeRO
    /// ladder and the optimizer see the same flat vector at any ep), so
    /// trajectories are ep-invariant.  Requires `ep | dp` and
    /// `ep | experts`; 1 = no token exchange, every rank runs all experts.
    pub ep: u32,
    /// GShard-style expert capacity factor: each expert accepts
    /// `ceil(cf * tokens * topk / experts)` tokens per micro-batch
    /// (clamped to `tokens`); overflow tokens are dropped from the MoE
    /// branch (the residual path still carries them).
    pub capacity_factor: f32,
}

impl Default for ParallelConfig {
    fn default() -> Self {
        Self {
            tp: 1,
            pp: 1,
            dp: 1,
            mbs: 1,
            gbs: 1,
            zero_stage: ShardingStage::Ddp,
            flash_attention: true,
            checkpoint_activations: true,
            precision: Precision::Fp16,
            schedule: ScheduleKind::OneF1B,
            zero3_prefetch: 1,
            experts: 1,
            moe_topk: 1,
            ep: 1,
            capacity_factor: 1.25,
        }
    }
}

impl ParallelConfig {
    /// GPUs per model replica.
    pub fn gpus_per_replica(&self) -> u32 {
        self.tp * self.pp
    }

    /// Total GPUs engaged.
    pub fn world_size(&self) -> u32 {
        self.tp * self.pp * self.dp
    }

    /// Micro-batches per replica per step (`m` in the bubble formulas);
    /// equals DeepSpeed's gradient-accumulation steps.
    pub fn microbatches(&self) -> u32 {
        let per_replica = self.gbs / self.dp;
        per_replica / self.mbs
    }

    /// A config is well-formed when the batch factorisation is exact.
    pub fn validate(&self) -> Result<(), String> {
        if self.tp == 0 || self.pp == 0 || self.dp == 0 || self.mbs == 0 || self.gbs == 0 {
            return Err("all sizes must be >= 1".into());
        }
        if self.gbs % self.dp != 0 {
            return Err(format!("gbs {} not divisible by dp {}", self.gbs, self.dp));
        }
        let per_replica = self.gbs / self.dp;
        if per_replica % self.mbs != 0 {
            return Err(format!(
                "per-replica batch {per_replica} not divisible by mbs {}",
                self.mbs
            ));
        }
        if self.microbatches() == 0 {
            return Err("at least one micro-batch per step required".into());
        }
        if let ScheduleKind::Interleaved1F1B { v } = self.schedule {
            if v == 0 {
                return Err("interleave chunks must be >= 1".into());
            }
            if v > 1 && self.microbatches() % self.pp != 0 {
                return Err(format!(
                    "interleaved 1F1B (v={v}) needs micro-batches ({}) divisible by pp ({})",
                    self.microbatches(),
                    self.pp
                ));
            }
        }
        if self.experts == 0 || self.moe_topk == 0 || self.ep == 0 {
            return Err("experts, moe_topk and ep must be >= 1".into());
        }
        if self.moe_topk > self.experts {
            return Err(format!(
                "moe_topk {} exceeds experts {}",
                self.moe_topk, self.experts
            ));
        }
        if self.experts % self.ep != 0 {
            return Err(format!(
                "experts {} not divisible by ep {} (every EP rank owns experts/ep whole experts)",
                self.experts, self.ep
            ));
        }
        if self.dp % self.ep != 0 {
            return Err(format!(
                "dp {} not divisible by ep {} (EP groups are blocks of ep consecutive DP replicas)",
                self.dp, self.ep
            ));
        }
        if !(self.capacity_factor.is_finite() && self.capacity_factor > 0.0) {
            return Err(format!(
                "capacity_factor must be finite and positive, got {}",
                self.capacity_factor
            ));
        }
        Ok(())
    }

    /// TP shards slice the hidden (column/row-parallel linears) and vocab
    /// (sharded embedding, vocab-parallel head) dimensions; a strategy is
    /// executable only when `tp` divides both.  `PerfModel::evaluate` and
    /// the engine both enforce this against their model specs.
    pub fn tp_divides(&self, hidden: u64, vocab: u64) -> bool {
        hidden % self.tp as u64 == 0 && vocab % self.tp as u64 == 0
    }

    /// Paper §V.A: "the number of micro-batches must equal or exceed the
    /// number of pipeline stages" for saturation.
    pub fn pipeline_saturated(&self) -> bool {
        self.microbatches() >= self.pp
    }

    pub fn bubble_fraction(&self) -> f64 {
        self.schedule.bubble_fraction(self.pp, self.microbatches())
    }

    // ----- builder-style helpers (used heavily by sweeps/benches) -----

    pub fn with_tp(mut self, tp: u32) -> Self {
        self.tp = tp;
        self
    }
    pub fn with_pp(mut self, pp: u32) -> Self {
        self.pp = pp;
        self
    }
    pub fn with_dp(mut self, dp: u32) -> Self {
        self.dp = dp;
        self
    }
    pub fn with_mbs(mut self, mbs: u32) -> Self {
        self.mbs = mbs;
        self
    }
    pub fn with_gbs(mut self, gbs: u32) -> Self {
        self.gbs = gbs;
        self
    }
    /// Deprecated boolean alias: `true` selects sharding stage 1 (the
    /// paper's ZeRO-1 knob), `false` plain DDP.  New call sites should
    /// use [`ParallelConfig::with_zero_stage`].
    pub fn with_zero1(mut self, z: bool) -> Self {
        self.zero_stage = if z { ShardingStage::OptimizerStates } else { ShardingStage::Ddp };
        self
    }
    pub fn with_zero_stage(mut self, s: ShardingStage) -> Self {
        self.zero_stage = s;
        self
    }
    pub fn with_schedule(mut self, s: ScheduleKind) -> Self {
        self.schedule = s;
        self
    }
    /// Interleaved 1F1B with `v` virtual chunks per rank (`v = 1` is
    /// plain 1F1B under the interleaved generator).
    pub fn with_interleave(mut self, v: u32) -> Self {
        self.schedule = ScheduleKind::Interleaved1F1B { v };
        self
    }
    pub fn with_flash(mut self, f: bool) -> Self {
        self.flash_attention = f;
        self
    }
    /// ZeRO-3 gather lookahead depth (`(n + 1)`-chunk transient residency).
    pub fn with_zero3_prefetch(mut self, n: u32) -> Self {
        self.zero3_prefetch = n;
        self
    }
    /// Top-k MoE layers: `experts` expert copies of each FFN, each token
    /// routed to `topk` of them.  `experts = 1` stays dense (no gate).
    pub fn with_moe(mut self, experts: u32, topk: u32) -> Self {
        self.experts = experts;
        self.moe_topk = topk;
        self
    }
    /// Expert-parallel group size (blocks of `ep` consecutive DP replicas).
    pub fn with_ep(mut self, ep: u32) -> Self {
        self.ep = ep;
        self
    }
    /// GShard capacity factor for the per-expert token buffers.
    pub fn with_capacity_factor(mut self, cf: f32) -> Self {
        self.capacity_factor = cf;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn microbatch_accounting() {
        let c = ParallelConfig::default().with_dp(4).with_gbs(128).with_mbs(2);
        assert_eq!(c.microbatches(), 16);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn invalid_factorisations_rejected() {
        assert!(ParallelConfig::default().with_dp(3).with_gbs(128).validate().is_err());
        assert!(ParallelConfig::default().with_gbs(10).with_mbs(3).validate().is_err());
        assert!(ParallelConfig::default().with_gbs(0).validate().is_err());
    }

    #[test]
    fn bubble_shrinks_with_microbatches() {
        // Obs III.2: saturating the pipeline reduces bubble size
        let s = ScheduleKind::OneF1B;
        let b1 = s.bubble_fraction(8, 8);
        let b2 = s.bubble_fraction(8, 64);
        assert!(b2 < b1);
        assert!((s.bubble_fraction(1, 4) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn interleaving_shrinks_bubble() {
        let plain = ScheduleKind::OneF1B.bubble_fraction(8, 16);
        let inter = ScheduleKind::Interleaved1F1B { v: 4 }.bubble_fraction(8, 16);
        assert!(inter < plain);
    }

    #[test]
    fn interleaved_requires_aligned_microbatches() {
        // m = 16, pp = 8: aligned, valid
        let ok = ParallelConfig::default().with_pp(8).with_gbs(16).with_interleave(2);
        ok.validate().unwrap();
        // m = 12, pp = 8: 12 % 8 != 0 — rejected for v > 1, fine for v = 1
        let bad = ParallelConfig::default().with_pp(8).with_gbs(12).with_interleave(2);
        assert!(bad.validate().is_err());
        let v1 = ParallelConfig::default().with_pp(8).with_gbs(12).with_interleave(1);
        v1.validate().unwrap();
    }

    #[test]
    fn tp_divisibility_rule() {
        let c = ParallelConfig::default().with_tp(8);
        assert!(c.tp_divides(12288, 51200));
        assert!(!c.tp_divides(12290, 51200));
        assert!(!c.tp_divides(12288, 51201));
        assert!(ParallelConfig::default().with_tp(1).tp_divides(7, 13));
    }

    #[test]
    fn moe_axis_validation() {
        // dense default: the MoE axes sit at their identity values
        let d = ParallelConfig::default();
        assert_eq!((d.experts, d.moe_topk, d.ep), (1, 1, 1));
        d.validate().unwrap();
        // well-formed MoE: 8 experts, top-2, ep=2 over dp=4
        ParallelConfig::default()
            .with_dp(4)
            .with_gbs(4)
            .with_moe(8, 2)
            .with_ep(2)
            .validate()
            .unwrap();
        // topk may not exceed experts
        assert!(ParallelConfig::default().with_moe(4, 5).validate().is_err());
        // ep must divide experts
        assert!(ParallelConfig::default()
            .with_dp(4)
            .with_gbs(4)
            .with_moe(6, 2)
            .with_ep(4)
            .validate()
            .is_err());
        // ep must divide dp
        assert!(ParallelConfig::default()
            .with_dp(3)
            .with_gbs(3)
            .with_moe(4, 1)
            .with_ep(2)
            .validate()
            .is_err());
        // zero / non-finite knobs rejected
        assert!(ParallelConfig::default().with_moe(0, 1).validate().is_err());
        assert!(ParallelConfig::default().with_capacity_factor(0.0).validate().is_err());
        assert!(ParallelConfig::default()
            .with_capacity_factor(f32::INFINITY)
            .validate()
            .is_err());
    }

    #[test]
    fn saturation_rule() {
        let c = ParallelConfig::default().with_pp(16).with_gbs(16);
        assert!(c.pipeline_saturated());
        let c = ParallelConfig::default().with_pp(16).with_gbs(8);
        assert!(!c.pipeline_saturated());
    }
}
