//! Configuration layer: model zoo (Table I), parallelism strategy
//! (Table III tunables), and the paper's tuned recipes (Table V).

pub mod model;
pub mod parallel;
pub mod recipes;

pub use model::{exec_zoo, lookup, paper_zoo, ModelSpec};
pub use parallel::{ParallelConfig, Precision, ScheduleKind};
pub use recipes::{fig11_recipes, recipe_175b, recipe_175b_moe, recipe_1t, recipe_22b, Recipe};
// The sharding-stage ladder lives in `zero` (the engine subsystem); re-export
// it here so strategy-level callers name it next to ParallelConfig.
pub use crate::zero::ShardingStage;
