//! GPT-style model architecture specifications (paper Table I).
//!
//! Mirrors `python/compile/configs.py` — `tests/test_configs.py` on the
//! python side and `integration.rs` on this side cross-check the parameter
//! counting so the two layers can never drift apart.


/// Architecture of a decoder-only GPT model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub n_layers: u32,
    pub hidden: u64,
    pub n_heads: u32,
    pub vocab: u64,
    pub seq: u64,
}

impl ModelSpec {
    pub fn new(
        name: &str,
        n_layers: u32,
        hidden: u64,
        n_heads: u32,
        vocab: u64,
        seq: u64,
    ) -> Self {
        assert!(
            hidden % n_heads as u64 == 0,
            "{name}: hidden {hidden} not divisible by heads {n_heads}"
        );
        Self { name: name.to_string(), n_layers, hidden, n_heads, vocab, seq }
    }

    pub fn head_dim(&self) -> u64 {
        self.hidden / self.n_heads as u64
    }

    /// Exact parameters of one transformer layer (incl. biases + norms).
    /// The paper's back-of-envelope is `11 d^2` (Fig 2).
    pub fn layer_params(&self) -> u64 {
        let d = self.hidden;
        let attn = d * 3 * d + 3 * d + d * d + d;
        let ffn = d * 4 * d + 4 * d + 4 * d * d + d;
        let norms = 4 * d;
        attn + ffn + norms
    }

    pub fn embed_params(&self) -> u64 {
        self.vocab * self.hidden + self.seq * self.hidden
    }

    /// Final LayerNorm + untied LM head.
    pub fn head_params(&self) -> u64 {
        2 * self.hidden + self.hidden * self.vocab
    }

    pub fn total_params(&self) -> u64 {
        self.embed_params() + self.n_layers as u64 * self.layer_params() + self.head_params()
    }

    /// The paper's `12 L d^2` estimate (§II.A).
    pub fn paper_params(&self) -> u64 {
        12 * self.n_layers as u64 * self.hidden * self.hidden
    }

    /// Training FLOPs per token: `6 N` plus the attention quadratic term
    /// (`12 L d s` per token, fwd+bwd) — the "hardware FLOPs ≈ model FLOPs"
    /// agreement the paper notes under Fig 11.
    pub fn flops_per_token(&self) -> f64 {
        let n = self.total_params() as f64;
        let attn_extra = 12.0 * self.n_layers as f64 * self.hidden as f64 * self.seq as f64;
        6.0 * n + attn_extra
    }

    /// Megatron-style contiguous layer spans for `p` pipeline stages.
    pub fn stage_spans(&self, p: u32) -> Vec<(u32, u32)> {
        assert!(p >= 1 && p <= self.n_layers, "pp must be in [1, {}]", self.n_layers);
        let base = self.n_layers / p;
        let rem = self.n_layers % p;
        let mut spans = Vec::with_capacity(p as usize);
        let mut start = 0;
        for i in 0..p {
            let size = base + u32::from(i < rem);
            spans.push((start, start + size));
            start += size;
        }
        spans
    }
}

/// The paper's Table I model zoo.
///
/// The 1.4B row prints `hidden=2114`, which is not divisible by its 24
/// heads — an apparent typo for 2112; we use 2112 (noted in EXPERIMENTS.md).
pub fn paper_zoo() -> Vec<ModelSpec> {
    vec![
        ModelSpec::new("1.4b", 24, 2112, 24, 51200, 2048),
        ModelSpec::new("22b", 48, 6144, 48, 51200, 2048),
        ModelSpec::new("175b", 96, 12288, 96, 51200, 2048),
        ModelSpec::new("1t", 128, 25600, 128, 51200, 2048),
    ]
}

/// Look up a spec by name across the paper zoo and the executable zoo.
pub fn lookup(name: &str) -> Option<ModelSpec> {
    paper_zoo().into_iter().chain(exec_zoo()).find(|m| m.name == name)
}

/// Configurations small enough to lower + execute on the CPU testbed
/// (mirrors `EXEC_ZOO` in python/compile/configs.py).
pub fn exec_zoo() -> Vec<ModelSpec> {
    vec![
        ModelSpec::new("tiny", 2, 64, 2, 256, 32),
        ModelSpec::new("mini", 4, 128, 4, 512, 64),
        ModelSpec::new("gpt-10m", 4, 256, 8, 4096, 128),
        ModelSpec::new("gpt-125m", 12, 768, 12, 16384, 256),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_param_counts_match_table1_sizes() {
        // Table I names the models by their rounded paper_params sizes.
        let zoo = paper_zoo();
        let b = 1_000_000_000f64;
        let approx: Vec<f64> = zoo.iter().map(|m| m.paper_params() as f64 / b).collect();
        assert!((approx[0] - 1.28).abs() < 0.2, "1.4B row: {}", approx[0]);
        assert!((approx[1] - 21.7).abs() < 1.0, "22B row: {}", approx[1]);
        assert!((approx[2] - 174.0).abs() < 4.0, "175B row: {}", approx[2]);
        assert!((approx[3] - 1006.6).abs() < 20.0, "1T row: {}", approx[3]);
    }

    #[test]
    fn exact_params_close_to_paper_formula() {
        for m in paper_zoo() {
            let exact = m.total_params() as f64;
            let paper = m.paper_params() as f64;
            let rel = (exact - paper).abs() / paper;
            // embedding + head (vocab 51200) dominate the delta for the
            // smallest model; everything stays within ~20% of 12Ld^2
            assert!(rel < 0.20, "{}: exact {exact:.3e} vs paper {paper:.3e}", m.name);
        }
    }

    #[test]
    fn stage_spans_partition_all_layers() {
        let m = ModelSpec::new("t", 13, 64, 2, 100, 32);
        for p in 1..=13 {
            let spans = m.stage_spans(p);
            assert_eq!(spans.len(), p as usize);
            assert_eq!(spans[0].0, 0);
            assert_eq!(spans.last().unwrap().1, 13);
            for w in spans.windows(2) {
                assert_eq!(w[0].1, w[1].0, "spans must be contiguous");
                // earlier stages take the remainder
                assert!(w[0].1 - w[0].0 >= w[1].1 - w[1].0);
            }
        }
    }

    #[test]
    fn exec_zoo_matches_python_tiny_param_count() {
        // python smoke test measured 134_912 params for `tiny`
        let tiny = lookup("tiny").unwrap();
        assert_eq!(tiny.total_params(), 134_912);
    }

    #[test]
    fn flops_per_token_dominated_by_6n() {
        let m = lookup("175b").unwrap();
        let f = m.flops_per_token();
        let n6 = 6.0 * m.total_params() as f64;
        assert!(f > n6 && f < 1.2 * n6);
    }
}
