//! Per-rank span tracing, the unified telemetry registry, and the
//! measured-vs-model divergence audit (DESIGN.md "Observability").
//!
//! The paper's method is measurement-driven: step time is attributed to
//! compute, TP/DP/PP communication and pipeline bubbles, and the
//! parallelism hyperparameters are tuned against those measurements
//! (Figs 6–13).  This module gives the engine the same attribution on a
//! per-rank timeline:
//!
//! * **Spans** — each worker thread installs a thread-local [`Tracer`]
//!   (pre-allocated event buffer, monotonic clock anchored to one run
//!   epoch).  Instrumentation sites open scoped [`span`]s categorized by
//!   [`Category`] and tagged `(step, chunk, mb, op)`; closing a span
//!   folds its duration into the parent's `child_ns`, so *self time*
//!   (duration − children) partitions the timeline without
//!   double-counting nested spans (e.g. a TP all-reduce inside a
//!   compute op).
//! * **Registry** — one [`Registry`] per traced run collects every
//!   rank's buffer at thread exit (the [`TraceGuard`] flushes even when
//!   a worker unwinds on `PeerLost`), owns the engine-wide counter
//!   snapshot type [`CounterSet`], and exports:
//!   - a merged Chrome Trace Event Format JSON (`--trace-out`; one
//!     `pid` per worker rank, one `tid` per chunk slot — loads in
//!     Perfetto / `chrome://tracing`),
//!   - a per-step JSONL metrics stream (`--metrics-jsonl`; loss, scale,
//!     wall time, per-category ms, and the delta of every `TrainReport`
//!     payload/residency counter).
//! * **Audit** — [`audit`] folds the span timeline into the same terms
//!   `PerfModel` prices and renders a measured-vs-predicted table,
//!   recomputing `dp_overlap` and the bubble fraction *from the trace*
//!   so they can be cross-checked against the engine's existing timer
//!   classification and the analytic `(p-1)/(mv+p-1)`.
//!
//! The hard contract, in the house style: tracing on ≡ tracing off
//! **bitwise** on the loss trajectory and every pinned counter (spans
//! never touch numerics or add collectives), and span accounting closes
//! — per (rank, step), Σ category self time ≤ wall time, with the
//! remainder reported as `idle`.  With tracing off every instrumentation
//! site is a thread-local `None` check (`tests/trace.rs` +
//! `engine_hotpath` pin the <3% overhead contract).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::io::{BufWriter, Write as _};
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::escape;

/// Tag value for "no chunk" / "no microbatch" on a span.
pub const TAG_NONE: u32 = u32::MAX;

/// Tag value for events recorded before the first `step_mark`.
pub const STEP_NONE: u32 = u32::MAX;

// ---------------------------------------------------------------------------
// Categories
// ---------------------------------------------------------------------------

/// Where a span's self time is charged.  The first eight are recorded by
/// instrumentation; `Idle` is synthesized per (rank, step) as
/// `wall − Σ self` when the timeline is aggregated.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Category {
    /// Stage forward/backward execution (builtin kernels or XLA).
    Compute,
    /// Tensor-parallel all-reduces inside an op.
    TpComm,
    /// DP gradient sync: bucket launches, drains, handle waits, the
    /// scaler-agreement and loss all-reduces.
    DpSync,
    /// Pipeline boundary activation/grad send/recv.
    PpP2p,
    /// ZeRO-3 parameter gathers (primary + node-local secondary).
    ZeroGather,
    /// MoE expert-parallel all-to-all dispatch/combine.
    MoeA2a,
    /// Optimizer step (sharded Adam over reduced grads).
    Optimizer,
    /// Checkpoint save path (barrier + snapshot/write).
    Checkpoint,
    /// Derived: unattributed wall time within a step.
    Idle,
}

/// The recordable categories (everything but the derived `Idle`).
pub const RECORDED: [Category; 8] = [
    Category::Compute,
    Category::TpComm,
    Category::DpSync,
    Category::PpP2p,
    Category::ZeroGather,
    Category::MoeA2a,
    Category::Optimizer,
    Category::Checkpoint,
];

impl Category {
    pub fn name(self) -> &'static str {
        match self {
            Category::Compute => "compute",
            Category::TpComm => "tp_comm",
            Category::DpSync => "dp_sync",
            Category::PpP2p => "pp_p2p",
            Category::ZeroGather => "zero_gather",
            Category::MoeA2a => "moe_a2a",
            Category::Optimizer => "optimizer",
            Category::Checkpoint => "checkpoint",
            Category::Idle => "idle",
        }
    }

    fn index(self) -> usize {
        match self {
            Category::Compute => 0,
            Category::TpComm => 1,
            Category::DpSync => 2,
            Category::PpP2p => 3,
            Category::ZeroGather => 4,
            Category::MoeA2a => 5,
            Category::Optimizer => 6,
            Category::Checkpoint => 7,
            Category::Idle => 8,
        }
    }
}

// ---------------------------------------------------------------------------
// Events + the thread-local tracer
// ---------------------------------------------------------------------------

/// One closed span on a rank's timeline.  Times are nanoseconds since
/// the run epoch; `child_ns` is the summed duration of *direct* child
/// spans, so `(t1 - t0) - child_ns` is this span's self time.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub cat: Category,
    pub op: &'static str,
    pub step: u32,
    pub chunk: u32,
    pub mb: u32,
    pub t0_ns: u64,
    pub t1_ns: u64,
    pub child_ns: u64,
}

#[derive(Debug, Clone, Copy)]
struct OpenSpan {
    cat: Category,
    op: &'static str,
    step: u32,
    chunk: u32,
    mb: u32,
    t0_ns: u64,
    child_ns: u64,
}

/// Per-thread span recorder.  Installed by [`Registry::install`]; every
/// instrumentation site is inert (one TLS `None` check) when no tracer
/// is installed.
#[derive(Debug)]
struct Tracer {
    rank: usize,
    epoch: Instant,
    events: Vec<Event>,
    stack: Vec<OpenSpan>,
    cur_step: u32,
    /// `(step, start_ns)` boundaries; a step's wall time runs to the
    /// next mark (or the trace end for the last step).
    marks: Vec<(u32, u64)>,
}

impl Tracer {
    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }
}

thread_local! {
    static TRACER: RefCell<Option<Tracer>> = const { RefCell::new(None) };
}

/// A rank's completed timeline, flushed to the registry at thread exit.
#[derive(Debug)]
pub struct RankTrace {
    pub rank: usize,
    pub events: Vec<Event>,
    pub marks: Vec<(u32, u64)>,
    pub end_ns: u64,
}

/// RAII span guard: open on construction, closed (recorded) on drop.
/// Inert when the thread has no tracer installed.
#[must_use = "a span closes when dropped; binding it to `_` closes it immediately"]
pub struct Span {
    active: bool,
}

/// Open an untagged span (inherits `(chunk, mb)` from the enclosing
/// span, if any).
pub fn span(cat: Category, op: &'static str) -> Span {
    span_cm(cat, op, TAG_NONE, TAG_NONE)
}

/// Open a span tagged with a chunk slot and microbatch.  `TAG_NONE`
/// tags inherit from the enclosing span, so a collective wait inside a
/// compute op lands on the op's chunk lane without extra plumbing.
pub fn span_cm(cat: Category, op: &'static str, chunk: u32, mb: u32) -> Span {
    TRACER.with(|t| {
        let mut slot = t.borrow_mut();
        let Some(tr) = slot.as_mut() else {
            return Span { active: false };
        };
        let now = tr.now_ns();
        let (ic, imb) = tr.stack.last().map(|o| (o.chunk, o.mb)).unwrap_or((TAG_NONE, TAG_NONE));
        tr.stack.push(OpenSpan {
            cat,
            op,
            step: tr.cur_step,
            chunk: if chunk == TAG_NONE { ic } else { chunk },
            mb: if mb == TAG_NONE { imb } else { mb },
            t0_ns: now,
            child_ns: 0,
        });
        Span { active: true }
    })
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        TRACER.with(|t| {
            if let Some(tr) = t.borrow_mut().as_mut() {
                let now = tr.now_ns();
                let o = tr.stack.pop().expect("span stack underflow");
                let dur = now.saturating_sub(o.t0_ns);
                if let Some(parent) = tr.stack.last_mut() {
                    parent.child_ns += dur;
                }
                tr.events.push(Event {
                    cat: o.cat,
                    op: o.op,
                    step: o.step,
                    chunk: o.chunk,
                    mb: o.mb,
                    t0_ns: o.t0_ns,
                    t1_ns: now,
                    child_ns: o.child_ns,
                });
            }
        });
    }
}

/// Mark the start of a training step on this rank's timeline.  Spans
/// opened after the mark are tagged with `step`.
pub fn step_mark(step: u32) {
    TRACER.with(|t| {
        if let Some(tr) = t.borrow_mut().as_mut() {
            let now = tr.now_ns();
            tr.cur_step = step;
            tr.marks.push((step, now));
        }
    });
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// One per traced run: owns the run epoch, collects every rank's
/// timeline, and renders the exports.  Created by `train_with_bundle`
/// when `--trace-out` or `--metrics-jsonl` is set.
#[derive(Debug)]
pub struct Registry {
    epoch: Instant,
    ranks: Mutex<Vec<RankTrace>>,
}

/// Uninstalls + flushes the calling thread's tracer on drop — including
/// panic unwinds (`PeerLost`) and `Err` returns (`KilledByFault`), so a
/// dying worker's partial timeline still reaches the registry.
pub struct TraceGuard {
    reg: Arc<Registry>,
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        TRACER.with(|t| {
            if let Some(mut tr) = t.borrow_mut().take() {
                let end = tr.now_ns();
                // close anything left open by an unwinding worker
                while let Some(o) = tr.stack.pop() {
                    tr.events.push(Event {
                        cat: o.cat,
                        op: o.op,
                        step: o.step,
                        chunk: o.chunk,
                        mb: o.mb,
                        t0_ns: o.t0_ns,
                        t1_ns: end,
                        child_ns: o.child_ns,
                    });
                }
                self.reg.ranks.lock().unwrap().push(RankTrace {
                    rank: tr.rank,
                    events: tr.events,
                    marks: tr.marks,
                    end_ns: end,
                });
            }
        });
    }
}

/// Aggregated timeline statistics (carried on `TrainReport`).
#[derive(Debug, Clone)]
pub struct Summary {
    /// Distinct worker ranks that flushed a timeline.
    pub ranks: usize,
    /// Distinct step ids observed across all ranks.
    pub steps: usize,
    /// Total recorded events across all ranks.
    pub events: u64,
    /// Self-time seconds per recorded category, summed over all ranks
    /// and steps (index by `Category::index`-order of [`RECORDED`]).
    pub cat_s: [f64; 8],
    /// Synthesized idle seconds (Σ per-(rank, step) `wall − busy`).
    pub idle_s: f64,
    /// Σ per-(rank, step) wall seconds.
    pub wall_s: f64,
    /// Full duration of hidden (launch-classified) DP sync spans.
    pub dp_hidden_s: f64,
    /// Full duration of exposed DP sync spans (exposed launches+drains).
    pub dp_exposed_s: f64,
    /// `1 − exposed/raw` over the trace's DP launch/drain spans — the
    /// same classification the engine's `nb_hidden/exposed_ns` timers
    /// use, recomputed from the timeline.
    pub dp_overlap: f64,
    /// PP p2p hidden fraction from the trace (the engine's p2p is
    /// blocking, so this measures 0 until sends overlap).
    pub pp_overlap: f64,
    /// (blocking p2p recv self time + idle) / wall — the measured
    /// pipeline-bubble fraction, compared against the analytic
    /// `(p-1)/(mv+p-1)` by the audit.
    pub bubble_fraction: f64,
    /// max over (rank, step) of `busy / wall`; the accounting contract
    /// is `≤ 1.0` within timer jitter (tests pin `< 1.01`).
    pub max_busy_over_wall: f64,
}

impl Summary {
    pub fn seconds(&self, cat: Category) -> f64 {
        match cat {
            Category::Idle => self.idle_s,
            c => self.cat_s[c.index()],
        }
    }

    /// Mean self-time milliseconds per rank per step for one category.
    pub fn ms_per_rank_step(&self, cat: Category) -> f64 {
        let obs = (self.ranks * self.steps).max(1) as f64;
        self.seconds(cat) * 1e3 / obs
    }
}

/// Per-step aggregate used by the JSONL stream: mean-over-ranks
/// category milliseconds plus the step's traced wall time.
#[derive(Debug, Clone, Default)]
struct StepCats {
    cat_ns: [u64; 8],
    busy_ns: u64,
    wall_ns: u64,
    obs: u32,
}

/// Per-step scalars the coordinator feeds the JSONL stream (mirrors
/// `StepLog` without depending on the coordinator's types).
#[derive(Debug, Clone, Copy)]
pub struct StepMeta {
    pub step: u32,
    pub loss: f32,
    pub grad_norm: f32,
    pub loss_scale: f32,
    pub skipped: bool,
    pub step_time_s: f64,
}

impl Registry {
    pub fn new() -> Arc<Self> {
        Arc::new(Self { epoch: Instant::now(), ranks: Mutex::new(Vec::new()) })
    }

    /// Install a tracer on the calling (worker) thread.  The returned
    /// guard flushes the thread's timeline into the registry on drop.
    pub fn install(self: &Arc<Self>, rank: usize) -> TraceGuard {
        TRACER.with(|t| {
            *t.borrow_mut() = Some(Tracer {
                rank,
                epoch: self.epoch,
                events: Vec::with_capacity(1 << 14),
                stack: Vec::with_capacity(8),
                cur_step: STEP_NONE,
                marks: Vec::new(),
            });
        });
        TraceGuard { reg: Arc::clone(self) }
    }

    /// Aggregate every flushed timeline into a [`Summary`].  Only spans
    /// inside a marked step participate in the category/idle accounting
    /// (pre-step setup shows in the Chrome trace but has no wall
    /// baseline to close against).
    pub fn summarize(&self) -> Summary {
        let ranks = self.ranks.lock().unwrap();
        let mut cat_s = [0.0f64; 8];
        let mut steps = std::collections::BTreeSet::new();
        let mut rank_ids = std::collections::BTreeSet::new();
        let mut events = 0u64;
        let (mut wall_ns, mut idle_ns) = (0u64, 0u64);
        let (mut dp_hidden_ns, mut dp_exposed_ns) = (0u64, 0u64);
        let mut pp_recv_wait_ns = 0u64;
        let mut max_busy_over_wall = 0.0f64;
        for rt in ranks.iter() {
            rank_ids.insert(rt.rank);
            events += rt.events.len() as u64;
            let mut walls: BTreeMap<u32, u64> = BTreeMap::new();
            for (i, &(s, t0)) in rt.marks.iter().enumerate() {
                let end = rt.marks.get(i + 1).map(|m| m.1).unwrap_or(rt.end_ns);
                *walls.entry(s).or_default() += end.saturating_sub(t0);
                steps.insert(s);
            }
            let mut busy: BTreeMap<u32, u64> = BTreeMap::new();
            for e in &rt.events {
                let self_ns = e.t1_ns.saturating_sub(e.t0_ns).saturating_sub(e.child_ns);
                let full_ns = e.t1_ns.saturating_sub(e.t0_ns);
                match e.op {
                    "dp_launch_hidden" => dp_hidden_ns += full_ns,
                    "dp_launch_exposed" | "dp_drain" => dp_exposed_ns += full_ns,
                    _ => {}
                }
                if e.cat == Category::PpP2p && e.op.starts_with("recv_") {
                    pp_recv_wait_ns += self_ns;
                }
                if e.step == STEP_NONE {
                    continue;
                }
                cat_s[e.cat.index()] += self_ns as f64 / 1e9;
                *busy.entry(e.step).or_default() += self_ns;
            }
            for (s, w) in walls {
                let b = busy.get(&s).copied().unwrap_or(0);
                wall_ns += w;
                idle_ns += w.saturating_sub(b);
                if w > 0 {
                    max_busy_over_wall = max_busy_over_wall.max(b as f64 / w as f64);
                }
            }
        }
        let wall_s = wall_ns as f64 / 1e9;
        let idle_s = idle_ns as f64 / 1e9;
        let (dp_hidden_s, dp_exposed_s) =
            (dp_hidden_ns as f64 / 1e9, dp_exposed_ns as f64 / 1e9);
        let pp_raw_s = cat_s[Category::PpP2p.index()];
        Summary {
            ranks: rank_ids.len(),
            steps: steps.len(),
            events,
            cat_s,
            idle_s,
            wall_s,
            dp_hidden_s,
            dp_exposed_s,
            dp_overlap: crate::perf::dp_overlap_fraction(
                dp_hidden_s + dp_exposed_s,
                dp_exposed_s,
            ),
            // the engine's p2p is blocking (every p2p span is exposed),
            // so hidden ≡ 0 and the fraction collapses to 0 — kept as a
            // computed quantity so an overlapped p2p path shows up here
            pp_overlap: crate::perf::dp_overlap_fraction(pp_raw_s, pp_raw_s),
            bubble_fraction: if wall_ns > 0 {
                (pp_recv_wait_ns + idle_ns) as f64 / wall_ns as f64
            } else {
                0.0
            },
            max_busy_over_wall,
        }
    }

    fn per_step(&self) -> BTreeMap<u32, StepCats> {
        let ranks = self.ranks.lock().unwrap();
        let mut out: BTreeMap<u32, StepCats> = BTreeMap::new();
        for rt in ranks.iter() {
            for (i, &(s, t0)) in rt.marks.iter().enumerate() {
                let end = rt.marks.get(i + 1).map(|m| m.1).unwrap_or(rt.end_ns);
                let sc = out.entry(s).or_default();
                sc.wall_ns += end.saturating_sub(t0);
                sc.obs += 1;
            }
            for e in &rt.events {
                if e.step == STEP_NONE {
                    continue;
                }
                let self_ns = e.t1_ns.saturating_sub(e.t0_ns).saturating_sub(e.child_ns);
                let sc = out.entry(e.step).or_default();
                sc.cat_ns[e.cat.index()] += self_ns;
                sc.busy_ns += self_ns;
            }
        }
        out
    }

    // -----------------------------------------------------------------
    // Chrome Trace Event Format export
    // -----------------------------------------------------------------

    /// Write the merged timeline as Chrome Trace Event Format JSON:
    /// `pid` = worker world rank, `tid` = chunk slot (+1; lane 0 carries
    /// untagged/step-level spans), balanced `B`/`E` duration events with
    /// per-lane monotonic microsecond timestamps.  Loads in Perfetto.
    pub fn write_chrome_trace(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut w = BufWriter::new(std::fs::File::create(path)?);
        let ranks = self.ranks.lock().unwrap();
        write!(w, "{{\"traceEvents\":[")?;
        let mut first = true;
        let mut sep = |w: &mut BufWriter<std::fs::File>| -> std::io::Result<()> {
            if first {
                first = false;
                Ok(())
            } else {
                write!(w, ",")
            }
        };
        // lanes per pid, for thread_name metadata
        let mut lanes: BTreeMap<usize, std::collections::BTreeSet<u32>> = BTreeMap::new();
        for rt in ranks.iter() {
            sep(&mut w)?;
            write!(
                w,
                "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{},\"tid\":0,\
                 \"args\":{{\"name\":{}}}}}",
                rt.rank,
                escape(&format!("rank {}", rt.rank))
            )?;
            // group events by lane, then emit each lane's span family as
            // balanced nested B/E pairs: sort by (t0 asc, t1 desc) and
            // close every span that ends before the next one begins
            let mut by_lane: BTreeMap<u32, Vec<&Event>> = BTreeMap::new();
            for e in &rt.events {
                let tid = if e.chunk == TAG_NONE { 0 } else { e.chunk + 1 };
                by_lane.entry(tid).or_default().push(e);
            }
            for (&s, &t) in &rt.marks {
                sep(&mut w)?;
                write!(
                    w,
                    "{{\"name\":{},\"ph\":\"i\",\"s\":\"t\",\"ts\":{:.3},\
                     \"pid\":{},\"tid\":0}}",
                    escape(&format!("step {s}")),
                    t as f64 / 1e3,
                    rt.rank
                )?;
            }
            for (tid, mut evs) in by_lane {
                lanes.entry(rt.rank).or_default().insert(tid);
                evs.sort_by(|a, b| {
                    a.t0_ns.cmp(&b.t0_ns).then(b.t1_ns.cmp(&a.t1_ns))
                });
                let mut open: Vec<&Event> = Vec::new();
                let emit_b =
                    |w: &mut BufWriter<std::fs::File>, e: &Event| -> std::io::Result<()> {
                        write!(
                            w,
                            "{{\"name\":{},\"cat\":{},\"ph\":\"B\",\"ts\":{:.3},\
                             \"pid\":{},\"tid\":{},\"args\":{{\"step\":{},\"mb\":{}}}}}",
                            escape(e.op),
                            escape(e.cat.name()),
                            e.t0_ns as f64 / 1e3,
                            rt.rank,
                            tid,
                            if e.step == STEP_NONE { -1i64 } else { e.step as i64 },
                            if e.mb == TAG_NONE { -1i64 } else { e.mb as i64 },
                        )
                    };
                let emit_e =
                    |w: &mut BufWriter<std::fs::File>, e: &Event| -> std::io::Result<()> {
                        write!(
                            w,
                            "{{\"name\":{},\"ph\":\"E\",\"ts\":{:.3},\"pid\":{},\"tid\":{}}}",
                            escape(e.op),
                            e.t1_ns as f64 / 1e3,
                            rt.rank,
                            tid
                        )
                    };
                for e in evs {
                    while let Some(top) = open.last() {
                        if top.t1_ns <= e.t0_ns {
                            sep(&mut w)?;
                            emit_e(&mut w, top)?;
                            open.pop();
                        } else {
                            break;
                        }
                    }
                    sep(&mut w)?;
                    emit_b(&mut w, e)?;
                    open.push(e);
                }
                while let Some(top) = open.pop() {
                    sep(&mut w)?;
                    emit_e(&mut w, top)?;
                }
            }
        }
        for (pid, tids) in lanes {
            for tid in tids {
                sep(&mut w)?;
                let name =
                    if tid == 0 { "step".to_string() } else { format!("chunk {}", tid - 1) };
                write!(
                    w,
                    "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\
                     \"args\":{{\"name\":{}}}}}",
                    escape(&name)
                )?;
            }
        }
        write!(w, "],\"displayTimeUnit\":\"ms\"}}")?;
        w.flush()
    }

    // -----------------------------------------------------------------
    // Per-step JSONL metrics export
    // -----------------------------------------------------------------

    /// Write one self-describing JSON object per step: the step scalars,
    /// mean-over-ranks per-category milliseconds, and the **delta** of
    /// every engine counter over the step.  `counters[i]` is the
    /// absolute [`CounterSet`] snapshot harvested right after
    /// `steps[i]`; the last step's delta is closed against
    /// `final_counters` (the post-join harvest), so the column sums
    /// reproduce `TrainReport`'s totals exactly.
    pub fn write_metrics_jsonl(
        &self,
        path: &Path,
        steps: &[StepMeta],
        counters: &[CounterSet],
        final_counters: &CounterSet,
    ) -> std::io::Result<()> {
        assert_eq!(steps.len(), counters.len(), "one counter snapshot per logged step");
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let per_step = self.per_step();
        let mut w = BufWriter::new(std::fs::File::create(path)?);
        let jnum = |x: f64| {
            if x.is_finite() {
                format!("{x}")
            } else {
                "null".to_string()
            }
        };
        let mut prev = CounterSet::default();
        for (i, (m, snap)) in steps.iter().zip(counters).enumerate() {
            // close the final step against the post-join totals so the
            // telescoped deltas sum to exactly the TrainReport counters
            // (the leader's snapshot races the tail of async work)
            let cur = if i + 1 == steps.len() { *final_counters } else { *snap };
            write!(
                w,
                "{{\"step\":{},\"loss\":{},\"grad_norm\":{},\"loss_scale\":{},\
                 \"skipped\":{},\"step_time_s\":{}",
                m.step,
                jnum(m.loss as f64),
                jnum(m.grad_norm as f64),
                jnum(m.loss_scale as f64),
                m.skipped,
                jnum(m.step_time_s),
            )?;
            if let Some(sc) = per_step.get(&m.step) {
                let obs = sc.obs.max(1) as f64;
                write!(w, ",\"trace\":{{\"cat_ms\":{{")?;
                for (k, cat) in RECORDED.iter().enumerate() {
                    write!(
                        w,
                        "{}{}:{}",
                        if k == 0 { "" } else { "," },
                        escape(cat.name()),
                        jnum(sc.cat_ns[cat.index()] as f64 / obs / 1e6)
                    )?;
                }
                let idle_ns = sc.wall_ns.saturating_sub(sc.busy_ns);
                write!(
                    w,
                    ",\"idle\":{}}},\"wall_ms\":{}}}",
                    jnum(idle_ns as f64 / obs / 1e6),
                    jnum(sc.wall_ns as f64 / obs / 1e6)
                )?;
            }
            write!(w, ",\"counters\":{{")?;
            let (names, cur_v, prev_v) = (CounterSet::NAMES, cur.values(), prev.values());
            for (k, name) in names.iter().enumerate() {
                // peak residency is a high-water mark, not a flow:
                // emitted absolute, never differenced
                let v = if *name == "zero3_peak_gathered_floats" {
                    cur_v[k]
                } else {
                    cur_v[k].saturating_sub(prev_v[k])
                };
                write!(w, "{}{}:{}", if k == 0 { "" } else { "," }, escape(name), v)?;
            }
            writeln!(w, "}}}}")?;
            prev = cur;
        }
        w.flush()
    }
}

// ---------------------------------------------------------------------------
// CounterSet: the engine-wide counter snapshot
// ---------------------------------------------------------------------------

/// One snapshot of every engine counter the coordinator harvests from
/// the collectives/checkpoint layers — the single owner of the totals
/// `TrainReport` reports and the JSONL stream differences per step.
/// `add` folds legs of an elastic run together (sums; the ZeRO-3 peak
/// takes the max).
#[derive(Debug, Default, Clone, Copy)]
pub struct CounterSet {
    pub comm_bytes: u64,
    pub tp_ar_bytes: u64,
    pub tp_ar_rounds: u64,
    pub dp_bucket_rounds: u64,
    pub dp_bucket_payload_bytes: u64,
    pub dp_param_ag_bytes: u64,
    pub pp_p2p_payload_bytes: u64,
    pub dp_bucket_intra_bytes: u64,
    pub dp_bucket_inter_bytes: u64,
    pub dp_param_ag_intra_bytes: u64,
    pub dp_param_ag_inter_bytes: u64,
    pub pp_p2p_intra_bytes: u64,
    pub pp_p2p_inter_bytes: u64,
    pub moe_a2a_rounds: u64,
    pub moe_a2a_payload_bytes: u64,
    pub moe_a2a_intra_bytes: u64,
    pub moe_a2a_inter_bytes: u64,
    pub moe_dropped_tokens: u64,
    pub zero3_peak_gathered_floats: u64,
    pub dp_sync_hidden_ns: u64,
    pub dp_sync_exposed_ns: u64,
    pub ckpt_hidden_ns: u64,
    pub ckpt_exposed_ns: u64,
}

impl CounterSet {
    /// Field names, in `values()` order (JSONL schema).
    pub const NAMES: [&'static str; 23] = [
        "comm_bytes",
        "tp_ar_bytes",
        "tp_ar_rounds",
        "dp_bucket_rounds",
        "dp_bucket_payload_bytes",
        "dp_param_ag_bytes",
        "pp_p2p_payload_bytes",
        "dp_bucket_intra_bytes",
        "dp_bucket_inter_bytes",
        "dp_param_ag_intra_bytes",
        "dp_param_ag_inter_bytes",
        "pp_p2p_intra_bytes",
        "pp_p2p_inter_bytes",
        "moe_a2a_rounds",
        "moe_a2a_payload_bytes",
        "moe_a2a_intra_bytes",
        "moe_a2a_inter_bytes",
        "moe_dropped_tokens",
        "zero3_peak_gathered_floats",
        "dp_sync_hidden_ns",
        "dp_sync_exposed_ns",
        "ckpt_hidden_ns",
        "ckpt_exposed_ns",
    ];

    pub fn values(&self) -> [u64; 23] {
        [
            self.comm_bytes,
            self.tp_ar_bytes,
            self.tp_ar_rounds,
            self.dp_bucket_rounds,
            self.dp_bucket_payload_bytes,
            self.dp_param_ag_bytes,
            self.pp_p2p_payload_bytes,
            self.dp_bucket_intra_bytes,
            self.dp_bucket_inter_bytes,
            self.dp_param_ag_intra_bytes,
            self.dp_param_ag_inter_bytes,
            self.pp_p2p_intra_bytes,
            self.pp_p2p_inter_bytes,
            self.moe_a2a_rounds,
            self.moe_a2a_payload_bytes,
            self.moe_a2a_intra_bytes,
            self.moe_a2a_inter_bytes,
            self.moe_dropped_tokens,
            self.zero3_peak_gathered_floats,
            self.dp_sync_hidden_ns,
            self.dp_sync_exposed_ns,
            self.ckpt_hidden_ns,
            self.ckpt_exposed_ns,
        ]
    }

    /// Fold another leg's totals in (sums; peak residency takes max).
    pub fn add(&mut self, o: &CounterSet) {
        self.comm_bytes += o.comm_bytes;
        self.tp_ar_bytes += o.tp_ar_bytes;
        self.tp_ar_rounds += o.tp_ar_rounds;
        self.dp_bucket_rounds += o.dp_bucket_rounds;
        self.dp_bucket_payload_bytes += o.dp_bucket_payload_bytes;
        self.dp_param_ag_bytes += o.dp_param_ag_bytes;
        self.pp_p2p_payload_bytes += o.pp_p2p_payload_bytes;
        self.dp_bucket_intra_bytes += o.dp_bucket_intra_bytes;
        self.dp_bucket_inter_bytes += o.dp_bucket_inter_bytes;
        self.dp_param_ag_intra_bytes += o.dp_param_ag_intra_bytes;
        self.dp_param_ag_inter_bytes += o.dp_param_ag_inter_bytes;
        self.pp_p2p_intra_bytes += o.pp_p2p_intra_bytes;
        self.pp_p2p_inter_bytes += o.pp_p2p_inter_bytes;
        self.moe_a2a_rounds += o.moe_a2a_rounds;
        self.moe_a2a_payload_bytes += o.moe_a2a_payload_bytes;
        self.moe_a2a_intra_bytes += o.moe_a2a_intra_bytes;
        self.moe_a2a_inter_bytes += o.moe_a2a_inter_bytes;
        self.moe_dropped_tokens += o.moe_dropped_tokens;
        self.zero3_peak_gathered_floats =
            self.zero3_peak_gathered_floats.max(o.zero3_peak_gathered_floats);
        self.dp_sync_hidden_ns += o.dp_sync_hidden_ns;
        self.dp_sync_exposed_ns += o.dp_sync_exposed_ns;
        self.ckpt_hidden_ns += o.ckpt_hidden_ns;
        self.ckpt_exposed_ns += o.ckpt_exposed_ns;
    }
}

// ---------------------------------------------------------------------------
// Divergence audit: trace-measured vs PerfModel-predicted
// ---------------------------------------------------------------------------

/// One audit table row.  `measured` comes from the span timeline,
/// `predicted` from `PerfModel::evaluate` when a model/parallel spec
/// could be built for the run (`None` otherwise — e.g. non-builtin
/// bundles, or terms the model has no counterpart for).
#[derive(Debug, Clone)]
pub struct AuditRow {
    pub term: &'static str,
    pub unit: &'static str,
    pub measured: f64,
    pub predicted: Option<f64>,
    pub note: &'static str,
}

/// Fold the trace [`Summary`] into the terms `PerfModel` prices.  The
/// predicted column prices *Frontier MI250X* hardware while the
/// measured column is this host's CPU simulation — the audit is about
/// which terms dominate and whether the *fractions* (overlap, bubble)
/// agree, not about absolute seconds matching.
pub fn audit(
    s: &Summary,
    predicted: Option<&crate::perf::StepBreakdown>,
    analytic_bubble: Option<f64>,
    engine_dp_overlap: Option<f64>,
) -> Vec<AuditRow> {
    let p = |f: fn(&crate::perf::StepBreakdown) -> f64| predicted.map(|b| f(b) * 1e3);
    vec![
        AuditRow {
            term: "compute",
            unit: "ms/step/rank",
            measured: s.ms_per_rank_step(Category::Compute),
            predicted: p(|b| b.t_compute),
            note: "stage fwd+bwd self time",
        },
        AuditRow {
            term: "tp_comm",
            unit: "ms/step/rank",
            measured: s.ms_per_rank_step(Category::TpComm),
            predicted: p(|b| b.t_tp_comm),
            note: "TP all-reduces inside ops",
        },
        AuditRow {
            term: "pp_p2p",
            unit: "ms/step/rank",
            measured: s.ms_per_rank_step(Category::PpP2p),
            predicted: p(|b| b.t_pp_comm),
            note: "boundary send/recv (blocking)",
        },
        AuditRow {
            term: "dp_exposed",
            unit: "ms/step/rank",
            measured: s.ms_per_rank_step(Category::DpSync),
            predicted: p(|b| b.t_dp_comm),
            note: "grad-sync time not hidden under backward",
        },
        AuditRow {
            term: "zero3_gather",
            unit: "ms/step/rank",
            measured: s.ms_per_rank_step(Category::ZeroGather),
            predicted: None,
            note: "param gather waits (priced inside the model's dp term)",
        },
        AuditRow {
            term: "moe_a2a",
            unit: "ms/step/rank",
            measured: s.ms_per_rank_step(Category::MoeA2a),
            predicted: None,
            note: "expert dispatch/combine wire",
        },
        AuditRow {
            term: "optimizer",
            unit: "ms/step/rank",
            measured: s.ms_per_rank_step(Category::Optimizer),
            predicted: p(|b| b.t_optimizer),
            note: "sharded Adam step",
        },
        AuditRow {
            term: "checkpoint",
            unit: "ms/step/rank",
            measured: s.ms_per_rank_step(Category::Checkpoint),
            predicted: None,
            note: "save barrier + exposed write",
        },
        AuditRow {
            term: "idle",
            unit: "ms/step/rank",
            measured: s.ms_per_rank_step(Category::Idle),
            predicted: None,
            note: "wall - Σ category self time",
        },
        AuditRow {
            term: "bubble_fraction",
            unit: "fraction",
            measured: s.bubble_fraction,
            predicted: analytic_bubble,
            note: "(p2p recv wait + idle)/wall vs (p-1)/(mv+p-1)",
        },
        AuditRow {
            term: "dp_overlap",
            unit: "fraction",
            measured: s.dp_overlap,
            predicted: engine_dp_overlap,
            note: "trace-classified vs engine hidden/exposed timers",
        },
        AuditRow {
            term: "pp_overlap",
            unit: "fraction",
            measured: s.pp_overlap,
            predicted: None,
            note: "p2p hidden fraction (blocking p2p => 0)",
        },
    ]
}

/// Render the audit as the fixed-width table `train_e2e` prints.
pub fn render_audit(rows: &[AuditRow]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<16} {:>14} {:>14}  {:<14} note",
        "term", "measured", "predicted", "unit"
    );
    for r in rows {
        let pred = match r.predicted {
            Some(v) => format!("{v:.3}"),
            None => "-".to_string(),
        };
        let _ = writeln!(
            out,
            "{:<16} {:>14.3} {:>14}  {:<14} {}",
            r.term, r.measured, pred, r.unit, r.note
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_are_inert_without_a_tracer() {
        // no registry installed on this thread: guards must be no-ops
        let s = span(Category::Compute, "noop");
        drop(s);
        step_mark(0);
    }

    #[test]
    fn self_time_excludes_children() {
        let reg = Registry::new();
        {
            let _g = reg.install(0);
            step_mark(0);
            {
                let _outer = span(Category::Compute, "outer");
                std::thread::sleep(std::time::Duration::from_millis(2));
                {
                    let _inner = span(Category::TpComm, "inner");
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
            }
        }
        let s = reg.summarize();
        assert_eq!(s.ranks, 1);
        assert_eq!(s.steps, 1);
        assert_eq!(s.events, 2);
        // compute self time must not include the nested tp span
        let total = s.seconds(Category::Compute) + s.seconds(Category::TpComm);
        assert!(s.seconds(Category::TpComm) >= 0.002 - 1e-4);
        assert!(s.seconds(Category::Compute) < total);
        assert!(s.max_busy_over_wall <= 1.0 + 1e-9);
    }

    #[test]
    fn tags_inherit_from_enclosing_span() {
        let reg = Registry::new();
        {
            let _g = reg.install(3);
            step_mark(7);
            let _outer = span_cm(Category::Compute, "fwd", 2, 1);
            let _inner = span(Category::TpComm, "ar");
        }
        let ranks = reg.ranks.lock().unwrap();
        let rt = &ranks[0];
        let inner = rt.events.iter().find(|e| e.op == "ar").unwrap();
        assert_eq!((inner.chunk, inner.mb, inner.step), (2, 1, 7));
    }

    #[test]
    fn chrome_trace_is_valid_json() {
        let reg = Registry::new();
        {
            let _g = reg.install(0);
            step_mark(0);
            let _a = span_cm(Category::Compute, "fwd", 0, 0);
            let _b = span(Category::TpComm, "ar");
        }
        let path = std::env::temp_dir()
            .join(format!("fllm-trace-unit-{}.json", std::process::id()));
        reg.write_chrome_trace(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let j = crate::util::json::Json::parse(&text).unwrap();
        let evs = j.field("traceEvents").unwrap().as_arr().unwrap();
        let b = evs.iter().filter(|e| e.str_field("ph").unwrap() == "B").count();
        let e = evs.iter().filter(|e| e.str_field("ph").unwrap() == "E").count();
        assert_eq!(b, 2);
        assert_eq!(b, e);
    }

    #[test]
    fn counter_set_add_sums_and_maxes() {
        let mut a = CounterSet { comm_bytes: 10, zero3_peak_gathered_floats: 5, ..Default::default() };
        let b = CounterSet { comm_bytes: 3, zero3_peak_gathered_floats: 9, ..Default::default() };
        a.add(&b);
        assert_eq!(a.comm_bytes, 13);
        assert_eq!(a.zero3_peak_gathered_floats, 9);
        assert_eq!(CounterSet::NAMES.len(), a.values().len());
    }
}
