//! SHAP sensitivity analysis (paper §IV, Fig 10).
//!
//! The paper fits a regression model predicting achieved FLOPS from the
//! hyper-parameters and reports mean-|SHAP| per feature.  We compute
//! *exact* Shapley values — the 6-feature space admits full enumeration of
//! all 2^5 coalitions per feature — against a background distribution of
//! evaluated points, with the fitted GP as the value function:
//!
//!   phi_i(x) = sum_{S ⊆ F\{i}} |S|!(|F|-|S|-1)!/|F|! [v(S ∪ i) - v(S)]
//!   v(S)     = E_background[ f(x_S, b_{F\S}) ]
//!
//! (the "interventional" conditional expectation KernelSHAP converges to).

use super::surrogate::Gp;

/// Mean-|SHAP| attribution per feature over a set of explained points.
pub fn mean_abs_shap(
    model: &Gp,
    explain: &[Vec<f64>],
    background: &[Vec<f64>],
) -> Vec<f64> {
    assert!(!explain.is_empty() && !background.is_empty());
    let d = explain[0].len();
    let mut acc = vec![0.0; d];
    for x in explain {
        let phi = shapley_values_multi(model, x, background);
        for (a, p) in acc.iter_mut().zip(phi) {
            *a += p.abs();
        }
    }
    acc.iter_mut().for_each(|a| *a /= explain.len() as f64);
    acc
}

/// Exact Shapley values of one prediction against a single baseline.
pub fn shapley_values(model: &Gp, x: &[f64], background: &[f64]) -> Vec<f64> {
    shapley_values_multi(model, x, std::slice::from_ref(&background.to_vec()))
}

/// Exact Shapley values with a multi-sample background set.
pub fn shapley_values_multi(model: &Gp, x: &[f64], background: &[Vec<f64>]) -> Vec<f64> {
    let d = x.len();
    assert!(d <= 16, "exact enumeration is exponential in features");
    let n_coalitions = 1usize << d;

    // v(S) for every coalition, averaged over the background set
    let mut v = vec![0.0f64; n_coalitions];
    for (mask, slot) in v.iter_mut().enumerate() {
        let mut total = 0.0;
        for b in background {
            let q: Vec<f64> = (0..d)
                .map(|i| if mask & (1 << i) != 0 { x[i] } else { b[i] })
                .collect();
            total += model.predict(&q).0;
        }
        *slot = total / background.len() as f64;
    }

    // Shapley weights |S|!(d-|S|-1)!/d!
    let fact: Vec<f64> = {
        let mut f = vec![1.0f64; d + 1];
        for i in 1..=d {
            f[i] = f[i - 1] * i as f64;
        }
        f
    };

    let mut phi = vec![0.0f64; d];
    for i in 0..d {
        let bit = 1usize << i;
        for mask in 0..n_coalitions {
            if mask & bit != 0 {
                continue;
            }
            let s = (mask as u32).count_ones() as usize;
            let w = fact[s] * fact[d - s - 1] / fact[d];
            phi[i] += w * (v[mask | bit] - v[mask]);
        }
    }
    phi
}

#[cfg(test)]
mod tests {
    use super::*;

    fn linear_gp() -> Gp {
        // y = 3 x0 + 1 x1 + 0 x2 over a grid
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for a in 0..3 {
            for b in 0..3 {
                for c in 0..3 {
                    let x = vec![a as f64 / 2.0, b as f64 / 2.0, c as f64 / 2.0];
                    ys.push(3.0 * x[0] + x[1]);
                    xs.push(x);
                }
            }
        }
        Gp::fit(&xs, &ys)
    }

    #[test]
    fn efficiency_property() {
        // Shapley values sum to f(x) - E[f(background)]
        let gp = linear_gp();
        let x = vec![1.0, 1.0, 1.0];
        let bg = vec![vec![0.0, 0.0, 0.0]];
        let phi = shapley_values_multi(&gp, &x, &bg);
        let fx = gp.predict(&x).0;
        let f0 = gp.predict(&bg[0]).0;
        let sum: f64 = phi.iter().sum();
        assert!((sum - (fx - f0)).abs() < 0.05, "{sum} vs {}", fx - f0);
    }

    #[test]
    fn attribution_ranks_linear_coefficients() {
        let gp = linear_gp();
        let explain: Vec<Vec<f64>> = vec![vec![1.0, 1.0, 1.0], vec![0.5, 0.5, 0.5]];
        let bg: Vec<Vec<f64>> = vec![vec![0.0, 0.0, 0.0], vec![0.25, 0.25, 0.25]];
        let m = mean_abs_shap(&gp, &explain, &bg);
        assert!(m[0] > m[1], "{m:?}");
        assert!(m[1] > m[2], "{m:?}");
    }

    #[test]
    fn null_feature_gets_no_attribution() {
        let gp = linear_gp();
        let phi = shapley_values_multi(
            &gp,
            &[1.0, 0.0, 1.0],
            &[vec![0.0, 0.0, 0.0]],
        );
        assert!(phi[2].abs() < 0.1, "{phi:?}");
    }
}
