//! The hyper-parameter search space of Table IV (175B tuning), extended
//! with the pipeline-schedule interleave factor `v` now that the engine
//! executes interleaved streams for real.

use crate::config::{lookup, ModelSpec, ParallelConfig, Precision, ScheduleKind};
use crate::data::Rng64;
use crate::topology::GPUS_PER_NODE;
use crate::zero::ShardingStage;

/// One point in the (extended) Table IV space.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    pub pp: u32,
    pub tp: u32,
    pub mbs: u32,
    /// Gradient-accumulation steps == micro-batches per replica.
    pub gas: u32,
    /// ZeRO sharding stage.  The sampled Table-IV space draws only
    /// {0, 1} — the paper's search toggled ZeRO-1 and nothing else, and
    /// keeping the draw binary keeps the sampler stream AND the feature
    /// values bit-stable with the calibrated Fig 9/10 behaviour — but
    /// the dimension itself spans the whole ladder: explicit points (and
    /// the engine's `--zero-stage`) reach stages 2/3, and
    /// [`Point::features`] / [`Point::to_config`] honour them.
    pub zero_stage: ShardingStage,
    pub nnodes: u32,
    /// Virtual-chunk interleave factor (1 = plain 1F1B).  Sampling clamps
    /// to 1 whenever `gas % pp != 0` — the alignment Megatron-style
    /// interleaving requires — so every sampled point is launchable.
    pub interleave: u32,
    /// Mixed precision (bf16 storage + fp32 masters) vs full fp32.  The
    /// Table IV space pins this `true` when sampling: at 175B a full-fp32
    /// run cannot fit regardless of the other knobs (its only effect on
    /// a search would be padding the OOM count), and keeping the sampler
    /// stream unchanged preserves the calibrated Fig 9/10 behaviour.
    /// The dimension is still explicit in [`FEATURES`] / [`Point::features`]
    /// and [`Point::to_config`] honours `bf16 = false`.
    pub bf16: bool,
    /// ZeRO-3 gather lookahead depth (`(N + 1)`-chunk transient
    /// residency).  Sampling pins this to 1 — the engine's historical
    /// depth — with no extra RNG draw, keeping the sampler stream and the
    /// calibrated Fig 9/10 behaviour bit-stable; explicit points span
    /// [`ZERO3_PREFETCH_CHOICES`], and [`Point::features`] /
    /// [`Point::to_config`] honour any depth.
    pub zero3_prefetch: u32,
    /// MoE expert count per FFN (1 = dense).  Sampling pins this to 1
    /// with no extra RNG draw — the paper's Table IV search was dense,
    /// and the pin keeps the sampler stream and the calibrated Fig 9/10
    /// behaviour bit-stable.  Explicit points span [`EXPERTS_CHOICES`];
    /// the dense pin sits at the feature-axis origin (0.0), so legacy
    /// surrogate inputs are reproduced bit for bit.
    pub experts: u32,
}

pub const PP_CHOICES: [u32; 6] = [1, 2, 4, 8, 12, 16];
pub const TP_CHOICES: [u32; 4] = [1, 2, 4, 8];
pub const MBS_RANGE: (u32, u32) = (4, 20);
pub const GAS_CHOICES: [u32; 2] = [5, 10];
pub const NNODES_CHOICES: [u32; 2] = [12, 16];
pub const INTERLEAVE_CHOICES: [u32; 3] = [1, 2, 4];
pub const ZERO3_PREFETCH_CHOICES: [u32; 3] = [1, 2, 4];
pub const EXPERTS_CHOICES: [u32; 4] = [1, 2, 4, 8];

/// Feature names in SHAP/reporting order (paper Fig 10 uses `p:` prefixes;
/// the `e:` prefix marks the expert axis added on top of Table IV).
pub const FEATURES: [&str; 10] = [
    "p:mbs",
    "p:tp",
    "p:pp",
    "p:num_nodes",
    "p:zero_stage",
    "p:gas",
    "p:interleave",
    "p:bf16",
    "p:zero3_prefetch",
    "e:experts",
];

impl Point {
    /// Uniform random sample over *launchable* points: configurations
    /// whose `tp*pp` cannot tile the node allocation are rejected at
    /// sampling time, the way the paper's SLURM launcher would refuse to
    /// build the srun command, and the interleave factor falls back to 1
    /// when the micro-batch count cannot align with the rank grid.  The
    /// failures that remain in a search trajectory are the interesting
    /// ones — OOMs (Fig 9's red arrows).
    pub fn sample(rng: &mut Rng64) -> Self {
        loop {
            let mut p = Self {
                pp: PP_CHOICES[rng.below(PP_CHOICES.len() as u64) as usize],
                tp: TP_CHOICES[rng.below(TP_CHOICES.len() as u64) as usize],
                mbs: MBS_RANGE.0 + rng.below((MBS_RANGE.1 - MBS_RANGE.0 + 1) as u64) as u32,
                gas: GAS_CHOICES[rng.below(GAS_CHOICES.len() as u64) as usize],
                zero_stage: if rng.below(2) == 1 {
                    ShardingStage::OptimizerStates
                } else {
                    ShardingStage::Ddp
                },
                nnodes: NNODES_CHOICES[rng.below(NNODES_CHOICES.len() as u64) as usize],
                interleave: INTERLEAVE_CHOICES
                    [rng.below(INTERLEAVE_CHOICES.len() as u64) as usize],
                bf16: true,
                zero3_prefetch: 1,
                experts: 1,
            };
            if p.gas % p.pp != 0 {
                p.interleave = 1;
            }
            if p.gpus() % (p.tp * p.pp) == 0 {
                return p;
            }
        }
    }

    /// GPUs this evaluation occupies.
    pub fn gpus(&self) -> u32 {
        self.nnodes * GPUS_PER_NODE
    }

    /// Normalised feature vector in [0,1]^10 (surrogate + SHAP input),
    /// ordered as [`FEATURES`].
    pub fn features(&self) -> [f64; 10] {
        let norm = |v: f64, lo: f64, hi: f64| (v - lo) / (hi - lo);
        [
            norm(self.mbs as f64, MBS_RANGE.0 as f64, MBS_RANGE.1 as f64),
            norm((self.tp as f64).log2(), 0.0, 3.0),
            norm((self.pp as f64).log2(), 0.0, 4.0),
            norm(self.nnodes as f64, 12.0, 16.0),
            // stage index as-is: the sampled {0, 1} values reproduce the
            // legacy boolean feature bit for bit (stages 2/3 extend the
            // axis for explicitly-constructed points)
            self.zero_stage.index() as f64,
            norm(self.gas as f64, 5.0, 10.0),
            norm((self.interleave as f64).log2(), 0.0, 2.0),
            if self.bf16 { 1.0 } else { 0.0 },
            norm((self.zero3_prefetch.max(1) as f64).log2(), 0.0, 2.0),
            // dense (experts = 1) sits exactly at the origin: log2(1) = 0
            norm((self.experts.max(1) as f64).log2(), 0.0, 3.0),
        ]
    }

    /// Instantiate the training configuration on the paper's 175B model.
    /// `Err` when the 3D factorisation cannot tile the allocation — the
    /// paper's launcher would fail the same way before the job even runs.
    pub fn to_config(&self) -> Result<(ModelSpec, ParallelConfig), String> {
        let model = lookup("175b").expect("175b in zoo");
        let gpus = self.gpus();
        let per_replica = self.tp * self.pp;
        if gpus % per_replica != 0 {
            return Err(format!(
                "tp*pp = {per_replica} does not divide {gpus} GPUs"
            ));
        }
        let dp = gpus / per_replica;
        let gbs = self.mbs * self.gas * dp;
        let schedule = if self.interleave > 1 {
            ScheduleKind::Interleaved1F1B { v: self.interleave }
        } else {
            ScheduleKind::OneF1B
        };
        Ok((
            model,
            ParallelConfig {
                tp: self.tp,
                pp: self.pp,
                dp,
                mbs: self.mbs,
                gbs,
                zero_stage: self.zero_stage,
                flash_attention: true,
                checkpoint_activations: true,
                precision: if self.bf16 { Precision::Bf16 } else { Precision::Fp32 },
                schedule,
                zero3_prefetch: self.zero3_prefetch,
                experts: self.experts,
                // the expert axis evaluates canonical GShard-style top-2
                // routing (top-1 when only one expert exists)
                moe_topk: self.experts.min(2),
                ep: 1,
                capacity_factor: 1.25,
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_stay_in_space() {
        let mut rng = Rng64::new(1);
        for _ in 0..200 {
            let p = Point::sample(&mut rng);
            assert!(PP_CHOICES.contains(&p.pp));
            assert!(TP_CHOICES.contains(&p.tp));
            assert!((MBS_RANGE.0..=MBS_RANGE.1).contains(&p.mbs));
            assert!(GAS_CHOICES.contains(&p.gas));
            assert!(NNODES_CHOICES.contains(&p.nnodes));
            assert!(INTERLEAVE_CHOICES.contains(&p.interleave));
            // interleaving only survives on aligned grids
            if p.interleave > 1 {
                assert_eq!(p.gas % p.pp, 0, "{p:?}");
            }
            let f = p.features();
            assert!(f.iter().all(|&x| (0.0..=1.0).contains(&x)), "{f:?}");
        }
    }

    #[test]
    fn sampler_reaches_interleaved_points() {
        let mut rng = Rng64::new(2);
        let n_inter = (0..300)
            .filter(|_| Point::sample(&mut rng).interleave > 1)
            .count();
        assert!(n_inter > 10, "interleave dimension must be explorable: {n_inter}");
    }

    #[test]
    fn config_instantiation() {
        let p = Point {
            pp: 16,
            tp: 4,
            mbs: 4,
            gas: 10,
            zero_stage: ShardingStage::OptimizerStates,
            nnodes: 16,
            interleave: 1,
            bf16: true,
            zero3_prefetch: 1,
            experts: 1,
        };
        let (_, cfg) = p.to_config().unwrap();
        assert_eq!(cfg.dp, 2);
        assert_eq!(cfg.gbs, 4 * 10 * 2);
        assert_eq!(cfg.microbatches(), 10);
        cfg.validate().unwrap();
    }

    #[test]
    fn interleaved_config_instantiation() {
        let p = Point {
            pp: 2,
            tp: 8,
            mbs: 4,
            gas: 10,
            zero_stage: ShardingStage::OptimizerStates,
            nnodes: 16,
            interleave: 2,
            bf16: true,
            zero3_prefetch: 1,
            experts: 1,
        };
        let (_, cfg) = p.to_config().unwrap();
        assert_eq!(cfg.schedule, ScheduleKind::Interleaved1F1B { v: 2 });
        cfg.validate().unwrap();
        // interleaving strictly shrinks the analytic bubble here
        let plain = ScheduleKind::OneF1B.bubble_fraction(2, 10);
        assert!(cfg.bubble_fraction() < plain);
    }

    #[test]
    fn precision_dimension_round_trips() {
        let mut p = Point {
            pp: 2,
            tp: 2,
            mbs: 4,
            gas: 10,
            zero_stage: ShardingStage::Ddp,
            nnodes: 16,
            interleave: 1,
            bf16: false,
            zero3_prefetch: 1,
            experts: 1,
        };
        let (_, cfg) = p.to_config().unwrap();
        assert_eq!(cfg.precision, Precision::Fp32);
        assert_eq!(p.features()[7], 0.0);
        p.bf16 = true;
        let (_, cfg) = p.to_config().unwrap();
        assert_eq!(cfg.precision, Precision::Bf16);
        assert_eq!(p.features()[7], 1.0);
        assert_eq!(FEATURES[7], "p:bf16");
    }

    #[test]
    fn zero_stage_dimension_round_trips() {
        let mut p = Point {
            pp: 2,
            tp: 2,
            mbs: 4,
            gas: 10,
            zero_stage: ShardingStage::Ddp,
            nnodes: 16,
            interleave: 1,
            bf16: true,
            zero3_prefetch: 1,
            experts: 1,
        };
        assert_eq!(p.features()[4], 0.0);
        p.zero_stage = ShardingStage::OptimizerStates;
        // the legacy boolean feature value, bit for bit
        assert_eq!(p.features()[4], 1.0);
        p.zero_stage = ShardingStage::Parameters;
        let (_, cfg) = p.to_config().unwrap();
        assert_eq!(cfg.zero_stage, ShardingStage::Parameters);
        assert_eq!(p.features()[4], 3.0);
        assert_eq!(FEATURES[4], "p:zero_stage");
    }

    #[test]
    fn zero3_prefetch_dimension_round_trips() {
        let mut p = Point {
            pp: 2,
            tp: 2,
            mbs: 4,
            gas: 10,
            zero_stage: ShardingStage::Parameters,
            nnodes: 16,
            interleave: 1,
            bf16: true,
            zero3_prefetch: 1,
            experts: 1,
        };
        // the pinned sampling depth sits at the feature-axis origin,
        // reproducing the pre-dimension surrogate input bit for bit
        assert_eq!(p.features()[8], 0.0);
        assert_eq!(FEATURES[8], "p:zero3_prefetch");
        for n in ZERO3_PREFETCH_CHOICES {
            p.zero3_prefetch = n;
            let (_, cfg) = p.to_config().unwrap();
            assert_eq!(cfg.zero3_prefetch, n);
            assert!((0.0..=1.0).contains(&p.features()[8]));
        }
        assert_eq!(p.features()[8], 1.0); // depth 4 = axis top
        // sampling never draws the dimension: the stream stays bit-stable
        let mut rng = Rng64::new(7);
        for _ in 0..50 {
            assert_eq!(Point::sample(&mut rng).zero3_prefetch, 1);
        }
    }

    #[test]
    fn experts_dimension_round_trips() {
        let mut p = Point {
            pp: 2,
            tp: 2,
            mbs: 4,
            gas: 10,
            zero_stage: ShardingStage::OptimizerStates,
            nnodes: 16,
            interleave: 1,
            bf16: true,
            zero3_prefetch: 1,
            experts: 1,
        };
        // the dense pin sits at the feature-axis origin, reproducing the
        // pre-dimension surrogate input bit for bit
        assert_eq!(p.features()[9], 0.0);
        assert_eq!(FEATURES[9], "e:experts");
        let (_, cfg) = p.to_config().unwrap();
        assert_eq!((cfg.experts, cfg.moe_topk), (1, 1));
        for e in EXPERTS_CHOICES {
            p.experts = e;
            let (_, cfg) = p.to_config().unwrap();
            assert_eq!(cfg.experts, e);
            assert_eq!(cfg.moe_topk, e.min(2));
            cfg.validate().unwrap();
            assert!((0.0..=1.0).contains(&p.features()[9]));
        }
        assert_eq!(p.features()[9], 1.0); // 8 experts = axis top
        // sampling never draws the dimension: the stream stays bit-stable
        let mut rng = Rng64::new(7);
        for _ in 0..50 {
            assert_eq!(Point::sample(&mut rng).experts, 1);
        }
    }

    #[test]
    fn untileable_allocations_fail() {
        // 12 nodes = 96 GPUs; tp*pp = 64 does not divide 96
        let p = Point {
            pp: 16,
            tp: 4,
            mbs: 4,
            gas: 5,
            zero_stage: ShardingStage::Ddp,
            nnodes: 12,
            interleave: 1,
            bf16: true,
            zero3_prefetch: 1,
            experts: 1,
        };
        assert!(p.to_config().is_err());
    }
}
