//! Gaussian-process surrogate for Bayesian optimisation.
//!
//! DeepHyper's solver is surrogate-based Bayesian optimisation; we use a
//! plain GP with an RBF kernel (Cholesky solve, no external linear-algebra
//! crates) — more than adequate for the 6-dimensional Table IV space and a
//! few hundred evaluations.

/// RBF-kernel GP regressor over fixed-dimension feature vectors.
#[derive(Debug, Clone)]
pub struct Gp {
    lengthscale: f64,
    signal_var: f64,
    noise_var: f64,
    x: Vec<Vec<f64>>,
    /// Cholesky factor L of (K + noise I).
    chol: Vec<Vec<f64>>,
    /// alpha = (K + noise I)^-1 y  (y standardised).
    alpha: Vec<f64>,
    y_mean: f64,
    y_std: f64,
}

impl Gp {
    pub fn fit(x: &[Vec<f64>], y: &[f64]) -> Self {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        let n = x.len();
        let y_mean = y.iter().sum::<f64>() / n as f64;
        let y_std = (y.iter().map(|v| (v - y_mean).powi(2)).sum::<f64>() / n as f64)
            .sqrt()
            .max(1e-9);
        let ys: Vec<f64> = y.iter().map(|v| (v - y_mean) / y_std).collect();

        let gp = |lengthscale: f64| {
            let signal_var = 1.0;
            let noise_var = 1e-4;
            let mut k = vec![vec![0.0; n]; n];
            for i in 0..n {
                for j in 0..n {
                    k[i][j] = rbf(&x[i], &x[j], lengthscale, signal_var);
                }
                k[i][i] += noise_var;
            }
            (k, signal_var, noise_var)
        };

        // light model selection: try a few lengthscales, keep the best
        // marginal likelihood
        let mut best: Option<(f64, Vec<Vec<f64>>, f64, f64)> = None;
        for &l in &[0.15, 0.3, 0.6, 1.2] {
            let (k, sv, nv) = gp(l);
            if let Some(chol) = cholesky(&k) {
                let alpha = chol_solve(&chol, &ys);
                // log marginal likelihood ~ -0.5 yᵀα - Σ log L_ii
                let fit_term: f64 = ys.iter().zip(&alpha).map(|(a, b)| a * b).sum();
                let logdet: f64 = (0..n).map(|i| chol[i][i].ln()).sum();
                let lml = -0.5 * fit_term - logdet;
                let better = match &best {
                    None => true,
                    Some((score, _, _, _)) => lml > *score,
                };
                if better {
                    best = Some((lml, chol, l, sv.max(nv)));
                }
            }
        }
        let (_, chol, lengthscale, _) = best.expect("at least one lengthscale must factor");
        let alpha = chol_solve(&chol, &ys);
        Self {
            lengthscale,
            signal_var: 1.0,
            noise_var: 1e-4,
            x: x.to_vec(),
            chol,
            alpha,
            y_mean,
            y_std,
        }
    }

    /// Posterior (mean, std) at `q`.
    pub fn predict(&self, q: &[f64]) -> (f64, f64) {
        let n = self.x.len();
        let kq: Vec<f64> = (0..n)
            .map(|i| rbf(&self.x[i], q, self.lengthscale, self.signal_var))
            .collect();
        let mean_std = kq.iter().zip(&self.alpha).map(|(a, b)| a * b).sum::<f64>();
        // var = k(q,q) - vᵀv where L v = k_q
        let v = forward_sub(&self.chol, &kq);
        let kqq = self.signal_var + self.noise_var;
        let var = (kqq - v.iter().map(|x| x * x).sum::<f64>()).max(1e-12);
        (self.y_mean + self.y_std * mean_std, self.y_std * var.sqrt())
    }

    /// Expected improvement over `best_y` (maximisation).
    pub fn expected_improvement(&self, q: &[f64], best_y: f64) -> f64 {
        let (mu, sigma) = self.predict(q);
        if sigma < 1e-12 {
            return (mu - best_y).max(0.0);
        }
        let z = (mu - best_y) / sigma;
        sigma * (z * norm_cdf(z) + norm_pdf(z))
    }
}

fn rbf(a: &[f64], b: &[f64], lengthscale: f64, signal_var: f64) -> f64 {
    let d2: f64 = a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum();
    signal_var * (-d2 / (2.0 * lengthscale * lengthscale)).exp()
}

/// Dense Cholesky factorisation; `None` if not positive definite.
fn cholesky(k: &[Vec<f64>]) -> Option<Vec<Vec<f64>>> {
    let n = k.len();
    let mut l = vec![vec![0.0; n]; n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = k[i][j];
            for p in 0..j {
                sum -= l[i][p] * l[j][p];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i][j] = sum.sqrt();
            } else {
                l[i][j] = sum / l[j][j];
            }
        }
    }
    Some(l)
}

/// Solve L v = b.
fn forward_sub(l: &[Vec<f64>], b: &[f64]) -> Vec<f64> {
    let n = b.len();
    let mut v = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for j in 0..i {
            sum -= l[i][j] * v[j];
        }
        v[i] = sum / l[i][i];
    }
    v
}

/// Solve (L Lᵀ) x = b.
fn chol_solve(l: &[Vec<f64>], b: &[f64]) -> Vec<f64> {
    let n = b.len();
    let v = forward_sub(l, b);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = v[i];
        for j in i + 1..n {
            sum -= l[j][i] * x[j];
        }
        x[i] = sum / l[i][i];
    }
    x
}

fn norm_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

fn norm_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// Abramowitz–Stegun erf approximation (7.1.26), |err| < 1.5e-7.
fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    sign * (1.0 - poly * (-x * x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gp_interpolates_training_points() {
        let x: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64 / 7.0]).collect();
        let y: Vec<f64> = x.iter().map(|v| (4.0 * v[0]).sin()).collect();
        let gp = Gp::fit(&x, &y);
        for (xi, yi) in x.iter().zip(&y) {
            let (mu, _) = gp.predict(xi);
            assert!((mu - yi).abs() < 0.05, "{mu} vs {yi}");
        }
    }

    #[test]
    fn uncertainty_grows_away_from_data() {
        let x = vec![vec![0.0], vec![0.1]];
        let y = vec![1.0, 1.1];
        let gp = Gp::fit(&x, &y);
        let (_, s_near) = gp.predict(&[0.05]);
        let (_, s_far) = gp.predict(&[5.0]);
        assert!(s_far > s_near);
    }

    #[test]
    fn ei_prefers_promising_regions() {
        // y rises with x; EI beyond the best observed point must exceed EI
        // in the clearly-worse region
        let x: Vec<Vec<f64>> = (0..6).map(|i| vec![i as f64 / 10.0]).collect();
        let y: Vec<f64> = x.iter().map(|v| v[0]).collect();
        let gp = Gp::fit(&x, &y);
        let best = 0.5;
        assert!(gp.expected_improvement(&[0.7], best) > gp.expected_improvement(&[0.0], best));
    }

    #[test]
    fn erf_sane() {
        assert!((erf(0.0)).abs() < 1e-9);
        assert!((erf(1.0) - 0.8427).abs() < 1e-3);
        assert!((erf(-1.0) + 0.8427).abs() < 1e-3);
    }
}
