//! Hyper-parameter optimisation (paper §IV): an async-DeepHyper-style
//! Bayesian search over the Table IV space, with OOM failures penalised
//! exactly the way the paper handles them ("catching the exception and
//! returning the special F-objective value ... which internally penalizes
//! such evaluations to discourage future evaluations").
//!
//! The black box is the calibrated performance model on the 175B model —
//! the same substitution DESIGN.md documents (we cannot run 16-node
//! Frontier jobs, but the failure/throughput structure the search learns
//! is produced by the same mechanisms: the memory wall and the
//! communication hierarchy).

pub mod shap;
pub mod space;
pub mod surrogate;

use crate::data::Rng64;
use crate::perf::{PerfError, PerfModel};
use space::Point;
use surrogate::Gp;

/// One completed evaluation.
#[derive(Debug, Clone)]
pub struct Evaluation {
    pub index: u32,
    pub point: Point,
    /// Achieved TFLOPS/GPU, `None` on failure (Fig 9's red arrows).
    pub objective: Option<f64>,
    pub failure: Option<String>,
}

/// Search settings.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Total evaluations (the paper ran jobs for ~hours on 128 nodes; we
    /// default to a trajectory of comparable length).
    pub n_evals: u32,
    /// Random warmup evaluations before the surrogate takes over.
    pub n_init: u32,
    /// Candidate pool size per BO iteration.
    pub n_candidates: u32,
    pub seed: u64,
}

impl Default for SearchConfig {
    fn default() -> Self {
        Self { n_evals: 128, n_init: 24, n_candidates: 256, seed: 7 }
    }
}

/// Search outcome: the full trajectory + the fitted surrogate inputs.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub evals: Vec<Evaluation>,
    /// Best objective value after each evaluation (Fig 9's rising front).
    pub best_trajectory: Vec<f64>,
}

impl SearchResult {
    pub fn best(&self) -> Option<&Evaluation> {
        self.evals
            .iter()
            .filter(|e| e.objective.is_some())
            .max_by(|a, b| a.objective.partial_cmp(&b.objective).unwrap())
    }

    pub fn n_failures(&self) -> usize {
        self.evals.iter().filter(|e| e.objective.is_none()).count()
    }

    /// Failure count per quarter of the trajectory — the Fig 9 taper.
    pub fn failures_by_quarter(&self) -> [usize; 4] {
        let mut q = [0usize; 4];
        let n = self.evals.len().max(1);
        for (i, e) in self.evals.iter().enumerate() {
            if e.objective.is_none() {
                q[(4 * i / n).min(3)] += 1;
            }
        }
        q
    }
}

/// Evaluate one point of the Table IV space (the "black box").
pub fn evaluate_point(perf: &PerfModel, p: &Point) -> Evaluation {
    let result = match p.to_config() {
        Err(e) => Err(e),
        Ok((model, cfg)) => match perf.evaluate(&model, &cfg) {
            Ok(b) => Ok(b.tflops_per_gpu),
            Err(PerfError::OutOfMemory { required_gib }) => {
                Err(format!("OOM: needs {required_gib} GiB/GPU"))
            }
            Err(PerfError::Invalid(e)) => Err(e),
        },
    };
    match result {
        Ok(v) => Evaluation { index: 0, point: *p, objective: Some(v), failure: None },
        Err(e) => Evaluation { index: 0, point: *p, objective: None, failure: Some(e) },
    }
}

/// Run the Bayesian search.
pub fn run_search(perf: &PerfModel, cfg: &SearchConfig) -> SearchResult {
    let mut rng = Rng64::new(cfg.seed);
    let mut evals: Vec<Evaluation> = Vec::with_capacity(cfg.n_evals as usize);
    let mut best_trajectory = Vec::with_capacity(cfg.n_evals as usize);
    let mut best = f64::NEG_INFINITY;

    for i in 0..cfg.n_evals {
        let point = if i < cfg.n_init || evals.len() < 4 {
            Point::sample(&mut rng)
        } else {
            propose(&evals, cfg, &mut rng)
        };
        let mut ev = evaluate_point(perf, &point);
        ev.index = i;
        if let Some(v) = ev.objective {
            best = best.max(v);
        }
        best_trajectory.push(best);
        evals.push(ev);
    }
    SearchResult { evals, best_trajectory }
}

/// Penalised objective vector for surrogate fitting: failures take
/// (min observed success − margin), DeepHyper's F-objective treatment.
pub fn penalised_objectives(evals: &[Evaluation]) -> Vec<f64> {
    let successes: Vec<f64> = evals.iter().filter_map(|e| e.objective).collect();
    let min = successes.iter().cloned().fold(f64::INFINITY, f64::min);
    let penalty = if min.is_finite() { min - 5.0 } else { -5.0 };
    evals.iter().map(|e| e.objective.unwrap_or(penalty)).collect()
}

/// BO proposal: fit the GP on penalised history, maximise EI over a random
/// candidate pool.
fn propose(evals: &[Evaluation], cfg: &SearchConfig, rng: &mut Rng64) -> Point {
    let x: Vec<Vec<f64>> = evals.iter().map(|e| e.point.features().to_vec()).collect();
    let y = penalised_objectives(evals);
    let gp = Gp::fit(&x, &y);
    let best_y = evals
        .iter()
        .filter_map(|e| e.objective)
        .fold(f64::NEG_INFINITY, f64::max);

    let mut best_point = Point::sample(rng);
    let mut best_ei = f64::NEG_INFINITY;
    for _ in 0..cfg.n_candidates {
        let c = Point::sample(rng);
        let ei = gp.expected_improvement(&c.features(), best_y);
        if ei > best_ei {
            best_ei = ei;
            best_point = c;
        }
    }
    best_point
}

/// Fit a surrogate on the full (penalised) search log and compute the
/// Fig 10 sensitivity ranking.  Returns `(feature name, mean |SHAP|)`
/// sorted descending.
pub fn shap_ranking(result: &SearchResult, max_points: usize) -> Vec<(String, f64)> {
    let x: Vec<Vec<f64>> = result.evals.iter().map(|e| e.point.features().to_vec()).collect();
    let y = penalised_objectives(&result.evals);
    // cap the GP size for tractable exact-SHAP
    let take = x.len().min(max_points);
    let gp = Gp::fit(&x[..take], &y[..take]);

    let explain: Vec<Vec<f64>> = x.iter().take(24).cloned().collect();
    let background: Vec<Vec<f64>> = x.iter().rev().take(16).cloned().collect();
    let m = shap::mean_abs_shap(&gp, &explain, &background);

    let mut ranked: Vec<(String, f64)> = space::FEATURES
        .iter()
        .map(|s| s.to_string())
        .zip(m)
        .collect();
    ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    ranked
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_search(n: u32, seed: u64) -> SearchResult {
        run_search(
            &PerfModel::default(),
            &SearchConfig { n_evals: n, n_init: 12, n_candidates: 64, seed },
        )
    }

    #[test]
    fn search_finds_feasible_configs() {
        let r = quick_search(48, 3);
        let best = r.best().expect("some config must be feasible");
        assert!(best.objective.unwrap() > 10.0, "{:?}", best);
        assert!(r.n_failures() > 0, "search space must contain OOMs");
    }

    #[test]
    fn best_trajectory_monotone() {
        let r = quick_search(40, 5);
        for w in r.best_trajectory.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn bo_beats_pure_random_on_average() {
        // with the same budget, the BO phase should find configs at least
        // as good as pure random sampling (same seeds)
        let mut bo_better = 0;
        for seed in 1..=5u64 {
            let bo = quick_search(60, seed);
            let random = run_search(
                &PerfModel::default(),
                &SearchConfig { n_evals: 60, n_init: 60, n_candidates: 1, seed },
            );
            let b = bo.best().map(|e| e.objective.unwrap()).unwrap_or(0.0);
            let r = random.best().map(|e| e.objective.unwrap()).unwrap_or(0.0);
            if b >= r - 0.5 {
                bo_better += 1;
            }
        }
        assert!(bo_better >= 3, "BO lost to random too often: {bo_better}/5");
    }

    #[test]
    fn fig9_failures_taper() {
        // paper: "the frequency of such failures decreases with time"
        let r = run_search(
            &PerfModel::default(),
            &SearchConfig { n_evals: 120, n_init: 24, n_candidates: 128, seed: 7 },
        );
        let q = r.failures_by_quarter();
        assert!(
            q[0] >= q[3],
            "failures must not increase over the search: {q:?}"
        );
        assert!(r.n_failures() > 5, "search space must contain OOMs: {q:?}");
    }

    #[test]
    fn fig10_mbs_most_impactful_zero_stage_least() {
        // paper Fig 10: micro-batch size dominates; the ZeRO stage is at the tail.
        // Individual seeds jitter the top ranks, so average over seeds
        // (the paper's chart is itself an average over the search log).
        let mut totals = std::collections::BTreeMap::<String, f64>::new();
        for seed in [5u64, 7, 9] {
            let r = run_search(
                &PerfModel::default(),
                &SearchConfig { n_evals: 120, n_init: 24, n_candidates: 256, seed },
            );
            for (name, v) in shap_ranking(&r, 96) {
                *totals.entry(name).or_insert(0.0) += v;
            }
        }
        let mut ranked: Vec<(&str, f64)> =
            totals.iter().map(|(k, v)| (k.as_str(), *v)).collect();
        ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let names: Vec<&str> = ranked.iter().map(|(n, _)| *n).collect();
        // Robust qualitative facts from Fig 10 (exact order is noisy
        // single-run data — see EXPERIMENTS.md): the parallelism/batching
        // knobs (mbs, tp, pp) dominate, and zero_stage + num_nodes trail.  The
        // schedule interleave factor only acts through the (small) bubble
        // term on the few aligned grids, so it trails as well.
        assert!(names[..3].contains(&"p:mbs"), "{ranked:?}");
        assert!(names[3..].contains(&"p:zero_stage"), "{ranked:?}");
        assert!(names[3..].contains(&"p:num_nodes"), "{ranked:?}");
        assert!(names[3..].contains(&"p:interleave"), "{ranked:?}");
        assert_eq!(names[0], "p:tp", "expect a parallelism knob on top: {ranked:?}");
    }

    #[test]
    fn penalty_below_all_successes() {
        let r = quick_search(30, 9);
        let y = penalised_objectives(&r.evals);
        let min_success = r
            .evals
            .iter()
            .filter_map(|e| e.objective)
            .fold(f64::INFINITY, f64::min);
        for (e, v) in r.evals.iter().zip(&y) {
            if e.objective.is_none() {
                assert!(*v < min_success);
            }
        }
    }
}
