//! Pure-Rust reference stage backend — the XLA-free compute path.
//!
//! A deliberately small next-token model with the *same stage contract*
//! as the AOT-compiled GPT stages (embed on the first global stage, one
//! Megatron-style MLP block per stage, softmax-xent head on the last), so
//! the whole coordinator — schedules, virtual chunks, collectives, tensor
//! parallelism, ZeRO-1 — can be exercised end-to-end without PJRT
//! artifacts.  The engine tests use it to prove schedule equivalence
//! (1F1B vs GPipe vs interleaved must walk the same loss trajectory) and
//! **tensor-parallel equivalence** (tp = 1/2/4 must walk the same
//! trajectory); gradients are validated against finite differences below,
//! for the dense and the sharded paths.
//!
//! Each stage block is the Megatron §II.B pattern, executed for real:
//!
//! ```text
//! h_r = tanh(x · W1_r + b1_r)        column-parallel first linear
//! y   = Σ_r h_r · W2_r  + b2         row-parallel second linear
//!       \__ all_reduce_sum __/        (forward: 1 all-reduce)
//! dx  = Σ_r dpre_r · W1_rᵀ           backward input grad: 1 all-reduce
//! ```
//!
//! The embedding is vocab-sharded (each shard contributes its owned token
//! rows, then one forward all-reduce); the head is a vocab-parallel
//! softmax-xent (all-reduce-max for stability, one packed all-reduce for
//! the (sum-exp, target-logit) statistics, one all-reduce for the input
//! gradient).  `tp = 1` ([`crate::collectives::TpComm::solo`]) turns every
//! all-reduce into a no-op, so the dense path IS the sharded path.
//!
//! All dense math runs on the cache-blocked, register-tiled kernels in
//! [`crate::runtime::kernels`] (bit-identical accumulation order to the
//! naive loops they replaced, so every equivalence test pins them too).
//!
//! Initialisation is keyed per *global* component (embedding, layer
//! index, head), never per stage or shard: each shard regenerates the
//! dense component stream and slices its own rows/columns, so any
//! partition of the same model — 1, 2, or `p·v` chunks, any `tp` —
//! materialises bit-identical parameter values.
//!
//! Replicated parameters: only the row-parallel bias `b2` is held by
//! every TP rank (Megatron holds norms/biases replicated the same way).
//! Its gradient is identical across shards by construction (it is a
//! function of the already-all-reduced `dy`); the engine still mean-
//! reduces it across the TP group before the optimizer step (see
//! [`BuiltinStage::replicated_span`]).

use std::sync::atomic::Ordering;

use crate::collectives::TpComm;
use crate::data::Rng64;
use crate::moe::{self, MoeFwdCtx};
use crate::precision::{CastPolicy, Dtype};
use crate::runtime::kernels;

// ---------------------------------------------------------------------------
// GEMM dispatch: the fp32 policy takes the blocked kernels verbatim (the
// bitwise-pinned legacy path); bf16 routes through the bf16-in/f32-acc
// variants, which are idempotent over the stages' already-quantized
// storage (`kernels::bf16`).
// ---------------------------------------------------------------------------

fn mm(dt: Dtype, out: &mut [f32], a: &[f32], b: &[f32], t: usize, k: usize, n: usize) {
    match dt {
        Dtype::F32 => kernels::matmul_acc(out, a, b, t, k, n),
        Dtype::Bf16 => kernels::bf16::matmul_acc(out, a, b, t, k, n),
    }
}

fn mm_at(dt: Dtype, w: &mut [f32], a: &[f32], g: &[f32], t: usize, k: usize, n: usize) {
    match dt {
        Dtype::F32 => kernels::matmul_at_acc(w, a, g, t, k, n),
        Dtype::Bf16 => kernels::bf16::matmul_at_acc(w, a, g, t, k, n),
    }
}

fn mm_bt(dt: Dtype, out: &mut [f32], g: &[f32], b: &[f32], t: usize, k: usize, n: usize) {
    match dt {
        Dtype::F32 => kernels::matmul_bt_acc(out, g, b, t, k, n),
        Dtype::Bf16 => kernels::bf16::matmul_bt_acc(out, g, b, t, k, n),
    }
}

/// Architecture + partition of one builtin bundle.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BuiltinSpec {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub seq: usize,
    pub mbs: usize,
    /// Global stages (= model blocks; one MLP block per stage).
    pub n_stages: usize,
    /// Experts per block (1 for the dense family).
    pub experts: usize,
    /// Gate picks per token (`topk <= experts`).
    pub topk: usize,
    /// Whether the block runs the MoE gate/dispatch/combine path.  A
    /// `-moe1` bundle sets this with `experts = 1`: same parameters as
    /// the dense block (no gate segment), but routed through the
    /// capacity-buffer machinery — the bitwise dense-equivalence probe.
    pub moe: bool,
}

impl BuiltinSpec {
    /// Parse an engine bundle name of the form
    /// `builtin:<model>[-moe<E>[k<K>]]-s<S>-mb<B>` (e.g.
    /// `builtin:tiny-s4-mb2`, `builtin:mini-moe4k2-s2-mb2`).  Returns
    /// `None` for artifact bundles and malformed MoE suffixes
    /// (`E = 0`, `K = 0`, `K > E`).
    pub fn parse(bundle: &str) -> Option<Self> {
        let rest = bundle.strip_prefix("builtin:")?;
        let (model, rest) = rest.split_once("-s")?;
        let (stages, mbs) = rest.split_once("-mb")?;
        let n_stages: usize = stages.parse().ok()?;
        let mbs: usize = mbs.parse().ok()?;
        if n_stages == 0 || mbs == 0 {
            return None;
        }
        let (base, experts, topk, moe) = match model.split_once("-moe") {
            Some((base, moe_spec)) => {
                let (e, k): (usize, usize) = match moe_spec.split_once('k') {
                    Some((e, k)) => (e.parse().ok()?, k.parse().ok()?),
                    None => (moe_spec.parse().ok()?, 1),
                };
                if e == 0 || k == 0 || k > e {
                    return None;
                }
                (base, e, k, true)
            }
            None => (model, 1, 1, false),
        };
        let (vocab, hidden, seq) = match base {
            "tiny" => (64, 16, 8),
            "mini" => (128, 32, 16),
            _ => return None,
        };
        Some(Self { name: model.to_string(), vocab, hidden, seq, mbs, n_stages, experts, topk, moe })
    }

    pub fn embed_params(&self) -> usize {
        self.vocab * self.hidden
    }

    /// Gate parameters of one block: the d×E router weight + E bias,
    /// present only when `experts > 1` — the single-expert MoE block is
    /// parameter-identical to the dense block (its top-1-of-1 gate is
    /// the constant 1.0 and needs no weights), which keeps the
    /// optimizer's grad-norm span partitioning — and therefore the whole
    /// fp32 trajectory — bitwise dense-equal.
    pub fn gate_params(&self) -> usize {
        if self.experts > 1 {
            self.hidden * self.experts + self.experts
        } else {
            0
        }
    }

    /// One block: per expert W1 (d×d) + b1 (d) + W2 (d×d), one shared
    /// replicated b2 (d), plus the gate.  `experts = 1` reduces to the
    /// dense 2d² + 2d.
    pub fn layer_params(&self) -> usize {
        let d = self.hidden;
        self.experts * (2 * d * d + d) + d + self.gate_params()
    }

    pub fn head_params(&self) -> usize {
        self.hidden * self.vocab + self.vocab
    }

    pub fn total_params(&self) -> usize {
        self.embed_params() + self.n_stages * self.layer_params() + self.head_params()
    }

    /// Parameters held by global stage `g` (embed on 0, head on last).
    pub fn stage_params(&self, g: usize) -> usize {
        let mut n = self.layer_params();
        if g == 0 {
            n += self.embed_params();
        }
        if g == self.n_stages - 1 {
            n += self.head_params();
        }
        n
    }

    // ---- tensor-parallel shard accounting ----

    /// TP degree `tp` is executable iff it slices both sharded dims.
    pub fn tp_ok(&self, tp: usize) -> bool {
        tp >= 1 && self.hidden % tp == 0 && self.vocab % tp == 0
    }

    /// Embedding rows held by one shard: (vocab/tp) × d.
    pub fn shard_embed_params(&self, tp: usize) -> usize {
        (self.vocab / tp) * self.hidden
    }

    /// Block parameters held by one shard: per expert W1 cols + b1 slice
    /// + W2 rows, plus the replicated b2 and the replicated gate (every
    /// TP rank holds the full router, like the head statistics the gate
    /// feeds are tiny and its output drives shard-identical routing).
    pub fn shard_layer_params(&self, tp: usize) -> usize {
        let d = self.hidden;
        let f = d / tp;
        self.experts * (d * f + f + f * d) + d + self.gate_params()
    }

    /// Head parameters held by one shard: (d × vocab/tp) + vocab/tp.
    pub fn shard_head_params(&self, tp: usize) -> usize {
        let vs = self.vocab / tp;
        self.hidden * vs + vs
    }

    /// Parameters held by shard `tp_rank` of global stage `g`.
    pub fn shard_stage_params(&self, g: usize, tp: usize) -> usize {
        let mut n = self.shard_layer_params(tp);
        if g == 0 {
            n += self.shard_embed_params(tp);
        }
        if g == self.n_stages - 1 {
            n += self.shard_head_params(tp);
        }
        n
    }
}

/// One global stage of the builtin model (optional embed, one MLP block,
/// optional vocab-parallel head), or one TP shard of it: `tp = 1`,
/// `tp_rank = 0` is the dense case.
#[derive(Debug, Clone)]
pub struct BuiltinStage {
    pub spec: BuiltinSpec,
    /// Global stage index (= global block index).
    pub stage: usize,
    /// Tensor-parallel group size this shard belongs to.
    pub tp: usize,
    /// This shard's rank within the TP group.
    pub tp_rank: usize,
    /// Numeric cast points (`CastPolicy::fp32()` = the legacy path,
    /// every cast a no-op).  Under bf16 the stage stores parameters,
    /// activations and per-micro-batch gradients on the bf16 grid and
    /// runs every GEMM bf16-in/f32-accumulate; the collective wire dtype
    /// is carried by the [`TpComm`] the engine hands each call.
    pub policy: CastPolicy,
    /// MoE expert capacity factor: each expert accepts at most
    /// `min(ceil(cf·T·k/E), T)` tokens per micro-batch, the rest of its
    /// assignments are dropped (their gate probability contributes a
    /// zero output).  Ignored by dense blocks.
    pub capacity_factor: f32,
}

/// Per-component init streams keyed by (run seed, global component id) so
/// every partition of the model draws identical values.
fn component_rng(seed: u64, salt: u64) -> Rng64 {
    Rng64::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ salt ^ 0x5EED_CAFE)
}

/// Offsets of the shard-local parameter segments in the flat vector.
/// `w1`/`b1`/`w2` are expert 0's segments (advance by
/// [`BuiltinStage::expert_stride`] per expert); `gw`/`gb` collapse onto
/// `hw` when there is no gate (`experts = 1`).
struct Lay {
    w1: usize,
    b1: usize,
    w2: usize,
    b2: usize,
    gw: usize,
    gb: usize,
    hw: usize,
    hb: usize,
}

impl BuiltinStage {
    /// Dense (tp = 1) stage.
    pub fn dense(spec: BuiltinSpec, stage: usize) -> Self {
        Self { spec, stage, tp: 1, tp_rank: 0, policy: CastPolicy::fp32(), capacity_factor: 1.25 }
    }

    /// TP shard `tp_rank`/`tp` of a stage.
    pub fn sharded(spec: BuiltinSpec, stage: usize, tp: usize, tp_rank: usize) -> Self {
        assert!(spec.tp_ok(tp), "tp {tp} does not slice hidden/vocab");
        assert!(tp_rank < tp);
        Self { spec, stage, tp, tp_rank, policy: CastPolicy::fp32(), capacity_factor: 1.25 }
    }

    /// The same stage under a different cast policy (builder-style; the
    /// engine sets the bundle-wide policy once at construction).
    pub fn with_policy(mut self, policy: CastPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// The same stage under a different MoE capacity factor.
    pub fn with_capacity_factor(mut self, cf: f32) -> Self {
        assert!(cf > 0.0, "capacity factor must be positive");
        self.capacity_factor = cf;
        self
    }

    fn d(&self) -> usize {
        self.spec.hidden
    }

    fn v(&self) -> usize {
        self.spec.vocab
    }

    /// Sharded feature width d/tp (column width of W1, row count of W2).
    fn f(&self) -> usize {
        self.spec.hidden / self.tp
    }

    /// Sharded vocab width vocab/tp.
    fn vs(&self) -> usize {
        self.spec.vocab / self.tp
    }

    /// First vocab id owned by this shard.
    fn vlo(&self) -> usize {
        self.tp_rank * self.vs()
    }

    /// First hidden feature owned by this shard.
    fn flo(&self) -> usize {
        self.tp_rank * self.f()
    }

    pub fn has_embed(&self) -> bool {
        self.stage == 0
    }

    pub fn has_head(&self) -> bool {
        self.stage == self.spec.n_stages - 1
    }

    pub fn param_count(&self) -> usize {
        self.spec.shard_stage_params(self.stage, self.tp)
    }

    /// Span of the TP-replicated parameters — the row-parallel bias b2
    /// plus (when present) the gate weight and bias — in this shard's
    /// flat vector: what the engine mean-reduces across the TP group
    /// before the optimizer step.  Gate gradients are shard-identical by
    /// construction (functions of the full `x`, the all-reduced expert
    /// outputs and the full `dy`), like b2's.
    pub fn replicated_span(&self) -> (usize, usize) {
        let l = self.lay();
        (l.b2, l.hw)
    }

    /// Shard parameters of one expert: W1 columns + b1 slice + W2 rows.
    fn expert_stride(&self) -> usize {
        let d = self.d();
        let f = self.f();
        d * f + f + f * d
    }

    /// `(w1, b1, w2)` offsets of expert `ex`'s segments.
    fn expert_off(&self, ex: usize) -> (usize, usize, usize) {
        let l = self.lay();
        let s = ex * self.expert_stride();
        (l.w1 + s, l.b1 + s, l.w2 + s)
    }

    fn lay(&self) -> Lay {
        let d = self.d();
        let f = self.f();
        let e = self.spec.experts;
        let embed = if self.has_embed() { self.vs() * d } else { 0 };
        let w1 = embed;
        let b1 = w1 + d * f;
        let w2 = b1 + f;
        let b2 = embed + e * (d * f + f + f * d);
        let gw = b2 + d;
        let gb = gw + if e > 1 { d * e } else { 0 };
        let hw = gb + if e > 1 { e } else { 0 };
        let hb = hw + if self.has_head() { d * self.vs() } else { 0 };
        Lay { w1, b1, w2, b2, gw, gb, hw, hb }
    }

    /// Deterministic, partition- and shard-invariant init of this shard's
    /// flat parameter vector: regenerate each dense component stream and
    /// slice this shard's rows/columns.
    pub fn init(&self, seed: u64) -> Vec<f32> {
        let d = self.d();
        let v = self.v();
        let f = self.f();
        let vs = self.vs();
        let scale = 1.0 / (d as f64).sqrt();
        let mut out = Vec::with_capacity(self.param_count());
        if self.has_embed() {
            let mut rng = component_rng(seed, 0xE0_BED);
            let dense: Vec<f32> = (0..v * d).map(|_| (rng.normal() * 0.5) as f32).collect();
            out.extend_from_slice(&dense[self.vlo() * d..(self.vlo() + vs) * d]);
        }
        // per-expert streams keyed by (layer, expert); expert 0 shares the
        // dense layer's stream, so `-moe1` inits bit-equal to dense
        for ex in 0..self.spec.experts {
            let salt = 0x1A7E5 + self.stage as u64 + ((ex as u64) << 20);
            let mut rng = component_rng(seed, salt);
            let w1: Vec<f32> = (0..d * d).map(|_| (rng.normal() * scale) as f32).collect();
            let w2: Vec<f32> = (0..d * d).map(|_| (rng.normal() * scale) as f32).collect();
            // column shard of W1: every input row i, cols [flo, flo + f)
            for i in 0..d {
                let row = i * d + self.flo();
                out.extend_from_slice(&w1[row..row + f]);
            }
            out.extend(std::iter::repeat(0.0f32).take(f)); // b1 shard
            // row shard of W2: rows [flo, flo + f), all d cols
            out.extend_from_slice(&w2[self.flo() * d..(self.flo() + f) * d]);
        }
        out.extend(std::iter::repeat(0.0f32).take(d)); // b2 (replicated)
        if self.spec.experts > 1 {
            let e = self.spec.experts;
            let mut rng = component_rng(seed, 0x6A7E_0000 + self.stage as u64);
            // gate weight d×E + zero bias, fully replicated on every shard
            out.extend((0..d * e).map(|_| (rng.normal() * scale) as f32));
            out.extend(std::iter::repeat(0.0f32).take(e));
        }
        if self.has_head() {
            let mut rng = component_rng(seed, 0xD_EAD);
            let dense: Vec<f32> = (0..d * v).map(|_| (rng.normal() * scale) as f32).collect();
            // column shard of the head: row i, vocab cols [vlo, vlo + vs)
            for i in 0..d {
                let row = i * v + self.vlo();
                out.extend_from_slice(&dense[row..row + vs]);
            }
            out.extend(std::iter::repeat(0.0f32).take(vs)); // head bias shard
        }
        debug_assert_eq!(out.len(), self.param_count());
        // parameter storage cast: constrain the working copy to the grid
        // (no-op under fp32); the quantization commutes with the shard
        // slicing above, so shard inits stay slices of the dense init
        self.policy.param.quantize_slice(&mut out);
        out
    }

    /// Vocab-sharded embedding forward: each shard contributes its owned
    /// token rows, one all-reduce assembles the full activation.
    fn embed(&self, comm: &TpComm, params: &[f32], tokens: &[i32]) -> Vec<f32> {
        let d = self.d();
        let vs = self.vs();
        let vlo = self.vlo();
        let mut x = vec![0.0f32; tokens.len() * d];
        for (t, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            if tok >= vlo && tok < vlo + vs {
                let row = (tok - vlo) * d;
                x[t * d..(t + 1) * d].copy_from_slice(&params[row..row + d]);
            }
        }
        comm.all_reduce_sum(&mut x);
        x
    }

    /// Embedding backward: scatter `dx` rows into this shard's owned rows
    /// of the table gradient.  No communication (dx is already full).
    fn embed_bwd(&self, gparams: &mut [f32], tokens: &[i32], dx: &[f32]) {
        let d = self.d();
        let vs = self.vs();
        let vlo = self.vlo();
        for (t, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            if tok >= vlo && tok < vlo + vs {
                let row = (tok - vlo) * d;
                for (g, &v) in gparams[row..row + d].iter_mut().zip(&dx[t * d..(t + 1) * d]) {
                    *g += v;
                }
            }
        }
    }

    /// Column-parallel first linear + tanh of expert `ex`:
    /// `h_r = tanh(x W1_r + b1_r)`, rows × f.  Shard-local (no
    /// communication); blocked GEMM kernel.
    fn expert_h(&self, params: &[f32], ex: usize, x: &[f32]) -> Vec<f32> {
        let d = self.d();
        let f = self.f();
        let (o_w1, o_b1, _) = self.expert_off(ex);
        let (w1, b1) = (&params[o_w1..o_w1 + d * f], &params[o_b1..o_b1 + f]);
        let t_count = x.len() / d;
        let mut h = vec![0.0f32; t_count * f];
        for t in 0..t_count {
            h[t * f..(t + 1) * f].copy_from_slice(b1);
        }
        mm(self.policy.activation, &mut h, x, w1, t_count, d, f);
        for o in h.iter_mut() {
            *o = o.tanh();
        }
        // activation storage cast (the recomputing backward re-derives
        // the identical quantized h, so fwd and bwd agree)
        self.policy.activation.quantize_slice(&mut h);
        h
    }

    /// Dense first linear = expert 0's.
    fn first_linear(&self, params: &[f32], x: &[f32]) -> Vec<f32> {
        self.expert_h(params, 0, x)
    }

    /// Row-parallel second linear of expert `ex` WITHOUT the bias and
    /// activation cast: `all_reduce(h_r W2_r)`, rows × d (the Megatron
    /// forward `g`, one all-reduce).  The MoE combine mixes these raw
    /// outputs gate-weighted, then b2 and the cast land once on the
    /// mixture — for the dense block that is [`Self::second_linear`].
    fn expert_out(&self, comm: &TpComm, params: &[f32], ex: usize, h: &[f32]) -> Vec<f32> {
        let d = self.d();
        let f = self.f();
        let (_, _, o_w2) = self.expert_off(ex);
        let w2 = &params[o_w2..o_w2 + f * d];
        let t_count = h.len() / f;
        let mut y = vec![0.0f32; t_count * d];
        mm(self.policy.activation, &mut y, h, w2, t_count, f, d);
        comm.all_reduce_sum(&mut y);
        y
    }

    /// Add the replicated bias b2 and apply the block-output activation
    /// cast in place.
    fn add_b2_and_cast(&self, params: &[f32], y: &mut [f32]) {
        let d = self.d();
        let l = self.lay();
        let b2 = &params[l.b2..l.b2 + d];
        for row in y.chunks_exact_mut(d) {
            for (o, &bv) in row.iter_mut().zip(b2) {
                *o += bv;
            }
        }
        self.policy.activation.quantize_slice(y);
    }

    /// Dense second linear: expert 0's all-reduced output + b2 + cast.
    fn second_linear(&self, comm: &TpComm, params: &[f32], h: &[f32]) -> Vec<f32> {
        let mut y = self.expert_out(comm, params, 0, h);
        self.add_b2_and_cast(params, &mut y);
        y
    }

    /// Block forward: column-parallel linear -> tanh -> row-parallel
    /// linear (1 all-reduce).
    fn block_fwd(&self, comm: &TpComm, params: &[f32], x: &[f32]) -> Vec<f32> {
        let h = self.first_linear(params, x);
        self.second_linear(comm, params, &h)
    }

    /// Gate logits `x·Wg + bg` (T × E).  The gate is TP-replicated, so
    /// every shard computes identical logits with no communication;
    /// logits stay f32 like the head's — the top-k softmax is the
    /// numerically fragile path.
    fn gate_logits(&self, params: &[f32], x: &[f32]) -> Vec<f32> {
        let d = self.d();
        let e = self.spec.experts;
        let l = self.lay();
        let (gw, gb) = (&params[l.gw..l.gw + d * e], &params[l.gb..l.gb + e]);
        let t_count = x.len() / d;
        let mut logits = vec![0.0f32; t_count * e];
        for t in 0..t_count {
            logits[t * e..(t + 1) * e].copy_from_slice(gb);
        }
        mm(self.policy.activation, &mut logits, x, gw, t_count, d, e);
        logits
    }

    /// The forward routing decision, recomputed identically by the
    /// backward: trivial (everything to expert 0 with probability 1.0)
    /// for the single-expert block, top-k over the gate logits otherwise.
    fn route(&self, params: &[f32], x: &[f32]) -> moe::TopK {
        let e = self.spec.experts;
        let t_count = x.len() / self.d();
        if e == 1 {
            moe::TopK { expert: vec![0; t_count], prob: vec![1.0; t_count] }
        } else {
            moe::top_k_select(&self.gate_logits(params, x), t_count, e, self.spec.topk)
        }
    }

    /// Run every expert's MLP over its capacity buffer and return the
    /// TP-all-reduced raw outputs, expert-indexed.  Without expert
    /// parallelism every expert runs locally.  With it (`ctx.a2a`, an EP
    /// group of `ep > 1` data-parallel peers) each rank ships buffers to
    /// the expert owners over one `all_to_all`, computes its `E/ep` owned
    /// experts for every source rank — all-reducing each (expert, source)
    /// buffer separately, so the TP all-reduce count, sizes and chunking
    /// match `ep = 1` exactly — and a second `all_to_all` returns the
    /// outputs to their sources.  Parameters are DP-replicated, so any
    /// rank can stand in for any expert and fp32 results are bitwise
    /// ep-invariant.
    fn expert_outputs(
        &self,
        comm: &TpComm,
        params: &[f32],
        bufs: Vec<Vec<f32>>,
        cap: usize,
        ctx: &MoeFwdCtx,
    ) -> Vec<Vec<f32>> {
        let d = self.d();
        let e = self.spec.experts;
        let a2a = match &ctx.a2a {
            Some(a) if a.group.len() > 1 => a,
            _ => {
                return bufs
                    .iter()
                    .enumerate()
                    .map(|(ex, b)| {
                        let h = self.expert_h(params, ex, b);
                        self.expert_out(comm, params, ex, &h)
                    })
                    .collect();
            }
        };
        let ep = a2a.group.len();
        assert_eq!(e % ep, 0, "experts {e} not divisible by ep {ep}");
        let per = e / ep;
        let me = a2a.ep_rank;
        // dispatch: parts[dst] = the dst-owned expert buffers, expert-major
        let parts: Vec<Vec<f32>> = (0..ep)
            .map(|dst| {
                let mut p = Vec::with_capacity(per * cap * d);
                for eo in 0..per {
                    p.extend_from_slice(&bufs[dst * per + eo]);
                }
                p
            })
            .collect();
        let recv = a2a.group.all_to_all(me, a2a.tag_base, parts, ctx.wire);
        // compute owned experts for every source rank's tokens
        let rets: Vec<Vec<f32>> = (0..ep)
            .map(|src| {
                let mut r = Vec::with_capacity(per * cap * d);
                for eo in 0..per {
                    let ex = me * per + eo;
                    let buf = &recv[src][eo * cap * d..(eo + 1) * cap * d];
                    let h = self.expert_h(params, ex, buf);
                    r.extend_from_slice(&self.expert_out(comm, params, ex, &h));
                }
                r
            })
            .collect();
        // combine: outputs come back from each owner, expert-major
        let back = a2a.group.all_to_all(me, a2a.tag_base | 1, rets, ctx.wire);
        (0..e)
            .map(|ex| back[ex / per][(ex % per) * cap * d..(ex % per + 1) * cap * d].to_vec())
            .collect()
    }

    /// MoE block forward: gate -> capacity-bounded dispatch -> expert
    /// MLPs (one TP all-reduce each) -> gate-weighted combine -> b2 +
    /// cast.  With `experts = 1` every step degenerates to the dense
    /// block bitwise: the capacity clamp makes the buffer exactly the
    /// token batch, the route probability is exactly 1.0, and the
    /// combine accumulates `0.0 + 1.0·v` (the kernels never produce
    /// -0.0, so this is the identity).
    fn block_fwd_moe(&self, comm: &TpComm, params: &[f32], x: &[f32], ctx: &MoeFwdCtx) -> Vec<f32> {
        let d = self.d();
        let e = self.spec.experts;
        let k = self.spec.topk;
        let t_count = x.len() / d;
        let sel = self.route(params, x);
        let cap = moe::capacity(t_count, k, e, self.capacity_factor);
        let plan = moe::plan_dispatch(&sel, t_count, k, e, cap);
        if let Some(ctr) = ctx.dropped {
            ctr.fetch_add(plan.dropped, Ordering::Relaxed);
        }
        // capacity-padded per-expert input buffers (cap × d each)
        let bufs: Vec<Vec<f32>> = (0..e)
            .map(|ex| {
                let mut b = vec![0.0f32; cap * d];
                for &(tok, slot, _) in &plan.slots[ex] {
                    b[slot * d..(slot + 1) * d].copy_from_slice(&x[tok * d..(tok + 1) * d]);
                }
                b
            })
            .collect();
        let outs = self.expert_outputs(comm, params, bufs, cap, ctx);
        // gate-weighted combine, experts ascending then slots in token
        // order — one fixed association order at every ep
        let mut y = vec![0.0f32; t_count * d];
        for (ex, out) in outs.iter().enumerate() {
            for &(tok, slot, p) in &plan.slots[ex] {
                let row = &out[slot * d..(slot + 1) * d];
                for (o, &v) in y[tok * d..(tok + 1) * d].iter_mut().zip(row) {
                    *o += p * v;
                }
            }
        }
        self.add_b2_and_cast(params, &mut y);
        y
    }

    /// Forward dispatch on the block kind.
    fn block_fwd_any(&self, comm: &TpComm, params: &[f32], x: &[f32], ctx: &MoeFwdCtx) -> Vec<f32> {
        if self.spec.moe {
            self.block_fwd_moe(comm, params, x, ctx)
        } else {
            self.block_fwd(comm, params, x)
        }
    }

    /// Block backward given the stage input `x` and upstream grad `dy`
    /// (recomputes the shard-local forward — checkpointing semantics).
    /// Writes parameter grads into `g` and returns the full `dx`
    /// (all-reduced across the TP group: the Megatron backward `f`).
    fn block_bwd(&self, comm: &TpComm, params: &[f32], g: &mut [f32], x: &[f32], dy: &[f32]) -> Vec<f32> {
        let d = self.d();
        let f = self.f();
        let l = self.lay();
        let h = self.first_linear(params, x); // recompute
        let t_count = x.len() / d;
        let act = self.policy.activation;
        let (w1, w2) = (&params[l.w1..l.w1 + d * f], &params[l.w2..l.w2 + f * d]);
        // b2 grad (replicated parameter, dy already full); bias grads
        // accumulate in f32 on both policies
        kernels::col_sum_acc(&mut g[l.b2..l.b2 + d], dy, t_count, d);
        // dW2_r += h_rᵀ dy ;  dh_r = dy W2_rᵀ
        mm_at(act, &mut g[l.w2..l.w2 + f * d], &h, dy, t_count, f, d);
        let mut dh = vec![0.0f32; t_count * f];
        mm_bt(act, &mut dh, dy, w2, t_count, f, d);
        // through tanh: dpre = dh ⊙ (1 - h²)
        for (dp, &hv) in dh.iter_mut().zip(&h) {
            *dp *= 1.0 - hv * hv;
        }
        // gradient-activation storage cast before dpre feeds two GEMMs
        act.quantize_slice(&mut dh);
        kernels::col_sum_acc(&mut g[l.b1..l.b1 + f], &dh, t_count, f);
        // dW1_r += xᵀ dpre ;  dx_partial = dpre W1_rᵀ
        mm_at(act, &mut g[l.w1..l.w1 + d * f], x, &dh, t_count, d, f);
        let mut dx = vec![0.0f32; x.len()];
        mm_bt(act, &mut dx, &dh, w1, t_count, d, f);
        comm.all_reduce_sum(&mut dx);
        // gradient-activation cast on the dx handed upstream
        act.quantize_slice(&mut dx);
        dx
    }

    /// MoE block backward — entirely local (checkpointing semantics, no
    /// all-to-all): recomputes the routing, capacity buffers and hidden
    /// activations, backprops every expert, and closes the gate path with
    /// coefficients `c[t,j] = dy_t · out_e` from the recomputed (and
    /// TP-all-reduced, like the forward's) expert outputs.  Dropped
    /// assignments contributed nothing forward, so their coefficient is
    /// exactly the correct 0.  With `experts = 1` the gate path vanishes
    /// and every step matches [`Self::block_bwd`] bitwise.
    fn block_bwd_moe(
        &self,
        comm: &TpComm,
        params: &[f32],
        g: &mut [f32],
        x: &[f32],
        dy: &[f32],
    ) -> Vec<f32> {
        let d = self.d();
        let f = self.f();
        let e = self.spec.experts;
        let k = self.spec.topk;
        let l = self.lay();
        let act = self.policy.activation;
        let t_count = x.len() / d;
        let sel = self.route(params, x);
        let cap = moe::capacity(t_count, k, e, self.capacity_factor);
        let plan = moe::plan_dispatch(&sel, t_count, k, e, cap);
        // b2 grad first (replicated bias of the mixture, dy already full)
        kernels::col_sum_acc(&mut g[l.b2..l.b2 + d], dy, t_count, d);
        let mut coeff = vec![0.0f32; t_count * k];
        let mut dx = vec![0.0f32; x.len()];
        for ex in 0..e {
            let (o_w1, o_b1, o_w2) = self.expert_off(ex);
            let w1 = &params[o_w1..o_w1 + d * f];
            let w2 = &params[o_w2..o_w2 + f * d];
            // recompute the capacity buffer; the upstream grad of this
            // expert's raw output is the gate-scaled dy of each slot
            let mut buf = vec![0.0f32; cap * d];
            let mut dout = vec![0.0f32; cap * d];
            for &(tok, slot, p) in &plan.slots[ex] {
                buf[slot * d..(slot + 1) * d].copy_from_slice(&x[tok * d..(tok + 1) * d]);
                let src = &dy[tok * d..(tok + 1) * d];
                for (o, &v) in dout[slot * d..(slot + 1) * d].iter_mut().zip(src) {
                    *o += p * v;
                }
            }
            let h = self.expert_h(params, ex, &buf);
            if e > 1 {
                // gate coefficients need the forward's raw expert output
                let out = self.expert_out(comm, params, ex, &h);
                for &(tok, slot, _) in &plan.slots[ex] {
                    let j = sel.expert[tok * k..(tok + 1) * k]
                        .iter()
                        .position(|&se| se == ex)
                        .expect("routed expert present in its token's selection");
                    let mut c = 0.0f32;
                    let row = &out[slot * d..(slot + 1) * d];
                    for (a, b) in dy[tok * d..(tok + 1) * d].iter().zip(row) {
                        c += a * b;
                    }
                    coeff[tok * k + j] = c;
                }
            }
            // dW2 += h_rᵀ dout ;  dh_r = dout W2_rᵀ
            mm_at(act, &mut g[o_w2..o_w2 + f * d], &h, &dout, cap, f, d);
            let mut dh = vec![0.0f32; cap * f];
            mm_bt(act, &mut dh, &dout, w2, cap, f, d);
            for (dp, &hv) in dh.iter_mut().zip(&h) {
                *dp *= 1.0 - hv * hv;
            }
            act.quantize_slice(&mut dh);
            kernels::col_sum_acc(&mut g[o_b1..o_b1 + f], &dh, cap, f);
            // dW1 += bufᵀ dpre ;  dbuf = dpre W1_rᵀ
            mm_at(act, &mut g[o_w1..o_w1 + d * f], &buf, &dh, cap, d, f);
            let mut dbuf = vec![0.0f32; cap * d];
            mm_bt(act, &mut dbuf, &dh, w1, cap, d, f);
            // scatter slot grads back to their tokens (dout already
            // carried the gate probability; dropped tokens get nothing)
            for &(tok, slot, _) in &plan.slots[ex] {
                let row = &dbuf[slot * d..(slot + 1) * d];
                for (o, &v) in dx[tok * d..(tok + 1) * d].iter_mut().zip(row) {
                    *o += v;
                }
            }
        }
        if e > 1 {
            let dlogits = moe::gate_backward(&sel, &coeff, t_count, e, k);
            kernels::col_sum_acc(&mut g[l.gb..l.gb + e], &dlogits, t_count, e);
            mm_at(act, &mut g[l.gw..l.gw + d * e], x, &dlogits, t_count, d, e);
            // dx += dlogits Wgᵀ (the gate reads the block input too)
            mm_bt(act, &mut dx, &dlogits, &params[l.gw..l.gw + d * e], t_count, d, e);
        }
        comm.all_reduce_sum(&mut dx);
        act.quantize_slice(&mut dx);
        dx
    }

    /// Backward dispatch on the block kind.
    fn block_bwd_any(
        &self,
        comm: &TpComm,
        params: &[f32],
        g: &mut [f32],
        x: &[f32],
        dy: &[f32],
    ) -> Vec<f32> {
        if self.spec.moe {
            self.block_bwd_moe(comm, params, g, x, dy)
        } else {
            self.block_bwd(comm, params, g, x, dy)
        }
    }

    /// Vocab-parallel softmax-xent head: loss + gradient into the block
    /// output `y`.  Three reductions: all-reduce-max (stability), one
    /// packed all-reduce-sum for the per-token (sum-exp, target-logit)
    /// statistics, one all-reduce-sum for the input gradient `dy`.
    fn head_bwd(
        &self,
        comm: &TpComm,
        params: &[f32],
        gparams: &mut [f32],
        y: &[f32],
        targets: &[i32],
    ) -> (Vec<f32>, f32) {
        let d = self.d();
        let vs = self.vs();
        let vlo = self.vlo();
        let l = self.lay();
        let wh = &params[l.hw..l.hw + d * vs];
        let t_count = y.len() / d;
        let inv_t = 1.0 / t_count as f32;

        // local logit shard, T × vs (blocked GEMM); logits stay f32 —
        // the softmax statistics path is the numerically fragile one
        let mut logits = vec![0.0f32; t_count * vs];
        for t in 0..t_count {
            logits[t * vs..(t + 1) * vs].copy_from_slice(&params[l.hb..l.hb + vs]);
        }
        mm(self.policy.activation, &mut logits, y, wh, t_count, d, vs);
        // global per-token max for the stable softmax
        let mut mx: Vec<f32> = (0..t_count)
            .map(|t| {
                logits[t * vs..(t + 1) * vs]
                    .iter()
                    .cloned()
                    .fold(f32::NEG_INFINITY, f32::max)
            })
            .collect();
        comm.all_reduce_max(&mut mx);
        // packed statistics: stats[t] = Σ_u exp(l - M), stats[T + t] = the
        // shifted target logit (owner contributes, others add 0).
        // `logits` is exponentiated in place (softmax numerators).
        let mut stats = vec![0.0f32; 2 * t_count];
        for t in 0..t_count {
            let tgt = targets[t] as usize;
            let lo = &mut logits[t * vs..(t + 1) * vs];
            if tgt >= vlo && tgt < vlo + vs {
                stats[t_count + t] = lo[tgt - vlo] - mx[t];
            }
            let mut z = 0.0f32;
            for v in lo.iter_mut() {
                *v = (*v - mx[t]).exp();
                z += *v;
            }
            stats[t] = z;
        }
        comm.all_reduce_sum(&mut stats);
        let mut loss = 0.0f32;
        for t in 0..t_count {
            loss -= (stats[t_count + t] - stats[t].max(1e-30).ln()) * inv_t;
        }
        // dlogits = (softmax - onehot) / T ;  dy = all_reduce(dlogits Wᵀ)
        for t in 0..t_count {
            let z = stats[t].max(1e-30);
            let tgt = targets[t] as usize;
            let lo = &mut logits[t * vs..(t + 1) * vs];
            for (u, v) in lo.iter_mut().enumerate() {
                let one = f32::from(tgt >= vlo && tgt < vlo + vs && u == tgt - vlo);
                *v = (*v / z - one) * inv_t;
            }
        }
        kernels::col_sum_acc(&mut gparams[l.hb..l.hb + vs], &logits, t_count, vs);
        mm_at(self.policy.activation, &mut gparams[l.hw..l.hw + d * vs], y, &logits, t_count, d, vs);
        let mut dy = vec![0.0f32; y.len()];
        mm_bt(self.policy.activation, &mut dy, &logits, wh, t_count, d, vs);
        comm.all_reduce_sum(&mut dy);
        // gradient-activation cast on the loss gradient fed to the block
        self.policy.activation.quantize_slice(&mut dy);
        (dy, loss)
    }

    // ---- the stage entry points the worker drives ----
    //
    // Every entry point that runs a *scheduled* block forward (fwd_first,
    // fwd_mid, and the fused forwards inside bwd_last / bwd_single) has a
    // `_ctx` variant carrying the MoE wiring: the expert-parallel a2a
    // group, wire dtype, and the dropped-assignment counter.  The plain
    // names keep their legacy signatures and run expert-local
    // ([`MoeFwdCtx::LOCAL`]).  Backward recomputes are always local and
    // never count drops — only the scheduled forward charges them.

    /// First-stage forward: tokens -> activation.
    pub fn fwd_first(&self, comm: &TpComm, params: &[f32], tokens: &[i32]) -> Vec<f32> {
        self.fwd_first_ctx(comm, params, tokens, &MoeFwdCtx::LOCAL)
    }

    /// First-stage forward with MoE wiring.
    pub fn fwd_first_ctx(
        &self,
        comm: &TpComm,
        params: &[f32],
        tokens: &[i32],
        ctx: &MoeFwdCtx,
    ) -> Vec<f32> {
        let x = self.embed(comm, params, tokens);
        self.block_fwd_any(comm, params, &x, ctx)
    }

    /// Middle-stage forward: activation -> activation.
    pub fn fwd_mid(&self, comm: &TpComm, params: &[f32], x: &[f32]) -> Vec<f32> {
        self.fwd_mid_ctx(comm, params, x, &MoeFwdCtx::LOCAL)
    }

    /// Middle-stage forward with MoE wiring.
    pub fn fwd_mid_ctx(&self, comm: &TpComm, params: &[f32], x: &[f32], ctx: &MoeFwdCtx) -> Vec<f32> {
        self.block_fwd_any(comm, params, x, ctx)
    }

    /// Last-stage backward: (stage input, targets) -> (gparams, gx, loss).
    pub fn bwd_last(
        &self,
        comm: &TpComm,
        params: &[f32],
        x: &[f32],
        targets: &[i32],
    ) -> (Vec<f32>, Vec<f32>, f32) {
        self.bwd_last_ctx(comm, params, x, targets, &MoeFwdCtx::LOCAL)
    }

    /// Last-stage backward with MoE wiring for the fused block forward
    /// (the last stage's only scheduled forward — it dispatches over the
    /// a2a group and charges drops; the backward recompute stays local).
    pub fn bwd_last_ctx(
        &self,
        comm: &TpComm,
        params: &[f32],
        x: &[f32],
        targets: &[i32],
        ctx: &MoeFwdCtx,
    ) -> (Vec<f32>, Vec<f32>, f32) {
        let mut g = vec![0.0f32; params.len()];
        let y = self.block_fwd_any(comm, params, x, ctx);
        let (dy, loss) = self.head_bwd(comm, params, &mut g, &y, targets);
        let dx = self.block_bwd_any(comm, params, &mut g, x, &dy);
        self.policy.grad.quantize_slice(&mut g);
        (g, dx, loss)
    }

    /// Middle-stage backward: (stage input, upstream grad) -> (gparams, gx).
    pub fn bwd_mid(&self, comm: &TpComm, params: &[f32], x: &[f32], gy: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let mut g = vec![0.0f32; params.len()];
        let dx = self.block_bwd_any(comm, params, &mut g, x, gy);
        self.policy.grad.quantize_slice(&mut g);
        (g, dx)
    }

    /// First-stage backward: (tokens, upstream grad) -> gparams.
    pub fn bwd_first(&self, comm: &TpComm, params: &[f32], tokens: &[i32], gy: &[f32]) -> Vec<f32> {
        let mut g = vec![0.0f32; params.len()];
        let x = self.embed(comm, params, tokens);
        let dx = self.block_bwd_any(comm, params, &mut g, &x, gy);
        self.embed_bwd(&mut g, tokens, &dx);
        self.policy.grad.quantize_slice(&mut g);
        g
    }

    /// Fused single-stage backward (K = 1): (tokens, targets) ->
    /// (gparams, loss).
    pub fn bwd_single(
        &self,
        comm: &TpComm,
        params: &[f32],
        tokens: &[i32],
        targets: &[i32],
    ) -> (Vec<f32>, f32) {
        self.bwd_single_ctx(comm, params, tokens, targets, &MoeFwdCtx::LOCAL)
    }

    /// Fused single-stage backward with MoE wiring for the fused block
    /// forward (see [`Self::bwd_last_ctx`]).
    pub fn bwd_single_ctx(
        &self,
        comm: &TpComm,
        params: &[f32],
        tokens: &[i32],
        targets: &[i32],
        ctx: &MoeFwdCtx,
    ) -> (Vec<f32>, f32) {
        let mut g = vec![0.0f32; params.len()];
        let x = self.embed(comm, params, tokens);
        let y = self.block_fwd_any(comm, params, &x, ctx);
        let (dy, loss) = self.head_bwd(comm, params, &mut g, &y, targets);
        let dx = self.block_bwd_any(comm, params, &mut g, &x, &dy);
        self.embed_bwd(&mut g, tokens, &dx);
        self.policy.grad.quantize_slice(&mut g);
        (g, loss)
    }
}

/// Extract the shard `(tp, tp_rank)` slice of a *dense* flat vector for
/// stage `g` — the mapping [`BuiltinStage::init`] applies to each dense
/// component stream.  Works for parameter vectors and (because gradients
/// share the layout) gradient vectors; the tests use it to pin sharded
/// results to slices of the dense ones.
pub fn extract_shard(spec: &BuiltinSpec, g: usize, tp: usize, tp_rank: usize, dense: &[f32]) -> Vec<f32> {
    assert_eq!(dense.len(), spec.stage_params(g));
    let shard = BuiltinStage::sharded(spec.clone(), g, tp, tp_rank);
    let d = spec.hidden;
    let v = spec.vocab;
    let f = d / tp;
    let vs = v / tp;
    let flo = tp_rank * f;
    let vlo = tp_rank * vs;
    let mut out = Vec::with_capacity(shard.param_count());
    let mut off = 0;
    if g == 0 {
        out.extend_from_slice(&dense[vlo * d..(vlo + vs) * d]);
        off += v * d;
    }
    for _ex in 0..spec.experts {
        // W1 columns
        for i in 0..d {
            let row = off + i * d + flo;
            out.extend_from_slice(&dense[row..row + f]);
        }
        off += d * d;
        // b1 slice
        out.extend_from_slice(&dense[off + flo..off + flo + f]);
        off += d;
        // W2 rows
        out.extend_from_slice(&dense[off + flo * d..off + (flo + f) * d]);
        off += d * d;
    }
    // b2 replicated
    out.extend_from_slice(&dense[off..off + d]);
    off += d;
    // gate replicated (weight + bias)
    let gate = spec.gate_params();
    out.extend_from_slice(&dense[off..off + gate]);
    off += gate;
    if g == spec.n_stages - 1 {
        // head W columns
        for i in 0..d {
            let row = off + i * v + vlo;
            out.extend_from_slice(&dense[row..row + vs]);
        }
        off += d * v;
        // head bias slice
        out.extend_from_slice(&dense[off + vlo..off + vlo + vs]);
        off += v;
    }
    assert_eq!(off, dense.len());
    assert_eq!(out.len(), shard.param_count());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{Group, SubGroup};
    use std::sync::Arc;
    use std::thread;

    fn spec(k: usize) -> BuiltinSpec {
        BuiltinSpec::parse(&format!("builtin:tiny-s{k}-mb2")).unwrap()
    }

    fn stage(sp: &BuiltinSpec, g: usize) -> BuiltinStage {
        BuiltinStage::dense(sp.clone(), g)
    }

    fn solo() -> TpComm {
        TpComm::solo()
    }

    fn test_tokens(sp: &BuiltinSpec, mul: usize, add: usize) -> (Vec<i32>, Vec<i32>) {
        let t = sp.mbs * sp.seq;
        let tokens: Vec<i32> = (0..t).map(|i| (i * mul % sp.vocab) as i32).collect();
        let targets: Vec<i32> = (0..t).map(|i| ((i * mul + add) % sp.vocab) as i32).collect();
        (tokens, targets)
    }

    /// Run `f(tp_rank, comm)` on `tp` threads sharing one TP group.
    fn run_tp<T, F>(tp: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize, TpComm) -> T + Send + Sync + 'static,
    {
        let world = Group::new(tp);
        let sub = SubGroup::new(&world, (0..tp).collect(), 0);
        let f = Arc::new(f);
        let handles: Vec<_> = (0..tp)
            .map(|r| {
                let comm = TpComm::new(sub.clone(), r);
                let f = f.clone();
                thread::spawn(move || f(r, comm))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn parse_bundle_names() {
        let sp = BuiltinSpec::parse("builtin:tiny-s4-mb2").unwrap();
        assert_eq!((sp.n_stages, sp.mbs, sp.hidden), (4, 2, 16));
        assert!(BuiltinSpec::parse("tiny-s4-mb2").is_none());
        assert!(BuiltinSpec::parse("builtin:nope-s4-mb2").is_none());
        assert!(BuiltinSpec::parse("builtin:tiny-s0-mb2").is_none());
    }

    #[test]
    fn stage_params_sum_to_total() {
        for k in [1usize, 2, 4] {
            let sp = spec(k);
            let sum: usize = (0..k).map(|g| sp.stage_params(g)).sum();
            assert_eq!(sum, sp.total_params());
            for g in 0..k {
                assert_eq!(stage(&sp, g).init(7).len(), sp.stage_params(g));
            }
        }
    }

    #[test]
    fn shard_params_account_for_replication() {
        // shards hold 1/tp of everything except the replicated b2
        for k in [1usize, 2, 4] {
            let sp = spec(k);
            for tp in [2usize, 4, 8] {
                assert!(sp.tp_ok(tp));
                for g in 0..k {
                    let dense = sp.stage_params(g);
                    let shard = sp.shard_stage_params(g, tp);
                    // dense splits exactly except b2 (d) replicated per shard
                    let replicated_extra = sp.hidden - sp.hidden / tp;
                    assert_eq!(shard, dense / tp + replicated_extra, "k={k} tp={tp} g={g}");
                    let st = BuiltinStage::sharded(sp.clone(), g, tp, tp - 1);
                    assert_eq!(st.init(7).len(), shard);
                }
            }
        }
        assert!(!spec(1).tp_ok(3));
    }

    #[test]
    fn init_is_partition_invariant() {
        // block 1's W1 must be identical whether the model is cut into 2
        // or 4 stages (global component keys)
        let s2 = stage(&spec(2), 1);
        let s4 = stage(&spec(4), 1);
        let p2 = s2.init(42);
        let p4 = s4.init(42);
        let d = 16;
        assert_eq!(&p2[..d * d], &p4[..d * d]);
    }

    #[test]
    fn init_is_shard_invariant() {
        // each shard's init is exactly its slice of the dense init
        for k in [1usize, 2] {
            let sp = spec(k);
            for g in 0..k {
                let dense = stage(&sp, g).init(42);
                for tp in [2usize, 4] {
                    for r in 0..tp {
                        let st = BuiltinStage::sharded(sp.clone(), g, tp, r);
                        assert_eq!(
                            st.init(42),
                            extract_shard(&sp, g, tp, r, &dense),
                            "k={k} g={g} tp={tp} r={r}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn gradcheck_single_stage() {
        // finite differences on the fused dense path (the multi-stage
        // paths are compositions of the same block/head/embed pieces)
        let sp = spec(1);
        let st = stage(&sp, 0);
        let comm = solo();
        let mut params = st.init(3);
        let (tokens, targets) = test_tokens(&sp, 7, 1);
        let (g, _) = st.bwd_single(&comm, &params, &tokens, &targets);
        let eps = 1e-3f32;
        let mut worst = 0.0f32;
        // embed, W1, b1, W2, b2, head W, head b probes
        let d = sp.hidden;
        let e = sp.embed_params();
        for idx in [
            0usize,
            100,
            e + 3,                       // W1
            e + d * d + 2,               // b1
            e + d * d + d + 11,          // W2
            e + 2 * d * d + d + 5,       // b2
            e + sp.layer_params() + 17,  // head W
            params.len() - 1,            // head b
        ] {
            let orig = params[idx];
            params[idx] = orig + eps;
            let (_, lp) = st.bwd_single(&comm, &params, &tokens, &targets);
            params[idx] = orig - eps;
            let (_, lm) = st.bwd_single(&comm, &params, &tokens, &targets);
            params[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            worst = worst.max((fd - g[idx]).abs());
        }
        assert!(worst < 2e-3, "finite-diff mismatch: {worst}");
    }

    #[test]
    fn sharded_matches_dense_tp2_tp4() {
        // forward activations, loss and every shard gradient must equal
        // the dense run (up to fp association order)
        let sp = spec(1);
        let st_dense = stage(&sp, 0);
        let comm = solo();
        let pd = st_dense.init(11);
        let (tokens, targets) = test_tokens(&sp, 5, 2);
        let y_dense = st_dense.fwd_first(&comm, &pd, &tokens);
        let (gd, loss_dense) = st_dense.bwd_single(&comm, &pd, &tokens, &targets);

        for tp in [2usize, 4] {
            let sp2 = sp.clone();
            let tk = tokens.clone();
            let tg = targets.clone();
            let results = run_tp(tp, move |r, comm| {
                let st = BuiltinStage::sharded(sp2.clone(), 0, tp, r);
                let p = st.init(11);
                let y = st.fwd_first(&comm, &p, &tk);
                let (g, loss) = st.bwd_single(&comm, &p, &tk, &tg);
                (y, g, loss)
            });
            for (r, (y, g, loss)) in results.into_iter().enumerate() {
                assert!(
                    (loss - loss_dense).abs() < 1e-4,
                    "tp={tp} r={r}: loss {loss} vs {loss_dense}"
                );
                for (a, b) in y.iter().zip(&y_dense) {
                    assert!((a - b).abs() < 1e-4, "tp={tp} r={r} fwd: {a} vs {b}");
                }
                let want = extract_shard(&sp, 0, tp, r, &gd);
                assert_eq!(g.len(), want.len());
                for (i, (a, b)) in g.iter().zip(&want).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-4,
                        "tp={tp} r={r} grad[{i}]: {a} vs {b}"
                    );
                }
            }
        }
    }

    /// Sharded 2-stage chain: fwd_first -> bwd_last -> bwd_first, with the
    /// loss recomputed under parameter perturbations for finite
    /// differencing.  Returns (loss, g0 shards, g1 shards).
    #[allow(clippy::type_complexity)]
    fn tp_chain(
        sp: &BuiltinSpec,
        tp: usize,
        p0: Vec<Vec<f32>>,
        p1: Vec<Vec<f32>>,
        tokens: Vec<i32>,
        targets: Vec<i32>,
    ) -> (f32, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let sp = sp.clone();
        let results = run_tp(tp, move |r, comm| {
            let s0 = BuiltinStage::sharded(sp.clone(), 0, tp, r);
            let s1 = BuiltinStage::sharded(sp.clone(), 1, tp, r);
            let y = s0.fwd_first(&comm, &p0[r], &tokens);
            let (g1, gx, loss) = s1.bwd_last(&comm, &p1[r], &y, &targets);
            let g0 = s0.bwd_first(&comm, &p0[r], &tokens, &gx);
            (loss, g0, g1)
        });
        let loss = results[0].0;
        let g0 = results.iter().map(|r| r.1.clone()).collect();
        let g1 = results.iter().map(|r| r.2.clone()).collect();
        (loss, g0, g1)
    }

    #[test]
    fn gradcheck_sharded_paths() {
        // finite differences THROUGH the communicating sharded stages at
        // tp ∈ {2, 4}: perturb one element of one shard, re-run the whole
        // TP group, compare the loss slope to the analytic shard gradient.
        // Probes cover every sharded component: vocab-sharded embed,
        // column-parallel W1/b1, row-parallel W2, replicated b2,
        // vocab-parallel head W/bias.
        let sp = spec(2);
        let (tokens, targets) = test_tokens(&sp, 5, 1);
        for tp in [2usize, 4] {
            let shards0: Vec<Vec<f32>> =
                (0..tp).map(|r| BuiltinStage::sharded(sp.clone(), 0, tp, r).init(9)).collect();
            let shards1: Vec<Vec<f32>> =
                (0..tp).map(|r| BuiltinStage::sharded(sp.clone(), 1, tp, r).init(9)).collect();
            let (_, g0, g1) = tp_chain(
                &sp,
                tp,
                shards0.clone(),
                shards1.clone(),
                tokens.clone(),
                targets.clone(),
            );

            let d = sp.hidden;
            let f = d / tp;
            let vs = sp.vocab / tp;
            let embed = vs * d;
            // probes: (stage, rank, shard index, replicated).  b2 is
            // REPLICATED — the analytic gradient treats it as one shared
            // parameter (every shard computes the identical db2), so its
            // finite-diff probe must move every shard's copy together.
            let l1 = sp.shard_layer_params(tp);
            let probes = [
                (0usize, 0usize, 3usize, false),            // embed row
                (0, tp - 1, embed + 1, false),              // W1 column
                (0, 0, embed + d * f + 1, false),           // b1 slice
                (0, tp - 1, embed + d * f + f + 2, false),  // W2 row
                (0, 0, embed + d * f + f + f * d + 3, true), // b2 (replicated)
                (1, 0, 1, false),                           // W1
                (1, tp - 1, l1 - 2, true),                  // b2 (replicated)
                (1, 0, l1 + 4, false),                      // head W
                (1, tp - 1, l1 + d * vs + 1, false),        // head b
            ];
            let eps = 1e-3f32;
            let mut worst = 0.0f32;
            for &(stage_idx, r, idx, replicated) in probes.iter() {
                let perturb = |delta: f32| -> f32 {
                    let mut s0 = shards0.clone();
                    let mut s1 = shards1.clone();
                    let bumped = if stage_idx == 0 { &mut s0 } else { &mut s1 };
                    if replicated {
                        for shard in bumped.iter_mut() {
                            shard[idx] += delta;
                        }
                    } else {
                        bumped[r][idx] += delta;
                    }
                    tp_chain(&sp, tp, s0, s1, tokens.clone(), targets.clone()).0
                };
                let fd = (perturb(eps) - perturb(-eps)) / (2.0 * eps);
                let analytic = if stage_idx == 0 { g0[r][idx] } else { g1[r][idx] };
                worst = worst.max((fd - analytic).abs());
            }
            assert!(worst < 2e-3, "tp={tp}: finite-diff mismatch {worst}");
        }
    }

    #[test]
    fn pipeline_composition_matches_fused() {
        // chaining stage entry points across a 2-stage cut must match a
        // finite-diff through the composed forward wrt a stage-0 weight
        let sp = spec(2);
        let s0 = stage(&sp, 0);
        let s1 = stage(&sp, 1);
        let comm = solo();
        let p0 = s0.init(9);
        let p1 = s1.init(9);
        let (tokens, targets) = test_tokens(&sp, 5, 1);

        let y0 = s0.fwd_first(&comm, &p0, &tokens);
        let (g1, gx, loss) = s1.bwd_last(&comm, &p1, &y0, &targets);
        let g0 = s0.bwd_first(&comm, &p0, &tokens, &gx);
        assert!(loss.is_finite() && loss > 0.0);
        assert!(g0.iter().any(|&x| x != 0.0));
        assert!(g1.iter().any(|&x| x != 0.0));

        let fwd_loss = |p0: &[f32]| -> f32 {
            let y0 = s0.fwd_first(&comm, p0, &tokens);
            let (_, _, l) = s1.bwd_last(&comm, &p1, &y0, &targets);
            l
        };
        let idx = sp.embed_params() + 3; // a W1 element
        let eps = 1e-3f32;
        let mut pp = p0.clone();
        pp[idx] += eps;
        let lp = fwd_loss(&pp);
        pp[idx] = p0[idx] - eps;
        let lm = fwd_loss(&pp);
        let fd = (lp - lm) / (2.0 * eps);
        assert!((fd - g0[idx]).abs() < 2e-3, "fd {fd} vs analytic {}", g0[idx]);
    }

    #[test]
    fn bf16_policy_stays_on_grid_and_tracks_fp32() {
        // the bf16 cast points: init / grads constrained to the grid,
        // loss and gradients tracking the fp32 stage within bf16 noise
        let sp = spec(1);
        let comm = solo();
        let fp = stage(&sp, 0);
        let bf = stage(&sp, 0).with_policy(CastPolicy::bf16());
        let (tokens, targets) = test_tokens(&sp, 7, 1);
        let p32 = fp.init(3);
        let p16 = bf.init(3);
        assert_eq!(p16.len(), p32.len());
        for (i, (a, b)) in p16.iter().zip(&p32).enumerate() {
            assert_eq!(
                a.to_bits(),
                Dtype::Bf16.quantize(*b).to_bits(),
                "init[{i}] must be the quantized fp32 init"
            );
        }
        let y32 = fp.fwd_first(&comm, &p32, &tokens);
        let y16 = bf.fwd_first(&comm, &p16, &tokens);
        for (i, (a, b)) in y16.iter().zip(&y32).enumerate() {
            assert_eq!(a.to_bits(), Dtype::Bf16.quantize(*a).to_bits(), "act[{i}] off grid");
            assert!((a - b).abs() < 0.05 * b.abs() + 0.05, "act[{i}]: {a} vs {b}");
        }
        let (g32, l32) = fp.bwd_single(&comm, &p32, &tokens, &targets);
        let (g16, l16) = bf.bwd_single(&comm, &p16, &tokens, &targets);
        assert!(l16.is_finite());
        assert!((l16 - l32).abs() < 0.05 * l32.abs().max(1.0), "loss {l16} vs {l32}");
        for (i, (a, b)) in g16.iter().zip(&g32).enumerate() {
            assert_eq!(a.to_bits(), Dtype::Bf16.quantize(*a).to_bits(), "grad[{i}] off grid");
            assert!((a - b).abs() < 0.05 * b.abs() + 5e-3, "grad[{i}]: {a} vs {b}");
        }
    }

    #[test]
    fn replicated_b2_grad_identical_across_shards() {
        // the TP grad-sync invariant: every shard computes the same b2
        // gradient before any synchronisation
        let sp = spec(1);
        let (tokens, targets) = test_tokens(&sp, 3, 1);
        let tp = 4;
        let sp2 = sp.clone();
        let results = run_tp(tp, move |r, comm| {
            let st = BuiltinStage::sharded(sp2.clone(), 0, tp, r);
            let p = st.init(21);
            let (g, _) = st.bwd_single(&comm, &p, &tokens, &targets);
            let (lo, hi) = st.replicated_span();
            g[lo..hi].to_vec()
        });
        for r in 1..tp {
            for (a, b) in results[0].iter().zip(&results[r]) {
                assert!((a - b).abs() < 1e-6, "shard {r}: {a} vs {b}");
            }
        }
    }

    // ---- MoE stage family ----

    #[test]
    fn parse_moe_bundle_names() {
        let sp = BuiltinSpec::parse("builtin:tiny-moe4k2-s2-mb2").unwrap();
        assert_eq!((sp.experts, sp.topk, sp.moe), (4, 2, true));
        assert_eq!((sp.n_stages, sp.hidden), (2, 16));
        let sp = BuiltinSpec::parse("builtin:mini-moe8-s1-mb2").unwrap();
        assert_eq!((sp.experts, sp.topk, sp.moe), (8, 1, true));
        let sp = BuiltinSpec::parse("builtin:tiny-moe1-s1-mb2").unwrap();
        assert_eq!((sp.experts, sp.topk, sp.moe), (1, 1, true));
        assert_eq!(sp.gate_params(), 0, "single-expert MoE carries no gate");
        let dense = BuiltinSpec::parse("builtin:tiny-s1-mb2").unwrap();
        assert_eq!((dense.experts, dense.topk, dense.moe), (1, 1, false));
        assert_eq!(sp.total_params(), dense.total_params());
        // malformed MoE suffixes
        for bad in [
            "builtin:tiny-moe0-s1-mb2",
            "builtin:tiny-moe2k0-s1-mb2",
            "builtin:tiny-moe2k3-s1-mb2",
            "builtin:tiny-moek2-s1-mb2",
            "builtin:nope-moe4-s1-mb2",
        ] {
            assert!(BuiltinSpec::parse(bad).is_none(), "{bad} must not parse");
        }
    }

    #[test]
    fn moe_param_accounting_and_init() {
        let sp = BuiltinSpec::parse("builtin:tiny-moe4k2-s2-mb2").unwrap();
        let d = sp.hidden;
        assert_eq!(sp.gate_params(), d * 4 + 4);
        assert_eq!(sp.layer_params(), 4 * (2 * d * d + d) + d + sp.gate_params());
        let sum: usize = (0..sp.n_stages).map(|g| sp.stage_params(g)).sum();
        assert_eq!(sum, sp.total_params());
        for g in 0..sp.n_stages {
            assert_eq!(stage(&sp, g).init(7).len(), sp.stage_params(g));
            for tp in [2usize, 4] {
                let st = BuiltinStage::sharded(sp.clone(), g, tp, tp - 1);
                assert_eq!(st.init(7).len(), sp.shard_stage_params(g, tp));
            }
        }
        // shard init is the extract_shard slice of the dense init
        for g in 0..sp.n_stages {
            let dense = stage(&sp, g).init(42);
            for tp in [2usize, 4] {
                for r in 0..tp {
                    let st = BuiltinStage::sharded(sp.clone(), g, tp, r);
                    assert_eq!(st.init(42), extract_shard(&sp, g, tp, r, &dense), "g={g} tp={tp} r={r}");
                }
            }
        }
        // expert 0 shares the dense layer stream; the gate stream is new
        let dn = BuiltinSpec::parse("builtin:tiny-s2-mb2").unwrap();
        let pm = stage(&sp, 1).init(42);
        let pd = stage(&dn, 1).init(42);
        assert_eq!(&pm[..d * d], &pd[..d * d], "expert 0 W1 = dense W1");
    }

    #[test]
    fn moe1_matches_dense_bitwise() {
        // the `-moe1` bundle routes through capacity buffers, dispatch
        // plan and gate-weighted combine, yet must reproduce the dense
        // block BIT FOR BIT on both precisions: init, forward, loss and
        // every gradient
        let dn = BuiltinSpec::parse("builtin:tiny-s1-mb2").unwrap();
        let mo = BuiltinSpec::parse("builtin:tiny-moe1-s1-mb2").unwrap();
        let (tokens, targets) = test_tokens(&dn, 7, 1);
        for policy in [CastPolicy::fp32(), CastPolicy::bf16()] {
            let comm = solo();
            let sd = stage(&dn, 0).with_policy(policy);
            let sm = stage(&mo, 0).with_policy(policy);
            let pd = sd.init(11);
            let pm = sm.init(11);
            assert_eq!(bits(&pd), bits(&pm), "init");
            let yd = sd.fwd_first(&comm, &pd, &tokens);
            let ym = sm.fwd_first(&comm, &pm, &tokens);
            assert_eq!(bits(&yd), bits(&ym), "forward");
            let (gd, ld) = sd.bwd_single(&comm, &pd, &tokens, &targets);
            let (gm, lm) = sm.bwd_single(&comm, &pm, &tokens, &targets);
            assert_eq!(ld.to_bits(), lm.to_bits(), "loss");
            assert_eq!(bits(&gd), bits(&gm), "grads");
        }
        // and through the communicating sharded path at fp32
        let tk = tokens.clone();
        let tg = targets.clone();
        let (dn2, mo2) = (dn.clone(), mo.clone());
        let results = run_tp(2, move |r, comm| {
            let sd = BuiltinStage::sharded(dn2.clone(), 0, 2, r);
            let sm = BuiltinStage::sharded(mo2.clone(), 0, 2, r);
            let pd = sd.init(11);
            let pm = sm.init(11);
            let yd = sd.fwd_first(&comm, &pd, &tk);
            let ym = sm.fwd_first(&comm, &pm, &tk);
            let (gd, ld) = sd.bwd_single(&comm, &pd, &tk, &tg);
            let (gm, lm) = sm.bwd_single(&comm, &pm, &tk, &tg);
            (bits(&yd) == bits(&ym), bits(&gd) == bits(&gm), ld.to_bits() == lm.to_bits())
        });
        for (r, ok) in results.iter().enumerate() {
            assert_eq!(*ok, (true, true, true), "tp=2 shard {r}");
        }
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn moe_gradcheck_dense() {
        // finite differences through gate -> dispatch -> experts ->
        // combine on the fused dense path; capacity factor 2.0 keeps
        // every assignment (cap = T), so the loss is differentiable
        // everywhere the routing is stable
        let sp = BuiltinSpec::parse("builtin:tiny-moe4k2-s1-mb2").unwrap();
        let st = stage(&sp, 0).with_capacity_factor(2.0);
        let comm = solo();
        let mut params = st.init(3);
        let (tokens, targets) = test_tokens(&sp, 7, 1);
        let (g, _) = st.bwd_single(&comm, &params, &tokens, &targets);
        let d = sp.hidden;
        let e = sp.embed_params();
        let per = 2 * d * d + d;
        let gate_off = e + 4 * per + d;
        let eps = 1e-3f32;
        let mut worst = 0.0f32;
        for idx in [
            e + 3,                   // expert 0 W1
            e + per + d * d + 2,     // expert 1 b1
            e + 2 * per + d * d + d + 11, // expert 2 W2
            e + 3 * per + 5,         // expert 3 W1
            e + 4 * per + 5,         // b2
            gate_off + 7,            // gate W
            gate_off + 4 * d + 2,    // gate bias
            e + sp.layer_params() + 17, // head W
            params.len() - 1,        // head b
        ] {
            let orig = params[idx];
            params[idx] = orig + eps;
            let (_, lp) = st.bwd_single(&comm, &params, &tokens, &targets);
            params[idx] = orig - eps;
            let (_, lm) = st.bwd_single(&comm, &params, &tokens, &targets);
            params[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            worst = worst.max((fd - g[idx]).abs());
        }
        assert!(worst < 2e-3, "finite-diff mismatch: {worst}");
    }

    #[test]
    fn moe_sharded_matches_dense() {
        // the TP-sharded MoE block (default capacity factor, so real
        // token drops happen identically on every shard) must track the
        // dense MoE run within fp association noise
        let sp = BuiltinSpec::parse("builtin:tiny-moe4k2-s1-mb2").unwrap();
        let st_dense = stage(&sp, 0);
        let comm = solo();
        let pd = st_dense.init(11);
        let (tokens, targets) = test_tokens(&sp, 5, 2);
        let y_dense = st_dense.fwd_first(&comm, &pd, &tokens);
        let (gd, loss_dense) = st_dense.bwd_single(&comm, &pd, &tokens, &targets);

        for tp in [2usize, 4] {
            let sp2 = sp.clone();
            let tk = tokens.clone();
            let tg = targets.clone();
            let results = run_tp(tp, move |r, comm| {
                let st = BuiltinStage::sharded(sp2.clone(), 0, tp, r);
                let p = st.init(11);
                let y = st.fwd_first(&comm, &p, &tk);
                let (g, loss) = st.bwd_single(&comm, &p, &tk, &tg);
                (y, g, loss)
            });
            for (r, (y, g, loss)) in results.into_iter().enumerate() {
                assert!((loss - loss_dense).abs() < 1e-4, "tp={tp} r={r}: loss {loss} vs {loss_dense}");
                for (a, b) in y.iter().zip(&y_dense) {
                    assert!((a - b).abs() < 1e-4, "tp={tp} r={r} fwd: {a} vs {b}");
                }
                let want = extract_shard(&sp, 0, tp, r, &gd);
                assert_eq!(g.len(), want.len());
                for (i, (a, b)) in g.iter().zip(&want).enumerate() {
                    assert!((a - b).abs() < 1e-4, "tp={tp} r={r} grad[{i}]: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn moe_capacity_drops_are_counted() {
        use std::sync::atomic::AtomicU64;
        // capacity factor 0.5 with top-1-of-4 caps each expert at 2 of
        // 16 tokens: at least half the assignments must drop, the
        // scheduled forward charges them to the counter, and the
        // backward recompute charges nothing
        let sp = BuiltinSpec::parse("builtin:tiny-moe4k1-s1-mb2").unwrap();
        let st = stage(&sp, 0).with_capacity_factor(0.5);
        let comm = solo();
        let params = st.init(5);
        let (tokens, targets) = test_tokens(&sp, 7, 1);
        let dropped = AtomicU64::new(0);
        let ctx = MoeFwdCtx { a2a: None, wire: Dtype::F32, dropped: Some(&dropped) };
        let y = st.fwd_first_ctx(&comm, &params, &tokens, &ctx);
        assert!(y.iter().all(|v| v.is_finite()));
        let n1 = dropped.load(Ordering::Relaxed);
        assert!(n1 >= 8, "cap 2×4 over 16 tokens must drop ≥ 8, got {n1}");
        // deterministic: the same forward drops the same count
        st.fwd_first_ctx(&comm, &params, &tokens, &ctx);
        assert_eq!(dropped.load(Ordering::Relaxed), 2 * n1);
        // fused bwd charges its forward once; grads stay finite
        let (g, loss) = st.bwd_single_ctx(&comm, &params, &tokens, &targets, &ctx);
        assert_eq!(dropped.load(Ordering::Relaxed), 3 * n1);
        assert!(loss.is_finite());
        assert!(g.iter().all(|v| v.is_finite()));
        assert!(g.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn moe_replicated_gate_grads_identical_across_shards() {
        // the TP grad-sync invariant extends to the gate: every shard
        // computes the same router gradient before any synchronisation
        let sp = BuiltinSpec::parse("builtin:tiny-moe4k2-s1-mb2").unwrap();
        let d = sp.hidden;
        assert_eq!(
            {
                let st = stage(&sp, 0);
                let (lo, hi) = st.replicated_span();
                hi - lo
            },
            d + d * 4 + 4,
            "replicated span = b2 + gate W + gate bias"
        );
        let (tokens, targets) = test_tokens(&sp, 3, 1);
        let tp = 2;
        let sp2 = sp.clone();
        let results = run_tp(tp, move |r, comm| {
            let st = BuiltinStage::sharded(sp2.clone(), 0, tp, r);
            let p = st.init(21);
            let (g, _) = st.bwd_single(&comm, &p, &tokens, &targets);
            let (lo, hi) = st.replicated_span();
            g[lo..hi].to_vec()
        });
        for r in 1..tp {
            for (a, b) in results[0].iter().zip(&results[r]) {
                assert!((a - b).abs() < 1e-6, "shard {r}: {a} vs {b}");
            }
        }
    }
}
